//! Network-intrusion monitoring scenario (the KDDCup99 motivation from the
//! paper's intro): cluster a live stream of connection records, watch for
//! the emergence of *new* dense clusters (attack bursts), and report how
//! quickly the dynamic structure surfaces them.
//!
//! The stream interleaves background traffic with a burst of "smurf-like"
//! attack records injected midway; a static or fixed-core clustering would
//! need a full recompute to see the new cluster — `DynamicDbscan` exposes
//! it within one batch.
//!
//! ```bash
//! cargo run --release --example intrusion_detection
//! ```

use dyn_dbscan::data::synth::{load, PaperDataset};
use dyn_dbscan::dbscan::{DbscanConfig, DynamicDbscan};
use dyn_dbscan::experiments::{PAPER_EPS, PAPER_K, PAPER_T};
use dyn_dbscan::util::rng::Rng;

fn main() {
    let seed = 7;
    // background: the kddcup stand-in (imbalanced, 23 classes, d=20)
    let ds = load(PaperDataset::KddCup99, 0.02, seed);
    println!(
        "background traffic: n={} d={} classes={}",
        ds.n(),
        ds.dim,
        ds.num_clusters()
    );
    let cfg = DbscanConfig {
        k: PAPER_K,
        t: PAPER_T,
        eps: PAPER_EPS,
        dim: ds.dim,
        eager_attach: true, // serving mode: adopt stragglers immediately
    };
    let mut db = DynamicDbscan::new(cfg, seed);
    let mut rng = Rng::new(seed ^ 0xFEED);

    // a previously unseen attack signature: tight cluster far from data
    let attack_center: Vec<f32> = (0..ds.dim).map(|j| 6.0 + (j % 3) as f32).collect();
    let mut attack_ids: Vec<u64> = Vec::new();

    let batch = 500;
    let inject_at = ds.n() / 2;
    let mut inserted = 0;
    let mut batches = 0;
    let t0 = std::time::Instant::now();
    while inserted < ds.n() {
        let end = (inserted + batch).min(ds.n());
        for i in inserted..end {
            db.add_point(ds.point(i));
        }
        // injection: a burst of 80 attack records in one batch
        if inserted < inject_at && end >= inject_at {
            for _ in 0..80 {
                let p: Vec<f32> = attack_center
                    .iter()
                    .map(|&c| c + 0.05 * rng.normal() as f32)
                    .collect();
                attack_ids.push(db.add_point(&p));
            }
            println!(
                "batch {batches}: >>> injected attack burst (80 records) <<<"
            );
        }
        inserted = end;
        batches += 1;

        // detection probe: is the attack burst a coherent dense cluster?
        if !attack_ids.is_empty() {
            let cores = attack_ids.iter().filter(|&&p| db.is_core(p)).count();
            let same = {
                let c0 = db.get_cluster(attack_ids[0]);
                attack_ids.iter().filter(|&&p| db.get_cluster(p) == c0).count()
            };
            println!(
                "batch {batches}: live={} attack cores={cores}/80, largest-attack-cluster={same}/80",
                db.num_points()
            );
            if cores >= 60 && same >= 70 && batches % 4 == 0 {
                println!("batch {batches}: ALERT — dense novel cluster stable");
            }
        }
    }
    println!(
        "\nprocessed {} records (+80 injected) in {:.2}s ({:.0} rec/s)",
        ds.n(),
        t0.elapsed().as_secs_f64(),
        (ds.n() + 80) as f64 / t0.elapsed().as_secs_f64()
    );
    // the attack cluster must be detected as core + coherent
    let cores = attack_ids.iter().filter(|&&p| db.is_core(p)).count();
    assert!(cores > 60, "attack burst not detected as dense ({cores}/80 cores)");
    println!("attack burst detected: {cores}/80 records are core points");

    // forensic cleanup: retract the attack records (e.g. after mitigation)
    for p in attack_ids {
        db.delete_point(p);
    }
    db.verify().expect("structure healthy after cleanup");
    println!("post-cleanup invariants OK ({} live points)", db.num_points());
}
