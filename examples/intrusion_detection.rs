//! Network-intrusion monitoring scenario (the KDDCup99 motivation from the
//! paper's intro): cluster a live stream of connection records, watch for
//! the emergence of *new* dense clusters (attack bursts), and report how
//! quickly the dynamic structure surfaces them.
//!
//! The stream interleaves background traffic with a burst of "smurf-like"
//! attack records injected midway. Detection runs on the serve façade's
//! read surface: publish after each batch, then probe the snapshot —
//! label coherence of the attack records, cluster sizes, and the
//! `watch()` event stream announcing the freshly formed cluster.
//!
//! ```bash
//! cargo run --release --example intrusion_detection
//! ```

use dyn_dbscan::data::synth::{load, PaperDataset};
use dyn_dbscan::experiments::{PAPER_EPS, PAPER_K, PAPER_T};
use dyn_dbscan::serve::{ClusterEngine, ClusterEvent, EngineBuilder};
use dyn_dbscan::util::rng::Rng;
use rustc_hash::FxHashMap;

fn main() {
    let seed = 7;
    // background: the kddcup stand-in (imbalanced, 23 classes, d=20)
    let ds = load(PaperDataset::KddCup99, 0.02, seed);
    println!(
        "background traffic: n={} d={} classes={}",
        ds.n(),
        ds.dim,
        ds.num_clusters()
    );
    let mut engine = EngineBuilder::new(ds.dim)
        .k(PAPER_K)
        .t(PAPER_T)
        .eps(PAPER_EPS)
        .eager_attach(true) // serving mode: adopt stragglers immediately
        .seed(seed)
        .build()
        .expect("engine");
    let events = engine.watch();
    let mut rng = Rng::new(seed ^ 0xFEED);

    // a previously unseen attack signature: tight cluster far from data
    let attack_center: Vec<f32> = (0..ds.dim).map(|j| 6.0 + (j % 3) as f32).collect();
    let mut attack_ids: Vec<u64> = Vec::new();
    let attack_base = ds.n() as u64; // ext key space above the dataset rows

    let batch = 500;
    let inject_at = ds.n() / 2;
    let mut inserted = 0;
    let mut batches = 0;
    let t0 = std::time::Instant::now();
    while inserted < ds.n() {
        let end = (inserted + batch).min(ds.n());
        for i in inserted..end {
            engine.upsert(i as u64, ds.point(i));
        }
        // injection: a burst of 80 attack records in one batch
        if inserted < inject_at && end >= inject_at {
            for r in 0..80u64 {
                let p: Vec<f32> = attack_center
                    .iter()
                    .map(|&c| c + 0.05 * rng.normal() as f32)
                    .collect();
                let ext = attack_base + r;
                engine.upsert(ext, &p);
                attack_ids.push(ext);
            }
            println!("batch {batches}: >>> injected attack burst (80 records) <<<");
        }
        inserted = end;
        batches += 1;
        let view = engine.publish();

        // detection probe: is the attack burst a coherent dense cluster?
        if !attack_ids.is_empty() {
            let cores = attack_ids.iter().filter(|&&a| view.is_core(a)).count();
            let mut by_label: FxHashMap<i64, usize> = FxHashMap::default();
            for &a in &attack_ids {
                if let Some(l) = view.label(a) {
                    if l >= 0 {
                        *by_label.entry(l).or_insert(0) += 1;
                    }
                }
            }
            let (modal, same) = by_label
                .iter()
                .max_by_key(|&(_, &c)| c)
                .map(|(&l, &c)| (Some(l), c))
                .unwrap_or((None, 0));
            println!(
                "batch {batches}: v{} live={} attack cores={cores}/80, \
                 largest-attack-cluster={same}/80",
                view.version(),
                view.live_points()
            );
            if let Some(l) = modal {
                if cores >= 60 && same >= 70 && view.cluster_members(l).len() <= 100
                {
                    println!(
                        "batch {batches}: ALERT — dense novel cluster #{l} stable"
                    );
                }
            }
        }
    }
    println!(
        "\nprocessed {} records (+80 injected) in {:.2}s ({:.0} rec/s)",
        ds.n(),
        t0.elapsed().as_secs_f64(),
        (ds.n() + 80) as f64 / t0.elapsed().as_secs_f64()
    );
    // the attack burst must be detected as dense (core points) and
    // coherent (≥ 70/80 sharing one cluster label) in the final snapshot
    let view = engine.snapshot();
    let cores = attack_ids.iter().filter(|&&a| view.is_core(a)).count();
    assert!(cores > 60, "attack burst not detected as dense ({cores}/80 cores)");
    let mut by_label: FxHashMap<i64, usize> = FxHashMap::default();
    for &a in &attack_ids {
        if let Some(l) = view.label(a) {
            if l >= 0 {
                *by_label.entry(l).or_insert(0) += 1;
            }
        }
    }
    let same = by_label.values().copied().max().unwrap_or(0);
    assert!(same >= 70, "attack burst not coherent ({same}/80 in one cluster)");
    println!(
        "attack burst detected: {cores}/80 core, {same}/80 in one dense cluster"
    );
    // the event stream announced new clusters as they formed
    let formed = events
        .drain()
        .iter()
        .filter(|e| matches!(e, ClusterEvent::Formed { .. }))
        .count();
    println!("cluster events: {formed} Formed since stream start");

    // forensic cleanup: retract the attack records (e.g. after mitigation)
    for a in attack_ids {
        engine.remove(a);
    }
    engine.verify().expect("structure healthy after cleanup");
    let view = engine.publish();
    println!("post-cleanup invariants OK ({} live points)", view.live_points());
}
