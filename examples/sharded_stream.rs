//! Sharded serving demo: the blobs workload through the serve façade's
//! sharded backend — S parallel workers behind the deterministic
//! grid-cell router, ghost replication at block boundaries, incremental
//! cross-shard stitching, snapshot-backed reads — compared against the
//! single backend on the identical stream, through the *same* API.
//!
//! ```bash
//! cargo run --release --example sharded_stream [-- scale shards seed]
//! # e.g. paper-size blobs on 8 shards:
//! cargo run --release --example sharded_stream -- 1.0 8
//! ```

use dyn_dbscan::coordinator::driver::to_stream_ops;
use dyn_dbscan::data::stream::{insert_stream, Order};
use dyn_dbscan::data::synth::{load, PaperDataset};
use dyn_dbscan::dbscan::DbscanConfig;
use dyn_dbscan::experiments::{PAPER_BATCH, PAPER_EPS, PAPER_K, PAPER_T};
use dyn_dbscan::metrics::adjusted_rand_index;
use dyn_dbscan::serve::driver::{final_quality, run_stream, summarize};
use dyn_dbscan::serve::{Backend, EngineBuilder};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale: f64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let shards: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let seed: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(42);

    let ds = load(PaperDataset::Blobs, scale, seed);
    println!(
        "blobs stand-in: n={} d={} clusters={} (scale {scale}), {shards} shards",
        ds.n(),
        ds.dim,
        ds.num_clusters()
    );
    let cfg = DbscanConfig {
        k: PAPER_K,
        t: PAPER_T,
        eps: PAPER_EPS,
        dim: ds.dim,
        ..Default::default()
    };
    let batches = to_stream_ops(&ds, &insert_stream(&ds, Order::Random, PAPER_BATCH, seed));
    let truth_labels = ds.labels.clone();
    let truth = move |e: u64| truth_labels[e as usize];

    // sharded backend with periodic snapshots
    let engine = EngineBuilder::from_config(cfg.clone())
        .backend(Backend::Sharded(shards))
        .seed(seed)
        .build()
        .expect("sharded engine");
    let out = run_stream(engine, batches.clone(), 5, Some(&truth))
        .expect("sharded stream failed");
    for r in &out.reports {
        println!("{}", summarize(r));
    }
    let (ari, nmi) = final_quality(&ds, &out);
    let stats = &out.outcome.stats;
    println!("\nsharded: ARI={ari:.3} NMI={nmi:.3} wall={:.2}s", out.total_wall_s);
    println!(
        "         {:.0} updates/s, ghost ratio {:.2}",
        out.updates_per_s(),
        stats.ghost_ratio(),
    );
    println!("         add latency: {}", stats.add_latency.summary());
    // delta publishes: O(changed points) each, not O(live points)
    println!("         publish latency: {}", stats.publish_latency.summary());
    let snap = &out.outcome.snapshot;
    let top: Vec<String> = snap
        .cluster_sizes()
        .iter()
        .take(5)
        .map(|&(l, s)| format!("#{l}:{s}"))
        .collect();
    println!("         {} clusters, largest: {}", snap.clusters(), top.join(" "));

    // single backend on the identical stream — same builder, same driver
    let engine = EngineBuilder::from_config(cfg)
        .backend(Backend::Single)
        .seed(seed)
        .build()
        .expect("single engine");
    let single = run_stream(engine, batches, 0, None).expect("single stream failed");
    let single_labels: Vec<i64> = single.final_labels.iter().map(|&(_, l)| l).collect();
    let sharded_labels: Vec<i64> = out.final_labels.iter().map(|&(_, l)| l).collect();
    // both label vectors are sorted by ext, so they align index-by-index
    let agreement = adjusted_rand_index(&single_labels, &sharded_labels);
    println!(
        "\nsingle:  {:.2}s ({:.0} updates/s)",
        single.total_wall_s,
        single.updates_per_s()
    );
    println!(
        "         sharded-vs-single ARI {agreement:.3}, speedup {:.2}x",
        single.total_wall_s / out.total_wall_s
    );
}
