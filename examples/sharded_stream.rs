//! Sharded serving demo: the blobs workload through `ShardedEngine` — S
//! parallel `DynamicDbscan` workers behind the deterministic grid-cell
//! router, ghost replication at block boundaries, cross-shard cluster
//! stitching, and snapshot-backed reads — compared against the
//! single-instance path on the same stream.
//!
//! ```bash
//! cargo run --release --example sharded_stream [-- scale shards seed]
//! # e.g. paper-size blobs on 8 shards:
//! cargo run --release --example sharded_stream -- 1.0 8
//! ```

use std::time::Instant;

use dyn_dbscan::data::stream::Order;
use dyn_dbscan::data::synth::{load, PaperDataset};
use dyn_dbscan::dbscan::{DbscanConfig, DynamicDbscan};
use dyn_dbscan::experiments::{PAPER_BATCH, PAPER_EPS, PAPER_K, PAPER_T};
use dyn_dbscan::metrics::adjusted_rand_index;
use dyn_dbscan::shard::driver::{
    final_quality_sharded, stream_dataset_sharded, summarize_shard,
};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale: f64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let shards: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let seed: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(42);

    let ds = load(PaperDataset::Blobs, scale, seed);
    println!(
        "blobs stand-in: n={} d={} clusters={} (scale {scale}), {shards} shards",
        ds.n(),
        ds.dim,
        ds.num_clusters()
    );
    let cfg = DbscanConfig {
        k: PAPER_K,
        t: PAPER_T,
        eps: PAPER_EPS,
        dim: ds.dim,
        ..Default::default()
    };

    // sharded run with periodic snapshots
    let out = stream_dataset_sharded(
        &ds,
        cfg.clone(),
        Order::Random,
        PAPER_BATCH,
        /*window=*/ 0,
        /*snapshot_every=*/ 5,
        seed,
        shards,
    )
    .expect("sharded stream failed");
    for r in &out.reports {
        println!("{}", summarize_shard(r));
    }
    let (ari, nmi) = final_quality_sharded(&ds, &out);
    let stats = &out.engine.stats;
    println!("\nsharded: ARI={ari:.3} NMI={nmi:.3} wall={:.2}s", out.total_wall_s);
    println!(
        "         {:.0} updates/s, ghost ratio {:.2}, per-shard live {:?}",
        out.updates_per_s(),
        stats.ghost_ratio(),
        out.engine.snapshot.shard_live
    );
    println!("         add latency: {}", out.engine.add_latency.summary());
    // delta publishes: O(changed points) each, not O(live points)
    println!("         publish latency: {}", out.engine.publish_latency.summary());
    let snap = &out.engine.snapshot;
    let top: Vec<String> = snap
        .cluster_sizes
        .iter()
        .take(5)
        .map(|&(l, s)| format!("#{l}:{s}"))
        .collect();
    println!("         {} clusters, largest: {}", snap.clusters, top.join(" "));

    // single-instance reference on the identical point set
    let t0 = Instant::now();
    let mut db = DynamicDbscan::new(cfg, seed);
    let ids: Vec<u64> = (0..ds.n()).map(|i| db.add_point(ds.point(i))).collect();
    let single_s = t0.elapsed().as_secs_f64();
    let single = db.labels_for(&ids);
    let sharded: Vec<i64> = out
        .final_labels
        .iter()
        .map(|&(_, l)| l)
        .collect();
    // final_labels is sorted by ext = insertion index, aligning with `ids`
    let agreement = adjusted_rand_index(&single, &sharded);
    println!(
        "\nsingle:  {:.2}s ({:.0} updates/s)",
        single_s,
        ds.n() as f64 / single_s
    );
    println!(
        "         sharded-vs-single ARI {agreement:.3}, speedup {:.2}x",
        single_s / out.total_wall_s
    );
}
