//! Quickstart: the 60-second tour of the serving API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds an engine through `serve::EngineBuilder`, streams points in,
//! publishes versioned snapshots, queries them (labels, members, sizes,
//! ε-neighborhoods), subscribes to cluster events, deletes points, and
//! machine-checks the Theorem-2 invariants.

use dyn_dbscan::serve::{Backend, ClusterEngine, ClusterEvent, EngineBuilder};

fn main() {
    // 1. One builder for every backend: swap Backend::Single for
    //    Backend::Sharded(8) and nothing else changes.
    let mut engine = EngineBuilder::new(2) // dim = 2
        .k(5)
        .t(8)
        .eps(0.5)
        .backend(Backend::Single)
        .seed(42)
        .build()
        .expect("engine");

    // 2. Subscribe to cluster events before writing.
    let events = engine.watch();

    // 3. Upserts: two dense blobs plus an outlier (external u64 keys).
    for i in 0..20u64 {
        let j = (i % 5) as f32 * 0.05;
        engine.upsert(i, &[0.0 + j, 0.0 + j]); // left blob: exts 0..20
        engine.upsert(100 + i, &[8.0 + j, 8.0 - j]); // right: exts 100..120
    }
    engine.upsert(999, &[100.0, -100.0]); // outlier

    // 4. Freshness is explicit: nothing is readable until a publish.
    assert_eq!(engine.snapshot().pending_writes(), 41);
    assert_eq!(engine.snapshot().label(0), None);
    let view = engine.publish(); // version 1, pending 0
    println!(
        "v{}: {} live, {} cores, {} clusters",
        view.version(),
        view.live_points(),
        view.core_points(),
        view.clusters()
    );

    // 5. Snapshot queries: labels, members, sizes, ε-neighborhoods.
    println!("0 ~ 19?    {}", view.label(0) == view.label(19));
    println!("0 ~ 100?   {}", view.label(0) == view.label(100));
    println!("outlier:   {:?} (−1 = noise)", view.label(999));
    println!("0 core?    {}   outlier core? {}", view.is_core(0), view.is_core(999));
    println!("sizes:     {:?}", view.cluster_sizes());
    let near = view.epsilon_neighbors(&[0.05, 0.05]);
    println!("ε-neighbors of (0.05, 0.05): {} points", near.len());
    let members = view.cluster_members(view.label(0).unwrap());
    assert!(members.contains(&0) && members.contains(&19));

    // 6. Deletes: retire the left blob, publish, watch the events.
    for i in 0..20u64 {
        engine.remove(i);
    }
    let view2 = engine.publish(); // version 2
    println!(
        "v{}: {} live, {} clusters",
        view2.version(),
        view2.live_points(),
        view2.clusters()
    );
    // the old view is immutable — it still sees the deleted blob
    assert_eq!(view.live_points(), 41);
    for e in events.drain() {
        if !matches!(e, ClusterEvent::Moved { .. }) {
            println!("event: {e:?}");
        }
    }

    // 7. Machine-checked Theorem 2: G[C] is a spanning forest of H.
    engine.verify().expect("invariants hold");
    println!("invariants OK — quickstart done");
}
