//! Quickstart: the 60-second tour of the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a `DynamicDbscan`, streams points in, queries clusters, deletes
//! points, and checks the structure against the Theorem-2 invariant
//! checker.

use dyn_dbscan::dbscan::{DbscanConfig, DynamicDbscan};

fn main() {
    // 1. Initialise(k, t, eps): k-point buckets confer core-ness, t
    //    independent grid hashes, bucket side 2*eps.
    let cfg = DbscanConfig { k: 5, t: 8, eps: 0.5, dim: 2, ..Default::default() };
    let mut db = DynamicDbscan::new(cfg, /*seed=*/ 42);

    // 2. AddPoint: two dense blobs plus an outlier.
    let mut left = Vec::new();
    let mut right = Vec::new();
    for i in 0..20 {
        let j = (i % 5) as f32 * 0.05;
        left.push(db.add_point(&[0.0 + j, 0.0 + j]));
        right.push(db.add_point(&[8.0 + j, 8.0 - j]));
    }
    let outlier = db.add_point(&[100.0, -100.0]);

    // 3. GetCluster: O(log n) canonical cluster ids.
    println!("points: {}  cores: {}", db.num_points(), db.num_core_points());
    println!(
        "left[0] ~ left[19]?   {}",
        db.get_cluster(left[0]) == db.get_cluster(left[19])
    );
    println!(
        "left[0] ~ right[0]?   {}",
        db.get_cluster(left[0]) == db.get_cluster(right[0])
    );
    println!("outlier is core?      {}", db.is_core(outlier));

    // 4. Dense labels (noise = -1) for downstream metrics.
    let mut ids = left.clone();
    ids.extend(&right);
    ids.push(outlier);
    let labels = db.labels_for(&ids);
    println!("labels: {labels:?}");

    // 5. DeletePoint: remove the left blob entirely.
    for p in left {
        db.delete_point(p);
    }
    println!("after deletes: points={} cores={}", db.num_points(), db.num_core_points());

    // 6. Machine-checked Theorem 2: G[C] is a spanning forest of H.
    db.verify().expect("invariants hold");
    println!("invariants OK — quickstart done");
}
