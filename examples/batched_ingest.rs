//! Batched ingestion through the serve façade's `apply` API.
//!
//! ```bash
//! cargo run --release --example batched_ingest
//! ```
//!
//! `apply` hashes a whole batch in one cache-friendly pass per hash
//! function and mixes upserts and removes in a single call. It is exactly
//! equivalent to the per-op calls — only faster.

use std::time::Instant;

use dyn_dbscan::data::blobs::{make_blobs, BlobsConfig};
use dyn_dbscan::serve::{ClusterEngine, EngineBuilder, Update};

fn main() {
    let n = 20_000;
    let ds = make_blobs(
        &BlobsConfig {
            n,
            dim: 8,
            clusters: 6,
            std: 0.3,
            center_box: 25.0,
            weights: vec![],
        },
        3,
    );

    // 1. bulk load: one Update batch, one call
    let mut engine = EngineBuilder::new(8).seed(42).build().expect("engine");
    let bulk: Vec<Update> = (0..n)
        .map(|i| Update::Upsert { ext: i as u64, coords: ds.point(i) })
        .collect();
    let t0 = Instant::now();
    engine.apply(&bulk);
    let bulk_s = t0.elapsed().as_secs_f64();
    let view = engine.publish();
    println!(
        "apply (bulk): {n} points in {bulk_s:.3}s ({:.0} adds/s), {} cores",
        n as f64 / bulk_s,
        view.core_points()
    );

    // 2. mixed batch: retire the first 1000 points while adding 1000
    //    fresh ones, in one apply call
    let fresh = make_blobs(
        &BlobsConfig {
            n: 1000,
            dim: 8,
            clusters: 6,
            std: 0.3,
            center_box: 25.0,
            weights: vec![],
        },
        9,
    );
    let mut ops: Vec<Update> = Vec::with_capacity(2000);
    for ext in 0..1000u64 {
        ops.push(Update::Remove { ext });
    }
    for i in 0..fresh.n() {
        ops.push(Update::Upsert { ext: (n + i) as u64, coords: fresh.point(i) });
    }
    let t0 = Instant::now();
    engine.apply(&ops);
    let view = engine.publish();
    println!(
        "apply (mixed): {} ops in {:.3}s; live={}",
        ops.len(),
        t0.elapsed().as_secs_f64(),
        view.live_points(),
    );

    // 3. the per-op and batched paths agree exactly (same seed ⇒ same
    //    hashing ⇒ identical structures and labels)
    let mut per_op = EngineBuilder::new(8).seed(42).build().expect("engine");
    for i in 0..n {
        per_op.upsert(i as u64, ds.point(i));
    }
    let mut batched = EngineBuilder::new(8).seed(42).build().expect("engine");
    batched.apply(&bulk);
    let a = per_op.publish();
    let b = batched.publish();
    println!(
        "per-op vs batched bulk load agree: {}",
        a.labels() == b.labels() && a.core_points() == b.core_points()
    );

    engine.verify().expect("invariants hold after batched churn");
    println!("invariants OK — batched ingest done");
}
