//! Batched ingestion: the allocation-free bulk API of `DynamicDbscan`.
//!
//! ```bash
//! cargo run --release --example batched_ingest
//! ```
//!
//! `add_points` hashes a whole flat batch in one cache-friendly pass per
//! hash function; `apply_batch` mixes adds and deletes in a single call.
//! Both are exactly equivalent to the per-op calls — only faster.

use std::time::Instant;

use dyn_dbscan::data::blobs::{make_blobs, BlobsConfig};
use dyn_dbscan::dbscan::{DbscanConfig, DynamicDbscan, Op};

fn main() {
    let n = 20_000;
    let ds = make_blobs(
        &BlobsConfig {
            n,
            dim: 8,
            clusters: 6,
            std: 0.3,
            center_box: 25.0,
            weights: vec![],
        },
        3,
    );
    let cfg = DbscanConfig { k: 10, t: 10, eps: 0.75, dim: 8, ..Default::default() };

    // 1. bulk load: one flat row-major buffer, one call
    let mut db = DynamicDbscan::new(cfg.clone(), 42);
    let t0 = Instant::now();
    let ids = db.add_points(&ds.xs, n);
    let bulk_s = t0.elapsed().as_secs_f64();
    println!(
        "add_points: {n} points in {bulk_s:.3}s ({:.0} adds/s), {} cores",
        n as f64 / bulk_s,
        db.num_core_points()
    );

    // 2. mixed batch: retire the first 1000 points while adding 1000 fresh
    //    ones, in one apply_batch call
    let fresh = make_blobs(
        &BlobsConfig {
            n: 1000,
            dim: 8,
            clusters: 6,
            std: 0.3,
            center_box: 25.0,
            weights: vec![],
        },
        9,
    );
    let mut ops: Vec<Op> = Vec::with_capacity(2000);
    for &id in &ids[..1000] {
        ops.push(Op::Delete(id));
    }
    for i in 0..fresh.n() {
        ops.push(Op::Add(fresh.point(i)));
    }
    let t0 = Instant::now();
    let new_ids = db.apply_batch(&ops);
    println!(
        "apply_batch: {} ops in {:.3}s; live={} (+{} fresh ids)",
        ops.len(),
        t0.elapsed().as_secs_f64(),
        db.num_points(),
        new_ids.len()
    );

    // 3. the per-op and batched paths agree exactly (same seed, same keys)
    let mut reference = DynamicDbscan::new(cfg.clone(), 42);
    for i in 0..n {
        reference.add_point(ds.point(i));
    }
    let mut bulk = DynamicDbscan::new(cfg, 42);
    bulk.add_points(&ds.xs, n);
    println!(
        "per-op vs batched bulk load agree: {}",
        reference.num_core_points() == bulk.num_core_points()
            && reference.stats == bulk.stats
    );

    db.verify().expect("invariants hold after batched churn");
    println!("invariants OK — batched ingest done");
}
