//! Figure-2-style streaming run: the blobs workload streamed through the
//! serve façade with per-batch ARI/NMI snapshots and latency histograms —
//! the paper's §5 experiment as a runnable example.
//!
//! ```bash
//! cargo run --release --example streaming_blobs [-- scale seed]
//! # paper size: cargo run --release --example streaming_blobs -- 1.0
//! ```

use dyn_dbscan::coordinator::driver::stream_dataset;
use dyn_dbscan::data::stream::Order;
use dyn_dbscan::data::synth::{load, PaperDataset};
use dyn_dbscan::dbscan::DbscanConfig;
use dyn_dbscan::experiments::{PAPER_BATCH, PAPER_EPS, PAPER_K, PAPER_T};
use dyn_dbscan::serve::driver::{final_quality, summarize};
use dyn_dbscan::serve::EngineKind;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale: f64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(42);

    let ds = load(PaperDataset::Blobs, scale, seed);
    println!(
        "blobs stand-in: n={} d={} clusters={} (scale {scale})",
        ds.n(),
        ds.dim,
        ds.num_clusters()
    );
    let cfg = DbscanConfig {
        k: PAPER_K,
        t: PAPER_T,
        eps: PAPER_EPS,
        dim: ds.dim,
        ..Default::default()
    };
    let out = stream_dataset(
        &ds,
        cfg,
        Order::Random,
        PAPER_BATCH,
        /*snapshot_every=*/ 5,
        seed,
        EngineKind::Native,
    )
    .expect("stream failed");

    for r in &out.reports {
        println!("{}", summarize(r));
    }
    let (ari, nmi) = final_quality(&ds, &out);
    println!("\nfinal ARI={ari:.3} NMI={nmi:.3}");
    println!("total wall time: {:.2}s", out.total_wall_s);
    println!("throughput: {:.0} updates/s", out.updates_per_s());
    println!("add latency:    {}", out.outcome.stats.add_latency.summary());
}
