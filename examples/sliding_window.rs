//! Sliding-window clustering over a drifting stream — the workload where
//! deletions are as frequent as insertions (each arrival evicts the oldest
//! record once the window fills), i.e. the regime where the paper's
//! `O(d log³n + log⁴n)` DeletePoint matters most.
//!
//! The generating distribution drifts: cluster centers move over time, and
//! the report shows the window's clustering tracking the drift while a
//! whole-history clustering would smear. Driven entirely through the
//! serve façade: upsert/remove with external keys, periodic publishes,
//! snapshot-backed quality probes.
//!
//! ```bash
//! cargo run --release --example sliding_window
//! ```

use dyn_dbscan::metrics::adjusted_rand_index;
use dyn_dbscan::serve::{ClusterEngine, EngineBuilder};
use dyn_dbscan::util::rng::Rng;
use std::collections::VecDeque;

fn main() {
    let dim = 4;
    let clusters = 3;
    let window = 3000;
    let total = 30_000;
    let mut engine = EngineBuilder::new(dim)
        .k(8)
        .t(10)
        .eps(0.6)
        .seed(11)
        .build()
        .expect("engine");
    let mut rng = Rng::new(4);
    let mut live: VecDeque<(u64, i64)> = VecDeque::new(); // (ext, truth)

    let t0 = std::time::Instant::now();
    for step in 0..total as u64 {
        // drifting centers: rotate slowly with time
        let phase = step as f64 / total as f64 * std::f64::consts::PI;
        let c = rng.below(clusters) as usize;
        let center: Vec<f64> = (0..dim)
            .map(|j| 6.0 * ((c as f64 * 2.1) + phase + j as f64).sin())
            .collect();
        let p: Vec<f32> = center
            .iter()
            .map(|&x| (x + 0.25 * rng.normal()) as f32)
            .collect();
        engine.upsert(step, &p);
        live.push_back((step, c as i64));
        if live.len() > window {
            let (old, _) = live.pop_front().unwrap();
            engine.remove(old);
        }

        if step % 5000 == 4999 {
            let view = engine.publish();
            let truth: Vec<i64> = live.iter().map(|&(_, t)| t).collect();
            let pred: Vec<i64> = live
                .iter()
                .map(|&(e, _)| view.label(e).expect("live ext labeled"))
                .collect();
            let ari = adjusted_rand_index(&truth, &pred);
            println!(
                "step {:>6}: v{} live={} cores={} window-ARI={:.3}",
                step + 1,
                view.version(),
                view.live_points(),
                view.core_points(),
                ari
            );
            assert!(ari > 0.5, "window clustering lost the drifting clusters");
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "\n{} updates ({} inserts + {} deletes) in {:.2}s = {:.0} updates/s",
        total * 2 - window,
        total,
        total - window,
        secs,
        (total * 2 - window) as f64 / secs
    );
    let st = engine.stats();
    println!(
        "replacement searches: {} (promoted {}, visited {} vertices)",
        st.conn.searches, st.conn.replacements, st.conn.visited
    );
    engine.verify().expect("invariants hold at end");
    println!("invariants OK");
}
