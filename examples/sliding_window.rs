//! Sliding-window clustering over a drifting stream — the workload where
//! deletions are as frequent as insertions (each arrival evicts the oldest
//! record once the window fills), i.e. the regime where the paper's
//! `O(d log³n + log⁴n)` DeletePoint matters most.
//!
//! The generating distribution drifts: cluster centers move over time, and
//! the report shows the window's clustering tracking the drift while a
//! whole-history clustering would smear.
//!
//! ```bash
//! cargo run --release --example sliding_window
//! ```

use dyn_dbscan::dbscan::{DbscanConfig, DynamicDbscan};
use dyn_dbscan::metrics::adjusted_rand_index;
use dyn_dbscan::util::rng::Rng;
use std::collections::VecDeque;

fn main() {
    let dim = 4;
    let clusters = 3;
    let window = 3000;
    let total = 30_000;
    let cfg = DbscanConfig {
        k: 8,
        t: 10,
        eps: 0.6,
        dim,
        ..Default::default()
    };
    let mut db = DynamicDbscan::new(cfg, 11);
    let mut rng = Rng::new(4);
    let mut live: VecDeque<(u64, i64)> = VecDeque::new(); // (id, truth)

    let t0 = std::time::Instant::now();
    for step in 0..total {
        // drifting centers: rotate slowly with time
        let phase = step as f64 / total as f64 * std::f64::consts::PI;
        let c = rng.below(clusters) as usize;
        let center: Vec<f64> = (0..dim)
            .map(|j| 6.0 * ((c as f64 * 2.1) + phase + j as f64).sin())
            .collect();
        let p: Vec<f32> = center
            .iter()
            .map(|&x| (x + 0.25 * rng.normal()) as f32)
            .collect();
        let id = db.add_point(&p);
        live.push_back((id, c as i64));
        if live.len() > window {
            let (old, _) = live.pop_front().unwrap();
            db.delete_point(old);
        }

        if step % 5000 == 4999 {
            let ids: Vec<u64> = live.iter().map(|&(i, _)| i).collect();
            let truth: Vec<i64> = live.iter().map(|&(_, t)| t).collect();
            let pred = db.labels_for(&ids);
            let ari = adjusted_rand_index(&truth, &pred);
            println!(
                "step {:>6}: live={} cores={} window-ARI={:.3}",
                step + 1,
                db.num_points(),
                db.num_core_points(),
                ari
            );
            assert!(ari > 0.5, "window clustering lost the drifting clusters");
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "\n{} updates ({} inserts + {} deletes) in {:.2}s = {:.0} updates/s",
        total * 2 - window,
        total,
        total - window,
        secs,
        (total * 2 - window) as f64 / secs
    );
    let st = db.repair_stats();
    println!(
        "replacement searches: {} (promoted {}, visited {} vertices)",
        st.searches, st.replacements, st.visited
    );
    db.verify().expect("invariants hold at end");
    println!("invariants OK");
}
