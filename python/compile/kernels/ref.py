"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every kernel in this package has a reference implementation here written in
the most direct jnp form possible. pytest (with hypothesis sweeps over
shapes) asserts ``assert_allclose(kernel(...), ref(...))``.
"""

from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x, eta, inv_two_eps):
    """floor((x + eta) * inv_two_eps) as int32. Shapes: x (B,d); eta, inv (1,)."""
    return jnp.floor((x + eta[0]) * inv_two_eps[0]).astype(jnp.int32)


def hash_model_ref(x, etas, inv_two_eps):
    """All-t quantization: (B,d) x (T,) -> (T,B,d) int32."""
    return jnp.floor(
        (x[None, :, :] + etas[:, None, None]) * inv_two_eps[0]
    ).astype(jnp.int32)


def pairwise_dist2_ref(x, y):
    """Exact O(Bq*M*d) squared distances via explicit differences."""
    diff = x[:, None, :] - y[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def project_ref(x, w):
    """PCA-apply / linear projection oracle."""
    return jnp.dot(x, w)
