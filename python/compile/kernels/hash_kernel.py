"""L1 Pallas kernel: grid-LSH quantizer (Definition 3 of the paper).

For a point batch ``x`` of shape ``(B, d)``, a shift ``eta`` drawn uniformly
from ``[0, 2eps]`` and ``inv_two_eps = 1/(2*eps)``, computes the integer grid
coordinates

    q[b, j] = floor((x[b, j] + eta) * inv_two_eps)            (int32)

Two points share a hash bucket iff their coordinate rows are equal
(the u128 bucket *key* is derived from the row on the Rust side so that the
kernel stays purely numeric).

TPU adaptation notes (see DESIGN.md §Hardware-Adaptation):
  * the batch is tiled into ``(ROW_BLOCK, d)`` VMEM blocks via ``BlockSpec``;
    with d <= 64 each row occupies a fraction of a VPU lane tile, so the
    kernel is VPU-bound (no MXU use) and the only schedule decision is the
    HBM->VMEM row blocking expressed by the index map;
  * ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
    custom-calls, so correctness is validated through the interpreter and the
    same HLO is what the Rust runtime loads.

IMPORTANT numerical contract: the expression is ``(x + eta) * inv_two_eps``
(an add followed by a multiply, *not* a division, *not* an FMA-rewritten
form). The Rust native hashing engine evaluates the identical expression so
that artifact and native paths agree bit-for-bit on non-boundary inputs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per VMEM block. 128 matches the TPU lane count; on CPU interpret mode
# it is simply the batch tile.
ROW_BLOCK = 128


def _quantize_kernel(x_ref, eta_ref, inv_ref, o_ref):
    """Pallas kernel body: one (ROW_BLOCK, d) tile."""
    x = x_ref[...]
    eta = eta_ref[0]
    inv = inv_ref[0]
    o_ref[...] = jnp.floor((x + eta) * inv).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("row_block",))
def quantize(x, eta, inv_two_eps, *, row_block: int = ROW_BLOCK):
    """Quantize a batch of points to integer grid coordinates.

    Args:
      x: ``(B, d)`` float32 array, ``B`` a multiple of ``row_block``.
      eta: ``(1,)`` float32 — the hash function's shift.
      inv_two_eps: ``(1,)`` float32 — ``1 / (2 * eps)``.
      row_block: rows per block (static).

    Returns:
      ``(B, d)`` int32 grid coordinates.
    """
    b, d = x.shape
    if b % row_block != 0:
        raise ValueError(f"batch {b} not a multiple of row block {row_block}")
    grid = (b // row_block,)
    return pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((row_block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.int32),
        interpret=True,
    )(x, eta, inv_two_eps)
