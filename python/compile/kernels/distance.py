"""L1 Pallas kernel: tiled pairwise squared Euclidean distances.

Computes ``D2[i, j] = || x[i] - y[j] ||^2`` for a query tile ``x`` of shape
``(Bq, d)`` against a corpus tile ``y`` of shape ``(M, d)`` using the
MXU-friendly decomposition

    D2 = ||x||^2[:, None] + ||y||^2[None, :] - 2 * x @ y.T

The exact-DBSCAN baseline (``rust/src/baselines/brute.rs``) consumes these
tiles for its eps-range queries: Rust streams fixed-size corpus tiles through
the compiled artifact and thresholds the result at ``eps^2``.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the output is tiled
``(TILE, TILE) = (128, 128)`` so the ``x @ y.T`` contraction maps onto the
128x128 systolic MXU; ``x`` and ``y`` tiles of shape ``(128, d)`` with
d <= 64 fit comfortably in VMEM (3 * 128 * 64 * 4B = 96 KiB << 16 MiB).
Under ``interpret=True`` we validate numerics only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _dist2_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...]
    y = y_ref[...]
    xx = jnp.sum(x * x, axis=1, keepdims=True)          # (TILE, 1)
    yy = jnp.sum(y * y, axis=1, keepdims=True).T        # (1, TILE)
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    # Clamp tiny negatives produced by cancellation so downstream
    # thresholding at eps^2 is safe.
    o_ref[...] = jnp.maximum(xx + yy - 2.0 * xy, 0.0)


@functools.partial(jax.jit, static_argnames=("tile",))
def pairwise_dist2(x, y, *, tile: int = TILE):
    """Pairwise squared distances between two point tiles.

    Args:
      x: ``(Bq, d)`` float32, ``Bq`` a multiple of ``tile``.
      y: ``(M, d)`` float32, ``M`` a multiple of ``tile``.

    Returns:
      ``(Bq, M)`` float32 squared distances.
    """
    bq, d = x.shape
    m, d2 = y.shape
    if d != d2:
        raise ValueError(f"dim mismatch {d} vs {d2}")
    if bq % tile or m % tile:
        raise ValueError(f"tile sizes must divide shapes: {bq}x{m} vs {tile}")
    grid = (bq // tile, m // tile)
    return pl.pallas_call(
        _dist2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bq, m), jnp.float32),
        interpret=True,
    )(x, y)
