"""L2: JAX compute graphs consumed by the Rust runtime.

Three model families, each lowered AOT (by ``aot.py``) to HLO text for a set
of fixed shape variants and executed from ``rust/src/runtime/``:

  * ``hash_model``    — the t-way grid-LSH quantizer (calls the L1 Pallas
                        kernel once per hash function; static unroll over t).
  * ``distance_model``— tiled pairwise squared distances (L1 Pallas kernel).
  * ``project_model`` — linear projection (PCA-apply) used by the data
                        preprocessing path for the MNIST-like datasets.

Conventions:
  * every model returns a 1-tuple so the HLO entry computation has a tuple
    root (the Rust side unwraps with ``to_tuple1``);
  * all shapes are static; the Rust engines pad batches to the compiled
    batch size and slice the results.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import distance as distance_kernel
from .kernels import hash_kernel


def make_hash_model(t: int):
    """Return ``f(x[B,d], etas[t], inv_two_eps[1]) -> (coords[t,B,d] i32,)``.

    Static unroll over the ``t`` hash functions — each iteration invokes the
    L1 Pallas quantizer so the whole model lowers into a single HLO module.
    """

    def hash_model(x, etas, inv_two_eps):
        outs = []
        for i in range(t):
            eta_i = jnp.reshape(etas[i], (1,))
            outs.append(hash_kernel.quantize(x, eta_i, inv_two_eps))
        return (jnp.stack(outs, axis=0),)

    return hash_model


def distance_model(x, y):
    """``f(x[Bq,d], y[M,d]) -> (dist2[Bq,M] f32,)``."""
    return (distance_kernel.pairwise_dist2(x, y),)


def project_model(x, w):
    """``f(x[B,Din], w[Din,Dout]) -> (proj[B,Dout] f32,)``."""
    return (jnp.dot(x, w),)
