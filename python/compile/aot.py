"""AOT pipeline: lower every L2 model variant to HLO *text* artifacts.

Run once at build time (``make artifacts``); the Rust runtime
(``rust/src/runtime/``) loads ``artifacts/<name>.hlo.txt`` via
``HloModuleProto::from_text_file``, compiles it on the PJRT CPU client and
executes it from the L3 hot path. Python never runs at request time.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` 0.1.6 crate links) rejects with
``proto.id() <= INT_MAX``; the text parser reassigns ids and round-trips
cleanly. Lowering goes stablehlo -> XlaComputation with ``return_tuple=True``
(the Rust side unwraps with ``to_tuple1``).

Usage:
    python -m compile.aot --out-dir ../artifacts [--only NAME] [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Variant registry
# ---------------------------------------------------------------------------

# Dataset dims used across Table 1 after preprocessing (blobs=10, letter=16,
# mnist/fashion/kddcup=20, covertype=54).
HASH_DIMS = (10, 16, 20, 54)
HASH_T = 10
HASH_B = 1024

DIST_DIMS = (10, 16, 20, 54)
DIST_Q = 256
DIST_M = 2048

PROJECT_B, PROJECT_DIN, PROJECT_DOUT = 1024, 784, 20

F32 = "f32"
I32 = "i32"


def _spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def variants(smoke: bool = False):
    """Yield (name, fn, example_arg_specs, meta) for every artifact."""
    out = []

    def add_hash(d, t, b):
        name = f"hash_d{d}_t{t}_b{b}"
        fn = model.make_hash_model(t)
        specs = (_spec((b, d)), _spec((t,)), _spec((1,)))
        meta = {
            "name": name,
            "kind": "hash",
            "d": d,
            "t": t,
            "b": b,
            "inputs": [
                {"shape": [b, d], "dtype": F32},
                {"shape": [t], "dtype": F32},
                {"shape": [1], "dtype": F32},
            ],
            "output": {"shape": [t, b, d], "dtype": I32},
        }
        out.append((name, fn, specs, meta))

    def add_dist(d, q, m):
        name = f"dist_d{d}_q{q}_m{m}"
        specs = (_spec((q, d)), _spec((m, d)))
        meta = {
            "name": name,
            "kind": "dist",
            "d": d,
            "q": q,
            "m": m,
            "inputs": [
                {"shape": [q, d], "dtype": F32},
                {"shape": [m, d], "dtype": F32},
            ],
            "output": {"shape": [q, m], "dtype": F32},
        }
        out.append((name, model.distance_model, specs, meta))

    def add_project(b, din, dout):
        name = f"project_b{b}_din{din}_dout{dout}"
        specs = (_spec((b, din)), _spec((din, dout)))
        meta = {
            "name": name,
            "kind": "project",
            "b": b,
            "din": din,
            "dout": dout,
            "inputs": [
                {"shape": [b, din], "dtype": F32},
                {"shape": [din, dout], "dtype": F32},
            ],
            "output": {"shape": [b, dout], "dtype": F32},
        }
        out.append((name, model.project_model, specs, meta))

    if smoke:
        # Tiny variants for fast pytest / cargo integration tests.
        add_hash(4, 2, 128)
        add_dist(4, 128, 128)
        add_project(128, 8, 4)
        return out

    for d in HASH_DIMS:
        add_hash(d, HASH_T, HASH_B)
    for d in DIST_DIMS:
        add_dist(d, DIST_Q, DIST_M)
    add_project(PROJECT_B, PROJECT_DIN, PROJECT_DOUT)
    # Smoke variants ship alongside the full set so tests never rebuild.
    add_hash(4, 2, 128)
    add_dist(4, 128, 128)
    add_project(128, 8, 4)
    return out


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(fn, specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def build(out_dir: str, only: str | None = None, smoke: bool = False) -> list:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for name, fn, specs, meta in variants(smoke=smoke):
        if only is not None and name != only:
            continue
        text = lower_variant(fn, specs)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta = dict(meta)
        meta["file"] = f"{name}.hlo.txt"
        manifest.append(meta)
        print(f"[aot] wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump({"artifacts": manifest}, f, indent=2)
    print(f"[aot] wrote manifest with {len(manifest)} artifacts")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="build a single variant")
    ap.add_argument(
        "--smoke", action="store_true", help="only the tiny test variants"
    )
    args = ap.parse_args()
    build(args.out_dir, only=args.only, smoke=args.smoke)


if __name__ == "__main__":
    main()
