"""AOT pipeline: lowering produces parseable HLO text + a coherent manifest."""

import json
import os

from compile import aot


def test_smoke_variants_lower(tmp_path):
    manifest = aot.build(str(tmp_path), smoke=True)
    names = {m["name"] for m in manifest}
    assert "hash_d4_t2_b128" in names
    assert "dist_d4_q128_m128" in names
    for m in manifest:
        path = tmp_path / m["file"]
        text = path.read_text()
        assert "ENTRY" in text, f"{m['name']}: no ENTRY computation"
        assert "->" in text
        # tuple root: aot lowers with return_tuple=True
        assert text.count("parameter(") >= len(m["inputs"])
    data = json.loads((tmp_path / "manifest.json").read_text())
    assert len(data["artifacts"]) == len(manifest)


def test_hash_artifact_shapes_in_text(tmp_path):
    aot.build(str(tmp_path), only="hash_d4_t2_b128", smoke=True)
    text = (tmp_path / "hash_d4_t2_b128.hlo.txt").read_text()
    # output is (2,128,4) int32 inside a tuple
    assert "s32[2,128,4]" in text.replace(" ", "")


def test_variant_registry_full_set():
    names = [m[0] for m in aot.variants(smoke=False)]
    assert len(names) == len(set(names)), "duplicate variant names"
    for d in aot.HASH_DIMS:
        assert f"hash_d{d}_t{aot.HASH_T}_b{aot.HASH_B}" in names
    for d in aot.DIST_DIMS:
        assert f"dist_d{d}_q{aot.DIST_Q}_m{aot.DIST_M}" in names
