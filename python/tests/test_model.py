"""L2 correctness: model graphs vs oracles + shape contracts."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose, assert_array_equal

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


@settings(max_examples=15, deadline=None)
@given(
    t=st.integers(min_value=1, max_value=12),
    d=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hash_model_matches_ref(t, d, seed):
    b = 128
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, d)).astype(np.float32) * 3.0
    etas = rng.uniform(0, 1.5, size=(t,)).astype(np.float32)
    inv = np.array([1 / 1.5], dtype=np.float32)
    fn = model.make_hash_model(t)
    (got,) = fn(jnp.asarray(x), jnp.asarray(etas), jnp.asarray(inv))
    want = ref.hash_model_ref(jnp.asarray(x), jnp.asarray(etas), jnp.asarray(inv))
    assert got.shape == (t, b, d)
    assert_array_equal(np.asarray(got), np.asarray(want))


def test_distance_model_matches_ref():
    rng = np.random.default_rng(11)
    x = rng.normal(size=(128, 20)).astype(np.float32)
    y = rng.normal(size=(256, 20)).astype(np.float32)
    (got,) = model.distance_model(jnp.asarray(x), jnp.asarray(y))
    want = ref.pairwise_dist2_ref(jnp.asarray(x), jnp.asarray(y))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_project_model_matches_ref():
    rng = np.random.default_rng(12)
    x = rng.normal(size=(64, 32)).astype(np.float32)
    w = rng.normal(size=(32, 8)).astype(np.float32)
    (got,) = model.project_model(jnp.asarray(x), jnp.asarray(w))
    want = ref.project_ref(jnp.asarray(x), jnp.asarray(w))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_hash_model_collision_probability_lemma1():
    """Lemma 1(1): Pr[h(x)=h(y)] >= 1 - ||x-y||_1 / (2 eps), empirically.

    Uses the model over many independent etas (many 'hash functions') and
    checks the empirical collision frequency dominates the bound.
    """
    eps = 1.0
    t = 512
    rng = np.random.default_rng(99)
    x = np.zeros((128, 4), dtype=np.float32)
    delta = rng.uniform(-0.2, 0.2, size=(128, 4)).astype(np.float32)
    y = x + delta
    etas = rng.uniform(0, 2 * eps, size=(t,)).astype(np.float32)
    inv = np.array([1 / (2 * eps)], dtype=np.float32)
    fn = model.make_hash_model(t)
    (qx,) = fn(jnp.asarray(x), jnp.asarray(etas), jnp.asarray(inv))
    (qy,) = fn(jnp.asarray(y), jnp.asarray(etas), jnp.asarray(inv))
    qx, qy = np.asarray(qx), np.asarray(qy)
    collide = (qx == qy).all(axis=2).mean(axis=0)  # per-point frequency
    bound = 1.0 - np.abs(delta).sum(axis=1) / (2 * eps)
    # allow 3-sigma slack on the empirical estimate
    sigma = np.sqrt(bound * (1 - bound) / t + 1e-9)
    assert (collide >= bound - 4 * sigma - 1e-3).all()
