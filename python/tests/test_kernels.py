"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (block-multiple batches, arbitrary small dims) and
value regimes; numpy RNG seeds derive from hypothesis-drawn integers so every
case is reproducible.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose, assert_array_equal

import jax.numpy as jnp

from compile.kernels import distance, hash_kernel, ref

# ---------------------------------------------------------------------------
# quantize (grid LSH)
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    rows=st.sampled_from([1, 2, 4]),
    d=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    eps=st.floats(min_value=0.05, max_value=4.0),
)
def test_quantize_matches_ref(rows, d, seed, eps):
    b = rows * hash_kernel.ROW_BLOCK
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, d)).astype(np.float32) * 10.0
    eta = rng.uniform(0.0, 2.0 * eps, size=(1,)).astype(np.float32)
    inv = np.array([1.0 / (2.0 * eps)], dtype=np.float32)
    got = hash_kernel.quantize(jnp.asarray(x), jnp.asarray(eta), jnp.asarray(inv))
    want = ref.quantize_ref(jnp.asarray(x), jnp.asarray(eta), jnp.asarray(inv))
    assert got.dtype == jnp.int32
    assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantize_rejects_ragged_batch():
    x = jnp.zeros((100, 3), jnp.float32)
    with pytest.raises(ValueError):
        hash_kernel.quantize(x, jnp.zeros((1,)), jnp.ones((1,)))


def test_quantize_translation_invariance():
    """Shifting x by exactly 2*eps shifts every coordinate by exactly 1."""
    rng = np.random.default_rng(0)
    eps = 0.75
    x = rng.normal(size=(128, 8)).astype(np.float32)
    eta = np.array([0.3], dtype=np.float32)
    inv = np.array([1.0 / (2 * eps)], dtype=np.float32)
    a = hash_kernel.quantize(jnp.asarray(x), jnp.asarray(eta), jnp.asarray(inv))
    # adding 2*eps*4 = 6.0 (exactly representable) shifts coords by 4
    b = hash_kernel.quantize(
        jnp.asarray(x + 4 * 2 * eps), jnp.asarray(eta), jnp.asarray(inv)
    )
    assert_array_equal(np.asarray(b), np.asarray(a) + 4)


def test_quantize_bucket_width_lemma1():
    """Lemma 1(2): equal hash row => L_inf distance <= 2*eps."""
    rng = np.random.default_rng(7)
    eps = 0.5
    x = rng.uniform(-5, 5, size=(256, 6)).astype(np.float32)
    eta = rng.uniform(0, 2 * eps, size=(1,)).astype(np.float32)
    inv = np.array([1 / (2 * eps)], dtype=np.float32)
    q = np.asarray(
        hash_kernel.quantize(jnp.asarray(x), jnp.asarray(eta), jnp.asarray(inv))
    )
    # group rows by identical coords and check the diameter bound
    buckets = {}
    for i in range(x.shape[0]):
        buckets.setdefault(tuple(q[i]), []).append(i)
    for idxs in buckets.values():
        pts = x[idxs]
        linf = np.max(np.abs(pts[:, None, :] - pts[None, :, :]))
        assert linf <= 2 * eps + 1e-6


# ---------------------------------------------------------------------------
# pairwise_dist2
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    qt=st.sampled_from([1, 2]),
    mt=st.sampled_from([1, 2, 3]),
    d=st.integers(min_value=1, max_value=48),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_dist2_matches_ref(qt, mt, d, seed):
    bq, m = qt * distance.TILE, mt * distance.TILE
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(bq, d)).astype(np.float32)
    y = rng.normal(size=(m, d)).astype(np.float32)
    got = np.asarray(distance.pairwise_dist2(jnp.asarray(x), jnp.asarray(y)))
    want = np.asarray(ref.pairwise_dist2_ref(jnp.asarray(x), jnp.asarray(y)))
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dist2_self_diagonal_zero():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(128, 16)).astype(np.float32)
    d2 = np.asarray(distance.pairwise_dist2(jnp.asarray(x), jnp.asarray(x)))
    assert_allclose(np.diag(d2), np.zeros(128), atol=1e-3)
    assert (d2 >= 0).all()


def test_dist2_symmetry():
    rng = np.random.default_rng(4)
    x = rng.normal(size=(128, 12)).astype(np.float32)
    y = rng.normal(size=(256, 12)).astype(np.float32)
    a = np.asarray(distance.pairwise_dist2(jnp.asarray(x), jnp.asarray(y)))
    b = np.asarray(distance.pairwise_dist2(jnp.asarray(y), jnp.asarray(x)))
    assert_allclose(a, b.T, rtol=1e-4, atol=1e-4)


def test_dist2_shape_validation():
    with pytest.raises(ValueError):
        distance.pairwise_dist2(
            jnp.zeros((128, 3)), jnp.zeros((128, 4))
        )
    with pytest.raises(ValueError):
        distance.pairwise_dist2(jnp.zeros((100, 3)), jnp.zeros((128, 3)))
