//! Delta-snapshot differential property tests: a chain of delta-published
//! `GlobalSnapshot`s must stay **label-isomorphic** to a from-scratch
//! stitch rebuild of the same engine state after every batch — the same
//! oracle discipline `tests/churn.rs` applies to the single-instance
//! structure (its Definition-4 ground truth is the per-shard worker here;
//! the stitch layer's oracle is the old union-find rebuild, now kept as
//! the explicit `stitch_full` fallback).
//!
//! The schedules deliberately include delete-heavy phases that carve
//! bridges out of clusters, forcing cross-shard cluster **splits** — the
//! un-union case the old per-snapshot rebuild existed to sidestep and the
//! HDT-backed stitch graph must now handle incrementally.

use dyn_dbscan::data::blobs::{make_blobs, BlobsConfig};
use dyn_dbscan::dbscan::DbscanConfig;
use dyn_dbscan::shard::{stitch_full, GlobalSnapshot, ShardConfig, ShardedEngine};
use dyn_dbscan::util::proptest::{run_prop, Gen};
use rustc_hash::FxHashMap;

/// Assert the two snapshots describe the same clustering: identical live
/// ext sets, identical noise sets, and a label bijection between the
/// clustered partitions (plus equal aggregate counters).
fn assert_label_isomorphic(delta: &GlobalSnapshot, full: &GlobalSnapshot, ctx: &str) {
    assert_eq!(delta.live_points, full.live_points, "{ctx}: live_points");
    assert_eq!(delta.clusters, full.clusters, "{ctx}: clusters");
    assert_eq!(delta.core_points, full.core_points, "{ctx}: core_points");
    assert_eq!(delta.shard_live, full.shard_live, "{ctx}: shard_live");
    let a = delta.labels();
    let b = full.labels();
    assert_eq!(a.len(), b.len(), "{ctx}: label count");
    let mut fwd: FxHashMap<i64, i64> = FxHashMap::default();
    let mut bwd: FxHashMap<i64, i64> = FxHashMap::default();
    for (&(ea, la), &(eb, lb)) in a.iter().zip(b.iter()) {
        assert_eq!(ea, eb, "{ctx}: live ext sets diverge at {ea} vs {eb}");
        assert_eq!(la < 0, lb < 0, "{ctx}: noise flag diverges at ext {ea}");
        if la < 0 {
            continue;
        }
        assert_eq!(
            *fwd.entry(la).or_insert(lb),
            lb,
            "{ctx}: delta label {la} maps to two rebuild labels (ext {ea})"
        );
        assert_eq!(
            *bwd.entry(lb).or_insert(la),
            la,
            "{ctx}: rebuild label {lb} maps to two delta labels (ext {ea})"
        );
    }
    // size multisets must agree too
    let mut sa: Vec<usize> = delta.cluster_sizes.iter().map(|&(_, s)| s).collect();
    let mut sb: Vec<usize> = full.cluster_sizes.iter().map(|&(_, s)| s).collect();
    sa.sort_unstable();
    sb.sort_unstable();
    assert_eq!(sa, sb, "{ctx}: cluster size multisets");
}

/// Randomized insert/delete schedules over a sharded engine in delta
/// mode; after every batch the published delta snapshot is checked
/// against `stitch_full` of a fresh full dump of the same workers.
#[test]
fn delta_snapshot_chain_matches_full_rebuild() {
    run_prop("delta snapshots vs full rebuild", 8, |g: &mut Gen| {
        let dim = g.usize_in(2..=4);
        let shards = *g.choose(&[1usize, 2, 3, 4]);
        let n = g.usize_in(300..=700);
        let ds = make_blobs(
            &BlobsConfig {
                n,
                dim,
                clusters: g.usize_in(2..=5),
                std: 0.35,
                center_box: 16.0,
                weights: vec![],
            },
            g.rng.next_u64(),
        );
        let cfg = DbscanConfig {
            k: g.usize_in(4..=8),
            t: 8,
            eps: 0.75,
            dim,
            ..Default::default()
        };
        let mut scfg = ShardConfig::new(cfg, shards, g.rng.next_u64());
        if g.rng.coin(0.5) {
            // small blocks force real cross-shard stitching
            scfg.block_side = 2;
        }
        let mut eng = ShardedEngine::new(scfg);
        let mut live: Vec<u64> = Vec::new();
        let mut next = 0usize;
        let mut round = 0usize;
        while next < n || !live.is_empty() {
            round += 1;
            // insert phase, then (every other round) a delete-heavy phase
            let ins = (g.usize_in(20..=80)).min(n - next);
            for _ in 0..ins {
                eng.insert(next as u64, ds.point(next));
                live.push(next as u64);
                next += 1;
            }
            let delete_heavy = round % 2 == 0 || next >= n;
            if delete_heavy && !live.is_empty() {
                let dels = g.usize_in(1..=live.len().min(60));
                for _ in 0..dels {
                    let i = g.rng.below_usize(live.len());
                    let e = live.swap_remove(i);
                    eng.delete(e);
                }
            }
            let snap = eng.publish();
            let reference = stitch_full(eng.full_dump(), snap.seq);
            assert_label_isomorphic(&snap, &reference, &format!("round {round}"));
            if next >= n && live.len() < 30 {
                // drain the tail and stop
                while let Some(e) = live.pop() {
                    eng.delete(e);
                }
                let snap = eng.publish();
                assert_eq!(snap.live_points, 0, "drained engine must be empty");
                assert_eq!(snap.clusters, 0);
                let reference = stitch_full(eng.full_dump(), snap.seq);
                assert_label_isomorphic(&snap, &reference, "drained");
                break;
            }
        }
        let _ = eng.finish();
    });
}

/// Deterministic split-forcing schedule: a 1-D bucket chain spanning
/// every shard boundary, with mid-chain block deletions that split one
/// global cluster into two — repeatedly, at different cut points — then
/// re-insertions that re-merge it. The delta chain must track every
/// split/merge exactly.
#[test]
fn cross_shard_splits_and_remerges_match_rebuild() {
    let cfg = DbscanConfig { k: 6, t: 4, eps: 0.4, dim: 1, ..Default::default() };
    let mut scfg = ShardConfig::new(cfg, 3, 11);
    scfg.block_side = 4; // many boundaries along the chain
    let mut eng = ShardedEngine::new(scfg);
    let n = 400usize;
    let pts: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
    for (i, &x) in pts.iter().enumerate() {
        eng.insert(i as u64, &[x]);
    }
    let first = eng.publish();
    let reference = stitch_full(eng.full_dump(), first.seq);
    assert_label_isomorphic(&first, &reference, "chain built");
    assert!(
        first.clusters >= 1,
        "chain should cluster, got {}",
        first.clusters
    );
    let mut rng = dyn_dbscan::util::rng::Rng::new(17);
    let block = 16usize;
    for round in 0..10 {
        let start = 40 + rng.below_usize(n - 80 - block);
        for i in start..start + block {
            eng.delete(i as u64);
        }
        let snap = eng.publish();
        let reference = stitch_full(eng.full_dump(), snap.seq);
        assert_label_isomorphic(&snap, &reference, &format!("round {round} split"));
        for i in start..start + block {
            eng.insert(i as u64, &[pts[i]]);
        }
        let snap = eng.publish();
        let reference = stitch_full(eng.full_dump(), snap.seq);
        assert_label_isomorphic(&snap, &reference, &format!("round {round} merge"));
    }
    let out = eng.finish();
    assert_eq!(out.snapshot.live_points, n);
}
