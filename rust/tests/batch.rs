//! Batched-ingestion equivalence: `add_points` / `apply_batch` must be
//! semantically identical to the same sequence of single `add_point` /
//! `delete_point` calls — same ids, same `OpStats`, same clustering.
//! (Batching only changes *when* hashing happens, never what is applied.)

use dyn_dbscan::data::blobs::{make_blobs, BlobsConfig};
use dyn_dbscan::dbscan::{DbscanConfig, DynamicDbscan, Op};
use dyn_dbscan::metrics::adjusted_rand_index;
use dyn_dbscan::util::proptest::{run_prop, Gen};
use dyn_dbscan::util::rng::Rng;
use rustc_hash::FxHashMap;

#[test]
fn add_points_matches_single_adds() {
    let ds = make_blobs(
        &BlobsConfig {
            n: 800,
            dim: 4,
            clusters: 3,
            std: 0.3,
            center_box: 20.0,
            weights: vec![],
        },
        21,
    );
    let cfg = DbscanConfig { k: 8, t: 10, eps: 0.75, dim: 4, ..Default::default() };
    // same seed => same hash functions; only the ingestion path differs
    let mut single = DynamicDbscan::new(cfg.clone(), 5);
    let mut batched = DynamicDbscan::new(cfg, 5);
    let ids_s: Vec<u64> = (0..ds.n()).map(|i| single.add_point(ds.point(i))).collect();
    let ids_b = batched.add_points(&ds.xs, ds.n());
    assert_eq!(ids_s, ids_b, "batched ids must match the single-add ids");
    assert_eq!(single.stats, batched.stats, "OpStats diverged");
    assert_eq!(single.num_core_points(), batched.num_core_points());
    let ls = single.labels_for(&ids_s);
    let lb = batched.labels_for(&ids_b);
    assert_eq!(
        adjusted_rand_index(&ls, &lb),
        1.0,
        "batched ingestion changed the clustering"
    );
}

/// Script of add/delete ops over stable point indices, pre-chunked so that
/// a delete never targets an add of its own chunk (its id would not exist
/// yet when the batch is built — the coordinator flushes in that case).
type Script = Vec<Vec<(bool, usize)>>;

fn build_script(g: &mut Gen, rng: &mut Rng, dim: usize) -> (Vec<Vec<f32>>, Script) {
    let mut pts: Vec<Vec<f32>> = Vec::new();
    let mut chunks: Script = Vec::new();
    // points added in earlier chunks and still live (deletable now) vs
    // added in the current chunk (deletable from the next chunk on)
    let mut live_old: Vec<usize> = Vec::new();
    let mut live_new: Vec<usize> = Vec::new();
    let n_chunks = g.usize_in(2..=8);
    for _ in 0..n_chunks {
        let len = g.usize_in(1..=25);
        let mut ops = Vec::new();
        for _ in 0..len {
            if live_old.is_empty() || rng.coin(0.65) {
                let c = rng.below(3) as f64 * 2.5;
                let p: Vec<f32> =
                    (0..dim).map(|_| (c + rng.uniform(-0.5, 0.5)) as f32).collect();
                ops.push((true, pts.len()));
                live_new.push(pts.len());
                pts.push(p);
            } else {
                let i = rng.below_usize(live_old.len());
                let idx = live_old.swap_remove(i);
                ops.push((false, idx));
            }
        }
        live_old.append(&mut live_new);
        chunks.push(ops);
    }
    (pts, chunks)
}

#[test]
fn apply_batch_matches_singles_under_churn() {
    run_prop("apply_batch vs single ops", 15, |g: &mut Gen| {
        let dim = g.usize_in(1..=3);
        let cfg = DbscanConfig {
            k: g.usize_in(2..=5),
            t: g.usize_in(2..=6),
            eps: g.f64_in(0.2, 1.0) as f32,
            dim,
            eager_attach: g.rng.coin(0.3),
        };
        let seed = g.rng.next_u64();
        let mut rng = Rng::new(seed ^ 0xBA7C);
        let (pts, chunks) = build_script(g, &mut rng, dim);

        // one op at a time
        let mut single = DynamicDbscan::new(cfg.clone(), seed);
        let mut id_s: FxHashMap<usize, u64> = FxHashMap::default();
        for chunk in &chunks {
            for &(is_add, idx) in chunk {
                if is_add {
                    id_s.insert(idx, single.add_point(&pts[idx]));
                } else {
                    let id = id_s.remove(&idx).expect("script deletes a dead point");
                    single.delete_point(id);
                }
            }
        }

        // one apply_batch per chunk
        let mut batched = DynamicDbscan::new(cfg, seed);
        let mut id_b: FxHashMap<usize, u64> = FxHashMap::default();
        for chunk in &chunks {
            let ops: Vec<Op> = chunk
                .iter()
                .map(|&(is_add, idx)| {
                    if is_add {
                        Op::Add(pts[idx].as_slice())
                    } else {
                        Op::Delete(id_b[&idx])
                    }
                })
                .collect();
            let new_ids = batched.apply_batch(&ops);
            let mut it = new_ids.into_iter();
            for &(is_add, idx) in chunk {
                if is_add {
                    id_b.insert(idx, it.next().expect("apply_batch returned too few ids"));
                } else {
                    id_b.remove(&idx);
                }
            }
            assert!(it.next().is_none(), "apply_batch returned too many ids");
        }

        // identical structure state
        assert_eq!(single.stats, batched.stats, "OpStats diverged");
        assert_eq!(single.num_points(), batched.num_points());
        assert_eq!(single.num_core_points(), batched.num_core_points());
        let mut surv_s: Vec<(usize, u64)> = id_s.into_iter().collect();
        let mut surv_b: Vec<(usize, u64)> = id_b.into_iter().collect();
        surv_s.sort_unstable();
        surv_b.sort_unstable();
        assert_eq!(surv_s, surv_b, "survivor (point, id) sets diverged");
        if !surv_s.is_empty() {
            let ids: Vec<u64> = surv_s.iter().map(|&(_, id)| id).collect();
            let ls = single.labels_for(&ids);
            let lb = batched.labels_for(&ids);
            assert_eq!(
                adjusted_rand_index(&ls, &lb),
                1.0,
                "batched churn changed the clustering"
            );
        }
    });
}
