//! Replication acceptance tests: differential checks of WAL log-shipping
//! read replicas against the leader they follow — bit-identical reads at
//! the same snapshot version, the bounded-staleness read contract under
//! churn, leader loss → promotion → uninterrupted service, and the
//! equivalence of incremental-checkpoint and full-checkpoint bootstrap.
//!
//! Replica determinism is stronger than crash-recovery determinism: a
//! replica attached to a *fresh* persist directory sees every op in the
//! exact order the leader logged it (shipped frames are the leader's
//! on-disk bytes), so labels — not just the partition — must match. Only
//! bootstrap from a pre-existing checkpoint re-ingests in a different
//! order, where the gate relaxes to ARI = 1.0 on well-separated blobs.

use std::path::PathBuf;

use dyn_dbscan::data::blobs::{make_blobs, BlobsConfig};
use dyn_dbscan::data::Dataset;
use dyn_dbscan::metrics::adjusted_rand_index;
use dyn_dbscan::persist::load_delta;
use dyn_dbscan::serve::{ClusterEngine, EngineBuilder, SnapshotView};
use rustc_hash::FxHashMap;

/// Fresh scratch directory under the system temp root (std-only: the
/// container has no tempfile crate). Unique per test name + process so
/// parallel test binaries never collide; recreated empty on entry.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dyn-dbscan-replica-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn blobs(n: usize, seed: u64) -> Dataset {
    // well separated (center_box ≫ std): border attachment is
    // order-independent up to the cluster label, so checkpoint-order
    // re-ingestion during bootstrap cannot cost ARI
    make_blobs(
        &BlobsConfig {
            n,
            dim: 3,
            clusters: 4,
            std: 0.3,
            center_box: 20.0,
            weights: vec![],
        },
        seed,
    )
}

fn builder(dim: usize) -> EngineBuilder {
    // eager_attach makes non-core attachment depend on the final point
    // set, not the insertion order — required by the ARI = 1.0 gates
    EngineBuilder::new(dim).k(8).t(6).eps(0.75).seed(21).eager_attach(true)
}

/// Exact label-partition agreement over identical live sets.
fn ari_of(a: &SnapshotView, b: &SnapshotView) -> f64 {
    let la = a.labels();
    let lb: FxHashMap<u64, i64> = b.labels().into_iter().collect();
    assert_eq!(la.len(), lb.len(), "live sets diverged");
    let mut pa = Vec::with_capacity(la.len());
    let mut pb = Vec::with_capacity(la.len());
    for (ext, va) in la {
        pa.push(va);
        pb.push(*lb.get(&ext).unwrap_or_else(|| panic!("{ext} missing in b")));
    }
    adjusted_rand_index(&pa, &pb)
}

// ---------------------------------------------------------------------
// bit-identical replica reads
// ---------------------------------------------------------------------

/// A replica view at version `v` answers every read — labels,
/// ε-neighborhoods, kNN — bit-identically to the leader's view at `v`,
/// across a delete-heavy churn schedule. Fresh persist directory, so the
/// followers see the leader's op stream verbatim: the gate is exact
/// equality, not ARI.
#[test]
fn replica_reads_are_bit_identical_at_the_same_version() {
    let dir = scratch("bit-identical");
    let ds = blobs(600, 3);
    let (mut leader, mut reads) = builder(3)
        .persist(&dir)
        .persist_every(1_000_000) // pure shipping: no mid-run spill
        .replicate(2)
        .max_staleness(0)
        .build_replicated()
        .unwrap();

    for (i, chunk) in (0..ds.n()).collect::<Vec<_>>().chunks(100).enumerate() {
        for &j in chunk {
            leader.upsert(j as u64, ds.point(j));
        }
        // churn: every other chunk deletes half of the previous chunk
        if i % 2 == 1 {
            for e in ((i - 1) * 100..(i - 1) * 100 + 50).map(|e| e as u64) {
                leader.remove(e);
            }
        }
        let lv = leader.publish();
        let shipped = reads.catch_up();
        assert!(shipped > 0, "publish must ship frames to the followers");

        // both followers (round-robin covers the pair in two reads)
        for _ in 0..2 {
            let rv = reads.read();
            assert_eq!(rv.version(), lv.version(), "version parity");
            assert_eq!(rv.live_points(), lv.live_points());
            assert_eq!(rv.core_points(), lv.core_points());
            let mut ll = lv.labels();
            let mut rl = rv.labels();
            ll.sort_unstable();
            rl.sort_unstable();
            assert_eq!(ll, rl, "replica labels must be bit-identical");

            // point queries answer from the replica's own pinned index
            for &p in &[0usize, 150, 420] {
                let probe = ds.point(p.min(ds.n() - 1));
                let mut ln = lv.epsilon_neighbors(probe);
                let mut rn = rv.epsilon_neighbors(probe);
                ln.sort_unstable();
                rn.sort_unstable();
                assert_eq!(ln, rn, "ε-neighborhood diverged at probe {p}");
                assert_eq!(
                    lv.k_nearest(probe, 5),
                    rv.k_nearest(probe, 5),
                    "kNN diverged at probe {p}"
                );
            }
        }
    }
    let _ = leader.finish();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// bounded staleness
// ---------------------------------------------------------------------

/// Staleness is measured in leader publish barriers and `read()` enforces
/// the configured bound: a lazy replica set falls behind publish by
/// publish, and a read either serves the (still-consistent) stale view —
/// when inside the bound — or synchronously catches the replica up first.
#[test]
fn reads_respect_the_publish_staleness_bound() {
    let dir = scratch("staleness");
    let ds = blobs(300, 5);
    let (mut leader, mut lazy) = builder(3)
        .persist(&dir)
        .replicate(2)
        .max_staleness(100) // never forces a catch-up in this run
        .build_replicated()
        .unwrap();

    let mut versions = Vec::new();
    for chunk in (0..ds.n()).collect::<Vec<_>>().chunks(60) {
        for &j in chunk {
            leader.upsert(j as u64, ds.point(j));
        }
        versions.push(leader.publish().version());
    }
    // nothing drained: every follower trails by all five publishes
    assert_eq!(lazy.lags(), vec![5, 5]);
    let stale = lazy.read();
    assert_eq!(
        stale.version(),
        0,
        "inside the bound, read() serves the stale view as-is"
    );
    assert_eq!(lazy.lags(), vec![5, 5], "a bounded read must not catch up");

    // a zero-staleness router over the same shipped stream always
    // answers at the leader's frontier
    let dir2 = scratch("staleness-zero");
    let (mut leader2, mut fresh) = builder(3)
        .persist(&dir2)
        .replicate(2)
        .max_staleness(0)
        .build_replicated()
        .unwrap();
    for chunk in (0..ds.n()).collect::<Vec<_>>().chunks(60) {
        for &j in chunk {
            leader2.upsert(j as u64, ds.point(j));
        }
        let lv = leader2.publish();
        // no explicit catch_up(): read() must do it to honor the bound
        let rv = fresh.read();
        assert_eq!(rv.version(), lv.version(), "zero staleness = parity");
        // the replica that answered is now at the frontier
        assert!(fresh.lags().iter().any(|&l| l == 0));
    }
    // per-replica lag accounting: the round-robin partner of the last
    // read may still trail, but never by more than the publishes issued
    for lag in fresh.lags() {
        assert!(lag <= versions.len() as u64);
    }
    let _ = leader.finish();
    let _ = leader2.finish();
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dir2);
}

// ---------------------------------------------------------------------
// leader loss → promotion
// ---------------------------------------------------------------------

/// Kill the leader (`mem::forget`: no flush, no shutdown spill) and
/// promote the follower: the promoted engine continues the leader's
/// version numbering, serves the full published history, and keeps
/// clustering new writes — ARI = 1.0 against an uninterrupted oracle fed
/// the identical op sequence.
#[test]
fn leader_kill_then_promote_continues_service() {
    let dir = scratch("promote");
    let ds = blobs(600, 9);
    let (mut leader, mut reads) = builder(3)
        .persist(&dir)
        .replicate(1)
        .max_staleness(0)
        .build_replicated()
        .unwrap();
    let mut oracle = builder(3).build().unwrap();

    let mut last_version = 0;
    for chunk in (0..400).collect::<Vec<_>>().chunks(100) {
        for &j in chunk {
            leader.upsert(j as u64, ds.point(j));
            oracle.upsert(j as u64, ds.point(j));
        }
        last_version = leader.publish().version();
        oracle.publish();
    }
    // accepted but never published: lost with the leader, by contract
    leader.upsert(999_999, &[50.0, 50.0, 50.0]);
    std::mem::forget(leader);

    let mut promoted = reads.promote(0);
    let pv = promoted.snapshot();
    assert_eq!(pv.version(), last_version, "version continuity");
    assert!(!pv.contains(999_999), "unpublished write must not survive");

    // the new leader keeps serving writes where the old one stopped
    for j in 400..ds.n() {
        promoted.upsert(j as u64, ds.point(j));
        oracle.upsert(j as u64, ds.point(j));
    }
    let after = promoted.publish();
    let fv = oracle.publish();
    assert_eq!(after.version(), last_version + 1, "numbering continues");
    assert_eq!(after.live_points(), fv.live_points());
    let ari = ari_of(&after, &fv);
    assert_eq!(ari, 1.0, "post-promotion partition diverged (ARI {ari})");
    let _ = promoted.finish();
    let _ = oracle.finish();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// incremental vs full checkpoint bootstrap
// ---------------------------------------------------------------------

/// Followers bootstrapping from an incremental chain (full spill + delta
/// checkpoints + WAL tail) and from full-only checkpoints must recover
/// the same published state: same version, same live/core counts, same
/// partition. The incremental run must actually exercise the delta path
/// (a `checkpoint.delta` survives on disk at bootstrap time).
#[test]
fn incremental_and_full_bootstrap_are_equivalent() {
    let ds = blobs(600, 13);
    let mut dirs = Vec::new();
    for (tag, incremental) in [("boot-incr", true), ("boot-full", false)] {
        let dir = scratch(tag);
        let mut leader = builder(3)
            .persist(&dir)
            .persist_every(2)
            .incremental_checkpoints(incremental)
            .build()
            .unwrap();
        // bulk load + publishes: cadence lands the first (always full)
        // spill with the whole dataset folded in
        for chunk in (0..ds.n()).collect::<Vec<_>>().chunks(150) {
            for &j in chunk {
                leader.upsert(j as u64, ds.point(j));
            }
            leader.publish();
        }
        // small touch-ups: 20 distinct keys dirty at most 20 of the 64
        // coordinate chunks, so the incremental run spills deltas
        // instead of re-writing the full state
        for round in 0..4u64 {
            for e in 0..5u64 {
                let j = (round * 5 + e) as usize;
                leader.upsert(j as u64, ds.point(ds.n() - 1 - j));
            }
            leader.publish();
        }
        if incremental {
            assert!(
                load_delta(&dir).is_some(),
                "incremental run must leave a delta checkpoint behind"
            );
        } else {
            assert!(load_delta(&dir).is_none());
        }
        // crash, not shutdown: finish() would spill a fresh full
        // checkpoint and erase the chain we want to bootstrap from
        std::mem::forget(leader);
        dirs.push(dir);
    }

    // bootstrap one follower from each directory and compare
    let mut views = Vec::new();
    for dir in &dirs {
        let (leader, mut reads) = builder(3)
            .persist(dir)
            .persist_every(1_000_000)
            .replicate(1)
            .max_staleness(0)
            .build_replicated()
            .unwrap();
        views.push(reads.read());
        let _ = leader.finish();
    }
    let (incr, full) = (&views[0], &views[1]);
    assert_eq!(incr.version(), full.version(), "recovered version parity");
    assert_eq!(incr.live_points(), full.live_points());
    assert_eq!(incr.core_points(), full.core_points());
    let ari = ari_of(incr, full);
    assert_eq!(ari, 1.0, "incremental bootstrap diverged from full (ARI {ari})");
    for dir in dirs {
        let _ = std::fs::remove_dir_all(&dir);
    }
}
