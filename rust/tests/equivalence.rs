//! Cross-validation between independent implementations:
//!
//! * `DynamicDbscan` (incremental, Euler-tour forest) vs a from-scratch
//!   static realization of Definition 4 over the *same* hash functions —
//!   core sets and core components must agree exactly after any stream;
//! * quality agreement between `DynamicDbscan`, EMZ and exact DBSCAN on
//!   separable data (all three should find the planted clusters);
//! * treap vs skip-list backends must produce identical clusterings.

use dyn_dbscan::baselines::brute::{BruteDbscan, NativeDistance};
use dyn_dbscan::baselines::emz::{Emz, EmzConfig};
use dyn_dbscan::baselines::unionfind::UnionFind;
use dyn_dbscan::data::blobs::{make_blobs, BlobsConfig};
use dyn_dbscan::dbscan::{DbscanConfig, DynamicDbscan};
use dyn_dbscan::lsh::GridHasher;
use dyn_dbscan::metrics::adjusted_rand_index;
use dyn_dbscan::util::rng::Rng;
use rustc_hash::FxHashMap;

/// Static Definition-4 clustering with externally supplied hash functions:
/// core = some bucket ≥ k; components = cores colliding anywhere.
fn static_def4(
    hasher: &GridHasher,
    k: usize,
    pts: &[Vec<f32>],
) -> (Vec<bool>, Vec<i64>) {
    let n = pts.len();
    let mut scratch = Vec::new();
    let keys: Vec<Vec<u128>> = pts.iter().map(|p| hasher.keys(p, &mut scratch)).collect();
    let mut is_core = vec![false; n];
    for i in 0..hasher.t {
        let mut buckets: FxHashMap<u128, Vec<usize>> = FxHashMap::default();
        for (j, kk) in keys.iter().enumerate() {
            buckets.entry(kk[i]).or_default().push(j);
        }
        for members in buckets.values() {
            if members.len() >= k {
                for &m in members {
                    is_core[m] = true;
                }
            }
        }
    }
    let mut uf = UnionFind::new(n);
    for i in 0..hasher.t {
        let mut rep: FxHashMap<u128, usize> = FxHashMap::default();
        for (j, kk) in keys.iter().enumerate() {
            if !is_core[j] {
                continue;
            }
            match rep.entry(kk[i]) {
                std::collections::hash_map::Entry::Occupied(e) => {
                    uf.union(j, *e.get());
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(j);
                }
            }
        }
    }
    let mut labels = vec![-1i64; n];
    let mut next = 0i64;
    let mut seen: FxHashMap<usize, i64> = FxHashMap::default();
    for j in 0..n {
        if is_core[j] {
            let r = uf.find(j);
            labels[j] = *seen.entry(r).or_insert_with(|| {
                let l = next;
                next += 1;
                l
            });
        }
    }
    (is_core, labels)
}

#[test]
fn dynamic_matches_static_def4_after_stream() {
    for seed in [3u64, 17, 99] {
        let cfg = DbscanConfig { k: 4, t: 5, eps: 0.5, dim: 2, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg.clone(), seed);
        let mut rng = Rng::new(seed ^ 0xAB);
        // churn: adds with interleaved deletes, then compare the SURVIVORS
        let mut pts: Vec<Vec<f32>> = Vec::new();
        let mut ids: Vec<u64> = Vec::new();
        let mut alive: Vec<usize> = Vec::new();
        for _ in 0..400 {
            if alive.is_empty() || rng.coin(0.75) {
                let c = rng.below(3) as f64 * 2.0;
                let p: Vec<f32> = (0..2)
                    .map(|_| (c + rng.uniform(-0.6, 0.6)) as f32)
                    .collect();
                ids.push(db.add_point(&p));
                pts.push(p);
                alive.push(ids.len() - 1);
            } else {
                let i = rng.below_usize(alive.len());
                let j = alive.swap_remove(i);
                db.delete_point(ids[j]);
            }
        }
        // static reference over the surviving points with the same hasher
        let survivors: Vec<Vec<f32>> = alive.iter().map(|&j| pts[j].clone()).collect();
        let (ref_core, ref_labels) = static_def4(&db.hasher, cfg.k, &survivors);
        // core set must agree exactly
        for (pos, &j) in alive.iter().enumerate() {
            assert_eq!(
                db.is_core(ids[j]),
                ref_core[pos],
                "core flag mismatch at live point {pos} (seed {seed})"
            );
        }
        // core components must agree exactly (pairwise)
        let live_ids: Vec<u64> = alive.iter().map(|&j| ids[j]).collect();
        for a in 0..alive.len() {
            for b in (a + 1)..alive.len() {
                if ref_core[a] && ref_core[b] {
                    assert_eq!(
                        db.get_cluster(live_ids[a]) == db.get_cluster(live_ids[b]),
                        ref_labels[a] == ref_labels[b],
                        "component mismatch between {a},{b} (seed {seed})"
                    );
                }
            }
        }
    }
}

#[test]
fn three_algorithms_agree_on_separable_blobs() {
    let ds = make_blobs(
        &BlobsConfig {
            n: 1500,
            dim: 5,
            clusters: 4,
            std: 0.3,
            center_box: 25.0,
            weights: vec![],
        },
        11,
    );
    // DynamicDbscan
    let cfg = DbscanConfig { k: 8, t: 10, eps: 0.75, dim: 5, ..Default::default() };
    let mut db = DynamicDbscan::new(cfg, 2);
    let ids: Vec<u64> = (0..ds.n()).map(|i| db.add_point(ds.point(i))).collect();
    let dyn_labels = db.labels_for(&ids);
    // EMZ
    let emz = Emz::new(EmzConfig { k: 8, t: 10, eps: 0.75, dim: 5 }, 3);
    let emz_labels = emz.cluster(&ds.xs, ds.n()).labels;
    // exact
    let brute_labels =
        BruteDbscan::new(1.0, 8).cluster(&ds.xs, ds.n(), 5, &mut NativeDistance);
    for (name, labels) in
        [("dyn", &dyn_labels), ("emz", &emz_labels), ("brute", &brute_labels)]
    {
        let ari = adjusted_rand_index(&ds.labels, labels);
        assert!(ari > 0.97, "{name} ARI {ari} too low");
    }
    // and with each other
    assert!(adjusted_rand_index(&dyn_labels, &emz_labels) > 0.95);
    assert!(adjusted_rand_index(&dyn_labels, &brute_labels) > 0.95);
}

#[test]
fn treap_and_skiplist_backends_agree() {
    use dyn_dbscan::dbscan::{RepairConn, TreapConn};
    use dyn_dbscan::ett::TreapForest;
    let cfg = DbscanConfig { k: 4, t: 6, eps: 0.5, dim: 2, ..Default::default() };
    let mut a = DynamicDbscan::new(cfg.clone(), 7);
    let mut b: DynamicDbscan<TreapConn> =
        DynamicDbscan::with_conn(cfg, 7, RepairConn::new(TreapForest::new(8)));
    let mut rng = Rng::new(123);
    let mut ids: Vec<(u64, u64)> = Vec::new();
    for _ in 0..500 {
        if ids.is_empty() || rng.coin(0.7) {
            let c = rng.below(3) as f64 * 2.0;
            let p: Vec<f32> =
                (0..2).map(|_| (c + rng.uniform(-0.5, 0.5)) as f32).collect();
            ids.push((a.add_point(&p), b.add_point(&p)));
        } else {
            let i = rng.below_usize(ids.len());
            let (ia, ib) = ids.swap_remove(i);
            a.delete_point(ia);
            b.delete_point(ib);
        }
    }
    assert_eq!(a.num_points(), b.num_points());
    assert_eq!(a.num_core_points(), b.num_core_points());
    for (pos, &(ia, ib)) in ids.iter().enumerate() {
        assert_eq!(a.is_core(ia), b.is_core(ib), "core mismatch at {pos}");
    }
    // identical partitions over all live points
    let la: Vec<i64> = a.labels_for(&ids.iter().map(|x| x.0).collect::<Vec<_>>());
    let lb: Vec<i64> = b.labels_for(&ids.iter().map(|x| x.1).collect::<Vec<_>>());
    assert_eq!(adjusted_rand_index(&la, &lb), 1.0, "backends disagree");
}
