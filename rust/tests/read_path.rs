//! Differential acceptance suite for the indexed read path: on random
//! churn snapshots, `epsilon_neighbors` / `k_nearest` / `cluster_members`
//! answered through the snapshot-pinned spatial index must be
//! **bit-identical** to the retained brute-force scan oracles
//! (`*_scan`), on both backends — including boundary-straddling probes,
//! points at exactly distance ε, and duplicate coordinates. Plus the CoW
//! contract: a publish that touches nothing must not deep-clone the
//! index (sharing gauge stays 1.0), and durable recovery rebuilds an
//! index that answers identically.

use dyn_dbscan::data::blobs::{make_blobs, BlobsConfig};
use dyn_dbscan::serve::{Backend, ClusterEngine, EngineBuilder, SnapshotView};
use dyn_dbscan::util::proptest::{run_prop, Gen};
use dyn_dbscan::util::rng::Rng;

const EPS: f32 = 0.5;

fn builder(dim: usize, seed: u64) -> EngineBuilder {
    EngineBuilder::new(dim).k(4).t(6).eps(EPS).seed(seed)
}

/// Indexed vs oracle answers on one view, for a set of probes.
fn assert_reads_match_oracle(view: &SnapshotView, probes: &[Vec<f32>]) {
    for p in probes {
        assert_eq!(
            view.epsilon_neighbors(p),
            view.epsilon_neighbors_scan(p),
            "ε-neighborhood diverged from the scan oracle at {p:?}"
        );
        for k in [1usize, 5, 64] {
            let indexed = view.k_nearest(p, k);
            let oracle = view.k_nearest_scan(p, k);
            assert_eq!(indexed, oracle, "kNN(k={k}) diverged at {p:?}");
        }
    }
    let mut labels: Vec<i64> =
        view.cluster_sizes().iter().map(|&(l, _)| l).collect();
    labels.push(-1); // noise
    labels.push(9_999_999); // unknown label
    for l in labels {
        assert_eq!(
            view.cluster_members(l),
            view.cluster_members_scan(l),
            "cluster_members({l}) diverged from the scan oracle"
        );
    }
}

/// Probes that stress the cell decomposition: data points themselves
/// (distance-0 and duplicate hits), points displaced by exactly ε along
/// an axis (boundary of the ball), points straddling cell boundaries
/// (displaced by the 2ε cell side), and uniform random positions.
fn stress_probes(g: &mut Rng, view: &SnapshotView, dim: usize, extent: f64) -> Vec<Vec<f32>> {
    let mut probes: Vec<Vec<f32>> = Vec::new();
    let mut exts: Vec<u64> = Vec::new();
    let mut labels: Vec<i64> =
        view.cluster_sizes().iter().map(|&(l, _)| l).collect();
    labels.push(-1);
    for l in labels {
        exts.extend(view.cluster_members(l).into_iter().take(1));
        if exts.len() >= 3 {
            break;
        }
    }
    for ext in exts {
        if let Some(row) = view.coords_of(ext) {
            let base = row.to_vec();
            probes.push(base.clone());
            for axis in 0..dim.min(2) {
                let mut at_eps = base.clone();
                at_eps[axis] += EPS; // a data point at exactly distance ε
                probes.push(at_eps);
                let mut straddle = base.clone();
                straddle[axis] += 2.0 * EPS; // exactly one cell side away
                probes.push(straddle);
            }
        }
    }
    for _ in 0..4 {
        probes.push(
            (0..dim).map(|_| ((g.next_f64() - 0.5) * extent) as f32).collect(),
        );
    }
    probes
}

/// Random churn (insert / upsert-replace / delete, duplicates injected)
/// across several publishes; every published view must answer indexed
/// reads identically to the oracles — on both backends.
#[test]
fn indexed_reads_match_oracle_under_churn() {
    run_prop("indexed reads vs scan oracle", 6, |g: &mut Gen| {
        let dim = *g.choose(&[2usize, 3]);
        let backend = *g.choose(&[Backend::Single, Backend::Sharded(2)]);
        let seed = g.rng.next_u64();
        let ds = make_blobs(
            &BlobsConfig {
                n: 400,
                dim,
                clusters: 4,
                std: 0.3,
                center_box: 6.0,
                weights: vec![],
            },
            seed,
        );
        let mut eng = builder(dim, seed).backend(backend).build().unwrap();
        let n = ds.n();
        let mut live: Vec<u64> = Vec::new();
        let mut next = 0usize;
        for round in 0..4 {
            // grow: insert a fresh slice, duplicating some coordinates
            for _ in 0..100 {
                if next >= n {
                    break;
                }
                let row = &ds.xs[next * dim..(next + 1) * dim];
                eng.upsert(next as u64, row);
                live.push(next as u64);
                if next % 7 == 0 {
                    // duplicate coordinates under a distinct ext
                    let dup = (n + next) as u64;
                    eng.upsert(dup, row);
                    live.push(dup);
                }
                next += 1;
            }
            // churn: replace some, delete some
            for _ in 0..20 {
                if live.len() < 4 {
                    break;
                }
                let i = g.usize_in(0..=live.len() - 1);
                let ext = live[i];
                if g.rng.next_u64() % 2 == 0 {
                    let j = g.usize_in(0..=n - 1);
                    eng.upsert(ext, &ds.xs[j * dim..(j + 1) * dim]);
                } else {
                    eng.remove(ext);
                    live.swap_remove(i);
                }
            }
            let view = eng.publish();
            assert!(view.has_spatial_index(), "index missing on round {round}");
            let probes = stress_probes(&mut g.rng, &view, dim, 14.0);
            assert_reads_match_oracle(&view, &probes);
        }
        let _ = eng.finish();
    });
}

/// The scan-fallback configurations (index off; dim past the policy
/// ceiling) answer through the same public methods — and still match the
/// oracles trivially (they *are* the oracles then).
#[test]
fn fallback_configurations_answer_identically() {
    for (label, builder) in [
        ("disabled", EngineBuilder::new(3).k(3).t(4).eps(EPS).spatial_index(false)),
        ("past-max-dim", EngineBuilder::new(3).k(3).t(4).eps(EPS).index_max_dim(2)),
    ] {
        let mut eng = builder.seed(5).build().unwrap();
        let mut rng = Rng::new(99);
        for e in 0..300u64 {
            let row: Vec<f32> =
                (0..3).map(|_| ((rng.next_f64() - 0.5) * 8.0) as f32).collect();
            eng.upsert(e, &row);
        }
        let view = eng.publish();
        assert!(!view.has_spatial_index(), "{label}: expected scan fallback");
        let probes = stress_probes(&mut rng, &view, 3, 8.0);
        assert_reads_match_oracle(&view, &probes);
        let _ = eng.finish();
    }
}

/// Rebuild-at-publish (the FullRebuild analogue) must serve the same
/// answers as delta maintenance.
#[test]
fn rebuild_mode_matches_delta_maintenance() {
    let mut delta = builder(2, 7).build().unwrap();
    let mut rebuild = builder(2, 7).index_rebuild(true).build().unwrap();
    let mut rng = Rng::new(31);
    for e in 0..500u64 {
        let row: Vec<f32> =
            (0..2).map(|_| ((rng.next_f64() - 0.5) * 10.0) as f32).collect();
        delta.upsert(e, &row);
        rebuild.upsert(e, &row);
    }
    for e in 0..100u64 {
        delta.remove(e * 3);
        rebuild.remove(e * 3);
    }
    let vd = delta.publish();
    let vr = rebuild.publish();
    assert!(vd.has_spatial_index() && vr.has_spatial_index());
    for _ in 0..10 {
        let p: Vec<f32> =
            (0..2).map(|_| ((rng.next_f64() - 0.5) * 10.0) as f32).collect();
        assert_eq!(vd.epsilon_neighbors(&p), vr.epsilon_neighbors(&p));
        assert_eq!(vd.k_nearest(&p, 9), vr.k_nearest(&p, 9));
    }
    let _ = delta.finish();
    let _ = rebuild.finish();
}

/// CoW contract: a publish with **no** intervening writes must not
/// deep-clone any index chunk — the `cow_index_sharing` gauge reads 1.0
/// — while a touched publish drops below 1.0 only because of the delta.
/// Also checks `index_cells` is live. Runs on both backends.
#[test]
fn untouched_publish_shares_the_whole_index() {
    for backend in [Backend::Single, Backend::Sharded(2)] {
        let mut eng = builder(2, 13).backend(backend).build().unwrap();
        let mut rng = Rng::new(17);
        for e in 0..2_000u64 {
            let row: Vec<f32> =
                (0..2).map(|_| ((rng.next_f64() - 0.5) * 40.0) as f32).collect();
            eng.upsert(e, &row);
        }
        eng.publish();
        // nothing written since the last publish: every chunk of the
        // index (and of the coord store) is still snapshot-shared
        eng.publish();
        let gauges = eng.metrics().gauges;
        let get = |name: &str| {
            gauges
                .iter()
                .find(|(g, _)| *g == name)
                .map(|&(_, v)| v)
                .unwrap_or_else(|| panic!("gauge {name} missing ({backend:?})"))
        };
        assert!(
            (get("cow_index_sharing") - 1.0).abs() < 1e-12,
            "untouched publish deep-cloned index chunks ({backend:?}): {}",
            get("cow_index_sharing")
        );
        assert!(get("index_cells") > 0.0, "index_cells gauge dead ({backend:?})");
        // one write: sharing drops below 1.0 (the delta), not to 0
        eng.upsert(5_000_000, &[0.0, 0.0]);
        eng.publish();
        let gauges = eng.metrics().gauges;
        let sharing = gauges
            .iter()
            .find(|(g, _)| *g == "cow_index_sharing")
            .map(|&(_, v)| v)
            .unwrap();
        assert!(
            sharing < 1.0 && sharing > 0.5,
            "single-write publish should deep-clone only touched chunks \
             ({backend:?}): {sharing}"
        );
        let _ = eng.finish();
    }
}

/// Durable recovery replays through the public write path, so a reopened
/// engine serves an index answering identically to the oracle at the
/// recovered version.
#[test]
fn recovered_engine_serves_indexed_reads() {
    let dir = std::env::temp_dir().join(format!(
        "dyn-dbscan-read-path-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let mut rng = Rng::new(41);
    let rows: Vec<Vec<f32>> = (0..300)
        .map(|_| (0..2).map(|_| ((rng.next_f64() - 0.5) * 8.0) as f32).collect())
        .collect();
    {
        let mut eng = builder(2, 3).persist(&dir).build().unwrap();
        for (e, row) in rows.iter().enumerate() {
            eng.upsert(e as u64, row);
        }
        eng.publish();
        // dropped without finish(): recovery comes from WAL + checkpoint
    }
    let mut eng = builder(2, 3).persist(&dir).build().unwrap();
    let view = eng.snapshot();
    assert_eq!(view.live_points(), rows.len());
    assert!(view.has_spatial_index(), "recovered view lost the index");
    let probes = stress_probes(&mut rng, &view, 2, 8.0);
    assert_reads_match_oracle(&view, &probes);
    let _ = eng.finish();
    let _ = std::fs::remove_dir_all(&dir);
}
