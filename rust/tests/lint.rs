//! Source lints enforced as tests — cheap greps over the hot-path sources
//! that guard the arena refactor's allocation discipline against
//! regressions a reviewer could easily miss.

/// The pre-arena update path cloned the per-point key vector at seven call
/// sites (`promote`, `eager_attach`, `delete_point` ×2, `unlink_core`,
/// `demote_marks`, plus the non-core delete branch). The arena borrows
/// 16-byte key copies by slot instead; no `.keys.clone()` may come back.
#[test]
fn no_keys_clone_in_update_path() {
    for (name, src) in [
        ("dbscan/mod.rs", include_str!("../src/dbscan/mod.rs")),
        ("dbscan/arena.rs", include_str!("../src/dbscan/arena.rs")),
        ("dbscan/connectivity.rs", include_str!("../src/dbscan/connectivity.rs")),
    ] {
        assert!(
            !src.contains(".keys.clone()"),
            "{name} clones a per-point key vector on the update path; \
             borrow the arena key row (PointArena::key / key_row) instead"
        );
    }
}

/// The update path must not materialize per-op coordinate vectors either:
/// `x.to_vec()` in dbscan/mod.rs would reintroduce a heap allocation per
/// add (coordinates are copied straight into the arena's flat row).
#[test]
fn no_coord_to_vec_in_update_path() {
    let src = include_str!("../src/dbscan/mod.rs");
    assert!(
        !src.contains("x.to_vec()"),
        "dbscan/mod.rs copies coordinates into a per-op Vec; \
         write them into the arena row instead"
    );
}

/// The shard wire format ships one flat coord buffer per batch; per-op
/// `coords.to_vec()` in the engine's insert path would undo that.
#[test]
fn shard_insert_path_has_no_per_op_coord_vec() {
    let src = include_str!("../src/shard/engine.rs");
    assert!(
        !src.contains("coords.to_vec()"),
        "shard/engine.rs allocates a coordinate Vec per op; \
         append to the pending ShardBatch's flat buffer instead"
    );
}

/// The connectivity hot path must never fall back to an unbounded
/// full-component tour walk: `Forest::component_vertices` is
/// `O(component size)` and exists solely for the legacy `RepairConn`
/// ablation. The leveled default and the DBSCAN core must reach
/// replacement candidates through the `O(log n)` mark aggregates
/// (`find_marked_vertex` / `find_marked_edge`) instead.
#[test]
fn no_component_walk_outside_the_repair_ablation() {
    for (name, src) in [
        ("dbscan/leveled.rs", include_str!("../src/dbscan/leveled.rs")),
        ("dbscan/mod.rs", include_str!("../src/dbscan/mod.rs")),
        ("dbscan/arena.rs", include_str!("../src/dbscan/arena.rs")),
        ("shard/worker.rs", include_str!("../src/shard/worker.rs")),
    ] {
        assert!(
            !src.contains("component_vertices"),
            "{name} walks a full component tour on the hot path; \
             use the mark-aggregate searches instead"
        );
    }
    // connectivity.rs keeps exactly one call site: RepairConn::replace
    let conn = include_str!("../src/dbscan/connectivity.rs");
    assert_eq!(
        conn.matches("component_vertices").count(),
        1,
        "connectivity.rs must keep component_vertices confined to the \
         single legacy RepairConn::replace call site"
    );
}

/// The Δ-charged tour walk (`for_each_tree_vertex`) exists solely for the
/// stable-component event plumbing — one call site in the leveled
/// structure's `comp_absorb`. It must never leak into the replacement
/// search, the DBSCAN core or the shard layer, where it would reintroduce
/// the `O(component)` walks this architecture removes.
#[test]
fn tree_walk_confined_to_comp_event_plumbing() {
    let leveled = include_str!("../src/dbscan/leveled.rs");
    assert_eq!(
        leveled.matches("for_each_tree_vertex").count(),
        1,
        "leveled.rs must call for_each_tree_vertex only from comp_absorb"
    );
    for (name, src) in [
        ("dbscan/mod.rs", include_str!("../src/dbscan/mod.rs")),
        ("dbscan/connectivity.rs", include_str!("../src/dbscan/connectivity.rs")),
        ("shard/stitch.rs", include_str!("../src/shard/stitch.rs")),
        ("shard/worker.rs", include_str!("../src/shard/worker.rs")),
        ("shard/engine.rs", include_str!("../src/shard/engine.rs")),
    ] {
        assert!(
            !src.contains("for_each_tree_vertex"),
            "{name} walks a full tree tour; only the comp-event plumbing \
             in dbscan/leveled.rs may do that"
        );
    }
}

/// Every consumer-facing layer goes through the serve façade: direct
/// engine construction (`DynamicDbscan::…` / `ShardedEngine::…` /
/// `ShardConfig::…`) and raw `PointId` mutation (`.add_point(…)` /
/// `.delete_point(…)`) are confined to `serve/` itself, the shard/dbscan
/// internals, the benches and the ablation/experiment code. The CLI, the
/// coordinator driver and every example must compile against
/// `serve::{EngineBuilder, ClusterEngine, SnapshotView}` only.
#[test]
fn consumers_go_through_the_serve_facade() {
    for (name, src) in [
        ("cli/commands.rs", include_str!("../src/cli/commands.rs")),
        ("cli/mod.rs", include_str!("../src/cli/mod.rs")),
        ("coordinator/driver.rs", include_str!("../src/coordinator/driver.rs")),
        ("examples/quickstart.rs", include_str!("../../examples/quickstart.rs")),
        (
            "examples/streaming_blobs.rs",
            include_str!("../../examples/streaming_blobs.rs"),
        ),
        (
            "examples/sliding_window.rs",
            include_str!("../../examples/sliding_window.rs"),
        ),
        (
            "examples/intrusion_detection.rs",
            include_str!("../../examples/intrusion_detection.rs"),
        ),
        (
            "examples/sharded_stream.rs",
            include_str!("../../examples/sharded_stream.rs"),
        ),
        (
            "examples/batched_ingest.rs",
            include_str!("../../examples/batched_ingest.rs"),
        ),
    ] {
        for pat in [
            "DynamicDbscan::",
            "ShardedEngine::",
            "ShardConfig::",
            ".add_point(",
            ".add_points(",
            ".apply_batch(",
            ".delete_point(",
        ] {
            assert!(
                !src.contains(pat),
                "{name} bypasses the serve façade ({pat}); construct engines \
                 through serve::EngineBuilder and drive them through \
                 serve::ClusterEngine"
            );
        }
    }
}

/// Full-rebuild stitching (`stitch_full` + full `ShardSnapshot` dumps) is
/// the explicit fallback path, not the serving default: the engine may
/// call it only from the `StitchMode::FullRebuild` publish arm (plus its
/// own differential test), and the delta plumbing must never fall back to
/// it silently.
#[test]
fn full_rebuild_stitching_confined_to_fallback_path() {
    let engine = include_str!("../src/shard/engine.rs");
    // one call in publish's FullRebuild arm + one in the in-file
    // differential test (imports excluded by matching the call form)
    assert_eq!(
        engine.matches("stitch_full(").count(),
        2,
        "engine.rs must call stitch_full only from the FullRebuild \
         publish arm and its differential test"
    );
    for (name, src) in [
        ("shard/worker.rs", include_str!("../src/shard/worker.rs")),
        ("shard/labels.rs", include_str!("../src/shard/labels.rs")),
        ("serve/sharded.rs", include_str!("../src/serve/sharded.rs")),
    ] {
        assert!(
            !src.contains("stitch_full"),
            "{name} reaches for the full-rebuild stitcher; the serving \
             path must stay incremental"
        );
    }
    // the incremental stitcher must not materialize the full sorted label
    // vector anywhere but the on-demand GlobalSnapshot::labels accessor
    let stitch = include_str!("../src/shard/stitch.rs");
    assert_eq!(
        stitch.matches(".sorted()").count(),
        1,
        "stitch.rs must materialize sorted labels only in GlobalSnapshot::labels"
    );
}

/// All wall-clock timing in the serving and clustering layers goes
/// through the obs span API (`obs::Stopwatch` / `obs::PhaseClock` /
/// `span!`) — never ad-hoc `Instant::now()`. This keeps instrumentation
/// centralized (one place to audit the overhead budget, one switch to
/// disable it) and is what makes the `obs_overhead` bench gate
/// meaningful. Bench harness and experiment drivers time themselves and
/// are exempt.
#[test]
fn timing_goes_through_the_obs_span_api() {
    for (name, src) in [
        ("serve/mod.rs", include_str!("../src/serve/mod.rs")),
        ("serve/builder.rs", include_str!("../src/serve/builder.rs")),
        ("serve/driver.rs", include_str!("../src/serve/driver.rs")),
        ("serve/events.rs", include_str!("../src/serve/events.rs")),
        ("serve/index.rs", include_str!("../src/serve/index.rs")),
        ("serve/inline.rs", include_str!("../src/serve/inline.rs")),
        ("serve/sharded.rs", include_str!("../src/serve/sharded.rs")),
        ("serve/snapshot.rs", include_str!("../src/serve/snapshot.rs")),
        ("serve/durable.rs", include_str!("../src/serve/durable.rs")),
        ("persist/mod.rs", include_str!("../src/persist/mod.rs")),
        ("persist/wal.rs", include_str!("../src/persist/wal.rs")),
        ("persist/checkpoint.rs", include_str!("../src/persist/checkpoint.rs")),
        ("shard/engine.rs", include_str!("../src/shard/engine.rs")),
        ("shard/labels.rs", include_str!("../src/shard/labels.rs")),
        ("shard/mod.rs", include_str!("../src/shard/mod.rs")),
        ("shard/placement.rs", include_str!("../src/shard/placement.rs")),
        ("shard/router.rs", include_str!("../src/shard/router.rs")),
        ("shard/stitch.rs", include_str!("../src/shard/stitch.rs")),
        ("shard/worker.rs", include_str!("../src/shard/worker.rs")),
        ("dbscan/arena.rs", include_str!("../src/dbscan/arena.rs")),
        ("dbscan/connectivity.rs", include_str!("../src/dbscan/connectivity.rs")),
        ("dbscan/invariants.rs", include_str!("../src/dbscan/invariants.rs")),
        ("dbscan/leveled.rs", include_str!("../src/dbscan/leveled.rs")),
        ("dbscan/mod.rs", include_str!("../src/dbscan/mod.rs")),
        ("replica/engine.rs", include_str!("../src/replica/engine.rs")),
        ("replica/ship.rs", include_str!("../src/replica/ship.rs")),
        ("replica/router.rs", include_str!("../src/replica/router.rs")),
        ("replica/transport.rs", include_str!("../src/replica/transport.rs")),
        ("replica/mod.rs", include_str!("../src/replica/mod.rs")),
    ] {
        assert!(
            !src.contains("Instant::now("),
            "{name} reads the wall clock directly; time through \
             obs::Stopwatch / obs::PhaseClock / span! so the overhead \
             stays auditable and the metrics switch stays total"
        );
    }
}

/// The WAL frame codec — length/CRC framing, field packing — lives in
/// `persist/wal.rs` and nowhere else. Replication ships the on-disk
/// frames verbatim and decodes them through `persist::wal::decode_frame`;
/// a second encoder or a hand-rolled byte pick in `replica/` would fork
/// the wire format from the disk format and silently break the
/// "shipped bytes = recovery bytes" guarantee.
#[test]
fn wal_frame_codec_confined_to_persist_wal() {
    for (name, src) in [
        ("replica/engine.rs", include_str!("../src/replica/engine.rs")),
        ("replica/ship.rs", include_str!("../src/replica/ship.rs")),
        ("replica/router.rs", include_str!("../src/replica/router.rs")),
        ("replica/transport.rs", include_str!("../src/replica/transport.rs")),
        ("replica/mod.rs", include_str!("../src/replica/mod.rs")),
    ] {
        for pat in [
            "to_le_bytes(",
            "from_le_bytes(",
            "crc32(",
            "fn encode_frame",
            "fn decode_frame",
        ] {
            assert!(
                !src.contains(pat),
                "{name} touches WAL frame bytes directly ({pat}); frames \
                 cross the replica layer opaque — only persist/wal.rs \
                 encodes or decodes them"
            );
        }
    }
    // the sanctioned codec, and the sanctioned call sites
    let wal = include_str!("../src/persist/wal.rs");
    for required in ["fn encode_frame", "fn decode_frame"] {
        assert!(
            wal.contains(required),
            "persist/wal.rs lost `{required}` — the shipping layer and \
             the recovery reader both depend on the shared frame codec"
        );
    }
    for (name, src) in [
        ("replica/ship.rs", include_str!("../src/replica/ship.rs")),
        ("replica/engine.rs", include_str!("../src/replica/engine.rs")),
    ] {
        assert!(
            src.contains("persist::wal::"),
            "{name} no longer goes through persist::wal for frame I/O; \
             ship and apply must reuse the durability codec"
        );
    }
}

/// Raw `O(n·d)` distance scans over the coordinate store are confined to
/// the oracle/fallback module (`serve/index.rs`, which owns the shared
/// `dist2` kernel plus the `scan_epsilon`/`scan_k_nearest` oracles): every
/// other serve file must answer neighborhood reads through the spatial
/// index or by *calling* the oracles — re-inlining the distance loop would
/// quietly reintroduce the scan read path the index replaced.
#[test]
fn distance_scans_confined_to_the_oracle_module() {
    for (name, src) in [
        ("serve/mod.rs", include_str!("../src/serve/mod.rs")),
        ("serve/builder.rs", include_str!("../src/serve/builder.rs")),
        ("serve/driver.rs", include_str!("../src/serve/driver.rs")),
        ("serve/durable.rs", include_str!("../src/serve/durable.rs")),
        ("serve/events.rs", include_str!("../src/serve/events.rs")),
        ("serve/inline.rs", include_str!("../src/serve/inline.rs")),
        ("serve/sharded.rs", include_str!("../src/serve/sharded.rs")),
        ("serve/snapshot.rs", include_str!("../src/serve/snapshot.rs")),
    ] {
        for pat in ["fn dist2", ".zip(x.iter())", "d * d"] {
            assert!(
                !src.contains(pat),
                "{name} hand-rolls a coordinate distance scan ({pat}); \
                 route the read through serve::index (SpatialIndex or the \
                 scan_epsilon/scan_k_nearest oracles) instead"
            );
        }
    }
    // and the oracles themselves must stay in the sanctioned module
    let index = include_str!("../src/serve/index.rs");
    for required in ["fn dist2", "fn scan_epsilon", "fn scan_k_nearest"] {
        assert!(
            index.contains(required),
            "serve/index.rs lost its `{required}` oracle/kernel — the \
             differential suite and scan fallbacks depend on it"
        );
    }
}

/// Every cell→shard assignment decision lives in `shard/placement.rs`:
/// the block-hash scatter primitive (`shard_of_blocks` and its mix seed)
/// must not be re-inlined anywhere else. The router *consults* the
/// placement map; the engine, workers, stitcher and serve layer consume
/// routing decisions. A second copy of the hash would silently fork the
/// assignment the migration planner and the checkpoint blob both pin.
#[test]
fn shard_assignment_confined_to_placement() {
    for (name, src) in [
        ("shard/router.rs", include_str!("../src/shard/router.rs")),
        ("shard/engine.rs", include_str!("../src/shard/engine.rs")),
        ("shard/worker.rs", include_str!("../src/shard/worker.rs")),
        ("shard/stitch.rs", include_str!("../src/shard/stitch.rs")),
        ("shard/mod.rs", include_str!("../src/shard/mod.rs")),
        ("serve/sharded.rs", include_str!("../src/serve/sharded.rs")),
        ("serve/builder.rs", include_str!("../src/serve/builder.rs")),
        ("serve/durable.rs", include_str!("../src/serve/durable.rs")),
    ] {
        for pat in ["shard_of_blocks", "0x8f3a_55b1"] {
            assert!(
                !src.contains(pat),
                "{name} makes a shard-assignment decision ({pat}); only \
                 shard/placement.rs may decide cell ownership — route \
                 through Router::decide / PlacementMap instead"
            );
        }
    }
    let placement = include_str!("../src/shard/placement.rs");
    for required in ["fn shard_of_blocks", "fn plan_migration", "fn apply_moves"] {
        assert!(
            placement.contains(required),
            "shard/placement.rs lost `{required}` — the assignment \
             primitives must stay in the placement module"
        );
    }
}

/// Channel endpoints and worker joins in the sharded serving path must
/// never `unwrap`/`expect`: a dead worker is a *recoverable* fault
/// (`EngineError` → `Health::Degraded` → respawn), not a panic. Every
/// `send`/`recv`/`join` result is matched; the one allowed `expect` family
/// is thread *spawn* (resource exhaustion at construction, not a runtime
/// fault), which these patterns don't cover because spawn isn't a channel
/// op.
#[test]
fn channel_ops_never_unwrap_in_the_serving_path() {
    for (name, src) in [
        ("shard/engine.rs", include_str!("../src/shard/engine.rs")),
        ("shard/placement.rs", include_str!("../src/shard/placement.rs")),
        ("shard/worker.rs", include_str!("../src/shard/worker.rs")),
        ("shard/mod.rs", include_str!("../src/shard/mod.rs")),
        ("serve/mod.rs", include_str!("../src/serve/mod.rs")),
        ("serve/builder.rs", include_str!("../src/serve/builder.rs")),
        ("serve/durable.rs", include_str!("../src/serve/durable.rs")),
        ("serve/events.rs", include_str!("../src/serve/events.rs")),
        ("serve/index.rs", include_str!("../src/serve/index.rs")),
        ("serve/inline.rs", include_str!("../src/serve/inline.rs")),
        ("serve/sharded.rs", include_str!("../src/serve/sharded.rs")),
        // replica/transport.rs is exempt: it *is* the channel primitive,
        // and its in-file unit test asserts on send results directly
        ("replica/engine.rs", include_str!("../src/replica/engine.rs")),
        ("replica/ship.rs", include_str!("../src/replica/ship.rs")),
        ("replica/router.rs", include_str!("../src/replica/router.rs")),
    ] {
        for (ln, line) in src.lines().enumerate() {
            let channel_op = line.contains(".send(")
                || line.contains(".recv(")
                || line.contains("recv_timeout(")
                || line.contains(".join()");
            if channel_op && (line.contains(".expect(") || line.contains(".unwrap(")) {
                panic!(
                    "{name}:{}: channel op unwraps instead of degrading \
                     ({line:?}); surface the failure as EngineError",
                    ln + 1
                );
            }
        }
    }
}
