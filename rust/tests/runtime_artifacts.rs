//! Integration over the PJRT runtime: load the AOT artifacts produced by
//! `make artifacts`, execute them, and check parity with the native Rust
//! implementations. Skipped (with a notice) when artifacts are absent.

use dyn_dbscan::baselines::brute::{NativeDistance, PairwiseDistance};
use dyn_dbscan::dbscan::{DbscanConfig, DynamicDbscan};
use dyn_dbscan::lsh::GridHasher;
use dyn_dbscan::metrics::adjusted_rand_index;
use dyn_dbscan::runtime::engines::{HashingEngine, NativeHashing, XlaHashing, XlaDistance};
use dyn_dbscan::runtime::Runtime;
use dyn_dbscan::util::rng::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !Runtime::available(&dir) {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime init"))
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    for name in ["hash_d4_t2_b128", "dist_d4_q128_m128", "project_b128_din8_dout4"] {
        assert!(rt.artifacts.contains_key(name), "missing artifact {name}");
    }
    let h = &rt.artifacts["hash_d4_t2_b128"];
    assert_eq!(h.kind, "hash");
    assert_eq!(h.output.shape, vec![2, 128, 4]);
}

#[test]
fn project_artifact_matches_native_matmul() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let (b, din, dout) = (128usize, 8usize, 4usize);
    let mut rng = Rng::new(5);
    let x: Vec<f32> = (0..b * din).map(|_| rng.next_f32() - 0.5).collect();
    let w: Vec<f32> = (0..din * dout).map(|_| rng.next_f32() - 0.5).collect();
    let got = rt
        .execute_f32_to_f32("project_b128_din8_dout4", &[&x, &w])
        .expect("execute");
    assert_eq!(got.len(), b * dout);
    for i in 0..b {
        for j in 0..dout {
            let want: f32 = (0..din).map(|k| x[i * din + k] * w[k * dout + j]).sum();
            assert!(
                (got[i * dout + j] - want).abs() < 1e-4,
                "({i},{j}): {} vs {want}",
                got[i * dout + j]
            );
        }
    }
}

#[test]
fn xla_hashing_engine_matches_native_bit_for_bit() {
    let Some(rt) = runtime_or_skip() else { return };
    let (d, t) = (4usize, 2usize);
    let hasher = GridHasher::new(t, d, 0.75, 99);
    let mut native = NativeHashing::new(hasher.clone());
    let mut xla = match XlaHashing::new(rt, hasher) {
        Ok(x) => x,
        Err(e) => panic!("no hash artifact for smoke shape: {e}"),
    };
    let mut rng = Rng::new(7);
    // n deliberately not a multiple of the compiled batch (tests padding)
    let n = 300;
    let xs: Vec<f32> = (0..n * d).map(|_| (rng.next_f32() - 0.5) * 10.0).collect();
    let kn = native.keys_batch(&xs, n).unwrap();
    let kx = xla.keys_batch(&xs, n).unwrap();
    assert_eq!(kn.len(), kx.len());
    let mut mismatches = 0;
    for i in 0..n {
        if kn[i] != kx[i] {
            mismatches += 1;
        }
    }
    assert_eq!(
        mismatches, 0,
        "native and XLA hashing disagree on {mismatches}/{n} points"
    );
}

#[test]
fn xla_distance_matches_native() {
    let Some(rt) = runtime_or_skip() else { return };
    let d = 4usize;
    let mut xd = XlaDistance::new(rt, d).expect("dist artifact");
    let (q, m) = xd.tile_shape();
    let mut rng = Rng::new(13);
    let nq = q.min(100);
    let nc = m.min(120);
    let qs: Vec<f32> = (0..nq * d).map(|_| rng.next_f32() * 4.0).collect();
    let cs: Vec<f32> = (0..nc * d).map(|_| rng.next_f32() * 4.0).collect();
    let mut got = vec![0f32; nq * nc];
    let mut want = vec![0f32; nq * nc];
    xd.dist2(&qs, nq, &cs, nc, d, &mut got);
    NativeDistance.dist2(&qs, nq, &cs, nc, d, &mut want);
    for i in 0..nq * nc {
        assert!(
            (got[i] - want[i]).abs() <= 1e-3 * (1.0 + want[i]),
            "tile mismatch at {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn clustering_through_xla_engine_matches_native_engine() {
    let Some(rt) = runtime_or_skip() else { return };
    let (d, t, k) = (4usize, 2usize, 4usize);
    let cfg = DbscanConfig { k, t, eps: 0.75, dim: d, ..Default::default() };
    let seed = 21;
    // identical hashers (same seed/config) on both paths
    let hasher = GridHasher::new(t, d, 0.75, seed);
    let mut xla = XlaHashing::new(rt, hasher).expect("hash artifact");

    let mut rng = Rng::new(3);
    let n = 500;
    let mut xs = Vec::with_capacity(n * d);
    for _ in 0..n {
        let c = rng.below(3) as f32 * 5.0;
        for _ in 0..d {
            xs.push(c + (rng.next_f32() - 0.5));
        }
    }
    let keys = xla.keys_batch(&xs, n).unwrap();

    let mut via_native = DynamicDbscan::new(cfg.clone(), seed);
    let mut via_xla = DynamicDbscan::new(cfg, seed);
    let mut ids_n = Vec::new();
    let mut ids_x = Vec::new();
    for i in 0..n {
        let p = &xs[i * d..(i + 1) * d];
        ids_n.push(via_native.add_point(p));
        ids_x.push(via_xla.add_point_with_keys(p, &keys[i]));
    }
    assert_eq!(via_native.num_core_points(), via_xla.num_core_points());
    let ln = via_native.labels_for(&ids_n);
    let lx = via_xla.labels_for(&ids_x);
    assert_eq!(adjusted_rand_index(&ln, &lx), 1.0, "XLA path diverged");
}
