//! Churn property tests for the arena-backed point store and the leveled
//! connectivity default: heavy interleaved add/delete streams exercise
//! slot reuse, the Theorem-2 counterexample class and deep-chain deletion
//! schedules exercise the HDT replacement search, then the structure is
//! checked against a from-scratch realization of Definition 4 over the
//! same hash functions (exact-collision-graph baseline — core partitions
//! must match with ARI = 1.0), and drained to zero to prove the arena,
//! the forest AND every per-level HDT forest leak nothing.

use dyn_dbscan::baselines::unionfind::UnionFind;
use dyn_dbscan::dbscan::{DbscanConfig, DynamicDbscan};
use dyn_dbscan::lsh::GridHasher;
use dyn_dbscan::metrics::adjusted_rand_index;
use dyn_dbscan::util::proptest::{run_prop, Gen};
use dyn_dbscan::util::rng::Rng;
use rustc_hash::FxHashMap;

/// Static Definition-4 core set + core components with externally supplied
/// hash functions (the brute-force oracle on the exact collision graph).
fn static_def4(hasher: &GridHasher, k: usize, pts: &[Vec<f32>]) -> (Vec<bool>, Vec<i64>) {
    let n = pts.len();
    let mut scratch = Vec::new();
    let keys: Vec<Vec<u128>> =
        pts.iter().map(|p| hasher.keys(p, &mut scratch)).collect();
    let mut is_core = vec![false; n];
    for i in 0..hasher.t {
        let mut buckets: FxHashMap<u128, Vec<usize>> = FxHashMap::default();
        for (j, kk) in keys.iter().enumerate() {
            buckets.entry(kk[i]).or_default().push(j);
        }
        for members in buckets.values() {
            if members.len() >= k {
                for &m in members {
                    is_core[m] = true;
                }
            }
        }
    }
    let mut uf = UnionFind::new(n);
    for i in 0..hasher.t {
        let mut rep: FxHashMap<u128, usize> = FxHashMap::default();
        for (j, kk) in keys.iter().enumerate() {
            if !is_core[j] {
                continue;
            }
            match rep.entry(kk[i]) {
                std::collections::hash_map::Entry::Occupied(e) => {
                    uf.union(j, *e.get());
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(j);
                }
            }
        }
    }
    let mut labels = vec![-1i64; n];
    let mut next = 0i64;
    let mut seen: FxHashMap<usize, i64> = FxHashMap::default();
    for j in 0..n {
        if is_core[j] {
            let r = uf.find(j);
            labels[j] = *seen.entry(r).or_insert_with(|| {
                let l = next;
                next += 1;
                l
            });
        }
    }
    (is_core, labels)
}

/// Compare the live structure against the static oracle: identical core
/// flags, and ARI = 1.0 between the core partitions.
fn assert_matches_oracle(
    db: &DynamicDbscan,
    pts: &[Vec<f32>],
    ids: &[u64],
    alive: &[usize],
    ctx: &str,
) {
    let survivors: Vec<Vec<f32>> = alive.iter().map(|&j| pts[j].clone()).collect();
    let (ref_core, ref_labels) = static_def4(&db.hasher, db.cfg.k, &survivors);
    let mut dyn_core_labels: Vec<i64> = Vec::new();
    let mut ref_core_labels: Vec<i64> = Vec::new();
    let mut roots: FxHashMap<u64, i64> = FxHashMap::default();
    for (pos, &j) in alive.iter().enumerate() {
        assert_eq!(
            db.is_core(ids[j]),
            ref_core[pos],
            "{ctx}: core flag mismatch at live point {pos}"
        );
        if ref_core[pos] {
            let r = db.get_cluster(ids[j]);
            let next = roots.len() as i64;
            dyn_core_labels.push(*roots.entry(r).or_insert(next));
            ref_core_labels.push(ref_labels[pos]);
        }
    }
    if !dyn_core_labels.is_empty() {
        let ari = adjusted_rand_index(&dyn_core_labels, &ref_core_labels);
        assert_eq!(ari, 1.0, "{ctx}: core partition ARI {ari} != 1.0");
    }
}

/// Heavy add/delete churn with slot reuse, checked against the exact
/// baseline mid-stream and after the stream, then drained to empty: the
/// arena's live-slot count and the forest's live-vertex count must both
/// return to zero, and the slot high-water mark must be reused rather than
/// grown when the structure refills.
#[test]
fn churn_with_slot_reuse_matches_bruteforce_baseline() {
    run_prop("arena churn vs static def4", 12, |g: &mut Gen| {
        let dim = g.usize_in(1..=3);
        let cfg = DbscanConfig {
            k: g.usize_in(2..=5),
            t: g.usize_in(2..=6),
            eps: g.f64_in(0.2, 1.0) as f32,
            dim,
            eager_attach: g.rng.coin(0.3),
        };
        let seed = g.rng.next_u64();
        let mut db = DynamicDbscan::new(cfg, seed);
        let mut pts: Vec<Vec<f32>> = Vec::new();
        let mut ids: Vec<u64> = Vec::new();
        let mut alive: Vec<usize> = Vec::new();
        let ops = g.usize_in(60..=200);
        for op in 0..ops {
            if alive.is_empty() || g.rng.coin(0.62) {
                let c = g.usize_in(0..=2) as f64 * 2.5;
                let p: Vec<f32> =
                    (0..dim).map(|_| (c + g.f64_in(-0.5, 0.5)) as f32).collect();
                ids.push(db.add_point(&p));
                pts.push(p);
                alive.push(ids.len() - 1);
            } else {
                let i = g.rng.below_usize(alive.len());
                let j = alive.swap_remove(i);
                db.delete_point(ids[j]);
            }
            if op % 40 == 39 {
                db.verify().unwrap_or_else(|e| panic!("op {op}: {e}"));
                assert_matches_oracle(&db, &pts, &ids, &alive, "mid-stream");
            }
        }
        db.verify().unwrap();
        assert_matches_oracle(&db, &pts, &ids, &alive, "end of stream");
        assert_eq!(db.live_slots(), alive.len());
        assert_eq!(db.live_vertices(), alive.len());

        // drain to empty: nothing may leak
        let high_water = db.capacity_slots();
        while let Some(j) = alive.pop() {
            db.delete_point(ids[j]);
        }
        assert_eq!(db.num_points(), 0);
        assert_eq!(db.num_core_points(), 0);
        assert_eq!(db.live_slots(), 0, "arena slots leaked after full drain");
        assert_eq!(db.live_vertices(), 0, "forest vertices leaked after full drain");
        let per_level = db.conn_level_live();
        assert!(
            per_level.iter().all(|&c| c == 0),
            "per-level forest leak after full drain: {per_level:?}"
        );
        db.verify().unwrap();

        // refill within the old high-water mark: slots must be reused
        let refill = high_water.min(10);
        for i in 0..refill {
            let p: Vec<f32> = (0..dim).map(|_| i as f32 * 0.01).collect();
            db.add_point(&p);
        }
        assert_eq!(
            db.capacity_slots(),
            high_water,
            "refill below the high-water mark must reuse free-listed slots"
        );
    });
}

/// The Theorem-2 counterexample workload class (k = 2, t = 2, 1-D — the
/// family in which the paper's verbatim Algorithm 2 provably violates
/// Theorem 2, see `dbscan::connectivity`) driven against the default
/// `LeveledConn`: the brute-force Definition-4 oracle must agree after
/// every burst, the machine-checked invariants must hold after every op,
/// and the full drain must empty every per-level HDT forest.
#[test]
fn theorem2_counterexample_class_on_leveled_default() {
    let cfg = DbscanConfig { k: 2, t: 2, eps: 0.4, dim: 1, eager_attach: false };
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed);
        let mut db = DynamicDbscan::new(cfg.clone(), seed);
        let mut pts: Vec<Vec<f32>> = Vec::new();
        let mut ids: Vec<u64> = Vec::new();
        let mut alive: Vec<usize> = Vec::new();
        for op in 0..60 {
            if alive.is_empty() || rng.coin(0.65) {
                let c = rng.below(3) as f64 * 3.0;
                let p = vec![(c + rng.uniform(-0.5, 0.5)) as f32];
                ids.push(db.add_point(&p));
                pts.push(p);
                alive.push(ids.len() - 1);
            } else {
                let i = rng.below_usize(alive.len());
                let j = alive.swap_remove(i);
                db.delete_point(ids[j]);
            }
            db.verify()
                .unwrap_or_else(|e| panic!("seed {seed} op {op}: {e}"));
        }
        assert_matches_oracle(&db, &pts, &ids, &alive, "counterexample class");
        while let Some(j) = alive.pop() {
            db.delete_point(ids[j]);
        }
        let per_level = db.conn_level_live();
        assert!(
            per_level.iter().all(|&c| c == 0),
            "seed {seed}: per-level forest leak after drain: {per_level:?}"
        );
        db.verify().unwrap();
    }
}

/// Deep-chain deletion schedule: a 1-D bucket chain (spacing 0.1, bucket
/// width 2ε = 0.8 ⇒ ~8 consecutive points per bucket, all core, chained
/// into one long path-shaped component) with repeated mid-chain **block**
/// deletions. Each block (width 1.2 > any bucket) genuinely splits the
/// component — the replacement-search worst case that drives the HDT
/// hierarchy. The Definition-4 oracle must agree after every round and
/// the final drain must empty every per-level forest.
#[test]
fn deep_chain_block_deletions_match_oracle_and_drain() {
    let cfg = DbscanConfig { k: 6, t: 3, eps: 0.4, dim: 1, eager_attach: false };
    for seed in [1u64, 7, 23] {
        let mut db = DynamicDbscan::new(cfg.clone(), seed);
        let n = 320usize;
        let pts: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32 * 0.1]).collect();
        let mut ids: Vec<u64> = pts.iter().map(|p| db.add_point(p)).collect();
        let mut rng = Rng::new(seed ^ 0xC4A1);
        let block = 12usize;
        for round in 0..12 {
            let start = 40 + rng.below_usize(n - 80 - block);
            for i in start..start + block {
                db.delete_point(ids[i]);
            }
            db.verify()
                .unwrap_or_else(|e| panic!("seed {seed} round {round}: {e}"));
            let alive: Vec<usize> =
                (0..n).filter(|i| !(start..start + block).contains(i)).collect();
            assert_matches_oracle(&db, &pts, &ids, &alive, "chain gap");
            for i in start..start + block {
                ids[i] = db.add_point(&pts[i]);
            }
            db.verify()
                .unwrap_or_else(|e| panic!("seed {seed} round {round} refill: {e}"));
        }
        // the schedule must actually have exercised the level hierarchy
        let st = db.repair_stats();
        assert!(
            st.levels >= 2,
            "seed {seed}: chain churn should grow ≥ 2 levels, got {}",
            st.levels
        );
        assert!(st.pushes > 0, "seed {seed}: no edges were ever pushed up");
        // drain: the arena, the spanning forest and every per-level
        // forest must all empty
        for &id in &ids {
            db.delete_point(id);
        }
        assert_eq!(db.num_points(), 0);
        assert_eq!(db.live_slots(), 0);
        let per_level = db.conn_level_live();
        assert!(
            per_level.iter().all(|&c| c == 0),
            "seed {seed}: per-level forest leak after drain: {per_level:?}"
        );
        db.verify().unwrap();
    }
}
