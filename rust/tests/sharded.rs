//! Sharded-vs-single equivalence and router determinism.
//!
//! The sharded engine must reproduce the single-instance clustering: the
//! router's ghost margin keeps every cross-boundary collision edge (and
//! the core status of the replicas carrying it) realized in at least one
//! shard, and the stitcher's union-find glues the per-shard components
//! back together. On separable data the two label sets should agree to
//! ARI ≈ 1; the gate is ≥ 0.95 (border-point attachment is arbitrary in
//! both paths).

use dyn_dbscan::data::blobs::{make_blobs, BlobsConfig};
use dyn_dbscan::data::synth::{load, PaperDataset};
use dyn_dbscan::data::Dataset;
use dyn_dbscan::dbscan::{DbscanConfig, DynamicDbscan};
use dyn_dbscan::metrics::adjusted_rand_index;
use dyn_dbscan::shard::{Router, ShardConfig, ShardedEngine};
use dyn_dbscan::util::rng::Rng;

/// Single-instance labels over a dataset, inserted in index order.
fn single_instance_labels(ds: &Dataset, cfg: &DbscanConfig, seed: u64) -> Vec<i64> {
    let mut db = DynamicDbscan::new(cfg.clone(), seed);
    let ids: Vec<u64> = (0..ds.n()).map(|i| db.add_point(ds.point(i))).collect();
    db.labels_for(&ids)
}

/// Sharded labels over the same dataset and seed.
fn sharded_labels(ds: &Dataset, scfg: ShardConfig) -> (Vec<i64>, u64) {
    let mut eng = ShardedEngine::new(scfg);
    for i in 0..ds.n() {
        eng.insert(i as u64, ds.point(i));
    }
    let out = eng.finish();
    assert_eq!(out.snapshot.live_points, ds.n());
    let labels = (0..ds.n() as u64)
        .map(|e| out.snapshot.cluster_of(e).expect("live ext must be labeled"))
        .collect();
    (labels, out.stats.ghost_inserts)
}

#[test]
fn sharded_matches_single_on_synth_blobs() {
    // the paper's blobs stand-in (standardized, d = 10), S = 4
    let ds = load(PaperDataset::Blobs, 0.02, 11);
    let cfg = DbscanConfig { k: 10, t: 10, eps: 0.75, dim: ds.dim, ..Default::default() };
    let single = single_instance_labels(&ds, &cfg, 5);
    let (sharded, _) = sharded_labels(&ds, ShardConfig::new(cfg, 4, 5));
    let ari = adjusted_rand_index(&single, &sharded);
    assert!(ari >= 0.95, "sharded vs single ARI {ari} < 0.95");
}

#[test]
fn sharded_matches_single_under_heavy_stitching() {
    // tiny blocks force boundaries through every cluster: ghosts and the
    // stitcher do real work, and the equivalence must still hold
    let ds = make_blobs(
        &BlobsConfig {
            n: 3000,
            dim: 6,
            clusters: 8,
            std: 0.3,
            center_box: 20.0,
            weights: vec![],
        },
        13,
    );
    let cfg = DbscanConfig { k: 8, t: 10, eps: 0.75, dim: 6, ..Default::default() };
    let single = single_instance_labels(&ds, &cfg, 21);
    let mut scfg = ShardConfig::new(cfg, 4, 21);
    scfg.block_side = 2;
    let (sharded, ghosts) = sharded_labels(&ds, scfg);
    assert!(ghosts > 0, "tiny blocks must produce ghost replicas");
    let ari = adjusted_rand_index(&single, &sharded);
    assert!(ari >= 0.95, "heavy-stitch ARI {ari} < 0.95 (ghosts={ghosts})");
}

#[test]
fn sharded_matches_single_with_deletes() {
    let ds = make_blobs(
        &BlobsConfig {
            n: 2400,
            dim: 5,
            clusters: 6,
            std: 0.3,
            center_box: 20.0,
            weights: vec![],
        },
        29,
    );
    let cfg = DbscanConfig { k: 8, t: 10, eps: 0.75, dim: 5, ..Default::default() };
    // delete every third point after inserting everything
    let deleted: Vec<usize> = (0..ds.n()).filter(|i| i % 3 == 0).collect();

    let mut db = DynamicDbscan::new(cfg.clone(), 3);
    let ids: Vec<u64> = (0..ds.n()).map(|i| db.add_point(ds.point(i))).collect();
    for &i in &deleted {
        db.delete_point(ids[i]);
    }
    let survivors: Vec<usize> = (0..ds.n()).filter(|i| i % 3 != 0).collect();
    let single = db.labels_for(&survivors.iter().map(|&i| ids[i]).collect::<Vec<_>>());

    let mut eng = ShardedEngine::new(ShardConfig::new(cfg, 4, 3));
    for i in 0..ds.n() {
        eng.insert(i as u64, ds.point(i));
    }
    for &i in &deleted {
        eng.delete(i as u64);
    }
    let out = eng.finish();
    assert_eq!(out.snapshot.live_points, survivors.len());
    let sharded: Vec<i64> = survivors
        .iter()
        .map(|&i| out.snapshot.cluster_of(i as u64).expect("survivor labeled"))
        .collect();
    let ari = adjusted_rand_index(&single, &sharded);
    assert!(ari >= 0.95, "post-delete ARI {ari} < 0.95");
    for &i in &deleted {
        assert_eq!(out.snapshot.cluster_of(i as u64), None, "deleted ext {i} labeled");
    }
}

#[test]
fn router_assigns_identical_shards_across_runs() {
    let dbscan = DbscanConfig { k: 10, t: 10, eps: 0.75, dim: 8, ..Default::default() };
    let cfg = ShardConfig::new(dbscan, 8, 123);
    let mut rng = Rng::new(77);
    let pts: Vec<Vec<f32>> = (0..1000)
        .map(|_| (0..8).map(|_| rng.uniform(-25.0, 25.0) as f32).collect())
        .collect();
    // "two runs" = two independently constructed routers over the same
    // config; decisions must agree point-for-point, ghosts included
    let mut run1 = Router::new(&cfg);
    let mut run2 = Router::new(&cfg);
    let a: Vec<_> = pts.iter().map(|p| run1.route(p)).collect();
    let b: Vec<_> = pts.iter().map(|p| run2.route(p)).collect();
    assert_eq!(a, b, "router decisions differ across runs");
    // and a different seed moves the geometry (different hash shifts)
    let mut other_cfg = cfg.clone();
    other_cfg.seed = 124;
    let mut run3 = Router::new(&other_cfg);
    let c: Vec<_> = pts.iter().map(|p| run3.route(p)).collect();
    assert_ne!(a, c, "routing should depend on the seed");
}

#[test]
fn cluster_sizes_are_consistent_with_labels() {
    let ds = make_blobs(
        &BlobsConfig {
            n: 1500,
            dim: 4,
            clusters: 5,
            std: 0.3,
            center_box: 20.0,
            weights: vec![],
        },
        41,
    );
    let cfg = DbscanConfig { k: 8, t: 10, eps: 0.75, dim: 4, ..Default::default() };
    let mut eng = ShardedEngine::new(ShardConfig::new(cfg, 3, 9));
    for i in 0..ds.n() {
        eng.insert(i as u64, ds.point(i));
    }
    let out = eng.finish();
    let snap = &out.snapshot;
    let clustered = snap.labels().iter().filter(|&&(_, l)| l >= 0).count();
    let sized: usize = snap.cluster_sizes.iter().map(|&(_, s)| s).sum();
    assert_eq!(clustered, sized);
    assert_eq!(snap.cluster_sizes.len(), snap.clusters);
    // sizes sorted descending
    for w in snap.cluster_sizes.windows(2) {
        assert!(w[0].1 >= w[1].1);
    }
    // dominant clusters should be found on separable blobs
    assert!(snap.clusters >= 5, "expected >= 5 clusters, got {}", snap.clusters);
}
