//! End-to-end integration: full pipelines over realistic workloads —
//! streaming with deletions, sliding windows, snapshots, CLI arg parsing
//! against command dispatch, and long-run structural health.

use dyn_dbscan::coordinator::driver::{
    final_quality, make_engine, stream_dataset, to_stream_ops, EngineKind,
};
use dyn_dbscan::coordinator::{run_pipeline, CoordinatorConfig};
use dyn_dbscan::data::blobs::{make_blobs, BlobsConfig};
use dyn_dbscan::data::stream::{sliding_window_stream, Order};
use dyn_dbscan::data::synth::{load, PaperDataset};
use dyn_dbscan::dbscan::{DbscanConfig, DynamicDbscan};
use dyn_dbscan::util::rng::Rng;

#[test]
fn blobs_stream_high_quality_with_snapshots() {
    // well-separated mixture at test scale (the paper-scale stand-in needs
    // its full n=200k for this density regime; see bench_fig2 for that)
    let ds = make_blobs(
        &BlobsConfig {
            n: 3000,
            dim: 6,
            clusters: 5,
            std: 0.3,
            center_box: 20.0,
            weights: vec![],
        },
        5,
    );
    let cfg = DbscanConfig {
        k: 10,
        t: 10,
        eps: 0.75,
        dim: ds.dim,
        ..Default::default()
    };
    let out =
        stream_dataset(&ds, cfg, Order::Random, 500, 1, 42, EngineKind::Native)
            .unwrap();
    let (ari, nmi) = final_quality(&ds, &out);
    assert!(ari > 0.95, "blobs ARI {ari}");
    assert!(nmi > 0.9, "blobs NMI {nmi}");
    // snapshots were produced and final snapshot is near-perfect
    let snaps: Vec<f64> = out.reports.iter().filter_map(|r| r.ari).collect();
    assert_eq!(snaps.len(), out.reports.len());
    assert!(snaps.last().unwrap() > &0.95);
}

#[test]
fn sliding_window_stream_is_stable() {
    let ds = load(PaperDataset::Blobs, 0.005, 9);
    let cfg = DbscanConfig {
        k: 8,
        t: 8,
        eps: 0.75,
        dim: ds.dim,
        ..Default::default()
    };
    let window = ds.n() / 3;
    let batches = sliding_window_stream(&ds, Order::Random, 200, window, 4);
    let ops = to_stream_ops(&ds, &batches);
    let mut engine = make_engine(&cfg, 17, EngineKind::Native).unwrap();
    let ccfg = CoordinatorConfig {
        dbscan: cfg,
        queue: 2,
        snapshot_every: 0,
        seed: 17,
    };
    let out = run_pipeline(ccfg, engine.as_mut(), ops, None).unwrap();
    let last = out.reports.last().unwrap();
    assert_eq!(last.live_points, window, "window size not respected");
    assert!(out.delete_latency.count() > 0, "no deletes were exercised");
    // live points of a stationary distribution should still cluster well
    let live: Vec<u64> = out.final_labels.iter().map(|&(e, _)| e).collect();
    let truth: Vec<i64> = live.iter().map(|&e| ds.labels[e as usize]).collect();
    let pred: Vec<i64> = out.final_labels.iter().map(|&(_, l)| l).collect();
    let ari = dyn_dbscan::metrics::adjusted_rand_index(&truth, &pred);
    assert!(ari > 0.85, "sliding-window ARI {ari}");
}

#[test]
fn long_churn_preserves_invariants_and_memory() {
    // heavy add/delete churn, then verify + drain to empty
    let cfg = DbscanConfig { k: 5, t: 6, eps: 0.4, dim: 3, ..Default::default() };
    let mut db = DynamicDbscan::new(cfg, 77);
    let mut rng = Rng::new(42);
    let mut live: Vec<u64> = Vec::new();
    for step in 0..3000 {
        if live.is_empty() || rng.coin(0.6) {
            let c = rng.below(4) as f64 * 2.5;
            let p: Vec<f32> =
                (0..3).map(|_| (c + rng.uniform(-0.5, 0.5)) as f32).collect();
            live.push(db.add_point(&p));
        } else {
            let i = rng.below_usize(live.len());
            db.delete_point(live.swap_remove(i));
        }
        if step % 500 == 499 {
            db.verify().unwrap_or_else(|e| panic!("step {step}: {e}"));
        }
    }
    let stats = db.repair_stats();
    // replacement machinery exercised but bounded
    assert!(stats.searches < db.stats.deletes * 50 + 1000);
    while let Some(p) = live.pop() {
        db.delete_point(p);
    }
    assert_eq!(db.num_points(), 0);
    assert_eq!(db.num_core_points(), 0);
    db.verify().unwrap();
}

#[test]
fn cli_dispatch_verify_and_info() {
    use dyn_dbscan::cli::{commands, Args};
    let argv: Vec<String> = ["verify", "--ops", "400", "--seed", "3"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let args = Args::parse(&argv).unwrap();
    commands::dispatch(&args).expect("verify command failed");
    // unknown command errors cleanly
    let bad = Args::parse(&["wat".to_string()]).unwrap();
    assert!(commands::dispatch(&bad).is_err());
}

#[test]
fn cluster_by_cluster_order_still_correct_for_dynamic() {
    // the order that breaks EMZFixedCore must not hurt DynamicDbscan
    let ds = make_blobs(
        &BlobsConfig {
            n: 2000,
            dim: 6,
            clusters: 5,
            std: 0.3,
            center_box: 20.0,
            weights: vec![],
        },
        3,
    );
    let cfg = DbscanConfig {
        k: 10,
        t: 10,
        eps: 0.75,
        dim: ds.dim,
        ..Default::default()
    };
    let out = stream_dataset(
        &ds,
        cfg,
        Order::ClusterByCluster,
        400,
        0,
        11,
        EngineKind::Native,
    )
    .unwrap();
    let (ari, _) = final_quality(&ds, &out);
    assert!(ari > 0.95, "cluster-ordered ARI {ari}");
}
