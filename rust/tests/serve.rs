//! The serve façade's acceptance tests: backend equivalence (Inline vs
//! Sharded answering every `SnapshotView` query identically on shared
//! churn schedules, deletes included), the `watch()` event stream
//! matching observed label diffs across publishes, and the
//! freshness/versioning contract.

use dyn_dbscan::data::blobs::{make_blobs, BlobsConfig};
use dyn_dbscan::metrics::adjusted_rand_index;
use dyn_dbscan::serve::{
    Backend, ClusterEngine, ClusterEvent, ConnKind, EngineBuilder, SnapshotView,
    StitchMode,
};
use dyn_dbscan::util::proptest::{run_prop, Gen};
use rustc_hash::FxHashMap;

fn builder(dim: usize, seed: u64) -> EngineBuilder {
    EngineBuilder::new(dim).k(4).t(6).eps(0.5).seed(seed)
}

/// Assert two views answer every query surface identically (labels up to
/// a bijection — the backends mint label values independently).
fn assert_views_equivalent(a: &SnapshotView, b: &SnapshotView, probes: &[Vec<f32>]) {
    assert_eq!(a.live_points(), b.live_points(), "live diverged");
    assert_eq!(a.core_points(), b.core_points(), "cores diverged");
    assert_eq!(a.clusters(), b.clusters(), "cluster count diverged");
    let sizes_a: Vec<usize> = a.cluster_sizes().iter().map(|&(_, s)| s).collect();
    let sizes_b: Vec<usize> = b.cluster_sizes().iter().map(|&(_, s)| s).collect();
    assert_eq!(sizes_a, sizes_b, "cluster sizes diverged");
    let la = a.labels();
    let lb = b.labels();
    assert_eq!(la.len(), lb.len());
    let mut fwd: FxHashMap<i64, i64> = FxHashMap::default();
    let mut bwd: FxHashMap<i64, i64> = FxHashMap::default();
    for (&(ea, va), &(eb, vb)) in la.iter().zip(lb.iter()) {
        assert_eq!(ea, eb, "live ext sets diverged");
        assert_eq!(va < 0, vb < 0, "noise flag diverged at ext {ea}");
        assert_eq!(a.is_core(ea), b.is_core(ea), "core flag diverged at {ea}");
        if va >= 0 {
            assert_eq!(*fwd.entry(va).or_insert(vb), vb, "label split at {ea}");
            assert_eq!(*bwd.entry(vb).or_insert(va), va, "label merge at {ea}");
        }
    }
    // members agree under the bijection
    for (&va, &vb) in fwd.iter() {
        assert_eq!(a.cluster_members(va), b.cluster_members(vb));
    }
    assert_eq!(a.cluster_members(-1), b.cluster_members(-1), "noise sets");
    for p in probes {
        assert_eq!(a.epsilon_neighbors(p), b.epsilon_neighbors(p), "ε at {p:?}");
    }
}

/// Inline vs Sharded(1): same seed ⇒ identical structures, so every
/// query must agree exactly — on random churn schedules with deletes.
#[test]
fn inline_vs_sharded1_answer_identically_under_churn() {
    run_prop("serve backend equivalence", 8, |g: &mut Gen| {
        let dim = 3;
        let mut inline = builder(dim, 11).build().unwrap();
        let mut sharded =
            builder(dim, 11).backend(Backend::Sharded(1)).build().unwrap();
        let mut live: Vec<u64> = Vec::new();
        let mut next = 0u64;
        let n_ops = g.usize_in(120..=240);
        let mut probes: Vec<Vec<f32>> = Vec::new();
        for step in 0..n_ops {
            // delete-heavy: 45% of ops remove a live point
            if live.is_empty() || g.f64_in(0.0, 1.0) < 0.55 {
                let c = g.usize_in(0..=2) as f64 * 2.0;
                let p: Vec<f32> =
                    (0..dim).map(|_| (c + g.f64_in(-0.5, 0.5)) as f32).collect();
                if probes.len() < 8 {
                    probes.push(p.clone());
                }
                inline.upsert(next, &p);
                sharded.upsert(next, &p);
                live.push(next);
                next += 1;
            } else {
                let i = g.usize_in(0..=live.len() - 1);
                let e = live.swap_remove(i);
                inline.remove(e);
                sharded.remove(e);
            }
            if step % 48 == 47 {
                let va = inline.publish();
                let vb = sharded.publish();
                assert_views_equivalent(&va, &vb, &probes);
            }
        }
        let va = inline.publish();
        let vb = sharded.publish();
        assert_eq!(va.pending_writes(), 0);
        assert_eq!(vb.pending_writes(), 0);
        assert_views_equivalent(&va, &vb, &probes);
        let _ = inline.finish();
        let _ = sharded.finish();
    });
}

/// Inline vs Sharded(4) on a realistic blobs churn: the multi-shard
/// clustering is allowed boundary-attachment differences (ARI gate), but
/// the façade-level surfaces — liveness, coordinates, ε-neighborhoods —
/// must agree exactly.
#[test]
fn inline_vs_sharded4_blobs_churn() {
    let ds = make_blobs(
        &BlobsConfig {
            n: 1200,
            dim: 4,
            clusters: 4,
            std: 0.3,
            center_box: 20.0,
            weights: vec![],
        },
        7,
    );
    let mut inline = EngineBuilder::new(4).k(8).eps(0.75).seed(21).build().unwrap();
    let mut sharded = EngineBuilder::new(4)
        .k(8)
        .eps(0.75)
        .seed(21)
        .backend(Backend::Sharded(4))
        .build()
        .unwrap();
    for i in 0..ds.n() {
        inline.upsert(i as u64, ds.point(i));
        sharded.upsert(i as u64, ds.point(i));
    }
    // delete a third, including whole-cluster chunks
    for e in 0..400u64 {
        inline.remove(e);
        sharded.remove(e);
    }
    let va = inline.publish();
    let vb = sharded.publish();
    assert_eq!(va.live_points(), 800);
    assert_eq!(vb.live_points(), 800);
    for i in [450usize, 700, 999] {
        assert_eq!(
            va.epsilon_neighbors(ds.point(i)),
            vb.epsilon_neighbors(ds.point(i))
        );
    }
    let pa: Vec<i64> = va.labels().iter().map(|&(_, l)| l).collect();
    let pb: Vec<i64> = vb.labels().iter().map(|&(_, l)| l).collect();
    let ari = adjusted_rand_index(&pa, &pb);
    assert!(ari > 0.95, "inline vs sharded(4) ARI {ari}");
    let _ = inline.finish();
    let _ = sharded.finish();
}

/// Per-publish event batches must match the label diffs observable from
/// consecutive snapshots, on both backends.
#[test]
fn watch_events_match_label_diffs() {
    for backend in [Backend::Single, Backend::Sharded(2)] {
        let ds = make_blobs(
            &BlobsConfig {
                n: 600,
                dim: 3,
                clusters: 3,
                std: 0.35,
                center_box: 15.0,
                weights: vec![],
            },
            13,
        );
        let mut eng = EngineBuilder::new(3)
            .k(6)
            .eps(0.75)
            .seed(5)
            .backend(backend)
            .build()
            .unwrap();
        let events = eng.watch();
        let mut prev: FxHashMap<u64, i64> = FxHashMap::default();
        let mut live: Vec<u64> = Vec::new();
        for round in 0..6 {
            for i in (round * 100)..((round + 1) * 100) {
                eng.upsert(i as u64, ds.point(i));
                live.push(i as u64);
            }
            if round >= 2 {
                // delete 60 of the oldest per round — forces splits
                for e in live.drain(..60) {
                    eng.remove(e);
                }
            }
            let view = eng.publish();
            let batch = events.next_publish().expect("engine alive");
            for e in &batch {
                assert_eq!(e.version(), view.version(), "event from wrong publish");
            }
            // Moved events == the exact label diff between snapshots
            let cur: FxHashMap<u64, i64> = view.labels().into_iter().collect();
            let mut expected: Vec<(u64, Option<i64>, Option<i64>)> = Vec::new();
            for (&e, &l) in cur.iter() {
                let from = prev.get(&e).copied();
                if from != Some(l) {
                    expected.push((e, from, Some(l)));
                }
            }
            for (&e, &l) in prev.iter() {
                if !cur.contains_key(&e) {
                    expected.push((e, Some(l), None));
                }
            }
            expected.sort_unstable();
            let mut moved: Vec<(u64, Option<i64>, Option<i64>)> = batch
                .iter()
                .filter_map(|e| match *e {
                    ClusterEvent::Moved { ext, from, to, .. } => {
                        Some((ext, from, to))
                    }
                    _ => None,
                })
                .collect();
            moved.sort_unstable();
            assert_eq!(moved, expected, "round {round}: moved ≠ label diff");
            // aggregate events are consistent with the label sets
            let prev_set: Vec<i64> =
                prev.values().copied().filter(|&l| l >= 0).collect();
            let now_set: Vec<i64> =
                cur.values().copied().filter(|&l| l >= 0).collect();
            for e in &batch {
                match *e {
                    ClusterEvent::Merged { from, into, .. } => {
                        assert!(prev_set.contains(&from));
                        assert!(!now_set.contains(&from));
                        assert!(
                            prev_set.contains(&into) || now_set.contains(&into)
                        );
                    }
                    ClusterEvent::Split { from, new, .. } => {
                        assert!(!prev_set.contains(&new));
                        assert!(now_set.contains(&new));
                        assert!(prev_set.contains(&from));
                        assert!(now_set.contains(&from));
                    }
                    ClusterEvent::Formed { label, .. } => {
                        assert!(!prev_set.contains(&label));
                        assert!(now_set.contains(&label));
                    }
                    ClusterEvent::Dissolved { label, .. } => {
                        assert!(prev_set.contains(&label));
                        assert!(!now_set.contains(&label));
                    }
                    ClusterEvent::Moved { .. } => {}
                }
            }
            prev = cur;
        }
        let _ = eng.finish();
    }
}

/// A genuine cross-publish merge and split must surface as events (1-D
/// bridge construction, mirroring the stitcher unit tests).
#[test]
fn watch_reports_bridge_split_and_merge() {
    let mut eng =
        EngineBuilder::new(1).k(3).t(10).eps(0.6).seed(11).build().unwrap();
    let events = eng.watch();
    let mut ext = 0u64;
    let mut add_blob = |eng: &mut Box<dyn ClusterEngine>, base: f32| -> Vec<u64> {
        (0..6)
            .map(|i| {
                let e = ext;
                ext += 1;
                eng.upsert(e, &[base + 0.01 * i as f32]);
                e
            })
            .collect()
    };
    let left = add_blob(&mut eng, 0.0);
    let right = add_blob(&mut eng, 2.0);
    let bridge = add_blob(&mut eng, 1.0);
    let v1 = eng.publish();
    let _ = events.next_publish();
    if v1.label(left[0]) != v1.label(right[0]) {
        // hash draw didn't connect the blobs; nothing to assert
        return;
    }
    // delete the bridge: the cluster must split, and the watcher must
    // hear about it
    for e in bridge {
        eng.remove(e);
    }
    let v2 = eng.publish();
    let batch = events.next_publish().unwrap();
    if v2.label(left[0]) != v2.label(right[0]) {
        assert!(
            batch.iter().any(|e| matches!(e, ClusterEvent::Split { .. })),
            "split happened but no Split event: {batch:?}"
        );
        // re-bridge: merge back, with a Merged event
        let _ = add_blob(&mut eng, 1.0);
        let v3 = eng.publish();
        let batch = events.next_publish().unwrap();
        if v3.label(left[0]) == v3.label(right[0]) {
            assert!(
                batch.iter().any(|e| matches!(e, ClusterEvent::Merged { .. })),
                "merge happened but no Merged event: {batch:?}"
            );
        }
    }
    let _ = eng.finish();
}

/// The freshness contract: snapshots carry version + pending_writes, and
/// publish gives read-your-publishes.
#[test]
fn snapshot_freshness_and_versioning() {
    for backend in [Backend::Single, Backend::Sharded(2)] {
        let mut eng = builder(2, 3).backend(backend).build().unwrap();
        assert_eq!(eng.snapshot().version(), 0);
        assert_eq!(eng.pending_writes(), 0);
        eng.upsert(7, &[0.0, 0.0]);
        eng.upsert(8, &[0.1, 0.1]);
        // the write state knows ext 7; the published view does not yet
        assert!(eng.contains(7));
        let stale = eng.snapshot();
        assert_eq!(stale.pending_writes(), 2);
        assert_eq!(stale.label(7), None);
        assert_eq!(eng.stats().pending_writes, 2);
        let v1 = eng.publish();
        assert_eq!(v1.pending_writes(), 0);
        assert!(v1.label(7).is_some());
        eng.remove(8);
        assert_eq!(eng.snapshot().pending_writes(), 1);
        // the published view is immutable: 8 is still visible there
        assert!(v1.label(8).is_some());
        let v2 = eng.publish();
        assert!(v2.version() > v1.version(), "versions must increase");
        assert_eq!(v2.label(8), None);
        assert_eq!(v2.live_points(), 1);
        // upsert replaces: same ext, new coordinates
        eng.upsert(7, &[5.0, 5.0]);
        let v3 = eng.publish();
        assert_eq!(v3.live_points(), 1);
        assert_eq!(v3.coords_of(7), Some(&[5.0, 5.0][..]));
        assert_eq!(v3.epsilon_neighbors(&[5.0, 5.0]), vec![7]);
        assert!(v3.epsilon_neighbors(&[0.0, 0.0]).is_empty());
        let _ = eng.finish();
    }
}

/// The connectivity ablation runs through the façade: flat conn modes
/// publish by full rebuild and still cluster correctly.
#[test]
fn flat_conn_modes_serve_via_full_rebuild() {
    let ds = make_blobs(
        &BlobsConfig {
            n: 500,
            dim: 3,
            clusters: 3,
            std: 0.3,
            center_box: 15.0,
            weights: vec![],
        },
        3,
    );
    for conn in [ConnKind::Repair, ConnKind::Paper] {
        let b = EngineBuilder::new(3).k(6).eps(0.75).seed(9).conn(conn);
        assert_eq!(b.effective_stitch(), StitchMode::FullRebuild);
        let mut eng = b.build().unwrap();
        for i in 0..ds.n() {
            eng.upsert(i as u64, ds.point(i));
        }
        let view = eng.publish();
        let pred: Vec<i64> = view.labels().iter().map(|&(_, l)| l).collect();
        let ari = adjusted_rand_index(&ds.labels, &pred);
        assert!(ari > 0.95, "{conn:?} ARI {ari}");
        let _ = eng.finish();
    }
}

#[test]
#[should_panic(expected = "remove of unknown ext")]
fn unknown_remove_panics_single() {
    let mut eng = builder(2, 1).build().unwrap();
    eng.remove(3);
}

#[test]
#[should_panic(expected = "remove of unknown ext")]
fn unknown_remove_panics_sharded() {
    let mut eng = builder(2, 1).backend(Backend::Sharded(2)).build().unwrap();
    eng.remove(3);
}
