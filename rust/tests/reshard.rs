//! Live-resharding acceptance tests: differential checks of the
//! `ReshardMode::Auto` migration path against a no-reshard oracle.
//!
//! The invariant under test: cell migration is a pure *placement* change.
//! Whatever the placement map does — greedy assignment, load-triggered
//! migration, restore from a checkpoint — the published global partition
//! must equal the one produced by the same op stream with resharding
//! off, after **every** publish, not just at quiescence. The workload is
//! built to actually trip the migration trigger: a contiguous "snake" of
//! cells is assigned to one shard while lightly loaded (CellGraph's
//! adjacency voting gloms a contiguous region onto one owner), then
//! hammered with dense inserts so that shard's member count blows past
//! `mean · slack + floor` and `plan_migration` has real work to do.

use std::path::PathBuf;

use dyn_dbscan::data::blobs::{make_blobs, BlobsConfig};
use dyn_dbscan::data::Dataset;
use dyn_dbscan::dbscan::DbscanConfig;
use dyn_dbscan::metrics::adjusted_rand_index;
use dyn_dbscan::serve::{
    Backend, ClusterEngine, EngineBuilder, FaultPlan, PlacementPolicy,
    ReshardMode, SnapshotView,
};
use dyn_dbscan::shard::{ShardConfig, ShardedEngine};
use rustc_hash::FxHashMap;

/// Fresh scratch directory under the system temp root (std-only: the
/// container has no tempfile crate).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dyn-dbscan-reshard-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn builder(dim: usize) -> EngineBuilder {
    // eager_attach makes non-core attachment depend on the final point
    // set, not the insertion order — required by the ARI = 1.0 gates
    EngineBuilder::new(dim).k(8).t(6).eps(0.75).seed(21).eager_attach(true)
}

/// Exact label-partition agreement over identical live sets.
fn ari_of(a: &SnapshotView, b: &SnapshotView) -> f64 {
    let la = a.labels();
    let lb: FxHashMap<u64, i64> = b.labels().into_iter().collect();
    assert_eq!(la.len(), lb.len(), "live sets diverged");
    let mut pa = Vec::with_capacity(la.len());
    let mut pb = Vec::with_capacity(la.len());
    for (ext, va) in la {
        pa.push(va);
        pb.push(*lb.get(&ext).unwrap_or_else(|| panic!("{ext} missing in b")));
    }
    adjusted_rand_index(&pa, &pb)
}

/// One op of the skew workload: `Some(coords)` = upsert, `None` = remove.
type Op = (u64, Option<Vec<f32>>);

/// Deterministic hot-spot workload in 3-d.
///
/// Phase 1 — establish the assignment: `n_uniform` well-separated blob
/// points plus one point in each cell of a 60-step snake along x (step
/// 0.3 ≪ the eps·block_side cell width, so consecutive steps are
/// neighbors and the snake spans several contiguous cells). Phase 2 —
/// skew: `n_hot` more points jittered onto the same snake (every one
/// lands in a cell already assigned in phase 1, so sticky first-touch
/// routes them all to the snake's owner), interleaved with removals of
/// some phase-1 blob points to deepen the imbalance.
fn hot_spot_workload(n_uniform: usize, n_hot: usize, seed: u64) -> Vec<Op> {
    let ds = make_blobs(
        &BlobsConfig {
            n: n_uniform,
            dim: 3,
            clusters: 4,
            std: 0.3,
            center_box: 20.0,
            weights: vec![],
        },
        seed,
    );
    let snake = |i: usize| -> Vec<f32> {
        // 60 slots, 0.3 apart: span 18.0 ≈ six 3.0-wide routing cells,
        // far from the blob box so the snake's cells are its own
        let slot = (i % 60) as f32;
        let jitter = ((i / 60) % 7) as f32 * 0.04;
        vec![40.0 + slot * 0.3, 40.0 + jitter, 0.25]
    };
    let mut ops: Vec<Op> = Vec::new();
    let base = n_uniform as u64;
    // phase 1: uniform mass + one point per snake slot
    for i in 0..n_uniform {
        ops.push((i as u64, Some(ds.point(i).to_vec())));
    }
    for i in 0..60 {
        ops.push((base + i as u64, Some(snake(i))));
    }
    // phase 2: hammer the snake, shed some uniform points
    for i in 0..n_hot {
        ops.push((base + 60 + i as u64, Some(snake(i))));
        if i % 6 == 0 && i / 6 < n_uniform / 4 {
            ops.push(((i / 6) as u64, None));
        }
    }
    ops
}

fn apply(eng: &mut Box<dyn ClusterEngine>, op: &Op) {
    match op {
        (ext, Some(coords)) => eng.upsert(*ext, coords),
        (ext, None) => eng.remove(*ext),
    }
}

// ---------------------------------------------------------------------
// the core differential gate
// ---------------------------------------------------------------------

/// Auto resharding must reproduce the no-reshard partition after every
/// publish — and must actually migrate (the run is vacuous otherwise).
#[test]
fn auto_resharding_matches_the_off_oracle_at_every_publish() {
    let ops = hot_spot_workload(400, 800, 31);
    let mut auto = builder(3)
        .backend(Backend::Sharded(2))
        .reshard(ReshardMode::Auto { max_cells_per_publish: 8 })
        .build()
        .unwrap();
    let mut off =
        builder(3).backend(Backend::Sharded(2)).build().unwrap();
    let mut last_epoch = 0;
    for chunk in ops.chunks(150) {
        for op in chunk {
            apply(&mut auto, op);
            apply(&mut off, op);
        }
        let va = auto.publish();
        let vo = off.publish();
        let ari = ari_of(&va, &vo);
        assert_eq!(
            ari, 1.0,
            "partition diverged at version {} (ARI {ari})",
            va.version()
        );
        assert_eq!(vo.reshard_epoch(), 0, "Off must never migrate");
        last_epoch = va.reshard_epoch();
    }
    assert!(
        last_epoch > 0,
        "the skewed workload never tripped a migration — the test is vacuous"
    );
    let _ = auto.finish();
    let _ = off.finish();
}

/// The point of migrating: under the same skewed stream, Auto's final
/// per-shard load spread must beat the frozen Off assignment.
#[test]
fn auto_rebalances_the_hot_shard() {
    let ops = hot_spot_workload(400, 800, 37);
    let mut auto = builder(3)
        .backend(Backend::Sharded(2))
        .reshard(ReshardMode::Auto { max_cells_per_publish: 8 })
        .build()
        .unwrap();
    let mut off =
        builder(3).backend(Backend::Sharded(2)).build().unwrap();
    for chunk in ops.chunks(150) {
        for op in chunk {
            apply(&mut auto, op);
            apply(&mut off, op);
        }
        auto.publish();
        off.publish();
    }
    let max_of = |eng: &Box<dyn ClusterEngine>| -> u64 {
        let loads = eng.metrics().shard_loads;
        assert_eq!(loads.len(), 2);
        assert!(loads.iter().sum::<u64>() > 0, "loads were never published");
        *loads.iter().max().unwrap()
    };
    let (a, o) = (max_of(&auto), max_of(&off));
    assert!(
        a < o,
        "migration did not reduce the peak shard load (auto {a} vs off {o})"
    );
    let _ = auto.finish();
    let _ = off.finish();
}

// ---------------------------------------------------------------------
// composition with fault tolerance
// ---------------------------------------------------------------------

/// Degrade → heal → migrate: a worker killed mid-stream degrades health
/// (resharding pauses while degraded), the next publish respawns and
/// re-feeds from the placement map, and migration then resumes — final
/// partition still exactly matches an unfaulted no-reshard oracle.
#[test]
fn killed_worker_heals_then_resharding_resumes() {
    let ops = hot_spot_workload(400, 800, 41);
    let plan = FaultPlan { shard: 1, kill_after_ops: Some(40), drop_next_reply: false };
    let mut faulty = builder(3)
        .backend(Backend::Sharded(3))
        .publish_timeout_ms(750)
        .reshard(ReshardMode::Auto { max_cells_per_publish: 8 })
        .faults(plan)
        .build()
        .unwrap();
    let mut oracle =
        builder(3).backend(Backend::Sharded(3)).build().unwrap();
    let mut saw_degraded = false;
    for chunk in ops.chunks(150) {
        for op in chunk {
            apply(&mut faulty, op);
            apply(&mut oracle, op);
        }
        faulty.publish();
        oracle.publish();
        saw_degraded |= !faulty.stats().health.is_ok();
    }
    assert!(saw_degraded, "the injected kill was never detected");
    // one more publish heals (respawn runs at publish start), and with
    // the skew still standing the reshard trigger fires post-heal
    let healed = faulty.publish();
    assert!(faulty.stats().health.is_ok(), "respawn must clear Degraded");
    assert!(
        healed.reshard_epoch() > 0,
        "resharding never resumed after the heal"
    );
    let ov = oracle.publish();
    let ari = ari_of(&healed, &ov);
    assert_eq!(ari, 1.0, "post-heal partition diverged (ARI {ari})");
    let out = faulty.finish();
    assert!(out.stats.health.is_ok());
    let _ = oracle.finish();
}

// ---------------------------------------------------------------------
// durability
// ---------------------------------------------------------------------

/// A durable reopen must reshard to the *same* assignment it spilled:
/// the checkpoint's placement blob is restored before re-ingestion, so
/// the exported map (version included) round-trips bit-for-bit and the
/// recovered partition matches.
#[test]
fn durable_reopen_reproduces_the_assignment() {
    let dir = scratch("reopen");
    let ops = hot_spot_workload(400, 800, 43);
    let mut eng = builder(3)
        .backend(Backend::Sharded(2))
        .reshard(ReshardMode::Auto { max_cells_per_publish: 8 })
        .persist(&dir)
        .build()
        .unwrap();
    for chunk in ops.chunks(150) {
        for op in chunk {
            apply(&mut eng, op);
        }
        eng.publish();
    }
    let before = eng.publish();
    assert!(before.reshard_epoch() > 0, "no migration before the close");
    let blob_before =
        eng.placement_blob().expect("sharded backend must export placement");
    let _ = eng.finish();

    let reopened = builder(3)
        .backend(Backend::Sharded(2))
        .reshard(ReshardMode::Auto { max_cells_per_publish: 8 })
        .persist(&dir)
        .build()
        .unwrap();
    let blob_after =
        reopened.placement_blob().expect("reopened backend must export placement");
    assert_eq!(blob_before, blob_after, "reopen re-derived a different assignment");
    let rv = reopened.snapshot();
    assert_eq!(rv.live_points(), before.live_points());
    let ari = ari_of(&rv, &before);
    assert_eq!(ari, 1.0, "reopened partition diverged (ARI {ari})");
    let _ = reopened.finish();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// stitch-graph ownership consistency
// ---------------------------------------------------------------------

/// After a quiesced publish the stitcher's per-shard live counts must
/// equal the placement map's expectation (members × routing fan-out) —
/// i.e. migration's delete/insert/flip ops left no stray or missing
/// replica anywhere.
#[test]
fn ownership_matches_the_placement_expectation_after_migration() {
    let cfg = DbscanConfig { k: 8, t: 6, eps: 0.75, dim: 3, ..Default::default() };
    let mut scfg = ShardConfig::new(cfg, 3, 7);
    scfg.reshard = ReshardMode::Auto { max_cells_per_publish: 8 };
    assert_eq!(scfg.placement, PlacementPolicy::CellGraph, "sharded default");
    let mut eng = ShardedEngine::new(scfg);
    let mut coords: FxHashMap<u64, Vec<f32>> = FxHashMap::default();
    let ops = hot_spot_workload(400, 800, 47);
    for chunk in ops.chunks(150) {
        for op in chunk {
            match op {
                (ext, Some(c)) => {
                    coords.insert(*ext, c.clone());
                    eng.insert(*ext, c);
                }
                (ext, None) => {
                    coords.remove(ext);
                    eng.delete(*ext);
                }
            }
        }
        eng.maybe_reshard(|ext, buf| match coords.get(&ext) {
            Some(row) => {
                buf.extend_from_slice(row);
                true
            }
            None => false,
        });
        let snap = eng.publish();
        let expected =
            eng.expected_shard_replicas().expect("S > 1 has a placement map");
        let got: Vec<u64> = snap.shard_live.iter().map(|&l| l as u64).collect();
        assert_eq!(
            expected, got,
            "stitcher ownership diverged from the placement map at seq {}",
            snap.seq
        );
    }
    assert!(eng.placement_version() > 0, "no migration happened");
    assert!(eng.stats().migrated_points > 0);
    let out = eng.finish();
    assert!(out.stats.health.is_ok());
}
