//! Observability acceptance tests: the striped atomic histogram agrees
//! with the single-threaded one under concurrent recording, the sharded
//! backend's `stats()` is live mid-run (the ROADMAP gap this PR closes),
//! publish traces respect the stage-sum ≤ total invariant, and the
//! Prometheus exporter emits well-formed text exposition.

use std::sync::Arc;
use std::thread;

use dyn_dbscan::obs::PublishStage;
use dyn_dbscan::serve::{Backend, ClusterEngine, EngineBuilder};
use dyn_dbscan::util::proptest::{run_prop, Gen};
use dyn_dbscan::util::rng::Rng;
use dyn_dbscan::util::stats::{AtomicHisto, LatencyHisto};

fn builder(dim: usize, seed: u64) -> EngineBuilder {
    EngineBuilder::new(dim).k(4).t(6).eps(0.5).seed(seed)
}

fn blob(rng: &mut Rng, dim: usize) -> Vec<f32> {
    let c = rng.below(3) as f64 * 4.0;
    (0..dim).map(|_| (c + rng.uniform(-0.4, 0.4)) as f32).collect()
}

/// Differential: N threads record identical per-thread value streams
/// into one shared [`AtomicHisto`] and into per-thread [`LatencyHisto`]s
/// merged afterwards. Same bucketing ⇒ identical count/min/max and
/// quantiles, regardless of interleaving — the property that makes the
/// sharded backend's live `stats()` trustworthy.
#[test]
fn atomic_histo_matches_merged_latency_histos_under_concurrency() {
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 5_000;
    let shared = Arc::new(AtomicHisto::new());
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let shared = Arc::clone(&shared);
            thread::spawn(move || {
                let mut rng = Rng::new(0xA70_u64 + t);
                let mut local = LatencyHisto::new();
                for _ in 0..PER_THREAD {
                    // span 6 decades, like real ns latencies
                    let v = 1 + rng.next_u64() % 1_000_000;
                    shared.record(v);
                    local.record(v);
                }
                local
            })
        })
        .collect();
    let mut merged = LatencyHisto::new();
    for h in handles {
        merged.merge(&h.join().unwrap());
    }
    let snap = shared.snapshot();
    assert_eq!(snap.count(), THREADS * PER_THREAD);
    assert_eq!(snap.count(), merged.count());
    assert_eq!(snap.min(), merged.min());
    assert_eq!(snap.max(), merged.max());
    for q in [0.1, 0.5, 0.9, 0.99, 0.999] {
        assert_eq!(
            snap.quantile(q),
            merged.quantile(q),
            "quantile {q} diverged between atomic and merged histograms"
        );
    }
}

/// The ROADMAP gap regression: before this PR the sharded backend's
/// per-op histograms lived inside worker threads and `stats()` came back
/// empty until `finish()`. With workers recording into the shared atomic
/// registry, a mid-run `stats()` must hold live add/delete latencies.
#[test]
fn sharded_stats_hold_live_latencies_mid_run() {
    let mut eng = builder(4, 11).backend(Backend::Sharded(4)).build().unwrap();
    let mut rng = Rng::new(5);
    for ext in 0..600u64 {
        eng.upsert(ext, &blob(&mut rng, 4));
    }
    for ext in 0..50u64 {
        eng.remove(ext);
    }
    eng.publish();
    // mid-run: no finish() yet, workers still running
    let stats = eng.stats();
    assert!(
        stats.add_latency.count() > 0,
        "sharded stats() must expose live add latencies mid-run"
    );
    assert!(
        stats.delete_latency.count() > 0,
        "sharded stats() must expose live delete latencies mid-run"
    );
    assert!(stats.add_latency.quantile(0.99) >= stats.add_latency.quantile(0.5));
    assert!(stats.publish_latency.count() > 0);
    // the full registry pull carries stage histograms too
    let m = eng.metrics();
    let route = m
        .publish_stages
        .iter()
        .find(|(name, _)| *name == "route")
        .expect("route stage histogram");
    assert!(route.1.count() > 0, "route stage must be recorded per publish");
    drop(eng.finish());
}

/// Per-publish stage traces: every publish yields a trace whose recorded
/// stages sum to at most the measured total, and the sharded trace covers
/// the route and stitch stages named in the acceptance criteria.
#[test]
fn publish_trace_stage_sum_bounded_by_total() {
    let mut eng = builder(4, 23).backend(Backend::Sharded(3)).build().unwrap();
    let mut rng = Rng::new(9);
    let mut ext = 0u64;
    for _ in 0..4 {
        for _ in 0..200 {
            eng.upsert(ext, &blob(&mut rng, 4));
            ext += 1;
        }
        eng.publish();
        let m = eng.metrics();
        let trace = &m.last_publish;
        assert!(trace.total_ns() > 0, "publish must stamp a total");
        assert!(
            trace.stage_sum_ns() <= trace.total_ns(),
            "stage sum {} exceeds publish total {}",
            trace.stage_sum_ns(),
            trace.total_ns()
        );
        // the engine-side stages the criteria call out explicitly
        let covered =
            trace.get(PublishStage::Route) + trace.get(PublishStage::Stitch);
        assert!(covered > 0, "trace must cover route/stitch");
    }
    drop(eng.finish());
}

/// Property: on the single backend too, traces respect the invariant
/// across randomized churn (upserts + deletes, varying batch shapes).
#[test]
fn prop_trace_invariant_under_churn() {
    run_prop("publish trace stage sum ≤ total", 12, |g: &mut Gen| {
        let mut eng = builder(3, 77).metrics(true).build().unwrap();
        let mut live: Vec<u64> = Vec::new();
        let mut next = 0u64;
        let rounds = g.usize_in(1..=3);
        for _ in 0..rounds {
            let n = g.usize_in(20..=150);
            for _ in 0..n {
                if !live.is_empty() && g.rng.coin(0.25) {
                    let i = g.rng.below_usize(live.len());
                    eng.remove(live.swap_remove(i));
                } else {
                    let p: Vec<f32> = (0..3)
                        .map(|_| g.f64_in(-5.0, 5.0) as f32)
                        .collect();
                    eng.upsert(next, &p);
                    live.push(next);
                    next += 1;
                }
            }
            eng.publish();
            let trace = eng.metrics().last_publish;
            assert!(trace.total_ns() > 0);
            assert!(trace.stage_sum_ns() <= trace.total_ns());
        }
    });
}

/// With metrics disabled the registry is a no-op recorder: no traces, no
/// stage histograms — the `obs_overhead` bench baseline.
#[test]
fn disabled_metrics_record_nothing() {
    let mut eng = builder(3, 41).metrics(false).build().unwrap();
    let mut rng = Rng::new(1);
    for ext in 0..300u64 {
        eng.upsert(ext, &blob(&mut rng, 3));
    }
    eng.publish();
    let m = eng.metrics();
    assert_eq!(m.last_publish.total_ns(), 0);
    assert!(m.publish_stages.iter().all(|(_, h)| h.count() == 0));
    assert!(m.update_stages.iter().all(|(_, h)| h.count() == 0));
}

/// The exporter must emit well-formed Prometheus text exposition: every
/// sample line is `name[{labels}] value` with a parseable float, and
/// every sample belongs to a family announced by a `# TYPE` header.
#[test]
fn prometheus_render_is_valid_text_exposition() {
    let mut eng = builder(4, 31).backend(Backend::Sharded(2)).build().unwrap();
    let mut rng = Rng::new(3);
    for ext in 0..400u64 {
        eng.upsert(ext, &blob(&mut rng, 4));
    }
    eng.publish();
    let text = eng.metrics().render_prometheus();
    assert!(text.contains("dyndbscan_inserts_total 400"));
    assert!(text.contains("dyndbscan_hdt_level_vertices{level=\"0\"}"));

    let mut families: Vec<String> = Vec::new();
    let mut samples = 0usize;
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            let mut parts = rest.splitn(3, ' ');
            let kw = parts.next().unwrap();
            let name = parts.next().expect("metric name after # keyword");
            assert!(
                kw == "HELP" || kw == "TYPE",
                "unknown comment keyword in {line:?}"
            );
            if kw == "TYPE" {
                let kind = parts.next().expect("metric kind");
                assert!(
                    ["counter", "gauge", "summary"].contains(&kind),
                    "bad TYPE in {line:?}"
                );
                families.push(name.to_string());
            }
            continue;
        }
        // sample line: name or name{label="v",...}, then a float value
        let (series, value) =
            line.rsplit_once(' ').expect("sample line needs a value");
        value.parse::<f64>().unwrap_or_else(|_| {
            panic!("unparseable value {value:?} in {line:?}")
        });
        let base = series.split('{').next().unwrap();
        assert!(
            families.iter().any(|f| base.starts_with(f.as_str())),
            "sample {base} has no preceding # TYPE family header"
        );
        samples += 1;
    }
    assert!(samples > 20, "exposition suspiciously small: {samples} samples");
    drop(eng.finish());
}
