//! Durability and fault-tolerance acceptance tests: WAL/checkpoint format
//! round-trips (including torn-tail damage), crash-recovery differential
//! checks against uninterrupted reference runs, and the sharded backend's
//! degrade → respawn → heal cycle under injected worker faults.
//!
//! Crash simulation: `std::mem::forget(engine)` skips every destructor —
//! the WAL's `BufWriter` never flushes and no shutdown checkpoint spills,
//! exactly like a `kill -9` after the last completed fsync. Forgotten
//! engines use the inline backend so no worker threads leak.

use std::path::PathBuf;

use dyn_dbscan::data::blobs::{make_blobs, BlobsConfig};
use dyn_dbscan::data::Dataset;
use dyn_dbscan::metrics::adjusted_rand_index;
use dyn_dbscan::persist::{
    load_checkpoint, read_wal, write_checkpoint, Checkpoint, WalOp, WalRecord,
    WalWriter, WAL_FILE,
};
use dyn_dbscan::serve::{
    Backend, ClusterEngine, EngineBuilder, FaultPlan, SnapshotView,
};
use rustc_hash::FxHashMap;

/// Fresh scratch directory under the system temp root (std-only: the
/// container has no tempfile crate). Unique per test name + process so
/// parallel test binaries never collide; recreated empty on entry.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dyn-dbscan-recovery-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn blobs(n: usize, seed: u64) -> Dataset {
    // well separated (center_box ≫ std): border attachment is
    // order-independent up to the cluster label, so recovery re-ingestion
    // order cannot cost ARI
    make_blobs(
        &BlobsConfig {
            n,
            dim: 3,
            clusters: 4,
            std: 0.3,
            center_box: 20.0,
            weights: vec![],
        },
        seed,
    )
}

fn builder(dim: usize) -> EngineBuilder {
    // eager_attach makes non-core attachment depend on the final point
    // set, not the insertion order — required by the ARI = 1.0 gates
    EngineBuilder::new(dim).k(8).t(6).eps(0.75).seed(21).eager_attach(true)
}

/// Exact label-partition agreement over identical live sets.
fn ari_of(a: &SnapshotView, b: &SnapshotView) -> f64 {
    let la = a.labels();
    let lb: FxHashMap<u64, i64> = b.labels().into_iter().collect();
    assert_eq!(la.len(), lb.len(), "live sets diverged");
    let mut pa = Vec::with_capacity(la.len());
    let mut pb = Vec::with_capacity(la.len());
    for (ext, va) in la {
        pa.push(va);
        pb.push(*lb.get(&ext).unwrap_or_else(|| panic!("{ext} missing in b")));
    }
    adjusted_rand_index(&pa, &pb)
}

// ---------------------------------------------------------------------
// format round-trips
// ---------------------------------------------------------------------

#[test]
fn wal_roundtrip_preserves_records_and_op_order() {
    let dir = scratch("wal-roundtrip");
    let records = vec![
        WalRecord::Upsert { seq: 1, ext: 7, coords: vec![1.0, -2.5] },
        WalRecord::Remove { seq: 2, ext: 7 },
        // remove-then-upsert of the same ext is a *replace*; order inside
        // the batch must survive the round-trip
        WalRecord::Apply {
            seq: 3,
            ops: vec![
                WalOp::Remove { ext: 9 },
                WalOp::Upsert { ext: 9, coords: vec![0.5, 0.5] },
                WalOp::Upsert { ext: 10, coords: vec![f32::MIN, f32::MAX] },
            ],
        },
        WalRecord::Publish { seq: 4, version: 17 },
    ];
    let mut w = WalWriter::open(&dir).unwrap();
    for r in &records {
        w.append(r).unwrap();
    }
    assert_eq!(w.pending(), 4);
    assert_eq!(w.sync().unwrap(), 4);
    assert_eq!(w.pending(), 0);
    let (back, clean) = read_wal(&dir).unwrap();
    assert!(clean);
    assert_eq!(back, records);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn torn_wal_tail_truncates_to_the_last_whole_record() {
    let dir = scratch("wal-torn");
    let mut w = WalWriter::open(&dir).unwrap();
    w.append(&WalRecord::Upsert { seq: 1, ext: 1, coords: vec![1.0] }).unwrap();
    w.append(&WalRecord::Publish { seq: 2, version: 1 }).unwrap();
    w.sync().unwrap();
    drop(w);
    let path = dir.join(WAL_FILE);
    let full = std::fs::read(&path).unwrap();

    // torn payload: cut the final frame mid-way
    std::fs::write(&path, &full[..full.len() - 5]).unwrap();
    let (recs, clean) = read_wal(&dir).unwrap();
    assert!(!clean);
    assert_eq!(recs.len(), 1, "only the first whole record survives");
    assert_eq!(recs[0].seq(), 1);

    // bit rot in the final payload: CRC must reject it, prefix survives
    let mut rotten = full.clone();
    let n = rotten.len();
    rotten[n - 1] ^= 0x40;
    std::fs::write(&path, &rotten).unwrap();
    let (recs, clean) = read_wal(&dir).unwrap();
    assert!(!clean);
    assert_eq!(recs.len(), 1);

    // torn header after a clean record: prefix survives
    let mut with_garbage = full.clone();
    with_garbage.extend_from_slice(&[0xAB, 0xCD, 0xEF]);
    std::fs::write(&path, &with_garbage).unwrap();
    let (recs, clean) = read_wal(&dir).unwrap();
    assert!(!clean);
    assert_eq!(recs.len(), 2, "the whole-record prefix is intact");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_roundtrip_and_damage_tolerance() {
    let dir = scratch("ckpt");
    let ckpt = Checkpoint {
        version: 11,
        wal_seq: 42,
        eps: 0.75,
        dim: 3,
        points: vec![(5, vec![1.0, 2.0, 3.0]), (9, vec![-1.0, 0.0, 4.5])],
        labels: vec![0, -1],
        cores: vec![true, false],
        placement: Some(vec![0xDE, 0xAD, 0xBE, 0xEF]),
    };
    write_checkpoint(&dir, &ckpt).unwrap();
    let back = load_checkpoint(&dir).expect("valid checkpoint must load");
    assert_eq!(back.version, 11);
    assert_eq!(back.wal_seq, 42);
    assert_eq!(back.points, ckpt.points);
    assert_eq!(back.labels, ckpt.labels);
    assert_eq!(back.cores, ckpt.cores);
    assert_eq!(back.placement, ckpt.placement, "placement blob survives the roundtrip");

    // an absent placement blob encodes as length 0 and reads back as None
    let bare = Checkpoint { placement: None, ..ckpt.clone() };
    write_checkpoint(&dir, &bare).unwrap();
    assert_eq!(load_checkpoint(&dir).unwrap().placement, None);
    write_checkpoint(&dir, &ckpt).unwrap();

    // truncation (crash mid-spill before the atomic rename would normally
    // prevent this — belt and braces) reads as absent, never as garbage
    let path = dir.join(dyn_dbscan::persist::CHECKPOINT_FILE);
    let full = std::fs::read(&path).unwrap();
    std::fs::write(&path, &full[..full.len() / 2]).unwrap();
    assert!(load_checkpoint(&dir).is_none());

    // CRC damage likewise
    let mut rotten = full.clone();
    let n = rotten.len();
    rotten[n - 3] ^= 0x01;
    std::fs::write(&path, &rotten).unwrap();
    assert!(load_checkpoint(&dir).is_none());
    let _ = std::fs::remove_dir_all(&dir);
}

/// A pre-placement `DDCKPT01` checkpoint must still load (as
/// `placement: None`): the WAL was truncated when it spilled, so
/// rejecting it would silently drop every point folded into it.
#[test]
fn legacy_v1_checkpoint_still_loads() {
    let dir = scratch("ckpt-v1");
    let ckpt = Checkpoint {
        version: 7,
        wal_seq: 21,
        eps: 0.5,
        dim: 2,
        points: vec![(3, vec![0.5, -0.5]), (8, vec![2.0, 2.0])],
        labels: vec![0, 0],
        cores: vec![true, true],
        placement: None,
    };
    // hand-frame the v1 layout: the v2 body minus the trailing
    // placement length field, under the old magic
    let mut body = Vec::new();
    body.extend_from_slice(&ckpt.version.to_le_bytes());
    body.extend_from_slice(&ckpt.wal_seq.to_le_bytes());
    body.extend_from_slice(&ckpt.eps.to_le_bytes());
    body.extend_from_slice(&ckpt.dim.to_le_bytes());
    body.extend_from_slice(&(ckpt.points.len() as u32).to_le_bytes());
    for (i, (ext, coords)) in ckpt.points.iter().enumerate() {
        body.extend_from_slice(&ext.to_le_bytes());
        body.extend_from_slice(&ckpt.labels[i].to_le_bytes());
        body.push(ckpt.cores[i] as u8);
        for x in coords {
            body.extend_from_slice(&x.to_le_bytes());
        }
    }
    let mut file = Vec::new();
    file.extend_from_slice(b"DDCKPT01");
    file.extend_from_slice(&(body.len() as u64).to_le_bytes());
    file.extend_from_slice(&body);
    file.extend_from_slice(&dyn_dbscan::persist::crc32(&body).to_le_bytes());
    std::fs::write(dir.join(dyn_dbscan::persist::CHECKPOINT_FILE), &file).unwrap();

    let back = load_checkpoint(&dir).expect("v1 checkpoint must load");
    assert_eq!(back, ckpt);

    // an unknown future magic is still rejected
    let mut future = file.clone();
    future[..8].copy_from_slice(b"DDCKPT99");
    std::fs::write(dir.join(dyn_dbscan::persist::CHECKPOINT_FILE), &future).unwrap();
    assert!(load_checkpoint(&dir).is_none());
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// crash recovery, differential against uninterrupted runs
// ---------------------------------------------------------------------

/// Cold full-log replay (checkpointing pushed out of reach) is bit-exact:
/// the recovered engine re-executes the identical op sequence, so labels
/// — not just the partition — match an uninterrupted run, on a
/// delete-heavy churn schedule.
#[test]
fn cold_replay_after_crash_is_bit_exact_on_churn() {
    let dir = scratch("cold-replay");
    let ds = blobs(600, 3);
    let mut durable = builder(3)
        .persist(&dir)
        .persist_every(1_000_000) // never checkpoint: pure WAL replay
        .build()
        .unwrap();
    let mut reference = builder(3).build().unwrap();

    let mut last_version = 0;
    for (i, chunk) in (0..ds.n()).collect::<Vec<_>>().chunks(100).enumerate() {
        for &j in chunk {
            durable.upsert(j as u64, ds.point(j));
            reference.upsert(j as u64, ds.point(j));
        }
        // churn: every other chunk deletes half of the previous chunk
        if i % 2 == 1 {
            for e in ((i - 1) * 100..(i - 1) * 100 + 50).map(|e| e as u64) {
                durable.remove(e);
                reference.remove(e);
            }
        }
        last_version = durable.publish().version();
        assert_eq!(last_version, reference.publish().version());
    }
    // writes after the last publish are buffered, not yet durable — a
    // crash loses exactly these (the documented contract)
    durable.upsert(999_999, &[50.0, 50.0, 50.0]);
    std::mem::forget(durable);

    let recovered = builder(3).persist(&dir).build().unwrap();
    let rv = recovered.snapshot();
    let fv = reference.publish();
    assert_eq!(rv.version(), last_version, "version continuity");
    assert!(!rv.contains(999_999), "unpublished write must not survive");
    let mut ra = rv.labels();
    let mut rb = fv.labels();
    ra.sort_unstable();
    rb.sort_unstable();
    assert_eq!(ra, rb, "cold replay must be bit-exact");
    let _ = recovered.finish();
    let _ = reference.finish();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Checkpoint + WAL-tail recovery: re-ingestion order differs from the
/// original insertion order, so the gate is partition equality (ARI = 1.0
/// on well-separated blobs) plus exact version continuity — and the next
/// publish after recovery keeps counting from the recovered version.
#[test]
fn checkpoint_plus_tail_recovery_restores_the_published_partition() {
    let dir = scratch("ckpt-tail");
    let ds = blobs(900, 5);
    let mut durable = builder(3)
        .persist(&dir)
        .persist_every(2) // force real checkpoints mid-run
        .build()
        .unwrap();
    let mut reference = builder(3).build().unwrap();
    let mut last_version = 0;
    for chunk in (0..ds.n()).collect::<Vec<_>>().chunks(150) {
        for &j in chunk {
            durable.upsert(j as u64, ds.point(j));
            reference.upsert(j as u64, ds.point(j));
        }
        last_version = durable.publish().version();
        reference.publish();
    }
    // a WAL tail past the last checkpoint: deletes + one publish
    for e in 0..120u64 {
        durable.remove(e);
        reference.remove(e);
    }
    last_version = durable.publish().version();
    let fv = reference.publish();
    assert!(load_checkpoint(&dir).is_some(), "mid-run checkpoint must exist");
    std::mem::forget(durable);

    let mut recovered = builder(3).persist(&dir).build().unwrap();
    let rv = recovered.snapshot();
    assert_eq!(rv.version(), last_version, "version continuity");
    assert_eq!(rv.live_points(), fv.live_points());
    assert_eq!(rv.core_points(), fv.core_points());
    let ari = ari_of(&rv, &fv);
    assert_eq!(ari, 1.0, "recovered partition diverged (ARI {ari})");
    // the engine keeps serving and counting from where it recovered
    recovered.upsert(1_000_000, ds.point(500));
    let next = recovered.publish();
    assert_eq!(next.version(), last_version + 1);
    assert!(next.contains(1_000_000));
    let _ = recovered.finish();
    let _ = reference.finish();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Kill mid-stream *between* publishes: recovery must land exactly on the
/// longest durable prefix — determined here independently via `read_wal` —
/// and match a reference run fed only that prefix.
#[test]
fn kill_between_publishes_recovers_the_durable_prefix() {
    let dir = scratch("kill-mid");
    let ds = blobs(400, 9);
    let mut durable = builder(3)
        .persist(&dir)
        .persist_every(1_000_000)
        .build()
        .unwrap();
    for j in 0..300 {
        durable.upsert(j as u64, ds.point(j));
        if j % 90 == 89 {
            durable.publish();
        }
    }
    // 30 more ops that never reach a publish (buffered, not fsynced)
    for j in 300..330 {
        durable.upsert(j as u64, ds.point(j));
    }
    std::mem::forget(durable);

    // independently decide what should have survived
    let (records, _clean) = read_wal(&dir).unwrap();
    let mut reference = builder(3).build().unwrap();
    let mut expect_version = 0;
    for rec in &records {
        match rec {
            WalRecord::Upsert { ext, coords, .. } => reference.upsert(*ext, coords),
            WalRecord::Remove { ext, .. } => reference.remove(*ext),
            WalRecord::Apply { ops, .. } => {
                for op in ops {
                    match op {
                        WalOp::Upsert { ext, coords } => {
                            reference.upsert(*ext, coords)
                        }
                        WalOp::Remove { ext } => reference.remove(*ext),
                    }
                }
            }
            WalRecord::Publish { version, .. } => {
                reference.publish();
                expect_version = *version;
            }
        }
    }
    assert!(expect_version > 0, "at least one publish must be durable");

    let recovered = builder(3).persist(&dir).build().unwrap();
    let rv = recovered.snapshot();
    let fv = reference.publish();
    assert_eq!(rv.version(), expect_version);
    assert_eq!(rv.live_points(), 270, "exactly the published prefix is live");
    let mut ra = rv.labels();
    let mut rb = fv.labels();
    ra.sort_unstable();
    rb.sort_unstable();
    assert_eq!(ra, rb, "recovered state must equal the durable prefix");
    let _ = recovered.finish();
    let _ = reference.finish();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Clean shutdown spills a checkpoint; reopening is replay-free and the
/// sharded backend recovers through the same path as the inline one.
#[test]
fn sharded_persist_shutdown_and_reopen() {
    let dir = scratch("sharded-reopen");
    let ds = blobs(600, 13);
    let mut eng = builder(3)
        .backend(Backend::Sharded(3))
        .persist(&dir)
        .build()
        .unwrap();
    for j in 0..ds.n() {
        eng.upsert(j as u64, ds.point(j));
    }
    let before = eng.publish();
    let out = eng.finish();
    assert!(out.stats.health.is_ok());
    // shutdown checkpoint landed and folded the whole log in
    let ckpt = load_checkpoint(&dir).expect("shutdown checkpoint");
    assert_eq!(ckpt.points.len(), ds.n());

    let reopened = builder(3)
        .backend(Backend::Sharded(3))
        .persist(&dir)
        .build()
        .unwrap();
    let after = reopened.snapshot();
    assert_eq!(after.version(), before.version());
    assert_eq!(after.live_points(), before.live_points());
    let ari = ari_of(&after, &before);
    assert_eq!(ari, 1.0, "reopened sharded partition diverged (ARI {ari})");
    let _ = reopened.finish();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// sharded fault tolerance: degrade, keep serving, respawn, heal
// ---------------------------------------------------------------------

/// A shard worker dying mid-stream must degrade `Stats::health` instead of
/// aborting, keep reads serving the last published snapshot, and heal on
/// the next publish via respawn + re-feed — back to ARI = 1.0 against an
/// uninterrupted run.
#[test]
fn killed_worker_degrades_health_then_respawn_heals() {
    let ds = blobs(900, 17);
    let plan = FaultPlan { shard: 1, kill_after_ops: Some(40), drop_next_reply: false };
    let mut faulty = builder(3)
        .backend(Backend::Sharded(3))
        .publish_timeout_ms(750)
        .faults(plan)
        .build()
        .unwrap();
    let mut reference =
        builder(3).backend(Backend::Sharded(3)).build().unwrap();

    let mut saw_degraded = false;
    let mut last_good: Option<SnapshotView> = None;
    for chunk in (0..ds.n()).collect::<Vec<_>>().chunks(150) {
        for &j in chunk {
            faulty.upsert(j as u64, ds.point(j));
            reference.upsert(j as u64, ds.point(j));
        }
        let view = faulty.publish();
        reference.publish();
        let health = faulty.stats().health;
        if !health.is_ok() {
            saw_degraded = true;
            assert_eq!(health.degraded_shards(), 1);
            // reads keep working while degraded: the previous published
            // snapshot is still fully answerable
            if let Some(prev) = &last_good {
                assert!(prev.live_points() > 0);
                let probe = ds.point(0);
                let _ = prev.epsilon_neighbors(probe);
            }
        }
        last_good = Some(view);
    }
    assert!(saw_degraded, "the injected kill was never detected");
    // one more publish heals: respawn happens at publish start
    let healed = faulty.publish();
    assert!(faulty.stats().health.is_ok(), "respawn must clear Degraded");
    let fv = reference.publish();
    assert_eq!(healed.live_points(), fv.live_points());
    let ari = ari_of(&healed, &fv);
    assert_eq!(ari, 1.0, "post-heal partition diverged (ARI {ari})");
    let out = faulty.finish();
    assert!(out.stats.health.is_ok());
    let _ = reference.finish();
}

/// A wedged worker (reply swallowed, thread alive) must trip the publish
/// timeout into `Degraded`, then heal exactly like a dead one — the
/// respawn replaces the wedged thread wholesale.
#[test]
fn dropped_reply_times_out_then_heals() {
    let ds = blobs(450, 23);
    let plan = FaultPlan { shard: 0, kill_after_ops: None, drop_next_reply: true };
    let mut faulty = builder(3)
        .backend(Backend::Sharded(2))
        .publish_timeout_ms(400)
        .faults(plan)
        .build()
        .unwrap();
    let mut reference =
        builder(3).backend(Backend::Sharded(2)).build().unwrap();
    for j in 0..ds.n() {
        faulty.upsert(j as u64, ds.point(j));
        reference.upsert(j as u64, ds.point(j));
    }
    faulty.publish();
    reference.publish();
    assert!(
        !faulty.stats().health.is_ok(),
        "swallowed barrier reply must surface as a publish timeout"
    );
    let healed = faulty.publish();
    assert!(faulty.stats().health.is_ok());
    let fv = reference.publish();
    let ari = ari_of(&healed, &fv);
    assert_eq!(ari, 1.0, "post-heal partition diverged (ARI {ari})");
    let _ = faulty.finish();
    let _ = reference.finish();
}

/// Durability composes with fault tolerance: a persisted sharded engine
/// that degrades and heals still recovers its state from disk afterwards.
#[test]
fn persisted_sharded_engine_survives_worker_kill_and_reopen() {
    let dir = scratch("persist-faulty");
    let ds = blobs(600, 29);
    let plan = FaultPlan { shard: 0, kill_after_ops: Some(60), drop_next_reply: false };
    let mut eng = builder(3)
        .backend(Backend::Sharded(2))
        .publish_timeout_ms(750)
        .persist(&dir)
        .faults(plan)
        .build()
        .unwrap();
    for chunk in (0..ds.n()).collect::<Vec<_>>().chunks(200) {
        for &j in chunk {
            eng.upsert(j as u64, ds.point(j));
        }
        eng.publish();
    }
    let healed = eng.publish();
    assert!(eng.stats().health.is_ok(), "faulty shard must have healed");
    let version = healed.version();
    let out = eng.finish();
    assert!(out.stats.health.is_ok());

    let reopened = builder(3)
        .backend(Backend::Sharded(2))
        .persist(&dir)
        .build()
        .unwrap();
    let rv = reopened.snapshot();
    assert_eq!(rv.version(), version);
    assert_eq!(rv.live_points(), ds.n());
    let ari = ari_of(&rv, &healed);
    assert_eq!(ari, 1.0, "reopened partition diverged (ARI {ari})");
    let _ = reopened.finish();
    let _ = std::fs::remove_dir_all(&dir);
}
