//! # dyn-dbscan — Dynamic DBSCAN with Euler Tour Sequences
//!
//! Production-grade reproduction of *“Dynamic DBSCAN with Euler Tour
//! Sequences”* (Shin, Shomorony, Macgregor — AISTATS 2025): a density-based
//! clustering structure that supports **point insertion and deletion in
//! `O(d·log³n + log⁴n)`** while matching the density-level-set guarantees of
//! the static near-linear-time DBSCAN of Esfandiari–Mirrokni–Zhong (AAAI'21).
//!
//! The library is the L3 (Rust) layer of a three-layer stack:
//!
//! * **L3 (this crate)** — the dynamic clustering structure
//!   ([`dbscan::DynamicDbscan`]), the Euler-tour dynamic forest ([`ett`]),
//!   grid-LSH bucket tables ([`lsh`]), baselines ([`baselines`]), metrics
//!   ([`metrics`]), datasets ([`data`]), the streaming coordinator
//!   ([`coordinator`]), the sharded parallel serving engine with
//!   cross-shard cluster stitching ([`shard`]) and the benchmark harness
//!   ([`bench_harness`]).
//! * **L2/L1 (python, build-time only)** — JAX/Pallas compute graphs
//!   (batched grid-hash quantizer, pairwise-distance tiles, PCA projection)
//!   AOT-lowered to HLO text and executed through [`runtime`] on the PJRT
//!   CPU client. Python never runs on the request path.
//!
//! ## Quick start
//!
//! ```no_run
//! use dyn_dbscan::dbscan::{DbscanConfig, DynamicDbscan};
//!
//! let cfg = DbscanConfig { k: 10, t: 10, eps: 0.75, dim: 2, ..Default::default() };
//! let mut db = DynamicDbscan::new(cfg, 42);
//! let a = db.add_point(&[0.0, 0.0]);
//! let b = db.add_point(&[0.1, 0.1]);
//! let _ = db.get_cluster(a) == db.get_cluster(b);
//! db.delete_point(a);
//! ```
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! the paper-vs-measured reproduction of every table and figure.

pub mod baselines;
pub mod bench_harness;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod dbscan;
pub mod ett;
pub mod experiments;
pub mod lsh;
pub mod metrics;
pub mod runtime;
pub mod shard;
pub mod util;
