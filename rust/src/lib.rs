//! # dyn-dbscan — Dynamic DBSCAN with Euler Tour Sequences
//!
//! Production-grade reproduction of *“Dynamic DBSCAN with Euler Tour
//! Sequences”* (Shin, Shomorony, Macgregor — AISTATS 2025): a density-based
//! clustering structure that supports **point insertion and deletion in
//! `O(d·log³n + log⁴n)`** while matching the density-level-set guarantees of
//! the static near-linear-time DBSCAN of Esfandiari–Mirrokni–Zhong (AAAI'21).
//!
//! The library is the L3 (Rust) layer of a three-layer stack:
//!
//! * **L3 (this crate)** — the unified serving API ([`serve`]: one typed
//!   engine façade, versioned snapshot reads, cluster-event
//!   subscriptions), the observability layer ([`obs`]: lock-free live
//!   metrics, publish-stage tracing, Prometheus-style exposition through
//!   `serve::MetricsSnapshot::render_prometheus`), the dynamic clustering
//!   structure
//!   ([`dbscan::DynamicDbscan`]), the Euler-tour dynamic forest ([`ett`]),
//!   grid-LSH bucket tables ([`lsh`]), baselines ([`baselines`]), metrics
//!   ([`metrics`]), datasets ([`data`]), the streaming coordinator
//!   ([`coordinator`]), the sharded parallel serving engine with
//!   cross-shard cluster stitching ([`shard`]), the durability primitives
//!   behind `EngineBuilder::persist` ([`persist`]: segmented CRC-framed
//!   op-log WAL + full/incremental checkpoint spill), the WAL log-shipping
//!   replication layer ([`replica`]: read replicas, staleness-bounded read
//!   routing, leader promotion) and the benchmark harness
//!   ([`bench_harness`]).
//! * **L2/L1 (python, build-time only)** — JAX/Pallas compute graphs
//!   (batched grid-hash quantizer, pairwise-distance tiles, PCA projection)
//!   AOT-lowered to HLO text and executed through [`runtime`] on the PJRT
//!   CPU client. Python never runs on the request path.
//!
//! ## Quick start
//!
//! Everything goes through [`serve::EngineBuilder`]; the same code drives
//! the single-instance and the S-way sharded backend:
//!
//! ```no_run
//! use dyn_dbscan::serve::{Backend, ClusterEngine, EngineBuilder};
//!
//! let mut engine = EngineBuilder::new(2) // dim = 2
//!     .k(10)
//!     .t(10)
//!     .eps(0.75)
//!     .backend(Backend::Single) // or Backend::Sharded(8)
//!     .seed(42)
//!     .build()
//!     .unwrap();
//!
//! // writes: external keys, buffered until an explicit publish
//! let events = engine.watch(); // merge/split/moved, per publish
//! engine.upsert(1, &[0.0, 0.0]);
//! engine.upsert(2, &[0.1, 0.1]);
//!
//! // reads: versioned immutable snapshots with a visible freshness gap;
//! // ε-neighborhood and kNN queries answer sublinearly from a pinned
//! // per-snapshot ε-cell index (see serve::IndexPolicy)
//! assert_eq!(engine.snapshot().pending_writes(), 2);
//! let view = engine.publish(); // read-your-publishes
//! let _ = view.label(1) == view.label(2);
//! let _near = view.epsilon_neighbors(&[0.0, 0.0]);
//! let _top3 = view.k_nearest(&[0.0, 0.0], 3);
//!
//! engine.remove(1);
//! let view = engine.publish();
//! let _ = events.drain(); // cluster events of both publishes
//! assert_eq!(view.version(), 2);
//!
//! // live observability: merged per-op latencies mid-run (sharded too),
//! // per-stage publish traces and Prometheus text exposition — the CLI
//! // streams the same output with `stream … --metrics-every N`
//! let m = engine.metrics();
//! println!("{}", m.render_prometheus());
//! ```
//!
//! Add `.persist(dir)` and the same engine survives crashes: every write
//! is op-logged before it is applied, publishes group-fsync the log, and
//! reopening the directory recovers checkpoint + WAL tail back to the
//! last published version:
//!
//! ```no_run
//! use dyn_dbscan::serve::{Backend, ClusterEngine, EngineBuilder};
//!
//! let mut engine = EngineBuilder::new(2)
//!     .backend(Backend::Sharded(4))
//!     .persist("/var/lib/dyn-dbscan") // WAL + checkpoint live here
//!     .build()
//!     .unwrap();
//! engine.upsert(1, &[0.0, 0.0]);
//! let view = engine.publish(); // durable once this returns
//! // …crash, restart: an identically-configured build() resumes at
//! // `view.version()` with the same labels.
//! # let _ = view;
//! ```
//!
//! Add `.replicate(n)` on top of `.persist(dir)` and `build_replicated()`
//! returns the writable leader plus a [`replica::ReadRouter`] over `n`
//! read replicas — each bootstrapped from the checkpoint chain and fed
//! the leader's fsynced WAL frames at every publish. Replica views carry
//! the leader's version numbering and are bit-identical to the leader's
//! view at the same version; staleness is bounded in publish barriers,
//! and `ReadRouter::promote(i)` fails a follower over into a writable
//! leader:
//!
//! ```no_run
//! use dyn_dbscan::serve::{ClusterEngine, EngineBuilder};
//!
//! let (mut leader, mut reads) = EngineBuilder::new(2)
//!     .persist("/var/lib/dyn-dbscan")
//!     .replicate(2)          // two read replicas
//!     .max_staleness(0)      // reads always catch up to the leader
//!     .build_replicated()
//!     .unwrap();
//! leader.upsert(1, &[0.0, 0.0]);
//! let v = leader.publish(); // fsync + ship to both replicas
//! let r = reads.read();     // replica view, version parity with v
//! assert_eq!(r.version(), v.version());
//! // leader gone? drain the tail and keep serving writes:
//! let mut leader2 = reads.promote(0);
//! leader2.upsert(2, &[0.1, 0.1]);
//! ```
//!
//! The structure-level API ([`dbscan::DynamicDbscan`]: `add_point` /
//! `delete_point` / `get_cluster` over internal `PointId`s) remains for
//! embedding and ablation; see `DESIGN.md` §Serving API for when to use
//! which. `EXPERIMENTS.md` holds the paper-vs-measured reproduction of
//! every table and figure.

pub mod baselines;
pub mod bench_harness;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod dbscan;
pub mod ett;
pub mod experiments;
pub mod lsh;
pub mod metrics;
pub mod obs;
pub mod persist;
pub mod replica;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod util;
