//! `dyn-dbscan` — leader entrypoint for the Dynamic DBSCAN system.
//!
//! See `dyn-dbscan help` (or `cli::USAGE`) for the command set: paper
//! experiment reproduction (`table2`, `fig2`), the streaming coordinator
//! (`stream`), the Theorem-2 invariant checker (`verify`) and artifact
//! introspection (`info`).

use dyn_dbscan::cli::{commands, Args};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", dyn_dbscan::cli::USAGE);
            std::process::exit(2);
        }
    };
    if let Err(e) = commands::dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
