//! Exact DBSCAN (Ester et al. 1996) with scikit-learn semantics — the
//! paper's "Sklearn" baseline.
//!
//! * core point: at least `min_pts` points within distance `eps`
//!   (**including itself**, the sklearn convention);
//! * clusters: BFS over ε-reachability from core points; border points join
//!   the first cluster that reaches them; the rest is noise (−1).
//!
//! Range queries run through a [`PairwiseDistance`] provider so the same
//! algorithm can use either the blocked native implementation or the AOT
//! Pallas distance-tile artifact (`runtime::engines::XlaDistance`). Cost is
//! `O(n²·d)` — the quadratic wall the paper's algorithm removes.

/// Tile-oriented pairwise squared-distance provider.
pub trait PairwiseDistance {
    /// Row-major `nq × nc` squared distances between `q` (`nq × d`) and
    /// `c` (`nc × d`), written into `out` (len `nq * nc`).
    fn dist2(&mut self, q: &[f32], nq: usize, c: &[f32], nc: usize, d: usize, out: &mut [f32]);
}

/// Blocked native implementation (cache-friendly `‖x‖²+‖y‖²−2x·y`).
#[derive(Default)]
pub struct NativeDistance;

impl PairwiseDistance for NativeDistance {
    fn dist2(
        &mut self,
        q: &[f32],
        nq: usize,
        c: &[f32],
        nc: usize,
        d: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(q.len(), nq * d);
        debug_assert_eq!(c.len(), nc * d);
        debug_assert_eq!(out.len(), nq * nc);
        let qn: Vec<f32> = (0..nq)
            .map(|i| q[i * d..(i + 1) * d].iter().map(|v| v * v).sum())
            .collect();
        let cn: Vec<f32> = (0..nc)
            .map(|j| c[j * d..(j + 1) * d].iter().map(|v| v * v).sum())
            .collect();
        for i in 0..nq {
            let qi = &q[i * d..(i + 1) * d];
            let row = &mut out[i * nc..(i + 1) * nc];
            for (j, r) in row.iter_mut().enumerate() {
                let cj = &c[j * d..(j + 1) * d];
                let mut dot = 0.0f32;
                for k in 0..d {
                    dot += qi[k] * cj[k];
                }
                *r = (qn[i] + cn[j] - 2.0 * dot).max(0.0);
            }
        }
    }
}

/// Query tile size (matches the AOT `dist_*_q256_*` artifacts).
pub const QUERY_TILE: usize = 256;
/// Corpus tile size (matches the AOT `dist_*_m2048` artifacts).
pub const CORPUS_TILE: usize = 2048;

pub struct BruteDbscan {
    pub eps: f32,
    pub min_pts: usize,
}

impl BruteDbscan {
    pub fn new(eps: f32, min_pts: usize) -> Self {
        BruteDbscan { eps, min_pts }
    }

    /// Neighbor lists within eps for all points (tile-blocked).
    fn neighbors(
        &self,
        xs: &[f32],
        n: usize,
        d: usize,
        engine: &mut dyn PairwiseDistance,
    ) -> Vec<Vec<u32>> {
        let eps2 = self.eps * self.eps;
        let mut nbrs: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut tile = vec![0.0f32; QUERY_TILE * CORPUS_TILE];
        let mut qi = 0;
        while qi < n {
            let nq = (n - qi).min(QUERY_TILE);
            let q = &xs[qi * d..(qi + nq) * d];
            let mut cj = 0;
            while cj < n {
                let nc = (n - cj).min(CORPUS_TILE);
                let c = &xs[cj * d..(cj + nc) * d];
                let out = &mut tile[..nq * nc];
                engine.dist2(q, nq, c, nc, d, out);
                for a in 0..nq {
                    let row = &out[a * nc..(a + 1) * nc];
                    let list = &mut nbrs[qi + a];
                    for (b, &v) in row.iter().enumerate() {
                        if v <= eps2 {
                            list.push((cj + b) as u32);
                        }
                    }
                }
                cj += nc;
            }
            qi += nq;
        }
        nbrs
    }

    /// Cluster `n` points; returns labels (−1 = noise).
    pub fn cluster(
        &self,
        xs: &[f32],
        n: usize,
        d: usize,
        engine: &mut dyn PairwiseDistance,
    ) -> Vec<i64> {
        let nbrs = self.neighbors(xs, n, d, engine);
        let is_core: Vec<bool> =
            nbrs.iter().map(|l| l.len() >= self.min_pts).collect();
        let mut labels = vec![-1i64; n];
        let mut cluster = 0i64;
        let mut queue = std::collections::VecDeque::new();
        for s in 0..n {
            if !is_core[s] || labels[s] != -1 {
                continue;
            }
            labels[s] = cluster;
            queue.push_back(s);
            while let Some(x) = queue.pop_front() {
                if !is_core[x] {
                    continue; // border: claimed but not expanded
                }
                for &y in &nbrs[x] {
                    let y = y as usize;
                    if labels[y] == -1 {
                        labels[y] = cluster;
                        if is_core[y] {
                            queue.push_back(y);
                        }
                    }
                }
            }
            cluster += 1;
        }
        labels
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{make_blobs, BlobsConfig};
    use crate::metrics::adjusted_rand_index;

    /// O(n²) literal reference (no tiling) for cross-checking the blocked
    /// implementation.
    fn naive_labels(xs: &[f32], n: usize, d: usize, eps: f32, k: usize) -> Vec<i64> {
        let eps2 = eps * eps;
        let dist2 = |a: usize, b: usize| -> f32 {
            (0..d).map(|j| (xs[a * d + j] - xs[b * d + j]).powi(2)).sum()
        };
        let nbrs: Vec<Vec<usize>> = (0..n)
            .map(|i| (0..n).filter(|&j| dist2(i, j) <= eps2).collect())
            .collect();
        let is_core: Vec<bool> = nbrs.iter().map(|l| l.len() >= k).collect();
        let mut labels = vec![-1i64; n];
        let mut cl = 0;
        for s in 0..n {
            if !is_core[s] || labels[s] != -1 {
                continue;
            }
            let mut stack = vec![s];
            labels[s] = cl;
            while let Some(x) = stack.pop() {
                if !is_core[x] {
                    continue;
                }
                for &y in &nbrs[x] {
                    if labels[y] == -1 {
                        labels[y] = cl;
                        if is_core[y] {
                            stack.push(y);
                        }
                    }
                }
            }
            cl += 1;
        }
        labels
    }

    #[test]
    fn matches_naive_reference() {
        use crate::util::proptest::{run_prop, Gen};
        run_prop("brute matches naive", 25, |g: &mut Gen| {
            let n = g.usize_in(5..=150);
            let d = g.usize_in(1..=4);
            let xs: Vec<f32> = (0..n * d)
                .map(|_| (g.f64_in(0.0, 4.0).floor() + g.f64_in(-0.15, 0.15)) as f32)
                .collect();
            let eps = g.f64_in(0.2, 0.8) as f32;
            let k = g.usize_in(2..=6);
            let got =
                BruteDbscan::new(eps, k).cluster(&xs, n, d, &mut NativeDistance);
            let want = naive_labels(&xs, n, d, eps, k);
            // identical partitions up to renaming + identical noise set
            assert_eq!(
                adjusted_rand_index(&want, &got),
                1.0,
                "partitions differ"
            );
            for i in 0..n {
                assert_eq!(got[i] == -1, want[i] == -1, "noise mismatch at {i}");
            }
        });
    }

    #[test]
    fn tile_boundaries_exact() {
        // n > QUERY_TILE forces multiple tiles
        let n = QUERY_TILE + 37;
        let xs: Vec<f32> = (0..n).map(|i| (i / 8) as f32 * 10.0).collect();
        let labels =
            BruteDbscan::new(0.5, 4).cluster(&xs, n, 1, &mut NativeDistance);
        let want = naive_labels(&xs, n, 1, 0.5, 4);
        assert_eq!(adjusted_rand_index(&want, &labels), 1.0);
    }

    #[test]
    fn blobs_quality() {
        let ds = make_blobs(
            &BlobsConfig {
                n: 600,
                dim: 3,
                clusters: 3,
                std: 0.25,
                center_box: 15.0,
                weights: vec![],
            },
            21,
        );
        let labels = BruteDbscan::new(1.0, 6).cluster(
            &ds.xs,
            ds.n(),
            ds.dim,
            &mut NativeDistance,
        );
        let ari = adjusted_rand_index(&ds.labels, &labels);
        assert!(ari > 0.98, "ARI {ari}");
    }
}
