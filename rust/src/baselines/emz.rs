//! EMZ: the static near-linear-time DBSCAN of Esfandiari–Mirrokni–Zhong
//! (AAAI 2021), as used for the paper's "EMZ" baseline.
//!
//! Faithful to the original: a **dedicated density hash** decides core
//! points (bucket size ≥ k), and `t` further hash functions provide the
//! connectivity graph (cores colliding anywhere are connected; non-core
//! points join the cluster of any core they collide with). Connected
//! components come from union-find. `O(t·d·n)` per run.
//!
//! In the paper's streaming comparison the whole computation is **re-run
//! from scratch after every batch** — that cost asymmetry against
//! `DynamicDbscan` is exactly what Table 2 / Figure 2(a) measure.

use rustc_hash::FxHashMap;

use crate::lsh::{BucketKey, GridHasher};

use super::unionfind::UnionFind;

#[derive(Clone, Debug)]
pub struct EmzConfig {
    pub k: usize,
    pub t: usize,
    pub eps: f32,
    pub dim: usize,
}

pub struct Emz {
    pub cfg: EmzConfig,
    /// t+1 hash functions: index 0 = density hash, 1..=t = connectivity.
    pub hasher: GridHasher,
}

/// Result of one static run.
pub struct EmzResult {
    /// cluster id per input point; −1 = noise
    pub labels: Vec<i64>,
    pub is_core: Vec<bool>,
    pub num_clusters: usize,
}

impl Emz {
    pub fn new(cfg: EmzConfig, seed: u64) -> Self {
        let hasher = GridHasher::new(cfg.t + 1, cfg.dim, cfg.eps, seed);
        Emz { cfg, hasher }
    }

    /// Hash a single point to its t+1 bucket keys (reused by the fixed-core
    /// variant and by streaming drivers that cache hashes).
    pub fn keys(&self, x: &[f32], scratch: &mut Vec<i32>) -> Vec<BucketKey> {
        self.hasher.keys(x, scratch)
    }

    /// Cluster `n` points (row-major `xs`, dim `cfg.dim`) from scratch.
    pub fn cluster(&self, xs: &[f32], n: usize) -> EmzResult {
        let d = self.cfg.dim;
        assert_eq!(xs.len(), n * d);
        let mut scratch = Vec::new();
        let keys: Vec<Vec<BucketKey>> = (0..n)
            .map(|i| self.keys(&xs[i * d..(i + 1) * d], &mut scratch))
            .collect();
        self.cluster_with_keys(&keys)
    }

    /// Cluster given precomputed per-point key vectors (len t+1 each).
    pub fn cluster_with_keys(&self, keys: &[Vec<BucketKey>]) -> EmzResult {
        let n = keys.len();
        let t = self.cfg.t;
        // density hash → core set
        let mut density: FxHashMap<BucketKey, u32> = FxHashMap::default();
        for k in keys {
            *density.entry(k[0]).or_insert(0) += 1;
        }
        let is_core: Vec<bool> = keys
            .iter()
            .map(|k| density[&k[0]] as usize >= self.cfg.k)
            .collect();
        // connectivity: union cores sharing any bucket of h_1..h_t
        let mut uf = UnionFind::new(n);
        let mut bucket_rep: FxHashMap<(usize, BucketKey), u32> = FxHashMap::default();
        for (i, k) in keys.iter().enumerate() {
            if !is_core[i] {
                continue;
            }
            for (j, &kj) in k.iter().enumerate().skip(1).take(t) {
                match bucket_rep.entry((j, kj)) {
                    std::collections::hash_map::Entry::Occupied(e) => {
                        uf.union(i, *e.get() as usize);
                    }
                    std::collections::hash_map::Entry::Vacant(e) => {
                        e.insert(i as u32);
                    }
                }
            }
        }
        // labels: dense ids over core components; non-core joins the first
        // core bucket it collides with, else noise
        let mut root_label: FxHashMap<usize, i64> = FxHashMap::default();
        let mut labels = vec![-1i64; n];
        for i in 0..n {
            if is_core[i] {
                let r = uf.find(i);
                let next = root_label.len() as i64;
                labels[i] = *root_label.entry(r).or_insert(next);
            }
        }
        for i in 0..n {
            if !is_core[i] {
                for (j, &kj) in keys[i].iter().enumerate().skip(1).take(t) {
                    if let Some(&rep) = bucket_rep.get(&(j, kj)) {
                        labels[i] = labels[uf.find(rep as usize)];
                        break;
                    }
                }
            }
        }
        let num_clusters = root_label.len();
        EmzResult { labels, is_core, num_clusters }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{make_blobs, BlobsConfig};
    use crate::metrics::adjusted_rand_index;

    #[test]
    fn separable_blobs_near_perfect() {
        let ds = make_blobs(
            &BlobsConfig {
                n: 1200,
                dim: 4,
                clusters: 3,
                std: 0.3,
                center_box: 20.0,
                weights: vec![],
            },
            5,
        );
        let emz = Emz::new(EmzConfig { k: 8, t: 10, eps: 0.75, dim: 4 }, 17);
        let r = emz.cluster(&ds.xs, ds.n());
        let ari = adjusted_rand_index(&ds.labels, &r.labels);
        assert!(ari > 0.98, "ARI {ari}");
        assert!(r.num_clusters >= 3);
    }

    #[test]
    fn sparse_data_all_noise() {
        let xs: Vec<f32> = (0..40).map(|i| i as f32 * 100.0).collect();
        let emz = Emz::new(EmzConfig { k: 3, t: 4, eps: 0.5, dim: 1 }, 3);
        let r = emz.cluster(&xs, 40);
        assert!(r.labels.iter().all(|&l| l == -1));
        assert_eq!(r.num_clusters, 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = make_blobs(
            &BlobsConfig { n: 300, dim: 3, clusters: 2, ..Default::default() },
            9,
        );
        let a = Emz::new(EmzConfig { k: 5, t: 5, eps: 0.75, dim: 3 }, 1)
            .cluster(&ds.xs, ds.n());
        let b = Emz::new(EmzConfig { k: 5, t: 5, eps: 0.75, dim: 3 }, 1)
            .cluster(&ds.xs, ds.n());
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn core_iff_density_bucket_large() {
        // 6 coincident points with k=5 -> all core; far singleton non-core
        let mut xs = vec![0.0f32; 6];
        xs.push(1000.0);
        let emz = Emz::new(EmzConfig { k: 5, t: 3, eps: 0.5, dim: 1 }, 7);
        let r = emz.cluster(&xs, 7);
        assert!(r.is_core[..6].iter().all(|&c| c));
        assert!(!r.is_core[6]);
        assert_eq!(r.labels[6], -1);
    }
}
