//! EMZFixedCore (paper §5, "Comparison with a fixed core point set"):
//! run EMZ on the initial batch, then **freeze the core set** — every
//! subsequent point is treated as non-core and assigned to the cluster of
//! the first frozen core it collides with under any hash function.
//!
//! Cheap (O(t·d) per arrival, no graph updates) but, as Figure 2(c) shows,
//! it cannot represent clusters that appear after the initial batch —
//! the failure mode `DynamicDbscan` fixes.

use rustc_hash::FxHashMap;

use crate::lsh::BucketKey;

use super::emz::{Emz, EmzConfig, EmzResult};

pub struct EmzFixedCore {
    emz: Emz,
    /// (hash index 1..=t, bucket key) → cluster label of a core in there
    core_buckets: FxHashMap<(usize, BucketKey), i64>,
    /// labels of the initial batch
    pub initial_labels: Vec<i64>,
    pub num_clusters: usize,
    scratch: Vec<i32>,
}

impl EmzFixedCore {
    /// Fit on the initial batch (row-major xs, n points).
    pub fn fit_initial(cfg: EmzConfig, seed: u64, xs: &[f32], n: usize) -> Self {
        let emz = Emz::new(cfg, seed);
        let EmzResult { labels, is_core, num_clusters } = emz.cluster(xs, n);
        let d = emz.cfg.dim;
        let mut core_buckets = FxHashMap::default();
        let mut scratch = Vec::new();
        for i in 0..n {
            if is_core[i] {
                let keys = emz.keys(&xs[i * d..(i + 1) * d], &mut scratch);
                for (j, &kj) in keys.iter().enumerate().skip(1) {
                    core_buckets.entry((j, kj)).or_insert(labels[i]);
                }
            }
        }
        EmzFixedCore {
            emz,
            core_buckets,
            initial_labels: labels,
            num_clusters,
            scratch: Vec::new(),
        }
    }

    /// Label one arriving point: the cluster of the first frozen core it
    /// collides with, else noise (−1).
    pub fn assign(&mut self, x: &[f32]) -> i64 {
        let mut scratch = std::mem::take(&mut self.scratch);
        let keys = self.emz.keys(x, &mut scratch);
        self.scratch = scratch;
        for (j, &kj) in keys.iter().enumerate().skip(1) {
            if let Some(&l) = self.core_buckets.get(&(j, kj)) {
                return l;
            }
        }
        -1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{make_blobs, BlobsConfig};
    use crate::metrics::adjusted_rand_index;

    fn cfg(dim: usize) -> EmzConfig {
        EmzConfig { k: 8, t: 10, eps: 0.75, dim }
    }

    #[test]
    fn random_order_works_cluster_order_fails() {
        // The Figure-2 phenomenon in miniature: with random arrivals the
        // initial batch samples every cluster, so assignments stay good;
        // cluster-by-cluster arrivals leave later clusters unrepresented.
        let ds = make_blobs(
            &BlobsConfig {
                n: 3000,
                dim: 4,
                clusters: 5,
                std: 0.3,
                center_box: 20.0,
                weights: vec![],
            },
            3,
        );
        let n0 = 600;
        let d = ds.dim;

        // random order: initial batch = random sample
        let mut order: Vec<usize> = (0..ds.n()).collect();
        crate::util::rng::Rng::new(5).shuffle(&mut order);
        let mut xs0 = Vec::new();
        for &i in &order[..n0] {
            xs0.extend_from_slice(ds.point(i));
        }
        let mut fc = EmzFixedCore::fit_initial(cfg(d), 11, &xs0, n0);
        let mut pred = vec![0i64; ds.n()];
        let mut truth = vec![0i64; ds.n()];
        for (pos, &i) in order.iter().enumerate() {
            truth[pos] = ds.labels[i];
            pred[pos] = if pos < n0 {
                fc.initial_labels[pos]
            } else {
                fc.assign(ds.point(i))
            };
        }
        let ari_random = adjusted_rand_index(&truth, &pred);

        // cluster-by-cluster: initial batch sees only cluster 0
        let mut order2: Vec<usize> = (0..ds.n()).collect();
        order2.sort_by_key(|&i| ds.labels[i]);
        let mut xs0b = Vec::new();
        for &i in &order2[..n0] {
            xs0b.extend_from_slice(ds.point(i));
        }
        let mut fc2 = EmzFixedCore::fit_initial(cfg(d), 11, &xs0b, n0);
        let mut pred2 = vec![0i64; ds.n()];
        let mut truth2 = vec![0i64; ds.n()];
        for (pos, &i) in order2.iter().enumerate() {
            truth2[pos] = ds.labels[i];
            pred2[pos] = if pos < n0 {
                fc2.initial_labels[pos]
            } else {
                fc2.assign(ds.point(i))
            };
        }
        let ari_cluster = adjusted_rand_index(&truth2, &pred2);

        assert!(ari_random > 0.9, "random-order ARI {ari_random}");
        assert!(
            ari_cluster < ari_random - 0.2,
            "cluster-order ARI {ari_cluster} should collapse vs {ari_random}"
        );
    }

    #[test]
    fn unseen_region_is_noise() {
        let xs0: Vec<f32> = (0..20).map(|i| (i % 5) as f32 * 0.01).collect();
        let mut fc = EmzFixedCore::fit_initial(
            EmzConfig { k: 5, t: 4, eps: 0.5, dim: 1 },
            1,
            &xs0,
            20,
        );
        assert_eq!(fc.assign(&[500.0]), -1);
        assert!(fc.assign(&[0.02]) >= 0);
    }
}
