//! Baseline algorithms the paper compares against (§5):
//!
//! * [`emz`] — the static near-linear-time DBSCAN of Esfandiari, Mirrokni &
//!   Zhong (AAAI'21), re-run from scratch after every batch (the paper's
//!   "EMZ" rows/curves);
//! * [`emz_fixed_core`] — the paper's own EMZFixedCore variant: EMZ on the
//!   first batch, core set frozen afterwards;
//! * [`brute`] — exact DBSCAN with sklearn semantics (the paper's "Sklearn"
//!   rows), range queries via pairwise-distance tiles (native or the AOT
//!   Pallas artifact);
//! * [`unionfind`] — shared connectivity substrate.

pub mod brute;
pub mod emz;
pub mod emz_fixed_core;
pub mod unionfind;
