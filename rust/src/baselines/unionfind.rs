//! Union-Find (disjoint sets) with path halving and union by size.

pub struct UnionFind {
    parent: Vec<u32>,
    size: Vec<u32>,
    components: usize,
}

impl UnionFind {
    pub fn new(n: usize) -> Self {
        UnionFind {
            parent: (0..n as u32).collect(),
            size: vec![1; n],
            components: n,
        }
    }

    pub fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] as usize != x {
            let gp = self.parent[self.parent[x] as usize];
            self.parent[x] = gp; // path halving
            x = gp as usize;
        }
        x
    }

    /// Returns true if the two sets were merged (false if already joined).
    pub fn union(&mut self, a: usize, b: usize) -> bool {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        if self.size[ra] < self.size[rb] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb] = ra as u32;
        self.size[ra] += self.size[rb];
        self.components -= 1;
        true
    }

    pub fn same(&mut self, a: usize, b: usize) -> bool {
        self.find(a) == self.find(b)
    }

    pub fn component_size(&mut self, a: usize) -> usize {
        let r = self.find(a);
        self.size[r] as usize
    }

    pub fn num_components(&self) -> usize {
        self.components
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_unions() {
        let mut uf = UnionFind::new(6);
        assert!(uf.union(0, 1));
        assert!(uf.union(2, 3));
        assert!(!uf.union(1, 0));
        assert!(uf.union(1, 2));
        assert!(uf.same(0, 3));
        assert!(!uf.same(0, 4));
        assert_eq!(uf.component_size(3), 4);
        assert_eq!(uf.num_components(), 3); // {0,1,2,3}, {4}, {5}
    }

    #[test]
    fn transitive_chain() {
        let n = 1000;
        let mut uf = UnionFind::new(n);
        for i in 1..n {
            uf.union(i - 1, i);
        }
        assert_eq!(uf.num_components(), 1);
        assert!(uf.same(0, n - 1));
        assert_eq!(uf.component_size(42), n);
    }
}
