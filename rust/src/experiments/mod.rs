//! Paper-experiment runners — the code behind every table and figure
//! (shared by `cargo bench` targets and the CLI).
//!
//! * [`table2`] — Table 2: time / ARI / NMI on the six Table-1 datasets for
//!   DynamicDBSCAN, EMZ (re-run per batch) and Sklearn-equivalent exact
//!   DBSCAN.
//! * [`fig2`] — Figure 2 (a) running time, (b) ARI under random arrivals,
//!   (c) ARI under cluster-by-cluster arrivals, on the blobs dataset, for
//!   DynamicDBSCAN, EMZ, EMZFixedCore and Sklearn-equivalent.
//!
//! Measurement semantics (documented in EXPERIMENTS.md): streaming
//! algorithms are timed over the entire update stream (batch = 1000, the
//! paper's setting); the exact-DBSCAN baseline is timed for one full
//! clustering of the final dataset. Quality is ARI/NMI of the final labels
//! against ground truth, mean ± stderr over independent seeds.

pub mod fig2;
pub mod table2;

/// Paper hyper-parameters (§5): k = 10, t = 10, ε = 0.75, batch = 1000.
pub const PAPER_K: usize = 10;
pub const PAPER_T: usize = 10;
pub const PAPER_EPS: f32 = 0.75;
pub const PAPER_BATCH: usize = 1000;

/// Scale factor for dataset sizes: `FULL=1` reproduces paper sizes;
/// otherwise `SCALE` (default 0.05) shrinks n for tractable CI runs.
pub fn env_scale() -> f64 {
    if std::env::var("FULL").map(|v| v == "1").unwrap_or(false) {
        return 1.0;
    }
    std::env::var("SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.05)
}

/// Number of independent runs (paper: 10). Default 3 scaled.
pub fn env_runs() -> usize {
    std::env::var("RUNS").ok().and_then(|s| s.parse().ok()).unwrap_or(3)
}
