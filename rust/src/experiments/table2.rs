//! Table 2: per-dataset Time / ARI / NMI for DyDBSCAN, EMZ and Sklearn.

use anyhow::Result;

use crate::baselines::brute::{BruteDbscan, NativeDistance};
use crate::baselines::emz::{Emz, EmzConfig};
use crate::bench_harness::Table;
use crate::coordinator::driver::{final_quality, stream_dataset, EngineKind};
use crate::data::stream::{insertion_order, Order};
use crate::data::synth::{load, PaperDataset};
use crate::dbscan::DbscanConfig;
use crate::metrics::ari_nmi;
use crate::util::stats::Welford;

use super::{PAPER_BATCH, PAPER_EPS, PAPER_K, PAPER_T};

/// Per-algorithm outcome of one dataset row.
#[derive(Clone, Debug, Default)]
pub struct Cell {
    pub time: Welford,
    pub ari: Welford,
    pub nmi: Welford,
}

impl Cell {
    fn fmt(&self) -> (String, String, String) {
        (
            format!("{:.2}±{:.3}", self.time.mean(), self.time.stderr()),
            format!("{:.2}±{:.3}", self.ari.mean(), self.ari.stderr()),
            format!("{:.2}±{:.3}", self.nmi.mean(), self.nmi.stderr()),
        )
    }
}

pub struct Row {
    pub dataset: PaperDataset,
    pub n: usize,
    pub dyn_: Cell,
    pub emz: Cell,
    pub sklearn: Option<Cell>,
}

/// Run one dataset × one seed for all three algorithms.
/// `run_sklearn=false` mirrors the paper skipping sklearn on the largest
/// datasets (memory), and keeps scaled runs fast.
pub fn run_dataset(
    which: PaperDataset,
    scale: f64,
    seed: u64,
    engine: EngineKind,
    run_sklearn: bool,
) -> Result<(f64, f64, f64, f64, f64, f64, Option<(f64, f64, f64)>, usize)> {
    let ds = load(which, scale, seed);
    let dim = ds.dim;
    let cfg = DbscanConfig {
        k: PAPER_K,
        t: PAPER_T,
        eps: PAPER_EPS,
        dim,
        ..Default::default()
    };

    // --- DynamicDBSCAN: stream through the coordinator ---
    let t0 = std::time::Instant::now();
    let out = stream_dataset(&ds, cfg, Order::Random, PAPER_BATCH, 0, seed, engine)?;
    let dyn_time = t0.elapsed().as_secs_f64();
    let (dyn_ari, dyn_nmi) = final_quality(&ds, &out);

    // --- EMZ: re-run the static algorithm after every batch ---
    let emz = Emz::new(
        EmzConfig { k: PAPER_K, t: PAPER_T, eps: PAPER_EPS, dim },
        seed,
    );
    let order = insertion_order(&ds, Order::Random, seed);
    let t0 = std::time::Instant::now();
    let mut xs_sofar: Vec<f32> = Vec::with_capacity(ds.xs.len());
    let mut labels_last = Vec::new();
    let mut seen = 0usize;
    for chunk in order.chunks(PAPER_BATCH) {
        for &i in chunk {
            xs_sofar.extend_from_slice(ds.point(i));
            seen += 1;
        }
        let r = emz.cluster(&xs_sofar, seen);
        labels_last = r.labels;
    }
    let emz_time = t0.elapsed().as_secs_f64();
    let truth: Vec<i64> = order.iter().map(|&i| ds.labels[i]).collect();
    let (emz_ari, emz_nmi) = ari_nmi(&truth, &labels_last);

    // --- Sklearn-equivalent exact DBSCAN: one full clustering ---
    let sk = if run_sklearn {
        let t0 = std::time::Instant::now();
        let labels = BruteDbscan::new(PAPER_EPS, PAPER_K).cluster(
            &ds.xs,
            ds.n(),
            dim,
            &mut NativeDistance,
        );
        let sk_time = t0.elapsed().as_secs_f64();
        let (a, m) = ari_nmi(&ds.labels, &labels);
        Some((sk_time, a, m))
    } else {
        None
    };

    Ok((dyn_time, dyn_ari, dyn_nmi, emz_time, emz_ari, emz_nmi, sk, ds.n()))
}

/// Full Table 2 over the requested datasets.
pub fn run_table2(
    datasets: &[PaperDataset],
    scale: f64,
    runs: usize,
    engine: EngineKind,
) -> Result<(Table, Vec<Row>)> {
    let mut rows = Vec::new();
    for &which in datasets {
        // the paper could not run sklearn on the two biggest datasets
        // (memory); we skip it whenever the scaled n crosses the O(n²)
        // practicality wall, which reproduces the same "-" cells.
        let n_scaled = (which.shape().0 as f64 * scale) as usize;
        let run_sklearn = n_scaled <= 30_000;
        let mut row = Row {
            dataset: which,
            n: 0,
            dyn_: Cell::default(),
            emz: Cell::default(),
            sklearn: run_sklearn.then(Cell::default),
        };
        for r in 0..runs {
            let seed = 1000 + r as u64;
            let (dt, da, dn, et, ea, en, sk, n) =
                run_dataset(which, scale, seed, engine, run_sklearn)?;
            row.n = n;
            row.dyn_.time.push(dt);
            row.dyn_.ari.push(da);
            row.dyn_.nmi.push(dn);
            row.emz.time.push(et);
            row.emz.ari.push(ea);
            row.emz.nmi.push(en);
            if let (Some(cell), Some((st, sa, sn))) = (row.sklearn.as_mut(), sk) {
                cell.time.push(st);
                cell.ari.push(sa);
                cell.nmi.push(sn);
            }
        }
        rows.push(row);
    }

    let mut table = Table::new(
        &format!("Table 2 (scale={:.2}, runs={})", rows_scale(scale), runs),
        &["dataset", "n", "metric", "DyDBSCAN", "EMZ", "SKLEARN"],
    );
    for row in &rows {
        let d = row.dyn_.fmt();
        let e = row.emz.fmt();
        let s = row
            .sklearn
            .as_ref()
            .map(|c| c.fmt())
            .unwrap_or(("-".into(), "-".into(), "-".into()));
        let name = row.dataset.name();
        table.row(vec![
            name.into(),
            row.n.to_string(),
            "Time".into(),
            d.0,
            e.0,
            s.0,
        ]);
        table.row(vec!["".into(), "".into(), "ARI".into(), d.1, e.1, s.1]);
        table.row(vec!["".into(), "".into(), "NMI".into(), d.2, e.2, s.2]);
    }
    Ok((table, rows))
}

fn rows_scale(s: f64) -> f64 {
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_table2_runs() {
        // smoke at 1% scale, letter only, 1 run — exercises all 3 algorithms
        let (table, rows) = run_table2(
            &[PaperDataset::Letter],
            0.01,
            1,
            EngineKind::Native,
        )
        .unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].dyn_.time.mean() > 0.0);
        assert!(rows[0].emz.time.mean() > 0.0);
        assert!(rows[0].sklearn.is_some());
        let s = table.render();
        assert!(s.contains("letter"));
        assert!(s.contains("ARI"));
    }
}
