//! Figure 2: blobs-dataset comparison of DynamicDBSCAN, EMZ, EMZFixedCore
//! (and the exact baseline at small scales).
//!
//! (a) cumulative running time after each batch;
//! (b) ARI of the full current labeling after each batch, random arrivals;
//! (c) same with cluster-by-cluster arrivals (the EMZFixedCore failure).

use anyhow::Result;

use crate::baselines::brute::{BruteDbscan, NativeDistance};
use crate::baselines::emz::{Emz, EmzConfig};
use crate::baselines::emz_fixed_core::EmzFixedCore;
use crate::bench_harness::Series;
use crate::data::stream::{insertion_order, Order};
use crate::data::synth::{load, PaperDataset};
use crate::dbscan::{DbscanConfig, DynamicDbscan};
use crate::metrics::adjusted_rand_index;

use super::{PAPER_BATCH, PAPER_EPS, PAPER_K, PAPER_T};

/// Which panel of Figure 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Panel {
    /// (a) running time vs stream position (random order)
    Time,
    /// (b) ARI vs stream position, random order
    AriRandom,
    /// (c) ARI vs stream position, cluster-by-cluster order
    AriClustered,
}

impl Panel {
    pub fn from_name(s: &str) -> Option<Panel> {
        match s {
            "a" | "time" => Some(Panel::Time),
            "b" | "ari-random" => Some(Panel::AriRandom),
            "c" | "ari-clustered" => Some(Panel::AriClustered),
            _ => None,
        }
    }
}

/// Run one panel; `include_exact` adds the O(n²) baseline (only sensible at
/// small scale). Returns a printable/plottable series.
pub fn run_fig2(panel: Panel, scale: f64, seed: u64, include_exact: bool) -> Result<Series> {
    let ds = load(PaperDataset::Blobs, scale, seed);
    let dim = ds.dim;
    let order_kind = match panel {
        Panel::AriClustered => Order::ClusterByCluster,
        _ => Order::Random,
    };
    let order = insertion_order(&ds, order_kind, seed);
    let batch = PAPER_BATCH.min((order.len() / 10).max(1));

    let mut names = vec!["DyDBSCAN", "EMZ", "EMZFixedCore"];
    if include_exact {
        names.push("SKLEARN");
    }
    let (title, x_name) = match panel {
        Panel::Time => ("Figure 2(a): cumulative seconds vs points", "points"),
        Panel::AriRandom => ("Figure 2(b): ARI vs points (random order)", "points"),
        Panel::AriClustered => {
            ("Figure 2(c): ARI vs points (cluster-by-cluster)", "points")
        }
    };
    let mut series = Series::new(title, x_name, &names);

    // --- DynamicDBSCAN ---
    let cfg = DbscanConfig {
        k: PAPER_K,
        t: PAPER_T,
        eps: PAPER_EPS,
        dim,
        ..Default::default()
    };
    let mut db = DynamicDbscan::new(cfg, seed);
    let mut dyn_ids: Vec<u64> = Vec::with_capacity(order.len());
    let mut dyn_cum = Vec::new();
    let mut dyn_ari = Vec::new();
    let mut cum = 0.0;
    for chunk in order.chunks(batch) {
        let t0 = std::time::Instant::now();
        for &i in chunk {
            dyn_ids.push(db.add_point(ds.point(i)));
        }
        cum += t0.elapsed().as_secs_f64();
        dyn_cum.push(cum);
        let pred = db.labels_for(&dyn_ids);
        let truth: Vec<i64> =
            order[..dyn_ids.len()].iter().map(|&i| ds.labels[i]).collect();
        dyn_ari.push(adjusted_rand_index(&truth, &pred));
    }

    // --- EMZ (re-run per batch) ---
    let emz = Emz::new(EmzConfig { k: PAPER_K, t: PAPER_T, eps: PAPER_EPS, dim }, seed);
    let mut emz_cum = Vec::new();
    let mut emz_ari = Vec::new();
    let mut xs: Vec<f32> = Vec::new();
    let mut n = 0;
    cum = 0.0;
    for chunk in order.chunks(batch) {
        let t0 = std::time::Instant::now();
        for &i in chunk {
            xs.extend_from_slice(ds.point(i));
            n += 1;
        }
        let r = emz.cluster(&xs, n);
        cum += t0.elapsed().as_secs_f64();
        emz_cum.push(cum);
        let truth: Vec<i64> = order[..n].iter().map(|&i| ds.labels[i]).collect();
        emz_ari.push(adjusted_rand_index(&truth, &r.labels));
    }

    // --- EMZFixedCore ---
    let mut fc_cum = Vec::new();
    let mut fc_ari = Vec::new();
    let first: Vec<f32> = order[..batch.min(order.len())]
        .iter()
        .flat_map(|&i| ds.point(i).iter().copied())
        .collect();
    let t0 = std::time::Instant::now();
    let mut fc = EmzFixedCore::fit_initial(
        EmzConfig { k: PAPER_K, t: PAPER_T, eps: PAPER_EPS, dim },
        seed,
        &first,
        batch.min(order.len()),
    );
    cum = t0.elapsed().as_secs_f64();
    let mut fc_labels: Vec<i64> = fc.initial_labels.clone();
    fc_cum.push(cum);
    {
        let truth: Vec<i64> =
            order[..fc_labels.len()].iter().map(|&i| ds.labels[i]).collect();
        fc_ari.push(adjusted_rand_index(&truth, &fc_labels));
    }
    for chunk in order.chunks(batch).skip(1) {
        let t0 = std::time::Instant::now();
        for &i in chunk {
            fc_labels.push(fc.assign(ds.point(i)));
        }
        cum += t0.elapsed().as_secs_f64();
        fc_cum.push(cum);
        let truth: Vec<i64> =
            order[..fc_labels.len()].iter().map(|&i| ds.labels[i]).collect();
        fc_ari.push(adjusted_rand_index(&truth, &fc_labels));
    }

    // --- exact baseline (optional; re-clusters per batch like sklearn
    // would have to in a dynamic setting) ---
    let (mut sk_cum, mut sk_ari) = (Vec::new(), Vec::new());
    if include_exact {
        let brute = BruteDbscan::new(PAPER_EPS, PAPER_K);
        let mut xs: Vec<f32> = Vec::new();
        let mut n = 0;
        cum = 0.0;
        for chunk in order.chunks(batch) {
            let t0 = std::time::Instant::now();
            for &i in chunk {
                xs.extend_from_slice(ds.point(i));
                n += 1;
            }
            let labels = brute.cluster(&xs, n, dim, &mut NativeDistance);
            cum += t0.elapsed().as_secs_f64();
            sk_cum.push(cum);
            let truth: Vec<i64> = order[..n].iter().map(|&i| ds.labels[i]).collect();
            sk_ari.push(adjusted_rand_index(&truth, &labels));
        }
    }

    let nb = dyn_cum.len();
    for b in 0..nb {
        let x = ((b + 1) * batch).min(order.len()) as f64;
        let mut vals = match panel {
            Panel::Time => vec![dyn_cum[b], emz_cum[b], fc_cum[b]],
            _ => vec![dyn_ari[b], emz_ari[b], fc_ari[b]],
        };
        if include_exact {
            vals.push(match panel {
                Panel::Time => sk_cum[b],
                _ => sk_ari[b],
            });
        }
        series.push(x, &vals);
    }
    Ok(series)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panel_parsing() {
        assert_eq!(Panel::from_name("a"), Some(Panel::Time));
        assert_eq!(Panel::from_name("ari-random"), Some(Panel::AriRandom));
        assert_eq!(Panel::from_name("z"), None);
    }

    #[test]
    fn fig2b_small_scale() {
        let s = run_fig2(Panel::AriRandom, 0.01, 4, false).unwrap();
        assert_eq!(s.names.len(), 3);
        assert!(!s.xs.is_empty());
        // DyDBSCAN final ARI should be high on blobs
        let last = *s.ys[0].last().unwrap();
        assert!(last > 0.9, "DyDBSCAN ARI {last}");
    }

    #[test]
    fn fig2c_fixedcore_collapses() {
        let s = run_fig2(Panel::AriClustered, 0.02, 4, false).unwrap();
        let dyn_final = *s.ys[0].last().unwrap();
        let fc_final = *s.ys[2].last().unwrap();
        assert!(
            fc_final < dyn_final - 0.2,
            "EMZFixedCore {fc_final} should collapse vs DyDBSCAN {dyn_final}"
        );
    }
}
