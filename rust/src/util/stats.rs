//! Streaming statistics: Welford mean/variance, log-bucketed latency
//! histograms (HdrHistogram-lite, plus a lock-free striped variant for
//! concurrent recorders) and simple run summaries with standard errors —
//! shared by the coordinator's metrics endpoint, the live `obs` metrics
//! registry and the bench harness.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Streaming mean / variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Standard error of the mean.
    pub fn stderr(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }
}

/// Log₂-bucketed latency histogram with sub-bucket linear resolution.
///
/// Records `u64` nanosecond values in `O(1)`; quantiles are approximate
/// (≤ ~3% relative error with 16 sub-buckets), which is plenty for p50/p99
/// reporting in the coordinator.
#[derive(Clone, Debug)]
pub struct LatencyHisto {
    /// counts[b][s]: bucket b covers [2^b, 2^(b+1)), split into SUB linear
    /// sub-buckets.
    counts: Vec<[u64; Self::SUB]>,
    total: u64,
    max: u64,
    min: u64,
    sum: u128,
}

impl Default for LatencyHisto {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHisto {
    const SUB: usize = 16;
    const BUCKETS: usize = 64;

    pub fn new() -> Self {
        LatencyHisto {
            counts: vec![[0u64; Self::SUB]; Self::BUCKETS],
            total: 0,
            max: 0,
            min: u64::MAX,
            sum: 0,
        }
    }

    #[inline]
    fn slot(v: u64) -> (usize, usize) {
        if v < Self::SUB as u64 {
            return (0, v as usize % Self::SUB);
        }
        let b = 63 - v.leading_zeros() as usize;
        let sub = ((v - (1u64 << b)) * Self::SUB as u64 >> b) as usize;
        (b, sub.min(Self::SUB - 1))
    }

    pub fn record(&mut self, v: u64) {
        let (b, s) = Self::slot(v);
        self.counts[b][s] += 1;
        self.total += 1;
        self.max = self.max.max(v);
        self.min = self.min.min(v);
        self.sum += v as u128;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Approximate quantile (q in [0,1]): midpoint of the containing slot.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.total as f64).ceil() as u64;
        let mut seen = 0u64;
        for b in 0..Self::BUCKETS {
            for s in 0..Self::SUB {
                let c = self.counts[b][s];
                if c == 0 {
                    continue;
                }
                seen += c;
                if seen >= target.max(1) {
                    let lo = if b == 0 {
                        s as u64
                    } else {
                        (1u64 << b) + ((s as u64) << b) / Self::SUB as u64
                    };
                    let hi = if b == 0 {
                        s as u64 + 1
                    } else {
                        (1u64 << b) + (((s + 1) as u64) << b) / Self::SUB as u64
                    };
                    return (lo + hi) / 2;
                }
            }
        }
        self.max
    }

    pub fn merge(&mut self, other: &LatencyHisto) {
        for b in 0..Self::BUCKETS {
            for s in 0..Self::SUB {
                self.counts[b][s] += other.counts[b][s];
            }
        }
        self.total += other.total;
        self.max = self.max.max(other.max);
        self.min = self.min.min(other.min);
        self.sum += other.sum;
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}ns p50={} p90={} p99={} max={}",
            self.total,
            self.mean(),
            self.quantile(0.5),
            self.quantile(0.9),
            self.quantile(0.99),
            self.max()
        )
    }
}

/// Write stripes in [`AtomicHisto`] — enough that a handful of shard
/// worker threads rarely share a counter cache line.
const STRIPES: usize = 8;

/// One stripe of atomic bucket counters. Each stripe's counter block is a
/// separate heap allocation, so writers pinned to different stripes never
/// touch the same cache lines.
struct Stripe {
    /// flattened `[bucket][sub]` counts (see [`LatencyHisto`])
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    /// nanosecond sum; `u64` holds > 500 years of accumulated latency
    sum: AtomicU64,
}

impl Stripe {
    fn new() -> Self {
        Stripe {
            counts: (0..LatencyHisto::BUCKETS * LatencyHisto::SUB)
                .map(|_| AtomicU64::new(0))
                .collect(),
            total: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

/// Lock-free multi-producer latency histogram with the exact bucket layout
/// of [`LatencyHisto`], striped so concurrent recorders (shard workers)
/// spread across independent counter blocks. All updates are `Relaxed`
/// single-counter increments; [`AtomicHisto::snapshot`] folds the stripes
/// into a plain [`LatencyHisto`] for quantile/summary queries, so a
/// mid-run reader sees live per-op p50/p99 without stopping the writers.
pub struct AtomicHisto {
    stripes: Vec<Stripe>,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for AtomicHisto {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicHisto {
    pub fn new() -> Self {
        AtomicHisto {
            stripes: (0..STRIPES).map(|_| Stripe::new()).collect(),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Stable per-thread stripe assignment (round-robin over first use),
    /// so a worker thread always writes the same counter block.
    #[inline]
    fn stripe_ix() -> usize {
        thread_local! {
            static STRIPE: Cell<usize> = Cell::new(usize::MAX);
        }
        static NEXT: AtomicUsize = AtomicUsize::new(0);
        STRIPE.with(|s| {
            let mut ix = s.get();
            if ix == usize::MAX {
                ix = NEXT.fetch_add(1, Ordering::Relaxed) % STRIPES;
                s.set(ix);
            }
            ix
        })
    }

    /// Record a nanosecond value — `O(1)`, wait-free, callable from any
    /// thread through a shared reference.
    pub fn record(&self, v: u64) {
        let (b, s) = LatencyHisto::slot(v);
        let st = &self.stripes[Self::stripe_ix()];
        st.counts[b * LatencyHisto::SUB + s].fetch_add(1, Ordering::Relaxed);
        st.total.fetch_add(1, Ordering::Relaxed);
        st.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Total recorded samples across every stripe.
    pub fn count(&self) -> u64 {
        self.stripes.iter().map(|s| s.total.load(Ordering::Relaxed)).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Merge every stripe into a plain [`LatencyHisto`]. Recorders may
    /// land counts mid-merge; the result is a point-in-time view whose
    /// per-slot counts are each individually exact, which is all the
    /// quantile reporting needs. With no concurrent writers the snapshot
    /// is bit-identical to recording the same values into a single
    /// [`LatencyHisto`].
    pub fn snapshot(&self) -> LatencyHisto {
        let mut h = LatencyHisto::new();
        for st in &self.stripes {
            for b in 0..LatencyHisto::BUCKETS {
                for s in 0..LatencyHisto::SUB {
                    h.counts[b][s] +=
                        st.counts[b * LatencyHisto::SUB + s].load(Ordering::Relaxed);
                }
            }
            h.total += st.total.load(Ordering::Relaxed);
            h.sum += st.sum.load(Ordering::Relaxed) as u128;
        }
        h.min = self.min.load(Ordering::Relaxed);
        h.max = self.max.load(Ordering::Relaxed);
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn histo_quantiles_on_uniform() {
        let mut h = LatencyHisto::new();
        let mut r = Rng::new(1);
        for _ in 0..200_000 {
            h.record(r.below(1_000_000));
        }
        let p50 = h.quantile(0.5) as f64;
        let p99 = h.quantile(0.99) as f64;
        assert!((p50 - 500_000.0).abs() / 500_000.0 < 0.08, "p50={p50}");
        assert!((p99 - 990_000.0).abs() / 990_000.0 < 0.08, "p99={p99}");
        assert!((h.mean() - 500_000.0).abs() / 500_000.0 < 0.02);
    }

    #[test]
    fn histo_exact_small_values() {
        let mut h = LatencyHisto::new();
        for v in [3u64, 3, 3, 10] {
            h.record(v);
        }
        assert_eq!(h.min(), 3);
        assert_eq!(h.max(), 10);
        assert_eq!(h.quantile(0.5), 3);
    }

    #[test]
    fn atomic_histo_snapshot_matches_sequential() {
        let a = AtomicHisto::new();
        let mut h = LatencyHisto::new();
        let mut r = Rng::new(7);
        for _ in 0..50_000 {
            let v = r.below(2_000_000);
            a.record(v);
            h.record(v);
        }
        let snap = a.snapshot();
        assert_eq!(snap.count(), h.count());
        assert_eq!(snap.min(), h.min());
        assert_eq!(snap.max(), h.max());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(snap.quantile(q), h.quantile(q), "q={q}");
        }
        assert!((snap.mean() - h.mean()).abs() < 1e-9);
    }

    #[test]
    fn atomic_histo_empty_snapshot() {
        let a = AtomicHisto::new();
        assert!(a.is_empty());
        let snap = a.snapshot();
        assert_eq!(snap.count(), 0);
        assert_eq!(snap.min(), 0);
        assert_eq!(snap.quantile(0.5), 0);
    }

    #[test]
    fn histo_merge() {
        let mut a = LatencyHisto::new();
        let mut b = LatencyHisto::new();
        for v in 0..1000u64 {
            a.record(v);
            b.record(v + 1000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 2000);
        assert_eq!(a.max(), 1999);
        let p50 = a.quantile(0.5);
        assert!((900..=1100).contains(&p50), "p50={p50}");
    }
}
