//! Tiny property-testing harness (offline stand-in for the `proptest` crate).
//!
//! Usage pattern (`no_run`: doctest binaries don't get the xla rpath):
//!
//! ```no_run
//! use dyn_dbscan::util::proptest::{run_prop, Gen};
//! run_prop("vec reverse twice is identity", 100, |g| {
//!     let v: Vec<u32> = g.vec(0..=64, |g| g.rng.next_u64() as u32);
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     assert_eq!(v, w);
//! });
//! ```
//!
//! Each case runs with a seed derived from a fixed master seed (or the
//! `PROPTEST_SEED` env var) so failures are reproducible; on panic the
//! harness reports the case seed before propagating.

use std::ops::RangeInclusive;
use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::util::rng::Rng;

/// Per-case generation context.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    /// Random length in `range`, then build a vec with `f`.
    pub fn vec<T>(&mut self, range: RangeInclusive<usize>, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let lo = *range.start();
        let hi = *range.end();
        let len = lo + self.rng.below_usize(hi - lo + 1);
        (0..len).map(|_| f(self)).collect()
    }

    /// Uniform usize in inclusive range.
    pub fn usize_in(&mut self, range: RangeInclusive<usize>) -> usize {
        let lo = *range.start();
        let hi = *range.end();
        lo + self.rng.below_usize(hi - lo + 1)
    }

    /// Uniform f64 in range.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Pick one element from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below_usize(xs.len())]
    }
}

fn master_seed() -> u64 {
    std::env::var("PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD15_EA5E)
}

/// Run `cases` random cases of `prop`. Panics (with the failing case seed in
/// the message) if any case fails.
pub fn run_prop(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    let master = master_seed();
    for case in 0..cases {
        let seed = master
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add(case as u64);
        let mut g = Gen { rng: Rng::new(seed), case };
        let result = catch_unwind(AssertUnwindSafe(|| prop(&mut g)));
        #[allow(clippy::manual_assert)]
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {case} (PROPTEST_SEED={master}, case seed {seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        run_prop("sort idempotent", 50, |g| {
            let mut v: Vec<u64> = g.vec(0..=32, |g| g.rng.below(100));
            v.sort_unstable();
            let w = v.clone();
            v.sort_unstable();
            assert_eq!(v, w);
        });
    }

    #[test]
    #[should_panic]
    fn reports_failure() {
        run_prop("always fails eventually", 50, |g| {
            assert!(g.rng.below(10) != 3);
        });
    }

    #[test]
    fn deterministic_cases() {
        let mut first: Vec<usize> = Vec::new();
        run_prop("collect", 5, |g| {
            first.push(g.usize_in(0..=1000));
        });
        let mut second: Vec<usize> = Vec::new();
        run_prop("collect", 5, |g| {
            second.push(g.usize_in(0..=1000));
        });
        assert_eq!(first, second);
    }
}
