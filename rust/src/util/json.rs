//! Minimal JSON: value model, recursive-descent parser, serializer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`), CLI
//! experiment configs and machine-readable bench outputs. Supports the full
//! JSON grammar except `\u` surrogate pairs are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization is
/// deterministic — handy for golden tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Builder: object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected token")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"artifacts":[{"b":1024,"d":10,"name":"hash_d10"}],"z":[true,null,1.5]}"#;
        let v = Json::parse(src).unwrap();
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn display_escapes_control() {
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(s, r#""a\"b\\c\nd""#);
    }
}
