//! Generic chunked copy-on-write map — the shared machinery behind the
//! stitcher's label store (`shard::labels::LabelMap`) and the serve
//! façade's coordinate store (`serve::snapshot::CoordMap`).
//!
//! A [`ChunkedCowMap`] shards a `u64 → V` relation into `Arc`-wrapped
//! hash-map chunks keyed by a 64-bit mix of the key. Cloning the map
//! clones the chunk *pointer* vector (cheap) and shares every chunk with
//! the clone; subsequent writes go through [`Arc::make_mut`], which
//! deep-copies only the chunks that actually receive changes. That clone
//! *is* a published snapshot's state: publication cost is `O(Δ · chunk)`
//! in changed keys plus an `O(#chunks)` pointer copy — never `O(n)`.
//!
//! The chunk count doubles (a full `O(n)` re-shard, amortized over the
//! doublings) whenever mean occupancy exceeds twice the configured
//! target, so per-publish deep-copy work stays bounded as the live set
//! grows. [`ChunkedCowMap::sharing_ratio`] reports the fraction of chunks
//! still shared with an earlier clone — the CoW-sharing gauge exported by
//! the observability layer.

use std::sync::Arc;

use rustc_hash::FxHashMap;

use crate::util::rng::mix64;

/// Initial chunk count (power of two).
const MIN_CHUNKS: usize = 64;

/// The chunk a key lives in for a given (power-of-two) chunk count —
/// exposed so the incremental-checkpoint loader can re-derive chunk
/// membership of previously spilled keys without the map itself.
#[inline]
pub fn chunk_ix_of(key: u64, num_chunks: usize) -> usize {
    debug_assert!(num_chunks.is_power_of_two());
    (mix64(key) as usize) & (num_chunks - 1)
}

/// Chunked CoW `u64 → V` map. Cloning is `O(#chunks)` pointer copies.
#[derive(Clone, Debug)]
pub struct ChunkedCowMap<V> {
    chunks: Vec<Arc<FxHashMap<u64, V>>>,
    len: usize,
    /// target mean entries per chunk; growth triggers at twice this
    target_per_chunk: usize,
    /// write generation: bumped by [`advance_gen`](Self::advance_gen)
    /// (once per publish); every mutation stamps its chunk with the
    /// current value, giving chunk-level dirty tracking for incremental
    /// checkpoint spills without any clear/reset race — a spill just
    /// remembers the generation it covered and later asks for chunks
    /// stamped after it.
    write_gen: u64,
    /// generation of the last mutation per chunk (0 = never written)
    chunk_gen: Vec<u64>,
}

impl<V: Clone> ChunkedCowMap<V> {
    pub fn new(target_per_chunk: usize) -> Self {
        debug_assert!(target_per_chunk > 0);
        ChunkedCowMap {
            chunks: (0..MIN_CHUNKS).map(|_| Arc::new(FxHashMap::default())).collect(),
            len: 0,
            target_per_chunk,
            write_gen: 1,
            chunk_gen: vec![0; MIN_CHUNKS],
        }
    }

    #[inline]
    fn chunk_ix(&self, key: u64) -> usize {
        // chunk count is always a power of two
        (mix64(key) as usize) & (self.chunks.len() - 1)
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn get(&self, key: u64) -> Option<&V> {
        self.chunks[self.chunk_ix(key)].get(&key)
    }

    /// Insert or update; returns the previous value. Deep-copies the
    /// target chunk iff it is shared with a clone.
    pub fn set(&mut self, key: u64, value: V) -> Option<V> {
        let i = self.chunk_ix(key);
        self.chunk_gen[i] = self.write_gen;
        let prev = Arc::make_mut(&mut self.chunks[i]).insert(key, value);
        if prev.is_none() {
            self.len += 1;
        }
        prev
    }

    /// Remove; returns the previous value if present. Checks membership
    /// before `Arc::make_mut` so removing an absent key never deep-copies
    /// a snapshot-shared chunk.
    pub fn remove(&mut self, key: u64) -> Option<V> {
        let i = self.chunk_ix(key);
        if !self.chunks[i].contains_key(&key) {
            return None;
        }
        self.chunk_gen[i] = self.write_gen;
        let prev = Arc::make_mut(&mut self.chunks[i]).remove(&key);
        if prev.is_some() {
            self.len -= 1;
        }
        prev
    }

    /// Mutable access to an existing entry. Checks membership before
    /// `Arc::make_mut` so probing an absent key never deep-copies a
    /// snapshot-shared chunk.
    pub fn get_mut(&mut self, key: u64) -> Option<&mut V> {
        let i = self.chunk_ix(key);
        if !self.chunks[i].contains_key(&key) {
            return None;
        }
        self.chunk_gen[i] = self.write_gen;
        Arc::make_mut(&mut self.chunks[i]).get_mut(&key)
    }

    /// Mutable access, inserting `make()` when the key is absent.
    pub fn get_or_insert_with(&mut self, key: u64, make: impl FnOnce() -> V) -> &mut V {
        let i = self.chunk_ix(key);
        if !self.chunks[i].contains_key(&key) {
            self.len += 1;
        }
        self.chunk_gen[i] = self.write_gen;
        Arc::make_mut(&mut self.chunks[i]).entry(key).or_insert_with(make)
    }

    /// Unordered iteration over `(key, &value)`.
    pub fn iter(&self) -> impl Iterator<Item = (u64, &V)> + '_ {
        self.chunks.iter().flat_map(|c| c.iter().map(|(&k, v)| (k, v)))
    }

    /// Double the chunk count when mean occupancy exceeds the target —
    /// call between publishes (`O(n)` then, amortized `O(1)` per
    /// insertion over the doublings).
    pub fn maybe_grow(&mut self) {
        if self.len <= self.chunks.len() * self.target_per_chunk * 2 {
            return;
        }
        let new_n = self.chunks.len() * 2;
        let mut fresh: Vec<FxHashMap<u64, V>> =
            (0..new_n).map(|_| FxHashMap::default()).collect();
        for (k, v) in self.iter() {
            fresh[(mix64(k) as usize) & (new_n - 1)].insert(k, v.clone());
        }
        self.chunks = fresh.into_iter().map(Arc::new).collect();
        // a re-shard moves keys between chunks, so every chunk is dirty
        // relative to any earlier spill
        self.chunk_gen = vec![self.write_gen; new_n];
    }

    /// Bump the write generation. The serve façade calls this once per
    /// publish, right after cloning the map into the snapshot, so the
    /// snapshot's clone carries the generation stamps of exactly the
    /// writes folded into it.
    pub fn advance_gen(&mut self) {
        self.write_gen += 1;
    }

    /// Current write generation.
    pub fn generation(&self) -> u64 {
        self.write_gen
    }

    /// Chunks mutated *after* generation `floor` (ascending indices) —
    /// the dirty set an incremental spill serializes when `floor` is the
    /// generation covered by the last full spill.
    pub fn chunks_dirty_since(&self, floor: u64) -> Vec<usize> {
        (0..self.chunks.len()).filter(|&i| self.chunk_gen[i] > floor).collect()
    }

    /// Iterate `(key, &value)` of one chunk.
    pub fn for_each_in_chunk(&self, ix: usize, mut f: impl FnMut(u64, &V)) {
        for (&k, v) in self.chunks[ix].iter() {
            f(k, v);
        }
    }

    /// How many chunks are *not* shared with any clone — i.e. were
    /// deep-copied since the last clone (introspection for the delta
    /// publication tests, benches and the CoW gauges).
    pub fn unshared_chunks(&self) -> usize {
        self.chunks.iter().filter(|c| Arc::strong_count(c) == 1).count()
    }

    /// Current chunk count (always a power of two).
    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// Fraction of chunks still structurally shared with an earlier clone
    /// — 1.0 right after a publish clone, dropping as writes deep-copy
    /// chunks. This is the value behind the `cow_*_sharing` gauges.
    pub fn sharing_ratio(&self) -> f64 {
        1.0 - self.unshared_chunks() as f64 / self.num_chunks().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_remove_roundtrip() {
        let mut m: ChunkedCowMap<i64> = ChunkedCowMap::new(48);
        assert_eq!(m.get(7), None);
        assert_eq!(m.set(7, 3), None);
        assert_eq!(m.set(8, -1), None);
        assert_eq!(m.len(), 2);
        assert_eq!(m.get(7), Some(&3));
        assert_eq!(m.set(7, 4), Some(3));
        assert_eq!(m.len(), 2);
        assert_eq!(m.remove(7), Some(4));
        assert_eq!(m.remove(7), None);
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn clone_shares_until_written() {
        let mut m: ChunkedCowMap<i64> = ChunkedCowMap::new(48);
        for k in 0..2000u64 {
            m.set(k, (k % 5) as i64);
        }
        let snap = m.clone(); // "publish"
        assert_eq!(m.unshared_chunks(), 0);
        assert!((m.sharing_ratio() - 1.0).abs() < 1e-12);
        // a single change deep-copies exactly one chunk
        m.set(42, 99);
        assert_eq!(m.unshared_chunks(), 1);
        assert!(m.sharing_ratio() < 1.0);
        assert_eq!(snap.get(42), Some(&2));
        assert_eq!(m.get(42), Some(&99));
    }

    #[test]
    fn growth_preserves_content() {
        let mut m: ChunkedCowMap<i64> = ChunkedCowMap::new(32);
        for k in 0..20_000u64 {
            m.set(k * 13, (k % 7) as i64 - 1);
        }
        let before = m.num_chunks();
        m.maybe_grow();
        assert!(m.num_chunks() > before);
        assert_eq!(m.len(), 20_000);
        for k in 0..20_000u64 {
            assert_eq!(m.get(k * 13), Some(&((k % 7) as i64 - 1)));
        }
        assert_eq!(m.get(1), None);
    }
}
