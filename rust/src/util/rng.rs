//! Deterministic, splittable PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Every stochastic component of the system (hash-function shifts, treap
//! priorities, skip-list heights, dataset generators, stream shuffles) takes
//! an explicit seed so that experiments are exactly reproducible. `rand` is
//! unavailable offline; this is a faithful implementation of the published
//! xoshiro256** 1.0 algorithm (Blackman & Vigna).

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded with SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Rng { s: [sm.next(), sm.next(), sm.next(), sm.next()] }
    }

    /// Derive an independent child generator (for per-component streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0x9e37_79b9_7f4a_7c15)
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's nearly-divisionless method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached spare is intentionally not
    /// kept: determinism under `split()` matters more than the 2x).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Geometric level draw for skip lists: number of fair-coin successes,
    /// capped at `max`.
    pub fn skip_height(&mut self, max: u32) -> u32 {
        // count trailing ones of a random word = geometric(1/2)
        let h = (self.next_u64() | (1u64 << 63)).trailing_ones();
        h.min(max)
    }
}

/// SplitMix64 — seed expander (also used standalone for cheap stateless
/// mixing in tests).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

/// One-shot 64-bit mix (Stafford variant 13) — used by the LSH key combiner.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut a = Rng::new(7);
        let mut c = a.split();
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(123);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.uniform(2.0, 4.0);
            assert!((2.0..4.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(42);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn skip_height_distribution() {
        let mut r = Rng::new(77);
        let mut ge1 = 0;
        let n = 40_000;
        for _ in 0..n {
            let h = r.skip_height(32);
            assert!(h <= 32);
            if h >= 1 {
                ge1 += 1;
            }
        }
        // P(h>=1) = 1/2
        assert!((ge1 as f64 / n as f64 - 0.5).abs() < 0.02);
    }
}
