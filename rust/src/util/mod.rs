//! Self-contained utility substrates.
//!
//! The build environment is fully offline, so the usual ecosystem crates
//! (`rand`, `serde`/`serde_json`, `clap`, `criterion`, `proptest`) are not
//! available. This module provides the small, well-tested equivalents the
//! rest of the crate needs:
//!
//! * [`rng`] — splittable xoshiro256** PRNG (deterministic, seedable);
//! * [`json`] — minimal JSON value model, parser and serializer (configs,
//!   the artifact manifest, experiment outputs);
//! * [`stats`] — streaming mean/variance, percentile sketches and latency
//!   histograms for the coordinator and the bench harness;
//! * [`proptest`] — a tiny property-testing harness (random case generation
//!   with seed reporting and bounded shrinking);
//! * [`cow_map`] — the generic chunked copy-on-write map behind the
//!   stitcher's label store and the serve façade's coordinate store.

pub mod cow_map;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;

pub use cow_map::ChunkedCowMap;

/// Round `n` up to the next multiple of `m` (m > 0).
pub fn round_up(n: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    n.div_ceil(m) * m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
        assert_eq!(round_up(1000, 1024), 1024);
    }
}
