//! Observability: the lock-free metrics registry, stage-level span timing
//! and the per-publish trace plumbing threaded through every layer of the
//! pipeline (router → ghosts → ETT/HDT connectivity → delta stitch →
//! snapshot publish).
//!
//! Design rules:
//!
//! * **One registry per engine.** [`Metrics`] is shared as an
//!   `Arc<Metrics>` between the engine, its shard workers and the DBSCAN
//!   cores; every mutation is a `Relaxed` atomic op on a striped counter
//!   ([`AtomicHisto`]), so workers never contend and readers merge live.
//! * **All timing goes through this module.** `serve`, `shard` and
//!   `dbscan` code uses [`Stopwatch`], [`PhaseClock`] or the [`span!`]
//!   macro — never ad-hoc `Instant::now()` (enforced by a grep-lint in
//!   `tests/lint.rs`), so instrumentation stays centralized and the
//!   overhead budget auditable.
//! * **Disabled means free.** A registry built with `Metrics::new(false)`
//!   turns every record into a branch on a plain `bool`; the
//!   `obs_overhead` bench axis gates the enabled cost at ≤ 2%.
//!
//! Metric naming follows the Prometheus convention: `dyndbscan_` prefix,
//! `_total` suffix on counters, `_ns` unit suffix on durations (see
//! `serve::MetricsSnapshot::render_prometheus`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::stats::{AtomicHisto, LatencyHisto};

// ---------------------------------------------------------------------
// stages
// ---------------------------------------------------------------------

/// One stage of a publish round, in pipeline order. `Route`, `DeltaFold`
/// and `Stitch` are timed inside the sharded engine; `SnapshotCow` and
/// `Events` are the serve façade's share (view construction and cluster
/// event derivation) and are folded into the same trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PublishStage {
    /// flushing pending batches through the router to the workers
    /// (includes ghost replication — ghosts are routed, not re-sent)
    Route,
    /// draining per-shard deltas at the publish barrier
    DeltaFold,
    /// folding deltas into the cross-shard stitch graph
    Stitch,
    /// CoW snapshot-view construction (label/coord chunk clones)
    SnapshotCow,
    /// cluster-event derivation for `watch()` subscribers
    Events,
}

impl PublishStage {
    pub const COUNT: usize = 5;
    pub const ALL: [PublishStage; Self::COUNT] = [
        PublishStage::Route,
        PublishStage::DeltaFold,
        PublishStage::Stitch,
        PublishStage::SnapshotCow,
        PublishStage::Events,
    ];

    pub fn name(self) -> &'static str {
        match self {
            PublishStage::Route => "route",
            PublishStage::DeltaFold => "delta_fold",
            PublishStage::Stitch => "stitch",
            PublishStage::SnapshotCow => "snapshot_cow",
            PublishStage::Events => "events",
        }
    }

    #[inline]
    pub fn ix(self) -> usize {
        self as usize
    }
}

/// One stage of a single point update inside the DBSCAN core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateStage {
    /// grid-LSH key hashing (amortized per batch)
    Hash,
    /// bucket probes: core threshold checks and neighbor collection
    NeighborQuery,
    /// ETT splice work: links, cuts, attach/detach of non-core points
    EttLinkCut,
    /// HDT replacement search incl. level promotion sweeps
    LevelPromotion,
    /// snapshot spatial-index maintenance folded into the update path:
    /// ε-cell probe (cell hash + CoW bucket edit) per upsert/remove
    IndexProbe,
}

impl UpdateStage {
    pub const COUNT: usize = 5;
    pub const ALL: [UpdateStage; Self::COUNT] = [
        UpdateStage::Hash,
        UpdateStage::NeighborQuery,
        UpdateStage::EttLinkCut,
        UpdateStage::LevelPromotion,
        UpdateStage::IndexProbe,
    ];

    pub fn name(self) -> &'static str {
        match self {
            UpdateStage::Hash => "hash",
            UpdateStage::NeighborQuery => "neighbor_query",
            UpdateStage::EttLinkCut => "ett_link_cut",
            UpdateStage::LevelPromotion => "level_promotion",
            UpdateStage::IndexProbe => "index_probe",
        }
    }

    #[inline]
    pub fn ix(self) -> usize {
        self as usize
    }
}

/// A stage identifier the [`span!`] macro can record through — implemented
/// by both stage enums so one macro serves the publish and update paths.
pub trait Stage: Copy {
    fn record_into(self, m: &Metrics, ns: u64);
}

impl Stage for PublishStage {
    #[inline]
    fn record_into(self, m: &Metrics, ns: u64) {
        m.record_publish_stage(self, ns);
    }
}

impl Stage for UpdateStage {
    #[inline]
    fn record_into(self, m: &Metrics, ns: u64) {
        m.record_update_stage(self, ns);
    }
}

// ---------------------------------------------------------------------
// clocks
// ---------------------------------------------------------------------

/// The sanctioned wall-clock handle for `serve`/`shard`/`dbscan` code —
/// thin wrapper over `Instant` so all timing flows through one API.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    #[inline]
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    #[inline]
    pub fn elapsed_ns(&self) -> u64 {
        self.0.elapsed().as_nanos() as u64
    }

    #[inline]
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Sequential stage timer: each [`PhaseClock::lap`] returns the
/// nanoseconds since the previous lap (or construction) and restarts, so
/// consecutive laps partition an interval without re-reading the clock
/// twice per boundary.
#[derive(Clone, Copy, Debug)]
pub struct PhaseClock {
    last: Instant,
}

impl PhaseClock {
    #[inline]
    pub fn new() -> Self {
        PhaseClock { last: Instant::now() }
    }

    /// A clock only when `on` — the update hot path's way to skip the
    /// clock reads entirely when metrics are disabled.
    #[inline]
    pub fn maybe(on: bool) -> Option<PhaseClock> {
        if on {
            Some(PhaseClock::new())
        } else {
            None
        }
    }

    #[inline]
    pub fn lap(&mut self) -> u64 {
        let now = Instant::now();
        let ns = now.duration_since(self.last).as_nanos() as u64;
        self.last = now;
        ns
    }
}

impl Default for PhaseClock {
    fn default() -> Self {
        Self::new()
    }
}

/// Time a body expression and record it against a stage:
///
/// ```ignore
/// let keys = span!(self.obs, UpdateStage::Hash, {
///     self.hasher.keys_for(&coords)
/// });
/// ```
///
/// Evaluates to the body's value. The registry reference may be anything
/// that derefs to [`Metrics`] (e.g. an `Arc<Metrics>`); with the registry
/// disabled the cost is two clock reads and a predictable branch.
#[macro_export]
macro_rules! span {
    ($metrics:expr, $stage:expr, $body:expr) => {{
        let __span_sw = $crate::obs::Stopwatch::start();
        let __span_out = $body;
        $crate::obs::Stage::record_into($stage, &$metrics, __span_sw.elapsed_ns());
        __span_out
    }};
}

// ---------------------------------------------------------------------
// publish trace
// ---------------------------------------------------------------------

/// Per-stage breakdown of the most recent publish. The engine fills
/// `Route`/`DeltaFold`/`Stitch` and sets the engine-side total; the serve
/// façade extends it with its `SnapshotCow`/`Events` share, so the
/// invariant `stage_sum_ns() ≤ total_ns()` holds at every layer.
#[derive(Clone, Debug, Default)]
pub struct PublishTrace {
    stage_ns: [u64; PublishStage::COUNT],
    total_ns: u64,
}

impl PublishTrace {
    pub fn record(&mut self, stage: PublishStage, ns: u64) {
        self.stage_ns[stage.ix()] += ns;
    }

    pub fn set_total(&mut self, ns: u64) {
        self.total_ns = ns;
    }

    /// Grow the total by a façade-side addition (the façade stages run
    /// after the engine's own total was taken).
    pub fn extend_total(&mut self, ns: u64) {
        self.total_ns += ns;
    }

    pub fn get(&self, stage: PublishStage) -> u64 {
        self.stage_ns[stage.ix()]
    }

    pub fn total_ns(&self) -> u64 {
        self.total_ns
    }

    pub fn stage_sum_ns(&self) -> u64 {
        self.stage_ns.iter().sum()
    }

    pub fn stages(&self) -> impl Iterator<Item = (PublishStage, u64)> + '_ {
        PublishStage::ALL.iter().map(move |&s| (s, self.stage_ns[s.ix()]))
    }

    /// `route=…ns delta_fold=…ns … total=…ns` one-liner for logs.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for (s, ns) in self.stages() {
            out.push_str(&format!("{}={}ns ", s.name(), ns));
        }
        out.push_str(&format!("total={}ns", self.total_ns));
        out
    }
}

// ---------------------------------------------------------------------
// gauges
// ---------------------------------------------------------------------

/// Structural gauges sampled cheaply at publish. Integer gauges hold the
/// raw value; ratio gauges (`is_ratio`) hold `f64` bits — [`Metrics::gauge`]
/// decodes either into an `f64`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gauge {
    /// live primary points in the published snapshot
    LivePoints,
    /// ghost inserts / primary inserts (replication overhead)
    GhostRatio,
    /// live ETT vertices summed over every HDT level forest
    EttVertices,
    /// live (multi-)edges in the connectivity structures
    EttEdges,
    /// deepest HDT level currently materialized across shards
    HdtLevels,
    /// cumulative HDT edge promotions (level pushes)
    EdgePromotions,
    /// stitch-graph vertices ((shard, root) nodes)
    StitchNodes,
    /// stitch-graph edges
    StitchEdges,
    /// label-map chunk-sharing ratio at last publish (1.0 = all shared)
    CowLabelSharing,
    /// coord-map chunk-sharing ratio at last publish
    CowCoordSharing,
    /// WAL records appended but not yet group-fsynced (durability lag in
    /// ops; zeroed at every publish barrier by the fsync)
    WalLag,
    /// non-empty ε-cells in the snapshot spatial index at last publish
    IndexCells,
    /// spatial-index chunk-sharing ratio at last publish (1.0 = fully
    /// shared with the previous snapshot's index)
    CowIndexSharing,
    /// dist-1 adjacent assigned placement cells owned by different shards
    /// — the quantity cell-graph placement minimizes
    CutEdges,
    /// cells migrated by live resharding in the last publish interval
    MigrationCells,
    /// slowest replica-shipped WAL sequence floor on the leader
    /// (`u64::MAX` scaled down to 0 when no followers are attached)
    ShipFloor,
    /// publishes the slowest follower trails the leader by (leader-side:
    /// sampled at ship; follower-side registries report their own lag)
    ReplicaLagPublishes,
}

impl Gauge {
    pub const COUNT: usize = 17;
    pub const ALL: [Gauge; Self::COUNT] = [
        Gauge::LivePoints,
        Gauge::GhostRatio,
        Gauge::EttVertices,
        Gauge::EttEdges,
        Gauge::HdtLevels,
        Gauge::EdgePromotions,
        Gauge::StitchNodes,
        Gauge::StitchEdges,
        Gauge::CowLabelSharing,
        Gauge::CowCoordSharing,
        Gauge::WalLag,
        Gauge::IndexCells,
        Gauge::CowIndexSharing,
        Gauge::CutEdges,
        Gauge::MigrationCells,
        Gauge::ShipFloor,
        Gauge::ReplicaLagPublishes,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Gauge::LivePoints => "live_points",
            Gauge::GhostRatio => "ghost_ratio",
            Gauge::EttVertices => "ett_vertices",
            Gauge::EttEdges => "ett_edges",
            Gauge::HdtLevels => "hdt_levels",
            Gauge::EdgePromotions => "edge_promotions",
            Gauge::StitchNodes => "stitch_nodes",
            Gauge::StitchEdges => "stitch_edges",
            Gauge::CowLabelSharing => "cow_label_sharing",
            Gauge::CowCoordSharing => "cow_coord_sharing",
            Gauge::WalLag => "wal_lag",
            Gauge::IndexCells => "index_cells",
            Gauge::CowIndexSharing => "cow_index_sharing",
            Gauge::CutEdges => "cut_edges",
            Gauge::MigrationCells => "migration_cells",
            Gauge::ShipFloor => "ship_floor",
            Gauge::ReplicaLagPublishes => "replica_lag_publishes",
        }
    }

    /// Stored as `f64` bits rather than an integer count.
    pub fn is_ratio(self) -> bool {
        matches!(
            self,
            Gauge::GhostRatio
                | Gauge::CowLabelSharing
                | Gauge::CowCoordSharing
                | Gauge::CowIndexSharing
        )
    }

    #[inline]
    fn ix(self) -> usize {
        self as usize
    }
}

// ---------------------------------------------------------------------
// registry
// ---------------------------------------------------------------------

/// The shared, lock-free metrics registry. One per engine, shared as an
/// `Arc<Metrics>` with every worker thread and DBSCAN core; all mutators
/// take `&self` and reduce to `Relaxed` atomic ops (or an early return
/// when disabled), so the hot paths never block on observation.
pub struct Metrics {
    enabled: bool,
    /// per-op insert latency (worker-recorded, striped)
    add: AtomicHisto,
    /// per-op delete latency
    delete: AtomicHisto,
    /// whole-publish latency
    publish: AtomicHisto,
    /// cumulative per-stage publish breakdowns
    publish_stages: [AtomicHisto; PublishStage::COUNT],
    /// cumulative per-stage update breakdowns
    update_stages: [AtomicHisto; UpdateStage::COUNT],
    gauges: [AtomicU64; Gauge::COUNT],
    /// live ETT vertices per HDT level (deeper levels fold into the last)
    hdt_level_verts: [AtomicU64; Self::MAX_LEVELS],
    /// live primary points per shard from the placement map, sampled at
    /// publish (shards beyond the tracked cap fold into the last slot)
    shard_loads: [AtomicU64; Self::MAX_SHARDS_TRACKED],
    /// WAL records appended (durable-layer throughput counter)
    wal_records: AtomicU64,
    /// framed WAL bytes appended
    wal_bytes: AtomicU64,
    /// group fsync barriers completed
    wal_fsyncs: AtomicU64,
    /// per-barrier fsync latency
    fsync: AtomicHisto,
    /// wall time of the last crash recovery (checkpoint load + WAL replay)
    replay_ns: AtomicU64,
    /// WAL records replayed by the last crash recovery
    replay_records: AtomicU64,
    /// WAL frames shipped to replication followers
    ship_frames: AtomicU64,
    /// ship rounds completed (one per durable publish with followers)
    ship_rounds: AtomicU64,
    /// per-round ship latency (read tail + transport sends)
    ship: AtomicHisto,
}

impl Metrics {
    /// Tracked HDT levels; `O(log n)` levels means 8 covers every
    /// realistic shard size, and deeper levels fold into the last slot.
    pub const MAX_LEVELS: usize = 8;

    /// Per-shard load gauges tracked; shard ids ≥ this fold into the
    /// last slot (the engine caps at far fewer workers than this on any
    /// real box, so the fold slot is normally just shard 31's own load).
    pub const MAX_SHARDS_TRACKED: usize = 32;

    pub fn new(enabled: bool) -> Self {
        Metrics {
            enabled,
            add: AtomicHisto::new(),
            delete: AtomicHisto::new(),
            publish: AtomicHisto::new(),
            publish_stages: std::array::from_fn(|_| AtomicHisto::new()),
            update_stages: std::array::from_fn(|_| AtomicHisto::new()),
            gauges: std::array::from_fn(|_| AtomicU64::new(0)),
            hdt_level_verts: std::array::from_fn(|_| AtomicU64::new(0)),
            shard_loads: std::array::from_fn(|_| AtomicU64::new(0)),
            wal_records: AtomicU64::new(0),
            wal_bytes: AtomicU64::new(0),
            wal_fsyncs: AtomicU64::new(0),
            fsync: AtomicHisto::new(),
            replay_ns: AtomicU64::new(0),
            replay_records: AtomicU64::new(0),
            ship_frames: AtomicU64::new(0),
            ship_rounds: AtomicU64::new(0),
            ship: AtomicHisto::new(),
        }
    }

    /// A registry whose every record is a no-op — the `metrics(false)`
    /// baseline the `obs_overhead` bench compares against.
    pub fn disabled() -> Self {
        Self::new(false)
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    // ---- histograms -------------------------------------------------

    #[inline]
    pub fn record_add(&self, ns: u64) {
        if self.enabled {
            self.add.record(ns);
        }
    }

    #[inline]
    pub fn record_delete(&self, ns: u64) {
        if self.enabled {
            self.delete.record(ns);
        }
    }

    #[inline]
    pub fn record_publish(&self, ns: u64) {
        if self.enabled {
            self.publish.record(ns);
        }
    }

    #[inline]
    pub fn record_publish_stage(&self, stage: PublishStage, ns: u64) {
        if self.enabled {
            self.publish_stages[stage.ix()].record(ns);
        }
    }

    #[inline]
    pub fn record_update_stage(&self, stage: UpdateStage, ns: u64) {
        if self.enabled {
            self.update_stages[stage.ix()].record(ns);
        }
    }

    /// Live merged view of the per-op insert latencies.
    pub fn add_histo(&self) -> LatencyHisto {
        self.add.snapshot()
    }

    pub fn delete_histo(&self) -> LatencyHisto {
        self.delete.snapshot()
    }

    pub fn publish_histo(&self) -> LatencyHisto {
        self.publish.snapshot()
    }

    pub fn publish_stage_histos(&self) -> Vec<(&'static str, LatencyHisto)> {
        PublishStage::ALL
            .iter()
            .map(|&s| (s.name(), self.publish_stages[s.ix()].snapshot()))
            .collect()
    }

    pub fn update_stage_histos(&self) -> Vec<(&'static str, LatencyHisto)> {
        UpdateStage::ALL
            .iter()
            .map(|&s| (s.name(), self.update_stages[s.ix()].snapshot()))
            .collect()
    }

    // ---- durability -------------------------------------------------

    /// One WAL record appended (`bytes` = framed size). The unsynced
    /// backlog is tracked separately via [`Gauge::WalLag`].
    #[inline]
    pub fn record_wal_append(&self, bytes: u64) {
        if self.enabled {
            self.wal_records.fetch_add(1, Ordering::Relaxed);
            self.wal_bytes.fetch_add(bytes, Ordering::Relaxed);
        }
    }

    /// One group fsync barrier completed in `ns`, making `records` ops
    /// durable.
    #[inline]
    pub fn record_wal_fsync(&self, ns: u64) {
        if self.enabled {
            self.wal_fsyncs.fetch_add(1, Ordering::Relaxed);
            self.fsync.record(ns);
        }
    }

    /// Crash recovery completed: `ns` of wall time to load the checkpoint
    /// and replay `records` WAL records.
    pub fn record_recovery(&self, ns: u64, records: u64) {
        if self.enabled {
            self.replay_ns.store(ns, Ordering::Relaxed);
            self.replay_records.store(records, Ordering::Relaxed);
        }
    }

    /// `(records appended, framed bytes, fsync barriers)`.
    pub fn wal_counters(&self) -> (u64, u64, u64) {
        (
            self.wal_records.load(Ordering::Relaxed),
            self.wal_bytes.load(Ordering::Relaxed),
            self.wal_fsyncs.load(Ordering::Relaxed),
        )
    }

    /// Live merged view of the per-barrier fsync latencies.
    pub fn fsync_histo(&self) -> LatencyHisto {
        self.fsync.snapshot()
    }

    /// `(replay wall ns, records replayed)` of the last crash recovery.
    pub fn recovery_stats(&self) -> (u64, u64) {
        (
            self.replay_ns.load(Ordering::Relaxed),
            self.replay_records.load(Ordering::Relaxed),
        )
    }

    // ---- replication ------------------------------------------------

    /// One log-shipping round completed in `ns`, forwarding `frames` WAL
    /// frames to followers.
    #[inline]
    pub fn record_ship(&self, ns: u64, frames: u64) {
        if self.enabled {
            self.ship_rounds.fetch_add(1, Ordering::Relaxed);
            self.ship_frames.fetch_add(frames, Ordering::Relaxed);
            self.ship.record(ns);
        }
    }

    /// `(frames shipped, ship rounds)` since the engine started.
    pub fn ship_counters(&self) -> (u64, u64) {
        (
            self.ship_frames.load(Ordering::Relaxed),
            self.ship_rounds.load(Ordering::Relaxed),
        )
    }

    /// Live merged view of the per-round ship latencies.
    pub fn ship_histo(&self) -> LatencyHisto {
        self.ship.snapshot()
    }

    // ---- gauges -----------------------------------------------------

    pub fn set_gauge(&self, g: Gauge, v: u64) {
        if self.enabled {
            debug_assert!(!g.is_ratio());
            self.gauges[g.ix()].store(v, Ordering::Relaxed);
        }
    }

    /// Accumulate into an integer gauge — how workers fold their share of
    /// a structural sample in at a publish barrier.
    pub fn add_gauge(&self, g: Gauge, v: u64) {
        if self.enabled {
            debug_assert!(!g.is_ratio());
            self.gauges[g.ix()].fetch_add(v, Ordering::Relaxed);
        }
    }

    /// Max-fold into an integer gauge (e.g. deepest HDT level).
    pub fn max_gauge(&self, g: Gauge, v: u64) {
        if self.enabled {
            debug_assert!(!g.is_ratio());
            self.gauges[g.ix()].fetch_max(v, Ordering::Relaxed);
        }
    }

    pub fn set_ratio(&self, g: Gauge, v: f64) {
        if self.enabled {
            debug_assert!(g.is_ratio());
            self.gauges[g.ix()].store(v.to_bits(), Ordering::Relaxed);
        }
    }

    /// Read any gauge as `f64` (decoding ratio bits where needed).
    pub fn gauge(&self, g: Gauge) -> f64 {
        let raw = self.gauges[g.ix()].load(Ordering::Relaxed);
        if g.is_ratio() {
            f64::from_bits(raw)
        } else {
            raw as f64
        }
    }

    pub fn gauge_values(&self) -> Vec<(&'static str, f64)> {
        Gauge::ALL.iter().map(|&g| (g.name(), self.gauge(g))).collect()
    }

    pub fn add_level_verts(&self, level: usize, v: u64) {
        if self.enabled {
            self.hdt_level_verts[level.min(Self::MAX_LEVELS - 1)]
                .fetch_add(v, Ordering::Relaxed);
        }
    }

    pub fn level_verts(&self) -> [u64; Self::MAX_LEVELS] {
        std::array::from_fn(|i| self.hdt_level_verts[i].load(Ordering::Relaxed))
    }

    /// Record the per-shard live primary loads (sampled at publish from
    /// the placement map). Shards past the tracked cap fold their load
    /// into the last slot — mirroring `add_level_verts` — so the total
    /// stays honest even on an implausibly wide fleet.
    pub fn set_shard_loads(&self, loads: &[u64]) {
        if !self.enabled {
            return;
        }
        let last = Self::MAX_SHARDS_TRACKED - 1;
        for (s, slot) in self.shard_loads.iter().enumerate() {
            let v = if s == last {
                loads.get(s..).map_or(0, |tail| tail.iter().sum())
            } else {
                loads.get(s).copied().unwrap_or(0)
            };
            slot.store(v, Ordering::Relaxed);
        }
    }

    /// Per-shard live primary loads (all tracked slots; callers truncate
    /// to the engine's shard count).
    pub fn shard_loads(&self) -> Vec<u64> {
        self.shard_loads
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// Zero the worker-accumulated structural gauges before a publish
    /// barrier; every worker then `add_gauge`s its share back in while
    /// handling the barrier marker, so the engine reads a consistent
    /// whole-fleet sample after the barrier completes.
    pub fn zero_structural(&self) {
        if !self.enabled {
            return;
        }
        for g in [
            Gauge::EttVertices,
            Gauge::EttEdges,
            Gauge::HdtLevels,
            Gauge::EdgePromotions,
        ] {
            self.gauges[g.ix()].store(0, Ordering::Relaxed);
        }
        for c in &self.hdt_level_verts {
            c.store(0, Ordering::Relaxed);
        }
    }
}

impl std::fmt::Debug for Metrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Metrics")
            .field("enabled", &self.enabled)
            .field("adds", &self.add.count())
            .field("deletes", &self.delete.count())
            .field("publishes", &self.publish.count())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_records_nothing() {
        let m = Metrics::disabled();
        m.record_add(10);
        m.record_publish_stage(PublishStage::Stitch, 10);
        m.set_gauge(Gauge::LivePoints, 5);
        assert_eq!(m.add_histo().count(), 0);
        assert_eq!(m.publish_stage_histos()[PublishStage::Stitch.ix()].1.count(), 0);
        assert_eq!(m.gauge(Gauge::LivePoints), 0.0);
    }

    #[test]
    fn span_macro_records_and_returns() {
        let m = Metrics::new(true);
        let v = crate::span!(m, UpdateStage::Hash, { 40 + 2 });
        assert_eq!(v, 42);
        let h = &m.update_stage_histos()[UpdateStage::Hash.ix()];
        assert_eq!(h.0, "hash");
        assert_eq!(h.1.count(), 1);
    }

    #[test]
    fn phase_clock_laps_partition_the_interval() {
        let sw = Stopwatch::start();
        let mut clk = PhaseClock::new();
        let mut acc = 0u64;
        for _ in 0..3 {
            std::hint::black_box((0..1000).sum::<u64>());
            acc += clk.lap();
        }
        assert!(acc <= sw.elapsed_ns(), "laps exceed enclosing interval");
    }

    #[test]
    fn publish_trace_invariants() {
        let mut t = PublishTrace::default();
        t.record(PublishStage::Route, 10);
        t.record(PublishStage::Stitch, 30);
        t.set_total(50);
        t.record(PublishStage::SnapshotCow, 7);
        t.extend_total(7);
        assert_eq!(t.get(PublishStage::Stitch), 30);
        assert_eq!(t.stage_sum_ns(), 47);
        assert_eq!(t.total_ns(), 57);
        assert!(t.stage_sum_ns() <= t.total_ns());
        assert!(t.summary().contains("stitch=30ns"));
    }

    #[test]
    fn gauges_roundtrip_and_zero() {
        let m = Metrics::new(true);
        m.set_gauge(Gauge::LivePoints, 123);
        m.set_ratio(Gauge::GhostRatio, 0.25);
        m.add_gauge(Gauge::EttVertices, 10);
        m.add_gauge(Gauge::EttVertices, 5);
        m.max_gauge(Gauge::HdtLevels, 3);
        m.max_gauge(Gauge::HdtLevels, 2);
        m.add_level_verts(0, 10);
        m.add_level_verts(99, 1); // folds into the last slot
        let mut loads = vec![0u64; 40];
        loads[2] = 77;
        loads[31] = 5;
        loads[39] = 3; // beyond the cap: folds into the last slot
        m.set_shard_loads(&loads);
        assert_eq!(m.shard_loads()[2], 77);
        assert_eq!(
            m.shard_loads()[Metrics::MAX_SHARDS_TRACKED - 1],
            8,
            "overflow shards fold into the last slot"
        );
        assert_eq!(m.shard_loads().iter().sum::<u64>(), 85, "no load dropped");
        assert_eq!(m.gauge(Gauge::LivePoints), 123.0);
        assert!((m.gauge(Gauge::GhostRatio) - 0.25).abs() < 1e-12);
        assert_eq!(m.gauge(Gauge::EttVertices), 15.0);
        assert_eq!(m.gauge(Gauge::HdtLevels), 3.0);
        assert_eq!(m.level_verts()[0], 10);
        assert_eq!(m.level_verts()[Metrics::MAX_LEVELS - 1], 1);
        m.zero_structural();
        assert_eq!(m.gauge(Gauge::EttVertices), 0.0);
        assert_eq!(m.level_verts()[0], 0);
        // non-structural gauges survive the barrier zeroing
        assert_eq!(m.gauge(Gauge::LivePoints), 123.0);
    }
}
