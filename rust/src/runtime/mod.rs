//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced by
//! `python/compile/aot.py`) and execute them from the Rust hot path.
//!
//! Interchange is **HLO text**, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
//! version the published `xla` 0.1.6 crate links) rejects; the text parser
//! reassigns ids and round-trips cleanly. Every model returns a 1-tuple
//! (`return_tuple=True` at lowering), unwrapped here with `to_tuple1`.

pub mod engines;
#[cfg(not(feature = "xla"))]
mod xla_stub;
#[cfg(not(feature = "xla"))]
use self::xla_stub as xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Shape+dtype of one artifact input/output, from `manifest.json`.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One artifact entry from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
    /// kind-specific integer params (d, t, b, q, m, ...)
    pub params: HashMap<String, usize>,
}

fn tensor_spec(j: &Json) -> Result<TensorSpec> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing shape"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = j
        .get("dtype")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing dtype"))?
        .to_string();
    Ok(TensorSpec { shape, dtype })
}

/// The artifact registry: parses `manifest.json`, lazily compiles
/// executables on the PJRT CPU client, and runs them.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub artifacts: HashMap<String, ArtifactMeta>,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Default artifacts directory: `$DYN_DBSCAN_ARTIFACTS` or `artifacts/`
    /// relative to the workspace root (also checked one level up so tests
    /// running from target dirs find it).
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("DYN_DBSCAN_ARTIFACTS") {
            return PathBuf::from(d);
        }
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            let p = PathBuf::from(cand);
            if p.join("manifest.json").exists() {
                return p;
            }
        }
        PathBuf::from("artifacts")
    }

    /// Are artifacts present (without constructing a client)?
    pub fn available(dir: &Path) -> bool {
        dir.join("manifest.json").exists()
    }

    pub fn new(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let json = Json::parse(&text).context("parsing manifest.json")?;
        let mut artifacts = HashMap::new();
        for a in json
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?
        {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let kind = a
                .get("kind")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing file"))?
                .to_string();
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact missing inputs"))?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            let output = tensor_spec(
                a.get("output").ok_or_else(|| anyhow!("artifact missing output"))?,
            )?;
            let mut params = HashMap::new();
            if let Json::Obj(m) = a {
                for (k, v) in m {
                    if let Some(x) = v.as_f64() {
                        params.insert(k.clone(), x as usize);
                    }
                }
            }
            artifacts.insert(
                name.clone(),
                ArtifactMeta { name, kind, file, inputs, output, params },
            );
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client, dir: dir.to_path_buf(), artifacts, executables: HashMap::new() })
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }

    /// Compile (idempotent) the named artifact.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let meta = self.meta(name)?.clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute with f32 inputs (shape-checked against the manifest); returns
    /// the single tuple element as a Literal.
    pub fn execute_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<xla::Literal> {
        self.load(name)?;
        let meta = self.meta(name)?.clone();
        if inputs.len() != meta.inputs.len() {
            return Err(anyhow!(
                "{name}: {} inputs supplied, {} expected",
                inputs.len(),
                meta.inputs.len()
            ));
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (spec, &data) in meta.inputs.iter().zip(inputs) {
            let want: usize = spec.shape.iter().product();
            if data.len() != want {
                return Err(anyhow!(
                    "{name}: input size {} != manifest {:?}",
                    data.len(),
                    spec.shape
                ));
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&x| x as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape: {e:?}"))?;
            lits.push(lit);
        }
        let exe = self.executables.get(name).expect("loaded above");
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        result.to_tuple1().map_err(|e| anyhow!("tuple unwrap: {e:?}"))
    }

    /// Execute and read the output as i32 (hash artifacts).
    pub fn execute_f32_to_i32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<i32>> {
        let lit = self.execute_f32(name, inputs)?;
        lit.to_vec::<i32>().map_err(|e| anyhow!("i32 readback: {e:?}"))
    }

    /// Execute and read the output as f32 (distance/project artifacts).
    pub fn execute_f32_to_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let lit = self.execute_f32(name, inputs)?;
        lit.to_vec::<f32>().map_err(|e| anyhow!("f32 readback: {e:?}"))
    }
}
