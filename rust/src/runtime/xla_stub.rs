//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The real crate links the xla_extension C++ library, which the offline
//! build image does not ship. This stub mirrors the exact API surface
//! `runtime::Runtime` touches; every entry point fails at the earliest
//! possible moment (`PjRtClient::cpu`), so callers degrade gracefully: the
//! coordinator's `make_engine` falls back to native hashing and the
//! artifact parity tests skip. Build with `--features xla` (plus the real
//! dependency) to restore the PJRT path.

#![allow(dead_code)]

/// Error type matching the `{e:?}` formatting the runtime uses.
#[derive(Debug)]
pub struct Error(pub &'static str);

const STUBBED: &str =
    "xla support not compiled in (offline stub; enable the `xla` feature \
     and provide the xla crate + xla_extension library)";

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error(STUBBED))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error(STUBBED))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error(STUBBED))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error(STUBBED))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error(STUBBED))
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error(STUBBED))
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(Error(STUBBED))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error(STUBBED))
    }
}
