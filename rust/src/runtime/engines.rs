//! Hashing and distance engines: the seam between the L3 coordinator and
//! the AOT compute artifacts.
//!
//! Each engine exists in two flavours — `Native` (pure Rust, scalar) and
//! `Xla` (batched through the compiled Pallas/JAX artifact) — implementing
//! the same trait, so the coordinator can route batches to either and the
//! `bench_hashing` ablation can compare them on identical inputs. The two
//! flavours are bit-identical on non-boundary inputs because both evaluate
//! exactly `floor((x + η) * inv_two_eps)` in f32.

use anyhow::{anyhow, Result};

use crate::baselines::brute::PairwiseDistance;
use crate::lsh::{BucketKey, GridHasher};

use super::Runtime;

/// Batched hashing: point batch → per-point `t` bucket keys.
pub trait HashingEngine {
    /// `xs` is row-major `n × dim`; returns `n` key vectors of length `t`.
    fn keys_batch(&mut self, xs: &[f32], n: usize) -> Result<Vec<Vec<BucketKey>>>;

    /// Keys of a single point written into a reusable row (length `t` on
    /// return). Default routes through [`Self::keys_batch`] (allocates);
    /// the native engine overrides with the scratch-buffer path so the
    /// serve façade's per-op writes stay allocation-free.
    fn key_row_into(&mut self, x: &[f32], out: &mut Vec<BucketKey>) -> Result<()> {
        let keys = self.keys_batch(x, 1)?;
        out.clear();
        out.extend_from_slice(&keys[0]);
        Ok(())
    }

    fn describe(&self) -> String;
}

/// Pure-Rust scalar hashing.
pub struct NativeHashing {
    pub hasher: GridHasher,
    scratch: Vec<i32>,
}

impl NativeHashing {
    pub fn new(hasher: GridHasher) -> Self {
        NativeHashing { hasher, scratch: Vec::new() }
    }
}

impl HashingEngine for NativeHashing {
    fn keys_batch(&mut self, xs: &[f32], n: usize) -> Result<Vec<Vec<BucketKey>>> {
        let d = self.hasher.dim;
        debug_assert_eq!(xs.len(), n * d);
        Ok((0..n)
            .map(|i| self.hasher.keys(&xs[i * d..(i + 1) * d], &mut self.scratch))
            .collect())
    }

    fn key_row_into(&mut self, x: &[f32], out: &mut Vec<BucketKey>) -> Result<()> {
        out.clear();
        out.resize(self.hasher.t, 0);
        self.hasher.keys_into(x, &mut self.scratch, out);
        Ok(())
    }

    fn describe(&self) -> String {
        format!("native(d={}, t={})", self.hasher.dim, self.hasher.t)
    }
}

/// Hashing through the AOT `hash_d{d}_t{t}_b{b}` artifact: pads the batch
/// to the compiled batch size, runs the Pallas quantizer, and reduces the
/// returned `t × b × d` grid coordinates to bucket keys with the same
/// combiner as the native path.
pub struct XlaHashing {
    runtime: Runtime,
    artifact: String,
    pub hasher: GridHasher,
    b: usize,
    padded: Vec<f32>,
}

impl XlaHashing {
    /// Pick the artifact matching the hasher's (d, t); errors when no
    /// compiled variant fits (fall back to native in that case).
    pub fn new(mut runtime: Runtime, hasher: GridHasher) -> Result<Self> {
        let (d, t) = (hasher.dim, hasher.t);
        let artifact = runtime
            .artifacts
            .values()
            .filter(|a| {
                a.kind == "hash"
                    && a.params.get("d") == Some(&d)
                    && a.params.get("t") == Some(&t)
            })
            .map(|a| a.name.clone())
            .next()
            .ok_or_else(|| anyhow!("no hash artifact for d={d}, t={t}"))?;
        let b = *runtime.meta(&artifact)?.params.get("b").unwrap();
        runtime.load(&artifact)?;
        Ok(XlaHashing { runtime, artifact, hasher, b, padded: Vec::new() })
    }

    pub fn batch_size(&self) -> usize {
        self.b
    }
}

impl HashingEngine for XlaHashing {
    fn keys_batch(&mut self, xs: &[f32], n: usize) -> Result<Vec<Vec<BucketKey>>> {
        let (d, t, b) = (self.hasher.dim, self.hasher.t, self.b);
        debug_assert_eq!(xs.len(), n * d);
        let inv = [self.hasher.inv_two_eps()];
        let mut out: Vec<Vec<BucketKey>> = Vec::with_capacity(n);
        let mut start = 0;
        while start < n {
            let chunk = (n - start).min(b);
            // pad the tail chunk with zeros up to the compiled batch size
            self.padded.clear();
            self.padded.extend_from_slice(&xs[start * d..(start + chunk) * d]);
            self.padded.resize(b * d, 0.0);
            let coords = self.runtime.execute_f32_to_i32(
                &self.artifact,
                &[&self.padded, &self.hasher.etas, &inv],
            )?;
            debug_assert_eq!(coords.len(), t * b * d);
            for j in 0..chunk {
                let keys = (0..t)
                    .map(|i| {
                        let off = i * b * d + j * d;
                        GridHasher::key_from_coords(&coords[off..off + d])
                    })
                    .collect();
                out.push(keys);
            }
            start += chunk;
        }
        Ok(out)
    }

    fn describe(&self) -> String {
        format!("xla({}, b={})", self.artifact, self.b)
    }
}

/// Distance tiles through the AOT `dist_d{d}_q{q}_m{m}` artifact,
/// implementing the exact-DBSCAN baseline's [`PairwiseDistance`].
pub struct XlaDistance {
    runtime: Runtime,
    artifact: String,
    q: usize,
    m: usize,
    d: usize,
    qbuf: Vec<f32>,
    cbuf: Vec<f32>,
}

impl XlaDistance {
    pub fn new(mut runtime: Runtime, d: usize) -> Result<Self> {
        let artifact = runtime
            .artifacts
            .values()
            .filter(|a| a.kind == "dist" && a.params.get("d") == Some(&d))
            .map(|a| a.name.clone())
            .next()
            .ok_or_else(|| anyhow!("no dist artifact for d={d}"))?;
        let meta = runtime.meta(&artifact)?.clone();
        let q = *meta.params.get("q").unwrap();
        let m = *meta.params.get("m").unwrap();
        runtime.load(&artifact)?;
        Ok(XlaDistance { runtime, artifact, q, m, d, qbuf: Vec::new(), cbuf: Vec::new() })
    }

    pub fn tile_shape(&self) -> (usize, usize) {
        (self.q, self.m)
    }
}

/// Padding coordinate far from all real data so padded rows/cols never pass
/// an ε-threshold.
const PAD: f32 = 1.0e15;

impl PairwiseDistance for XlaDistance {
    fn dist2(
        &mut self,
        q: &[f32],
        nq: usize,
        c: &[f32],
        nc: usize,
        d: usize,
        out: &mut [f32],
    ) {
        assert_eq!(d, self.d, "XlaDistance compiled for d={}, got {d}", self.d);
        assert!(nq <= self.q && nc <= self.m, "tile exceeds compiled shape");
        self.qbuf.clear();
        self.qbuf.extend_from_slice(q);
        self.qbuf.resize(self.q * d, PAD);
        self.cbuf.clear();
        self.cbuf.extend_from_slice(c);
        self.cbuf.resize(self.m * d, -PAD);
        let full = self
            .runtime
            .execute_f32_to_f32(&self.artifact, &[&self.qbuf, &self.cbuf])
            .expect("distance artifact execution failed");
        for i in 0..nq {
            out[i * nc..(i + 1) * nc]
                .copy_from_slice(&full[i * self.m..i * self.m + nc]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_engine_matches_hasher() {
        let hasher = GridHasher::new(4, 3, 0.75, 9);
        let mut eng = NativeHashing::new(hasher.clone());
        let xs = vec![0.1f32, 0.2, 0.3, -4.0, 5.0, -6.0];
        let keys = eng.keys_batch(&xs, 2).unwrap();
        let mut scratch = Vec::new();
        assert_eq!(keys[0], hasher.keys(&xs[0..3], &mut scratch));
        assert_eq!(keys[1], hasher.keys(&xs[3..6], &mut scratch));
    }

    // XLA-engine parity tests live in rust/tests/runtime_artifacts.rs (they
    // need the compiled artifacts on disk).
}
