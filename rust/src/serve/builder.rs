//! [`EngineBuilder`] — fluent construction of any serve backend,
//! replacing the old `DbscanConfig` / `ShardConfig` / `EngineKind`
//! triplet every consumer had to wire up by hand.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::driver::{make_engine, EngineKind};
use crate::dbscan::{ConnKind, DbscanConfig};
use crate::replica::{channel_pair, LogShipper, ReadPreference, ReadRouter, ReplicaEngine};
use crate::shard::{
    FaultPlan, PlacementPolicy, ReshardMode, ShardConfig, StitchMode,
};

use super::durable::{DurableEngine, DEFAULT_CHECKPOINT_EVERY};
use super::index::IndexPolicy;
use super::inline::InlineEngine;
use super::sharded::ShardedServe;
use super::ClusterEngine;

/// Where the clustering structure lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// One in-process `DynamicDbscan` behind the façade — lowest latency,
    /// exact Algorithm-2 semantics.
    Single,
    /// S parallel shard workers with ghost replication and incremental
    /// cross-shard stitching. `Sharded(1)` degenerates to an inline core
    /// (no router/channels) but keeps the sharded publish plumbing.
    Sharded(usize),
}

/// Fluent configuration for a [`ClusterEngine`].
///
/// ```no_run
/// use dyn_dbscan::serve::{Backend, ClusterEngine, EngineBuilder};
///
/// let mut engine = EngineBuilder::new(8)
///     .k(10)
///     .t(10)
///     .eps(0.75)
///     .backend(Backend::Sharded(4))
///     .seed(42)
///     .build()
///     .unwrap();
/// engine.upsert(1, &[0.0; 8]);
/// let view = engine.publish();
/// assert_eq!(view.pending_writes(), 0);
/// ```
#[derive(Clone, Debug)]
pub struct EngineBuilder {
    dbscan: DbscanConfig,
    backend: Backend,
    conn: ConnKind,
    stitch: Option<StitchMode>,
    hashing: EngineKind,
    seed: u64,
    queue: usize,
    block_side: u32,
    ghost_margin: u32,
    routing_dims: usize,
    placement: Option<PlacementPolicy>,
    reshard: ReshardMode,
    metrics: bool,
    index: IndexPolicy,
    persist: Option<PathBuf>,
    checkpoint_every: u64,
    incremental_ckpt: bool,
    publish_timeout_ms: u64,
    faults: Option<FaultPlan>,
    replicas: usize,
    read_pref: ReadPreference,
    max_staleness: u64,
}

impl EngineBuilder {
    /// Start from the paper's default hyper-parameters (k=10, t=10,
    /// ε=0.75) at the given dimensionality.
    pub fn new(dim: usize) -> Self {
        Self::from_config(DbscanConfig { dim, ..Default::default() })
    }

    /// Start from an existing [`DbscanConfig`].
    pub fn from_config(dbscan: DbscanConfig) -> Self {
        EngineBuilder {
            dbscan,
            backend: Backend::Single,
            conn: ConnKind::Leveled,
            stitch: None,
            hashing: EngineKind::Native,
            seed: 42,
            queue: 8,
            block_side: 8,
            ghost_margin: 2,
            routing_dims: 0,
            placement: None,
            reshard: ReshardMode::Off,
            metrics: true,
            index: IndexPolicy::default(),
            persist: None,
            checkpoint_every: DEFAULT_CHECKPOINT_EVERY,
            incremental_ckpt: true,
            publish_timeout_ms: 10_000,
            faults: None,
            replicas: 0,
            read_pref: ReadPreference::RoundRobin,
            max_staleness: 0,
        }
    }

    /// Core threshold (bucket size conferring core-ness).
    pub fn k(mut self, k: usize) -> Self {
        self.dbscan.k = k;
        self
    }

    /// Number of grid-LSH hash functions.
    pub fn t(mut self, t: usize) -> Self {
        self.dbscan.t = t;
        self
    }

    /// Neighborhood radius (bucket side = 2ε).
    pub fn eps(mut self, eps: f32) -> Self {
        self.dbscan.eps = eps;
        self
    }

    /// Adopt unattached non-core points when a fresh core arrives
    /// (serving-mode extension; off = exact Algorithm 2).
    pub fn eager_attach(mut self, on: bool) -> Self {
        self.dbscan.eager_attach = on;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Single in-process structure or S shard workers.
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Connectivity layer (default [`ConnKind::Leveled`]; the flat modes
    /// are ablations and force full-rebuild publishing).
    pub fn conn(mut self, conn: ConnKind) -> Self {
        self.conn = conn;
        self
    }

    /// Publish strategy. Defaults to [`StitchMode::Delta`] on the leveled
    /// connectivity and [`StitchMode::FullRebuild`] on the flat modes.
    pub fn stitch(mut self, stitch: StitchMode) -> Self {
        self.stitch = Some(stitch);
        self
    }

    /// Hash-stage engine for the single backend (`Xla` routes insert
    /// hashing through the AOT Pallas artifact, falling back to native
    /// when no artifact matches). Shard workers always hash natively.
    pub fn hashing(mut self, hashing: EngineKind) -> Self {
        self.hashing = hashing;
        self
    }

    /// Bounded op-channel capacity per shard worker, in batches.
    pub fn queue(mut self, queue: usize) -> Self {
        self.queue = queue;
        self
    }

    /// Router block edge length, in grid cells (sharded backend).
    pub fn block_side(mut self, block_side: u32) -> Self {
        self.block_side = block_side;
        self
    }

    /// Ghost-replication margin, in grid cells (sharded backend).
    pub fn ghost_margin(mut self, ghost_margin: u32) -> Self {
        self.ghost_margin = ghost_margin;
        self
    }

    /// Cell axes used for block routing (sharded backend; 0 = auto).
    pub fn routing_dims(mut self, routing_dims: usize) -> Self {
        self.routing_dims = routing_dims;
        self
    }

    /// Cell→shard placement policy (sharded backend; default
    /// [`PlacementPolicy::CellGraph`] — greedy cell-graph partitioning.
    /// [`PlacementPolicy::BlockHash`] keeps the legacy stateless scatter).
    /// Rejected at build time on the single backend.
    pub fn placement(mut self, policy: PlacementPolicy) -> Self {
        self.placement = Some(policy);
        self
    }

    /// Live resharding (sharded backend; default [`ReshardMode::Off`]).
    /// `Auto { max_cells_per_publish }` migrates up to that many cells
    /// from the hottest to the coldest shard per publish when the load
    /// imbalance trips the trigger. Requires ≥ 2 shards and `CellGraph`
    /// placement; rejected at build time otherwise.
    pub fn reshard(mut self, mode: ReshardMode) -> Self {
        self.reshard = mode;
        self
    }

    /// Live metrics recording (default on): per-op latency histograms,
    /// publish/update stage spans and structural gauges, pulled via
    /// [`ClusterEngine::metrics`]. Off turns the registry into a no-op
    /// recorder — the `obs_overhead` bench baseline.
    pub fn metrics(mut self, on: bool) -> Self {
        self.metrics = on;
        self
    }

    /// Make the engine durable: write-ahead-log every mutation into
    /// `dir/wal.log`, spill periodic checkpoints into
    /// `dir/checkpoint.ckpt`, and on `build()` **recover** whatever state
    /// a previous engine persisted there (empty or missing directory =
    /// fresh start). See [`super::DurableEngine`] for the contract.
    pub fn persist(mut self, dir: impl Into<PathBuf>) -> Self {
        self.persist = Some(dir.into());
        self
    }

    /// Publishes between checkpoint spills (default 8; persistent engines
    /// only). Lower = shorter WAL replay after a crash, more spill work.
    pub fn persist_every(mut self, publishes: u64) -> Self {
        self.checkpoint_every = publishes.max(1);
        self
    }

    /// Incremental checkpoint spills (default on; persistent engines
    /// only): between full spills, write `DDCKPT03` deltas carrying only
    /// the coordinate chunks dirtied since the last full spill. Off pins
    /// every spill to a full `DDCKPT02` — the bootstrap-equivalence test
    /// baseline and the conservative fallback.
    pub fn incremental_checkpoints(mut self, on: bool) -> Self {
        self.incremental_ckpt = on;
        self
    }

    /// Attach `n` WAL-shipped read replicas (requires [`Self::persist`];
    /// build with [`Self::build_replicated`]). Each replica bootstraps
    /// from the checkpoint chain and applies the leader's fsynced frames
    /// at every publish; see [`crate::replica`] for the contract.
    pub fn replicate(mut self, n: usize) -> Self {
        self.replicas = n;
        self
    }

    /// How the [`ReadRouter`] picks the replica answering each read
    /// (default [`ReadPreference::RoundRobin`]).
    pub fn read_preference(mut self, pref: ReadPreference) -> Self {
        self.read_pref = pref;
        self
    }

    /// Staleness bound for routed reads, in **leader publishes** (default
    /// 0 — always catch the chosen replica up before answering). A view
    /// returned by `ReadRouter::read` never trails the leader by more
    /// publish barriers than this.
    pub fn max_staleness(mut self, publishes: u64) -> Self {
        self.max_staleness = publishes;
        self
    }

    /// How long a publish barrier waits per outstanding shard reply
    /// before quarantining the worker as wedged (sharded backend;
    /// default 10 s).
    pub fn publish_timeout_ms(mut self, ms: u64) -> Self {
        self.publish_timeout_ms = ms.max(1);
        self
    }

    /// Per-snapshot ε-cell spatial index (default on): sublinear
    /// `epsilon_neighbors`/`k_nearest` on published views, maintained in
    /// `O(Δ)` across publishes. Off pins every view to the `O(n·d)` scan
    /// oracle — the indexed-vs-scan bench baseline.
    pub fn spatial_index(mut self, on: bool) -> Self {
        self.index.enabled = on;
        self
    }

    /// Index cell side as a multiple of ε (default 2.0, the write-path
    /// grid scale). Smaller cells probe more buckets with fewer points
    /// each. Must be finite and positive (validated at `build`).
    pub fn index_cell_factor(mut self, factor: f32) -> Self {
        self.index.cell_factor = factor;
        self
    }

    /// Dimensionality ceiling for the index (default 12): past it the
    /// `≤3^d` cell-probe fan-out beats the scan, so views fall back to
    /// the scan oracle.
    pub fn index_max_dim(mut self, max_dim: usize) -> Self {
        self.index.max_dim = max_dim;
        self
    }

    /// Rebuild the index from scratch at every publish instead of
    /// delta-maintaining it on the update path — the
    /// `StitchMode::FullRebuild` analogue, kept as an ablation/fallback.
    pub fn index_rebuild(mut self, on: bool) -> Self {
        self.index.rebuild_at_publish = on;
        self
    }

    /// Test-only fault injection for one shard worker (see
    /// `shard::FaultPlan`); ignored by the single backend.
    #[doc(hidden)]
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// The publish strategy `build` will use (explicit choice, or the
    /// connectivity-dependent default).
    pub fn effective_stitch(&self) -> StitchMode {
        self.stitch.unwrap_or(if self.conn.supports_comp_tracking() {
            StitchMode::Delta
        } else {
            StitchMode::FullRebuild
        })
    }

    /// Reject contradictory configuration; returns the resolved publish
    /// strategy on success.
    fn validate(&self) -> Result<StitchMode> {
        let stitch = self.effective_stitch();
        if stitch == StitchMode::Delta && !self.conn.supports_comp_tracking() {
            return Err(anyhow!(
                "StitchMode::Delta needs stable component ids, which only \
                 ConnKind::Leveled provides; drop .stitch(Delta) or use \
                 .conn(ConnKind::Leveled)"
            ));
        }
        if !(self.index.cell_factor.is_finite() && self.index.cell_factor > 0.0) {
            return Err(anyhow!(
                "index_cell_factor must be finite and positive, got {}",
                self.index.cell_factor
            ));
        }
        if self.backend == Backend::Single {
            if self.placement.is_some() {
                return Err(anyhow!(
                    "placement() configures the sharded router's cell→shard \
                     map; the single backend has no router — drop \
                     .placement(..) or use Backend::Sharded(..)"
                ));
            }
            if self.reshard != ReshardMode::Off {
                return Err(anyhow!(
                    "reshard() migrates cells between shard workers; the \
                     single backend has none — drop .reshard(..) or use \
                     Backend::Sharded(..)"
                ));
            }
        }
        let placement = self.placement.unwrap_or(PlacementPolicy::CellGraph);
        if let ReshardMode::Auto { max_cells_per_publish } = self.reshard {
            if max_cells_per_publish == 0 {
                return Err(anyhow!(
                    "reshard(Auto) needs max_cells_per_publish >= 1 — a \
                     zero budget can never migrate anything"
                ));
            }
            if let Backend::Sharded(shards) = self.backend {
                if shards < 2 {
                    return Err(anyhow!(
                        "reshard(Auto) is meaningless at one shard — there \
                         is nowhere to migrate to"
                    ));
                }
            }
            if placement != PlacementPolicy::CellGraph {
                return Err(anyhow!(
                    "reshard(Auto) requires PlacementPolicy::CellGraph — \
                     BlockHash assignments are stateless and cannot migrate"
                ));
            }
        }
        Ok(stitch)
    }

    /// Construct one bare (non-durable) backend from this configuration.
    /// Called once by [`Self::build`]; [`Self::build_replicated`] calls
    /// it once per engine — the leader and every follower are built from
    /// the same deterministic configuration, which is what makes shipped
    /// replay bit-reproducible.
    fn build_inner(&self, stitch: StitchMode) -> Result<Box<dyn ClusterEngine>> {
        let placement = self.placement.unwrap_or(PlacementPolicy::CellGraph);
        Ok(match self.backend {
            Backend::Single => {
                let hashing = make_engine(&self.dbscan, self.seed, self.hashing)?;
                Box::new(InlineEngine::new(
                    self.dbscan.clone(),
                    self.conn,
                    stitch,
                    self.seed,
                    hashing,
                    self.metrics,
                    self.index,
                ))
            }
            Backend::Sharded(shards) => {
                // note: shard workers always hash natively; a non-native
                // `hashing` choice applies to the single backend only
                // (the CLI surfaces this to the user — library consumers
                // get silent, documented behaviour instead of stderr)
                let mut scfg =
                    ShardConfig::new(self.dbscan.clone(), shards, self.seed);
                scfg.conn = self.conn;
                scfg.stitch = stitch;
                scfg.queue = self.queue;
                scfg.block_side = self.block_side;
                scfg.ghost_margin = self.ghost_margin;
                scfg.routing_dims = self.routing_dims;
                scfg.placement = placement;
                scfg.reshard = self.reshard;
                scfg.metrics = self.metrics;
                scfg.publish_timeout_ms = self.publish_timeout_ms;
                scfg.faults = self.faults.clone();
                Box::new(ShardedServe::new(scfg, self.index))
            }
        })
    }

    /// Construct the engine. Errors on contradictory configuration
    /// (delta publishing on a connectivity without stable component ids)
    /// or a failed hash-stage setup.
    pub fn build(self) -> Result<Box<dyn ClusterEngine>> {
        if self.replicas > 0 {
            return Err(anyhow!(
                "replicate({}) builds a leader plus read replicas — call \
                 build_replicated() instead of build()",
                self.replicas
            ));
        }
        let stitch = self.validate()?;
        let inner = self.build_inner(stitch)?;
        match self.persist {
            None => Ok(inner),
            Some(dir) => {
                let mut eng =
                    DurableEngine::open(&dir, inner, self.checkpoint_every)
                        .with_context(|| {
                            format!("opening persist directory {}", dir.display())
                        })?;
                eng.set_incremental(self.incremental_ckpt);
                Ok(Box::new(eng))
            }
        }
    }

    /// Construct a replicated deployment: the durable **leader** plus a
    /// [`ReadRouter`] over [`Self::replicate`]`(n)` read replicas.
    /// Requires [`Self::persist`] — replicas bootstrap from the
    /// checkpoint chain and the leader ships its fsynced WAL frames to
    /// them at every publish. See [`crate::replica`] for read,
    /// staleness and promotion semantics.
    pub fn build_replicated(
        self,
    ) -> Result<(Box<dyn ClusterEngine>, ReadRouter)> {
        let Some(dir) = self.persist.clone() else {
            return Err(anyhow!(
                "build_replicated() needs .persist(dir): replicas bootstrap \
                 from the checkpoint chain and ship the on-disk WAL"
            ));
        };
        if self.replicas == 0 {
            return Err(anyhow!(
                "build_replicated() needs .replicate(n) with n >= 1"
            ));
        }
        let stitch = self.validate()?;
        // the leader recovers first, so followers bootstrap from a
        // directory the leader has already validated
        let mut leader =
            DurableEngine::open(&dir, self.build_inner(stitch)?, self.checkpoint_every)
                .with_context(|| {
                    format!("opening persist directory {}", dir.display())
                })?;
        leader.set_incremental(self.incremental_ckpt);
        let mut shipper = LogShipper::new();
        let clock = shipper.publish_clock();
        let mut followers = Vec::with_capacity(self.replicas);
        for i in 0..self.replicas {
            let (tx, rx) = channel_pair();
            let rep = ReplicaEngine::bootstrap(
                self.build_inner(stitch)?,
                &dir,
                rx,
                Arc::clone(&clock),
            )
            .with_context(|| {
                format!("bootstrapping replica {i} from {}", dir.display())
            })?;
            shipper.subscribe(tx, rep.floor());
            followers.push(rep);
        }
        leader.set_shipper(shipper);
        let router = ReadRouter::new(followers, self.read_pref, self.max_staleness);
        Ok((Box::new(leader), router))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stitch_defaults_follow_the_connectivity() {
        let b = EngineBuilder::new(2);
        assert_eq!(b.effective_stitch(), StitchMode::Delta);
        let b = EngineBuilder::new(2).conn(ConnKind::Repair);
        assert_eq!(b.effective_stitch(), StitchMode::FullRebuild);
        let b = EngineBuilder::new(2).conn(ConnKind::Paper).stitch(StitchMode::Delta);
        assert_eq!(b.effective_stitch(), StitchMode::Delta);
    }

    #[test]
    fn delta_on_flat_connectivity_is_rejected() {
        let err = EngineBuilder::new(2)
            .conn(ConnKind::Repair)
            .stitch(StitchMode::Delta)
            .build();
        assert!(err.is_err());
        // the connectivity-dependent default resolves the conflict
        assert!(EngineBuilder::new(2).conn(ConnKind::Repair).build().is_ok());
    }

    #[test]
    fn index_knobs_and_validation() {
        // default: index on at modest dims
        let mut eng = EngineBuilder::new(2).k(3).t(4).build().unwrap();
        assert!(eng.publish().has_spatial_index());
        let _ = eng.finish();
        // off, past the dim ceiling, or rebuild-mode all still build
        let mut eng = EngineBuilder::new(2).k(3).t(4).spatial_index(false).build().unwrap();
        assert!(!eng.publish().has_spatial_index());
        let _ = eng.finish();
        let mut eng = EngineBuilder::new(2).k(3).t(4).index_max_dim(1).build().unwrap();
        assert!(!eng.publish().has_spatial_index());
        let _ = eng.finish();
        let mut eng = EngineBuilder::new(2)
            .k(3)
            .t(4)
            .index_cell_factor(1.0)
            .index_rebuild(true)
            .build()
            .unwrap();
        eng.upsert(1, &[0.25, 0.25]);
        let view = eng.publish();
        assert!(view.has_spatial_index());
        assert_eq!(view.epsilon_neighbors(&[0.25, 0.25]), vec![1]);
        let _ = eng.finish();
        // invalid cell factor is rejected at build
        assert!(EngineBuilder::new(2).index_cell_factor(0.0).build().is_err());
        assert!(EngineBuilder::new(2).index_cell_factor(f32::NAN).build().is_err());
    }

    #[test]
    fn placement_and_reshard_validation() {
        // single backend has no router: both knobs are rejected
        assert!(EngineBuilder::new(2)
            .placement(PlacementPolicy::CellGraph)
            .build()
            .is_err());
        assert!(EngineBuilder::new(2)
            .reshard(ReshardMode::Auto { max_cells_per_publish: 8 })
            .build()
            .is_err());
        // Auto needs somewhere to migrate to
        assert!(EngineBuilder::new(2)
            .backend(Backend::Sharded(1))
            .reshard(ReshardMode::Auto { max_cells_per_publish: 8 })
            .build()
            .is_err());
        // Auto over a stateless assignment cannot migrate
        assert!(EngineBuilder::new(2)
            .backend(Backend::Sharded(2))
            .placement(PlacementPolicy::BlockHash)
            .reshard(ReshardMode::Auto { max_cells_per_publish: 8 })
            .build()
            .is_err());
        // a zero migration budget is a configuration bug, not a no-op
        assert!(EngineBuilder::new(2)
            .backend(Backend::Sharded(2))
            .reshard(ReshardMode::Auto { max_cells_per_publish: 0 })
            .build()
            .is_err());
        // the valid combinations build
        for policy in [PlacementPolicy::BlockHash, PlacementPolicy::CellGraph] {
            let mut eng = EngineBuilder::new(2)
                .k(3)
                .t(4)
                .backend(Backend::Sharded(2))
                .placement(policy)
                .build()
                .unwrap();
            eng.upsert(1, &[0.5, 0.5]);
            assert_eq!(eng.publish().live_points(), 1);
            let _ = eng.finish();
        }
        let mut eng = EngineBuilder::new(2)
            .k(3)
            .t(4)
            .backend(Backend::Sharded(2))
            .reshard(ReshardMode::Auto { max_cells_per_publish: 8 })
            .build()
            .unwrap();
        eng.upsert(1, &[0.5, 0.5]);
        assert_eq!(eng.publish().live_points(), 1);
        let _ = eng.finish();
    }

    #[test]
    fn builds_every_backend() {
        for backend in [Backend::Single, Backend::Sharded(1), Backend::Sharded(3)] {
            let mut eng = EngineBuilder::new(3)
                .k(4)
                .t(6)
                .eps(0.5)
                .backend(backend)
                .seed(7)
                .build()
                .unwrap();
            assert_eq!(eng.dim(), 3);
            eng.upsert(1, &[0.0, 0.0, 0.0]);
            let view = eng.publish();
            assert_eq!(view.live_points(), 1);
            assert_eq!(view.label(1), Some(-1));
            let _ = eng.finish();
        }
    }
}
