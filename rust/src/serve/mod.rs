//! `serve` — the unified serving API: one typed engine façade over every
//! clustering backend.
//!
//! Before this module the repo had two incompatible front doors —
//! `DynamicDbscan` (internal `PointId`s, mutable synchronous reads) and
//! `ShardedEngine` (external keys, snapshot reads) — and every consumer
//! re-implemented its own glue. `serve` replaces that with one surface:
//!
//! ```text
//!            EngineBuilder ───────────── build() ──────────────┐
//!   .backend(Single | Sharded(S))  .conn(Leveled|Repair|Paper) │
//!   .stitch(Delta | FullRebuild)   .hashing(Native | Xla)      ▼
//!                                             Box<dyn ClusterEngine>
//!                                    ┌──────────────┴──────────────┐
//!                              InlineEngine                  ShardedServe
//!                        (DynamicDbscan + ext map)      (ShardedEngine wrapper)
//!                                    └──────────────┬──────────────┘
//!        writes:  upsert / remove / apply(batch)    │ explicit publish()
//!        reads:   SnapshotView (versioned, immutable, CoW + pinned ε-cell index)
//!                   label · cluster_members · cluster_sizes ·
//!                   epsilon_neighbors · k_nearest · stats · version ·
//!                   pending_writes
//!        events:  watch() → ClusterEvents (merge / split / moved per publish)
//! ```
//!
//! **Write model.** All writes are external-key-first (`ext: u64`, the
//! caller's stable id) and buffered; nothing becomes visible to readers
//! until an explicit [`ClusterEngine::publish`], which barriers the
//! backend and emits the next [`SnapshotView`]. `upsert` replaces a live
//! point (delete + insert); `remove` panics on an unknown key — the same
//! contract on every backend.
//!
//! **Read model / freshness.** Reads go through [`SnapshotView`] — an
//! immutable CoW handle pinned to one publish. `version()` identifies the
//! publish; `pending_writes()` reports how many accepted writes the view
//! does *not* reflect (0 on a view returned by `publish` —
//! read-your-publishes). This fixes the historical `cluster_of` staleness
//! trap: freshness is now visible in the type you read from. Neighborhood
//! reads (`epsilon_neighbors`, `k_nearest`) are answered sublinearly from
//! a per-snapshot ε-cell [`index::SpatialIndex`] delta-maintained across
//! publishes ([`IndexPolicy`] on the builder governs cell size and
//! fallback); `cluster_members` reads a lazily built per-view inverted
//! index. See [`snapshot`] for the full contract.
//!
//! **Events.** [`ClusterEngine::watch`] subscribes to per-publish
//! [`ClusterEvent`]s (merges, splits, formed/dissolved clusters, per-point
//! moves) derived from the stable-component change plumbing — no snapshot
//! polling. See [`events`] for semantics.
//!
//! **Metrics.** One [`Stats`] struct — op counters, pending writes, and
//! the add/delete/publish latency histograms — replaces the previously
//! duplicated per-backend accessors. [`ClusterEngine::metrics`] widens it
//! to a [`MetricsSnapshot`]: per-stage publish/update histograms, the
//! latest per-publish [`PublishTrace`] and the structural gauges, all
//! pulled live from the backend's lock-free [`crate::obs::Metrics`]
//! registry and renderable as Prometheus text exposition
//! ([`MetricsSnapshot::render_prometheus`]).

pub mod builder;
pub(crate) mod durable;
pub mod driver;
pub mod events;
pub mod index;
mod inline;
mod sharded;
pub mod snapshot;

pub use builder::{Backend, EngineBuilder};
pub use durable::DurableEngine;
pub use events::{ClusterEvent, ClusterEvents};
pub use index::IndexPolicy;
pub use snapshot::{SnapshotStats, SnapshotView};

pub use crate::coordinator::driver::EngineKind;
pub use crate::dbscan::ConnKind;
pub use crate::shard::{EngineError, PlacementPolicy, ReshardMode, StitchMode};
#[doc(hidden)]
pub use crate::shard::FaultPlan;

use crate::dbscan::RepairStats;
use crate::obs::PublishTrace;
use crate::util::stats::LatencyHisto;

/// One buffered update in a [`ClusterEngine::apply`] batch. `Upsert`
/// borrows its coordinates — the batch path copies them at most once
/// (into the engine's wire/arena storage).
#[derive(Clone, Copy, Debug)]
pub enum Update<'a> {
    Upsert { ext: u64, coords: &'a [f32] },
    Remove { ext: u64 },
}

/// Backend health, reported on [`Stats::health`].
///
/// The sharded backend degrades instead of panicking when a worker dies
/// or wedges (send/recv channel errors, publish-barrier timeout): the
/// failed shards are quarantined, writes routed to them are dropped, and
/// reads keep serving the last published snapshot. The engine respawns
/// quarantined workers at the start of the next publish — re-seeding each
/// from the façade's authoritative live-point state (itself recovered
/// from checkpoint + WAL when persistence is on) — after which health
/// returns to `Ok`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Health {
    /// every shard worker answering
    Ok,
    /// these shard workers are down or wedged; their write slice is
    /// stale until the next publish respawns them
    Degraded {
        /// quarantined shard ids, ascending
        shards: Vec<u32>,
    },
}

impl Health {
    /// `true` when every worker is answering.
    pub fn is_ok(&self) -> bool {
        matches!(self, Health::Ok)
    }

    /// Number of quarantined shards (0 when healthy).
    pub fn degraded_shards(&self) -> usize {
        match self {
            Health::Ok => 0,
            Health::Degraded { shards } => shards.len(),
        }
    }
}

/// The unified metrics surface of a serve engine — op counters plus the
/// latency histograms that were previously scattered across
/// `EngineOutcome` fields and per-engine accessors.
///
/// `inserts`/`deletes`/`pending_writes` count **accepted façade writes**:
/// an upsert that replaces a live point is one write (the sharded
/// engine's internal delete + re-insert fan-out is not surfaced here,
/// except through `ghost_inserts`, which stays an engine-level counter).
///
/// `add_latency`/`delete_latency` are **live on every backend**: sharded
/// workers record each op into the engine's shared striped-atomic
/// registry ([`crate::obs::Metrics`]), so a mid-run
/// [`ClusterEngine::stats`] sees the histograms as of the last recorded
/// op — no finish barrier needed. Only `conn` (the connectivity-layer
/// repair counters) still merges at [`ClusterEngine::finish`] on the
/// sharded backend; mid-run it reads zero there.
#[derive(Clone, Debug)]
pub struct Stats {
    /// shard workers (1 = the inline/single backend)
    pub shards: usize,
    /// primary inserts accepted
    pub inserts: u64,
    /// deletes accepted
    pub deletes: u64,
    /// ghost replicas created by boundary replication (sharded only)
    pub ghost_inserts: u64,
    pub publishes: u64,
    /// writes accepted since the last publish (not yet readable)
    pub pending_writes: u64,
    pub add_latency: LatencyHisto,
    pub delete_latency: LatencyHisto,
    /// end-to-end publish latency as seen through the façade
    pub publish_latency: LatencyHisto,
    /// connectivity-layer counters (summed across shards at finish)
    pub conn: RepairStats,
    /// backend health: `Degraded { shards }` while any worker is down
    pub health: Health,
}

impl Stats {
    /// Ghost replicas per primary insert (0 on the single backend).
    pub fn ghost_ratio(&self) -> f64 {
        if self.inserts == 0 {
            0.0
        } else {
            self.ghost_inserts as f64 / self.inserts as f64
        }
    }

    /// Render the op counters and latency histograms as Prometheus text
    /// exposition (`dyndbscan_` prefix, `_total` counters, `_ns` duration
    /// summaries). [`MetricsSnapshot::render_prometheus`] extends this
    /// with the stage breakdowns and structural gauges.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        prom_scalar(
            &mut out,
            "dyndbscan_inserts_total",
            "Primary inserts accepted by the facade",
            "counter",
            self.inserts as f64,
        );
        prom_scalar(
            &mut out,
            "dyndbscan_deletes_total",
            "Deletes accepted by the facade",
            "counter",
            self.deletes as f64,
        );
        prom_scalar(
            &mut out,
            "dyndbscan_ghost_inserts_total",
            "Ghost replicas created by boundary replication",
            "counter",
            self.ghost_inserts as f64,
        );
        prom_scalar(
            &mut out,
            "dyndbscan_publishes_total",
            "Snapshot publishes",
            "counter",
            self.publishes as f64,
        );
        prom_scalar(
            &mut out,
            "dyndbscan_shards",
            "Shard workers (1 = single backend)",
            "gauge",
            self.shards as f64,
        );
        prom_scalar(
            &mut out,
            "dyndbscan_pending_writes",
            "Writes accepted since the last publish",
            "gauge",
            self.pending_writes as f64,
        );
        prom_scalar(
            &mut out,
            "dyndbscan_degraded_shards",
            "Quarantined (down or wedged) shard workers",
            "gauge",
            self.health.degraded_shards() as f64,
        );
        prom_summary(
            &mut out,
            "dyndbscan_add_latency_ns",
            "Per-op insert latency",
            None,
            &self.add_latency,
        );
        prom_summary(
            &mut out,
            "dyndbscan_delete_latency_ns",
            "Per-op delete latency",
            None,
            &self.delete_latency,
        );
        prom_summary(
            &mut out,
            "dyndbscan_publish_latency_ns",
            "End-to-end publish latency",
            None,
            &self.publish_latency,
        );
        out
    }
}

/// One `# HELP`/`# TYPE` header plus a single sample line.
fn prom_scalar(out: &mut String, name: &str, help: &str, kind: &str, v: f64) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    out.push_str(&format!("{name} {v}\n"));
}

/// Series lines of one summary family: `{quantile=…}` samples plus
/// `_sum`/`_count`, with an optional extra label (the stage dimension).
/// Callers emit the `# HELP`/`# TYPE` header once per family.
fn prom_summary_series(
    out: &mut String,
    name: &str,
    extra: Option<(&str, &str)>,
    h: &LatencyHisto,
) {
    let lbl = |q: Option<f64>| -> String {
        let mut parts: Vec<String> = Vec::new();
        if let Some((k, v)) = extra {
            parts.push(format!("{k}=\"{v}\""));
        }
        if let Some(q) = q {
            parts.push(format!("quantile=\"{q}\""));
        }
        if parts.is_empty() {
            String::new()
        } else {
            format!("{{{}}}", parts.join(","))
        }
    };
    for q in [0.5, 0.9, 0.99] {
        out.push_str(&format!("{name}{} {}\n", lbl(Some(q)), h.quantile(q)));
    }
    let sum = if h.count() == 0 { 0.0 } else { h.mean() * h.count() as f64 };
    out.push_str(&format!("{name}_sum{} {sum}\n", lbl(None)));
    out.push_str(&format!("{name}_count{} {}\n", lbl(None), h.count()));
}

/// A complete single-series summary family (header + series).
fn prom_summary(
    out: &mut String,
    name: &str,
    help: &str,
    extra: Option<(&str, &str)>,
    h: &LatencyHisto,
) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} summary\n"));
    prom_summary_series(out, name, extra, h);
}

/// Durability-layer counters pulled from the registry — all zero unless
/// the engine was built with [`EngineBuilder::persist`].
#[derive(Clone, Debug, Default)]
pub struct WalStats {
    /// op records appended to the WAL
    pub records: u64,
    /// framed WAL bytes appended
    pub bytes: u64,
    /// group fsync barriers completed (one per publish)
    pub fsyncs: u64,
    /// per-barrier fsync latency
    pub fsync_latency: LatencyHisto,
    /// wall time of the last crash recovery (checkpoint load + replay)
    pub replay_ns: u64,
    /// WAL records replayed by the last crash recovery
    pub replay_records: u64,
}

/// A pull-model snapshot of everything the backend's lock-free
/// [`crate::obs::Metrics`] registry holds: the [`Stats`] counters and
/// latency histograms, cumulative per-stage publish/update breakdowns,
/// the latest per-publish [`PublishTrace`] and the structural gauges.
/// Obtained from [`ClusterEngine::metrics`]; render with
/// [`Self::render_prometheus`].
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub stats: Stats,
    /// per-stage breakdown of the most recent publish
    pub last_publish: PublishTrace,
    /// cumulative `(stage, histogram)` publish breakdowns, pipeline order
    pub publish_stages: Vec<(&'static str, LatencyHisto)>,
    /// cumulative `(stage, histogram)` update breakdowns
    pub update_stages: Vec<(&'static str, LatencyHisto)>,
    /// structural `(name, value)` gauges sampled at the last publish
    pub gauges: Vec<(&'static str, f64)>,
    /// live ETT vertices per HDT level (deeper levels fold into the last)
    pub hdt_level_verts: Vec<u64>,
    /// live primary points per shard from the placement map, sampled at
    /// the last publish (empty on the single backend; shards past
    /// [`crate::obs::Metrics::MAX_SHARDS_TRACKED`] fold into the last
    /// entry)
    pub shard_loads: Vec<u64>,
    /// durability-layer counters (zero without `persist`)
    pub wal: WalStats,
}

impl MetricsSnapshot {
    /// Degrade to counters-and-latencies only — the default for backends
    /// without a registry.
    pub fn from_stats(stats: Stats) -> Self {
        MetricsSnapshot {
            stats,
            last_publish: PublishTrace::default(),
            publish_stages: Vec::new(),
            update_stages: Vec::new(),
            gauges: Vec::new(),
            hdt_level_verts: Vec::new(),
            shard_loads: Vec::new(),
            wal: WalStats::default(),
        }
    }

    /// Prometheus text exposition of the full snapshot.
    pub fn render_prometheus(&self) -> String {
        let mut out = self.stats.render_prometheus();
        if self.last_publish.total_ns() > 0 {
            let name = "dyndbscan_last_publish_stage_ns";
            out.push_str(&format!(
                "# HELP {name} Stage share of the most recent publish\n\
                 # TYPE {name} gauge\n"
            ));
            for (stage, ns) in self.last_publish.stages() {
                out.push_str(&format!(
                    "{name}{{stage=\"{}\"}} {ns}\n",
                    stage.name()
                ));
            }
            prom_scalar(
                &mut out,
                "dyndbscan_last_publish_total_ns",
                "Total duration of the most recent publish",
                "gauge",
                self.last_publish.total_ns() as f64,
            );
        }
        if !self.publish_stages.is_empty() {
            let name = "dyndbscan_publish_stage_ns";
            out.push_str(&format!(
                "# HELP {name} Cumulative per-stage publish latency\n\
                 # TYPE {name} summary\n"
            ));
            for (stage, h) in &self.publish_stages {
                prom_summary_series(&mut out, name, Some(("stage", stage)), h);
            }
        }
        if !self.update_stages.is_empty() {
            let name = "dyndbscan_update_stage_ns";
            out.push_str(&format!(
                "# HELP {name} Cumulative per-stage update latency\n\
                 # TYPE {name} summary\n"
            ));
            for (stage, h) in &self.update_stages {
                prom_summary_series(&mut out, name, Some(("stage", stage)), h);
            }
        }
        for (g, v) in &self.gauges {
            prom_scalar(
                &mut out,
                &format!("dyndbscan_{g}"),
                "Structural gauge sampled at the last publish",
                "gauge",
                *v,
            );
        }
        if !self.hdt_level_verts.is_empty() {
            let name = "dyndbscan_hdt_level_vertices";
            out.push_str(&format!(
                "# HELP {name} Live ETT vertices per HDT level\n\
                 # TYPE {name} gauge\n"
            ));
            for (level, v) in self.hdt_level_verts.iter().enumerate() {
                out.push_str(&format!("{name}{{level=\"{level}\"}} {v}\n"));
            }
        }
        if !self.shard_loads.is_empty() {
            let name = "dyndbscan_shard_load";
            out.push_str(&format!(
                "# HELP {name} Live primary points per shard (placement map; \
                 shards past the tracked cap fold into the highest slot)\n\
                 # TYPE {name} gauge\n"
            ));
            for (shard, v) in self.shard_loads.iter().enumerate() {
                out.push_str(&format!("{name}{{shard=\"{shard}\"}} {v}\n"));
            }
        }
        if self.wal.records > 0 || self.wal.replay_records > 0 {
            prom_scalar(
                &mut out,
                "dyndbscan_wal_records_total",
                "Op records appended to the WAL",
                "counter",
                self.wal.records as f64,
            );
            prom_scalar(
                &mut out,
                "dyndbscan_wal_bytes_total",
                "Framed WAL bytes appended",
                "counter",
                self.wal.bytes as f64,
            );
            prom_scalar(
                &mut out,
                "dyndbscan_wal_fsyncs_total",
                "Group fsync barriers completed",
                "counter",
                self.wal.fsyncs as f64,
            );
            prom_summary(
                &mut out,
                "dyndbscan_wal_fsync_ns",
                "Per-barrier group fsync latency",
                None,
                &self.wal.fsync_latency,
            );
            prom_scalar(
                &mut out,
                "dyndbscan_recovery_replay_ns",
                "Wall time of the last crash recovery",
                "gauge",
                self.wal.replay_ns as f64,
            );
            prom_scalar(
                &mut out,
                "dyndbscan_recovery_replay_records",
                "WAL records replayed by the last crash recovery",
                "gauge",
                self.wal.replay_records as f64,
            );
        }
        out
    }
}

/// Everything a finished engine hands back: the final published view and
/// the complete [`Stats`] (worker latencies merged).
pub struct ServeOutcome {
    pub snapshot: SnapshotView,
    pub stats: Stats,
}

/// The unified serving engine: external-key writes, explicit publication,
/// versioned snapshot reads and cluster-event subscriptions — one
/// contract for the single-instance and sharded backends. Construct via
/// [`EngineBuilder`].
pub trait ClusterEngine {
    /// Data dimensionality the engine was built with.
    fn dim(&self) -> usize;

    /// Insert — or, when `ext` is live, replace — a point. Buffered;
    /// visible to readers after the next [`Self::publish`].
    fn upsert(&mut self, ext: u64, coords: &[f32]);

    /// Remove a live point. Panics when `ext` is unknown (a remove that
    /// silently no-ops would hide double-delete bugs).
    fn remove(&mut self, ext: u64);

    /// Apply a mixed batch in order — semantically identical to the
    /// per-op calls, but lets the backend hash/ship the batch in bulk.
    fn apply(&mut self, batch: &[Update<'_>]) {
        for u in batch {
            match *u {
                Update::Upsert { ext, coords } => self.upsert(ext, coords),
                Update::Remove { ext } => self.remove(ext),
            }
        }
    }

    /// Is `ext` live in the engine's **write** state (pending writes
    /// included — unlike [`SnapshotView::contains`])?
    fn contains(&self, ext: u64) -> bool;

    /// Barrier on every buffered write, fold the changes into the global
    /// clustering and return the next [`SnapshotView`] (version + 1,
    /// `pending_writes() == 0` — read-your-publishes).
    fn publish(&mut self) -> SnapshotView;

    /// The latest published view, with `pending_writes()` counted at this
    /// call. Cheap (CoW clone); never blocks the update path.
    fn snapshot(&self) -> SnapshotView;

    /// Subscribe to per-publish cluster events (merge/split/moved — see
    /// [`events`]). Any number of watchers; each publish delivers one
    /// batch per live watcher.
    fn watch(&mut self) -> ClusterEvents;

    /// Writes accepted since the last publish.
    fn pending_writes(&self) -> u64;

    /// Current metrics (see [`Stats`] for sharded-backend caveats).
    fn stats(&self) -> Stats;

    /// Everything the backend's live metrics registry holds: [`Stats`]
    /// plus stage histograms, the latest publish trace and structural
    /// gauges. The default degrades to [`Self::stats`] only; both built-in
    /// backends override it with the full registry pull.
    fn metrics(&self) -> MetricsSnapshot {
        MetricsSnapshot::from_stats(self.stats())
    }

    /// Machine-check the Theorem-2 structural invariants. Supported on
    /// the single backend; the sharded backend returns `Err` (workers own
    /// their structures).
    fn verify(&self) -> Result<(), String>;

    /// The backend's shared metrics registry, if it has one — the hook
    /// the durability wrapper uses to record WAL/fsync/recovery metrics
    /// into the *same* registry its inner engine reports from.
    #[doc(hidden)]
    fn obs_registry(&self) -> Option<std::sync::Arc<crate::obs::Metrics>> {
        None
    }

    /// Serialized cell→shard placement assignment, if the backend routes
    /// through one — the hook the durability wrapper spills into
    /// checkpoints so a reopen reshards to the same assignment. `None` on
    /// backends without a placement map.
    #[doc(hidden)]
    fn placement_blob(&self) -> Option<Vec<u8>> {
        None
    }

    /// Restore a placement assignment spilled by [`Self::placement_blob`]
    /// (called by recovery before re-ingesting checkpointed points).
    /// Default: ignore — backends without a placement map have nothing to
    /// restore.
    #[doc(hidden)]
    fn placement_restore(&mut self, _blob: &[u8]) {}

    /// Tell the backend where durable state lives so it can heal a dead
    /// shard **warm** — re-seeding from the checkpoint chain + WAL tail
    /// instead of the in-memory store. Called by the durability wrapper
    /// once recovery has completed. Default: ignore — only the sharded
    /// backend heals.
    #[doc(hidden)]
    fn install_wal_heal(&mut self, _dir: &std::path::Path) {}

    /// Publish any pending writes, stop the backend and hand back the
    /// final view plus complete stats.
    fn finish(self: Box<Self>) -> ServeOutcome;
}
