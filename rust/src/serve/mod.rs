//! `serve` — the unified serving API: one typed engine façade over every
//! clustering backend.
//!
//! Before this module the repo had two incompatible front doors —
//! `DynamicDbscan` (internal `PointId`s, mutable synchronous reads) and
//! `ShardedEngine` (external keys, snapshot reads) — and every consumer
//! re-implemented its own glue. `serve` replaces that with one surface:
//!
//! ```text
//!            EngineBuilder ───────────── build() ──────────────┐
//!   .backend(Single | Sharded(S))  .conn(Leveled|Repair|Paper) │
//!   .stitch(Delta | FullRebuild)   .hashing(Native | Xla)      ▼
//!                                             Box<dyn ClusterEngine>
//!                                    ┌──────────────┴──────────────┐
//!                              InlineEngine                  ShardedServe
//!                        (DynamicDbscan + ext map)      (ShardedEngine wrapper)
//!                                    └──────────────┬──────────────┘
//!        writes:  upsert / remove / apply(batch)    │ explicit publish()
//!        reads:   SnapshotView (versioned, immutable, CoW)
//!                   label · cluster_members · cluster_sizes ·
//!                   epsilon_neighbors · stats · version · pending_writes
//!        events:  watch() → ClusterEvents (merge / split / moved per publish)
//! ```
//!
//! **Write model.** All writes are external-key-first (`ext: u64`, the
//! caller's stable id) and buffered; nothing becomes visible to readers
//! until an explicit [`ClusterEngine::publish`], which barriers the
//! backend and emits the next [`SnapshotView`]. `upsert` replaces a live
//! point (delete + insert); `remove` panics on an unknown key — the same
//! contract on every backend.
//!
//! **Read model / freshness.** Reads go through [`SnapshotView`] — an
//! immutable CoW handle pinned to one publish. `version()` identifies the
//! publish; `pending_writes()` reports how many accepted writes the view
//! does *not* reflect (0 on a view returned by `publish` —
//! read-your-publishes). This fixes the historical `cluster_of` staleness
//! trap: freshness is now visible in the type you read from. See
//! [`snapshot`] for the full contract.
//!
//! **Events.** [`ClusterEngine::watch`] subscribes to per-publish
//! [`ClusterEvent`]s (merges, splits, formed/dissolved clusters, per-point
//! moves) derived from the stable-component change plumbing — no snapshot
//! polling. See [`events`] for semantics.
//!
//! **Metrics.** One [`Stats`] struct — op counters, pending writes, and
//! the add/delete/publish latency histograms — replaces the previously
//! duplicated per-backend accessors.

pub mod builder;
pub mod driver;
pub mod events;
mod inline;
mod sharded;
pub mod snapshot;

pub use builder::{Backend, EngineBuilder};
pub use events::{ClusterEvent, ClusterEvents};
pub use snapshot::{SnapshotStats, SnapshotView};

pub use crate::coordinator::driver::EngineKind;
pub use crate::dbscan::ConnKind;
pub use crate::shard::StitchMode;

use crate::dbscan::RepairStats;
use crate::util::stats::LatencyHisto;

/// One buffered update in a [`ClusterEngine::apply`] batch. `Upsert`
/// borrows its coordinates — the batch path copies them at most once
/// (into the engine's wire/arena storage).
#[derive(Clone, Copy, Debug)]
pub enum Update<'a> {
    Upsert { ext: u64, coords: &'a [f32] },
    Remove { ext: u64 },
}

/// The unified metrics surface of a serve engine — op counters plus the
/// latency histograms that were previously scattered across
/// `EngineOutcome` fields and per-engine accessors.
///
/// `inserts`/`deletes`/`pending_writes` count **accepted façade writes**:
/// an upsert that replaces a live point is one write (the sharded
/// engine's internal delete + re-insert fan-out is not surfaced here,
/// except through `ghost_inserts`, which stays an engine-level counter).
///
/// For the sharded backend, `add_latency`/`delete_latency` and `conn` are
/// owned by the worker threads and merge in at [`ClusterEngine::finish`];
/// mid-run [`ClusterEngine::stats`] reports them empty. The inline
/// backend tracks everything live.
#[derive(Clone, Debug)]
pub struct Stats {
    /// shard workers (1 = the inline/single backend)
    pub shards: usize,
    /// primary inserts accepted
    pub inserts: u64,
    /// deletes accepted
    pub deletes: u64,
    /// ghost replicas created by boundary replication (sharded only)
    pub ghost_inserts: u64,
    pub publishes: u64,
    /// writes accepted since the last publish (not yet readable)
    pub pending_writes: u64,
    pub add_latency: LatencyHisto,
    pub delete_latency: LatencyHisto,
    /// end-to-end publish latency as seen through the façade
    pub publish_latency: LatencyHisto,
    /// connectivity-layer counters (summed across shards at finish)
    pub conn: RepairStats,
}

impl Stats {
    /// Ghost replicas per primary insert (0 on the single backend).
    pub fn ghost_ratio(&self) -> f64 {
        if self.inserts == 0 {
            0.0
        } else {
            self.ghost_inserts as f64 / self.inserts as f64
        }
    }
}

/// Everything a finished engine hands back: the final published view and
/// the complete [`Stats`] (worker latencies merged).
pub struct ServeOutcome {
    pub snapshot: SnapshotView,
    pub stats: Stats,
}

/// The unified serving engine: external-key writes, explicit publication,
/// versioned snapshot reads and cluster-event subscriptions — one
/// contract for the single-instance and sharded backends. Construct via
/// [`EngineBuilder`].
pub trait ClusterEngine {
    /// Data dimensionality the engine was built with.
    fn dim(&self) -> usize;

    /// Insert — or, when `ext` is live, replace — a point. Buffered;
    /// visible to readers after the next [`Self::publish`].
    fn upsert(&mut self, ext: u64, coords: &[f32]);

    /// Remove a live point. Panics when `ext` is unknown (a remove that
    /// silently no-ops would hide double-delete bugs).
    fn remove(&mut self, ext: u64);

    /// Apply a mixed batch in order — semantically identical to the
    /// per-op calls, but lets the backend hash/ship the batch in bulk.
    fn apply(&mut self, batch: &[Update<'_>]) {
        for u in batch {
            match *u {
                Update::Upsert { ext, coords } => self.upsert(ext, coords),
                Update::Remove { ext } => self.remove(ext),
            }
        }
    }

    /// Is `ext` live in the engine's **write** state (pending writes
    /// included — unlike [`SnapshotView::contains`])?
    fn contains(&self, ext: u64) -> bool;

    /// Barrier on every buffered write, fold the changes into the global
    /// clustering and return the next [`SnapshotView`] (version + 1,
    /// `pending_writes() == 0` — read-your-publishes).
    fn publish(&mut self) -> SnapshotView;

    /// The latest published view, with `pending_writes()` counted at this
    /// call. Cheap (CoW clone); never blocks the update path.
    fn snapshot(&self) -> SnapshotView;

    /// Subscribe to per-publish cluster events (merge/split/moved — see
    /// [`events`]). Any number of watchers; each publish delivers one
    /// batch per live watcher.
    fn watch(&mut self) -> ClusterEvents;

    /// Writes accepted since the last publish.
    fn pending_writes(&self) -> u64;

    /// Current metrics (see [`Stats`] for sharded-backend caveats).
    fn stats(&self) -> Stats;

    /// Machine-check the Theorem-2 structural invariants. Supported on
    /// the single backend; the sharded backend returns `Err` (workers own
    /// their structures).
    fn verify(&self) -> Result<(), String>;

    /// Publish any pending writes, stop the backend and hand back the
    /// final view plus complete stats.
    fn finish(self: Box<Self>) -> ServeOutcome;
}
