//! [`ShardedServe`] — the serve façade over [`ShardedEngine`]: external
//! keys in, versioned [`SnapshotView`]s out, with the engine's delta
//! publish plumbing surfaced as cluster events. Adds the upsert/liveness
//! bookkeeping and publish-pinned coordinate state the raw engine does
//! not keep.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use rustc_hash::{FxHashMap, FxHashSet};

use crate::dbscan::RepairStats;
use crate::obs::{Gauge, PhaseClock, Stopwatch, UpdateStage};
use crate::shard::{ShardConfig, ShardedEngine};
use crate::util::stats::LatencyHisto;

use super::events::{derive_events, ClusterEvents, EventHub};
use super::index::{IndexPolicy, SpatialIndex};
use super::snapshot::{CoordMap, SnapshotView};
use super::{
    ClusterEngine, Health, MetricsSnapshot, ServeOutcome, Stats, Update, WalStats,
};

pub(crate) struct ShardedServe {
    eng: ShardedEngine,
    dim: usize,
    eps: f32,
    /// live coordinates (CoW-shared with published views); also the
    /// liveness set backing `upsert`'s replace semantics
    coords: CoordMap,
    /// ε-cell spatial index over the façade's authoritative live set
    /// (CoW-shared with published views); `None` when disabled by policy
    index: Option<SpatialIndex>,
    /// the policy that built `index` (carries the rebuild-fallback flag)
    index_policy: IndexPolicy,
    /// the latest published view
    view: SnapshotView,
    hub: EventHub,
    publish_latency: LatencyHisto,
    /// façade-level write accounting: an upsert-replace is **one**
    /// accepted write even though the engine sees a delete + an insert
    pending: u64,
    inserts: u64,
    deletes: u64,
    /// persist directory for warm shard heals (`install_wal_heal`);
    /// `None` when the engine is not wrapped in a `DurableEngine`
    wal_heal_dir: Option<PathBuf>,
}

/// Rebuild the live `ext → coords` relation from durable state: the
/// checkpoint chain plus the WAL tail past its floor. The durable engine
/// flushes the WAL *before* the inner publish (whose barrier runs the
/// heal), so this replay reconstructs exactly the façade's live
/// coordinate set at heal time. `None` on any read failure — the caller
/// falls back to the in-memory re-feed.
fn durable_coords(dir: &Path) -> Option<FxHashMap<u64, Vec<f32>>> {
    use crate::persist::{load_checkpoint_chain, read_wal, WalOp, WalRecord};
    let mut map: FxHashMap<u64, Vec<f32>> = FxHashMap::default();
    let floor = match load_checkpoint_chain(dir) {
        Some(c) => {
            let floor = c.wal_seq;
            for (ext, row) in c.points {
                map.insert(ext, row);
            }
            floor
        }
        None => 0, // cold full-log replay
    };
    let (records, _clean) = read_wal(dir).ok()?;
    for rec in records {
        if rec.seq() <= floor {
            continue;
        }
        match rec {
            WalRecord::Upsert { ext, coords, .. } => {
                map.insert(ext, coords);
            }
            WalRecord::Remove { ext, .. } => {
                map.remove(&ext);
            }
            WalRecord::Apply { ops, .. } => {
                for op in ops {
                    match op {
                        WalOp::Upsert { ext, coords } => {
                            map.insert(ext, coords);
                        }
                        WalOp::Remove { ext } => {
                            map.remove(&ext);
                        }
                    }
                }
            }
            WalRecord::Publish { .. } => {}
        }
    }
    Some(map)
}

impl ShardedServe {
    pub fn new(cfg: ShardConfig, index_policy: IndexPolicy) -> Self {
        let (dim, eps) = (cfg.dbscan.dim, cfg.dbscan.eps);
        ShardedServe {
            eng: ShardedEngine::new(cfg),
            dim,
            eps,
            coords: CoordMap::new(),
            index: index_policy.build_for(eps, dim),
            index_policy,
            view: SnapshotView::empty(eps, dim),
            hub: EventHub::default(),
            publish_latency: LatencyHisto::new(),
            pending: 0,
            inserts: 0,
            deletes: 0,
            wal_heal_dir: None,
        }
    }

    /// Current health: `Degraded` lists the quarantined shards whose
    /// workers died or wedged (reads still serve the last snapshot).
    fn health(&self) -> Health {
        if self.eng.is_degraded() {
            Health::Degraded { shards: self.eng.down_shards().to_vec() }
        } else {
            Health::Ok
        }
    }

    /// Respawn every shard quarantined **before** this publish. With a
    /// persist directory installed ([`ClusterEngine::install_wal_heal`])
    /// the re-seed coordinates come **warm** from durable state — the
    /// checkpoint chain plus the WAL tail, i.e. the same bytes crash
    /// recovery trusts — proving the log is sufficient to rebuild any
    /// single shard without the in-memory store. When persistence is off
    /// (or the durable read fails or disagrees with the live set), the
    /// heal falls back to the façade's coordinate map, the original
    /// placement re-feed. A fault detected during the barrier of the
    /// current publish surfaces as `Degraded` at least once; the *next*
    /// publish heals it.
    fn heal_down_shards(&mut self) {
        let down: Vec<u32> = self.eng.down_shards().to_vec();
        if down.is_empty() {
            return;
        }
        let durable = self
            .wal_heal_dir
            .as_deref()
            .and_then(durable_coords)
            // a durable set that disagrees with the live one means the
            // directory is stale or damaged — don't seed from it
            .filter(|m| m.len() == self.coords.len());
        for s in down {
            if let Some(map) = &durable {
                let healed = self
                    .eng
                    .respawn_shard(s, |ext, buf| match map.get(&ext) {
                        Some(row) => {
                            buf.extend_from_slice(row);
                            true
                        }
                        None => false,
                    })
                    .is_ok();
                if healed {
                    continue;
                }
            }
            let coords = &self.coords;
            // a failed respawn leaves the shard quarantined (and the
            // fault logged in the engine) — retried at the next publish
            let _ = self.eng.respawn_shard(s, |ext, buf| match coords.get(ext) {
                Some(row) => {
                    buf.extend_from_slice(row);
                    true
                }
                None => false,
            });
        }
    }

    /// Fold one index insertion into the update path under the
    /// `index_probe` span — `O(1)` amortized. Skipped entirely in
    /// rebuild-at-publish mode (the publish barrier rebuilds instead).
    fn index_upsert(&mut self, ext: u64, coords: &[f32]) {
        if self.index_policy.rebuild_at_publish {
            return;
        }
        if let Some(ix) = self.index.as_mut() {
            let m = self.eng.metrics();
            let sw = m.enabled().then(Stopwatch::start);
            ix.upsert(ext, coords);
            if let Some(sw) = sw {
                m.record_update_stage(UpdateStage::IndexProbe, sw.elapsed_ns());
            }
        }
    }

    /// Index twin of a façade-level remove (see [`Self::index_upsert`]).
    fn index_remove(&mut self, ext: u64) {
        if self.index_policy.rebuild_at_publish {
            return;
        }
        if let Some(ix) = self.index.as_mut() {
            let m = self.eng.metrics();
            let sw = m.enabled().then(Stopwatch::start);
            ix.remove(ext);
            if let Some(sw) = sw {
                m.record_update_stage(UpdateStage::IndexProbe, sw.elapsed_ns());
            }
        }
    }

    fn publish_inner(&mut self) -> SnapshotView {
        self.heal_down_shards();
        {
            // live resharding rides the publish: a bounded cell migration
            // (if load skew trips the trigger) re-routes members through
            // the same pending batches the barrier below flushes, with
            // coordinates re-fed from the façade's authoritative store —
            // the exact respawn contract
            let coords = &self.coords;
            self.eng.maybe_reshard(|ext, buf| match coords.get(ext) {
                Some(row) => {
                    buf.extend_from_slice(row);
                    true
                }
                None => false,
            });
        }
        let t0 = Stopwatch::start();
        let obs_on = self.eng.metrics().enabled();
        let snap = self.eng.publish();
        let changes = self.eng.drain_label_changes();
        // façade share of the publish: CoW view construction, then event
        // derivation — folded into the engine's trace via
        // `note_facade_stages` below
        let mut clk = PhaseClock::maybe(obs_on);
        if self.index_policy.rebuild_at_publish {
            // the StitchMode::FullRebuild analogue: no per-op
            // maintenance, the barrier rebuilds the index from scratch
            if let Some(ix) = self.index.as_mut() {
                ix.rebuild(self.coords.iter());
            }
        }
        if obs_on {
            // measured before the clone below re-shares everything:
            // chunks rewritten since the last publish are the unshared ones
            self.eng
                .metrics()
                .set_ratio(Gauge::CowCoordSharing, self.coords.sharing_ratio());
            if let Some(ix) = &self.index {
                let m = self.eng.metrics();
                m.set_gauge(Gauge::IndexCells, ix.num_cells() as u64);
                m.set_ratio(Gauge::CowIndexSharing, ix.sharing_ratio());
            }
        }
        self.coords.maybe_grow();
        if let Some(ix) = self.index.as_mut() {
            ix.maybe_grow();
        }
        debug_assert_eq!(
            self.coords.len(),
            snap.live_points,
            "coordinate store out of sync with the published snapshot"
        );
        debug_assert!(
            self.index.as_ref().map(|ix| ix.len() == self.coords.len()).unwrap_or(true),
            "spatial index out of sync with the coordinate store"
        );
        let mut view = SnapshotView::new(
            snap.seq,
            0,
            snap.live_points,
            snap.core_points,
            Arc::new(snap.cluster_sizes.clone()),
            snap.label_map().clone(),
            snap.core_map().clone(),
            self.coords.clone(),
            self.index.as_ref().map(|ix| Arc::new(ix.clone())),
            self.eps,
            self.dim,
        );
        view.set_reshard_epoch(self.eng.placement_version());
        // the clone above froze this publish's writes into the view;
        // stamp later writes with a fresh generation so incremental
        // checkpoint spills can diff chunks against this publish
        self.coords.advance_gen();
        let cow_ns = clk.as_mut().map_or(0, |c| c.lap());
        if self.hub.has_watchers() {
            let prev: FxHashSet<i64> =
                self.view.cluster_sizes().iter().map(|&(l, _)| l).collect();
            let now: FxHashSet<i64> =
                view.cluster_sizes().iter().map(|&(l, _)| l).collect();
            let events = derive_events(view.version(), &changes, &prev, &now);
            self.hub.emit(events);
        } else {
            // the last watcher is gone (emit pruned it): stop paying for
            // engine-level change recording until the next watch()
            self.eng.set_change_log(false);
        }
        let events_ns = clk.as_mut().map_or(0, |c| c.lap());
        if obs_on {
            self.eng.note_facade_stages(cow_ns, events_ns);
        }
        self.publish_latency.record(t0.elapsed_ns());
        self.pending = 0;
        self.view = view.clone();
        view
    }
}

impl ClusterEngine for ShardedServe {
    fn dim(&self) -> usize {
        self.dim
    }

    fn upsert(&mut self, ext: u64, coords: &[f32]) {
        assert_eq!(coords.len(), self.dim, "bad dim in upsert");
        if self.coords.get(ext).is_some() {
            // replace: one accepted write, two engine ops
            self.eng.delete(ext);
        }
        self.eng.insert(ext, coords);
        self.coords.set(ext, coords);
        self.index_upsert(ext, coords);
        self.inserts += 1;
        self.pending += 1;
    }

    fn remove(&mut self, ext: u64) {
        assert!(
            self.coords.get(ext).is_some(),
            "serve: remove of unknown ext {ext}"
        );
        self.eng.delete(ext);
        self.coords.remove(ext);
        self.index_remove(ext);
        self.deletes += 1;
        self.pending += 1;
    }

    fn apply(&mut self, batch: &[Update<'_>]) {
        for u in batch {
            match *u {
                Update::Upsert { ext, coords } => self.upsert(ext, coords),
                Update::Remove { ext } => self.remove(ext),
            }
        }
        // ship the batch now so the workers overlap with the caller's
        // next batch instead of waiting for the publish barrier
        self.eng.flush();
    }

    fn contains(&self, ext: u64) -> bool {
        self.coords.get(ext).is_some()
    }

    fn publish(&mut self) -> SnapshotView {
        self.publish_inner()
    }

    fn snapshot(&self) -> SnapshotView {
        let mut view = self.view.clone();
        view.set_pending(self.pending);
        view
    }

    fn watch(&mut self) -> ClusterEvents {
        // start recording label transitions from the next publish on
        self.eng.set_change_log(true);
        self.hub.subscribe()
    }

    fn pending_writes(&self) -> u64 {
        self.pending
    }

    fn stats(&self) -> Stats {
        let es = self.eng.stats();
        let m = self.eng.metrics();
        Stats {
            shards: self.eng.shards(),
            inserts: self.inserts,
            deletes: self.deletes,
            ghost_inserts: es.ghost_inserts,
            publishes: es.publishes,
            pending_writes: self.pending,
            // live mid-run: workers record every op into the engine's
            // shared striped-atomic registry; merging a snapshot here
            // never blocks them (closes the old workers-own-their-
            // histograms-until-finish gap)
            add_latency: m.add_histo(),
            delete_latency: m.delete_histo(),
            publish_latency: self.publish_latency.clone(),
            // conn repair counters still merge at finish
            conn: RepairStats::default(),
            health: self.health(),
        }
    }

    fn metrics(&self) -> MetricsSnapshot {
        let m = self.eng.metrics();
        MetricsSnapshot {
            stats: self.stats(),
            last_publish: self.eng.last_trace().clone(),
            publish_stages: m.publish_stage_histos(),
            update_stages: m.update_stage_histos(),
            gauges: m.gauge_values(),
            hdt_level_verts: m.level_verts().to_vec(),
            shard_loads: {
                let mut loads = m.shard_loads();
                loads.truncate(self.eng.shards());
                loads
            },
            wal: WalStats::default(),
        }
    }

    fn verify(&self) -> Result<(), String> {
        Err("invariant verification runs on the single backend only \
             (shard workers own their structures)"
            .to_string())
    }

    fn obs_registry(&self) -> Option<Arc<crate::obs::Metrics>> {
        Some(Arc::clone(self.eng.metrics()))
    }

    fn placement_blob(&self) -> Option<Vec<u8>> {
        self.eng.placement_blob()
    }

    fn placement_restore(&mut self, blob: &[u8]) {
        self.eng.placement_restore(blob);
    }

    fn install_wal_heal(&mut self, dir: &Path) {
        self.wal_heal_dir = Some(dir.to_path_buf());
    }

    fn finish(mut self: Box<Self>) -> ServeOutcome {
        if self.pending > 0 || self.eng.stats().publishes == 0 {
            // publish through the façade so the view and watchers update
            self.publish_inner();
        }
        let health = self.health();
        let this = *self;
        let ShardedServe { eng, view, publish_latency, inserts, deletes, .. } = this;
        let shards = eng.shards();
        let out = eng.finish();
        let conn = out.conn_stats();
        let stats = Stats {
            shards,
            inserts,
            deletes,
            ghost_inserts: out.stats.ghost_inserts,
            publishes: out.stats.publishes,
            pending_writes: 0,
            add_latency: out.add_latency,
            delete_latency: out.delete_latency,
            publish_latency,
            conn,
            health,
        };
        ServeOutcome { snapshot: view, stats }
    }
}
