//! Cluster-event subscriptions — the push counterpart of snapshot reads.
//!
//! [`super::ClusterEngine::watch`] returns a [`ClusterEvents`] handle;
//! at every publish the engine derives cluster-level events from the
//! per-ext label transitions the stitch/stable-component plumbing already
//! tracks ([`LabelChange`]) and fans them out to every live handle, so
//! downstream consumers react to merges and splits instead of polling
//! and diffing full snapshots.
//!
//! ## Event semantics (per publish, labels as of the two snapshots)
//!
//! * [`ClusterEvent::Formed`] — a label was minted whose members carried
//!   no cluster label before (fresh or noise points condensed).
//! * [`ClusterEvent::Dissolved`] — a label vanished and none of its
//!   members moved to another cluster (all became noise or were deleted).
//! * [`ClusterEvent::Merged`] — a label vanished and (some of) its
//!   members now carry another label. Under delta publishing the
//!   surviving label is the larger side's, so a merge reads
//!   "smaller `from` absorbed into larger `into`".
//! * [`ClusterEvent::Split`] — a fresh label was minted for members that
//!   previously carried a label that **survives**: the smaller side of a
//!   genuine cluster split (delta publishing mints fresh ids for the
//!   smaller side).
//! * [`ClusterEvent::Moved`] — one point's label changed; the raw feed
//!   the aggregate events are derived from.
//!
//! Label **stability** (and therefore meaningful merge/split events)
//! needs [`crate::shard::StitchMode::Delta`]; the full-rebuild fallback
//! renumbers labels wholesale every publish, so its event stream is
//! dominated by renames and is useful mostly for `Moved`-level auditing.

use std::sync::mpsc::{channel, Receiver, Sender};

use rustc_hash::{FxHashMap, FxHashSet};

pub use crate::shard::LabelChange;

/// A cluster-level change observed at one publish; `version` is the
/// publishing snapshot's [`super::SnapshotView::version`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ClusterEvent {
    /// `label` minted from fresh/noise points only
    Formed { version: u64, label: i64 },
    /// `label` vanished without survivors joining another cluster
    Dissolved { version: u64, label: i64 },
    /// `from` vanished; its members now carry `into`
    Merged { version: u64, from: i64, into: i64 },
    /// fresh `new` split out of the surviving `from`
    Split { version: u64, from: i64, new: i64 },
    /// one point's label changed (`None`: not live on that side)
    Moved { version: u64, ext: u64, from: Option<i64>, to: Option<i64> },
}

impl ClusterEvent {
    /// The publish that produced this event.
    pub fn version(&self) -> u64 {
        match *self {
            ClusterEvent::Formed { version, .. }
            | ClusterEvent::Dissolved { version, .. }
            | ClusterEvent::Merged { version, .. }
            | ClusterEvent::Split { version, .. }
            | ClusterEvent::Moved { version, .. } => version,
        }
    }
}

/// Subscription handle returned by [`super::ClusterEngine::watch`]. Each
/// publish delivers one batch (possibly empty — a publish with no label
/// changes), so batches align 1:1 with versions.
///
/// Delivery is buffered and unbounded: a live handle accumulates one
/// batch per publish until drained, so a subscriber that stops consuming
/// should **drop the handle** (the engine prunes disconnected watchers
/// at the next publish and stops recording changes once none remain)
/// rather than letting the backlog grow.
pub struct ClusterEvents {
    rx: Receiver<Vec<ClusterEvent>>,
}

impl ClusterEvents {
    /// Everything delivered so far, without blocking.
    pub fn drain(&self) -> Vec<ClusterEvent> {
        let mut out = Vec::new();
        while let Ok(mut batch) = self.rx.try_recv() {
            out.append(&mut batch);
        }
        out
    }

    /// Block for the next publish's batch (`None`: the engine is gone).
    pub fn next_publish(&self) -> Option<Vec<ClusterEvent>> {
        self.rx.recv().ok()
    }
}

/// Engine-side fan-out: one sender per live watcher; disconnected
/// watchers are dropped at the next emit.
#[derive(Default)]
pub(crate) struct EventHub {
    txs: Vec<Sender<Vec<ClusterEvent>>>,
}

impl EventHub {
    pub fn subscribe(&mut self) -> ClusterEvents {
        let (tx, rx) = channel();
        self.txs.push(tx);
        ClusterEvents { rx }
    }

    pub fn has_watchers(&self) -> bool {
        !self.txs.is_empty()
    }

    pub fn emit(&mut self, events: Vec<ClusterEvent>) {
        self.txs.retain(|tx| tx.send(events.clone()).is_ok());
    }
}

/// Derive the cluster-level events of one publish from its per-ext label
/// transitions. `prev`/`now` are the cluster-label sets alive on each
/// side of the publish. Deterministic: aggregate events are sorted by
/// label, `Moved` events by ext.
pub(crate) fn derive_events(
    version: u64,
    changes: &[LabelChange],
    prev: &FxHashSet<i64>,
    now: &FxHashSet<i64>,
) -> Vec<ClusterEvent> {
    // flows: vanished label → labeled destinations; new label → sources
    let mut vanished_dests: FxHashMap<i64, FxHashSet<i64>> = FxHashMap::default();
    let mut new_sources: FxHashMap<i64, FxHashSet<i64>> = FxHashMap::default();
    for c in changes {
        if let Some(f) = c.from {
            if f >= 0 && !now.contains(&f) {
                let dests = vanished_dests.entry(f).or_default();
                if let Some(t) = c.to {
                    if t >= 0 {
                        dests.insert(t);
                    }
                }
            }
        }
        if let Some(t) = c.to {
            if t >= 0 && !prev.contains(&t) {
                let sources = new_sources.entry(t).or_default();
                if let Some(f) = c.from {
                    if f >= 0 {
                        sources.insert(f);
                    }
                }
            }
        }
    }
    let mut out = Vec::new();
    let mut vanished: Vec<(i64, Vec<i64>)> = vanished_dests
        .into_iter()
        .map(|(f, d)| {
            let mut d: Vec<i64> = d.into_iter().collect();
            d.sort_unstable();
            (f, d)
        })
        .collect();
    vanished.sort_unstable_by_key(|&(f, _)| f);
    for (from, dests) in vanished {
        if dests.is_empty() {
            out.push(ClusterEvent::Dissolved { version, label: from });
        } else {
            for into in dests {
                out.push(ClusterEvent::Merged { version, from, into });
            }
        }
    }
    let mut minted: Vec<(i64, Vec<i64>)> = new_sources
        .into_iter()
        .map(|(n, s)| {
            let mut s: Vec<i64> = s.into_iter().collect();
            s.sort_unstable();
            (n, s)
        })
        .collect();
    minted.sort_unstable_by_key(|&(n, _)| n);
    for (new, sources) in minted {
        if sources.is_empty() {
            out.push(ClusterEvent::Formed { version, label: new });
        } else {
            // vanished sources already reported as Merged into `new`
            for from in sources.into_iter().filter(|s| now.contains(s)) {
                out.push(ClusterEvent::Split { version, from, new });
            }
        }
    }
    let mut moved: Vec<&LabelChange> = changes.iter().collect();
    moved.sort_unstable_by_key(|c| c.ext);
    out.extend(moved.into_iter().map(|c| ClusterEvent::Moved {
        version,
        ext: c.ext,
        from: c.from,
        to: c.to,
    }));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sets(v: &[i64]) -> FxHashSet<i64> {
        v.iter().copied().collect()
    }

    fn ch(ext: u64, from: Option<i64>, to: Option<i64>) -> LabelChange {
        LabelChange { ext, from, to }
    }

    #[test]
    fn merge_is_reported_for_the_vanished_side() {
        // cluster 2 absorbed into surviving cluster 1
        let events = derive_events(
            5,
            &[ch(10, Some(2), Some(1)), ch(11, Some(2), Some(1))],
            &sets(&[1, 2]),
            &sets(&[1]),
        );
        assert!(events
            .contains(&ClusterEvent::Merged { version: 5, from: 2, into: 1 }));
        let moved = events
            .iter()
            .filter(|e| matches!(e, ClusterEvent::Moved { .. }))
            .count();
        assert_eq!(moved, 2);
    }

    #[test]
    fn split_mints_fresh_label_from_survivor() {
        let events = derive_events(
            7,
            &[ch(3, Some(0), Some(4)), ch(4, Some(0), Some(4))],
            &sets(&[0]),
            &sets(&[0, 4]),
        );
        assert!(events.contains(&ClusterEvent::Split { version: 7, from: 0, new: 4 }));
        assert!(!events.iter().any(|e| matches!(e, ClusterEvent::Merged { .. })));
    }

    #[test]
    fn formed_and_dissolved() {
        let events = derive_events(
            2,
            &[
                ch(1, None, Some(3)),
                ch(2, Some(-1), Some(3)),
                ch(7, Some(5), Some(-1)),
                ch(8, Some(5), None),
            ],
            &sets(&[5]),
            &sets(&[3]),
        );
        assert!(events.contains(&ClusterEvent::Formed { version: 2, label: 3 }));
        assert!(events.contains(&ClusterEvent::Dissolved { version: 2, label: 5 }));
    }

    #[test]
    fn rename_reads_as_merge_into_the_new_label_not_split() {
        // label 6 vanished wholesale into fresh label 9
        let events = derive_events(
            4,
            &[ch(1, Some(6), Some(9)), ch(2, Some(6), Some(9))],
            &sets(&[6]),
            &sets(&[9]),
        );
        assert!(events.contains(&ClusterEvent::Merged { version: 4, from: 6, into: 9 }));
        assert!(!events.iter().any(|e| matches!(e, ClusterEvent::Split { .. })));
        assert!(!events.iter().any(|e| matches!(e, ClusterEvent::Formed { .. })));
    }

    #[test]
    fn hub_fans_out_and_drops_dead_watchers() {
        let mut hub = EventHub::default();
        assert!(!hub.has_watchers());
        let a = hub.subscribe();
        let b = hub.subscribe();
        hub.emit(vec![ClusterEvent::Formed { version: 1, label: 0 }]);
        assert_eq!(a.drain().len(), 1);
        drop(b);
        hub.emit(vec![]);
        assert!(hub.has_watchers());
        assert_eq!(a.next_publish().unwrap().len(), 0);
    }
}
