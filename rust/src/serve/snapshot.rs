//! Versioned immutable read handles.
//!
//! A [`SnapshotView`] is the uniform read surface of every
//! [`super::ClusterEngine`] backend: label lookups, cluster membership and
//! sizes, ε-neighborhoods, kNN and summary stats, all answered from state
//! frozen at one publish. Internally it is a bundle of CoW structures —
//! the [`crate::shard::LabelMap`] label state, a `CoordMap` of point
//! coordinates, and (when the builder's `IndexPolicy` allows) a pinned
//! [`super::index::SpatialIndex`] ε-cell table — so cloning a view (and
//! publishing the next one) costs `O(#chunks)` pointer copies, never
//! `O(n)`.
//!
//! ## Freshness contract
//!
//! * [`SnapshotView::version`] increases by one publish; two views with
//!   the same version answer every query identically. The spatial index
//!   and the lazily built members index are *derived* state pinned at the
//!   same publish barrier as the labels and coordinates, so indexed
//!   answers ([`SnapshotView::epsilon_neighbors`],
//!   [`SnapshotView::k_nearest`], [`SnapshotView::cluster_members`])
//!   carry exactly the same freshness as the scans they replace — and are
//!   bit-identical to the retained scan oracles
//!   ([`SnapshotView::epsilon_neighbors_scan`] and friends).
//! * A view reflects **exactly** the writes accepted before the publish
//!   that produced it. Writes accepted later are invisible to it —
//!   [`SnapshotView::pending_writes`] (captured when the handle was
//!   obtained) says how many such writes the engine had buffered.
//! * For read-your-writes, call [`super::ClusterEngine::publish`] and use
//!   the view it returns (its `pending_writes` is 0 by construction).
//!
//! ## Replica staleness contract
//!
//! A view served by a [`crate::replica::ReplicaEngine`] carries the
//! **leader's** version numbering (replicas rebase at every shipped
//! `Publish{seq, version}` marker), and a replica view at version `v` is
//! bit-identical — labels, cores, `epsilon_neighbors`, `k_nearest`,
//! cluster membership — to the leader's view at the same `v`: both are
//! deterministic replays of the same op prefix. What a replica view may
//! be is *behind*: at most `max_staleness` leader publishes (the
//! [`crate::replica::ReadRouter`] bound, measured in publishes — never a
//! wall-clock claim), and never mid-publish — replicas apply shipped ops
//! only up to complete publish markers, so no view exposes a state the
//! leader never published.

use std::sync::{Arc, OnceLock};

use rustc_hash::FxHashMap;

use super::index::{self, SpatialIndex};
use crate::shard::LabelMap;
use crate::util::cow_map::ChunkedCowMap;

/// Target mean entries per chunk; growth triggers at twice this.
const TARGET_PER_CHUNK: usize = 32;

/// CoW `ext → coordinates` map, a thin wrapper over the generic
/// [`ChunkedCowMap`] (chunked like [`LabelMap`]): publishing clones the
/// chunk-pointer vector, later upserts deep-copy only the touched chunks
/// (each entry is an `Arc<[f32]>`, so a chunk copy clones pointers, not
/// coordinate data).
#[derive(Clone, Debug)]
pub(crate) struct CoordMap {
    inner: ChunkedCowMap<Arc<[f32]>>,
}

impl CoordMap {
    pub fn new() -> Self {
        CoordMap { inner: ChunkedCowMap::new(TARGET_PER_CHUNK) }
    }

    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn get(&self, ext: u64) -> Option<&[f32]> {
        self.inner.get(ext).map(|a| a.as_ref())
    }

    /// Insert or replace; deep-copies the target chunk iff a published
    /// view still shares it.
    pub fn set(&mut self, ext: u64, coords: &[f32]) {
        self.inner.set(ext, Arc::from(coords));
    }

    /// Remove; removing an absent key never deep-copies a view-shared
    /// chunk.
    pub fn remove(&mut self, ext: u64) {
        self.inner.remove(ext);
    }

    /// Unordered iteration over `(ext, coords)`.
    pub fn iter(&self) -> impl Iterator<Item = (u64, &[f32])> + '_ {
        self.inner.iter().map(|(e, a)| (e, a.as_ref()))
    }

    /// Double the chunk count once mean occupancy exceeds the target —
    /// amortized `O(1)` per insertion, called between publishes.
    pub fn maybe_grow(&mut self) {
        self.inner.maybe_grow();
    }

    /// Fraction of chunks still shared with a published view — the
    /// `cow_coord_sharing` gauge.
    pub fn sharing_ratio(&self) -> f64 {
        self.inner.sharing_ratio()
    }

    /// Bump the write generation (once per publish, after cloning into
    /// the view) — the chunk-level dirty clock incremental checkpoints
    /// spill against.
    pub fn advance_gen(&mut self) {
        self.inner.advance_gen();
    }

    /// Write generation carried by this map (a view's clone keeps the
    /// generation of the publish that froze it).
    pub fn generation(&self) -> u64 {
        self.inner.generation()
    }

    /// Current chunk count (power of two).
    pub fn num_chunks(&self) -> usize {
        self.inner.num_chunks()
    }

    /// Chunks mutated after generation `floor` — the incremental spill's
    /// dirty set when `floor` is the generation of the last full spill.
    pub fn chunks_dirty_since(&self, floor: u64) -> Vec<usize> {
        self.inner.chunks_dirty_since(floor)
    }

    /// Visit `(ext, coords)` of one chunk.
    pub fn for_each_in_chunk(&self, ix: usize, mut f: impl FnMut(u64, &[f32])) {
        self.inner.for_each_in_chunk(ix, |k, v| f(k, v.as_ref()));
    }
}

impl Default for CoordMap {
    fn default() -> Self {
        Self::new()
    }
}

/// Summary counters of one view (see [`SnapshotView::stats`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SnapshotStats {
    pub version: u64,
    /// writes the engine had accepted but not published when this handle
    /// was obtained — 0 on a view returned by `publish`
    pub pending_writes: u64,
    pub live_points: usize,
    pub core_points: usize,
    pub clusters: usize,
}

/// An immutable, versioned view of the clustering — the uniform read
/// handle of every serve backend. Cheap to clone and safe to hand to
/// other threads; it never blocks (or observes) the update path. See the
/// [module docs](self) for the freshness contract.
#[derive(Clone, Debug)]
pub struct SnapshotView {
    version: u64,
    pending: u64,
    live_points: usize,
    core_points: usize,
    cluster_sizes: Arc<Vec<(i64, usize)>>,
    labels: LabelMap,
    /// core-primary set ([`LabelMap`] used as a CoW set)
    cores: LabelMap,
    coords: CoordMap,
    /// publish-pinned ε-cell index; `None` when disabled or past the
    /// policy's dimension threshold (reads fall back to the scan oracle)
    index: Option<Arc<SpatialIndex>>,
    /// label → sorted members, built lazily on the first
    /// `cluster_members` call and shared by every clone of this view
    members: Arc<OnceLock<FxHashMap<i64, Vec<u64>>>>,
    /// placement-map version this view was published under (0 on the
    /// single backend and before any live migration)
    reshard_epoch: u64,
    eps: f32,
    dim: usize,
}

impl SnapshotView {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        version: u64,
        pending: u64,
        live_points: usize,
        core_points: usize,
        cluster_sizes: Arc<Vec<(i64, usize)>>,
        labels: LabelMap,
        cores: LabelMap,
        coords: CoordMap,
        index: Option<Arc<SpatialIndex>>,
        eps: f32,
        dim: usize,
    ) -> Self {
        debug_assert!(
            index.as_ref().map(|ix| ix.len() == coords.len()).unwrap_or(true),
            "spatial index out of sync with the coordinate store"
        );
        SnapshotView {
            version,
            pending,
            live_points,
            core_points,
            cluster_sizes,
            labels,
            cores,
            coords,
            index,
            members: Arc::new(OnceLock::new()),
            reshard_epoch: 0,
            eps,
            dim,
        }
    }

    /// The view of an engine that has never published (version 0, empty).
    pub(crate) fn empty(eps: f32, dim: usize) -> Self {
        SnapshotView {
            version: 0,
            pending: 0,
            live_points: 0,
            core_points: 0,
            cluster_sizes: Arc::new(Vec::new()),
            labels: LabelMap::new(),
            cores: LabelMap::new(),
            coords: CoordMap::new(),
            index: None,
            members: Arc::new(OnceLock::new()),
            reshard_epoch: 0,
            eps,
            dim,
        }
    }

    pub(crate) fn set_pending(&mut self, pending: u64) {
        self.pending = pending;
    }

    pub(crate) fn set_reshard_epoch(&mut self, epoch: u64) {
        self.reshard_epoch = epoch;
    }

    /// Placement-map version this view was published under: bumped once
    /// per applied live-resharding migration, 0 on the single backend.
    /// Views with equal `(version, reshard_epoch)` were routed under the
    /// same cell→shard assignment.
    pub fn reshard_epoch(&self) -> u64 {
        self.reshard_epoch
    }

    /// Shift the version by a recovered base — the durability wrapper's
    /// continuity hook: after crash recovery the inner engine restarts its
    /// publish counter, and the wrapper re-anchors it at the version the
    /// WAL says was last published.
    pub(crate) fn rebase_version(&mut self, base: u64) {
        self.version += base;
    }

    /// Visit every live point as `(ext, coords, label, is_core)` — the
    /// checkpoint writer's serialization walk. Unordered.
    pub(crate) fn for_each_point(&self, f: &mut dyn FnMut(u64, &[f32], i64, bool)) {
        for (ext, coords) in self.coords.iter() {
            // labels and coords are published from the same barrier, so a
            // live coordinate row always has a label
            let label = self.labels.get(ext).unwrap_or(-1);
            f(ext, coords, label, self.cores.get(ext).is_some());
        }
    }

    /// Write generation of the coordinate store frozen in this view —
    /// the dirty clock the incremental checkpoint spill records and later
    /// diffs against.
    pub(crate) fn coords_generation(&self) -> u64 {
        self.coords.generation()
    }

    /// Chunk count of the frozen coordinate store (power of two).
    pub(crate) fn coords_num_chunks(&self) -> usize {
        self.coords.num_chunks()
    }

    /// Coordinate chunks mutated after generation `floor` as of this
    /// view — the incremental spill's dirty set.
    pub(crate) fn coords_chunks_dirty_since(&self, floor: u64) -> Vec<usize> {
        self.coords.chunks_dirty_since(floor)
    }

    /// Visit `(ext, coords)` of one coordinate chunk — the incremental
    /// spill's per-dirty-chunk serialization walk.
    pub(crate) fn for_each_point_in_chunk(
        &self,
        ix: usize,
        f: &mut dyn FnMut(u64, &[f32]),
    ) {
        self.coords.for_each_in_chunk(ix, |ext, coords| f(ext, coords));
    }

    /// Visit every live point as `(ext, label, is_core)` without touching
    /// coordinates — the incremental spill's label overlay walk.
    pub(crate) fn for_each_label(&self, f: &mut dyn FnMut(u64, i64, bool)) {
        for (ext, label) in self.labels.iter() {
            f(ext, label, self.cores.get(ext).is_some());
        }
    }

    /// Publish counter of the producing engine; strictly increasing, and
    /// equal versions answer identically.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Writes accepted by the engine but **not** reflected here, counted
    /// when this handle was obtained. 0 on views returned by `publish`.
    pub fn pending_writes(&self) -> u64 {
        self.pending
    }

    /// Global cluster of an external id: `None` when not live (as of this
    /// view), `Some(-1)` for noise, `Some(l ≥ 0)` for cluster `l`.
    pub fn label(&self, ext: u64) -> Option<i64> {
        self.labels.get(ext)
    }

    /// Is `ext` live in this view?
    pub fn contains(&self, ext: u64) -> bool {
        self.labels.get(ext).is_some()
    }

    /// Is `ext` a core point (Definition 4) as of this view? `false` for
    /// non-core and unknown ids alike, matching the structure-level
    /// convention.
    pub fn is_core(&self, ext: u64) -> bool {
        self.cores.get(ext).is_some()
    }

    /// Coordinates of a live point, pinned at publish time.
    pub fn coords_of(&self, ext: u64) -> Option<&[f32]> {
        self.coords.get(ext)
    }

    /// `(label, size)` sorted by size descending (ties: label ascending);
    /// noise excluded.
    pub fn cluster_sizes(&self) -> &[(i64, usize)] {
        &self.cluster_sizes
    }

    /// Number of clusters (noise excluded).
    pub fn clusters(&self) -> usize {
        self.cluster_sizes.len()
    }

    pub fn live_points(&self) -> usize {
        self.live_points
    }

    pub fn core_points(&self) -> usize {
        self.core_points
    }

    /// The lazily built label → sorted-members inverted index. First call
    /// pays one `O(n log n)` build (noise, key `-1`, included — it is
    /// *not* re-materialized per call); every later call on this view or
    /// any clone of it is a lookup. Never built on the publish path.
    fn members_index(&self) -> &FxHashMap<i64, Vec<u64>> {
        self.members.get_or_init(|| {
            let mut m: FxHashMap<i64, Vec<u64>> = FxHashMap::default();
            for (e, l) in self.labels.iter() {
                m.entry(l).or_default().push(e);
            }
            for v in m.values_mut() {
                v.sort_unstable();
            }
            m
        })
    }

    /// Members of a cluster (`-1`: the noise set), sorted by ext.
    /// `O(|cluster|)` copy off the lazy inverted index (one `O(n log n)`
    /// build amortized over every query on this snapshot version); an
    /// unknown label — or any label on an empty snapshot — is `[]`.
    pub fn cluster_members(&self, label: i64) -> Vec<u64> {
        self.members_index().get(&label).cloned().unwrap_or_default()
    }

    /// Scan-oracle twin of [`Self::cluster_members`]: one-shot `O(n)`
    /// label filter, no inverted index (for the differential suite).
    pub fn cluster_members_scan(&self, label: i64) -> Vec<u64> {
        let mut out: Vec<u64> = self
            .labels
            .iter()
            .filter(|&(_, l)| l == label)
            .map(|(e, _)| e)
            .collect();
        out.sort_unstable();
        out
    }

    /// Data dimensionality of the producing engine.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Neighborhood radius of the producing engine (checkpoint metadata).
    pub(crate) fn eps(&self) -> f32 {
        self.eps
    }

    /// Live points within Euclidean distance ε of `x` (the classical
    /// DBSCAN ε-neighborhood), sorted by ext. Answered from the
    /// publish-pinned spatial index when one is attached — ≤ `3^d`
    /// cell probes, sublinear in `n` — and bit-identically from the
    /// `O(n·d)` scan oracle otherwise. Panics on a wrong-dimensionality
    /// probe (a truncated zip would silently inflate the neighborhood).
    pub fn epsilon_neighbors(&self, x: &[f32]) -> Vec<u64> {
        assert_eq!(x.len(), self.dim, "bad dim in epsilon_neighbors");
        match &self.index {
            Some(ix) => ix.epsilon_neighbors(x),
            None => self.epsilon_neighbors_scan(x),
        }
    }

    /// Scan-oracle twin of [`Self::epsilon_neighbors`]: always the
    /// brute-force `O(n·d)` pass over the pinned coordinates, regardless
    /// of any attached index (for the differential suite and the
    /// indexed-vs-scan bench axis).
    pub fn epsilon_neighbors_scan(&self, x: &[f32]) -> Vec<u64> {
        assert_eq!(x.len(), self.dim, "bad dim in epsilon_neighbors_scan");
        index::scan_epsilon(self.coords.iter(), x, self.eps)
    }

    /// The `k` nearest live points to `x` as `(ext, Euclidean distance)`,
    /// ordered by `(distance², ext)` ascending (fewer than `k` when the
    /// snapshot is smaller; `[]` on an empty snapshot). Expanding-ring
    /// search on the pinned index when attached, scan fallback otherwise
    /// — identical results either way. Panics on a wrong-dimensionality
    /// probe.
    pub fn k_nearest(&self, x: &[f32], k: usize) -> Vec<(u64, f64)> {
        assert_eq!(x.len(), self.dim, "bad dim in k_nearest");
        match &self.index {
            Some(ix) => ix.k_nearest(x, k),
            None => self.k_nearest_scan(x, k),
        }
    }

    /// Scan-oracle twin of [`Self::k_nearest`] (for the differential
    /// suite and the indexed-vs-scan bench axis).
    pub fn k_nearest_scan(&self, x: &[f32], k: usize) -> Vec<(u64, f64)> {
        assert_eq!(x.len(), self.dim, "bad dim in k_nearest_scan");
        index::scan_k_nearest(self.coords.iter(), x, k)
    }

    /// Is an ε-cell spatial index attached to this view? `false` means
    /// neighborhood reads use the scan fallback (index disabled via
    /// `EngineBuilder::spatial_index(false)` or `dim` past the policy
    /// threshold).
    pub fn has_spatial_index(&self) -> bool {
        self.index.is_some()
    }

    /// `(ext, label)` for every live point, sorted by ext — `O(n log n)`,
    /// for quality evaluation and tests.
    pub fn labels(&self) -> Vec<(u64, i64)> {
        self.labels.sorted()
    }

    /// Summary counters of this view.
    pub fn stats(&self) -> SnapshotStats {
        SnapshotStats {
            version: self.version,
            pending_writes: self.pending,
            live_points: self.live_points,
            core_points: self.core_points,
            clusters: self.cluster_sizes.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coord_map_roundtrip_and_cow() {
        let mut m = CoordMap::new();
        for e in 0..500u64 {
            m.set(e, &[e as f32, -(e as f32)]);
        }
        assert_eq!(m.len(), 500);
        let snap = m.clone(); // "publish"
        m.set(7, &[9.0, 9.0]);
        m.remove(8);
        assert_eq!(snap.get(7), Some(&[7.0, -7.0][..]));
        assert!(snap.get(8).is_some());
        assert_eq!(m.get(7), Some(&[9.0, 9.0][..]));
        assert!(m.get(8).is_none());
        assert_eq!(m.len(), 499);
    }

    #[test]
    fn coord_map_growth_preserves_content() {
        let mut m = CoordMap::new();
        for e in 0..10_000u64 {
            m.set(e * 3, &[e as f32]);
        }
        m.maybe_grow();
        assert_eq!(m.len(), 10_000);
        for e in 0..10_000u64 {
            assert_eq!(m.get(e * 3), Some(&[e as f32][..]));
        }
        assert!(m.get(1).is_none());
    }

    #[test]
    fn view_queries_on_manual_state() {
        let mut labels = LabelMap::new();
        let mut cores = LabelMap::new();
        let mut coords = CoordMap::new();
        for (e, l, x) in
            [(1u64, 0i64, 0.0f32), (2, 0, 0.1), (3, -1, 5.0), (9, 1, 10.0)]
        {
            labels.set(e, l);
            coords.set(e, &[x, 0.0]);
        }
        cores.set(1, 1);
        cores.set(9, 1);
        let mut ix = SpatialIndex::new(0.5, 2, 2.0);
        for (e, c) in coords.iter() {
            ix.upsert(e, c);
        }
        let view = SnapshotView::new(
            3,
            2,
            4,
            2,
            Arc::new(vec![(0, 2), (1, 1)]),
            labels,
            cores,
            coords,
            Some(Arc::new(ix)),
            0.5,
            2,
        );
        assert_eq!(view.dim(), 2);
        assert_eq!(view.version(), 3);
        assert_eq!(view.pending_writes(), 2);
        assert_eq!(view.label(1), Some(0));
        assert_eq!(view.label(3), Some(-1));
        assert_eq!(view.label(4), None);
        assert!(view.is_core(1) && view.is_core(9));
        assert!(!view.is_core(2) && !view.is_core(404));
        assert_eq!(view.cluster_members(0), vec![1, 2]);
        assert_eq!(view.cluster_members(-1), vec![3]);
        assert!(view.has_spatial_index());
        assert_eq!(view.epsilon_neighbors(&[0.0, 0.0]), vec![1, 2]);
        assert_eq!(view.epsilon_neighbors_scan(&[0.0, 0.0]), vec![1, 2]);
        assert_eq!(
            view.k_nearest(&[0.0, 0.0], 2),
            view.k_nearest_scan(&[0.0, 0.0], 2)
        );
        assert_eq!(view.k_nearest(&[0.0, 0.0], 1)[0].0, 1);
        assert_eq!(view.clusters(), 2);
        assert_eq!(view.stats().live_points, 4);
        assert_eq!(view.labels(), vec![(1, 0), (2, 0), (3, -1), (9, 1)]);
    }

    #[test]
    fn noise_members_and_members_scan_agree() {
        let mut labels = LabelMap::new();
        let mut coords = CoordMap::new();
        for (e, l) in [(5u64, -1i64), (2, -1), (8, 0), (1, -1)] {
            labels.set(e, l);
            coords.set(e, &[e as f32, 0.0]);
        }
        let view = SnapshotView::new(
            1,
            0,
            4,
            0,
            Arc::new(vec![(0, 1)]),
            labels,
            LabelMap::new(),
            coords,
            None,
            0.5,
            2,
        );
        // noise (-1) comes off the same lazy inverted index as any
        // cluster — sorted, not re-materialized per call
        assert_eq!(view.cluster_members(-1), vec![1, 2, 5]);
        assert_eq!(view.cluster_members(-1), view.cluster_members_scan(-1));
        assert_eq!(view.cluster_members(0), vec![8]);
        assert_eq!(view.cluster_members(42), Vec::<u64>::new());
        assert_eq!(view.cluster_members_scan(42), Vec::<u64>::new());
    }

    #[test]
    fn empty_snapshot_edge_cases() {
        let view = SnapshotView::empty(0.5, 3);
        assert!(!view.has_spatial_index());
        assert_eq!(view.cluster_members(-1), Vec::<u64>::new());
        assert_eq!(view.cluster_members(0), Vec::<u64>::new());
        assert_eq!(view.epsilon_neighbors(&[0.0; 3]), Vec::<u64>::new());
        assert_eq!(view.k_nearest(&[0.0; 3], 5), Vec::<(u64, f64)>::new());
        assert_eq!(view.k_nearest(&[0.0; 3], 0), Vec::<(u64, f64)>::new());
        assert_eq!(view.stats().live_points, 0);
    }
}
