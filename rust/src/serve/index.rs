//! Snapshot-pinned spatial index: sublinear ε-neighborhood and kNN reads.
//!
//! The serving north star is millions of read-QPS, but until this module
//! the only read path for [`super::SnapshotView::epsilon_neighbors`] was an
//! `O(n·d)` scan over the CoW coordinate store. Low-dimensional
//! DBSCAN-style neighborhood queries are answerable in sublinear time from
//! grid/box decompositions (de Berg et al., arXiv:1702.08607), and the
//! ε-grid-cell decomposition is exactly what the write-path
//! [`crate::lsh::GridHasher`] already computes (cf. Wang–Gu–Shun,
//! arXiv:1912.06255). [`SpatialIndex`] turns those cells into a read-side
//! structure:
//!
//! * **ε-cell bucket table** — `cell key → CellBucket` where a bucket holds
//!   packed ext-id + row-major coordinate rows for every live point whose
//!   per-axis cell is `⌊x_i / side⌋` (`side = cell_factor · ε`, default
//!   `2ε` to match the write-path grid). Stored in a
//!   [`ChunkedCowMap`] of `Arc<CellBucket>`: publishing clones chunk
//!   *pointers*, and a delta publish deep-copies only the chunks — and via
//!   `Arc::make_mut` only the *buckets* — actually touched, so maintenance
//!   is folded into the delta-publish path in `O(Δ)` extra work.
//! * **reverse map** — `ext → cell key`, so upserts/removes find the old
//!   bucket without rehashing stale coordinates.
//!
//! Cell keys are 64-bit mixes ([`lsh::cell_key`]); a key collision merges
//! two cells' candidate lists, which the exact distance filter below makes
//! harmless (unlike the write-path LSH buckets, which need 128 bits).
//!
//! ## Exactness contract
//!
//! Indexed results are **bit-identical** to the brute-force scan: both
//! paths share one distance kernel ([`dist2`] — f32 subtraction widened to
//! f64, matching the pre-index scan), the probe box is *conservatively*
//! widened by a `1e-6` relative margin (over-probing is filtered away;
//! under-probing can never happen), and kNN tie-breaking is the
//! lexicographic `(d², ext)` order in both the heap and the oracle sort.
//! The scan oracles themselves live here too ([`scan_epsilon`],
//! [`scan_k_nearest`]) — `tests/lint.rs` confines raw distance scans to
//! this module so no new `O(n·d)` read path sneaks into serve.
//!
//! ## Dimension threshold
//!
//! An ε-probe visits ≤ `(1 + ⌈ε/side⌉·2)^d ≤ 3^d` adjacent cells (exactly
//! `2^d` box corners at the default `side = 2ε`) and the kNN ring search
//! `≈ 3^d` per ring, pruned by per-axis slab distance to roughly `1.5^d`
//! visited on clustered data. Past `max_dim` (ablation: the crossover
//! sits between the 2^12 = 4096-cell probe box and the scan on the
//! standard 50k-point workloads) enumeration overhead swamps the scan, so
//! [`IndexPolicy::build_for`] returns `None` and views fall back to the
//! scan oracle.

use std::sync::Arc;

use rustc_hash::FxHashSet;

use crate::lsh;
use crate::util::cow_map::ChunkedCowMap;

/// Target mean *cells* per CoW chunk — coarser than the per-point maps
/// (cells aggregate many points, and a chunk deep-copy clones only
/// `Arc<CellBucket>` pointers).
const TARGET_CELLS_PER_CHUNK: usize = 8;

/// Relative slack applied to probe ranges and prune bounds so f64
/// rounding can only ever *over*-probe (the exact filter removes the
/// excess), never miss a true neighbor.
const PROBE_SLACK: f64 = 1e-6;

/// Index build/maintenance policy — the `EngineBuilder` knobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IndexPolicy {
    /// Build the index at all? `false` pins every view to the scan oracle.
    pub enabled: bool,
    /// Cell side length as a multiple of ε. 2.0 matches the write-path
    /// grid (probe box = `2^d` cells); smaller cells probe more buckets
    /// with fewer points each.
    pub cell_factor: f32,
    /// Above this dimensionality the probe fan-out beats the scan —
    /// `build_for` returns `None` and reads fall back (see module docs).
    pub max_dim: usize,
    /// Rebuild the index from scratch at every publish instead of
    /// delta-maintaining it — the `StitchMode::FullRebuild` analogue,
    /// kept as an ablation/fallback.
    pub rebuild_at_publish: bool,
}

impl Default for IndexPolicy {
    fn default() -> Self {
        IndexPolicy {
            enabled: true,
            cell_factor: 2.0,
            max_dim: 12,
            rebuild_at_publish: false,
        }
    }
}

impl IndexPolicy {
    /// The index this policy prescribes for an engine of the given shape —
    /// `None` when disabled or past the dimension threshold (reads then
    /// use the scan fallback).
    pub(crate) fn build_for(&self, eps: f32, dim: usize) -> Option<SpatialIndex> {
        if !self.enabled || dim > self.max_dim {
            return None;
        }
        Some(SpatialIndex::new(eps, dim, self.cell_factor))
    }
}

/// Packed rows of one ε-cell: parallel ext ids and row-major coordinates.
#[derive(Clone, Debug, Default)]
pub(crate) struct CellBucket {
    exts: Vec<u64>,
    coords: Vec<f32>,
}

impl CellBucket {
    fn rows(&self, dim: usize) -> impl Iterator<Item = (u64, &[f32])> + '_ {
        self.exts.iter().zip(self.coords.chunks_exact(dim)).map(|(&e, c)| (e, c))
    }

    fn push(&mut self, ext: u64, x: &[f32]) {
        self.exts.push(ext);
        self.coords.extend_from_slice(x);
    }

    /// Swap-remove the row of `ext`; false if absent.
    fn remove_ext(&mut self, ext: u64, dim: usize) -> bool {
        let Some(i) = self.exts.iter().position(|&e| e == ext) else {
            return false;
        };
        let last = self.exts.len() - 1;
        self.exts.swap_remove(i);
        if i != last {
            let (head, tail) = self.coords.split_at_mut(last * dim);
            head[i * dim..(i + 1) * dim].copy_from_slice(&tail[..dim]);
        }
        self.coords.truncate(last * dim);
        true
    }
}

/// Immutable-after-publish ε-cell index over the live coordinate set. The
/// owning engine mutates it in `O(1)` per update op and clones it at
/// publish (chunk-pointer copies); views share the clone behind an `Arc`.
#[derive(Clone, Debug)]
pub(crate) struct SpatialIndex {
    /// cell key → bucket; `Arc` values so a chunk deep-copy clones bucket
    /// *pointers* and only the touched bucket is deep-copied
    cells: ChunkedCowMap<Arc<CellBucket>>,
    /// ext → current cell key (liveness + relocation bookkeeping)
    ext_cell: ChunkedCowMap<u64>,
    eps: f32,
    dim: usize,
    cell_factor: f32,
    /// cell side length, `cell_factor · ε` in f64
    side: f64,
}

impl SpatialIndex {
    pub fn new(eps: f32, dim: usize, cell_factor: f32) -> Self {
        assert!(eps > 0.0 && dim > 0);
        assert!(cell_factor.is_finite() && cell_factor > 0.0);
        SpatialIndex {
            cells: ChunkedCowMap::new(TARGET_CELLS_PER_CHUNK),
            ext_cell: ChunkedCowMap::new(TARGET_CELLS_PER_CHUNK * 4),
            eps,
            dim,
            cell_factor,
            side: cell_factor as f64 * eps as f64,
        }
    }

    /// Indexed points.
    pub fn len(&self) -> usize {
        self.ext_cell.len()
    }

    /// Non-empty ε-cells — the `index_cells` gauge.
    pub fn num_cells(&self) -> usize {
        self.cells.len()
    }

    /// Fraction of CoW chunks still shared with the last published clone
    /// (the more conservative of the two underlying maps) — the
    /// `cow_index_sharing` gauge.
    pub fn sharing_ratio(&self) -> f64 {
        self.cells.sharing_ratio().min(self.ext_cell.sharing_ratio())
    }

    /// Double chunk counts once occupancy exceeds target — between
    /// publishes, like the label/coord maps.
    pub fn maybe_grow(&mut self) {
        self.cells.maybe_grow();
        self.ext_cell.maybe_grow();
    }

    #[inline]
    fn cell_coord(&self, v: f32) -> i64 {
        (v as f64 / self.side).floor() as i64
    }

    fn key_of(&self, x: &[f32], scratch: &mut Vec<i64>) -> u64 {
        scratch.clear();
        scratch.extend(x.iter().map(|&v| self.cell_coord(v)));
        lsh::cell_key(scratch)
    }

    /// Insert or relocate a point. Same-cell coordinate updates rewrite
    /// the row in place; cross-cell moves detach from the old bucket
    /// first. `O(bucket)` worst case, `O(1)` amortized on ε-scale cells.
    pub fn upsert(&mut self, ext: u64, x: &[f32]) {
        debug_assert_eq!(x.len(), self.dim);
        let dim = self.dim;
        let mut scratch = Vec::with_capacity(dim);
        let key = self.key_of(x, &mut scratch);
        if let Some(&old) = self.ext_cell.get(ext) {
            if old == key {
                if let Some(b) = self.cells.get_mut(old) {
                    let b = Arc::make_mut(b);
                    if let Some(i) = b.exts.iter().position(|&e| e == ext) {
                        b.coords[i * dim..(i + 1) * dim].copy_from_slice(x);
                        return;
                    }
                }
                debug_assert!(false, "ext_cell points at a bucket without the ext");
            } else {
                self.detach(ext, old);
            }
        }
        self.ext_cell.set(ext, key);
        let b = self.cells.get_or_insert_with(key, || Arc::new(CellBucket::default()));
        Arc::make_mut(b).push(ext, x);
    }

    /// Remove a point; absent exts are a no-op (never deep-copies a
    /// shared chunk).
    pub fn remove(&mut self, ext: u64) {
        if let Some(old) = self.ext_cell.remove(ext) {
            self.detach(ext, old);
        }
    }

    fn detach(&mut self, ext: u64, key: u64) {
        let dim = self.dim;
        let emptied = match self.cells.get_mut(key) {
            Some(b) => {
                let b = Arc::make_mut(b);
                let found = b.remove_ext(ext, dim);
                debug_assert!(found, "ext_cell pointed at a bucket without the ext");
                b.exts.is_empty()
            }
            None => {
                debug_assert!(false, "ext_cell pointed at a missing bucket");
                false
            }
        };
        if emptied {
            self.cells.remove(key);
        }
    }

    /// Rebuild from scratch off a row iterator — the
    /// `rebuild_at_publish` fallback and the recovery path.
    pub fn rebuild<'a>(&mut self, rows: impl Iterator<Item = (u64, &'a [f32])>) {
        *self = SpatialIndex::new(self.eps, self.dim, self.cell_factor);
        for (e, x) in rows {
            self.upsert(e, x);
        }
    }

    /// All indexed rows, unordered.
    fn rows(&self) -> impl Iterator<Item = (u64, &[f32])> + '_ {
        self.cells.iter().flat_map(move |(_, b)| b.rows(self.dim))
    }

    /// Enumerate the cells of the axis-aligned box `ranges`, pruning any
    /// subtree whose accumulated per-axis slab distance² to `x` exceeds
    /// `bound`. Visits each surviving cell's key once per distinct cell.
    fn probe_box(
        &self,
        x: &[f32],
        ranges: &[(i64, i64)],
        bound: f64,
        cell: &mut Vec<i64>,
        visit: &mut dyn FnMut(u64),
    ) {
        self.probe_rec(x, ranges, bound, 0, 0.0, cell, visit);
    }

    #[allow(clippy::too_many_arguments)]
    fn probe_rec(
        &self,
        x: &[f32],
        ranges: &[(i64, i64)],
        bound: f64,
        axis: usize,
        acc: f64,
        cell: &mut Vec<i64>,
        visit: &mut dyn FnMut(u64),
    ) {
        if axis == ranges.len() {
            visit(lsh::cell_key(cell));
            return;
        }
        let (lo, hi) = ranges[axis];
        for c in lo..=hi {
            let gap = axis_gap(x[axis] as f64, c, self.side);
            let acc2 = acc + gap * gap;
            if acc2 > bound {
                continue;
            }
            cell[axis] = c;
            self.probe_rec(x, ranges, bound, axis + 1, acc2, cell, visit);
        }
    }

    /// Live points within Euclidean distance ε of `x`, sorted by ext —
    /// bit-identical to [`scan_epsilon`] over the same rows. Probes the
    /// ≤ `3^d` cells overlapping the ε-ball (exactly `2^d` at the default
    /// `side = 2ε`), slab-pruned.
    pub fn epsilon_neighbors(&self, x: &[f32]) -> Vec<u64> {
        debug_assert_eq!(x.len(), self.dim);
        let eps2 = (self.eps as f64) * (self.eps as f64);
        let bound = eps2 * (1.0 + PROBE_SLACK);
        let r = self.eps as f64 * (1.0 + PROBE_SLACK);
        let ranges: Vec<(i64, i64)> = x
            .iter()
            .map(|&v| {
                let v = v as f64;
                (
                    ((v - r) / self.side).floor() as i64,
                    ((v + r) / self.side).floor() as i64,
                )
            })
            .collect();
        let mut out = Vec::new();
        let mut cell = vec![0i64; self.dim];
        self.probe_box(x, &ranges, bound, &mut cell, &mut |key| {
            if let Some(b) = self.cells.get(key) {
                for (ext, row) in b.rows(self.dim) {
                    if dist2(row, x) <= eps2 {
                        out.push(ext);
                    }
                }
            }
        });
        out.sort_unstable();
        // a 64-bit key collision inside the probe box would visit one
        // merged bucket twice — dedup keeps the result set exact
        out.dedup();
        out
    }

    /// The `k` nearest live points to `x` as `(ext, distance)`, ordered by
    /// `(distance², ext)` ascending — bit-identical to [`scan_k_nearest`].
    /// Expanding Chebyshev-ring search from `x`'s cell; after finishing
    /// ring `r` every unvisited cell is ≥ `r·side` away, so the search
    /// stops once the current kth distance² is strictly below
    /// `(r·side)²` (with downward slack, so exact-distance ties keep
    /// probing and resolve by ext like the oracle sort).
    pub fn k_nearest(&self, x: &[f32], k: usize) -> Vec<(u64, f64)> {
        debug_assert_eq!(x.len(), self.dim);
        let total = self.len();
        if k == 0 || total == 0 {
            return Vec::new();
        }
        // cells enumerated before conceding the data is too spread out
        // for ring search and falling back to an internal full scan
        let budget = 4096usize.max(self.num_cells() * 4);
        let center: Vec<i64> = x.iter().map(|&v| self.cell_coord(v)).collect();
        // max-heap of (d²-bits, ext): non-negative f64 bits are
        // order-isomorphic to the values, so the heap keeps the k
        // lexicographically smallest (d², ext) pairs
        let mut heap: std::collections::BinaryHeap<(u64, u64)> =
            std::collections::BinaryHeap::new();
        let mut visited: FxHashSet<u64> = FxHashSet::default();
        let mut examined = 0usize;
        let mut enumerated = 0usize;
        let mut cell = vec![0i64; self.dim];
        for ring in 0i64.. {
            let ranges: Vec<(i64, i64)> =
                center.iter().map(|&c| (c - ring, c + ring)).collect();
            let bound = if heap.len() >= k {
                f64::from_bits(heap.peek().expect("heap has >= k >= 1 entries").0)
                    * (1.0 + PROBE_SLACK)
            } else {
                f64::INFINITY
            };
            self.probe_box(x, &ranges, bound, &mut cell, &mut |key| {
                enumerated += 1;
                if !visited.insert(key) {
                    return; // inner cells of previous rings
                }
                if let Some(b) = self.cells.get(key) {
                    for (ext, row) in b.rows(self.dim) {
                        examined += 1;
                        let bits = dist2(row, x).to_bits();
                        if heap.len() < k {
                            heap.push((bits, ext));
                        } else if (bits, ext) < *heap.peek().expect("heap is non-empty") {
                            heap.pop();
                            heap.push((bits, ext));
                        }
                    }
                }
            });
            if examined >= total {
                break; // every indexed point has been scored
            }
            if heap.len() >= k {
                let kth = f64::from_bits(heap.peek().expect("heap has k entries").0);
                let ring_lb = ring as f64 * self.side;
                if kth < ring_lb * ring_lb * (1.0 - PROBE_SLACK) {
                    break;
                }
            }
            if enumerated > budget {
                // sparse/far data: ring search degenerates — exact scan
                // over our own rows (same kernel, same order, same result)
                return scan_k_nearest(self.rows(), x, k);
            }
        }
        let mut out: Vec<(u64, u64)> = heap.into_iter().collect();
        out.sort_unstable();
        out.into_iter().map(|(bits, ext)| (ext, f64::from_bits(bits).sqrt())).collect()
    }
}

/// Distance from `x` to the slab `[c·side, (c+1)·side]` on one axis.
/// Closed interval: boundary points report gap 0, which only ever
/// *weakens* pruning (conservative).
#[inline]
fn axis_gap(x: f64, c: i64, side: f64) -> f64 {
    let lo = c as f64 * side;
    let hi = lo + side;
    if x < lo {
        lo - x
    } else if x > hi {
        x - hi
    } else {
        0.0
    }
}

/// The one distance kernel both read paths share: f32 subtraction widened
/// to f64, exactly the arithmetic of the pre-index scan — this is what
/// makes indexed results bit-identical to the oracle.
#[inline]
pub(crate) fn dist2(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b.iter())
        .map(|(&p, &q)| {
            let d = (p - q) as f64;
            d * d
        })
        .sum()
}

/// Brute-force ε-neighborhood oracle/fallback: every row within ε of `x`,
/// sorted by ext. The only sanctioned `O(n·d)` distance scan
/// (lint-enforced).
pub(crate) fn scan_epsilon<'a>(
    rows: impl Iterator<Item = (u64, &'a [f32])>,
    x: &[f32],
    eps: f32,
) -> Vec<u64> {
    let eps2 = (eps as f64) * (eps as f64);
    let mut out: Vec<u64> =
        rows.filter(|(_, c)| dist2(c, x) <= eps2).map(|(e, _)| e).collect();
    out.sort_unstable();
    out
}

/// Brute-force kNN oracle/fallback: all rows scored and sorted by
/// `(distance², ext)`, truncated to `k`, as `(ext, distance)`.
pub(crate) fn scan_k_nearest<'a>(
    rows: impl Iterator<Item = (u64, &'a [f32])>,
    x: &[f32],
    k: usize,
) -> Vec<(u64, f64)> {
    let mut all: Vec<(u64, u64)> = rows.map(|(e, c)| (dist2(c, x).to_bits(), e)).collect();
    all.sort_unstable();
    all.truncate(k);
    all.into_iter().map(|(bits, e)| (e, f64::from_bits(bits).sqrt())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_points(rng: &mut Rng, n: usize, dim: usize, extent: f64) -> Vec<(u64, Vec<f32>)> {
        (0..n as u64)
            .map(|e| {
                let x: Vec<f32> =
                    (0..dim).map(|_| ((rng.next_f64() - 0.5) * extent) as f32).collect();
                (e, x)
            })
            .collect()
    }

    #[test]
    fn upsert_remove_relocate_roundtrip() {
        let mut ix = SpatialIndex::new(0.5, 2, 2.0);
        ix.upsert(1, &[0.1, 0.1]);
        ix.upsert(2, &[0.2, 0.2]); // same cell
        ix.upsert(3, &[10.0, 10.0]); // far cell
        assert_eq!(ix.len(), 3);
        assert_eq!(ix.num_cells(), 2);
        // in-place same-cell coordinate update
        ix.upsert(2, &[0.3, 0.3]);
        assert_eq!(ix.len(), 3);
        assert_eq!(ix.num_cells(), 2);
        assert_eq!(ix.epsilon_neighbors(&[0.3, 0.3]), vec![1, 2]);
        // cross-cell relocation
        ix.upsert(1, &[10.0, 10.1]);
        assert_eq!(ix.epsilon_neighbors(&[10.0, 10.0]), vec![1, 3]);
        assert_eq!(ix.epsilon_neighbors(&[0.3, 0.3]), vec![2]);
        // removal prunes emptied cells
        ix.remove(2);
        ix.remove(2); // absent: no-op
        assert_eq!(ix.len(), 2);
        assert_eq!(ix.num_cells(), 1);
        assert_eq!(ix.epsilon_neighbors(&[0.3, 0.3]), Vec::<u64>::new());
    }

    #[test]
    fn epsilon_matches_scan_randomized() {
        let mut rng = Rng::new(0xE75);
        for dim in [1usize, 2, 3, 5] {
            for _ in 0..20 {
                let eps = (0.2 + rng.next_f64() * 1.5) as f32;
                let factor = [0.5f32, 1.0, 2.0][(rng.next_u64() % 3) as usize];
                let mut ix = SpatialIndex::new(eps, dim, factor);
                let pts = random_points(&mut rng, 300, dim, 8.0);
                for (e, x) in &pts {
                    ix.upsert(*e, x);
                }
                for _ in 0..20 {
                    // half the probes sit exactly on a data point so
                    // distance-exactly-ε and duplicate cases get exercised
                    let probe: Vec<f32> = if rng.next_u64() % 2 == 0 {
                        pts[(rng.next_u64() as usize) % pts.len()].1.clone()
                    } else {
                        (0..dim).map(|_| ((rng.next_f64() - 0.5) * 8.0) as f32).collect()
                    };
                    let want = scan_epsilon(
                        pts.iter().map(|(e, x)| (*e, x.as_slice())),
                        &probe,
                        eps,
                    );
                    assert_eq!(ix.epsilon_neighbors(&probe), want, "dim={dim} eps={eps}");
                }
            }
        }
    }

    #[test]
    fn k_nearest_matches_scan_randomized() {
        let mut rng = Rng::new(0x4E4);
        for dim in [1usize, 2, 4] {
            for _ in 0..15 {
                let eps = (0.2 + rng.next_f64()) as f32;
                let mut ix = SpatialIndex::new(eps, dim, 2.0);
                let mut pts = random_points(&mut rng, 250, dim, 10.0);
                // duplicate coordinates: distance ties must break by ext
                let dup = pts[0].1.clone();
                pts.push((9_000, dup.clone()));
                pts.push((9_001, dup));
                for (e, x) in &pts {
                    ix.upsert(*e, x);
                }
                for &k in &[0usize, 1, 3, 10, 300] {
                    let probe: Vec<f32> =
                        (0..dim).map(|_| ((rng.next_f64() - 0.5) * 12.0) as f32).collect();
                    let want = scan_k_nearest(
                        pts.iter().map(|(e, x)| (*e, x.as_slice())),
                        &probe,
                        k,
                    );
                    assert_eq!(ix.k_nearest(&probe, k), want, "dim={dim} k={k}");
                }
            }
        }
    }

    #[test]
    fn k_nearest_far_probe_falls_back_consistently() {
        let mut rng = Rng::new(7);
        let mut ix = SpatialIndex::new(0.3, 3, 2.0);
        let pts = random_points(&mut rng, 100, 3, 2.0);
        for (e, x) in &pts {
            ix.upsert(*e, x);
        }
        // probe far outside the data extent: many empty rings
        let probe = [500.0f32, -500.0, 500.0];
        let want = scan_k_nearest(pts.iter().map(|(e, x)| (*e, x.as_slice())), &probe, 5);
        assert_eq!(ix.k_nearest(&probe, 5), want);
    }

    #[test]
    fn clone_shares_until_touched() {
        let mut rng = Rng::new(11);
        let mut ix = SpatialIndex::new(0.5, 2, 2.0);
        for (e, x) in random_points(&mut rng, 2_000, 2, 50.0) {
            ix.upsert(e, &x);
        }
        let snap = ix.clone(); // "publish"
        assert!((ix.sharing_ratio() - 1.0).abs() < 1e-12);
        let before = snap.epsilon_neighbors(&[0.0, 0.0]);
        ix.upsert(5_000, &[0.0, 0.0]);
        assert!(ix.sharing_ratio() < 1.0);
        // the published clone is unaffected
        assert_eq!(snap.epsilon_neighbors(&[0.0, 0.0]), before);
        assert!(ix.epsilon_neighbors(&[0.0, 0.0]).contains(&5_000));
    }

    #[test]
    fn rebuild_matches_incremental() {
        let mut rng = Rng::new(23);
        let mut inc = SpatialIndex::new(0.4, 3, 2.0);
        let pts = random_points(&mut rng, 500, 3, 6.0);
        for (e, x) in &pts {
            inc.upsert(*e, x);
        }
        for e in 0..100u64 {
            inc.remove(e * 3);
        }
        let live: Vec<(u64, Vec<f32>)> =
            pts.iter().filter(|(e, _)| !(*e % 3 == 0 && *e / 3 < 100)).cloned().collect();
        let mut full = SpatialIndex::new(0.4, 3, 2.0);
        full.rebuild(live.iter().map(|(e, x)| (*e, x.as_slice())));
        assert_eq!(inc.len(), full.len());
        assert_eq!(inc.num_cells(), full.num_cells());
        for _ in 0..10 {
            let probe: Vec<f32> =
                (0..3).map(|_| ((rng.next_f64() - 0.5) * 6.0) as f32).collect();
            assert_eq!(inc.epsilon_neighbors(&probe), full.epsilon_neighbors(&probe));
            assert_eq!(inc.k_nearest(&probe, 7), full.k_nearest(&probe, 7));
        }
    }

    #[test]
    fn policy_gates_build() {
        let p = IndexPolicy::default();
        assert!(p.build_for(0.5, 2).is_some());
        assert!(p.build_for(0.5, p.max_dim).is_some());
        assert!(p.build_for(0.5, p.max_dim + 1).is_none());
        let off = IndexPolicy { enabled: false, ..IndexPolicy::default() };
        assert!(off.build_for(0.5, 2).is_none());
    }
}
