//! [`DurableEngine`] — crash recovery for any serve backend.
//!
//! A decorator over `Box<dyn ClusterEngine>` that write-ahead-logs every
//! mutation into the segmented WAL under `<dir>` ([`crate::persist::wal`])
//! and periodically spills the published state into
//! `<dir>/checkpoint.ckpt` / `<dir>/checkpoint.delta`
//! ([`crate::persist::checkpoint`]). `EngineBuilder::persist(dir)` wraps
//! the chosen backend in this type; nothing else about the engine changes.
//!
//! ## Durability contract
//!
//! Op records are appended (buffered) *before* the op is applied in
//! memory; the group fsync runs inside `publish()`, before the published
//! view is returned. State observable through a returned
//! [`SnapshotView`] therefore survives a crash; writes accepted after the
//! last publish may not (they are re-accepted by the caller or lost,
//! exactly like a process that never got to publish them).
//!
//! ## Recovery
//!
//! On open, the wrapper loads the latest *valid* checkpoint chain
//! (full + incremental delta; corrupt or truncated pieces degrade to the
//! shorter chain), re-ingests its points through the public write path,
//! then replays the WAL tail past the chain's sequence floor — `Publish`
//! records replay as real publishes, so the engine resumes at the
//! recorded [`SnapshotView::version`] (continuity is kept by re-anchoring
//! the inner engine's fresh counter at the recovered version). Clustering
//! is *recomputed* from the coordinates during re-ingestion, which
//! inherits the engine's determinism instead of trusting serialized
//! labels; with no checkpoint, a cold full-log replay reproduces the
//! uninterrupted run op-for-op. On sharded backends the checkpoint also
//! carries the cell→shard placement map, restored *before* re-ingestion
//! so recovery reshards points to the same assignment the original run
//! had (and the WAL tail re-evolves it identically); a cold replay
//! instead re-derives placement from the same deterministic op stream.
//!
//! ## Incremental checkpoints
//!
//! With `EngineBuilder::incremental_checkpoints(true)` (the default), a
//! spill writes a full `DDCKPT02` file only when the chain needs a reset
//! (first spill, chunk-map growth, a long delta chain, or most chunks
//! dirty anyway); otherwise it writes a `DDCKPT03` delta — the coordinate
//! chunks of the façade's CoW store whose write generation moved since
//! the last *full* spill, plus a compact label/core overlay — and
//! atomically replaces `checkpoint.delta`. The WAL retention floor stays
//! at the **full** spill's sequence, so a damaged delta degrades to
//! `full + longer WAL tail`, never to data loss.
//!
//! ## Segment retention & log shipping
//!
//! Every spill seals the active WAL segment ([`WalWriter::roll`]) and
//! drops sealed segments below `min(full-checkpoint floor, slowest
//! shipped floor)` ([`WalWriter::retain`]). With no replicas attached the
//! ship floor is `∞` and this reduces to truncate-after-checkpoint; with
//! an attached [`crate::replica::LogShipper`] the log is shipped right
//! after each publish fsync (the frames a follower applies are exactly
//! the bytes the crash-recovery reader trusts), and segments survive
//! until the slowest follower has them.
//!
//! Known limit: cluster events emitted to `watch()` subscribers carry the
//! inner engine's un-rebased version after a recovery; views are always
//! rebased.

use std::io;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::obs::{Gauge, Metrics, Stopwatch};
use crate::persist::{
    clear_delta, load_checkpoint_chain, read_wal, write_checkpoint, write_delta,
    Checkpoint, CheckpointDelta, WalOp, WalRecord, WalWriter,
};
use crate::replica::LogShipper;

use super::events::ClusterEvents;
use super::snapshot::SnapshotView;
use super::{ClusterEngine, MetricsSnapshot, ServeOutcome, Stats, Update, WalStats};

/// Default publish cadence between checkpoint spills.
pub(crate) const DEFAULT_CHECKPOINT_EVERY: u64 = 8;

/// Incremental spills allowed between full spills before the chain is
/// reset with a full one (bounds both `checkpoint.delta` staleness and
/// how far behind the full floor the WAL retention can trail).
const DELTA_CHAIN_MAX: u64 = 8;

/// How many checkpoint points are re-ingested per `apply` batch during
/// recovery (bounds peak `Update` buffer size, and on the sharded backend
/// gives workers batch-level parallelism while replay streams).
const RECOVER_CHUNK: usize = 2048;

/// What [`recover_into`] reconstructed — shared by [`DurableEngine::open`]
/// and the replica bootstrap (`crate::replica::ReplicaEngine`), so a
/// follower's starting state is bit-for-bit the leader's recovery of the
/// same directory.
pub(crate) struct Recovered {
    /// next WAL sequence number to assign (leader) / first shipped
    /// sequence still needed (follower floor is `next_seq - 1`)
    pub next_seq: u64,
    /// recovered-version offset: external version = base + inner version
    pub version_base: u64,
    /// records + checkpoint points folded in (for the recovery metrics)
    pub replayed: u64,
}

/// Recover a **fresh, empty** engine to the durable state under `dir`:
/// checkpoint chain re-ingestion, then WAL tail replay past its floor.
pub(crate) fn recover_into(
    dir: &Path,
    inner: &mut Box<dyn ClusterEngine>,
) -> io::Result<Recovered> {
    let ckpt = load_checkpoint_chain(dir);
    let (records, _clean) = read_wal(dir)?;
    let mut replayed: u64 = 0;
    let mut next_seq: u64 = 1;
    // version to resume at: the checkpoint's, superseded by any later
    // Publish record in the tail
    let mut recovered_version: u64 = 0;
    let ckpt_floor = match &ckpt {
        Some(c) => {
            assert_eq!(
                c.dim as usize,
                inner.dim(),
                "checkpoint dim {} does not match the configured engine \
                 dim {} — wrong persist directory?",
                c.dim,
                inner.dim()
            );
            // pin the cell→shard assignment *before* any point flows
            // through the router, so re-ingestion (and the WAL tail
            // after it) reshards to the assignment the original run
            // had at spill time
            if let Some(blob) = &c.placement {
                inner.placement_restore(blob);
            }
            for chunk in c.points.chunks(RECOVER_CHUNK) {
                let batch: Vec<Update<'_>> = chunk
                    .iter()
                    .map(|(ext, coords)| Update::Upsert {
                        ext: *ext,
                        coords: coords.as_slice(),
                    })
                    .collect();
                inner.apply(&batch);
            }
            if !c.points.is_empty() || c.version > 0 {
                // materialize the checkpoint state as one publish, so
                // tail replay starts from the same published baseline
                // the original run had when the checkpoint was taken
                inner.publish();
            }
            recovered_version = c.version;
            next_seq = c.wal_seq + 1;
            replayed += c.points.len() as u64;
            c.wal_seq
        }
        None => 0,
    };
    for rec in &records {
        let seq = rec.seq();
        if seq <= ckpt_floor {
            continue; // already folded into the checkpoint
        }
        next_seq = next_seq.max(seq + 1);
        replayed += 1;
        match rec {
            WalRecord::Upsert { ext, coords, .. } => {
                inner.upsert(*ext, coords);
            }
            WalRecord::Remove { ext, .. } => inner.remove(*ext),
            WalRecord::Apply { ops, .. } => {
                let batch: Vec<Update<'_>> = ops
                    .iter()
                    .map(|op| match op {
                        WalOp::Upsert { ext, coords } => Update::Upsert {
                            ext: *ext,
                            coords: coords.as_slice(),
                        },
                        WalOp::Remove { ext } => Update::Remove { ext: *ext },
                    })
                    .collect();
                inner.apply(&batch);
            }
            WalRecord::Publish { version, .. } => {
                inner.publish();
                recovered_version = *version;
            }
        }
    }
    // re-anchor: the inner engine restarted its publish counter from
    // zero; external versions continue where the log left off
    let inner_version = inner.snapshot().version();
    let version_base = recovered_version.saturating_sub(inner_version);
    Ok(Recovered { next_seq, version_base, replayed })
}

/// Durability decorator: WAL + periodic checkpoint around any backend.
/// Constructed by `EngineBuilder::persist(dir)`; see the [module
/// docs](self) for the contract.
pub struct DurableEngine {
    inner: Box<dyn ClusterEngine>,
    wal: WalWriter,
    dir: PathBuf,
    /// next WAL sequence number (strictly increasing across restarts)
    next_seq: u64,
    /// recovered-version offset: external version = base + inner version
    version_base: u64,
    publishes_since_ckpt: u64,
    checkpoint_every: u64,
    /// spill deltas chained to the last full checkpoint (vs full-only)
    incremental: bool,
    /// coordinate-store write generation covered by the last full spill
    /// of this process (0 = none yet → next spill is full)
    full_gen: u64,
    /// snapshot version of that full spill (the delta chain's base)
    full_version: u64,
    /// WAL sequence floor of that full spill — the checkpoint side of
    /// the segment retention floor (deltas do *not* advance it)
    full_seq: u64,
    deltas_since_full: u64,
    /// replica log shipper; `None` when no followers are attached
    shipper: Option<LogShipper>,
    /// the backend's metrics registry (None when the backend exposes none)
    obs: Option<Arc<Metrics>>,
}

impl DurableEngine {
    /// Open (or create) the persist directory and recover `inner` — a
    /// **fresh, empty** engine — to the durable state recorded there.
    pub fn open(
        dir: &Path,
        mut inner: Box<dyn ClusterEngine>,
        checkpoint_every: u64,
    ) -> io::Result<DurableEngine> {
        let obs = inner.obs_registry();
        let sw = Stopwatch::start();
        let recovered = recover_into(dir, &mut inner)?;
        if let Some(m) = &obs {
            m.record_recovery(sw.elapsed_ns(), recovered.replayed);
        }
        // recovery is done: from here on the sharded backend may heal a
        // dead shard warm, straight from this directory's checkpoint +
        // WAL tail (no-op hook on other backends)
        inner.install_wal_heal(dir);
        let wal = WalWriter::open(dir)?;
        Ok(DurableEngine {
            inner,
            wal,
            dir: dir.to_path_buf(),
            next_seq: recovered.next_seq,
            version_base: recovered.version_base,
            publishes_since_ckpt: 0,
            checkpoint_every: checkpoint_every.max(1),
            incremental: true,
            full_gen: 0,
            full_version: 0,
            full_seq: 0,
            deltas_since_full: 0,
            shipper: None,
            obs,
        })
    }

    /// The persist directory this engine recovers from and spills into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Spill full checkpoints only (disable the `DDCKPT03` delta chain).
    /// Wired to `EngineBuilder::incremental_checkpoints(false)`.
    pub fn set_incremental(&mut self, on: bool) {
        self.incremental = on;
    }

    /// Attach the replica log shipper. From now on every durable publish
    /// ships the fsynced WAL tail to its subscribers, and sealed WAL
    /// segments are retained until the slowest subscriber has them.
    pub fn set_shipper(&mut self, shipper: LogShipper) {
        self.shipper = Some(shipper);
    }

    /// Last WAL sequence number assigned so far.
    pub fn last_seq(&self) -> u64 {
        self.next_seq - 1
    }

    fn note_append(&self, bytes: usize) {
        if let Some(m) = &self.obs {
            m.record_wal_append(bytes as u64);
            m.set_gauge(Gauge::WalLag, self.wal.pending());
        }
    }

    fn next_seq(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    /// The segment-retention floor: sealed WAL segments at or below it
    /// are dead weight. Recovery needs everything past the last *full*
    /// spill; each shipping subscriber needs everything past its floor.
    fn retention_floor(&self) -> u64 {
        let ship = self.shipper.as_ref().map(|s| s.min_floor()).unwrap_or(u64::MAX);
        self.full_seq.min(ship)
    }

    /// Serialize `view` into the checkpoint chain and (only once the
    /// atomic rename has landed) roll the WAL and drop sealed segments
    /// below the retention floor. A failed spill keeps the WAL intact —
    /// recovery still works, the log is just longer; the spill is
    /// retried a cadence later.
    fn spill_checkpoint(&mut self, view: &SnapshotView, wal_seq: u64) {
        // a delta only makes sense against a full spill taken by *this*
        // process (generations restart on reopen), with a short chain,
        // and when clean chunks still carry most of the payload
        let dirty = if self.full_gen > 0 {
            view.coords_chunks_dirty_since(self.full_gen)
        } else {
            Vec::new()
        };
        let go_delta = self.incremental
            && self.full_gen > 0
            && self.deltas_since_full < DELTA_CHAIN_MAX
            && dirty.len() * 2 <= view.coords_num_chunks();
        let wrote = if go_delta {
            self.spill_delta(view, wal_seq, dirty)
        } else {
            self.spill_full(view, wal_seq)
        };
        if wrote {
            let _ = self.wal.roll();
            let _ = self.wal.retain(self.retention_floor());
        }
        self.publishes_since_ckpt = 0;
    }

    fn spill_full(&mut self, view: &SnapshotView, wal_seq: u64) -> bool {
        let mut points = Vec::with_capacity(view.live_points());
        let mut labels = Vec::with_capacity(view.live_points());
        let mut cores = Vec::with_capacity(view.live_points());
        view.for_each_point(&mut |ext, coords, label, core| {
            points.push((ext, coords.to_vec()));
            labels.push(label);
            cores.push(core);
        });
        let ckpt = Checkpoint {
            version: view.version(),
            wal_seq,
            eps: view.eps(),
            dim: view.dim() as u32,
            points,
            labels,
            cores,
            placement: self.inner.placement_blob(),
        };
        if write_checkpoint(&self.dir, &ckpt).is_err() {
            return false;
        }
        // the full spill resets the delta chain and advances the
        // checkpoint side of the retention floor
        clear_delta(&self.dir);
        self.full_gen = view.coords_generation();
        self.full_version = view.version();
        self.full_seq = wal_seq;
        self.deltas_since_full = 0;
        true
    }

    fn spill_delta(
        &mut self,
        view: &SnapshotView,
        wal_seq: u64,
        dirty: Vec<usize>,
    ) -> bool {
        let mut chunks = Vec::with_capacity(dirty.len());
        for ix in dirty {
            let mut rows = Vec::new();
            view.for_each_point_in_chunk(ix, &mut |ext, coords| {
                rows.push((ext, coords.to_vec()));
            });
            chunks.push((ix as u32, rows));
        }
        let mut overlay = Vec::with_capacity(view.live_points());
        view.for_each_label(&mut |ext, label, core| {
            overlay.push((ext, label, core));
        });
        let delta = CheckpointDelta {
            base_version: self.full_version,
            version: view.version(),
            wal_seq,
            eps: view.eps(),
            dim: view.dim() as u32,
            chunk_count: view.coords_num_chunks() as u32,
            chunks,
            overlay,
            placement: self.inner.placement_blob(),
        };
        if write_delta(&self.dir, &delta).is_err() {
            return false;
        }
        self.deltas_since_full += 1;
        true
    }

    /// The WAL-framed publish: flush the op tail so on-disk frames are
    /// whole (the warm-heal reader may run inside the publish), publish,
    /// append the commit marker with the minted version, group-fsync,
    /// ship the durable tail to any attached followers, then maybe spill
    /// a checkpoint.
    fn publish_durable(&mut self) -> SnapshotView {
        // complete every buffered frame on disk before the inner publish:
        // a degraded sharded backend heals inside publish by replaying
        // this very log, and must see whole frames up to the last append
        // (flush only — the durability fsync comes after the marker)
        self.wal.flush().expect("WAL flush failed");
        let mut view = self.inner.publish();
        view.rebase_version(self.version_base);
        let seq = self.next_seq();
        let marker = WalRecord::Publish { seq, version: view.version() };
        let bytes = self.wal.append(&marker).expect("WAL append failed");
        self.note_append(bytes);
        let sw = Stopwatch::start();
        self.wal.sync().expect("WAL fsync failed");
        if let Some(m) = &self.obs {
            m.record_wal_fsync(sw.elapsed_ns());
            m.set_gauge(Gauge::WalLag, 0);
        }
        if let Some(shipper) = &mut self.shipper {
            let sw = Stopwatch::start();
            shipper.note_publish();
            let shipped = shipper.ship(&self.dir).unwrap_or(0);
            if let Some(m) = &self.obs {
                m.record_ship(sw.elapsed_ns(), shipped);
                let floor = shipper.min_floor();
                let floor = if floor == u64::MAX { 0 } else { floor };
                m.set_gauge(Gauge::ShipFloor, floor);
            }
        }
        self.publishes_since_ckpt += 1;
        if self.publishes_since_ckpt >= self.checkpoint_every {
            self.spill_checkpoint(&view, seq);
        }
        view
    }
}

impl ClusterEngine for DurableEngine {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn upsert(&mut self, ext: u64, coords: &[f32]) {
        let seq = self.next_seq();
        let rec = WalRecord::Upsert { seq, ext, coords: coords.to_vec() };
        let bytes = self.wal.append(&rec).expect("WAL append failed");
        self.note_append(bytes);
        self.inner.upsert(ext, coords);
    }

    fn remove(&mut self, ext: u64) {
        let seq = self.next_seq();
        let bytes = self
            .wal
            .append(&WalRecord::Remove { seq, ext })
            .expect("WAL append failed");
        self.note_append(bytes);
        self.inner.remove(ext);
    }

    fn apply(&mut self, batch: &[Update<'_>]) {
        let seq = self.next_seq();
        let ops: Vec<WalOp> = batch
            .iter()
            .map(|u| match *u {
                Update::Upsert { ext, coords } => {
                    WalOp::Upsert { ext, coords: coords.to_vec() }
                }
                Update::Remove { ext } => WalOp::Remove { ext },
            })
            .collect();
        let bytes = self
            .wal
            .append(&WalRecord::Apply { seq, ops })
            .expect("WAL append failed");
        self.note_append(bytes);
        self.inner.apply(batch);
    }

    fn contains(&self, ext: u64) -> bool {
        self.inner.contains(ext)
    }

    fn publish(&mut self) -> SnapshotView {
        self.publish_durable()
    }

    fn snapshot(&self) -> SnapshotView {
        let mut view = self.inner.snapshot();
        view.rebase_version(self.version_base);
        view
    }

    fn watch(&mut self) -> ClusterEvents {
        self.inner.watch()
    }

    fn pending_writes(&self) -> u64 {
        self.inner.pending_writes()
    }

    fn stats(&self) -> Stats {
        self.inner.stats()
    }

    fn metrics(&self) -> MetricsSnapshot {
        let mut m = self.inner.metrics();
        if let Some(reg) = &self.obs {
            let (records, bytes, fsyncs) = reg.wal_counters();
            let (replay_ns, replay_records) = reg.recovery_stats();
            m.wal = WalStats {
                records,
                bytes,
                fsyncs,
                fsync_latency: reg.fsync_histo(),
                replay_ns,
                replay_records,
            };
        }
        m
    }

    fn verify(&self) -> Result<(), String> {
        self.inner.verify()
    }

    fn obs_registry(&self) -> Option<Arc<Metrics>> {
        self.obs.clone()
    }

    fn placement_blob(&self) -> Option<Vec<u8>> {
        self.inner.placement_blob()
    }

    fn placement_restore(&mut self, blob: &[u8]) {
        self.inner.placement_restore(blob);
    }

    fn finish(mut self: Box<Self>) -> ServeOutcome {
        // route the final implicit publish through the WAL path so the
        // commit marker (and version continuity) reaches the log
        if self.inner.pending_writes() > 0 || self.inner.stats().publishes == 0 {
            self.publish_durable();
        } else {
            let _ = self.wal.sync();
        }
        // a shutdown checkpoint makes the next open replay-free; always
        // full — a clean shutdown is the natural chain reset
        let view = self.snapshot();
        let last_seq = self.next_seq - 1;
        if self.spill_full(&view, last_seq) {
            let _ = self.wal.roll();
            let _ = self.wal.retain(self.retention_floor());
        }
        let mut out = self.inner.finish();
        out.snapshot.rebase_version(self.version_base);
        out
    }
}
