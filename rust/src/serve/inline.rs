//! [`InlineEngine`] — the single-instance backend of the serve façade:
//! one `DynamicDbscan` (any connectivity mode) plus the ext ↔ `PointId`
//! bookkeeping, incremental label maintenance and CoW snapshot state that
//! every consumer used to hand-roll.
//!
//! Publishing is incremental by default: the structure's stitch-change
//! tracking (stable component ids, dirty-point recording) yields the set
//! of points whose label may have changed, and only those are relabeled —
//! `O(Δ·log n)` per publish, the single-instance analogue of the sharded
//! delta stitch. The flat connectivity ablations lack stable component
//! ids, so they publish by full relabel (`StitchMode::FullRebuild`),
//! mirroring the sharded fallback.

use std::sync::Arc;

use rustc_hash::{FxHashMap, FxHashSet};

use crate::dbscan::{AnyDbscan, ConnKind, DbscanConfig};
use crate::lsh::table::PointId;
use crate::lsh::BucketKey;
use crate::obs::{
    Gauge, Metrics, PhaseClock, PublishStage, PublishTrace, Stopwatch, UpdateStage,
};
use crate::runtime::engines::HashingEngine;
use crate::shard::{LabelChange, LabelMap, StitchMode};
use crate::util::stats::LatencyHisto;

use super::events::{derive_events, ClusterEvents, EventHub};
use super::index::{IndexPolicy, SpatialIndex};
use super::snapshot::{CoordMap, SnapshotView};
use super::{
    ClusterEngine, Health, MetricsSnapshot, ServeOutcome, Stats, Update, WalStats,
};

pub(crate) struct InlineEngine {
    db: AnyDbscan,
    hashing: Box<dyn HashingEngine>,
    stitch: StitchMode,
    dim: usize,
    eps: f32,
    ext_pid: FxHashMap<u64, PointId>,
    pid_ext: FxHashMap<PointId, u64>,
    /// label state as of the last publish
    labels: LabelMap,
    /// core-primary set as of the last publish (LabelMap used as a set)
    cores: LabelMap,
    /// label → clustered-ext count (noise excluded)
    sizes: FxHashMap<i64, usize>,
    /// stable component id → minted label (delta publishing)
    comp_label: FxHashMap<u64, i64>,
    next_label: i64,
    /// exts touched since the last publish
    dirty: FxHashSet<u64>,
    /// reused per-op key row (the single-op upsert path allocates
    /// nothing for hashing, matching the direct engine)
    key_row: Vec<BucketKey>,
    /// live coordinates (CoW-shared with published views)
    coords: CoordMap,
    /// ε-cell spatial index (CoW-shared with published views); `None`
    /// when the policy disables it or `dim` exceeds its threshold
    index: Option<SpatialIndex>,
    /// the policy that built `index` (carries the rebuild-fallback flag)
    index_policy: IndexPolicy,
    /// the latest published view
    view: SnapshotView,
    version: u64,
    pending: u64,
    hub: EventHub,
    inserts: u64,
    deletes: u64,
    publishes: u64,
    add_latency: LatencyHisto,
    delete_latency: LatencyHisto,
    publish_latency: LatencyHisto,
    /// shared lock-free metrics registry (also attached to `db` for the
    /// update-stage spans)
    obs: Arc<Metrics>,
    /// per-stage breakdown of the most recent publish
    last_trace: PublishTrace,
}

impl InlineEngine {
    pub fn new(
        cfg: DbscanConfig,
        conn: ConnKind,
        stitch: StitchMode,
        seed: u64,
        hashing: Box<dyn HashingEngine>,
        metrics: bool,
        index_policy: IndexPolicy,
    ) -> Self {
        let (dim, eps) = (cfg.dim, cfg.eps);
        let mut db = AnyDbscan::new(conn, cfg, seed);
        if stitch == StitchMode::Delta {
            db.enable_stitch_tracking();
        }
        let obs = Arc::new(Metrics::new(metrics));
        db.set_metrics(Arc::clone(&obs));
        InlineEngine {
            db,
            hashing,
            stitch,
            dim,
            eps,
            ext_pid: FxHashMap::default(),
            pid_ext: FxHashMap::default(),
            labels: LabelMap::new(),
            cores: LabelMap::new(),
            sizes: FxHashMap::default(),
            comp_label: FxHashMap::default(),
            next_label: 0,
            dirty: FxHashSet::default(),
            key_row: Vec::new(),
            coords: CoordMap::new(),
            index: index_policy.build_for(eps, dim),
            index_policy,
            view: SnapshotView::empty(eps, dim),
            version: 0,
            pending: 0,
            hub: EventHub::default(),
            inserts: 0,
            deletes: 0,
            publishes: 0,
            add_latency: LatencyHisto::new(),
            delete_latency: LatencyHisto::new(),
            publish_latency: LatencyHisto::new(),
            obs,
            last_trace: PublishTrace::default(),
        }
    }

    /// Insert with precomputed keys (shared by `upsert` and `apply`).
    /// `hash_ns` is the hashing cost attributed to this op so the
    /// recorded add latency stays comparable with backends that hash
    /// inside the timed region. A replace (live `ext`) counts as **one**
    /// accepted write.
    fn insert_inner(&mut self, ext: u64, coords: &[f32], keys: &[u128], hash_ns: u64) {
        if let Some(pid) = self.ext_pid.get(&ext).copied() {
            self.drop_point(ext, pid);
        }
        let o0 = Stopwatch::start();
        let pid = self.db.add_point_with_keys(coords, keys);
        let op_ns = o0.elapsed_ns() + hash_ns;
        self.add_latency.record(op_ns);
        self.obs.record_add(op_ns);
        self.ext_pid.insert(ext, pid);
        self.pid_ext.insert(pid, ext);
        self.coords.set(ext, coords);
        self.index_upsert(ext, coords);
        self.dirty.insert(ext);
        self.inserts += 1;
        self.pending += 1;
    }

    /// Fold one index insertion into the update path under the
    /// `index_probe` span — `O(1)` amortized. Skipped entirely in
    /// rebuild-at-publish mode (the publish barrier rebuilds instead).
    fn index_upsert(&mut self, ext: u64, coords: &[f32]) {
        if self.index_policy.rebuild_at_publish {
            return;
        }
        if let Some(ix) = self.index.as_mut() {
            let sw = self.obs.enabled().then(Stopwatch::start);
            ix.upsert(ext, coords);
            if let Some(sw) = sw {
                self.obs.record_update_stage(UpdateStage::IndexProbe, sw.elapsed_ns());
            }
        }
    }

    /// Index twin of a structure-level delete (see [`Self::index_upsert`]).
    fn index_remove(&mut self, ext: u64) {
        if self.index_policy.rebuild_at_publish {
            return;
        }
        if let Some(ix) = self.index.as_mut() {
            let sw = self.obs.enabled().then(Stopwatch::start);
            ix.remove(ext);
            if let Some(sw) = sw {
                self.obs.record_update_stage(UpdateStage::IndexProbe, sw.elapsed_ns());
            }
        }
    }

    /// Structure-level deletion behind a remove or an upsert-replace —
    /// bookkeeping only; the callers account the accepted write.
    fn drop_point(&mut self, ext: u64, pid: PointId) {
        self.ext_pid.remove(&ext);
        self.pid_ext.remove(&pid);
        let o0 = Stopwatch::start();
        self.db.delete_point(pid);
        let op_ns = o0.elapsed_ns();
        self.delete_latency.record(op_ns);
        self.obs.record_delete(op_ns);
        self.coords.remove(ext);
        self.index_remove(ext);
        self.dirty.insert(ext);
    }

    /// Delta publish: relabel only the exts whose stitch-visible state
    /// changed — `O(Δ·log n)`.
    fn publish_delta(&mut self) -> Vec<LabelChange> {
        // membership changes surfaced by the structure's change tracking
        let pid_ext = &self.pid_ext;
        let dirty = &mut self.dirty;
        self.db.drain_stitch_changes(&mut |pid| {
            if let Some(&e) = pid_ext.get(&pid) {
                dirty.insert(e);
            }
        });
        let mut changes = Vec::new();
        let touched: Vec<u64> = self.dirty.drain().collect();
        for ext in touched {
            // core set maintenance — flips happen with or without a
            // label change, so this runs before the label short-circuit
            match self.ext_pid.get(&ext) {
                Some(&pid) if self.db.is_core(pid) => {
                    self.cores.set(ext, 1);
                }
                _ => {
                    self.cores.remove(ext);
                }
            }
            let new_label: Option<i64> = match self.ext_pid.get(&ext) {
                None => None, // deleted
                Some(&pid) => {
                    if self.db.is_noise(pid) {
                        Some(-1)
                    } else {
                        let comp = self.db.stable_cluster(pid);
                        let next = &mut self.next_label;
                        let l = *self.comp_label.entry(comp).or_insert_with(|| {
                            let l = *next;
                            *next += 1;
                            l
                        });
                        Some(l)
                    }
                }
            };
            let old = self.labels.get(ext);
            if old == new_label {
                continue;
            }
            if let Some(o) = old {
                if o >= 0 {
                    let c = self.sizes.get_mut(&o).expect("size of live label");
                    *c -= 1;
                    if *c == 0 {
                        self.sizes.remove(&o);
                    }
                }
            }
            match new_label {
                Some(l) => {
                    self.labels.set(ext, l);
                    if l >= 0 {
                        *self.sizes.entry(l).or_insert(0) += 1;
                    }
                }
                None => {
                    self.labels.remove(ext);
                }
            }
            changes.push(LabelChange { ext, from: old, to: new_label });
        }
        debug_assert_eq!(
            self.cores.len(),
            self.db.num_core_points(),
            "core set out of sync with the structure"
        );
        // occasional comp→label pruning (stale merged-away comps), off
        // the per-publish Δ path
        if self.publishes % 64 == 63 {
            let db = &self.db;
            let live: FxHashSet<u64> = self
                .ext_pid
                .values()
                .map(|&pid| db.stable_cluster(pid))
                .collect();
            self.comp_label.retain(|c, _| live.contains(c));
        }
        changes
    }

    /// Full relabel — the fallback for connectivity modes without stable
    /// component ids. Labels renumber densely every publish (mirroring
    /// the sharded `FullRebuild` stitch); `O(n log n)`.
    fn publish_rebuild(&mut self) -> Vec<LabelChange> {
        self.dirty.clear();
        let mut root_label: FxHashMap<u64, i64> = FxHashMap::default();
        let mut fresh = LabelMap::new();
        let mut fresh_cores = LabelMap::new();
        let mut sizes: FxHashMap<i64, usize> = FxHashMap::default();
        let mut exts: Vec<(u64, PointId)> =
            self.ext_pid.iter().map(|(&e, &p)| (e, p)).collect();
        exts.sort_unstable(); // deterministic label numbering
        let db = &self.db;
        for (ext, pid) in exts {
            let l = if db.is_noise(pid) {
                -1
            } else {
                let root = db.stable_cluster(pid);
                let next = root_label.len() as i64;
                *root_label.entry(root).or_insert(next)
            };
            fresh.set(ext, l);
            if l >= 0 {
                *sizes.entry(l).or_insert(0) += 1;
            }
            if db.is_core(pid) {
                fresh_cores.set(ext, 1);
            }
        }
        let changes = fresh.diff_from(&self.labels);
        self.labels = fresh;
        self.cores = fresh_cores;
        self.sizes = sizes;
        changes
    }

    /// Sample the structural gauges from the live structure at publish —
    /// the inline counterpart of the shard workers' barrier-marker
    /// sampling (here nothing races, so zero-then-add is trivially
    /// consistent).
    fn sample_structural(&self) {
        self.obs.zero_structural();
        self.obs.set_gauge(Gauge::LivePoints, self.db.num_points() as u64);
        let per_level = self.db.conn_level_live();
        self.obs
            .add_gauge(Gauge::EttVertices, per_level.iter().sum::<usize>() as u64);
        for (l, &n) in per_level.iter().enumerate() {
            self.obs.add_level_verts(l, n as u64);
        }
        self.obs.add_gauge(Gauge::EttEdges, self.db.conn_edge_count() as u64);
        let rs = self.db.repair_stats();
        self.obs.max_gauge(Gauge::HdtLevels, rs.levels as u64);
        self.obs.add_gauge(Gauge::EdgePromotions, rs.pushes);
    }
}

impl ClusterEngine for InlineEngine {
    fn dim(&self) -> usize {
        self.dim
    }

    fn upsert(&mut self, ext: u64, coords: &[f32]) {
        assert_eq!(coords.len(), self.dim, "bad dim in upsert");
        let mut row = std::mem::take(&mut self.key_row);
        let hash_ns = {
            let h0 = Stopwatch::start();
            self.hashing.key_row_into(coords, &mut row).expect("hash stage failed");
            h0.elapsed_ns()
        };
        self.obs.record_update_stage(UpdateStage::Hash, hash_ns);
        self.insert_inner(ext, coords, &row, hash_ns);
        self.key_row = row;
    }

    fn remove(&mut self, ext: u64) {
        let pid = self
            .ext_pid
            .get(&ext)
            .copied()
            .unwrap_or_else(|| panic!("serve: remove of unknown ext {ext}"));
        self.drop_point(ext, pid);
        self.deletes += 1;
        self.pending += 1;
    }

    fn apply(&mut self, batch: &[Update<'_>]) {
        // hash every upsert in one pass (hashing is pure in the
        // coordinates, so interleaved removes cannot change keys), then
        // apply in order — semantically identical to the per-op calls
        let mut flat: Vec<f32> = Vec::new();
        let mut n = 0usize;
        for u in batch {
            if let Update::Upsert { coords, .. } = *u {
                assert_eq!(coords.len(), self.dim, "bad dim in batch upsert");
                flat.extend_from_slice(coords);
                n += 1;
            }
        }
        let (keys, hash_ns_per_insert) = if n > 0 {
            let h0 = Stopwatch::start();
            let keys = self.hashing.keys_batch(&flat, n).expect("hash stage failed");
            let hash_ns = h0.elapsed_ns();
            self.obs.record_update_stage(UpdateStage::Hash, hash_ns);
            // amortize the batch hash over its inserts (same accounting
            // as the shard workers' batch path)
            (keys, hash_ns / n as u64)
        } else {
            (Vec::new(), 0)
        };
        let mut j = 0usize;
        for u in batch {
            match *u {
                Update::Upsert { ext, coords } => {
                    self.insert_inner(ext, coords, &keys[j], hash_ns_per_insert);
                    j += 1;
                }
                Update::Remove { ext } => self.remove(ext),
            }
        }
    }

    fn contains(&self, ext: u64) -> bool {
        self.ext_pid.contains_key(&ext)
    }

    fn publish(&mut self) -> SnapshotView {
        let t0 = Stopwatch::start();
        let mut clk = PhaseClock::maybe(self.obs.enabled());
        let mut trace = PublishTrace::default();
        let changes = match self.stitch {
            StitchMode::Delta => self.publish_delta(),
            StitchMode::FullRebuild => self.publish_rebuild(),
        };
        if let Some(c) = clk.as_mut() {
            // the single-instance analogue of the sharded delta fold
            trace.record(PublishStage::DeltaFold, c.lap());
        }
        self.version += 1;
        self.publishes += 1;
        self.pending = 0;
        if self.index_policy.rebuild_at_publish {
            // the StitchMode::FullRebuild analogue: no per-op
            // maintenance, the barrier rebuilds the index from scratch
            if let Some(ix) = self.index.as_mut() {
                ix.rebuild(self.coords.iter());
            }
        }
        if self.obs.enabled() {
            // chunk sharing is measured before the clones below re-share
            // everything: unshared chunks are the ones rewritten since
            // the previous publish
            self.obs.set_ratio(Gauge::CowLabelSharing, self.labels.sharing_ratio());
            self.obs.set_ratio(Gauge::CowCoordSharing, self.coords.sharing_ratio());
            if let Some(ix) = &self.index {
                self.obs.set_gauge(Gauge::IndexCells, ix.num_cells() as u64);
                self.obs.set_ratio(Gauge::CowIndexSharing, ix.sharing_ratio());
            }
        }
        self.labels.maybe_grow();
        self.cores.maybe_grow();
        self.coords.maybe_grow();
        if let Some(ix) = self.index.as_mut() {
            ix.maybe_grow();
        }
        debug_assert_eq!(
            self.coords.len(),
            self.db.num_points(),
            "coordinate store out of sync with the structure"
        );
        debug_assert!(
            self.index.as_ref().map(|ix| ix.len() == self.coords.len()).unwrap_or(true),
            "spatial index out of sync with the coordinate store"
        );
        let mut cs: Vec<(i64, usize)> =
            self.sizes.iter().map(|(&l, &s)| (l, s)).collect();
        cs.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let view = SnapshotView::new(
            self.version,
            0,
            self.db.num_points(),
            self.db.num_core_points(),
            Arc::new(cs),
            self.labels.clone(),
            self.cores.clone(),
            self.coords.clone(),
            self.index.as_ref().map(|ix| Arc::new(ix.clone())),
            self.eps,
            self.dim,
        );
        // the clone above froze this publish's writes into the view;
        // stamp later writes with a fresh generation so incremental
        // checkpoint spills can diff chunks against this publish
        self.coords.advance_gen();
        if let Some(c) = clk.as_mut() {
            trace.record(PublishStage::SnapshotCow, c.lap());
        }
        if self.hub.has_watchers() {
            let prev: FxHashSet<i64> =
                self.view.cluster_sizes().iter().map(|&(l, _)| l).collect();
            let now: FxHashSet<i64> =
                view.cluster_sizes().iter().map(|&(l, _)| l).collect();
            let events = derive_events(self.version, &changes, &prev, &now);
            self.hub.emit(events);
        }
        if let Some(c) = clk.as_mut() {
            trace.record(PublishStage::Events, c.lap());
        }
        let total_ns = t0.elapsed_ns();
        self.publish_latency.record(total_ns);
        if self.obs.enabled() {
            trace.set_total(total_ns);
            self.obs.record_publish(total_ns);
            for stage in [
                PublishStage::DeltaFold,
                PublishStage::SnapshotCow,
                PublishStage::Events,
            ] {
                self.obs.record_publish_stage(stage, trace.get(stage));
            }
            self.sample_structural();
            self.last_trace = trace;
        }
        self.view = view.clone();
        view
    }

    fn snapshot(&self) -> SnapshotView {
        let mut view = self.view.clone();
        view.set_pending(self.pending);
        view
    }

    fn watch(&mut self) -> ClusterEvents {
        self.hub.subscribe()
    }

    fn pending_writes(&self) -> u64 {
        self.pending
    }

    fn stats(&self) -> Stats {
        Stats {
            shards: 1,
            inserts: self.inserts,
            deletes: self.deletes,
            ghost_inserts: 0,
            publishes: self.publishes,
            pending_writes: self.pending,
            add_latency: self.add_latency.clone(),
            delete_latency: self.delete_latency.clone(),
            publish_latency: self.publish_latency.clone(),
            conn: self.db.repair_stats(),
            // no worker threads to lose: the inline backend is healthy
            // for as long as it exists
            health: Health::Ok,
        }
    }

    fn metrics(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            stats: self.stats(),
            last_publish: self.last_trace.clone(),
            publish_stages: self.obs.publish_stage_histos(),
            update_stages: self.obs.update_stage_histos(),
            gauges: self.obs.gauge_values(),
            hdt_level_verts: self.obs.level_verts().to_vec(),
            shard_loads: Vec::new(),
            wal: WalStats::default(),
        }
    }

    fn verify(&self) -> Result<(), String> {
        self.db.verify().map_err(|e| e.to_string())
    }

    fn obs_registry(&self) -> Option<Arc<Metrics>> {
        Some(Arc::clone(&self.obs))
    }

    fn finish(mut self: Box<Self>) -> ServeOutcome {
        if self.pending > 0 || self.publishes == 0 {
            self.publish();
        }
        let stats = self.stats();
        ServeOutcome { snapshot: self.view.clone(), stats }
    }
}
