//! Stream drivers over the serve façade — the one glue layer the CLI,
//! the examples and the coordinator's dataset helpers share, for every
//! backend.

use anyhow::Result;

use crate::coordinator::{StreamOp, TruthFn};
use crate::data::Dataset;
use crate::metrics::ari_nmi;
use crate::obs::Stopwatch;

use super::{ClusterEngine, ServeOutcome, Update};

/// Per-published-snapshot progress report.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// index of the last batch folded into this snapshot
    pub seq: usize,
    /// ops in that batch
    pub ops: usize,
    pub live_points: usize,
    pub core_points: usize,
    pub clusters: usize,
    /// snapshot version ([`super::SnapshotView::version`])
    pub version: u64,
    /// wall-clock seconds since stream start
    pub wall_s: f64,
    pub ari: Option<f64>,
    pub nmi: Option<f64>,
}

/// Outcome of a full stream run through any serve backend.
pub struct ServeRunOutcome {
    pub reports: Vec<ServeReport>,
    /// final labels per live ext id (sorted by ext)
    pub final_labels: Vec<(u64, i64)>,
    pub outcome: ServeOutcome,
    /// end-to-end wall time: first op applied → final publish
    pub total_wall_s: f64,
}

impl ServeRunOutcome {
    /// Primary updates applied per wall-clock second.
    pub fn updates_per_s(&self) -> f64 {
        let ops = self.outcome.stats.inserts + self.outcome.stats.deletes;
        if self.total_wall_s > 0.0 {
            ops as f64 / self.total_wall_s
        } else {
            0.0
        }
    }
}

/// Run batched stream ops through a serve engine, publishing a snapshot
/// (and a report) every `snapshot_every` batches plus once at the end.
/// `truth` adds ARI/NMI against ground-truth labels to each report.
pub fn run_stream(
    engine: Box<dyn ClusterEngine>,
    batches: Vec<Vec<StreamOp>>,
    snapshot_every: usize,
    truth: Option<&TruthFn>,
) -> Result<ServeRunOutcome> {
    run_stream_with(engine, batches, snapshot_every, truth, 0, &mut |_| {})
}

/// [`run_stream`] plus a live metrics feed: every `metrics_every`
/// batches (0 = never) the engine's [`ClusterEngine::metrics`] snapshot
/// is rendered as Prometheus text exposition and handed to `sink` — the
/// plumbing behind the CLI's `stream --metrics-every N` mode.
pub fn run_stream_with(
    mut engine: Box<dyn ClusterEngine>,
    batches: Vec<Vec<StreamOp>>,
    snapshot_every: usize,
    truth: Option<&TruthFn>,
    metrics_every: usize,
    sink: &mut dyn FnMut(&str),
) -> Result<ServeRunOutcome> {
    let mut reports = Vec::new();
    let t0 = Stopwatch::start();
    let last = batches.len().saturating_sub(1);
    for (seq, ops) in batches.iter().enumerate() {
        let updates: Vec<Update<'_>> = ops
            .iter()
            .map(|op| match op {
                StreamOp::Insert { ext, coords } => {
                    Update::Upsert { ext: *ext, coords }
                }
                StreamOp::Delete { ext } => Update::Remove { ext: *ext },
            })
            .collect();
        engine.apply(&updates);
        let snap_due =
            snapshot_every > 0 && (seq + 1) % snapshot_every == 0 && seq != last;
        if snap_due {
            let snap = engine.publish();
            let labels = snap.labels();
            let (ari, nmi) = quality_vs_truth(&labels, truth);
            reports.push(ServeReport {
                seq,
                ops: ops.len(),
                live_points: snap.live_points(),
                core_points: snap.core_points(),
                clusters: snap.clusters(),
                version: snap.version(),
                wall_s: t0.elapsed_s(),
                ari,
                nmi,
            });
        }
        if metrics_every > 0 && (seq + 1) % metrics_every == 0 {
            sink(&engine.metrics().render_prometheus());
        }
    }
    // final publish + teardown (finish publishes anything pending)
    if metrics_every > 0 {
        // one last pull with everything recorded, before the registry
        // goes away with the engine
        engine.publish();
        sink(&engine.metrics().render_prometheus());
    }
    let outcome = engine.finish();
    let total_wall_s = t0.elapsed_s();
    let final_labels = outcome.snapshot.labels();
    let (ari, nmi) = quality_vs_truth(&final_labels, truth);
    reports.push(ServeReport {
        seq: last,
        ops: 0,
        live_points: outcome.snapshot.live_points(),
        core_points: outcome.snapshot.core_points(),
        clusters: outcome.snapshot.clusters(),
        version: outcome.snapshot.version(),
        wall_s: total_wall_s,
        ari,
        nmi,
    });
    Ok(ServeRunOutcome { reports, final_labels, outcome, total_wall_s })
}

fn quality_vs_truth(
    labels: &[(u64, i64)],
    truth: Option<&TruthFn>,
) -> (Option<f64>, Option<f64>) {
    match truth {
        None => (None, None),
        Some(t) => {
            if labels.is_empty() {
                return (None, None);
            }
            let want: Vec<i64> = labels.iter().map(|&(e, _)| t(e)).collect();
            let pred: Vec<i64> = labels.iter().map(|&(_, l)| l).collect();
            let (a, n) = ari_nmi(&want, &pred);
            (Some(a), Some(n))
        }
    }
}

/// Final-state quality of a run (ARI/NMI over the live points).
pub fn final_quality(ds: &Dataset, out: &ServeRunOutcome) -> (f64, f64) {
    let truth: Vec<i64> =
        out.final_labels.iter().map(|&(e, _)| ds.labels[e as usize]).collect();
    let pred: Vec<i64> = out.final_labels.iter().map(|&(_, l)| l).collect();
    ari_nmi(&truth, &pred)
}

/// One-line progress summary for CLI logs.
pub fn summarize(r: &ServeReport) -> String {
    format!(
        "snap v{:<4} @batch {:>4}: live={:<7} cores={:<7} clusters={:<5} \
         wall={:.2}s{}",
        r.version,
        r.seq,
        r.live_points,
        r.core_points,
        r.clusters,
        r.wall_s,
        match (r.ari, r.nmi) {
            (Some(a), Some(n)) => format!(" ARI={a:.3} NMI={n:.3}"),
            _ => String::new(),
        }
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{make_blobs, BlobsConfig};
    use crate::serve::{Backend, EngineBuilder};

    fn blob_batches(n: usize, seed: u64) -> (Dataset, Vec<Vec<StreamOp>>) {
        let ds = make_blobs(
            &BlobsConfig {
                n,
                dim: 4,
                clusters: 3,
                std: 0.3,
                center_box: 20.0,
                weights: vec![],
            },
            seed,
        );
        let ops: Vec<StreamOp> = (0..n)
            .map(|i| StreamOp::Insert { ext: i as u64, coords: ds.point(i).to_vec() })
            .collect();
        let batches = ops.chunks(200).map(|c| c.to_vec()).collect();
        (ds, batches)
    }

    #[test]
    fn run_stream_reports_and_quality_single_backend() {
        let (ds, batches) = blob_batches(800, 3);
        let engine = EngineBuilder::new(4).k(8).eps(0.75).seed(9).build().unwrap();
        let labels = ds.labels.clone();
        let truth = move |e: u64| labels[e as usize];
        let out = run_stream(engine, batches, 2, Some(&truth)).unwrap();
        // one mid-stream snapshot (seq 1; seq 3 is the last batch and
        // folds into the final publish) plus the final report
        assert_eq!(out.reports.len(), 2);
        assert_eq!(out.final_labels.len(), 800);
        let last = out.reports.last().unwrap();
        assert!(last.ari.unwrap() > 0.95, "ari={:?}", last.ari);
        let (ari, nmi) = final_quality(&ds, &out);
        assert!(ari > 0.95 && nmi > 0.9, "ari={ari} nmi={nmi}");
        assert!(out.updates_per_s() > 0.0);
        // versions increase monotonically across reports
        let versions: Vec<u64> = out.reports.iter().map(|r| r.version).collect();
        assert!(versions.windows(2).all(|w| w[0] < w[1]), "{versions:?}");
    }

    #[test]
    fn run_stream_with_metrics_sink_emits_exposition() {
        let (_ds, batches) = blob_batches(400, 7);
        let engine = EngineBuilder::new(4)
            .k(8)
            .eps(0.75)
            .backend(Backend::Sharded(2))
            .seed(3)
            .build()
            .unwrap();
        let mut dumps: Vec<String> = Vec::new();
        let out = run_stream_with(engine, batches, 0, None, 1, &mut |s| {
            dumps.push(s.to_string())
        })
        .unwrap();
        // one dump per batch plus the final pre-finish dump
        assert_eq!(dumps.len(), 3);
        let last = dumps.last().unwrap();
        assert!(last.contains("dyndbscan_inserts_total 400"));
        assert!(last.contains("dyndbscan_publish_stage_ns"));
        assert!(last.contains("stage=\"stitch\""));
        assert_eq!(out.final_labels.len(), 400);
    }

    #[test]
    fn run_stream_sharded_backend_handles_deletes() {
        let (ds, mut batches) = blob_batches(600, 5);
        let dels: Vec<StreamOp> =
            (0..200).map(|e| StreamOp::Delete { ext: e as u64 }).collect();
        batches.push(dels);
        let engine = EngineBuilder::new(4)
            .k(8)
            .eps(0.75)
            .backend(Backend::Sharded(3))
            .seed(9)
            .build()
            .unwrap();
        let labels = ds.labels.clone();
        let truth = move |e: u64| labels[e as usize];
        let out = run_stream(engine, batches, 0, Some(&truth)).unwrap();
        assert_eq!(out.reports.len(), 1);
        assert_eq!(out.final_labels.len(), 400);
        assert_eq!(out.outcome.stats.deletes, 200);
        assert_eq!(out.outcome.snapshot.live_points(), 400);
    }
}
