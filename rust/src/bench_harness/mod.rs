//! Benchmark harness (criterion is unavailable offline): timed runs with
//! warmup, mean ± stderr aggregation, aligned table / CSV-ish series
//! printing, and JSON export for EXPERIMENTS.md bookkeeping.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Welford;

/// One measured configuration.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub mean_s: f64,
    pub stderr_s: f64,
    pub runs: usize,
}

impl Measurement {
    pub fn fmt_seconds(&self) -> String {
        format!("{:.2}±{:.3}", self.mean_s, self.stderr_s)
    }
}

/// Time one invocation of `f` in seconds.
pub fn time_once<T>(mut f: impl FnMut() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let out = f();
    (t0.elapsed().as_secs_f64(), out)
}

/// Run `f` `runs` times (after `warmup` unmeasured runs); mean ± stderr.
pub fn bench(name: &str, warmup: usize, runs: usize, mut f: impl FnMut()) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut w = Welford::new();
    for _ in 0..runs.max(1) {
        let (s, ()) = time_once(&mut f);
        w.push(s);
    }
    Measurement {
        name: name.to_string(),
        mean_s: w.mean(),
        stderr_s: w.stderr(),
        runs: runs.max(1),
    }
}

/// Aligned console table (the Table-2-style report).
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n== {} ==\n", self.title);
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::new();
            for i in 0..ncol {
                s.push_str(&format!("{:<width$}  ", cells[i], width = w[i]));
            }
            s.trim_end().to_string() + "\n"
        };
        out.push_str(&line(&self.headers, &widths));
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            (
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::str(h.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::Arr(r.iter().map(|c| Json::str(c.clone())).collect())
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// A named (x, series...) line chart printed as aligned columns — the
/// Figure-2-style report.
pub struct Series {
    pub title: String,
    pub x_name: String,
    pub names: Vec<String>,
    pub xs: Vec<f64>,
    pub ys: Vec<Vec<f64>>, // ys[series][point]
}

impl Series {
    pub fn new(title: &str, x_name: &str, names: &[&str]) -> Self {
        Series {
            title: title.to_string(),
            x_name: x_name.to_string(),
            names: names.iter().map(|s| s.to_string()).collect(),
            xs: Vec::new(),
            ys: vec![Vec::new(); names.len()],
        }
    }

    pub fn push(&mut self, x: f64, values: &[f64]) {
        assert_eq!(values.len(), self.names.len());
        self.xs.push(x);
        for (s, &v) in self.ys.iter_mut().zip(values) {
            s.push(v);
        }
    }

    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        let mut hdr = format!("{:>12}", self.x_name);
        for n in &self.names {
            hdr.push_str(&format!("  {n:>14}"));
        }
        println!("{hdr}");
        for (i, &x) in self.xs.iter().enumerate() {
            let mut row = format!("{x:>12.0}");
            for s in &self.ys {
                row.push_str(&format!("  {:>14.4}", s[i]));
            }
            println!("{row}");
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            ("x_name", Json::str(self.x_name.clone())),
            (
                "series",
                Json::Arr(
                    self.names
                        .iter()
                        .zip(&self.ys)
                        .map(|(n, ys)| {
                            Json::obj(vec![
                                ("name", Json::str(n.clone())),
                                ("y", Json::arr_f64(ys)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("x", Json::arr_f64(&self.xs)),
        ])
    }
}

/// Append a JSON record to `bench_results.jsonl` (best-effort).
pub fn export_json(record: &Json) {
    use std::io::Write;
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("bench_results.jsonl")
    {
        let _ = writeln!(f, "{record}");
    }
}

/// Write a JSON record to a named file, replacing any previous contents
/// (best-effort) — used for standalone machine-readable results like
/// `BENCH_shard.json` / `BENCH_updates.json`.
pub fn write_json<P: AsRef<std::path::Path>>(path: P, record: &Json) {
    use std::io::Write;
    if let Ok(mut f) = std::fs::File::create(path) {
        let _ = writeln!(f, "{record}");
    }
}

/// Path of a perf-trajectory artifact at the repository root, regardless of
/// the invocation cwd (`cargo bench` may run from the workspace root or the
/// package dir): resolved as the parent of the crate's manifest dir.
pub fn repo_root_file(name: &str) -> std::path::PathBuf {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().unwrap_or(manifest).join(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let m = bench("spin", 1, 3, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(m.mean_s > 0.0);
        assert_eq!(m.runs, 3);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["dataset", "time"]);
        t.row(vec!["blobs".into(), "84.39".into()]);
        t.row(vec!["covertype-long".into(), "874".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("covertype-long"));
        let lines: Vec<&str> = s.lines().filter(|l| l.contains("  ")).collect();
        assert!(lines.len() >= 3);
    }

    #[test]
    fn series_roundtrip_json() {
        let mut s = Series::new("fig", "n", &["a", "b"]);
        s.push(1000.0, &[0.5, 0.7]);
        s.push(2000.0, &[0.6, 0.8]);
        let j = s.to_json();
        assert_eq!(j.get("x").unwrap().as_arr().unwrap().len(), 2);
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed, j);
    }
}
