//! Synthetic stand-ins for the paper's OpenML datasets (Table 1).
//!
//! No network access is available, so each dataset is simulated with the
//! same `(n, d, #clusters)` and a separation/imbalance profile chosen to
//! land in the clustering-quality *regime* the paper reports (low ARI for
//! Letter/MNIST/Covertype, high ARI for Blobs/KDDCup). Runtime cost of
//! every algorithm depends only on `(n, d, bucket occupancy)`, which these
//! match; see DESIGN.md §Substitutions.
//!
//! Generation profiles:
//! * heavy overlap  → clusters barely separated (`sep` ≈ cluster std):
//!   Letter, MNIST-like, Fashion-MNIST-like, Covertype.
//! * dominant classes → a few clusters carry most of the mass (KDDCup99's
//!   smurf/neptune/normal traffic mix, Covertype's two big forest types).
//! * high-dim native + PCA → MNIST-like sets are generated at their native
//!   dimensionality then reduced to 20 with [`super::pca`], as in the paper.

use crate::util::rng::Rng;

use super::blobs::BlobsConfig;
use super::pca::Pca;
use super::scale::standardize;
use super::Dataset;

/// Low-rank latent Gaussian mixture: `x = B·(u_c + σ·g)` with a random
/// column-orthonormal `B ∈ R^{d×m}`. Real tabular/image data concentrates
/// near a low-dimensional manifold — that concentration is what lets grid
/// buckets fill in high ambient dimension, so the overlapping-dataset
/// stand-ins must share it (an isotropic d-dim mixture has essentially no
/// LSH collisions at d ≳ 20).
#[allow(clippy::too_many_arguments)]
fn make_lowrank_mixture(
    n: usize,
    d: usize,
    m: usize,
    clusters: usize,
    sep: f64,
    sigma: f64,
    spiky: bool,
    weights: &[f64],
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    // random orthonormal columns via Gram–Schmidt on gaussian matrix
    let mut b = vec![0.0f64; d * m]; // column-major d×m
    for v in b.iter_mut() {
        *v = rng.normal();
    }
    for c in 0..m {
        for p in 0..c {
            let mut dot = 0.0;
            for j in 0..d {
                dot += b[c * d + j] * b[p * d + j];
            }
            for j in 0..d {
                b[c * d + j] -= dot * b[p * d + j];
            }
        }
        let norm: f64 = b[c * d..(c + 1) * d].iter().map(|x| x * x).sum::<f64>().sqrt();
        for j in 0..d {
            b[c * d + j] /= norm.max(1e-12);
        }
    }
    let centers: Vec<Vec<f64>> = (0..clusters)
        .map(|_| (0..m).map(|_| sep * rng.normal()).collect())
        .collect();
    let w: Vec<f64> = if weights.is_empty() {
        vec![1.0; clusters]
    } else {
        weights.to_vec()
    };
    let total: f64 = w.iter().sum();
    let mut cum = Vec::with_capacity(clusters);
    let mut acc = 0.0;
    for x in &w {
        acc += x / total;
        cum.push(acc);
    }
    let mut xs = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    let mut z = vec![0.0f64; m];
    for _ in 0..n {
        let u = rng.next_f64();
        let c = cum.iter().position(|&x| u <= x).unwrap_or(clusters - 1);
        // `spiky` models real image/tabular data: most points sit in a
        // tight mode (near-duplicates), a minority spreads wide. Per-dim
        // variance stays ~sigma² but dense LSH buckets exist — matching
        // how DBSCAN finds cores on the real datasets.
        let scale = if spiky {
            if rng.coin(0.6) { 0.25 * sigma } else { 1.8 * sigma }
        } else {
            sigma
        };
        for (j, zj) in z.iter_mut().enumerate() {
            *zj = centers[c][j] + scale * rng.normal();
        }
        for j in 0..d {
            let mut s = 0.0;
            for l in 0..m {
                s += b[l * d + j] * z[l];
            }
            xs.push(s as f32);
        }
        labels.push(c as i64);
    }
    Dataset { name: String::new(), dim: d, xs, labels }
}

/// Table 1 rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PaperDataset {
    Letter,
    Mnist,
    FashionMnist,
    Blobs,
    KddCup99,
    Covertype,
}

impl PaperDataset {
    pub const ALL: [PaperDataset; 6] = [
        PaperDataset::Letter,
        PaperDataset::Mnist,
        PaperDataset::FashionMnist,
        PaperDataset::Blobs,
        PaperDataset::KddCup99,
        PaperDataset::Covertype,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            PaperDataset::Letter => "letter",
            PaperDataset::Mnist => "mnist",
            PaperDataset::FashionMnist => "fashion-mnist",
            PaperDataset::Blobs => "blobs",
            PaperDataset::KddCup99 => "kddcup99",
            PaperDataset::Covertype => "covertype",
        }
    }

    pub fn from_name(s: &str) -> Option<PaperDataset> {
        Self::ALL.iter().copied().find(|d| d.name() == s)
    }

    /// Paper's (n, post-preprocessing d, clusters).
    pub fn shape(&self) -> (usize, usize, usize) {
        match self {
            PaperDataset::Letter => (20_000, 16, 26),
            PaperDataset::Mnist => (70_000, 20, 10),
            PaperDataset::FashionMnist => (70_000, 20, 10),
            PaperDataset::Blobs => (200_000, 10, 10),
            PaperDataset::KddCup99 => (494_000, 20, 23),
            PaperDataset::Covertype => (581_012, 54, 7),
        }
    }
}

/// Generate a stand-in dataset, fully preprocessed (PCA where the paper
/// applies it, then standardized). `scale` ∈ (0,1] shrinks n for fast test
/// and bench runs while keeping d and cluster structure.
pub fn load(which: PaperDataset, scale: f64, seed: u64) -> Dataset {
    let (n_full, d, c) = which.shape();
    let n = ((n_full as f64 * scale).round() as usize).max(c * 20);
    let mut rng = Rng::new(seed ^ 0xDA7A_5E7);
    let mut ds = match which {
        PaperDataset::Blobs => {
            // the paper's own synthetic mixture: well separated — every
            // algorithm reaches ARI ≈ 1 on it (Table 2), so the stand-in
            // uses corner-placed centers that stay many bucket-widths
            // apart after standardization.
            super::blobs::make_separated_blobs(
                &BlobsConfig {
                    n,
                    dim: d,
                    clusters: c,
                    std: 1.0,
                    center_box: 20.0,
                    weights: vec![],
                },
                seed,
            )
        }
        PaperDataset::Letter => {
            // 26 heavily overlapping classes on a low-rank manifold →
            // near-zero ARI, modest NMI (paper: 0.02 / 0.27)
            make_lowrank_mixture(n, d, 6, c, 1.0, 0.45, false, &[], seed)
        }
        PaperDataset::Mnist | PaperDataset::FashionMnist => {
            // native 64-dim data on a rank-20 manifold, overlapping
            // classes; PCA to 20 recovers the manifold, as with the real
            // digits (paper: ARI 0.02-0.05, NMI 0.15-0.26)
            let native = 64;
            let (m, sep, sigma, dseed) = if which == PaperDataset::Mnist {
                (d, 0.7, 1.0, seed)
            } else {
                (16, 0.8, 0.9, seed ^ 0xFA51)
            };
            let raw =
                make_lowrank_mixture(n, native, m, c, sep, sigma, true, &[], dseed);
            let pca = Pca::fit(&raw, d, seed ^ 1);
            pca.transform(&raw)
        }
        PaperDataset::KddCup99 => {
            // 23 classes, mass concentrated in 3 (smurf/neptune/normal ≈
            // 57/22/20 % of traffic), well separated → high-ARI regime
            // (paper: 0.91 / 0.80). Native 41 features → PCA to 20.
            let mut w = vec![0.0017; c];
            w[0] = 0.57;
            w[1] = 0.21;
            w[2] = 0.19;
            let raw = make_lowrank_mixture(n, 41, 10, c, 4.0, 0.25, false, &w, seed);
            let pca = Pca::fit(&raw, d, seed ^ 1);
            pca.transform(&raw)
        }
        PaperDataset::Covertype => {
            // 7 cover types, two dominant (~85%), heavy overlap on a
            // low-rank manifold → low ARI, modest NMI (paper: 0.05 / 0.20)
            let w = vec![0.365, 0.488, 0.062, 0.012, 0.016, 0.030, 0.035];
            make_lowrank_mixture(n, d, 8, c, 1.0, 0.4, false, &w, seed)
        }
    };
    // small label-noise so stand-ins aren't perfectly separable even when
    // geometry is (mirrors real-data label impurity)
    if matches!(which, PaperDataset::Letter | PaperDataset::Covertype) {
        let c = ds.num_clusters() as u64;
        for l in ds.labels.iter_mut() {
            if rng.coin(0.05) {
                *l = rng.below(c) as i64;
            }
        }
    }
    ds.name = which.name().to_string();
    standardize(&mut ds);
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_table1() {
        for which in PaperDataset::ALL {
            let (n, d, c) = which.shape();
            let ds = load(which, 0.01, 7);
            assert_eq!(ds.dim, d, "{} dim", which.name());
            assert!(ds.n() >= c * 20);
            assert!(ds.n() <= n);
            assert_eq!(ds.num_clusters(), c, "{} clusters", which.name());
        }
    }

    #[test]
    fn standardized_output() {
        let ds = load(PaperDataset::Letter, 0.05, 3);
        let d = ds.dim;
        let n = ds.n();
        for j in [0, d - 1] {
            let mean: f64 =
                (0..n).map(|i| ds.xs[i * d + j] as f64).sum::<f64>() / n as f64;
            assert!(mean.abs() < 1e-3);
        }
    }

    #[test]
    fn kddcup_is_imbalanced() {
        let ds = load(PaperDataset::KddCup99, 0.02, 5);
        let mut counts = std::collections::HashMap::new();
        for &l in &ds.labels {
            *counts.entry(l).or_insert(0usize) += 1;
        }
        let max = *counts.values().max().unwrap();
        assert!(max as f64 / ds.n() as f64 > 0.4, "dominant class missing");
    }

    #[test]
    fn name_roundtrip() {
        for which in PaperDataset::ALL {
            assert_eq!(PaperDataset::from_name(which.name()), Some(which));
        }
        assert_eq!(PaperDataset::from_name("nope"), None);
    }
}
