//! Isotropic Gaussian mixture ("blobs") generator — the paper's synthetic
//! dataset (n = 200 000, d = 10, 10 clusters) and the workload of Figure 2.

use crate::util::rng::Rng;

use super::Dataset;

/// Configuration mirroring `sklearn.datasets.make_blobs`.
#[derive(Clone, Debug)]
pub struct BlobsConfig {
    pub n: usize,
    pub dim: usize,
    pub clusters: usize,
    /// per-cluster standard deviation
    pub std: f64,
    /// centers drawn uniformly from [-center_box, center_box]^d
    pub center_box: f64,
    /// relative cluster weights (uniform when empty)
    pub weights: Vec<f64>,
}

impl Default for BlobsConfig {
    fn default() -> Self {
        BlobsConfig {
            n: 200_000,
            dim: 10,
            clusters: 10,
            std: 1.0,
            center_box: 10.0,
            weights: Vec::new(),
        }
    }
}

pub fn make_blobs(cfg: &BlobsConfig, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f64>> = (0..cfg.clusters)
        .map(|_| {
            (0..cfg.dim)
                .map(|_| rng.uniform(-cfg.center_box, cfg.center_box))
                .collect()
        })
        .collect();
    make_blobs_with_centers(cfg, centers, rng)
}

/// Blobs with centers on random `±center_box` hypercube corners, chosen
/// with pairwise Hamming distance ≥ `dim/3` — guarantees clusters stay
/// separated by many grid-bucket widths even after standardization (the
/// regime of the paper's blobs evaluation, where every algorithm reaches
/// ARI ≈ 1).
pub fn make_separated_blobs(cfg: &BlobsConfig, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let min_hamming = (cfg.dim / 3).max(1);
    let mut centers: Vec<Vec<f64>> = Vec::new();
    while centers.len() < cfg.clusters {
        let cand: Vec<f64> = (0..cfg.dim)
            .map(|_| if rng.coin(0.5) { cfg.center_box } else { -cfg.center_box })
            .collect();
        let ok = centers.iter().all(|c| {
            c.iter().zip(&cand).filter(|(a, b)| a != b).count() >= min_hamming
        });
        if ok {
            centers.push(cand);
        }
    }
    make_blobs_with_centers(cfg, centers, rng)
}

fn make_blobs_with_centers(
    cfg: &BlobsConfig,
    centers: Vec<Vec<f64>>,
    mut rng: Rng,
) -> Dataset {
    // cumulative weights
    let w: Vec<f64> = if cfg.weights.is_empty() {
        vec![1.0; cfg.clusters]
    } else {
        assert_eq!(cfg.weights.len(), cfg.clusters);
        cfg.weights.clone()
    };
    let total: f64 = w.iter().sum();
    let mut cum = Vec::with_capacity(cfg.clusters);
    let mut acc = 0.0;
    for x in &w {
        acc += x / total;
        cum.push(acc);
    }
    let mut xs = Vec::with_capacity(cfg.n * cfg.dim);
    let mut labels = Vec::with_capacity(cfg.n);
    for _ in 0..cfg.n {
        let u = rng.next_f64();
        let c = cum.iter().position(|&x| u <= x).unwrap_or(cfg.clusters - 1);
        for j in 0..cfg.dim {
            xs.push((centers[c][j] + cfg.std * rng.normal()) as f32);
        }
        labels.push(c as i64);
    }
    Dataset { name: "blobs".into(), dim: cfg.dim, xs, labels }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let cfg = BlobsConfig { n: 500, dim: 4, clusters: 3, ..Default::default() };
        let d = make_blobs(&cfg, 7);
        assert_eq!(d.n(), 500);
        assert_eq!(d.xs.len(), 2000);
        assert_eq!(d.num_clusters(), 3);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = BlobsConfig { n: 100, dim: 3, clusters: 2, ..Default::default() };
        let a = make_blobs(&cfg, 1);
        let b = make_blobs(&cfg, 1);
        let c = make_blobs(&cfg, 2);
        assert_eq!(a.xs, b.xs);
        assert_ne!(a.xs, c.xs);
    }

    #[test]
    fn points_near_their_center() {
        // with std=0.5 and box=50 the intra-cluster spread is far below the
        // inter-center distance w.h.p.; check points of one cluster are
        // mutually closer than points across clusters on average.
        let cfg = BlobsConfig {
            n: 400,
            dim: 5,
            clusters: 4,
            std: 0.5,
            center_box: 50.0,
            weights: vec![],
        };
        let d = make_blobs(&cfg, 3);
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter()
                .zip(b)
                .map(|(x, y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let mut intra = (0.0, 0);
        let mut inter = (0.0, 0);
        for i in 0..100 {
            for j in (i + 1)..100 {
                let dd = dist(d.point(i), d.point(j));
                if d.labels[i] == d.labels[j] {
                    intra = (intra.0 + dd, intra.1 + 1);
                } else {
                    inter = (inter.0 + dd, inter.1 + 1);
                }
            }
        }
        assert!(intra.0 / intra.1 as f64 * 4.0 < inter.0 / inter.1 as f64);
    }

    #[test]
    fn weighted_mixture_respects_weights() {
        let cfg = BlobsConfig {
            n: 10_000,
            dim: 2,
            clusters: 2,
            weights: vec![0.9, 0.1],
            ..Default::default()
        };
        let d = make_blobs(&cfg, 11);
        let c0 = d.labels.iter().filter(|&&l| l == 0).count();
        assert!((c0 as f64 / 10_000.0 - 0.9).abs() < 0.02);
    }
}
