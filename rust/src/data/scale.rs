//! Per-dimension standardization (zero mean, unit variance) — the paper
//! applies this to every dataset before clustering.

use super::Dataset;

/// Fitted standardizer (kept so streams of *new* points can be transformed
//  with the same statistics).
#[derive(Clone, Debug)]
pub struct Standardizer {
    pub mean: Vec<f64>,
    pub inv_std: Vec<f64>,
}

impl Standardizer {
    /// Fit on a dataset (population variance, like sklearn StandardScaler).
    pub fn fit(ds: &Dataset) -> Self {
        let (n, d) = (ds.n(), ds.dim);
        let mut mean = vec![0.0f64; d];
        for i in 0..n {
            for (j, m) in mean.iter_mut().enumerate() {
                *m += ds.xs[i * d + j] as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= n as f64;
        }
        let mut var = vec![0.0f64; d];
        for i in 0..n {
            for j in 0..d {
                let e = ds.xs[i * d + j] as f64 - mean[j];
                var[j] += e * e;
            }
        }
        let inv_std = var
            .iter()
            .map(|&v| {
                let s = (v / n as f64).sqrt();
                if s > 1e-12 {
                    1.0 / s
                } else {
                    1.0 // constant dimension: leave centered values at 0
                }
            })
            .collect();
        Standardizer { mean, inv_std }
    }

    pub fn transform_point(&self, x: &mut [f32]) {
        for (j, v) in x.iter_mut().enumerate() {
            *v = ((*v as f64 - self.mean[j]) * self.inv_std[j]) as f32;
        }
    }

    pub fn transform(&self, ds: &mut Dataset) {
        let d = ds.dim;
        for row in ds.xs.chunks_mut(d) {
            self.transform_point(row);
        }
    }
}

/// Fit + transform in place.
pub fn standardize(ds: &mut Dataset) -> Standardizer {
    let s = Standardizer::fit(ds);
    s.transform(ds);
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{make_blobs, BlobsConfig};

    #[test]
    fn zero_mean_unit_var() {
        let cfg = BlobsConfig { n: 2000, dim: 6, clusters: 3, ..Default::default() };
        let mut ds = make_blobs(&cfg, 5);
        standardize(&mut ds);
        let (n, d) = (ds.n(), ds.dim);
        for j in 0..d {
            let mean: f64 =
                (0..n).map(|i| ds.xs[i * d + j] as f64).sum::<f64>() / n as f64;
            let var: f64 = (0..n)
                .map(|i| (ds.xs[i * d + j] as f64 - mean).powi(2))
                .sum::<f64>()
                / n as f64;
            assert!(mean.abs() < 1e-3, "dim {j} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "dim {j} var {var}");
        }
    }

    #[test]
    fn constant_dimension_is_safe() {
        let mut ds = Dataset {
            name: "c".into(),
            dim: 2,
            xs: vec![3.0, 1.0, 3.0, 2.0, 3.0, 3.0],
            labels: vec![0, 0, 0],
        };
        standardize(&mut ds);
        for i in 0..3 {
            assert_eq!(ds.xs[i * 2], 0.0, "constant dim centered to zero");
            assert!(ds.xs[i * 2 + 1].is_finite());
        }
    }

    #[test]
    fn stream_transform_matches_batch() {
        let cfg = BlobsConfig { n: 100, dim: 3, clusters: 2, ..Default::default() };
        let ds0 = make_blobs(&cfg, 9);
        let mut batch = ds0.clone();
        let s = standardize(&mut batch);
        // transform points one by one with the fitted scaler
        for i in 0..ds0.n() {
            let mut p = ds0.point(i).to_vec();
            s.transform_point(&mut p);
            assert_eq!(&p[..], batch.point(i));
        }
    }
}
