//! Datasets and preprocessing.
//!
//! The paper evaluates on OpenML datasets (Table 1). The build environment
//! has no network access, so [`synth`] provides **seeded synthetic
//! stand-ins** with the same `(n, d, #clusters)` and per-dataset
//! separation/imbalance profiles (see `DESIGN.md` §Substitutions). The
//! preprocessing path is exactly the paper's: generate at native
//! dimensionality → [`pca`] to 20 where the paper does → [`scale`] every
//! dimension to zero mean / unit variance → stream in batches of 1000
//! ([`stream`]).

pub mod blobs;
pub mod pca;
pub mod scale;
pub mod stream;
pub mod synth;

/// A labeled point set, row-major `n × dim`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub dim: usize,
    /// row-major coordinates, `n * dim`
    pub xs: Vec<f32>,
    /// ground-truth cluster labels, length n
    pub labels: Vec<i64>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.labels.len()
    }

    #[inline]
    pub fn point(&self, i: usize) -> &[f32] {
        &self.xs[i * self.dim..(i + 1) * self.dim]
    }

    /// Number of distinct ground-truth labels.
    pub fn num_clusters(&self) -> usize {
        let mut ls: Vec<i64> = self.labels.clone();
        ls.sort_unstable();
        ls.dedup();
        ls.len()
    }

    /// Keep only the first `n` points (used by scaled-down bench runs).
    pub fn truncate(&mut self, n: usize) {
        if n < self.n() {
            self.xs.truncate(n * self.dim);
            self.labels.truncate(n);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_accessors() {
        let d = Dataset {
            name: "t".into(),
            dim: 2,
            xs: vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0],
            labels: vec![0, 0, 1],
        };
        assert_eq!(d.n(), 3);
        assert_eq!(d.point(1), &[2.0, 3.0]);
        assert_eq!(d.num_clusters(), 2);
        let mut e = d.clone();
        e.truncate(2);
        assert_eq!(e.n(), 2);
        assert_eq!(e.xs.len(), 4);
    }
}
