//! Update streams: turn a dataset into the dynamic workload the paper
//! evaluates — batches of 1000 insertions in a random or cluster-by-cluster
//! order, plus deletion-bearing variants (sliding window) for the dynamic
//! stress tests.

use crate::util::rng::Rng;

use super::Dataset;

/// Arrival order of the stream (Figure 2 b vs c).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Order {
    Random,
    /// all of cluster 0, then cluster 1, ... (the EMZFixedCore killer)
    ClusterByCluster,
}

/// A single update against the clustering structure.
#[derive(Clone, Debug, PartialEq)]
pub enum UpdateOp {
    /// Insert point `i` of the dataset.
    Insert(usize),
    /// Delete (previously inserted) point `i`.
    Delete(usize),
}

/// Insertion order of dataset indices under `order`.
pub fn insertion_order(ds: &Dataset, order: Order, seed: u64) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..ds.n()).collect();
    match order {
        Order::Random => {
            let mut rng = Rng::new(seed);
            rng.shuffle(&mut idx);
        }
        Order::ClusterByCluster => {
            // stable by (label, original position); shuffle within cluster
            let mut rng = Rng::new(seed);
            idx.sort_by_key(|&i| (ds.labels[i], i));
            // shuffle runs of equal labels
            let mut start = 0;
            while start < idx.len() {
                let l = ds.labels[idx[start]];
                let mut end = start;
                while end < idx.len() && ds.labels[idx[end]] == l {
                    end += 1;
                }
                rng.shuffle(&mut idx[start..end]);
                start = end;
            }
        }
    }
    idx
}

/// Pure-insertion stream in `batch`-sized chunks (the paper's workload:
/// batch = 1000, metrics evaluated after each batch).
pub fn insert_stream(
    ds: &Dataset,
    order: Order,
    batch: usize,
    seed: u64,
) -> Vec<Vec<UpdateOp>> {
    insertion_order(ds, order, seed)
        .chunks(batch.max(1))
        .map(|c| c.iter().map(|&i| UpdateOp::Insert(i)).collect())
        .collect()
}

/// Sliding-window stream: insert in order; once more than `window` points
/// are live, delete the oldest alongside each insertion. Exercises
/// `DeletePoint` exactly as the paper's dynamic setting requires.
pub fn sliding_window_stream(
    ds: &Dataset,
    order: Order,
    batch: usize,
    window: usize,
    seed: u64,
) -> Vec<Vec<UpdateOp>> {
    let idx = insertion_order(ds, order, seed);
    let mut out = Vec::new();
    let mut cur = Vec::with_capacity(batch * 2);
    let mut live_from = 0usize; // pointer into idx of the oldest live point
    for (pos, &i) in idx.iter().enumerate() {
        cur.push(UpdateOp::Insert(i));
        let live = pos + 1 - live_from;
        if live > window {
            cur.push(UpdateOp::Delete(idx[live_from]));
            live_from += 1;
        }
        if cur.len() >= batch {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{make_blobs, BlobsConfig};

    fn ds() -> Dataset {
        make_blobs(
            &BlobsConfig { n: 100, dim: 2, clusters: 4, ..Default::default() },
            3,
        )
    }

    #[test]
    fn random_order_is_permutation() {
        let d = ds();
        let idx = insertion_order(&d, Order::Random, 1);
        let mut s = idx.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(idx, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn cluster_order_is_grouped() {
        let d = ds();
        let idx = insertion_order(&d, Order::ClusterByCluster, 1);
        let labels: Vec<i64> = idx.iter().map(|&i| d.labels[i]).collect();
        // labels must be non-decreasing
        assert!(labels.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn batching() {
        let d = ds();
        let s = insert_stream(&d, Order::Random, 30, 2);
        assert_eq!(s.len(), 4);
        assert_eq!(s[0].len(), 30);
        assert_eq!(s[3].len(), 10);
        let total: usize = s.iter().map(|b| b.len()).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn sliding_window_keeps_live_bounded() {
        let d = ds();
        let s = sliding_window_stream(&d, Order::Random, 25, 40, 4);
        let mut live = std::collections::HashSet::new();
        for batch in &s {
            for op in batch {
                match op {
                    UpdateOp::Insert(i) => {
                        assert!(live.insert(*i));
                    }
                    UpdateOp::Delete(i) => {
                        assert!(live.remove(i));
                    }
                }
                assert!(live.len() <= 41);
            }
        }
        assert_eq!(live.len(), 40);
    }
}
