//! Principal component analysis via covariance + subspace (orthogonal)
//! iteration — used to reduce the MNIST/Fashion-MNIST/KDDCup-like datasets
//! to d = 20, exactly the paper's preprocessing.
//!
//! The projection step (`X @ W`) can optionally run through the AOT
//! `project_*` artifact (see `runtime::engines`); the fit is pure Rust
//! (d ≤ a few hundred, so the d×d eigenproblem is tiny).

use crate::util::rng::Rng;

use super::Dataset;

#[derive(Clone, Debug)]
pub struct Pca {
    pub mean: Vec<f64>,
    /// column-major `din × dout` projection matrix
    pub components: Vec<f64>,
    pub din: usize,
    pub dout: usize,
}

impl Pca {
    /// Fit the top `dout` principal components with subspace iteration.
    pub fn fit(ds: &Dataset, dout: usize, seed: u64) -> Pca {
        let (n, d) = (ds.n(), ds.dim);
        assert!(dout <= d, "dout {dout} > dim {d}");
        // mean
        let mut mean = vec![0.0f64; d];
        for i in 0..n {
            for (j, m) in mean.iter_mut().enumerate() {
                *m += ds.xs[i * d + j] as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= n as f64;
        }
        // covariance (upper triangle, then mirror)
        let mut cov = vec![0.0f64; d * d];
        for i in 0..n {
            let row = &ds.xs[i * d..(i + 1) * d];
            for a in 0..d {
                let xa = row[a] as f64 - mean[a];
                for b in a..d {
                    cov[a * d + b] += xa * (row[b] as f64 - mean[b]);
                }
            }
        }
        for a in 0..d {
            for b in a..d {
                let v = cov[a * d + b] / (n as f64 - 1.0).max(1.0);
                cov[a * d + b] = v;
                cov[b * d + a] = v;
            }
        }
        // subspace iteration: Q ← orth(C·Q), 60 rounds
        let mut rng = Rng::new(seed);
        let mut q = vec![0.0f64; d * dout]; // column-major d × dout
        for v in q.iter_mut() {
            *v = rng.normal();
        }
        orthonormalize(&mut q, d, dout);
        let mut tmp = vec![0.0f64; d * dout];
        for _ in 0..60 {
            // tmp = C * q  (column by column)
            for c in 0..dout {
                for a in 0..d {
                    let mut s = 0.0;
                    for b in 0..d {
                        s += cov[a * d + b] * q[c * d + b];
                    }
                    tmp[c * d + a] = s;
                }
            }
            std::mem::swap(&mut q, &mut tmp);
            orthonormalize(&mut q, d, dout);
        }
        Pca { mean, components: q, din: d, dout }
    }

    /// Project a dataset to the fitted subspace.
    pub fn transform(&self, ds: &Dataset) -> Dataset {
        let (n, d) = (ds.n(), ds.dim);
        assert_eq!(d, self.din);
        let mut xs = Vec::with_capacity(n * self.dout);
        for i in 0..n {
            let row = &ds.xs[i * d..(i + 1) * d];
            for c in 0..self.dout {
                let col = &self.components[c * d..(c + 1) * d];
                let mut s = 0.0f64;
                for j in 0..d {
                    s += (row[j] as f64 - self.mean[j]) * col[j];
                }
                xs.push(s as f32);
            }
        }
        Dataset {
            name: ds.name.clone(),
            dim: self.dout,
            xs,
            labels: ds.labels.clone(),
        }
    }

    /// Projection matrix as row-major f32 `din × dout` (for the AOT
    /// `project` artifact which computes `X @ W`).
    pub fn weight_matrix_f32(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.din * self.dout];
        for c in 0..self.dout {
            for r in 0..self.din {
                w[r * self.dout + c] = self.components[c * self.din + r] as f32;
            }
        }
        w
    }
}

/// Gram–Schmidt on column-major `d × k`.
fn orthonormalize(q: &mut [f64], d: usize, k: usize) {
    for c in 0..k {
        // subtract projections on previous columns
        for p in 0..c {
            let mut dot = 0.0;
            for j in 0..d {
                dot += q[c * d + j] * q[p * d + j];
            }
            for j in 0..d {
                q[c * d + j] -= dot * q[p * d + j];
            }
        }
        let norm: f64 = q[c * d..(c + 1) * d].iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm > 1e-12 {
            for j in 0..d {
                q[c * d + j] /= norm;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Build a dataset with known dominant directions.
    fn anisotropic(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut xs = Vec::with_capacity(n * d);
        for _ in 0..n {
            // variance 100 on dim 0, 25 on dim 1, 1 elsewhere
            for j in 0..d {
                let s = match j {
                    0 => 10.0,
                    1 => 5.0,
                    _ => 1.0,
                };
                xs.push((s * rng.normal()) as f32);
            }
        }
        Dataset { name: "aniso".into(), dim: d, xs, labels: vec![0; n] }
    }

    #[test]
    fn recovers_dominant_directions() {
        let ds = anisotropic(4000, 6, 1);
        let pca = Pca::fit(&ds, 2, 2);
        // first component ≈ e0, second ≈ e1 (up to sign)
        let c0 = &pca.components[0..6];
        let c1 = &pca.components[6..12];
        assert!(c0[0].abs() > 0.99, "c0 = {c0:?}");
        assert!(c1[1].abs() > 0.99, "c1 = {c1:?}");
    }

    #[test]
    fn transform_preserves_variance_ordering() {
        let ds = anisotropic(4000, 6, 3);
        let pca = Pca::fit(&ds, 3, 4);
        let proj = pca.transform(&ds);
        assert_eq!(proj.dim, 3);
        assert_eq!(proj.n(), ds.n());
        let var = |k: usize| -> f64 {
            let m: f64 = (0..proj.n()).map(|i| proj.xs[i * 3 + k] as f64).sum::<f64>()
                / proj.n() as f64;
            (0..proj.n())
                .map(|i| (proj.xs[i * 3 + k] as f64 - m).powi(2))
                .sum::<f64>()
                / proj.n() as f64
        };
        let (v0, v1, v2) = (var(0), var(1), var(2));
        assert!(v0 > v1 && v1 > v2, "variances not ordered: {v0} {v1} {v2}");
        assert!((v0 - 100.0).abs() / 100.0 < 0.15, "v0 = {v0}");
    }

    #[test]
    fn components_are_orthonormal() {
        let ds = anisotropic(1000, 8, 5);
        let pca = Pca::fit(&ds, 4, 6);
        for a in 0..4 {
            for b in 0..4 {
                let dot: f64 = (0..8)
                    .map(|j| pca.components[a * 8 + j] * pca.components[b * 8 + j])
                    .sum();
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-8, "Q'Q[{a}][{b}] = {dot}");
            }
        }
    }
}
