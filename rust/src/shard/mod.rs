//! `shard` — the sharded parallel serving engine with incremental
//! cross-shard cluster stitching.
//!
//! The paper's `O(d·log³n + log⁴n)` update bound (Theorem 1) is per-point
//! and single-threaded; this subsystem scales it across cores the way
//! Wang–Gu–Shun (arXiv:1912.06255) parallelize static DBSCAN: the grid
//! decomposition is the partitioning unit. Our grid-LSH buckets
//! (Definition 3) give that unit for free — the cell of the *first* hash
//! function spatially partitions the data, so an S-way split by cell block
//! co-locates density-connected points and makes cross-shard edges rare and
//! local to block boundaries.
//!
//! ```text
//!            ┌─────────┐   per-shard bounded op channels
//!  updates ─▶│ Router  │──┬──▶ [worker 0: DynamicDbscan]──┐  delta reports
//!            │ (cell → │  ├──▶ [worker 1: DynamicDbscan]──┤  (changed (ext,
//!            │ Placeme-│  ├──▶ [worker 2: DynamicDbscan]──┼──▶ [Stitcher] ─▶ Arc<GlobalSnapshot>
//!            │ ntMap)  │  └──▶ [worker 3: DynamicDbscan]──┘  local-root)s)      │
//!            └─────────┘      + ghost replicas    persistent stitch graph   reads: cluster_of /
//!              versioned        in boundary margin  over (shard, root) on   cluster_sizes / stats
//!              cell→shard map,  + migration batches LeveledConn (HDT)
//!              live resharding    at publish
//! ```
//!
//! **Routing** ([`router::Router`] + [`placement::PlacementMap`]): a
//! point's cell is its integer grid coordinate row under hash function 0,
//! truncated to the first `routing_dims` axes. Which shard owns a cell is
//! answered by the router's stateful, versioned **placement map** — under
//! the default [`PlacementPolicy::CellGraph`] cells are assigned greedily
//! over cell adjacency (fewest new cut edges, load-capped, block hash as
//! the bootstrap seed); [`PlacementPolicy::BlockHash`] keeps the legacy
//! stateless block-hash scatter. Deterministic in (seed, config, op
//! sequence) — the same stream always routes identically. With
//! [`ReshardMode::Auto`], publish-time load imbalance triggers a bounded
//! cell migration executed through the ordinary worker batches (see
//! [`placement`]). At `shards == 1` the router (and ghost replication,
//! and the worker channel) is bypassed entirely: the engine drives one
//! inline [`worker::ShardCore`], so the one-shard configuration is the
//! direct path plus delta bookkeeping instead of a slower pipeline.
//!
//! **Ghost replication**: a grid-LSH collision (any of the `t` hash
//! functions) implies `‖x−y‖∞ ≤ 2ε`, i.e. the two cells differ by at most
//! one per axis. Points whose cell lies within `ghost_margin` cells of a
//! block face are replicated into the neighboring block's shard as *ghost
//! points*. With the default margin of 2, every bucket containing a primary
//! point — and every bucket containing a replica that sits within one cell
//! of the boundary — is complete in that shard, so core flags and
//! cross-boundary connectivity are exact where it matters (see
//! `DESIGN.md` §Sharding for the argument).
//!
//! **Stitching** ([`stitch::Stitcher`]): a **persistent dynamic stitch
//! graph** over `(shard, local cluster root)` nodes, maintained by the
//! same HDT-leveled connectivity ([`crate::dbscan::LeveledConn`]) the
//! per-shard instances use — which makes cross-shard *un-unions* (cluster
//! splits under deletes) as cheap as unions. On publish each worker ships
//! a [`worker::ShardDelta`] — only the `(ext, local-root)` assignments
//! that changed since its previous report — and the stitcher folds it in
//! at `O(Δ·log²n)`. The old from-scratch union-find rebuild survives as
//! the explicit [`StitchMode::FullRebuild`] fallback ([`stitch::stitch_full`]).
//!
//! **Reads** ([`stitch::GlobalSnapshot`]): `cluster_of`, `cluster_sizes`
//! and counters are served from the latest published immutable snapshot
//! behind an `Arc` — readers clone the `Arc` and never block the update
//! path. Successive snapshots CoW-share their label state
//! ([`labels::LabelMap`]), so publication allocates in changed points,
//! not live points.

pub mod engine;
pub mod labels;
pub mod placement;
pub mod router;
pub mod stitch;
pub mod worker;

pub use engine::{EngineError, EngineOutcome, EngineStats, ShardedEngine};
pub use labels::LabelMap;
pub use placement::{CellKey, CellMove, PlacementMap, PlacementPolicy, ReshardMode};
pub use router::{RouteDecision, Router};
pub use stitch::{stitch_full, GlobalSnapshot, LabelChange, Stitcher};
pub use worker::{
    FaultPlan, ShardBatch, ShardCore, ShardDelta, ShardOp, ShardReply,
    ShardSnapshot, WorkerReport,
};

use crate::dbscan::{ConnKind, DbscanConfig};

/// How `publish` turns per-shard state into a [`GlobalSnapshot`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StitchMode {
    /// Incremental (default): per-shard delta reports folded into the
    /// persistent stitch graph — `O(Δ·log²n)` per publish in changed
    /// points.
    Delta,
    /// From-scratch union-find rebuild over full state dumps —
    /// `O(n log n)` per publish. Explicit fallback + differential oracle.
    FullRebuild,
}

/// Configuration of the sharded engine. All shards share the DBSCAN
/// hyper-parameters and the seed, so every worker draws the *same* hash
/// shifts as the router — the per-shard structures are restrictions of one
/// global bucket space.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    pub dbscan: DbscanConfig,
    /// number of shard workers (≥ 1)
    pub shards: usize,
    /// cell axes used for block routing; 0 = auto (`min(dim, 2)`), capped
    /// at 4 to bound the 3^r ghost-neighbor enumeration
    pub routing_dims: usize,
    /// block edge length in cells along each routing axis (≥ 1)
    pub block_side: u32,
    /// replicate points whose cell is within this many cells of a block
    /// face; 2 keeps boundary-adjacent buckets complete in both shards
    pub ghost_margin: u32,
    /// cell→shard assignment policy (default [`PlacementPolicy::CellGraph`]:
    /// greedy cell-graph partitioning; [`PlacementPolicy::BlockHash`] is
    /// the legacy stateless scatter)
    pub placement: PlacementPolicy,
    /// live resharding (default [`ReshardMode::Off`]). `Auto` requires
    /// ≥ 2 shards and `CellGraph` placement (enforced by
    /// `ShardedEngine::new`; the builder rejects it earlier with a typed
    /// error).
    pub reshard: ReshardMode,
    /// bounded op-channel capacity per worker, in batches
    pub queue: usize,
    /// snapshot publication strategy (delta = incremental, the default)
    pub stitch: StitchMode,
    /// connectivity layer of every worker's `DynamicDbscan`. The flat
    /// ablation modes lack stable component ids, so they require
    /// [`StitchMode::FullRebuild`] (enforced by `ShardedEngine::new`).
    pub conn: ConnKind,
    pub seed: u64,
    /// live metrics (default on): workers record per-op latencies, stage
    /// spans and structural gauges into the engine's shared
    /// [`crate::obs::Metrics`] registry. Off = a no-op recorder (the
    /// `obs_overhead` bench baseline).
    pub metrics: bool,
    /// how long a publish barrier waits for each outstanding worker reply
    /// before declaring the shard wedged and degrading (see
    /// [`engine::EngineError`])
    pub publish_timeout_ms: u64,
    /// test-only fault injection for one worker (`None` in production)
    #[doc(hidden)]
    pub faults: Option<worker::FaultPlan>,
}

impl ShardConfig {
    pub fn new(dbscan: DbscanConfig, shards: usize, seed: u64) -> Self {
        ShardConfig {
            dbscan,
            shards: shards.max(1),
            routing_dims: 0,
            block_side: 8,
            ghost_margin: 2,
            placement: PlacementPolicy::CellGraph,
            reshard: ReshardMode::Off,
            queue: 8,
            stitch: StitchMode::Delta,
            conn: ConnKind::Leveled,
            seed,
            metrics: true,
            publish_timeout_ms: 10_000,
            faults: None,
        }
    }

    /// Effective number of routing axes.
    pub fn effective_routing_dims(&self) -> usize {
        let r = if self.routing_dims == 0 {
            self.dbscan.dim.min(2)
        } else {
            self.routing_dims.min(self.dbscan.dim)
        };
        r.clamp(1, 4)
    }
}
