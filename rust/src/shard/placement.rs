//! Stateful cell→shard placement: the single authority on where a grid
//! cell (and therefore every point hashing into it) lives, and on when
//! cells should migrate between shards.
//!
//! The pre-placement router hashed a cell's *block* to a shard — a pure
//! function, deterministic but blind to geometry and load: adjacent cell
//! neighborhoods scatter across shards, so boundary replication (ghosts)
//! grows with the shard count and eats the parallelism. This module
//! replaces the pure function with an explicit, versioned assignment map
//! in the spirit of Wang–Gu–Shun's cell-graph partitioning
//! (arXiv:1912.06255):
//!
//! * **[`PlacementPolicy::BlockHash`]** keeps the legacy behavior bit-for-
//!   bit: every cell's owner is the block hash, ghosts are the owners of
//!   the cells within `ghost_margin` (identical to the old per-face rule
//!   whenever `ghost_margin ≤ block_side`).
//! * **[`PlacementPolicy::CellGraph`]** (the sharded default) assigns each
//!   cell *greedily on first touch*: it joins the shard that owns the most
//!   of its already-assigned neighbors — minimizing new cut edges — unless
//!   that shard is over the load cap, in which case the least-loaded
//!   admissible shard takes it (block hash as the bootstrap tie-break, so
//!   an empty map starts out exactly like the legacy scatter). Assignments
//!   are sticky: a cell's owner only changes through an explicit
//!   migration, so in-flight batches always route consistently.
//!
//! **Ghost correctness is policy-independent.** A grid-LSH collision
//! bounds the cell distance by one per axis, so replicating every point
//! into the owners of all cells within `ghost_margin ≥ 1` of its own cell
//! keeps every collision edge realized in at least one shard — and margin
//! 2 keeps boundary-adjacent buckets complete, making replica core flags
//! exact — *no matter what the cell→shard map looks like* (see DESIGN.md
//! §Partitioning). To keep decisions stable, deciding a cell under
//! `CellGraph` force-assigns its whole margin neighborhood, so a later
//! first-touch of a neighbor can never change an already-issued decision.
//!
//! **Live resharding** ([`PlacementMap::plan_migration`]): when the
//! hottest shard's live load exceeds the trigger slack over the mean, the
//! map plans a bounded migration — boundary cells of the hot shard with
//! the highest affinity to the coldest shard (whole cell neighborhoods
//! peel together), capped per publish and by half the load imbalance so
//! repeated plans converge instead of oscillating. [`apply_moves`]
//! (re)assigns the cells, bumps the map **version** and clears the route
//! cache; the engine then re-routes the members of every affected cell
//! through the normal worker batches. Each map version defines one
//! consistent routing epoch.
//!
//! [`apply_moves`]: PlacementMap::apply_moves

use rustc_hash::{FxHashMap, FxHashSet};

use crate::util::rng::mix64;

use super::router::RouteDecision;

/// Hard cap on routing axes (bounds the `(2m+1)^r` neighbor enumeration).
pub const MAX_ROUTING_DIMS: usize = 4;

/// A cell's routing coordinates: the grid cell of hash function 0,
/// truncated to the routing axes (unused trailing axes are zero). Fixed
/// width so keys are `Copy` and order deterministically.
pub type CellKey = [i32; MAX_ROUTING_DIMS];

/// How cells map to shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Legacy stateless scatter: owner = hash of the cell's block. Zero
    /// placement state to migrate, but adjacent neighborhoods split across
    /// shards and the ghost ratio grows with the shard count.
    BlockHash,
    /// Greedy cell-graph partitioning (sharded default): cells join the
    /// shard owning most of their assigned neighbors, subject to a load
    /// cap — fewer cut edges, fewer ghosts, and the substrate live
    /// resharding migrates over.
    CellGraph,
}

/// Whether publish-time load imbalance triggers live cell migration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReshardMode {
    /// Assignments are sticky forever (still the default).
    Off,
    /// Plan and execute a bounded migration at publish when the load
    /// imbalance trips [`RESHARD_TRIGGER_SLACK`]; at most
    /// `max_cells_per_publish` cells move per publish, so reads never
    /// wait on a stop-the-world rebuild.
    Auto { max_cells_per_publish: usize },
}

/// One planned cell migration (source shard → target shard).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CellMove {
    pub cell: CellKey,
    pub from: u32,
    pub to: u32,
    /// live members the move re-routes (plan-time count)
    pub points: usize,
}

/// Greedy admission: a shard may accept a first-touch cell while its load
/// is within this slack of the mean.
const LOAD_SLACK: f64 = 1.2;

/// Absolute load headroom added to the greedy cap so the bootstrap phase
/// (mean ≈ 0) doesn't force round-robin scatter.
const LOAD_HEADROOM: f64 = 32.0;

/// Migration triggers when the hottest shard exceeds the mean load by
/// this factor (plus [`RESHARD_MIN_IMBALANCE`] points).
const RESHARD_TRIGGER_SLACK: f64 = 1.25;

/// Minimum absolute head-over-mean before migration is worth its churn.
const RESHARD_MIN_IMBALANCE: u64 = 64;

/// Per-cell assignment state: the owning shard and the live external ids
/// whose *primary* cell this is (ghost replicas are derived, not stored).
struct CellState {
    owner: u32,
    members: FxHashSet<u64>,
}

/// The versioned cell→shard assignment map. Owned by the router; every
/// routing decision, load gauge, migration plan and respawn re-feed is
/// answered from here — no other module may map cells (or blocks) to
/// shards (lint-enforced).
pub struct PlacementMap {
    policy: PlacementPolicy,
    shards: usize,
    routing_dims: usize,
    block_side: i32,
    ghost_margin: i32,
    /// bumped once per applied migration plan; decisions issued under one
    /// version route consistently (the route cache never spans versions)
    version: u64,
    cells: FxHashMap<CellKey, CellState>,
    /// live primary points per shard (the balance the greedy cap and the
    /// migration trigger act on)
    load: Vec<u64>,
    /// dist-1 adjacent assigned cell pairs with different owners — the
    /// quantity the greedy assignment minimizes (`cut_edges` gauge)
    cut_edges: i64,
    /// memoized decisions for the current version
    route_cache: FxHashMap<CellKey, RouteDecision>,
}

/// The legacy block→shard hash — the bootstrap/fallback owner. Kept
/// byte-identical to the pre-placement router so `BlockHash` reproduces
/// historical routing exactly.
fn shard_of_blocks(blocks: &[i32], shards: usize) -> usize {
    let mut h: u64 = 0x8f3a_55b1_c2d4_e693;
    for &b in blocks {
        h = mix64(h ^ (b as u32 as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
    }
    (h % shards as u64) as usize
}

/// All cells within Chebyshev distance `radius` of `cell` along the first
/// `r` axes, excluding `cell` itself, in deterministic odometer order.
fn neighbor_keys(cell: &CellKey, r: usize, radius: i32) -> Vec<CellKey> {
    if radius <= 0 {
        return Vec::new();
    }
    let width = (2 * radius + 1) as usize;
    let mut out = Vec::with_capacity(width.pow(r as u32).saturating_sub(1));
    let mut off = [0i32; MAX_ROUTING_DIMS];
    off[..r].fill(-radius);
    loop {
        if off[..r].iter().any(|&o| o != 0) {
            let mut nb = *cell;
            for ax in 0..r {
                nb[ax] += off[ax];
            }
            out.push(nb);
        }
        let mut ax = 0;
        loop {
            if ax == r {
                return out;
            }
            off[ax] += 1;
            if off[ax] <= radius {
                break;
            }
            off[ax] = -radius;
            ax += 1;
        }
    }
}

impl PlacementMap {
    pub fn new(
        policy: PlacementPolicy,
        shards: usize,
        routing_dims: usize,
        block_side: u32,
        ghost_margin: u32,
    ) -> Self {
        assert!(block_side >= 1, "block_side must be >= 1");
        assert!(
            (1..=MAX_ROUTING_DIMS).contains(&routing_dims),
            "routing_dims must be in 1..={MAX_ROUTING_DIMS}"
        );
        PlacementMap {
            policy,
            shards: shards.max(1),
            routing_dims,
            block_side: block_side as i32,
            ghost_margin: ghost_margin as i32,
            version: 0,
            cells: FxHashMap::default(),
            load: vec![0; shards.max(1)],
            cut_edges: 0,
            route_cache: FxHashMap::default(),
        }
    }

    pub fn policy(&self) -> PlacementPolicy {
        self.policy
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn routing_dims(&self) -> usize {
        self.routing_dims
    }

    /// Routing epoch: bumped once per applied migration plan (and restored
    /// by [`Self::import`]).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Live primary points per shard.
    pub fn load(&self) -> &[u64] {
        &self.load
    }

    /// Dist-1 adjacent assigned cell pairs owned by different shards.
    pub fn cut_edges(&self) -> u64 {
        self.cut_edges.max(0) as u64
    }

    /// Assigned cells (member-bearing or not).
    pub fn total_cells(&self) -> usize {
        self.cells.len()
    }

    /// Cells currently holding at least one live member.
    pub fn live_cells(&self) -> usize {
        self.cells.values().filter(|st| !st.members.is_empty()).count()
    }

    /// The legacy block-hash owner of `cell` — the bootstrap seed and the
    /// `BlockHash` policy's entire answer.
    fn fallback_owner(&self, cell: &CellKey) -> u32 {
        let mut blocks = [0i32; MAX_ROUTING_DIMS];
        for ax in 0..self.routing_dims {
            blocks[ax] = cell[ax].div_euclid(self.block_side);
        }
        shard_of_blocks(&blocks[..self.routing_dims], self.shards) as u32
    }

    /// Greedy first-touch owner under `CellGraph`: most assigned dist-1
    /// neighbors win (fewest new cut edges), the load cap keeps shards
    /// balanced, and ties break load-ascending → block-hash → lowest id,
    /// so an empty bootstrap reproduces the legacy scatter exactly.
    fn pick_owner(&self, cell: &CellKey) -> u32 {
        let mut votes = vec![0u32; self.shards];
        for nb in neighbor_keys(cell, self.routing_dims, 1) {
            if let Some(st) = self.cells.get(&nb) {
                votes[st.owner as usize] += 1;
            }
        }
        let total: u64 = self.load.iter().sum();
        let cap = (total as f64 / self.shards as f64) * LOAD_SLACK + LOAD_HEADROOM;
        let fb = self.fallback_owner(cell);
        let mut best: Option<(u32, u64, bool, usize)> = None;
        for s in 0..self.shards {
            if self.load[s] as f64 > cap {
                continue;
            }
            let key = (votes[s], u64::MAX - self.load[s], s as u32 == fb);
            let better = match best {
                None => true,
                Some((v, il, f, _)) => key > (v, il, f),
            };
            if better {
                best = Some((key.0, key.1, key.2, s));
            }
        }
        match best {
            Some((.., s)) => s as u32,
            // every shard above cap is transient (min ≤ mean ≤ cap can
            // only be violated mid-migration): least-loaded wins
            None => {
                let mut s = 0;
                for i in 1..self.shards {
                    if self.load[i] < self.load[s] {
                        s = i;
                    }
                }
                s as u32
            }
        }
    }

    /// Owner of `cell`, assigning it on first touch (sticky thereafter)
    /// and keeping the cut-edge count current.
    fn ensure_cell(&mut self, cell: &CellKey) -> u32 {
        if let Some(st) = self.cells.get(cell) {
            return st.owner;
        }
        let owner = match self.policy {
            PlacementPolicy::CellGraph => self.pick_owner(cell),
            PlacementPolicy::BlockHash => self.fallback_owner(cell),
        };
        let mut cut = 0i64;
        for nb in neighbor_keys(cell, self.routing_dims, 1) {
            if let Some(st) = self.cells.get(&nb) {
                if st.owner != owner {
                    cut += 1;
                }
            }
        }
        self.cut_edges += cut;
        self.cells
            .insert(*cell, CellState { owner, members: FxHashSet::default() });
        owner
    }

    /// Owner for decision purposes. `CellGraph` force-assigns on touch so
    /// issued decisions can never be invalidated by a later first-touch;
    /// `BlockHash` stays stateless for untracked cells (probing a margin
    /// neighborhood must not materialize map entries).
    fn owner_of(&mut self, cell: &CellKey) -> u32 {
        match self.policy {
            PlacementPolicy::CellGraph => self.ensure_cell(cell),
            PlacementPolicy::BlockHash => match self.cells.get(cell) {
                Some(st) => st.owner,
                None => self.fallback_owner(cell),
            },
        }
    }

    fn compute_decision(&mut self, cell: &CellKey) -> RouteDecision {
        let primary = self.owner_of(cell) as usize;
        let mut ghosts: Vec<usize> = Vec::new();
        if self.shards > 1 && self.ghost_margin > 0 {
            for nb in neighbor_keys(cell, self.routing_dims, self.ghost_margin) {
                let s = self.owner_of(&nb) as usize;
                if s != primary && !ghosts.contains(&s) {
                    ghosts.push(s);
                }
            }
            ghosts.sort_unstable();
        }
        RouteDecision { primary, ghosts }
    }

    /// The routing decision for `cell` under the current version:
    /// primary = owner, ghosts = the other owners within `ghost_margin`.
    /// Memoized until the next migration bumps the version.
    pub fn decide(&mut self, cell: &CellKey) -> &RouteDecision {
        if !self.route_cache.contains_key(cell) {
            let dec = self.compute_decision(cell);
            self.route_cache.insert(*cell, dec);
        }
        &self.route_cache[cell]
    }

    /// Record a live primary member of `cell` (tracks per-shard load and
    /// the cell's member set for migration/respawn re-feeds).
    pub fn note_insert(&mut self, cell: &CellKey, ext: u64) {
        let owner = self.ensure_cell(cell);
        let st = self.cells.get_mut(cell).expect("cell tracked above");
        let fresh = st.members.insert(ext);
        debug_assert!(fresh, "placement member {ext} inserted twice");
        self.load[owner as usize] += 1;
    }

    /// Remove a live member recorded by [`Self::note_insert`].
    pub fn note_remove(&mut self, cell: &CellKey, ext: u64) {
        let st = self
            .cells
            .get_mut(cell)
            .unwrap_or_else(|| panic!("member {ext} removed from untracked cell"));
        let was = st.members.remove(&ext);
        debug_assert!(was, "placement member {ext} removed twice");
        let owner = st.owner as usize;
        debug_assert!(self.load[owner] > 0, "shard load underflow");
        self.load[owner] -= 1;
    }

    /// Live members whose primary cell is `cell`, ascending (sorted so
    /// migration and respawn batches are deterministic regardless of hash
    /// map iteration order).
    pub fn members_sorted(&self, cell: &CellKey) -> Vec<u64> {
        let mut out: Vec<u64> = self
            .cells
            .get(cell)
            .map(|st| st.members.iter().copied().collect())
            .unwrap_or_default();
        out.sort_unstable();
        out
    }

    /// Member-bearing cells in ascending key order — the deterministic
    /// enumeration respawn re-feeds and tests walk.
    pub fn cells_sorted(&self) -> Vec<CellKey> {
        let mut out: Vec<CellKey> = self
            .cells
            .iter()
            .filter(|(_, st)| !st.members.is_empty())
            .map(|(k, _)| *k)
            .collect();
        out.sort_unstable();
        out
    }

    /// Plan a bounded migration from the hottest to the coldest shard.
    /// Empty when balanced, under `BlockHash` (nothing to reassign), at
    /// one shard, or when nothing fits the budget. Deterministic: the
    /// candidate order is (cold-affinity score desc, cell key asc), never
    /// map iteration order.
    pub fn plan_migration(&mut self, max_cells: usize) -> Vec<CellMove> {
        if self.shards < 2
            || max_cells == 0
            || self.policy != PlacementPolicy::CellGraph
        {
            return Vec::new();
        }
        let total: u64 = self.load.iter().sum();
        if total == 0 {
            return Vec::new();
        }
        let mean = total as f64 / self.shards as f64;
        let (mut hot, mut cold) = (0usize, 0usize);
        for s in 1..self.shards {
            if self.load[s] > self.load[hot] {
                hot = s;
            }
            if self.load[s] < self.load[cold] {
                cold = s;
            }
        }
        let trigger = mean * RESHARD_TRIGGER_SLACK + RESHARD_MIN_IMBALANCE as f64;
        if (self.load[hot] as f64) <= trigger {
            return Vec::new();
        }
        let imbalance = self.load[hot] - self.load[cold];
        // moving m points changes the hot−cold gap from D to |D − 2m|:
        // budgeting D/2 rebalances without overshooting into oscillation
        let budget = imbalance / 2;
        let mut cands: Vec<(i64, CellKey, usize)> = Vec::new();
        for (cell, st) in self.cells.iter() {
            if st.owner as usize != hot || st.members.is_empty() {
                continue;
            }
            let mut score = 0i64;
            for nb in neighbor_keys(cell, self.routing_dims, 1) {
                if let Some(n) = self.cells.get(&nb) {
                    if n.owner as usize == cold {
                        score += 1;
                    } else if n.owner as usize == hot {
                        score -= 1;
                    }
                }
            }
            cands.push((score, *cell, st.members.len()));
        }
        cands.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut out = Vec::new();
        let mut moved = 0u64;
        for (_, cell, m) in cands {
            if out.len() >= max_cells {
                break;
            }
            let m64 = m as u64;
            if moved + m64 <= budget {
                out.push(CellMove {
                    cell,
                    from: hot as u32,
                    to: cold as u32,
                    points: m,
                });
                moved += m64;
            } else if out.is_empty() && m64 < imbalance {
                // one oversized hot cell: |D − 2m| < D is still a strict
                // improvement, so take it alone rather than stall
                out.push(CellMove {
                    cell,
                    from: hot as u32,
                    to: cold as u32,
                    points: m,
                });
                break;
            }
        }
        out
    }

    /// Member-bearing cells whose routing decision may change under
    /// `moves`: each moved cell plus everything within `ghost_margin` of
    /// it. Sorted and deduplicated. Callers snapshot these cells'
    /// decisions *before* [`Self::apply_moves`] to compute the re-route
    /// delta.
    pub fn affected_cells(&self, moves: &[CellMove]) -> Vec<CellKey> {
        let mut out: Vec<CellKey> = Vec::new();
        let member_bearing = |cell: &CellKey| {
            self.cells.get(cell).is_some_and(|st| !st.members.is_empty())
        };
        for mv in moves {
            if member_bearing(&mv.cell) {
                out.push(mv.cell);
            }
            for nb in neighbor_keys(&mv.cell, self.routing_dims, self.ghost_margin)
            {
                if member_bearing(&nb) {
                    out.push(nb);
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Execute a plan: reassign owners, carry member counts between shard
    /// loads, keep the cut-edge count exact, bump the version and drop the
    /// route cache. The *point*-level re-route (delete/insert through the
    /// worker batches) is the engine's job.
    pub fn apply_moves(&mut self, moves: &[CellMove]) {
        if moves.is_empty() {
            return;
        }
        for mv in moves {
            let members = {
                let st = self.cells.get(&mv.cell).expect("moving unassigned cell");
                debug_assert_eq!(st.owner, mv.from, "stale migration plan");
                st.members.len() as u64
            };
            let mut cut = 0i64;
            for nb in neighbor_keys(&mv.cell, self.routing_dims, 1) {
                if let Some(n) = self.cells.get(&nb) {
                    if n.owner != mv.from {
                        cut -= 1;
                    }
                    if n.owner != mv.to {
                        cut += 1;
                    }
                }
            }
            self.cut_edges += cut;
            self.cells.get_mut(&mv.cell).expect("moving unassigned cell").owner =
                mv.to;
            self.load[mv.from as usize] -= members;
            self.load[mv.to as usize] += members;
        }
        self.version += 1;
        self.route_cache.clear();
    }

    /// Expected replica count per shard (members × decision fan-out) —
    /// the stitch-graph ownership-consistency oracle for tests.
    pub fn expected_replicas(&mut self) -> Vec<u64> {
        let cells: Vec<(CellKey, u64)> = self
            .cells
            .iter()
            .filter(|(_, st)| !st.members.is_empty())
            .map(|(k, st)| (*k, st.members.len() as u64))
            .collect();
        let mut out = vec![0u64; self.shards];
        for (cell, m) in cells {
            let dec = self.decide(&cell).clone();
            out[dec.primary] += m;
            for g in dec.ghosts {
                out[g] += m;
            }
        }
        out
    }

    /// Serialize the assignment (version, geometry, every cell's owner —
    /// members are rebuilt by recovery re-ingestion) for checkpoint spill.
    /// Little-endian, fixed layout; integrity is the checkpoint frame's
    /// CRC.
    pub fn export(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(29 + self.cells.len() * 20);
        out.extend_from_slice(&self.version.to_le_bytes());
        out.push(match self.policy {
            PlacementPolicy::BlockHash => 0u8,
            PlacementPolicy::CellGraph => 1u8,
        });
        out.extend_from_slice(&(self.shards as u32).to_le_bytes());
        out.extend_from_slice(&(self.routing_dims as u32).to_le_bytes());
        out.extend_from_slice(&(self.block_side as u32).to_le_bytes());
        out.extend_from_slice(&(self.ghost_margin as u32).to_le_bytes());
        let mut cells: Vec<(&CellKey, &CellState)> = self.cells.iter().collect();
        cells.sort_unstable_by_key(|(k, _)| **k);
        out.extend_from_slice(&(cells.len() as u32).to_le_bytes());
        for (k, st) in cells {
            for v in k.iter() {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out.extend_from_slice(&st.owner.to_le_bytes());
        }
        out
    }

    /// Restore an exported assignment into an *empty* map (recovery runs
    /// before re-ingestion). Returns `false` — leaving the map to evolve
    /// organically — if the blob is malformed or was exported under a
    /// different policy/geometry; recovery still converges then, it just
    /// reshards afresh.
    pub fn import(&mut self, blob: &[u8]) -> bool {
        if self.load.iter().any(|&l| l > 0) {
            return false;
        }
        let mut at = 0usize;
        let take = |at: &mut usize, n: usize| -> Option<&[u8]> {
            let s = blob.get(*at..*at + n)?;
            *at += n;
            Some(s)
        };
        let Some(v) = take(&mut at, 8) else { return false };
        let version = u64::from_le_bytes(v.try_into().unwrap());
        let Some(p) = take(&mut at, 1) else { return false };
        let policy = match p[0] {
            0 => PlacementPolicy::BlockHash,
            1 => PlacementPolicy::CellGraph,
            _ => return false,
        };
        let mut u32_at = |at: &mut usize| -> Option<u32> {
            take(at, 4).map(|s| u32::from_le_bytes(s.try_into().unwrap()))
        };
        let (Some(shards), Some(dims), Some(side), Some(margin)) = (
            u32_at(&mut at),
            u32_at(&mut at),
            u32_at(&mut at),
            u32_at(&mut at),
        ) else {
            return false;
        };
        if policy != self.policy
            || shards as usize != self.shards
            || dims as usize != self.routing_dims
            || side as i32 != self.block_side
            || margin as i32 != self.ghost_margin
        {
            return false;
        }
        let Some(n_cells) = u32_at(&mut at) else { return false };
        if blob.len() - at != n_cells as usize * 20 {
            return false;
        }
        let mut cells = FxHashMap::default();
        for _ in 0..n_cells {
            let mut key: CellKey = [0; MAX_ROUTING_DIMS];
            for v in key.iter_mut() {
                let s = take(&mut at, 4).unwrap();
                *v = i32::from_le_bytes(s.try_into().unwrap());
            }
            let Some(owner) = u32_at(&mut at) else { return false };
            if owner as usize >= self.shards {
                return false;
            }
            cells.insert(key, CellState { owner, members: FxHashSet::default() });
        }
        // recompute the cut count from scratch (each pair seen twice)
        let mut doubled = 0i64;
        for (cell, st) in cells.iter() {
            for nb in neighbor_keys(cell, self.routing_dims, 1) {
                if let Some(n) = cells.get(&nb) {
                    if n.owner != st.owner {
                        doubled += 1;
                    }
                }
            }
        }
        self.cells = cells;
        self.cut_edges = doubled / 2;
        self.version = version;
        self.route_cache.clear();
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(a: i32, b: i32) -> CellKey {
        [a, b, 0, 0]
    }

    fn map(policy: PlacementPolicy, shards: usize) -> PlacementMap {
        PlacementMap::new(policy, shards, 2, 8, 2)
    }

    #[test]
    fn block_hash_policy_matches_the_stateless_fallback() {
        let mut m = map(PlacementPolicy::BlockHash, 4);
        for a in -20..20 {
            for b in -20..20 {
                let c = key(a, b);
                let fb = m.fallback_owner(&c) as usize;
                let dec = m.decide(&c).clone();
                assert_eq!(dec.primary, fb, "owner diverged from hash at {c:?}");
                assert!(!dec.ghosts.contains(&dec.primary));
                let mut sorted = dec.ghosts.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted, dec.ghosts, "ghosts unsorted or duplicated");
            }
        }
        // stateless probing must not materialize cells
        assert_eq!(m.total_cells(), 0);
    }

    #[test]
    fn cell_graph_bootstrap_seeds_from_the_block_hash() {
        let mut m = map(PlacementPolicy::CellGraph, 4);
        // the very first cell of an empty, load-free map has no neighbor
        // votes; the block-hash tie-break must win
        let c = key(3, -5);
        let fb = m.fallback_owner(&c) as usize;
        assert_eq!(m.decide(&c).primary, fb);
    }

    #[test]
    fn cell_graph_keeps_neighborhoods_together() {
        let mut m = map(PlacementPolicy::CellGraph, 4);
        let anchor = m.decide(&key(0, 0)).primary;
        // deciding (0,0) force-assigned its whole margin neighborhood, so
        // nearby cells vote themselves onto the same shard while balanced
        for a in -1..=1 {
            for b in -1..=1 {
                assert_eq!(
                    m.decide(&key(a, b)).primary,
                    anchor,
                    "adjacent cell ({a},{b}) split off its neighborhood"
                );
            }
        }
    }

    #[test]
    fn load_cap_forces_spill_to_other_shards() {
        let mut m = map(PlacementPolicy::CellGraph, 2);
        // hammer one growing region; the cap must eventually route new
        // cells to the other shard even though affinity says otherwise
        let mut ext = 0u64;
        for a in 0..60 {
            let c = key(a, 0);
            let _ = m.decide(&c).clone();
            for _ in 0..10 {
                m.note_insert(&c, ext);
                ext += 1;
            }
        }
        assert!(
            m.load().iter().all(|&l| l > 0),
            "one shard absorbed everything: {:?}",
            m.load()
        );
    }

    #[test]
    fn decisions_are_sticky_and_version_pinned() {
        let mut m = map(PlacementPolicy::CellGraph, 3);
        let before = m.decide(&key(5, 5)).clone();
        // touching many other cells (shifting loads and votes) must not
        // change an issued decision
        let mut ext = 0u64;
        for a in -10..10 {
            let c = key(a, 9);
            let _ = m.decide(&c).clone();
            m.note_insert(&c, ext);
            ext += 1;
        }
        assert_eq!(*m.decide(&key(5, 5)), before);
        assert_eq!(m.version(), 0, "no migration ⇒ no version bump");
    }

    #[test]
    fn migration_rebalances_and_bumps_the_version() {
        let mut m = map(PlacementPolicy::CellGraph, 2);
        // all load on whatever shard owns the hot region
        let mut ext = 0u64;
        for a in 0..8 {
            for b in 0..8 {
                let c = key(a, b);
                let _ = m.decide(&c).clone();
                for _ in 0..8 {
                    m.note_insert(&c, ext);
                    ext += 1;
                }
            }
        }
        let before_max = *m.load().iter().max().unwrap();
        let mut guard = 0;
        loop {
            let plan = m.plan_migration(4);
            if plan.is_empty() {
                break;
            }
            for mv in &plan {
                assert_ne!(mv.from, mv.to);
            }
            m.apply_moves(&plan);
            guard += 1;
            assert!(guard < 200, "migration failed to converge");
        }
        let after_max = *m.load().iter().max().unwrap();
        assert!(
            after_max < before_max,
            "migration did not shed load ({before_max} → {after_max})"
        );
        assert!(m.version() > 0, "applied plans must bump the version");
        let total: u64 = m.load().iter().sum();
        assert_eq!(total, ext, "migration lost or duplicated load");
    }

    #[test]
    fn affected_cells_cover_the_ghost_margin() {
        let mut m = map(PlacementPolicy::CellGraph, 2);
        let c = key(4, 4);
        let _ = m.decide(&c).clone();
        m.note_insert(&c, 1);
        let nb = key(5, 5);
        let _ = m.decide(&nb).clone();
        m.note_insert(&nb, 2);
        let moves = [CellMove { cell: c, from: m.decide(&c).primary as u32, to: 1, points: 1 }];
        let affected = m.affected_cells(&moves);
        assert!(affected.contains(&c));
        assert!(
            affected.contains(&nb),
            "member-bearing margin neighbor missing from the affected set"
        );
    }

    #[test]
    fn export_import_reproduces_decisions_and_cut() {
        let mut m = map(PlacementPolicy::CellGraph, 4);
        let mut ext = 0u64;
        for a in -6..6 {
            for b in -6..6 {
                let c = key(a, b);
                let _ = m.decide(&c).clone();
                m.note_insert(&c, ext);
                ext += 1;
            }
        }
        let plan = m.plan_migration(3);
        m.apply_moves(&plan);
        let blob = m.export();

        let mut fresh = map(PlacementPolicy::CellGraph, 4);
        assert!(fresh.import(&blob), "matching-config import must succeed");
        assert_eq!(fresh.version(), m.version());
        assert_eq!(fresh.cut_edges(), m.cut_edges());
        for a in -6..6 {
            for b in -6..6 {
                let c = key(a, b);
                assert_eq!(fresh.decide(&c), m.decide(&c), "decision diverged at {c:?}");
            }
        }
        assert_eq!(fresh.export(), blob, "re-export must be byte-identical");

        // geometry mismatch is refused, not silently adopted
        let mut other = PlacementMap::new(PlacementPolicy::CellGraph, 4, 2, 4, 2);
        assert!(!other.import(&blob));
        let mut truncated = blob.clone();
        truncated.pop();
        let mut fresh2 = map(PlacementPolicy::CellGraph, 4);
        assert!(!fresh2.import(&truncated));
    }

    #[test]
    fn expected_replicas_count_members_times_fanout() {
        let mut m = map(PlacementPolicy::CellGraph, 3);
        let c = key(0, 0);
        let dec = m.decide(&c).clone();
        for e in 0..5 {
            m.note_insert(&c, e);
        }
        let reps = m.expected_replicas();
        assert_eq!(reps[dec.primary], 5);
        for g in dec.ghosts {
            assert_eq!(reps[g], 5);
        }
        assert_eq!(reps.iter().sum::<u64>() % 5, 0);
    }
}
