//! Stream drivers for the sharded engine — the S-way counterpart of
//! [`crate::coordinator::driver`], sharing its `StreamOp`/`TruthFn` types
//! so datasets, stream generators and the CLI feed either path.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::driver::to_stream_ops;
use crate::coordinator::{StreamOp, TruthFn};
use crate::data::stream::{self, Order};
use crate::data::Dataset;
use crate::dbscan::DbscanConfig;
use crate::metrics::ari_nmi;

use super::engine::{EngineOutcome, ShardedEngine};
use super::ShardConfig;

/// Per-published-snapshot progress report.
#[derive(Clone, Debug)]
pub struct ShardReport {
    /// index of the last batch folded into this snapshot
    pub seq: usize,
    /// ops in that batch (primary ops; ghosts excluded)
    pub ops: usize,
    pub live_points: usize,
    pub core_points: usize,
    pub clusters: usize,
    /// wall-clock seconds since stream start (routing + workers + stitch)
    pub wall_s: f64,
    pub ari: Option<f64>,
    pub nmi: Option<f64>,
}

/// Outcome of a sharded stream run.
pub struct ShardedRunOutcome {
    pub reports: Vec<ShardReport>,
    /// final global labels per live ext id (sorted by ext)
    pub final_labels: Vec<(u64, i64)>,
    pub engine: EngineOutcome,
    /// end-to-end wall time: first op routed → final snapshot published
    pub total_wall_s: f64,
}

impl ShardedRunOutcome {
    /// Primary updates applied per wall-clock second.
    pub fn updates_per_s(&self) -> f64 {
        let ops = self.engine.stats.inserts + self.engine.stats.deletes;
        if self.total_wall_s > 0.0 {
            ops as f64 / self.total_wall_s
        } else {
            0.0
        }
    }
}

/// Run batched stream ops through a [`ShardedEngine`], publishing a
/// snapshot (and a report) every `snapshot_every` batches plus once at the
/// end. `truth` adds ARI/NMI against ground-truth labels to each report.
pub fn run_sharded(
    cfg: ShardConfig,
    batches: Vec<Vec<StreamOp>>,
    snapshot_every: usize,
    truth: Option<&TruthFn>,
) -> Result<ShardedRunOutcome> {
    let mut engine = ShardedEngine::new(cfg);
    let mut reports = Vec::new();
    let t0 = Instant::now();
    let last = batches.len().saturating_sub(1);
    for (seq, ops) in batches.into_iter().enumerate() {
        let n_ops = ops.len();
        for op in ops {
            match op {
                StreamOp::Insert { ext, coords } => engine.insert(ext, &coords),
                StreamOp::Delete { ext } => engine.delete(ext),
            }
        }
        engine.flush();
        let snap_due =
            snapshot_every > 0 && (seq + 1) % snapshot_every == 0 && seq != last;
        if snap_due {
            let snap = engine.publish();
            // materialized on demand: the publish path itself no longer
            // builds the full label vector
            let labels = snap.labels();
            let (ari, nmi) = quality_vs_truth(&labels, truth);
            reports.push(ShardReport {
                seq,
                ops: n_ops,
                live_points: snap.live_points,
                core_points: snap.core_points,
                clusters: snap.clusters,
                wall_s: t0.elapsed().as_secs_f64(),
                ari,
                nmi,
            });
        }
    }
    // final barrier + snapshot (finish always publishes once more)
    let outcome = engine.finish();
    let total_wall_s = t0.elapsed().as_secs_f64();
    let snap = &outcome.snapshot;
    let final_labels = snap.labels();
    let (ari, nmi) = quality_vs_truth(&final_labels, truth);
    reports.push(ShardReport {
        seq: last,
        ops: 0,
        live_points: snap.live_points,
        core_points: snap.core_points,
        clusters: snap.clusters,
        wall_s: total_wall_s,
        ari,
        nmi,
    });
    Ok(ShardedRunOutcome {
        reports,
        final_labels,
        engine: outcome,
        total_wall_s,
    })
}

fn quality_vs_truth(
    labels: &[(u64, i64)],
    truth: Option<&TruthFn>,
) -> (Option<f64>, Option<f64>) {
    match truth {
        None => (None, None),
        Some(t) => {
            if labels.is_empty() {
                return (None, None);
            }
            let want: Vec<i64> = labels.iter().map(|&(e, _)| t(e)).collect();
            let pred: Vec<i64> = labels.iter().map(|&(_, l)| l).collect();
            let (a, n) = ari_nmi(&want, &pred);
            (Some(a), Some(n))
        }
    }
}

/// Stream a dataset (insert-only, or sliding-window when `window > 0`)
/// through the sharded engine — the S-way analogue of
/// [`crate::coordinator::driver::stream_dataset`].
#[allow(clippy::too_many_arguments)]
pub fn stream_dataset_sharded(
    ds: &Dataset,
    cfg: DbscanConfig,
    order: Order,
    batch: usize,
    window: usize,
    snapshot_every: usize,
    seed: u64,
    shards: usize,
) -> Result<ShardedRunOutcome> {
    let update_batches = if window > 0 {
        stream::sliding_window_stream(ds, order, batch, window, seed)
    } else {
        stream::insert_stream(ds, order, batch, seed)
    };
    let batches = to_stream_ops(ds, &update_batches);
    let scfg = ShardConfig::new(cfg, shards, seed);
    let labels = &ds.labels;
    let truth = move |e: u64| labels[e as usize];
    run_sharded(scfg, batches, snapshot_every, Some(&truth))
}

/// Final-state quality of a sharded run (ARI/NMI over live points).
pub fn final_quality_sharded(ds: &Dataset, out: &ShardedRunOutcome) -> (f64, f64) {
    let truth: Vec<i64> =
        out.final_labels.iter().map(|&(e, _)| ds.labels[e as usize]).collect();
    let pred: Vec<i64> = out.final_labels.iter().map(|&(_, l)| l).collect();
    ari_nmi(&truth, &pred)
}

/// One-line progress summary for CLI logs.
pub fn summarize_shard(r: &ShardReport) -> String {
    format!(
        "snap @batch {:>4}: live={:<7} cores={:<7} clusters={:<5} wall={:.2}s{}",
        r.seq,
        r.live_points,
        r.core_points,
        r.clusters,
        r.wall_s,
        match (r.ari, r.nmi) {
            (Some(a), Some(n)) => format!(" ARI={a:.3} NMI={n:.3}"),
            _ => String::new(),
        }
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{make_blobs, BlobsConfig};

    #[test]
    fn sharded_stream_end_to_end() {
        let ds = make_blobs(
            &BlobsConfig {
                n: 1200,
                dim: 4,
                clusters: 4,
                std: 0.3,
                center_box: 20.0,
                weights: vec![],
            },
            7,
        );
        let cfg = DbscanConfig { k: 8, t: 10, eps: 0.75, dim: 4, ..Default::default() };
        let out = stream_dataset_sharded(&ds, cfg, Order::Random, 300, 0, 2, 11, 4)
            .unwrap();
        assert_eq!(out.final_labels.len(), 1200);
        // snapshots at batch 1 (seq=1) and the final one
        assert_eq!(out.reports.len(), 2);
        assert!(out.reports.last().unwrap().ari.is_some());
        let (ari, nmi) = final_quality_sharded(&ds, &out);
        assert!(ari > 0.95, "ari {ari}");
        assert!(nmi > 0.9, "nmi {nmi}");
        assert!(out.updates_per_s() > 0.0);
    }

    #[test]
    fn sharded_sliding_window_keeps_window_size() {
        let ds = make_blobs(
            &BlobsConfig {
                n: 900,
                dim: 3,
                clusters: 3,
                std: 0.4,
                center_box: 15.0,
                weights: vec![],
            },
            5,
        );
        let cfg = DbscanConfig { k: 6, t: 8, eps: 0.75, dim: 3, ..Default::default() };
        let out = stream_dataset_sharded(&ds, cfg, Order::Random, 200, 300, 0, 3, 3)
            .unwrap();
        assert_eq!(out.final_labels.len(), 300);
        assert_eq!(out.engine.stats.deletes, 600);
    }
}
