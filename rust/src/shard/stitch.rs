//! Cross-shard cluster stitching: per-shard components → global labels.
//!
//! Nodes of the **stitch graph** are `(shard, local cluster root)` pairs;
//! two nodes are joined whenever the same external point is clustered in
//! both shards (a primary and its ghost replicas are the *same physical
//! point*, so the clusters containing them overlap and belong to one
//! global cluster). The connected components of that graph are exactly
//! the global clusters.
//!
//! Since this PR the graph is **persistent and incremental**
//! ([`Stitcher`]): it is maintained by the same HDT-leveled dynamic
//! connectivity the per-shard instances use ([`LeveledConn`] — dogfooded
//! here outside `DynamicDbscan`), which handles *un-unions* (cluster
//! splits on delete) in `O(log² n)` amortized per edge — the operation
//! the old per-snapshot union-find rebuild existed to sidestep. Workers
//! feed it [`ShardDelta`]s — only the `(ext, local-root)` assignments
//! that changed since their previous report — and every publish emits a
//! [`GlobalSnapshot`] whose label state is CoW-shared with its
//! predecessor ([`LabelMap`]), so publication costs `O(Δ·log²n)` in
//! changed points instead of the old `O(n log n)` full re-emission.
//!
//! Label identity: a stitch component carries a **stable** id from the
//! connectivity layer (merges keep the larger side's id, splits mint a
//! fresh id for the smaller side — [`Connectivity::comp_id`]), and each
//! component id maps to a global label minted once. Labels are therefore
//! stable across snapshots for points whose cluster did not change —
//! unlike the old dense per-snapshot renumbering.
//!
//! Live resharding (`shard::placement`) is invisible here too: a migrated
//! point leaves one shard's delta report (listed as no longer held) and
//! appears in another's, so the stitch graph nets the ownership change out
//! through the ordinary delta path — no migration-specific edge type.
//!
//! Soundness: a shard's component is an induced-subgraph component of the
//! global collision graph, hence a subset of one global cluster — every
//! stitch edge joins subsets of the same global cluster. Completeness
//! rests on the router's ghost margin: every collision edge, and the core
//! status of every replica on such an edge, is realized in at least one
//! shard, so walking a global cluster's edges walks a chain of stitch
//! edges (see `DESIGN.md` §Sharding).
//!
//! The from-scratch rebuild ([`stitch_full`]) is kept as the explicit
//! fallback path (`StitchMode::FullRebuild`) and as the differential
//! oracle for the delta path (`rust/tests/delta_snapshots.rs`); a
//! grep-lint confines its call sites (`rust/tests/lint.rs`).
//!
//! [`Connectivity::comp_id`]: crate::dbscan::Connectivity
//! [`LeveledConn`]: crate::dbscan::LeveledConn

use std::sync::Arc;

use rustc_hash::{FxHashMap, FxHashSet};

use crate::baselines::unionfind::UnionFind;
use crate::dbscan::{Connectivity, LeveledConn};
use crate::ett::skiplist::SkipSeq;
use crate::ett::VertexId;

use super::labels::LabelMap;
use super::worker::{ShardDelta, ShardSnapshot, SnapPoint};

/// An immutable, globally-consistent view of the sharded clustering.
/// Published behind an [`Arc`]; readers clone the `Arc` and never touch
/// the update path. Label state is CoW-shared with neighboring snapshots.
#[derive(Clone, Debug)]
pub struct GlobalSnapshot {
    pub seq: u64,
    /// `(label, size)` sorted by size descending (ties: label ascending);
    /// noise excluded
    pub cluster_sizes: Vec<(i64, usize)>,
    /// number of global clusters (excluding noise)
    pub clusters: usize,
    /// live primary points
    pub live_points: usize,
    /// live primary core points (exact: a primary's buckets are complete
    /// in its own shard)
    pub core_points: usize,
    /// per-shard live points, ghosts included (index = shard id)
    pub shard_live: Vec<usize>,
    label_of: LabelMap,
    /// CoW set of the live core primaries (LabelMap used as a set)
    core_of: LabelMap,
}

impl GlobalSnapshot {
    /// Snapshot of an empty engine (published before any ops).
    pub fn empty() -> Arc<GlobalSnapshot> {
        Arc::new(GlobalSnapshot {
            seq: 0,
            cluster_sizes: Vec::new(),
            clusters: 0,
            live_points: 0,
            core_points: 0,
            shard_live: Vec::new(),
            label_of: LabelMap::new(),
            core_of: LabelMap::new(),
        })
    }

    /// Global cluster of an external id: `None` when the point is not
    /// live, `Some(-1)` for noise, `Some(l ≥ 0)` for cluster `l`.
    pub fn cluster_of(&self, ext: u64) -> Option<i64> {
        self.label_of.get(ext)
    }

    /// `(ext, global label)` for every live primary point, sorted by ext —
    /// materialized on demand in `O(n log n)` (quality evaluation, tests);
    /// the publish path never builds it.
    pub fn labels(&self) -> Vec<(u64, i64)> {
        self.label_of.sorted()
    }

    /// The CoW label state backing this snapshot (cheap to clone — the
    /// serve façade wraps it in its `SnapshotView`).
    pub fn label_map(&self) -> &LabelMap {
        &self.label_of
    }

    /// Is `ext` a live core (primary) point as of this snapshot?
    pub fn is_core(&self, ext: u64) -> bool {
        self.core_of.get(ext).is_some()
    }

    /// The CoW core set backing [`Self::is_core`].
    pub fn core_map(&self) -> &LabelMap {
        &self.core_of
    }
}

/// One external point's label transition across a publish — the raw
/// feed the serve façade turns into merge/split/moved cluster events.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LabelChange {
    pub ext: u64,
    /// label before the publish (`None`: was not live)
    pub from: Option<i64>,
    /// label after the publish (`None`: deleted)
    pub to: Option<i64>,
}

// ---------------------------------------------------------------------
// incremental stitcher (the default read path)
// ---------------------------------------------------------------------

/// One replica's stitch-relevant state, as last reported by its shard.
#[derive(Clone, Copy, Debug)]
struct Rep {
    shard: u32,
    root: u64,
    clustered: bool,
    primary: bool,
    core: bool,
}

/// Per stitch-graph vertex: its `(shard, root)` key and the exts
/// clustered under that local root (needed to fan component-id changes
/// out to labels).
#[derive(Debug)]
struct NodeMeta {
    key: (u32, u64),
    members: FxHashSet<u64>,
}

/// Persistent cross-shard stitcher. Feed one [`ShardDelta`] per shard per
/// round through [`Stitcher::apply`]; each call returns the next
/// [`GlobalSnapshot`] in `O(Δ·log²n)` for Δ changed replicas.
pub struct Stitcher {
    /// dynamic connectivity over the stitch graph, with stable component
    /// ids (the HDT layer makes cluster *splits* as cheap as merges)
    conn: LeveledConn<SkipSeq>,
    node_of: FxHashMap<(u32, u64), VertexId>,
    /// vertex → metadata (None for retired vertex-id slots)
    nodes: Vec<Option<NodeMeta>>,
    /// ext → replica states (every shard currently holding it)
    exts: FxHashMap<u64, Vec<Rep>>,
    /// CoW label state shared with published snapshots
    labels: LabelMap,
    /// CoW core-primary set shared with published snapshots
    cores: LabelMap,
    /// stable component id → minted global label
    comp_label: FxHashMap<u64, i64>,
    /// label → clustered-ext count (noise excluded)
    sizes: FxHashMap<i64, usize>,
    next_label: i64,
    core_points: usize,
    shard_live: Vec<usize>,
    /// exts whose label must be recomputed this round
    label_dirty: FxHashSet<u64>,
    /// record label transitions into `changes` (serve `watch()` plumbing)
    log_changes: bool,
    changes: Vec<LabelChange>,
    rounds: u64,
    /// label-map chunk-sharing ratio measured at the last publish, just
    /// before the snapshot clone (the `cow_label_sharing` gauge)
    last_label_sharing: f64,
}

impl Stitcher {
    pub fn new(shards: usize, seed: u64) -> Self {
        let mut conn = LeveledConn::new(seed ^ 0x5717C4);
        conn.set_comp_tracking(true);
        Stitcher {
            conn,
            node_of: FxHashMap::default(),
            nodes: Vec::new(),
            exts: FxHashMap::default(),
            labels: LabelMap::new(),
            cores: LabelMap::new(),
            comp_label: FxHashMap::default(),
            sizes: FxHashMap::default(),
            next_label: 0,
            core_points: 0,
            shard_live: vec![0; shards],
            label_dirty: FxHashSet::default(),
            log_changes: false,
            changes: Vec::new(),
            rounds: 0,
            last_label_sharing: 0.0,
        }
    }

    /// `(vertices, edges)` of the persistent stitch graph — the
    /// `stitch_nodes` / `stitch_edges` structural gauges.
    pub fn graph_size(&self) -> (usize, usize) {
        (self.node_of.len(), self.conn.edge_count())
    }

    /// Fraction of label-map chunks still CoW-shared with previously
    /// published snapshots, as measured at the last [`Self::apply`]
    /// (1.0 = nothing was rewritten this round).
    pub fn last_label_sharing(&self) -> f64 {
        self.last_label_sharing
    }

    /// Toggle per-ext transition recording (drained by
    /// [`Self::drain_changes`]); off by default so an unwatched engine
    /// never grows the buffer.
    pub fn set_change_log(&mut self, on: bool) {
        self.log_changes = on;
        if !on {
            self.changes.clear();
        }
    }

    /// Take every transition recorded since the last drain.
    pub fn drain_changes(&mut self) -> Vec<LabelChange> {
        std::mem::take(&mut self.changes)
    }

    fn node_for(&mut self, key: (u32, u64)) -> VertexId {
        if let Some(&v) = self.node_of.get(&key) {
            return v;
        }
        let v = self.conn.add_vertex();
        let i = v as usize;
        if i >= self.nodes.len() {
            self.nodes.resize_with(i + 1, || None);
        }
        self.nodes[i] = Some(NodeMeta { key, members: FxHashSet::default() });
        self.node_of.insert(key, v);
        v
    }

    /// Retire a stitch node once its last member ext left (all its star
    /// edges are gone by then — each edge is refcounted by member exts).
    fn retire_if_empty(&mut self, v: VertexId) {
        let empty = self.nodes[v as usize]
            .as_ref()
            .map(|m| m.members.is_empty())
            .unwrap_or(false);
        if empty {
            let meta = self.nodes[v as usize].take().unwrap();
            self.node_of.remove(&meta.key);
            self.conn.remove_vertex(v);
        }
    }

    /// Does this replica set make the ext a live core primary?
    fn is_core_primary(reps: &[Rep]) -> bool {
        reps.iter().any(|r| r.primary && r.core)
    }

    /// Transform ext `e`'s stored replica set via `update`, keeping node
    /// membership, star edges and the core counter in sync. Star edges are
    /// desired-new-first so unchanged connectivity never transiently
    /// splits (which would cause spurious relabel work).
    fn rewire_ext(&mut self, e: u64, update: impl FnOnce(&mut Vec<Rep>)) {
        let old_reps: Vec<Rep> = self.exts.get(&e).cloned().unwrap_or_default();
        let old_nodes: Vec<VertexId> = old_reps
            .iter()
            .filter(|r| r.clustered)
            .map(|r| self.node_of[&(r.shard, r.root)])
            .collect();
        let had_core = Self::is_core_primary(&old_reps);

        let mut reps = old_reps;
        update(&mut reps);

        let mut new_nodes: Vec<VertexId> = Vec::with_capacity(reps.len());
        for r in reps.iter().filter(|r| r.clustered) {
            let key = (r.shard, r.root);
            new_nodes.push(self.node_for(key));
        }
        if Self::is_core_primary(&reps) != had_core {
            if had_core {
                self.core_points -= 1;
            } else {
                self.core_points += 1;
            }
        }
        // membership: drop old, then add new (shared nodes net out)
        for &v in &old_nodes {
            self.nodes[v as usize].as_mut().unwrap().members.remove(&e);
        }
        for &v in &new_nodes {
            self.nodes[v as usize].as_mut().unwrap().members.insert(e);
        }
        // star edges: desire new before undesiring old
        if let Some((&anchor, rest)) = new_nodes.split_first() {
            for &n in rest {
                self.conn.desire(anchor, n);
            }
        }
        if let Some((&anchor, rest)) = old_nodes.split_first() {
            for &n in rest {
                self.conn.undesire(anchor, n);
            }
        }
        for &v in &old_nodes {
            self.retire_if_empty(v);
        }
        if reps.is_empty() {
            self.exts.remove(&e);
        } else {
            self.exts.insert(e, reps);
        }
        self.label_dirty.insert(e);
    }

    /// Purge every replica last reported by shard `s` — the respawn path:
    /// a fresh worker re-reports its whole slice (its delta baseline is
    /// empty), so the dead worker's stale roots must not linger in the
    /// stitch graph where they would contradict the re-seeded assignment.
    /// The affected exts are left label-dirty; the next [`Self::apply`]
    /// (which also folds the fresh worker's full report) relabels them.
    pub fn drop_shard(&mut self, s: usize) {
        let sh = s as u32;
        let affected: Vec<u64> = self
            .exts
            .iter()
            .filter(|(_, reps)| reps.iter().any(|r| r.shard == sh))
            .map(|(&e, _)| e)
            .collect();
        for e in affected {
            self.rewire_ext(e, |reps| reps.retain(|r| r.shard != sh));
        }
        if s < self.shard_live.len() {
            self.shard_live[s] = 0;
        }
    }

    fn apply_upsert(&mut self, shard: u32, p: SnapPoint) {
        let rep = Rep {
            shard,
            root: p.root,
            clustered: p.clustered,
            primary: p.primary,
            core: p.core,
        };
        self.rewire_ext(p.ext, |reps| {
            match reps.iter().position(|r| r.shard == shard) {
                Some(i) => reps[i] = rep,
                None => reps.push(rep),
            }
        });
    }

    fn apply_removal(&mut self, shard: u32, ext: u64) {
        self.rewire_ext(ext, |reps| {
            if let Some(i) = reps.iter().position(|r| r.shard == shard) {
                reps.remove(i);
            }
        });
    }

    /// Recompute labels for every ext whose own replicas or whose stitch
    /// component changed this round — `O(relabeled)`.
    fn relabel(&mut self) {
        // component-id changes fan out to the member exts of every
        // changed node
        let nodes = &self.nodes;
        let dirty = &mut self.label_dirty;
        self.conn.drain_comp_changes(&mut |v| {
            if let Some(Some(meta)) = nodes.get(v as usize) {
                for &e in &meta.members {
                    dirty.insert(e);
                }
            }
        });
        let dirty: Vec<u64> = self.label_dirty.drain().collect();
        for ext in dirty {
            let (new_label, new_core): (Option<i64>, bool) = match self
                .exts
                .get(&ext)
            {
                None => (None, false), // deleted
                Some(reps) => {
                    let core = Self::is_core_primary(reps);
                    if !reps.iter().any(|r| r.primary) {
                        // ghost-only replica set: deletes fan out to every
                        // holder within the round, so this cannot survive
                        // a round — stay defensive like the old stitcher
                        (None, false)
                    } else if let Some(r) = reps.iter().find(|r| r.clustered) {
                        let v = self.node_of[&(r.shard, r.root)];
                        let comp = self.conn.comp_id(v);
                        let l = match self.comp_label.get(&comp) {
                            Some(&l) => l,
                            None => {
                                let l = self.next_label;
                                self.next_label += 1;
                                self.comp_label.insert(comp, l);
                                l
                            }
                        };
                        (Some(l), core)
                    } else {
                        (Some(-1), core)
                    }
                }
            };
            // the core set updates on every flip, label change or not
            if new_core {
                self.cores.set(ext, 1);
            } else {
                self.cores.remove(ext);
            }
            let old = self.labels.get(ext);
            if old == new_label {
                continue;
            }
            if self.log_changes {
                self.changes.push(LabelChange { ext, from: old, to: new_label });
            }
            if let Some(o) = old {
                if o >= 0 {
                    let c = self.sizes.get_mut(&o).expect("size of live label");
                    *c -= 1;
                    if *c == 0 {
                        self.sizes.remove(&o);
                    }
                }
            }
            match new_label {
                Some(l) => {
                    self.labels.set(ext, l);
                    if l >= 0 {
                        *self.sizes.entry(l).or_insert(0) += 1;
                    }
                }
                None => {
                    self.labels.remove(ext);
                }
            }
        }
    }

    /// Fold one round of per-shard deltas into the persistent state and
    /// emit the next snapshot.
    pub fn apply(&mut self, deltas: &[ShardDelta], seq: u64) -> GlobalSnapshot {
        self.rounds += 1;
        for d in deltas {
            if d.shard < self.shard_live.len() {
                self.shard_live[d.shard] = d.live;
            }
            let shard = d.shard as u32;
            for &ext in &d.removals {
                self.apply_removal(shard, ext);
            }
            for p in &d.upserts {
                self.apply_upsert(shard, *p);
            }
        }
        self.relabel();
        // housekeeping off the per-round critical Δ path: occasional
        // comp→label pruning (stale merged-away comps) and label-map
        // re-sharding (amortized)
        if self.rounds % 64 == 0 {
            let conn = &self.conn;
            let live: FxHashSet<u64> =
                self.node_of.values().map(|&v| conn.comp_id(v)).collect();
            self.comp_label.retain(|c, _| live.contains(c));
        }
        self.labels.maybe_grow();
        self.cores.maybe_grow();
        debug_assert_eq!(
            self.cores.len(),
            self.core_points,
            "core set out of sync with the core counter"
        );
        let mut cluster_sizes: Vec<(i64, usize)> =
            self.sizes.iter().map(|(&l, &s)| (l, s)).collect();
        cluster_sizes.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        // measured before the clone below re-shares everything: chunks
        // rewritten this round are the unshared ones
        self.last_label_sharing = self.labels.sharing_ratio();
        GlobalSnapshot {
            seq,
            clusters: self.sizes.len(),
            live_points: self.labels.len(),
            core_points: self.core_points,
            shard_live: self.shard_live.clone(),
            cluster_sizes,
            label_of: self.labels.clone(),
            core_of: self.cores.clone(),
        }
    }
}

// ---------------------------------------------------------------------
// from-scratch rebuild (explicit fallback + differential oracle)
// ---------------------------------------------------------------------

/// Aggregate per-ext state while scanning shard snapshots.
struct ExtAgg {
    primary_seen: bool,
    core: bool,
    /// union-find node of the first clustered replica seen
    node: Option<usize>,
}

/// Stitch one full-snapshot round (one [`ShardSnapshot`] per shard) into
/// a global label space from scratch — `O(n log n)` in live points. This
/// is the `StitchMode::FullRebuild` fallback and the oracle the delta
/// path is differentially tested against; the serving default is the
/// incremental [`Stitcher`].
pub fn stitch_full(mut snaps: Vec<ShardSnapshot>, seq: u64) -> GlobalSnapshot {
    snaps.sort_by_key(|s| s.shard);
    // 1) index the (shard, local root) nodes of all clustered replicas
    let mut node_ix: FxHashMap<(usize, u64), usize> = FxHashMap::default();
    for s in &snaps {
        for p in &s.points {
            if p.clustered {
                let next = node_ix.len();
                node_ix.entry((s.shard, p.root)).or_insert(next);
            }
        }
    }
    // 2) union the nodes of every replica set
    let mut uf = UnionFind::new(node_ix.len());
    let mut by_ext: FxHashMap<u64, ExtAgg> = FxHashMap::default();
    for s in &snaps {
        for p in &s.points {
            let agg = by_ext
                .entry(p.ext)
                .or_insert(ExtAgg { primary_seen: false, core: false, node: None });
            if p.primary {
                agg.primary_seen = true;
                if p.core {
                    agg.core = true;
                }
            }
            if p.clustered {
                let nd = node_ix[&(s.shard, p.root)];
                match agg.node {
                    None => agg.node = Some(nd),
                    Some(first) => {
                        uf.union(first, nd);
                    }
                }
            }
        }
    }
    // 3) dense global labels over primary points
    let mut root_label: FxHashMap<usize, i64> = FxHashMap::default();
    let mut sizes: FxHashMap<i64, usize> = FxHashMap::default();
    let mut label_of = LabelMap::new();
    let mut core_of = LabelMap::new();
    let mut core_points = 0usize;
    for (&ext, agg) in by_ext.iter() {
        if !agg.primary_seen {
            // ghost replica whose primary has been deleted mid-stream
            // cannot occur (deletes fan out to every holder), but stay
            // defensive: ghosts never carry labels.
            continue;
        }
        if agg.core {
            core_points += 1;
            core_of.set(ext, 1);
        }
        let label = match agg.node {
            None => -1,
            Some(nd) => {
                let root = uf.find(nd);
                let next = root_label.len() as i64;
                *root_label.entry(root).or_insert(next)
            }
        };
        if label >= 0 {
            *sizes.entry(label).or_insert(0) += 1;
        }
        label_of.set(ext, label);
    }
    let mut cluster_sizes: Vec<(i64, usize)> = sizes.into_iter().collect();
    cluster_sizes.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    GlobalSnapshot {
        seq,
        clusters: root_label.len(),
        live_points: label_of.len(),
        core_points,
        shard_live: snaps.iter().map(|s| s.live).collect(),
        cluster_sizes,
        label_of,
        core_of,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::worker::SnapPoint;

    fn pt(ext: u64, root: u64, clustered: bool, primary: bool, core: bool) -> SnapPoint {
        SnapPoint { ext, root, clustered, primary, core }
    }

    #[test]
    fn stitches_two_shards_via_shared_ghost() {
        // shard 0: cluster {1, 2, ghost 3}; shard 1: cluster {3, 4}
        let s0 = ShardSnapshot {
            shard: 0,
            seq: 7,
            points: vec![
                pt(1, 100, true, true, true),
                pt(2, 100, true, true, false),
                pt(3, 100, true, false, false),
            ],
            live: 3,
        };
        let s1 = ShardSnapshot {
            shard: 1,
            seq: 7,
            points: vec![pt(3, 200, true, true, true), pt(4, 200, true, true, false)],
            live: 2,
        };
        let g = stitch_full(vec![s1, s0], 7);
        assert_eq!(g.seq, 7);
        assert_eq!(g.live_points, 4); // exts 1,2,3,4 (3's ghost not counted)
        assert_eq!(g.clusters, 1);
        let l = g.cluster_of(1).unwrap();
        assert!(l >= 0);
        for e in [2u64, 3, 4] {
            assert_eq!(g.cluster_of(e), Some(l), "ext {e} not stitched");
        }
        assert_eq!(g.cluster_sizes, vec![(l, 4)]);
        assert_eq!(g.core_points, 2);
        assert_eq!(g.shard_live, vec![3, 2]);
    }

    #[test]
    fn unlinked_shards_stay_separate_and_noise_is_minus_one() {
        let s0 = ShardSnapshot {
            shard: 0,
            seq: 1,
            points: vec![pt(1, 10, true, true, true), pt(5, 11, false, true, false)],
            live: 2,
        };
        let s1 = ShardSnapshot {
            shard: 1,
            seq: 1,
            points: vec![pt(2, 20, true, true, true)],
            live: 1,
        };
        let g = stitch_full(vec![s0, s1], 1);
        assert_eq!(g.clusters, 2);
        assert_ne!(g.cluster_of(1), g.cluster_of(2));
        assert_eq!(g.cluster_of(5), Some(-1));
        assert_eq!(g.cluster_of(99), None);
        assert_eq!(g.live_points, 3);
    }

    #[test]
    fn ghost_clustered_where_primary_is_noise_still_labels() {
        // ext 1 primary-noise in shard 0 but clustered as a ghost in
        // shard 1 (wrongly-non-core near a boundary): label must come
        // from the ghost's cluster.
        let s0 = ShardSnapshot {
            shard: 0,
            seq: 2,
            points: vec![pt(1, 10, false, true, false)],
            live: 1,
        };
        let s1 = ShardSnapshot {
            shard: 1,
            seq: 2,
            points: vec![pt(1, 20, true, false, false), pt(2, 20, true, true, true)],
            live: 2,
        };
        let g = stitch_full(vec![s0, s1], 2);
        assert_eq!(g.clusters, 1);
        assert_eq!(g.cluster_of(1), g.cluster_of(2));
        assert!(g.cluster_of(1).unwrap() >= 0);
    }

    // -----------------------------------------------------------------
    // incremental stitcher
    // -----------------------------------------------------------------

    fn delta(
        shard: usize,
        seq: u64,
        upserts: Vec<SnapPoint>,
        removals: Vec<u64>,
        live: usize,
    ) -> ShardDelta {
        ShardDelta { shard, seq, upserts, removals, live }
    }

    #[test]
    fn incremental_stitch_merges_and_unmerges_across_shards() {
        let mut st = Stitcher::new(2, 1);
        // round 1: two clusters joined by shared ext 3
        let g = st.apply(
            &[
                delta(
                    0,
                    1,
                    vec![
                        pt(1, 100, true, true, true),
                        pt(2, 100, true, true, false),
                        pt(3, 100, true, false, false),
                    ],
                    vec![],
                    3,
                ),
                delta(
                    1,
                    1,
                    vec![pt(3, 200, true, true, true), pt(4, 200, true, true, false)],
                    vec![],
                    2,
                ),
            ],
            1,
        );
        assert_eq!(g.clusters, 1);
        assert_eq!(g.live_points, 4);
        assert_eq!(g.core_points, 2);
        let l = g.cluster_of(1).unwrap();
        for e in [2u64, 3, 4] {
            assert_eq!(g.cluster_of(e), Some(l), "ext {e} not stitched");
        }
        assert_eq!(g.cluster_sizes, vec![(l, 4)]);
        assert_eq!(g.shard_live, vec![3, 2]);

        // round 2: the bridge ext 3 is deleted everywhere — the global
        // cluster must split (the un-union the old rebuild sidestepped)
        let g2 = st.apply(
            &[
                delta(0, 2, vec![], vec![3], 2),
                delta(1, 2, vec![pt(4, 201, true, true, true)], vec![3], 1),
            ],
            2,
        );
        assert_eq!(g2.clusters, 2);
        assert_eq!(g2.live_points, 3);
        assert_eq!(g2.cluster_of(3), None);
        assert_ne!(g2.cluster_of(1), g2.cluster_of(4));
        // exts 1 and 2 stay co-clustered through the split
        assert_eq!(g2.cluster_of(1), g2.cluster_of(2));

        // round 3: re-bridge — one cluster again, labels stay stable for
        // the larger (surviving) side
        let g3 = st.apply(
            &[
                delta(0, 3, vec![pt(3, 100, true, false, false)], vec![], 3),
                delta(1, 3, vec![pt(3, 201, true, true, true)], vec![], 2),
            ],
            3,
        );
        assert_eq!(g3.clusters, 1);
        assert_eq!(g3.live_points, 4);
        assert_eq!(g3.cluster_of(1), g3.cluster_of(4));
    }

    #[test]
    fn incremental_matches_full_rebuild_on_the_same_state() {
        // identical rounds fed both ways must agree on the partition
        let ups0 = vec![
            pt(1, 10, true, true, true),
            pt(5, 11, false, true, false),
            pt(7, 10, true, true, false),
        ];
        let ups1 = vec![pt(2, 20, true, true, true), pt(7, 21, true, false, true)];
        let mut st = Stitcher::new(2, 3);
        let inc = st.apply(
            &[
                delta(0, 1, ups0.clone(), vec![], 3),
                delta(1, 1, ups1.clone(), vec![], 2),
            ],
            1,
        );
        let full = stitch_full(
            vec![
                ShardSnapshot { shard: 0, seq: 1, points: ups0, live: 3 },
                ShardSnapshot { shard: 1, seq: 1, points: ups1, live: 2 },
            ],
            1,
        );
        assert_eq!(inc.clusters, full.clusters);
        assert_eq!(inc.live_points, full.live_points);
        assert_eq!(inc.core_points, full.core_points);
        assert_eq!(inc.cluster_of(5), Some(-1));
        // same partition up to label renaming
        let a = inc.labels();
        let b = full.labels();
        assert_eq!(a.len(), b.len());
        let mut rename: FxHashMap<i64, i64> = FxHashMap::default();
        for (&(ea, la), &(eb, lb)) in a.iter().zip(b.iter()) {
            assert_eq!(ea, eb);
            if la < 0 || lb < 0 {
                assert_eq!(la < 0, lb < 0, "noise disagreement at ext {ea}");
                continue;
            }
            assert_eq!(*rename.entry(la).or_insert(lb), lb, "partition mismatch");
        }
    }

    #[test]
    fn label_state_is_cow_shared_between_snapshots() {
        let mut st = Stitcher::new(1, 9);
        let ups: Vec<SnapPoint> =
            (0..500).map(|e| pt(e, 5, true, true, true)).collect();
        let _g1 = st.apply(&[delta(0, 1, ups, vec![], 500)], 1);
        // one changed ext → at most a couple of label chunks deep-copied
        let g2 =
            st.apply(&[delta(0, 2, vec![pt(7, 5, false, true, false)], vec![], 500)], 2);
        assert_eq!(g2.cluster_of(7), Some(-1));
        assert_eq!(g2.live_points, 500);
    }
}
