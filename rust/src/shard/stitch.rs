//! Cross-shard cluster stitching: per-shard components → global labels.
//!
//! Nodes of the stitch graph are `(shard, local cluster root)` pairs; two
//! nodes are unioned whenever the same external point is clustered in both
//! shards (a primary and its ghost replicas are the *same physical point*,
//! so the clusters containing them overlap and belong to one global
//! cluster). A union-find over the nodes — rebuilt per snapshot, which
//! sidesteps the un-union problem deletes would otherwise pose — yields the
//! global partition; primary replicas then carry the labels.
//!
//! Soundness: a shard's component is an induced-subgraph component of the
//! global collision graph, hence a subset of one global cluster — every
//! union merges subsets of the same global cluster. Completeness rests on
//! the router's ghost margin: every collision edge, and the core status of
//! every replica on such an edge, is realized in at least one shard, so
//! walking a global cluster's edges walks a chain of unions (see
//! `DESIGN.md` §Sharding).

use std::sync::Arc;

use rustc_hash::FxHashMap;

use crate::baselines::unionfind::UnionFind;

use super::worker::ShardSnapshot;

/// An immutable, globally-consistent view of the sharded clustering.
/// Published behind an [`Arc`]; readers clone the `Arc` and never touch
/// the update path.
#[derive(Clone, Debug)]
pub struct GlobalSnapshot {
    pub seq: u64,
    /// `(ext, global label)` for every live primary point, sorted by ext;
    /// noise is `-1`, clusters are numbered `0..`
    pub labels: Vec<(u64, i64)>,
    /// `(label, size)` sorted by size descending (ties: label ascending);
    /// noise excluded
    pub cluster_sizes: Vec<(i64, usize)>,
    /// number of global clusters (excluding noise)
    pub clusters: usize,
    /// live primary points
    pub live_points: usize,
    /// live primary core points (exact: a primary's buckets are complete
    /// in its own shard)
    pub core_points: usize,
    /// per-shard live points, ghosts included (index = shard id)
    pub shard_live: Vec<usize>,
    label_of: FxHashMap<u64, i64>,
}

impl GlobalSnapshot {
    /// Snapshot of an empty engine (published before any ops).
    pub fn empty() -> Arc<GlobalSnapshot> {
        Arc::new(GlobalSnapshot {
            seq: 0,
            labels: Vec::new(),
            cluster_sizes: Vec::new(),
            clusters: 0,
            live_points: 0,
            core_points: 0,
            shard_live: Vec::new(),
            label_of: FxHashMap::default(),
        })
    }

    /// Global cluster of an external id: `None` when the point is not
    /// live, `Some(-1)` for noise, `Some(l ≥ 0)` for cluster `l`.
    pub fn cluster_of(&self, ext: u64) -> Option<i64> {
        self.label_of.get(&ext).copied()
    }
}

/// Aggregate per-ext state while scanning shard snapshots.
struct ExtAgg {
    primary_seen: bool,
    core: bool,
    /// union-find node of the first clustered replica seen
    node: Option<usize>,
}

/// Stitch one snapshot round (one `ShardSnapshot` per shard) into a
/// global label space.
pub fn stitch(mut snaps: Vec<ShardSnapshot>, seq: u64) -> GlobalSnapshot {
    snaps.sort_by_key(|s| s.shard);
    // 1) index the (shard, local root) nodes of all clustered replicas
    let mut node_ix: FxHashMap<(usize, u64), usize> = FxHashMap::default();
    for s in &snaps {
        for p in &s.points {
            if p.clustered {
                let next = node_ix.len();
                node_ix.entry((s.shard, p.root)).or_insert(next);
            }
        }
    }
    // 2) union the nodes of every replica set
    let mut uf = UnionFind::new(node_ix.len());
    let mut by_ext: FxHashMap<u64, ExtAgg> = FxHashMap::default();
    for s in &snaps {
        for p in &s.points {
            let agg = by_ext
                .entry(p.ext)
                .or_insert(ExtAgg { primary_seen: false, core: false, node: None });
            if p.primary {
                agg.primary_seen = true;
                if p.core {
                    agg.core = true;
                }
            }
            if p.clustered {
                let nd = node_ix[&(s.shard, p.root)];
                match agg.node {
                    None => agg.node = Some(nd),
                    Some(first) => {
                        uf.union(first, nd);
                    }
                }
            }
        }
    }
    // 3) dense global labels over primary points
    let mut root_label: FxHashMap<usize, i64> = FxHashMap::default();
    let mut sizes: FxHashMap<i64, usize> = FxHashMap::default();
    let mut labels: Vec<(u64, i64)> = Vec::new();
    let mut core_points = 0usize;
    for (&ext, agg) in by_ext.iter() {
        if !agg.primary_seen {
            // ghost replica whose primary has been deleted mid-stream
            // cannot occur (deletes fan out to every holder), but stay
            // defensive: ghosts never carry labels.
            continue;
        }
        if agg.core {
            core_points += 1;
        }
        let label = match agg.node {
            None => -1,
            Some(nd) => {
                let root = uf.find(nd);
                let next = root_label.len() as i64;
                *root_label.entry(root).or_insert(next)
            }
        };
        if label >= 0 {
            *sizes.entry(label).or_insert(0) += 1;
        }
        labels.push((ext, label));
    }
    labels.sort_unstable_by_key(|&(e, _)| e);
    let mut cluster_sizes: Vec<(i64, usize)> = sizes.into_iter().collect();
    cluster_sizes.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    let label_of: FxHashMap<u64, i64> = labels.iter().copied().collect();
    GlobalSnapshot {
        seq,
        clusters: root_label.len(),
        live_points: labels.len(),
        core_points,
        shard_live: snaps.iter().map(|s| s.live).collect(),
        labels,
        cluster_sizes,
        label_of,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::worker::SnapPoint;

    fn pt(ext: u64, root: u64, clustered: bool, primary: bool, core: bool) -> SnapPoint {
        SnapPoint { ext, root, clustered, primary, core }
    }

    #[test]
    fn stitches_two_shards_via_shared_ghost() {
        // shard 0: cluster {1, 2, ghost 3}; shard 1: cluster {3, 4}
        let s0 = ShardSnapshot {
            shard: 0,
            seq: 7,
            points: vec![
                pt(1, 100, true, true, true),
                pt(2, 100, true, true, false),
                pt(3, 100, true, false, false),
            ],
            live: 3,
        };
        let s1 = ShardSnapshot {
            shard: 1,
            seq: 7,
            points: vec![pt(3, 200, true, true, true), pt(4, 200, true, true, false)],
            live: 2,
        };
        let g = stitch(vec![s1, s0], 7);
        assert_eq!(g.seq, 7);
        assert_eq!(g.live_points, 4); // exts 1,2,3,4 (3's ghost not counted)
        assert_eq!(g.clusters, 1);
        let l = g.cluster_of(1).unwrap();
        assert!(l >= 0);
        for e in [2u64, 3, 4] {
            assert_eq!(g.cluster_of(e), Some(l), "ext {e} not stitched");
        }
        assert_eq!(g.cluster_sizes, vec![(l, 4)]);
        assert_eq!(g.core_points, 2);
        assert_eq!(g.shard_live, vec![3, 2]);
    }

    #[test]
    fn unlinked_shards_stay_separate_and_noise_is_minus_one() {
        let s0 = ShardSnapshot {
            shard: 0,
            seq: 1,
            points: vec![pt(1, 10, true, true, true), pt(5, 11, false, true, false)],
            live: 2,
        };
        let s1 = ShardSnapshot {
            shard: 1,
            seq: 1,
            points: vec![pt(2, 20, true, true, true)],
            live: 1,
        };
        let g = stitch(vec![s0, s1], 1);
        assert_eq!(g.clusters, 2);
        assert_ne!(g.cluster_of(1), g.cluster_of(2));
        assert_eq!(g.cluster_of(5), Some(-1));
        assert_eq!(g.cluster_of(99), None);
        assert_eq!(g.live_points, 3);
    }

    #[test]
    fn ghost_clustered_where_primary_is_noise_still_labels() {
        // ext 1 primary-noise in shard 0 but clustered as a ghost in
        // shard 1 (wrongly-non-core near a boundary): label must come
        // from the ghost's cluster.
        let s0 = ShardSnapshot {
            shard: 0,
            seq: 2,
            points: vec![pt(1, 10, false, true, false)],
            live: 1,
        };
        let s1 = ShardSnapshot {
            shard: 1,
            seq: 2,
            points: vec![pt(1, 20, true, false, false), pt(2, 20, true, true, true)],
            live: 2,
        };
        let g = stitch(vec![s0, s1], 2);
        assert_eq!(g.clusters, 1);
        assert_eq!(g.cluster_of(1), g.cluster_of(2));
        assert!(g.cluster_of(1).unwrap() >= 0);
    }
}
