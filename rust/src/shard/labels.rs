//! Copy-on-write label store backing delta-published [`GlobalSnapshot`]s.
//!
//! A [`LabelMap`] is the `ext → global label` relation, a thin wrapper
//! over the generic [`ChunkedCowMap`] (`Arc`-chunked hash maps keyed by a
//! 64-bit mix of the external id). Publishing a snapshot clones the chunk
//! *pointer* vector (cheap) and shares every chunk with the previous
//! snapshot; the stitcher then mutates its working copy through
//! `Arc::make_mut`, which deep-copies only the chunks that actually
//! receive changed labels. Publication cost is therefore `O(Δ · chunk)`
//! in changed points plus an `O(#chunks)` pointer clone — never `O(n)`
//! re-emission of the full label set the pre-delta stitcher paid.
//!
//! The chunk count doubles (a full `O(n)` re-shard, amortized over the
//! doublings) whenever mean occupancy exceeds `2 × TARGET_PER_CHUNK`, so
//! per-publish deep-copy work stays bounded as the live set grows.
//!
//! [`GlobalSnapshot`]: super::stitch::GlobalSnapshot

use crate::util::cow_map::ChunkedCowMap;

use super::stitch::LabelChange;

/// Target mean entries per chunk; growth triggers at twice this.
const TARGET_PER_CHUNK: usize = 48;

/// CoW `ext → label` map (−1 = noise; absent = not live). Cloning is
/// `O(#chunks)` pointer copies — that clone *is* the published snapshot's
/// label state.
#[derive(Clone, Debug)]
pub struct LabelMap {
    inner: ChunkedCowMap<i64>,
}

impl LabelMap {
    pub fn new() -> Self {
        LabelMap { inner: ChunkedCowMap::new(TARGET_PER_CHUNK) }
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    pub fn get(&self, ext: u64) -> Option<i64> {
        self.inner.get(ext).copied()
    }

    /// Insert or update; returns the previous label. Deep-copies the
    /// target chunk iff it is shared with a published snapshot.
    pub fn set(&mut self, ext: u64, label: i64) -> Option<i64> {
        self.inner.set(ext, label)
    }

    /// Remove; returns the previous label if present. Removing an absent
    /// key never deep-copies a snapshot-shared chunk.
    pub fn remove(&mut self, ext: u64) -> Option<i64> {
        self.inner.remove(ext)
    }

    /// Unordered iteration over `(ext, label)`.
    pub fn iter(&self) -> impl Iterator<Item = (u64, i64)> + '_ {
        self.inner.iter().map(|(e, &l)| (e, l))
    }

    /// Sorted `(ext, label)` pairs — `O(n log n)`; for quality evaluation
    /// and tests, never on the publish path.
    pub fn sorted(&self) -> Vec<(u64, i64)> {
        let mut v: Vec<(u64, i64)> = self.iter().collect();
        v.sort_unstable_by_key(|&(e, _)| e);
        v
    }

    /// Double the chunk count when mean occupancy exceeds the target —
    /// called by the stitcher between publishes (`O(n)` then, amortized
    /// `O(1)` per insertion over the doublings).
    pub fn maybe_grow(&mut self) {
        self.inner.maybe_grow();
    }

    /// How many chunks are *not* shared with any snapshot — i.e. were
    /// deep-copied since the last clone (introspection for the delta
    /// publication tests and benches).
    pub fn unshared_chunks(&self) -> usize {
        self.inner.unshared_chunks()
    }

    /// Current chunk count (power of two).
    pub fn num_chunks(&self) -> usize {
        self.inner.num_chunks()
    }

    /// Fraction of chunks still shared with an earlier snapshot — the
    /// `cow_label_sharing` gauge.
    pub fn sharing_ratio(&self) -> f64 {
        self.inner.sharing_ratio()
    }

    /// Per-ext transitions turning `prev` into `self` — the shared
    /// full-rebuild event diff (`O(n)` over both maps; the delta publish
    /// paths record transitions inline instead). Unordered.
    pub fn diff_from(&self, prev: &LabelMap) -> Vec<LabelChange> {
        let mut changes = Vec::new();
        for (ext, l) in self.iter() {
            let from = prev.get(ext);
            if from != Some(l) {
                changes.push(LabelChange { ext, from, to: Some(l) });
            }
        }
        for (ext, l) in prev.iter() {
            if self.get(ext).is_none() {
                changes.push(LabelChange { ext, from: Some(l), to: None });
            }
        }
        changes
    }
}

impl Default for LabelMap {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_remove_roundtrip() {
        let mut m = LabelMap::new();
        assert_eq!(m.get(7), None);
        assert_eq!(m.set(7, 3), None);
        assert_eq!(m.set(8, -1), None);
        assert_eq!(m.len(), 2);
        assert_eq!(m.get(7), Some(3));
        assert_eq!(m.set(7, 4), Some(3));
        assert_eq!(m.len(), 2);
        assert_eq!(m.remove(7), Some(4));
        assert_eq!(m.remove(7), None);
        assert_eq!(m.len(), 1);
        assert_eq!(m.sorted(), vec![(8, -1)]);
    }

    #[test]
    fn cow_shares_unchanged_chunks() {
        let mut m = LabelMap::new();
        for e in 0..2000u64 {
            m.set(e, (e % 5) as i64);
        }
        let snap = m.clone(); // "publish"
        assert!((m.sharing_ratio() - 1.0).abs() < 1e-12);
        // a single change must deep-copy exactly one chunk
        m.set(42, 99);
        assert_eq!(m.unshared_chunks(), 1, "one chunk deep-copied");
        assert!(m.sharing_ratio() < 1.0);
        // the snapshot still sees the old value
        assert_eq!(snap.get(42), Some(2));
        assert_eq!(m.get(42), Some(99));
    }

    #[test]
    fn growth_preserves_content() {
        let mut m = LabelMap::new();
        for e in 0..20_000u64 {
            m.set(e * 13, (e % 7) as i64 - 1);
        }
        m.maybe_grow();
        assert_eq!(m.len(), 20_000);
        for e in 0..20_000u64 {
            assert_eq!(m.get(e * 13), Some((e % 7) as i64 - 1));
        }
        assert_eq!(m.get(1), None);
    }
}
