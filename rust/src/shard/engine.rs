//! `ShardedEngine` — the front of the sharded serving path.
//!
//! Owns the router, the per-worker bounded channels (or, at `shards == 1`,
//! a single **inline** [`ShardCore`] — no router, no ghost replication, no
//! channel hop, so the one-shard configuration degenerates to the direct
//! path instead of paying pipeline tax), the persistent cross-shard
//! [`Stitcher`] and the latest published [`GlobalSnapshot`].
//!
//! Updates are routed and buffered per shard (`insert`/`delete`), shipped
//! in batches (`flush`), and made visible to readers by `publish`, which
//! barriers on every worker (a marker op rides the op channels) and folds
//! their **delta reports** into the persistent stitch graph — `O(Δ·log²n)`
//! in changed points per publish ([`StitchMode::Delta`], the default). The
//! from-scratch `O(n log n)` path survives as the explicit
//! [`StitchMode::FullRebuild`] fallback. Reads (`cluster_of`,
//! `cluster_sizes`, `snapshot`) only touch the immutable snapshot — they
//! never contend with the update path.
//!
//! With [`ReshardMode::Auto`], [`ShardedEngine::maybe_reshard`] runs
//! ahead of each publish: the placement map plans a bounded cell
//! migration when shard load skews, and the engine executes it through
//! the same pending batches ordinary updates ride — deletes at shards
//! losing a replica, re-inserts at shards gaining one — so migration
//! needs no new worker or stitcher machinery and never blocks readers.

use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use rustc_hash::FxHashMap;

use crate::dbscan::RepairStats;
use crate::obs::{Gauge, Metrics, PhaseClock, PublishStage, PublishTrace, Stopwatch};
use crate::util::stats::LatencyHisto;

use super::placement::{CellKey, PlacementPolicy, ReshardMode};
use super::router::{RouteDecision, Router};
use super::stitch::{stitch_full, GlobalSnapshot, LabelChange, Stitcher};
use super::worker::{
    run_worker, ShardBatch, ShardCore, ShardDelta, ShardOp, ShardReply,
    ShardSnapshot, WorkerReport,
};
use super::{ShardConfig, StitchMode};

/// A worker-channel fault, reported instead of the pre-PR-7 `expect`
/// panics: one dead or wedged shard degrades the engine (its write slice
/// goes stale, reads keep serving the last published snapshot) rather than
/// aborting the process. The engine quarantines the shard and respawns it
/// on request ([`ShardedEngine::respawn_shard`]); the serve façade does so
/// automatically at the next publish.
#[derive(Clone, Copy, Debug, PartialEq, Eq, thiserror::Error)]
pub enum EngineError {
    /// the worker's op channel closed — the thread panicked or exited
    #[error("shard {shard} worker is down (op channel closed)")]
    ShardDown { shard: u32 },
    /// the worker failed to answer a publish barrier in time — wedged,
    /// or so overloaded it should be treated as such
    #[error("shard {shard} missed the publish barrier after {ms} ms")]
    PublishTimeout { shard: u32, ms: u64 },
}

impl EngineError {
    /// The shard this fault quarantined.
    pub fn shard(&self) -> u32 {
        match *self {
            EngineError::ShardDown { shard } => shard,
            EngineError::PublishTimeout { shard, .. } => shard,
        }
    }
}

/// Quarantine `err.shard()` (idempotent) and log the fault.
fn mark_down(down: &mut Vec<u32>, faults: &mut Vec<EngineError>, err: EngineError) {
    if !down.contains(&err.shard()) {
        down.push(err.shard());
        down.sort_unstable();
        faults.push(err);
    }
}

/// Send one marker batch to every up shard and collect exactly one
/// matching reply per shard from the shared reply channel. Shards that
/// fail the send (channel closed) or miss the deadline are quarantined
/// into `down` instead of panicking; replies that don't satisfy `extract`
/// (stale barriers from a previously timed-out publish) are discarded.
fn barrier_collect<T>(
    txs: &[SyncSender<ShardBatch>],
    reply_rx: &Receiver<ShardReply>,
    down: &mut Vec<u32>,
    faults: &mut Vec<EngineError>,
    timeout_ms: u64,
    marker: impl Fn() -> ShardBatch,
    extract: impl Fn(ShardReply) -> Option<(usize, T)>,
) -> Vec<T> {
    let mut expect = vec![false; txs.len()];
    let mut outstanding = 0usize;
    for (s, tx) in txs.iter().enumerate() {
        if down.contains(&(s as u32)) {
            continue;
        }
        if tx.send(marker()).is_err() {
            mark_down(down, faults, EngineError::ShardDown { shard: s as u32 });
        } else {
            expect[s] = true;
            outstanding += 1;
        }
    }
    let mut out = Vec::with_capacity(outstanding);
    let timeout_ns = timeout_ms.saturating_mul(1_000_000);
    let sw = Stopwatch::start();
    while outstanding > 0 {
        let elapsed = sw.elapsed_ns();
        if elapsed >= timeout_ns {
            break;
        }
        match reply_rx.recv_timeout(Duration::from_nanos(timeout_ns - elapsed)) {
            Ok(reply) => {
                if let Some((s, val)) = extract(reply) {
                    if s < expect.len() && expect[s] {
                        expect[s] = false;
                        outstanding -= 1;
                        out.push(val);
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                break
            }
        }
    }
    for (s, waiting) in expect.iter().enumerate() {
        if *waiting {
            mark_down(
                down,
                faults,
                EngineError::PublishTimeout { shard: s as u32, ms: timeout_ms },
            );
        }
    }
    out
}

/// Engine-side op counters.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    /// primary inserts (= external points inserted)
    pub inserts: u64,
    /// ghost replicas created by boundary replication
    pub ghost_inserts: u64,
    /// external deletes (each fans out to every holding shard)
    pub deletes: u64,
    pub publishes: u64,
    /// cells migrated between shards by live resharding
    pub migrated_cells: u64,
    /// point replicas re-routed by live resharding (not counted in
    /// `inserts`/`deletes` — migration moves existing points)
    pub migrated_points: u64,
}

impl EngineStats {
    /// Ghost replicas per primary insert — the replication overhead the
    /// block geometry costs.
    pub fn ghost_ratio(&self) -> f64 {
        if self.inserts == 0 {
            0.0
        } else {
            self.ghost_inserts as f64 / self.inserts as f64
        }
    }
}

/// Everything a finished engine hands back.
pub struct EngineOutcome {
    /// final snapshot (published by `finish` after the last op)
    pub snapshot: Arc<GlobalSnapshot>,
    pub stats: EngineStats,
    /// per-shard reports, sorted by shard id
    pub worker_reports: Vec<WorkerReport>,
    /// add latency merged across shards (ghost inserts included)
    pub add_latency: LatencyHisto,
    pub delete_latency: LatencyHisto,
    /// end-to-end publish (snapshot-emission) latency
    pub publish_latency: LatencyHisto,
    /// per-stage breakdown of the final publish (route / delta-fold /
    /// stitch, plus the façade's snapshot-CoW / events share when driven
    /// through `serve`)
    pub last_trace: PublishTrace,
}

impl EngineOutcome {
    /// Connectivity-layer counters aggregated across shards (counters
    /// summed; `levels` is the deepest per-shard HDT hierarchy).
    pub fn conn_stats(&self) -> RepairStats {
        let mut total = RepairStats::default();
        for r in &self.worker_reports {
            total.nt_edges += r.conn.nt_edges;
            total.searches += r.conn.searches;
            total.replacements += r.conn.replacements;
            total.visited += r.conn.visited;
            total.pushes += r.conn.pushes;
            total.levels = total.levels.max(r.conn.levels);
        }
        total
    }
}

/// Where the per-shard structures live: worker threads behind bounded
/// channels (S ≥ 2), or one inline core (S == 1 — the `shards=1`
/// regression fix: no channel hop, no marker round-trip).
enum Backend {
    Inline(Box<ShardCore>),
    Threads {
        txs: Vec<SyncSender<ShardBatch>>,
        reply_rx: Receiver<ShardReply>,
        /// master clone handed to respawned workers; kept alive so the
        /// reply channel never disconnects while the engine lives
        reply_tx: Sender<ShardReply>,
        workers: Vec<JoinHandle<WorkerReport>>,
    },
}

/// S parallel `DynamicDbscan` instances behind a deterministic spatial
/// router, with incremental cross-shard cluster stitching. See the
/// [module docs](super) for the architecture.
pub struct ShardedEngine {
    cfg: ShardConfig,
    /// `None` at S == 1: everything is primary on shard 0, no ghosts
    router: Option<Router>,
    backend: Backend,
    /// ext → routing cell key; with the cell in hand, the shards holding
    /// a replica are always derivable from the placement map's current
    /// decision for that cell. Unused at S == 1.
    ext_cell: FxHashMap<u64, CellKey>,
    /// per-shard batch being assembled (ops + one shared flat coord buffer
    /// — no per-op coordinate allocation on the wire)
    pending: Vec<ShardBatch>,
    stitcher: Stitcher,
    snapshot: Arc<GlobalSnapshot>,
    next_seq: u64,
    stats: EngineStats,
    publish_latency: LatencyHisto,
    /// ops accepted since the last publish (lets `finish` skip a
    /// redundant stitch when the snapshot is already current)
    dirty: bool,
    /// ops accepted since the last publish — the freshness gap between
    /// the engine's write state and the published read snapshot
    pending_writes: u64,
    /// record per-ext label transitions at publish (the serve façade's
    /// `watch()` plumbing); off by default
    log_changes: bool,
    /// transitions of the latest publish, drained by `drain_label_changes`
    last_changes: Vec<LabelChange>,
    /// shared lock-free metrics registry (one per engine; every worker and
    /// DBSCAN core records into it)
    obs: Arc<Metrics>,
    /// per-stage breakdown of the most recent publish
    last_trace: PublishTrace,
    /// quarantined shard ids, ascending — their workers died or wedged;
    /// writes to them are dropped (respawn re-seeds from the placement
    /// map) and barriers skip them
    down: Vec<u32>,
    /// every fault observed so far, in detection order
    faults: Vec<EngineError>,
    /// publishes to skip before live resharding may plan again — set by
    /// `placement_restore` so the checkpoint-materialization publish of a
    /// durable reopen replays the spilled assignment instead of planning
    /// a divergent migration of its own
    reshard_holdoff: u32,
    /// cells moved by `maybe_reshard` since the last publish (consumed
    /// into the `migration_cells` gauge)
    migrated_this_publish: u64,
}

impl ShardedEngine {
    pub fn new(cfg: ShardConfig) -> Self {
        let shards = cfg.shards.max(1);
        // delta tracking only pays off when deltas are consumed
        let track = cfg.stitch == StitchMode::Delta;
        assert!(
            !track || cfg.conn.supports_comp_tracking(),
            "StitchMode::Delta needs stable component ids — only \
             ConnKind::Leveled provides them; use StitchMode::FullRebuild \
             for the flat ablation modes"
        );
        if let ReshardMode::Auto { max_cells_per_publish } = cfg.reshard {
            assert!(
                shards >= 2,
                "ReshardMode::Auto is meaningless at one shard"
            );
            assert!(
                max_cells_per_publish >= 1,
                "ReshardMode::Auto needs max_cells_per_publish >= 1"
            );
            assert!(
                cfg.placement == PlacementPolicy::CellGraph,
                "ReshardMode::Auto requires PlacementPolicy::CellGraph — \
                 BlockHash assignments are stateless and cannot migrate"
            );
        }
        let obs = Arc::new(Metrics::new(cfg.metrics));
        let (router, backend) = if shards == 1 {
            (
                None,
                Backend::Inline(Box::new(ShardCore::new(
                    0,
                    cfg.dbscan.clone(),
                    cfg.conn,
                    cfg.seed,
                    track,
                    Arc::clone(&obs),
                ))),
            )
        } else {
            let router = Router::new(&cfg);
            let (reply_tx, reply_rx) = channel::<ShardReply>();
            let mut txs = Vec::with_capacity(shards);
            let mut workers = Vec::with_capacity(shards);
            for shard in 0..shards {
                let (tx, rx) = sync_channel::<ShardBatch>(cfg.queue.max(1));
                let dcfg = cfg.dbscan.clone();
                let conn = cfg.conn;
                let seed = cfg.seed;
                let rtx = reply_tx.clone();
                let wobs = Arc::clone(&obs);
                let plan = cfg.faults.filter(|p| p.shard as usize == shard);
                let handle = std::thread::Builder::new()
                    .name(format!("shard-{shard}"))
                    .spawn(move || {
                        run_worker(shard, dcfg, conn, seed, track, wobs, rx, rtx, plan)
                    })
                    .expect("failed to spawn shard worker");
                txs.push(tx);
                workers.push(handle);
            }
            (Some(router), Backend::Threads { txs, reply_rx, reply_tx, workers })
        };
        ShardedEngine {
            router,
            backend,
            ext_cell: FxHashMap::default(),
            pending: (0..shards).map(|_| ShardBatch::new()).collect(),
            stitcher: Stitcher::new(shards, cfg.seed),
            snapshot: GlobalSnapshot::empty(),
            next_seq: 1,
            stats: EngineStats::default(),
            publish_latency: LatencyHisto::new(),
            dirty: false,
            pending_writes: 0,
            log_changes: false,
            last_changes: Vec::new(),
            obs,
            last_trace: PublishTrace::default(),
            down: Vec::new(),
            faults: Vec::new(),
            reshard_holdoff: 0,
            migrated_this_publish: 0,
            cfg,
        }
    }

    pub fn shards(&self) -> usize {
        self.pending.len()
    }

    pub fn config(&self) -> &ShardConfig {
        &self.cfg
    }

    // ------------------------------------------------------------------
    // update path
    // ------------------------------------------------------------------

    /// Route and buffer an insert. `ext` is the caller's stable external
    /// id; it must not be live already.
    pub fn insert(&mut self, ext: u64, coords: &[f32]) {
        assert_eq!(coords.len(), self.cfg.dbscan.dim, "bad dim in sharded insert");
        self.stats.inserts += 1;
        self.dirty = true;
        self.pending_writes += 1;
        let Some(router) = &mut self.router else {
            // S == 1: no routing, no ghosts, no placement bookkeeping
            // (the core's own ext map enforces id uniqueness)
            self.pending[0].push_insert(ext, coords, true);
            return;
        };
        let cell = router.cell_key(coords);
        let prev = self.ext_cell.insert(ext, cell);
        assert!(prev.is_none(), "sharded insert of duplicate ext id {ext}");
        let decision = router.decide(&cell);
        let mut ghosts = 0u64;
        self.pending[decision.primary].push_insert(ext, coords, true);
        for &g in &decision.ghosts {
            self.pending[g].push_insert(ext, coords, false);
            ghosts += 1;
        }
        router.note_insert(&cell, ext);
        self.stats.ghost_inserts += ghosts;
    }

    /// Buffer a delete for every shard holding a replica of `ext` (the
    /// placement map's current decision for its cell).
    pub fn delete(&mut self, ext: u64) {
        self.stats.deletes += 1;
        self.dirty = true;
        self.pending_writes += 1;
        if self.router.is_none() {
            self.pending[0].push_delete(ext);
            return;
        }
        let cell = self
            .ext_cell
            .remove(&ext)
            .unwrap_or_else(|| panic!("sharded delete of unknown ext id {ext}"));
        let router = self.router.as_mut().expect("routed backend");
        let decision = router.decide(&cell);
        self.pending[decision.primary].push_delete(ext);
        for &g in &decision.ghosts {
            self.pending[g].push_delete(ext);
        }
        router.note_remove(&cell, ext);
    }

    /// Ship buffered ops to the workers. Threads: blocks only when a
    /// worker's bounded queue is full (backpressure). Inline: applies the
    /// batch directly.
    ///
    /// A failed send quarantines the shard and **drops** the batch: the
    /// placement map and the façade's coordinate store already reflect
    /// those ops, so [`Self::respawn_shard`] rebuilds the shard's slice
    /// from them exactly — buffering the batch instead would double-apply
    /// it after the re-seed.
    pub fn flush(&mut self) {
        match &mut self.backend {
            Backend::Inline(core) => {
                if !self.pending[0].is_empty() {
                    let batch = std::mem::take(&mut self.pending[0]);
                    core.apply(&batch, &mut |_| {});
                }
            }
            Backend::Threads { txs, .. } => {
                for (s, tx) in txs.iter().enumerate() {
                    if self.pending[s].is_empty() {
                        continue;
                    }
                    let batch = std::mem::take(&mut self.pending[s]);
                    if self.down.contains(&(s as u32)) {
                        continue; // dropped: the respawn re-seed covers it
                    }
                    if tx.send(batch).is_err() {
                        mark_down(
                            &mut self.down,
                            &mut self.faults,
                            EngineError::ShardDown { shard: s as u32 },
                        );
                    }
                }
            }
        }
    }

    /// Live resharding step, called by the serve façade right before each
    /// publish. Asks the placement map for a bounded migration plan (empty
    /// unless the hottest shard's load trips the trigger) and executes it
    /// through the ordinary worker batches: for every member of every
    /// affected cell, the decision delta between the old and new map
    /// version turns into deletes at shards that lose the replica, inserts
    /// (coords re-fetched via `coords_of`, exactly the respawn contract)
    /// at shards that gain it, and a delete+insert pair where only the
    /// primary/ghost role flips (workers apply batch ops in order, so the
    /// pair is a replace). Readers keep serving the last published
    /// snapshot throughout; the moved points travel with the publish that
    /// follows. Returns the number of cells migrated.
    ///
    /// No-op when resharding is off, at S == 1, while degraded (heal
    /// first: respawn re-feeds assume a stable assignment), or during a
    /// restore holdoff publish.
    pub fn maybe_reshard(
        &mut self,
        mut coords_of: impl FnMut(u64, &mut Vec<f32>) -> bool,
    ) -> usize {
        let ReshardMode::Auto { max_cells_per_publish } = self.cfg.reshard else {
            return 0;
        };
        if self.router.is_none() || self.is_degraded() {
            return 0;
        }
        if self.reshard_holdoff > 0 {
            self.reshard_holdoff -= 1;
            return 0;
        }
        let router = self.router.as_mut().expect("routed backend");
        let plan = router.placement_mut().plan_migration(max_cells_per_publish);
        if plan.is_empty() {
            return 0;
        }
        let affected = router.placement().affected_cells(&plan);
        // snapshot the old decisions before the version bump voids them
        let old: Vec<RouteDecision> =
            affected.iter().map(|c| router.decide(c).clone()).collect();
        router.placement_mut().apply_moves(&plan);
        let mut migrated_points = 0u64;
        let mut coords: Vec<f32> = Vec::new();
        for (cell, before) in affected.iter().zip(&old) {
            let after = router.decide(cell).clone();
            if after == *before {
                continue;
            }
            for ext in router.placement().members_sorted(cell) {
                // fetch coordinates before buffering anything: if the
                // store has no row for this ext, skip it entirely so the
                // losing shards keep their replica instead of dropping it
                // with no re-insert at the gaining ones
                coords.clear();
                let have_coords = coords_of(ext, &mut coords);
                debug_assert!(have_coords, "live ext {ext} has no coordinate row");
                if !have_coords {
                    continue;
                }
                let mut touched = false;
                // shards losing their replica — or keeping it with a
                // flipped primary/ghost role (delete now, re-insert below)
                for &s in std::iter::once(&before.primary).chain(&before.ghosts) {
                    let keeps = s == after.primary || after.ghosts.contains(&s);
                    let flip =
                        keeps && (s == before.primary) != (s == after.primary);
                    if !keeps || flip {
                        self.pending[s].push_delete(ext);
                        touched = true;
                    }
                }
                // shards gaining a replica (or completing a role flip)
                for &s in std::iter::once(&after.primary).chain(&after.ghosts) {
                    let had = s == before.primary || before.ghosts.contains(&s);
                    let flip =
                        had && (s == before.primary) != (s == after.primary);
                    if had && !flip {
                        continue;
                    }
                    self.pending[s].push_insert(ext, &coords, s == after.primary);
                    touched = true;
                }
                if touched {
                    migrated_points += 1;
                }
            }
        }
        self.dirty = true;
        self.stats.migrated_cells += plan.len() as u64;
        self.stats.migrated_points += migrated_points;
        self.migrated_this_publish += plan.len() as u64;
        plan.len()
    }

    /// Flush and barrier on every worker **without** publishing: the
    /// delta-tracking state is left untouched. Lets callers (benches)
    /// separate op-application cost from snapshot-publication cost.
    pub fn quiesce(&mut self) {
        self.flush();
        let seq = self.next_seq;
        self.next_seq += 1;
        if let Backend::Threads { txs, reply_rx, .. } = &mut self.backend {
            let _acks = barrier_collect(
                txs,
                reply_rx,
                &mut self.down,
                &mut self.faults,
                self.cfg.publish_timeout_ms,
                || ShardBatch::sync(seq),
                |reply| match reply {
                    ShardReply::Sync { shard, seq: s } if s == seq => {
                        Some((shard, ()))
                    }
                    _ => None, // stale barrier from a timed-out publish
                },
            );
        }
    }

    /// Collect one delta report per up shard (barrier via the op
    /// channels). Quarantined shards contribute nothing — their last
    /// folded state stays in the stitch graph until a respawn re-seeds
    /// them.
    fn collect_deltas(&mut self, seq: u64) -> Vec<ShardDelta> {
        match &mut self.backend {
            Backend::Inline(core) => vec![core.delta(seq)],
            Backend::Threads { txs, reply_rx, .. } => barrier_collect(
                txs,
                reply_rx,
                &mut self.down,
                &mut self.faults,
                self.cfg.publish_timeout_ms,
                || ShardBatch::delta(seq),
                |reply| match reply {
                    ShardReply::Delta(d) if d.seq == seq => {
                        Some((d.shard, d))
                    }
                    _ => None,
                },
            ),
        }
    }

    /// Collect one **full** state dump per shard — the `O(n)` path. Kept
    /// for the `FullRebuild` fallback mode and as the oracle feed of the
    /// delta-vs-rebuild differential tests; the serving path never calls
    /// it in `Delta` mode.
    pub fn full_dump(&mut self) -> Vec<ShardSnapshot> {
        self.flush();
        let seq = self.next_seq;
        self.next_seq += 1;
        match &mut self.backend {
            Backend::Inline(core) => vec![core.full_snapshot(seq)],
            Backend::Threads { txs, reply_rx, .. } => barrier_collect(
                txs,
                reply_rx,
                &mut self.down,
                &mut self.faults,
                self.cfg.publish_timeout_ms,
                || ShardBatch::snapshot(seq),
                |reply| match reply {
                    ShardReply::Full(s) if s.seq == seq => Some((s.shard, s)),
                    _ => None,
                },
            ),
        }
    }

    /// Flush, barrier on all workers, fold their reports into the global
    /// clustering and publish the result as the new immutable snapshot.
    /// `Delta` mode (default): `O(Δ·log²n)` in changed points.
    /// `FullRebuild` mode: the old `O(n log n)` from-scratch stitch.
    pub fn publish(&mut self) -> Arc<GlobalSnapshot> {
        let t0 = Stopwatch::start();
        let mut clk = PhaseClock::maybe(self.obs.enabled());
        let mut trace = PublishTrace::default();
        self.flush();
        if let Some(c) = clk.as_mut() {
            trace.record(PublishStage::Route, c.lap());
        }
        // workers re-accumulate the structural gauges while handling the
        // barrier marker below; FIFO order makes the post-barrier read a
        // consistent whole-fleet sample
        self.obs.zero_structural();
        let snap = match self.cfg.stitch {
            StitchMode::Delta => {
                let seq = self.next_seq;
                self.next_seq += 1;
                let deltas = self.collect_deltas(seq);
                if let Some(c) = clk.as_mut() {
                    trace.record(PublishStage::DeltaFold, c.lap());
                }
                let snap = Arc::new(self.stitcher.apply(&deltas, seq));
                if self.log_changes {
                    self.last_changes = self.stitcher.drain_changes();
                }
                snap
            }
            StitchMode::FullRebuild => {
                let snaps = self.full_dump();
                if let Some(c) = clk.as_mut() {
                    trace.record(PublishStage::DeltaFold, c.lap());
                }
                if snaps.is_empty() {
                    // every shard quarantined: keep serving the last
                    // published snapshot instead of an empty rebuild
                    let snap = Arc::clone(&self.snapshot);
                    self.stats.publishes += 1;
                    return snap;
                }
                let seq = snaps[0].seq;
                let snap = Arc::new(stitch_full(snaps, seq));
                if self.log_changes {
                    // no per-ext plumbing on this path: diff the label
                    // maps — O(n), same order as the rebuild itself
                    self.last_changes =
                        snap.label_map().diff_from(self.snapshot.label_map());
                }
                snap
            }
        };
        if let Some(c) = clk.as_mut() {
            trace.record(PublishStage::Stitch, c.lap());
        }
        let total_ns = t0.elapsed_ns();
        self.publish_latency.record(total_ns);
        if self.obs.enabled() {
            trace.set_total(total_ns);
            self.obs.record_publish(total_ns);
            for stage in
                [PublishStage::Route, PublishStage::DeltaFold, PublishStage::Stitch]
            {
                self.obs.record_publish_stage(stage, trace.get(stage));
            }
            self.obs.set_gauge(Gauge::LivePoints, snap.live_points as u64);
            self.obs.set_ratio(Gauge::GhostRatio, self.stats.ghost_ratio());
            let (nodes, edges) = self.stitcher.graph_size();
            self.obs.set_gauge(Gauge::StitchNodes, nodes as u64);
            self.obs.set_gauge(Gauge::StitchEdges, edges as u64);
            self.obs
                .set_ratio(Gauge::CowLabelSharing, self.stitcher.last_label_sharing());
            if let Some(router) = &self.router {
                let p = router.placement();
                self.obs.set_gauge(Gauge::CutEdges, p.cut_edges());
                self.obs.set_shard_loads(p.load());
            }
            self.obs.set_gauge(Gauge::MigrationCells, self.migrated_this_publish);
            self.last_trace = trace;
        }
        self.migrated_this_publish = 0;
        self.snapshot = Arc::clone(&snap);
        self.stats.publishes += 1;
        self.dirty = false;
        self.pending_writes = 0;
        snap
    }

    // ------------------------------------------------------------------
    // fault tolerance
    // ------------------------------------------------------------------

    /// chunk size of the respawn re-seed batches — bounds peak wire
    /// memory without serializing the whole shard slice at once
    const RESEED_CHUNK: usize = 4096;

    /// Quarantined shard ids, ascending. Non-empty means the engine is
    /// degraded: those shards' slices are stale in the published snapshot
    /// until [`Self::respawn_shard`] heals them. Reads keep serving.
    pub fn down_shards(&self) -> &[u32] {
        &self.down
    }

    pub fn is_degraded(&self) -> bool {
        !self.down.is_empty()
    }

    /// Every fault observed so far, in detection order.
    pub fn fault_log(&self) -> &[EngineError] {
        &self.faults
    }

    /// Replace a quarantined shard's worker with a fresh one and rebuild
    /// its slice through the same cell-granular path migration uses: walk
    /// the placement map's member-bearing cells in deterministic key
    /// order, and for every cell whose routing decision involves the
    /// healing shard, re-feed its members as whole cell-neighborhood
    /// batches — `coords_of(ext, buf)` appends the point's coordinate row
    /// (the serve façade keeps every live row; return false for unknown
    /// exts). The dead worker's stale roots are purged from the stitch
    /// graph, and the fresh core's empty delta baseline makes its next
    /// report ship the full assignment — the next publish heals the
    /// global clustering without a full rebuild. No-op for up shards and
    /// the inline backend.
    pub fn respawn_shard(
        &mut self,
        shard: u32,
        mut coords_of: impl FnMut(u64, &mut Vec<f32>) -> bool,
    ) -> Result<(), EngineError> {
        if !self.down.contains(&shard) {
            return Ok(());
        }
        let track = self.cfg.stitch == StitchMode::Delta;
        let Backend::Threads { txs, workers, reply_tx, .. } = &mut self.backend
        else {
            return Ok(());
        };
        let s = shard as usize;
        // ops buffered while down are already reflected in the placement
        // map and the façade's coordinate store — the re-seed below covers
        // them; shipping the buffered batch too would double-apply
        self.pending[s] = ShardBatch::new();
        let (tx, rx) = sync_channel::<ShardBatch>(self.cfg.queue.max(1));
        let dcfg = self.cfg.dbscan.clone();
        let conn = self.cfg.conn;
        let seed = self.cfg.seed;
        let rtx = reply_tx.clone();
        let wobs = Arc::clone(&self.obs);
        let handle = std::thread::Builder::new()
            .name(format!("shard-{shard}"))
            .spawn(move || {
                run_worker(s, dcfg, conn, seed, track, wobs, rx, rtx, None)
            })
            .map_err(|_| EngineError::ShardDown { shard })?;
        txs[s] = tx; // old sender dropped: a still-live old worker exits
        workers[s] = handle; // old handle dropped: detached
        self.stitcher.drop_shard(s);
        let router = self.router.as_mut().expect("threads backend has a router");
        let mut batch = ShardBatch::new();
        for cell in router.placement().cells_sorted() {
            let decision = router.decide(&cell).clone();
            let primary = decision.primary == s;
            if !primary && !decision.ghosts.contains(&s) {
                continue;
            }
            for ext in router.placement().members_sorted(&cell) {
                if coords_of(ext, &mut batch.coords) {
                    batch.ops.push(ShardOp::Insert { ext, primary });
                }
                if batch.ops.len() >= Self::RESEED_CHUNK {
                    let full = std::mem::take(&mut batch);
                    if txs[s].send(full).is_err() {
                        return Err(EngineError::ShardDown { shard });
                    }
                }
            }
        }
        if !batch.is_empty() && txs[s].send(batch).is_err() {
            return Err(EngineError::ShardDown { shard });
        }
        self.down.retain(|&d| d != shard);
        self.dirty = true; // the heal must reach the next snapshot
        Ok(())
    }

    // ------------------------------------------------------------------
    // placement / resharding surface
    // ------------------------------------------------------------------

    /// Routing epoch of the placement map: bumped once per applied
    /// migration plan. 0 at S == 1 (no map) and before any migration.
    pub fn placement_version(&self) -> u64 {
        self.router.as_ref().map_or(0, |r| r.placement().version())
    }

    /// Serialized cell→shard assignment for checkpoint spill (`None` at
    /// S == 1 — nothing to reshard).
    pub fn placement_blob(&self) -> Option<Vec<u8>> {
        self.router.as_ref().map(|r| r.placement().export())
    }

    /// Restore a spilled assignment into the (still empty) placement map
    /// before recovery re-ingests the checkpointed points, so a durable
    /// reopen reshards to exactly the assignment it spilled. Mismatched
    /// or malformed blobs are ignored — the map then evolves afresh,
    /// which is still correct, just a different (valid) assignment. Sets
    /// a one-publish reshard holdoff so the checkpoint-materialization
    /// publish replays rather than re-plans.
    pub fn placement_restore(&mut self, blob: &[u8]) {
        if let Some(r) = self.router.as_mut() {
            if r.placement_mut().import(blob) {
                self.reshard_holdoff = 1;
            }
        }
    }

    /// Expected replica count per shard from the placement map (members ×
    /// routing fan-out) — the oracle the ownership-consistency tests
    /// compare `GlobalSnapshot::shard_live` against after a quiesced
    /// publish. `None` at S == 1.
    pub fn expected_shard_replicas(&mut self) -> Option<Vec<u64>> {
        self.router.as_mut().map(|r| r.placement_mut().expected_replicas())
    }

    // ------------------------------------------------------------------
    // read path (snapshot-backed; never blocks on the workers)
    // ------------------------------------------------------------------

    /// Latest published snapshot. Cheap (`Arc` clone); hand it to reader
    /// threads.
    pub fn snapshot(&self) -> Arc<GlobalSnapshot> {
        Arc::clone(&self.snapshot)
    }

    /// Global cluster of `ext` **as of the latest published snapshot**
    /// (`None`: not live, `Some(-1)`: noise).
    ///
    /// Freshness: this answers from the last [`Self::publish`] even when
    /// unflushed or unpublished writes are pending — a point inserted
    /// after that publish reads as `None` here. Check
    /// [`Self::pending_writes`] (surfaced as
    /// `serve::SnapshotView::pending_writes` on the façade) to reason
    /// about the gap, and call `publish` for read-your-writes.
    pub fn cluster_of(&self, ext: u64) -> Option<i64> {
        self.snapshot.cluster_of(ext)
    }

    /// Ops accepted since the last publish — the number of writes the
    /// snapshot-backed reads do **not** yet reflect.
    pub fn pending_writes(&self) -> u64 {
        self.pending_writes
    }

    /// Global `(label, size)` pairs, largest first, as of the latest
    /// snapshot.
    pub fn cluster_sizes(&self) -> &[(i64, usize)] {
        &self.snapshot.cluster_sizes
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// The engine's shared lock-free metrics registry — live mid-run
    /// (workers record into it through the striped atomic histograms).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.obs
    }

    /// Per-stage breakdown of the most recent publish (zeroed until the
    /// first publish, or when metrics are disabled).
    pub fn last_trace(&self) -> &PublishTrace {
        &self.last_trace
    }

    /// Fold the serve façade's post-publish share — CoW snapshot-view
    /// construction and cluster-event derivation — into the latest trace
    /// and the cumulative stage histograms. These stages run after the
    /// engine's own total was taken, so they extend both the stage vector
    /// and the total (keeping `stage_sum_ns ≤ total_ns`); they are never
    /// counted against the engine's `publish` histogram.
    pub fn note_facade_stages(&mut self, cow_ns: u64, events_ns: u64) {
        if !self.obs.enabled() {
            return;
        }
        self.last_trace.record(PublishStage::SnapshotCow, cow_ns);
        self.last_trace.record(PublishStage::Events, events_ns);
        self.last_trace.extend_total(cow_ns + events_ns);
        self.obs.record_publish_stage(PublishStage::SnapshotCow, cow_ns);
        self.obs.record_publish_stage(PublishStage::Events, events_ns);
    }

    /// Record per-ext label transitions at every publish, drained via
    /// [`Self::drain_label_changes`] — the plumbing behind the serve
    /// façade's `watch()` events. Off by default (the buffer would grow
    /// unbounded with nobody draining it).
    pub fn set_change_log(&mut self, on: bool) {
        self.log_changes = on;
        self.stitcher.set_change_log(on);
        if !on {
            self.last_changes.clear();
        }
    }

    /// Take the label transitions of the most recent publish (empty when
    /// the change log is off).
    pub fn drain_label_changes(&mut self) -> Vec<LabelChange> {
        std::mem::take(&mut self.last_changes)
    }

    // ------------------------------------------------------------------
    // shutdown
    // ------------------------------------------------------------------

    /// Publish a final snapshot (skipped when the last publish is still
    /// current), stop the workers and collect their reports.
    pub fn finish(mut self) -> EngineOutcome {
        let snapshot = if self.dirty || self.stats.publishes == 0 {
            self.publish()
        } else {
            Arc::clone(&self.snapshot)
        };
        let mut add_latency = LatencyHisto::new();
        let mut delete_latency = LatencyHisto::new();
        let mut worker_reports: Vec<WorkerReport> = Vec::new();
        match self.backend {
            Backend::Inline(core) => worker_reports.push(core.into_report()),
            Backend::Threads { txs, workers, reply_tx, .. } => {
                drop(txs); // drop senders: workers drain and exit
                drop(reply_tx);
                for handle in workers {
                    // a panicked worker's report died with it — its fault
                    // is already in `faults`; don't panic the caller too
                    if let Ok(r) = handle.join() {
                        worker_reports.push(r);
                    }
                }
            }
        }
        for r in &worker_reports {
            add_latency.merge(&r.add_latency);
            delete_latency.merge(&r.delete_latency);
        }
        worker_reports.sort_by_key(|r| r.shard);
        EngineOutcome {
            snapshot,
            stats: self.stats.clone(),
            worker_reports,
            add_latency,
            delete_latency,
            publish_latency: self.publish_latency.clone(),
            last_trace: self.last_trace.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{make_blobs, BlobsConfig};
    use crate::dbscan::{DbscanConfig, DynamicDbscan};
    use crate::metrics::adjusted_rand_index;

    fn engine(shards: usize, dim: usize, seed: u64) -> ShardedEngine {
        let dbscan =
            DbscanConfig { k: 6, t: 8, eps: 0.75, dim, ..Default::default() };
        ShardedEngine::new(ShardConfig::new(dbscan, shards, seed))
    }

    #[test]
    fn insert_publish_read_roundtrip() {
        let ds = make_blobs(
            &BlobsConfig {
                n: 600,
                dim: 4,
                clusters: 3,
                std: 0.3,
                center_box: 20.0,
                weights: vec![],
            },
            3,
        );
        let mut eng = engine(3, 4, 17);
        assert_eq!(eng.cluster_of(0), None, "empty engine has no labels");
        for i in 0..ds.n() {
            eng.insert(i as u64, ds.point(i));
        }
        let snap = eng.publish();
        assert_eq!(snap.live_points, 600);
        assert!(snap.clusters >= 3, "expected >= 3 clusters, got {}", snap.clusters);
        let sized: usize = snap.cluster_sizes.iter().map(|&(_, s)| s).sum();
        assert!(sized <= 600);
        assert!(snap.core_points > 0);
        // reads come from the snapshot
        assert_eq!(eng.cluster_of(0), snap.cluster_of(0));
        let out = eng.finish();
        assert_eq!(out.stats.inserts, 600);
        assert_eq!(out.snapshot.live_points, 600);
        assert_eq!(out.worker_reports.len(), 3);
        assert_eq!(out.add_latency.count(), 600 + out.stats.ghost_inserts);
        assert!(out.publish_latency.count() >= 1);
        // metrics default on: the final trace partitions the publish
        assert!(out.last_trace.total_ns() > 0);
        assert!(out.last_trace.stage_sum_ns() <= out.last_trace.total_ns());
    }

    #[test]
    fn deletes_fan_out_to_all_replicas() {
        let ds = make_blobs(
            &BlobsConfig {
                n: 400,
                dim: 3,
                clusters: 4,
                std: 0.4,
                center_box: 15.0,
                weights: vec![],
            },
            9,
        );
        let mut eng = engine(4, 3, 5);
        for i in 0..ds.n() {
            eng.insert(i as u64, ds.point(i));
        }
        for e in 0..200u64 {
            eng.delete(e);
        }
        let out = eng.finish();
        assert_eq!(out.snapshot.live_points, 200);
        assert_eq!(out.stats.deletes, 200);
        assert_eq!(out.snapshot.cluster_of(0), None);
        assert!(out.snapshot.cluster_of(250).is_some());
        // deletes removed ghosts too: total live across shards = surviving
        // primaries + surviving ghosts = all replicas created − all deleted
        let live_all: usize = out.snapshot.shard_live.iter().sum();
        let replicas = out.stats.inserts + out.stats.ghost_inserts;
        let removed: u64 = out.worker_reports.iter().map(|r| r.deletes).sum();
        assert_eq!(live_all as u64, replicas - removed);
        assert_eq!(
            out.worker_reports.iter().map(|r| r.primary_inserts).sum::<u64>(),
            400
        );
    }

    /// The S == 1 inline path must reproduce the single-instance
    /// clustering exactly (same config and seed ⇒ identical structures)
    /// while skipping router, ghosts and channels entirely.
    #[test]
    fn single_shard_inline_path_matches_single_instance() {
        let ds = make_blobs(
            &BlobsConfig {
                n: 500,
                dim: 3,
                clusters: 4,
                std: 0.3,
                center_box: 15.0,
                weights: vec![],
            },
            21,
        );
        let cfg = DbscanConfig { k: 6, t: 8, eps: 0.75, dim: 3, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg.clone(), 11);
        let ids: Vec<u64> = (0..ds.n()).map(|i| db.add_point(ds.point(i))).collect();
        for i in (0..200).rev() {
            db.delete_point(ids[i]);
        }
        let survivors: Vec<u64> = ids[200..].to_vec();
        let single = db.labels_for(&survivors);

        let mut eng = engine(1, 3, 11);
        for i in 0..ds.n() {
            eng.insert(i as u64, ds.point(i));
        }
        for e in (0..200u64).rev() {
            eng.delete(e);
        }
        let out = eng.finish();
        assert_eq!(out.stats.ghost_inserts, 0, "S=1 must not replicate");
        assert_eq!(out.snapshot.live_points, 300);
        assert_eq!(out.worker_reports.len(), 1);
        let sharded: Vec<i64> = (200..ds.n() as u64)
            .map(|e| out.snapshot.cluster_of(e).expect("live ext labeled"))
            .collect();
        let ari = adjusted_rand_index(&single, &sharded);
        assert!(
            (ari - 1.0).abs() < 1e-9,
            "inline S=1 must match single instance exactly, ARI {ari}"
        );
    }

    /// Delta publishes across rounds must agree with the full-rebuild
    /// fallback on the same engine state.
    #[test]
    fn delta_publish_matches_full_rebuild_fallback() {
        let ds = make_blobs(
            &BlobsConfig {
                n: 800,
                dim: 4,
                clusters: 4,
                std: 0.35,
                center_box: 18.0,
                weights: vec![],
            },
            5,
        );
        let mut eng = engine(3, 4, 7);
        for round in 0..4 {
            for i in (round * 200)..((round + 1) * 200) {
                eng.insert(i as u64, ds.point(i));
            }
            if round == 2 {
                for e in 0..100u64 {
                    eng.delete(e);
                }
            }
            let snap = eng.publish();
            let reference = stitch_full(eng.full_dump(), snap.seq);
            assert_eq!(snap.live_points, reference.live_points);
            assert_eq!(snap.clusters, reference.clusters);
            assert_eq!(snap.core_points, reference.core_points);
            let a = snap.labels();
            let b = reference.labels();
            assert_eq!(a.len(), b.len());
            let mut fwd: FxHashMap<i64, i64> = FxHashMap::default();
            let mut bwd: FxHashMap<i64, i64> = FxHashMap::default();
            for (&(ea, la), &(eb, lb)) in a.iter().zip(b.iter()) {
                assert_eq!(ea, eb, "live ext sets diverged");
                assert_eq!(la < 0, lb < 0, "noise flag diverged at ext {ea}");
                if la >= 0 {
                    assert_eq!(*fwd.entry(la).or_insert(lb), lb, "split label");
                    assert_eq!(*bwd.entry(lb).or_insert(la), la, "merged label");
                }
            }
        }
        let _ = eng.finish();
    }

    #[test]
    #[should_panic(expected = "duplicate ext id")]
    fn duplicate_insert_panics() {
        let mut eng = engine(2, 2, 1);
        eng.insert(7, &[0.0, 0.0]);
        eng.insert(7, &[1.0, 1.0]);
        let _ = eng.finish();
    }

    #[test]
    #[should_panic(expected = "duplicate insert of ext")]
    fn duplicate_insert_panics_inline() {
        let mut eng = engine(1, 2, 1);
        eng.insert(7, &[0.0, 0.0]);
        eng.insert(7, &[1.0, 1.0]);
        eng.flush();
    }

    #[test]
    #[should_panic(expected = "unknown ext id")]
    fn unknown_delete_panics() {
        let mut eng = engine(2, 2, 1);
        eng.delete(3);
        let _ = eng.finish();
    }

    #[test]
    #[should_panic(expected = "delete of unknown ext")]
    fn unknown_delete_panics_inline() {
        let mut eng = engine(1, 2, 1);
        eng.delete(3);
        eng.flush();
    }
}
