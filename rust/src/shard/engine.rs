//! `ShardedEngine` — the front of the sharded serving path.
//!
//! Owns the router, the per-worker bounded channels and the latest
//! published [`GlobalSnapshot`]. Updates are routed and buffered per shard
//! (`insert`/`delete`), shipped in batches (`flush`), and made visible to
//! readers by `publish`, which barriers on every worker (the `Snapshot`
//! marker rides the op channels) and stitches the replies. Reads
//! (`cluster_of`, `cluster_sizes`, `snapshot`) only touch the immutable
//! snapshot — they never contend with the update path.

use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

use rustc_hash::FxHashMap;

use crate::dbscan::RepairStats;
use crate::util::stats::LatencyHisto;

use super::router::Router;
use super::stitch::{stitch, GlobalSnapshot};
use super::worker::{run_worker, ShardBatch, ShardSnapshot, WorkerReport};
use super::ShardConfig;

/// Engine-side op counters.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    /// primary inserts (= external points inserted)
    pub inserts: u64,
    /// ghost replicas created by boundary replication
    pub ghost_inserts: u64,
    /// external deletes (each fans out to every holding shard)
    pub deletes: u64,
    pub publishes: u64,
}

impl EngineStats {
    /// Ghost replicas per primary insert — the replication overhead the
    /// block geometry costs.
    pub fn ghost_ratio(&self) -> f64 {
        if self.inserts == 0 {
            0.0
        } else {
            self.ghost_inserts as f64 / self.inserts as f64
        }
    }
}

/// Everything a finished engine hands back.
pub struct EngineOutcome {
    /// final snapshot (published by `finish` after the last op)
    pub snapshot: Arc<GlobalSnapshot>,
    pub stats: EngineStats,
    /// per-shard reports, sorted by shard id
    pub worker_reports: Vec<WorkerReport>,
    /// add latency merged across shards (ghost inserts included)
    pub add_latency: LatencyHisto,
    pub delete_latency: LatencyHisto,
}

impl EngineOutcome {
    /// Connectivity-layer counters aggregated across shards (counters
    /// summed; `levels` is the deepest per-shard HDT hierarchy).
    pub fn conn_stats(&self) -> RepairStats {
        let mut total = RepairStats::default();
        for r in &self.worker_reports {
            total.nt_edges += r.conn.nt_edges;
            total.searches += r.conn.searches;
            total.replacements += r.conn.replacements;
            total.visited += r.conn.visited;
            total.pushes += r.conn.pushes;
            total.levels = total.levels.max(r.conn.levels);
        }
        total
    }
}

/// S parallel `DynamicDbscan` instances behind a deterministic spatial
/// router, with cross-shard cluster stitching. See the [module
/// docs](super) for the architecture.
pub struct ShardedEngine {
    cfg: ShardConfig,
    router: Router,
    txs: Vec<SyncSender<ShardBatch>>,
    snap_rx: Receiver<ShardSnapshot>,
    workers: Vec<JoinHandle<WorkerReport>>,
    /// ext → shards holding a replica (primary first)
    placement: FxHashMap<u64, Vec<u32>>,
    /// per-shard batch being assembled (ops + one shared flat coord buffer
    /// — no per-op coordinate allocation on the wire)
    pending: Vec<ShardBatch>,
    snapshot: Arc<GlobalSnapshot>,
    next_seq: u64,
    stats: EngineStats,
    /// ops accepted since the last publish (lets `finish` skip a
    /// redundant stitch when the snapshot is already current)
    dirty: bool,
}

impl ShardedEngine {
    pub fn new(cfg: ShardConfig) -> Self {
        let shards = cfg.shards.max(1);
        let router = Router::new(&cfg);
        let (snap_tx, snap_rx) = channel::<ShardSnapshot>();
        let mut txs = Vec::with_capacity(shards);
        let mut workers = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx) = sync_channel::<ShardBatch>(cfg.queue.max(1));
            let dcfg = cfg.dbscan.clone();
            let seed = cfg.seed;
            let stx = snap_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("shard-{shard}"))
                .spawn(move || run_worker(shard, dcfg, seed, rx, stx))
                .expect("failed to spawn shard worker");
            txs.push(tx);
            workers.push(handle);
        }
        drop(snap_tx);
        ShardedEngine {
            router,
            txs,
            snap_rx,
            workers,
            placement: FxHashMap::default(),
            pending: (0..shards).map(|_| ShardBatch::new()).collect(),
            snapshot: GlobalSnapshot::empty(),
            next_seq: 1,
            stats: EngineStats::default(),
            dirty: false,
            cfg,
        }
    }

    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    pub fn config(&self) -> &ShardConfig {
        &self.cfg
    }

    // ------------------------------------------------------------------
    // update path
    // ------------------------------------------------------------------

    /// Route and buffer an insert. `ext` is the caller's stable external
    /// id; it must not be live already.
    pub fn insert(&mut self, ext: u64, coords: &[f32]) {
        assert_eq!(coords.len(), self.cfg.dbscan.dim, "bad dim in sharded insert");
        let decision = self.router.route(coords);
        let mut held: Vec<u32> = Vec::with_capacity(1 + decision.ghosts.len());
        held.push(decision.primary as u32);
        self.pending[decision.primary].push_insert(ext, coords, true);
        self.stats.inserts += 1;
        for &g in &decision.ghosts {
            held.push(g as u32);
            self.pending[g].push_insert(ext, coords, false);
            self.stats.ghost_inserts += 1;
        }
        let prev = self.placement.insert(ext, held);
        assert!(prev.is_none(), "sharded insert of duplicate ext id {ext}");
        self.dirty = true;
    }

    /// Buffer a delete for every shard holding a replica of `ext`.
    pub fn delete(&mut self, ext: u64) {
        let held = self
            .placement
            .remove(&ext)
            .unwrap_or_else(|| panic!("sharded delete of unknown ext id {ext}"));
        for s in held {
            self.pending[s as usize].push_delete(ext);
        }
        self.stats.deletes += 1;
        self.dirty = true;
    }

    /// Ship buffered ops to the workers. Blocks only when a worker's
    /// bounded queue is full (backpressure).
    pub fn flush(&mut self) {
        for (s, tx) in self.txs.iter().enumerate() {
            if !self.pending[s].is_empty() {
                let batch = std::mem::take(&mut self.pending[s]);
                tx.send(batch).expect("shard worker terminated");
            }
        }
    }

    /// Flush, barrier on all workers, stitch their local clusterings and
    /// publish the result as the new immutable snapshot.
    pub fn publish(&mut self) -> Arc<GlobalSnapshot> {
        self.flush();
        let seq = self.next_seq;
        self.next_seq += 1;
        for tx in &self.txs {
            tx.send(ShardBatch::snapshot(seq)).expect("shard worker terminated");
        }
        let mut snaps: Vec<ShardSnapshot> = Vec::with_capacity(self.txs.len());
        while snaps.len() < self.txs.len() {
            let s = self.snap_rx.recv().expect("snapshot channel closed");
            debug_assert_eq!(s.seq, seq, "stale snapshot sequence");
            snaps.push(s);
        }
        let snap = Arc::new(stitch(snaps, seq));
        self.snapshot = Arc::clone(&snap);
        self.stats.publishes += 1;
        self.dirty = false;
        snap
    }

    // ------------------------------------------------------------------
    // read path (snapshot-backed; never blocks on the workers)
    // ------------------------------------------------------------------

    /// Latest published snapshot. Cheap (`Arc` clone); hand it to reader
    /// threads.
    pub fn snapshot(&self) -> Arc<GlobalSnapshot> {
        Arc::clone(&self.snapshot)
    }

    /// Global cluster of `ext` as of the latest snapshot (`None`: not
    /// live, `Some(-1)`: noise).
    pub fn cluster_of(&self, ext: u64) -> Option<i64> {
        self.snapshot.cluster_of(ext)
    }

    /// Global `(label, size)` pairs, largest first, as of the latest
    /// snapshot.
    pub fn cluster_sizes(&self) -> &[(i64, usize)] {
        &self.snapshot.cluster_sizes
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    // ------------------------------------------------------------------
    // shutdown
    // ------------------------------------------------------------------

    /// Publish a final snapshot (skipped when the last publish is still
    /// current), stop the workers and collect their reports.
    pub fn finish(mut self) -> EngineOutcome {
        let snapshot = if self.dirty || self.stats.publishes == 0 {
            self.publish()
        } else {
            Arc::clone(&self.snapshot)
        };
        self.txs.clear(); // drop senders: workers drain and exit
        let mut add_latency = LatencyHisto::new();
        let mut delete_latency = LatencyHisto::new();
        let mut worker_reports: Vec<WorkerReport> = Vec::with_capacity(self.workers.len());
        for handle in self.workers.drain(..) {
            let r = handle.join().expect("shard worker panicked");
            add_latency.merge(&r.add_latency);
            delete_latency.merge(&r.delete_latency);
            worker_reports.push(r);
        }
        worker_reports.sort_by_key(|r| r.shard);
        EngineOutcome {
            snapshot,
            stats: self.stats.clone(),
            worker_reports,
            add_latency,
            delete_latency,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{make_blobs, BlobsConfig};
    use crate::dbscan::DbscanConfig;

    fn engine(shards: usize, dim: usize, seed: u64) -> ShardedEngine {
        let dbscan =
            DbscanConfig { k: 6, t: 8, eps: 0.75, dim, ..Default::default() };
        ShardedEngine::new(ShardConfig::new(dbscan, shards, seed))
    }

    #[test]
    fn insert_publish_read_roundtrip() {
        let ds = make_blobs(
            &BlobsConfig {
                n: 600,
                dim: 4,
                clusters: 3,
                std: 0.3,
                center_box: 20.0,
                weights: vec![],
            },
            3,
        );
        let mut eng = engine(3, 4, 17);
        assert_eq!(eng.cluster_of(0), None, "empty engine has no labels");
        for i in 0..ds.n() {
            eng.insert(i as u64, ds.point(i));
        }
        let snap = eng.publish();
        assert_eq!(snap.live_points, 600);
        assert!(snap.clusters >= 3, "expected >= 3 clusters, got {}", snap.clusters);
        let sized: usize = snap.cluster_sizes.iter().map(|&(_, s)| s).sum();
        assert!(sized <= 600);
        assert!(snap.core_points > 0);
        // reads come from the snapshot
        assert_eq!(eng.cluster_of(0), snap.cluster_of(0));
        let out = eng.finish();
        assert_eq!(out.stats.inserts, 600);
        assert_eq!(out.snapshot.live_points, 600);
        assert_eq!(out.worker_reports.len(), 3);
        assert_eq!(out.add_latency.count(), 600 + out.stats.ghost_inserts);
    }

    #[test]
    fn deletes_fan_out_to_all_replicas() {
        let ds = make_blobs(
            &BlobsConfig {
                n: 400,
                dim: 3,
                clusters: 4,
                std: 0.4,
                center_box: 15.0,
                weights: vec![],
            },
            9,
        );
        let mut eng = engine(4, 3, 5);
        for i in 0..ds.n() {
            eng.insert(i as u64, ds.point(i));
        }
        for e in 0..200u64 {
            eng.delete(e);
        }
        let out = eng.finish();
        assert_eq!(out.snapshot.live_points, 200);
        assert_eq!(out.stats.deletes, 200);
        assert_eq!(out.snapshot.cluster_of(0), None);
        assert!(out.snapshot.cluster_of(250).is_some());
        // deletes removed ghosts too: total live across shards = surviving
        // primaries + surviving ghosts = all replicas created − all deleted
        let live_all: usize = out.snapshot.shard_live.iter().sum();
        let replicas = out.stats.inserts + out.stats.ghost_inserts;
        let removed: u64 = out.worker_reports.iter().map(|r| r.deletes).sum();
        assert_eq!(live_all as u64, replicas - removed);
        assert_eq!(
            out.worker_reports.iter().map(|r| r.primary_inserts).sum::<u64>(),
            400
        );
    }

    #[test]
    #[should_panic(expected = "duplicate ext id")]
    fn duplicate_insert_panics() {
        let mut eng = engine(2, 2, 1);
        eng.insert(7, &[0.0, 0.0]);
        eng.insert(7, &[1.0, 1.0]);
        let _ = eng.finish();
    }

    #[test]
    #[should_panic(expected = "unknown ext id")]
    fn unknown_delete_panics() {
        let mut eng = engine(2, 2, 1);
        eng.delete(3);
        let _ = eng.finish();
    }
}
