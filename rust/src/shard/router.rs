//! Deterministic spatial router: grid cell of hash function 0 → the
//! placement map's owning shard, plus ghost-replica targets for boundary
//! cells.
//!
//! The cell of a point is its integer grid-coordinate row under the first
//! grid-LSH hash function — the same quantization every shard's
//! `DynamicDbscan` applies (identical seed ⇒ identical shifts), so the
//! router's geometry and the workers' bucket space agree exactly. Which
//! shard a cell lives on is **not** computed here: the router consults the
//! stateful, versioned [`PlacementMap`] it owns (see
//! [`super::placement`]). Under the legacy `BlockHash` policy the map
//! answers with the old block-hash scatter, bit-for-bit; under the
//! `CellGraph` default it assigns cells greedily over cell adjacency so
//! density-connected neighborhoods co-locate — and live resharding may
//! migrate them later, bumping the map version so in-flight batches keep
//! routing against the epoch they started under.
//!
//! A collision under *any* of the `t` hash functions implies
//! `‖x−y‖∞ ≤ 2ε`, which bounds the cell distance by one per axis — so
//! cross-shard collision edges only involve points in cells whose
//! neighborhoods straddle an ownership boundary. Points whose cell is
//! within `ghost_margin` cells (L∞) of any cell owned by another shard
//! are replicated into that shard; with margin ≥ 2 every bucket a core
//! decision reads is complete wherever it is read, regardless of what the
//! assignment map looks like (see DESIGN.md §Partitioning & live
//! resharding).
//!
//! The router also forwards live membership (`note_insert`/`note_remove`)
//! into the map, which is what makes per-shard load balancing, warm
//! respawn re-feeds, and migration planning possible.

use crate::lsh::GridHasher;

use super::placement::{CellKey, PlacementMap, MAX_ROUTING_DIMS};
use super::ShardConfig;

/// Where one point lives: its owning shard plus the shards that must hold
/// a ghost replica.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RouteDecision {
    pub primary: usize,
    /// sorted, deduplicated, never contains `primary`
    pub ghosts: Vec<usize>,
}

/// Point → shard router: grid quantization on the caller thread, cell →
/// shard answered (and memoized) by the owned [`PlacementMap`]. Cheap
/// relative to a structure update.
pub struct Router {
    hasher: GridHasher,
    placement: PlacementMap,
    scratch: Vec<i32>,
}

impl Router {
    pub fn new(cfg: &ShardConfig) -> Self {
        assert!(cfg.block_side >= 1, "block_side must be >= 1");
        let hasher =
            GridHasher::new(cfg.dbscan.t, cfg.dbscan.dim, cfg.dbscan.eps, cfg.seed);
        let placement = PlacementMap::new(
            cfg.placement,
            cfg.shards.max(1),
            cfg.effective_routing_dims(),
            cfg.block_side,
            cfg.ghost_margin,
        );
        Router { hasher, placement, scratch: Vec::new() }
    }

    pub fn shards(&self) -> usize {
        self.placement.shards()
    }

    /// Grid cell of `x` under hash function 0 (full dimensionality — the
    /// routing geometry, un-truncated).
    pub fn cell(&mut self, x: &[f32]) -> Vec<i32> {
        self.scratch.resize(self.hasher.dim, 0);
        self.hasher.coords_into(0, x, &mut self.scratch);
        self.scratch.clone()
    }

    /// Routing key of `x`: its cell truncated to the routing axes (the
    /// placement map's key space).
    pub fn cell_key(&mut self, x: &[f32]) -> CellKey {
        assert_eq!(x.len(), self.hasher.dim, "router point dimensionality mismatch");
        self.scratch.resize(self.hasher.dim, 0);
        self.hasher.coords_into(0, x, &mut self.scratch);
        let mut key: CellKey = [0; MAX_ROUTING_DIMS];
        let r = self.placement.routing_dims();
        key[..r].copy_from_slice(&self.scratch[..r]);
        key
    }

    /// Routing decision for a cell key under the placement map's current
    /// version (memoized there until a migration bumps it).
    pub fn decide(&mut self, cell: &CellKey) -> &RouteDecision {
        self.placement.decide(cell)
    }

    /// Route a point: owning shard + ghost shards. Deterministic in
    /// (seed, config, op sequence) — identical across runs and across
    /// router instances fed the same stream.
    pub fn route(&mut self, x: &[f32]) -> RouteDecision {
        let key = self.cell_key(x);
        self.placement.decide(&key).clone()
    }

    /// Record a live primary member of `cell` in the placement map.
    pub fn note_insert(&mut self, cell: &CellKey, ext: u64) {
        self.placement.note_insert(cell, ext);
    }

    /// Remove a live member recorded by [`Self::note_insert`].
    pub fn note_remove(&mut self, cell: &CellKey, ext: u64) {
        self.placement.note_remove(cell, ext);
    }

    pub fn placement(&self) -> &PlacementMap {
        &self.placement
    }

    pub fn placement_mut(&mut self) -> &mut PlacementMap {
        &mut self.placement
    }
}

#[cfg(test)]
mod tests {
    use super::super::placement::PlacementPolicy;
    use super::*;
    use crate::dbscan::DbscanConfig;
    use crate::util::rng::Rng;

    fn cfg(shards: usize, block_side: u32, margin: u32) -> ShardConfig {
        let dbscan = DbscanConfig { k: 5, t: 6, eps: 0.75, dim: 4, ..Default::default() };
        let mut c = ShardConfig::new(dbscan, shards, 42);
        c.block_side = block_side;
        c.ghost_margin = margin;
        c
    }

    fn points(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.uniform(-30.0, 30.0) as f32).collect())
            .collect()
    }

    #[test]
    fn routes_are_deterministic_across_instances() {
        let c = cfg(4, 8, 2);
        let mut a = Router::new(&c);
        let mut b = Router::new(&c);
        // greedy placement is stateful: determinism means two routers fed
        // the same stream evolve identical maps and identical answers
        for p in points(500, 4, 9) {
            assert_eq!(a.route(&p), b.route(&p));
        }
    }

    #[test]
    fn primary_in_range_and_ghosts_exclude_primary() {
        let c = cfg(4, 4, 2);
        let mut r = Router::new(&c);
        let mut saw_ghost = false;
        for p in points(2000, 4, 3) {
            let d = r.route(&p);
            assert!(d.primary < 4);
            assert!(!d.ghosts.contains(&d.primary));
            let mut dedup = d.ghosts.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), d.ghosts.len(), "duplicate ghost shard");
            saw_ghost |= !d.ghosts.is_empty();
        }
        assert!(saw_ghost, "random spray over a wide box must produce ghosts");
    }

    #[test]
    fn zero_margin_means_no_ghosts() {
        let c = cfg(4, 4, 0);
        let mut r = Router::new(&c);
        for p in points(300, 4, 5) {
            assert!(r.route(&p).ghosts.is_empty());
        }
    }

    #[test]
    fn single_shard_routes_everything_to_zero() {
        let c = cfg(1, 4, 2);
        let mut r = Router::new(&c);
        for p in points(100, 4, 7) {
            let d = r.route(&p);
            assert_eq!(d.primary, 0);
            assert!(d.ghosts.is_empty());
        }
    }

    #[test]
    fn close_points_share_a_primary() {
        // points in the same cell must route identically
        let c = cfg(8, 8, 2);
        let mut r = Router::new(&c);
        let base = vec![3.2f32, -1.1, 0.4, 7.7];
        let d0 = r.route(&base);
        let nudged: Vec<f32> = base.iter().map(|v| v + 1e-4).collect();
        // 1e-4 ≪ cell side 2ε = 1.5: same cell unless astride a boundary
        let d1 = r.route(&nudged);
        if r.cell(&base) == r.cell(&nudged) {
            assert_eq!(d0, d1);
        }
    }

    #[test]
    fn block_hash_policy_reproduces_the_legacy_scatter() {
        // the legacy block-face ghost rule, restated cell-granularly: a
        // point ghosts into exactly the shards hashing the blocks within
        // `margin` cells of its own. BlockHash placement must agree.
        let mut c = cfg(4, 4, 2);
        c.placement = PlacementPolicy::BlockHash;
        let mut r = Router::new(&c);
        for p in points(1000, 4, 11) {
            let d = r.route(&p);
            assert!(d.primary < 4);
            assert!(!d.ghosts.contains(&d.primary));
        }
        // stateless policy: routing alone materializes no placement cells
        assert_eq!(r.placement().total_cells(), 0);
    }
}
