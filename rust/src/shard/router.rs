//! Deterministic spatial router: grid cell of hash function 0 → block →
//! shard, plus ghost-replica targets for boundary cells.
//!
//! The cell of a point is its integer grid-coordinate row under the first
//! grid-LSH hash function — the same quantization every shard's
//! `DynamicDbscan` applies (identical seed ⇒ identical shifts), so the
//! router's geometry and the workers' bucket space agree exactly. Cells are
//! grouped into blocks of `block_side` cells along the first
//! `routing_dims` axes; the block coordinate row is hashed to a shard id.
//! Spatially-close points share cells, cells share blocks, blocks pin a
//! shard: density-connected regions co-locate.
//!
//! A collision under *any* of the `t` hash functions implies
//! `‖x−y‖∞ ≤ 2ε`, which bounds the cell distance by one per axis — so
//! cross-shard collision edges only involve points within one cell of a
//! block face. Points within `ghost_margin` cells of a face are replicated
//! into the neighboring block's shard (diagonal neighbors included via the
//! offset product), which keeps those edges — and, with margin ≥ 2, the
//! core status of every replica that carries one — realized inside at
//! least one shard.

use crate::lsh::GridHasher;
use crate::util::rng::mix64;

use super::ShardConfig;

/// Where one point lives: its owning shard plus the shards that must hold
/// a ghost replica.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RouteDecision {
    pub primary: usize,
    /// deduplicated, never contains `primary`
    pub ghosts: Vec<usize>,
}

/// Deterministic point → shard router. Cheap (`O(d)` per point) relative
/// to a structure update; runs on the caller thread ahead of the workers.
pub struct Router {
    hasher: GridHasher,
    shards: usize,
    routing_dims: usize,
    block_side: i32,
    ghost_margin: i32,
    scratch: Vec<i32>,
}

impl Router {
    pub fn new(cfg: &ShardConfig) -> Self {
        assert!(cfg.block_side >= 1, "block_side must be >= 1");
        let hasher =
            GridHasher::new(cfg.dbscan.t, cfg.dbscan.dim, cfg.dbscan.eps, cfg.seed);
        Router {
            hasher,
            shards: cfg.shards.max(1),
            routing_dims: cfg.effective_routing_dims(),
            block_side: cfg.block_side as i32,
            ghost_margin: cfg.ghost_margin as i32,
            scratch: Vec::new(),
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Grid cell of `x` under hash function 0 (the routing geometry).
    pub fn cell(&mut self, x: &[f32]) -> Vec<i32> {
        self.scratch.resize(self.hasher.dim, 0);
        self.hasher.coords_into(0, x, &mut self.scratch);
        self.scratch.clone()
    }

    fn shard_of_blocks(&self, blocks: &[i32]) -> usize {
        let mut h: u64 = 0x8f3a_55b1_c2d4_e693;
        for &b in blocks {
            h = mix64(h ^ (b as u32 as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        }
        (h % self.shards as u64) as usize
    }

    /// Route a point: owning shard + ghost shards. Deterministic in
    /// (seed, config) — identical across runs and across router instances.
    pub fn route(&mut self, x: &[f32]) -> RouteDecision {
        assert_eq!(x.len(), self.hasher.dim, "router point dimensionality mismatch");
        self.scratch.resize(self.hasher.dim, 0);
        self.hasher.coords_into(0, x, &mut self.scratch);
        let (b, m, r) = (self.block_side, self.ghost_margin, self.routing_dims);
        // block coordinates and the ghost offsets each routing axis allows
        let mut blocks = [0i32; 4];
        let mut opts = [[0i32; 3]; 4];
        let mut counts = [1usize; 4];
        for ax in 0..r {
            let c = self.scratch[ax];
            blocks[ax] = c.div_euclid(b);
            let rem = c.rem_euclid(b);
            let mut k = 1; // opts[ax][0] = 0 (stay) always present
            if rem < m {
                opts[ax][k] = -1;
                k += 1;
            }
            if rem >= b - m {
                opts[ax][k] = 1;
                k += 1;
            }
            counts[ax] = k;
        }
        let primary = self.shard_of_blocks(&blocks[..r]);
        let mut ghosts: Vec<usize> = Vec::new();
        if self.shards > 1 {
            // odometer over the per-axis offset choices, skipping all-zero
            let mut idx = [0usize; 4];
            'combos: loop {
                let mut ax = 0;
                loop {
                    if ax == r {
                        break 'combos;
                    }
                    idx[ax] += 1;
                    if idx[ax] < counts[ax] {
                        break;
                    }
                    idx[ax] = 0;
                    ax += 1;
                }
                let mut nb = [0i32; 4];
                for ax in 0..r {
                    nb[ax] = blocks[ax] + opts[ax][idx[ax]];
                }
                let s = self.shard_of_blocks(&nb[..r]);
                if s != primary && !ghosts.contains(&s) {
                    ghosts.push(s);
                }
            }
        }
        RouteDecision { primary, ghosts }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbscan::DbscanConfig;
    use crate::util::rng::Rng;

    fn cfg(shards: usize, block_side: u32, margin: u32) -> ShardConfig {
        let dbscan = DbscanConfig { k: 5, t: 6, eps: 0.75, dim: 4, ..Default::default() };
        let mut c = ShardConfig::new(dbscan, shards, 42);
        c.block_side = block_side;
        c.ghost_margin = margin;
        c
    }

    fn points(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.uniform(-30.0, 30.0) as f32).collect())
            .collect()
    }

    #[test]
    fn routes_are_deterministic_across_instances() {
        let c = cfg(4, 8, 2);
        let mut a = Router::new(&c);
        let mut b = Router::new(&c);
        for p in points(500, 4, 9) {
            assert_eq!(a.route(&p), b.route(&p));
        }
    }

    #[test]
    fn primary_in_range_and_ghosts_exclude_primary() {
        let c = cfg(4, 4, 2);
        let mut r = Router::new(&c);
        let mut saw_ghost = false;
        for p in points(2000, 4, 3) {
            let d = r.route(&p);
            assert!(d.primary < 4);
            assert!(!d.ghosts.contains(&d.primary));
            let mut dedup = d.ghosts.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), d.ghosts.len(), "duplicate ghost shard");
            saw_ghost |= !d.ghosts.is_empty();
        }
        assert!(saw_ghost, "small blocks over a wide box must produce ghosts");
    }

    #[test]
    fn zero_margin_means_no_ghosts() {
        let c = cfg(4, 4, 0);
        let mut r = Router::new(&c);
        for p in points(300, 4, 5) {
            assert!(r.route(&p).ghosts.is_empty());
        }
    }

    #[test]
    fn single_shard_routes_everything_to_zero() {
        let c = cfg(1, 4, 2);
        let mut r = Router::new(&c);
        for p in points(100, 4, 7) {
            let d = r.route(&p);
            assert_eq!(d.primary, 0);
            assert!(d.ghosts.is_empty());
        }
    }

    #[test]
    fn close_points_share_a_primary() {
        // points in the same cell must route identically
        let c = cfg(8, 8, 2);
        let mut r = Router::new(&c);
        let base = vec![3.2f32, -1.1, 0.4, 7.7];
        let d0 = r.route(&base);
        let nudged: Vec<f32> = base.iter().map(|v| v + 1e-4).collect();
        // 1e-4 ≪ cell side 2ε = 1.5: same cell unless astride a boundary
        let d1 = r.route(&nudged);
        if r.cell(&base) == r.cell(&nudged) {
            assert_eq!(d0, d1);
        }
    }
}
