//! Shard worker: one thread owning one private `DynamicDbscan`, draining a
//! bounded op channel.
//!
//! Workers know nothing about routing — they apply the inserts (primary or
//! ghost) and deletes the engine sends, track per-op latency, and answer
//! `Snapshot` markers with their current `(ext → local cluster root)`
//! assignment. Because the marker travels the same channel as the ops,
//! a snapshot reply reflects exactly the ops sent before it (per-channel
//! FIFO) — the engine uses this as a barrier.

use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

use rustc_hash::FxHashMap;

use crate::dbscan::{DbscanConfig, DynamicDbscan};
use crate::lsh::table::PointId;
use crate::util::stats::LatencyHisto;

/// One operation on a shard's structure.
#[derive(Clone, Debug)]
pub enum ShardOp {
    Insert {
        ext: u64,
        coords: Vec<f32>,
        /// false for ghost replicas of points owned by another shard
        primary: bool,
    },
    Delete {
        ext: u64,
    },
    /// Publish a [`ShardSnapshot`] for all ops received so far.
    Snapshot {
        seq: u64,
    },
}

/// One point's state inside one shard, as of a snapshot.
#[derive(Clone, Debug)]
pub struct SnapPoint {
    pub ext: u64,
    /// local cluster root (canonical forest root; meaningful when
    /// `clustered`)
    pub root: u64,
    /// core, or non-core attached to a core — i.e. not noise locally
    pub clustered: bool,
    pub primary: bool,
    pub core: bool,
}

/// A shard's reply to a `Snapshot` marker.
#[derive(Clone, Debug)]
pub struct ShardSnapshot {
    pub shard: usize,
    pub seq: u64,
    pub points: Vec<SnapPoint>,
    /// live points in this shard, ghosts included
    pub live: usize,
}

/// Final accounting returned when a worker's channel closes.
#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub shard: usize,
    pub primary_inserts: u64,
    pub ghost_inserts: u64,
    pub deletes: u64,
    pub add_latency: LatencyHisto,
    pub delete_latency: LatencyHisto,
    /// wall time spent applying ops (excludes channel waits)
    pub busy_s: f64,
}

/// Worker loop: runs until the op channel disconnects. Snapshot sends are
/// best-effort (a vanished engine just ends the run).
pub fn run_worker(
    shard: usize,
    cfg: DbscanConfig,
    seed: u64,
    rx: Receiver<Vec<ShardOp>>,
    snap_tx: Sender<ShardSnapshot>,
) -> WorkerReport {
    let mut db = DynamicDbscan::new(cfg, seed);
    let mut ext_map: FxHashMap<u64, (PointId, bool)> = FxHashMap::default();
    let mut report = WorkerReport {
        shard,
        primary_inserts: 0,
        ghost_inserts: 0,
        deletes: 0,
        add_latency: LatencyHisto::new(),
        delete_latency: LatencyHisto::new(),
        busy_s: 0.0,
    };
    for batch in rx.iter() {
        let t0 = Instant::now();
        for op in batch {
            match op {
                ShardOp::Insert { ext, coords, primary } => {
                    let o0 = Instant::now();
                    let pid = db.add_point(&coords);
                    report.add_latency.record(o0.elapsed().as_nanos() as u64);
                    if primary {
                        report.primary_inserts += 1;
                    } else {
                        report.ghost_inserts += 1;
                    }
                    let prev = ext_map.insert(ext, (pid, primary));
                    assert!(prev.is_none(), "shard {shard}: duplicate insert of ext {ext}");
                }
                ShardOp::Delete { ext } => {
                    let (pid, _) = ext_map
                        .remove(&ext)
                        .unwrap_or_else(|| panic!("shard {shard}: delete of unknown ext {ext}"));
                    let o0 = Instant::now();
                    db.delete_point(pid);
                    report.delete_latency.record(o0.elapsed().as_nanos() as u64);
                    report.deletes += 1;
                }
                ShardOp::Snapshot { seq } => {
                    let mut points = Vec::with_capacity(ext_map.len());
                    for (&ext, &(pid, primary)) in ext_map.iter() {
                        points.push(SnapPoint {
                            ext,
                            root: db.get_cluster(pid),
                            clustered: !db.is_noise(pid),
                            primary,
                            core: db.is_core(pid),
                        });
                    }
                    let snap =
                        ShardSnapshot { shard, seq, points, live: db.num_points() };
                    let _ = snap_tx.send(snap);
                }
            }
        }
        report.busy_s += t0.elapsed().as_secs_f64();
    }
    report
}
