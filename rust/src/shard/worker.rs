//! Shard worker: one thread owning one private `DynamicDbscan`, draining a
//! bounded channel of [`ShardBatch`]es. The same per-shard state
//! ([`ShardCore`]) also runs **inline** in the engine thread when
//! `shards == 1`, so the single-shard configuration degenerates to the
//! direct path with no channel hop (see `shard::engine`).
//!
//! Workers know nothing about routing — they apply the inserts (primary or
//! ghost) and deletes the engine sends, track per-op latency, and answer
//! marker ops riding the same channel (per-channel FIFO makes every reply
//! a barrier over the ops sent before it):
//!
//! * [`ShardOp::Delta`] — the serving default: reply with the
//!   `(ext, local-root)` assignments that **changed** since the previous
//!   delta report (`O(Δ)`, driven by `DynamicDbscan`'s stitch-change
//!   tracking), plus the exts no longer held;
//! * [`ShardOp::Snapshot`] — full `(ext → local root)` dump (`O(live)`),
//!   kept for the full-rebuild fallback and the differential tests;
//! * [`ShardOp::Sync`] — bare ack: barrier without consuming the
//!   delta-tracking state (benches use it to isolate publish latency).
//!
//! **Live resharding needs no worker support.** When the placement layer
//! migrates a cell between shards (`shard::placement`), the engine
//! expresses the move as ordinary deletes at the losing shard and inserts
//! at the gaining shard, riding this same FIFO op stream — a worker cannot
//! tell a migration op from a client op, and the delta reports it already
//! emits carry the ownership change to the stitcher.
//!
//! ## Batch wire format
//!
//! A [`ShardBatch`] carries its ops plus **one shared flat coordinate
//! buffer**: the j-th insert of the batch owns row j (`dim` floats) of
//! `coords`, so shipping a batch of B inserts costs two allocations total
//! instead of B per-op `Vec<f32>`s. On receipt the worker hashes the whole
//! buffer in one cache-friendly pass per hash function
//! (`GridHasher::keys_batch_into`) and feeds the precomputed key rows to
//! `add_point_with_keys` — the per-op hot loop allocates nothing.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use rustc_hash::{FxHashMap, FxHashSet};

use crate::dbscan::{AnyDbscan, ConnKind, DbscanConfig, RepairStats};
use crate::lsh::table::PointId;
use crate::lsh::BucketKey;
use crate::obs::{Gauge, Metrics, Stopwatch, UpdateStage};
use crate::util::stats::LatencyHisto;

/// One operation on a shard's structure. Inserts carry no coordinates —
/// they consume the next row of the owning [`ShardBatch`]'s `coords`.
#[derive(Clone, Debug)]
pub enum ShardOp {
    Insert {
        ext: u64,
        /// false for ghost replicas of points owned by another shard
        primary: bool,
    },
    Delete {
        ext: u64,
    },
    /// Publish a full [`ShardSnapshot`] for all ops received so far
    /// (fallback / differential-testing path).
    Snapshot {
        seq: u64,
    },
    /// Publish a [`ShardDelta`] of changes since the previous delta
    /// report (the serving default).
    Delta {
        seq: u64,
    },
    /// Reply [`ShardReply::Sync`] once every prior op has been applied —
    /// a barrier that leaves the delta-tracking state untouched.
    Sync {
        seq: u64,
    },
}

/// A batch of ops for one shard, with the flat row-major coordinate buffer
/// shared by its inserts (insert j ⇒ `coords[j*dim .. (j+1)*dim]`, in op
/// order).
#[derive(Clone, Debug, Default)]
pub struct ShardBatch {
    pub ops: Vec<ShardOp>,
    pub coords: Vec<f32>,
}

impl ShardBatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// A control batch carrying a single marker op.
    pub fn marker(op: ShardOp) -> Self {
        ShardBatch { ops: vec![op], coords: Vec::new() }
    }

    /// A control batch carrying only a full-snapshot marker.
    pub fn snapshot(seq: u64) -> Self {
        Self::marker(ShardOp::Snapshot { seq })
    }

    /// A control batch carrying only a delta marker.
    pub fn delta(seq: u64) -> Self {
        Self::marker(ShardOp::Delta { seq })
    }

    /// A control batch carrying only a sync barrier.
    pub fn sync(seq: u64) -> Self {
        Self::marker(ShardOp::Sync { seq })
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Queue an insert, appending its coordinate row to the shared buffer.
    pub fn push_insert(&mut self, ext: u64, coords: &[f32], primary: bool) {
        self.ops.push(ShardOp::Insert { ext, primary });
        self.coords.extend_from_slice(coords);
    }

    pub fn push_delete(&mut self, ext: u64) {
        self.ops.push(ShardOp::Delete { ext });
    }

    /// Number of inserts (= coordinate rows) in the batch.
    pub fn inserts(&self) -> usize {
        self.ops
            .iter()
            .filter(|op| matches!(op, ShardOp::Insert { .. }))
            .count()
    }
}

/// One point's state inside one shard, as of a snapshot or delta upsert.
#[derive(Clone, Copy, Debug)]
pub struct SnapPoint {
    pub ext: u64,
    /// local cluster root (**stable** across restructures — see
    /// `DynamicDbscan::stable_cluster`; meaningful when `clustered`)
    pub root: u64,
    /// core, or non-core attached to a core — i.e. not noise locally
    pub clustered: bool,
    pub primary: bool,
    pub core: bool,
}

/// A shard's reply to a `Snapshot` marker: its full state.
#[derive(Clone, Debug)]
pub struct ShardSnapshot {
    pub shard: usize,
    pub seq: u64,
    pub points: Vec<SnapPoint>,
    /// live points in this shard, ghosts included
    pub live: usize,
}

/// A shard's reply to a `Delta` marker: only what changed since its
/// previous delta report.
#[derive(Clone, Debug)]
pub struct ShardDelta {
    pub shard: usize,
    pub seq: u64,
    /// replicas whose stitch-visible state changed (or appeared)
    pub upserts: Vec<SnapPoint>,
    /// exts this shard no longer holds
    pub removals: Vec<u64>,
    /// live points in this shard, ghosts included
    pub live: usize,
}

/// Worker → engine replies (all marker kinds share one channel).
#[derive(Clone, Debug)]
pub enum ShardReply {
    Full(ShardSnapshot),
    Delta(ShardDelta),
    Sync { shard: usize, seq: u64 },
}

/// Final accounting returned when a worker's channel closes.
#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub shard: usize,
    pub primary_inserts: u64,
    pub ghost_inserts: u64,
    pub deletes: u64,
    pub add_latency: LatencyHisto,
    pub delete_latency: LatencyHisto,
    /// wall time spent applying ops (excludes channel waits)
    pub busy_s: f64,
    /// this shard's connectivity-layer counters (replacement searches,
    /// HDT level pushes, live levels — see `dbscan::RepairStats`)
    pub conn: RepairStats,
}

/// Replica state as last reported to the stitcher:
/// `(root, clustered, primary, core)`.
type RepState = (u64, bool, bool, bool);

/// The per-shard engine state: a private `DynamicDbscan` with
/// ext-id bookkeeping, latency accounting and delta-report tracking.
/// Driven either by a worker thread ([`run_worker`]) or inline by the
/// engine when `shards == 1`.
pub struct ShardCore {
    shard: usize,
    dim: usize,
    t: usize,
    /// delta-report tracking on? Off in `StitchMode::FullRebuild` engines:
    /// nothing ever drains the dirty set there, so recording into it would
    /// grow it without bound (and the comp-event bookkeeping would be pure
    /// overhead).
    track: bool,
    db: AnyDbscan,
    /// ext → (pid, primary)
    ext_map: FxHashMap<u64, (PointId, bool)>,
    /// pid → ext (resolves the dbscan layer's dirty points)
    ext_of: FxHashMap<PointId, u64>,
    /// state as last shipped in a delta (absent = never reported)
    reported: FxHashMap<u64, RepState>,
    /// exts touched since the last delta report
    dirty: FxHashSet<u64>,
    keybuf: Vec<BucketKey>,
    scratch: Vec<i32>,
    pub report: WorkerReport,
    /// the engine's shared live-metrics registry: per-op latencies are
    /// mirrored here so `stats()` reads them **mid-run**, and structural
    /// gauges are accumulated while answering publish-barrier markers
    obs: Arc<Metrics>,
}

impl ShardCore {
    pub fn new(
        shard: usize,
        cfg: DbscanConfig,
        conn: ConnKind,
        seed: u64,
        track: bool,
        obs: Arc<Metrics>,
    ) -> Self {
        let (dim, t) = (cfg.dim, cfg.t);
        let mut db = AnyDbscan::new(conn, cfg, seed);
        if track {
            db.enable_stitch_tracking();
        }
        db.set_metrics(obs.clone());
        ShardCore {
            shard,
            dim,
            t,
            track,
            db,
            ext_map: FxHashMap::default(),
            ext_of: FxHashMap::default(),
            reported: FxHashMap::default(),
            dirty: FxHashSet::default(),
            keybuf: Vec::new(),
            scratch: Vec::new(),
            report: WorkerReport {
                shard,
                primary_inserts: 0,
                ghost_inserts: 0,
                deletes: 0,
                add_latency: LatencyHisto::new(),
                delete_latency: LatencyHisto::new(),
                busy_s: 0.0,
                conn: RepairStats::default(),
            },
            obs,
        }
    }

    /// Fold the dbscan layer's dirty points into the dirty-ext set.
    fn drain_dirty(&mut self) {
        let ext_of = &self.ext_of;
        let dirty = &mut self.dirty;
        self.db.drain_stitch_changes(&mut |pid| {
            if let Some(&e) = ext_of.get(&pid) {
                dirty.insert(e);
            }
        });
    }

    /// Apply one batch — ops plus any marker replies (via `reply`).
    pub fn apply(&mut self, batch: &ShardBatch, reply: &mut dyn FnMut(ShardReply)) {
        let t0 = Stopwatch::start();
        // hash every insert row of the batch in one pass per hash function
        let n_ins = batch.inserts();
        debug_assert_eq!(
            batch.coords.len(),
            n_ins * self.dim,
            "batch coords misaligned"
        );
        self.keybuf.clear();
        self.keybuf.resize(n_ins * self.t, 0);
        let hash_ns_per_insert = if n_ins > 0 {
            let h0 = Stopwatch::start();
            self.db.hasher().keys_batch_into(
                &batch.coords,
                n_ins,
                &mut self.scratch,
                &mut self.keybuf,
            );
            let hash_ns = h0.elapsed_ns();
            self.obs.record_update_stage(UpdateStage::Hash, hash_ns);
            // amortize the batch hash over its inserts so the recorded
            // per-op add latency stays comparable with the single-instance
            // path (which hashes inside the timed add_point call)
            hash_ns / n_ins as u64
        } else {
            0
        };
        let mut row = 0usize;
        for op in &batch.ops {
            match *op {
                ShardOp::Insert { ext, primary } => {
                    let x = &batch.coords[row * self.dim..(row + 1) * self.dim];
                    let keys = &self.keybuf[row * self.t..(row + 1) * self.t];
                    row += 1;
                    let o0 = Stopwatch::start();
                    let pid = self.db.add_point_with_keys(x, keys);
                    let op_ns = o0.elapsed_ns() + hash_ns_per_insert;
                    self.report.add_latency.record(op_ns);
                    self.obs.record_add(op_ns);
                    if primary {
                        self.report.primary_inserts += 1;
                    } else {
                        self.report.ghost_inserts += 1;
                    }
                    let prev = self.ext_map.insert(ext, (pid, primary));
                    assert!(
                        prev.is_none(),
                        "shard {}: duplicate insert of ext {ext}",
                        self.shard
                    );
                    self.ext_of.insert(pid, ext);
                    if self.track {
                        self.dirty.insert(ext);
                        self.drain_dirty();
                    }
                }
                ShardOp::Delete { ext } => {
                    let (pid, _) = self.ext_map.remove(&ext).unwrap_or_else(|| {
                        panic!("shard {}: delete of unknown ext {ext}", self.shard)
                    });
                    self.ext_of.remove(&pid);
                    if self.track {
                        self.dirty.insert(ext);
                    }
                    let o0 = Stopwatch::start();
                    self.db.delete_point(pid);
                    let op_ns = o0.elapsed_ns();
                    self.report.delete_latency.record(op_ns);
                    self.obs.record_delete(op_ns);
                    self.report.deletes += 1;
                    if self.track {
                        self.drain_dirty();
                    }
                }
                ShardOp::Snapshot { seq } => {
                    self.sample_structural();
                    reply(ShardReply::Full(self.full_snapshot(seq)))
                }
                ShardOp::Delta { seq } => {
                    self.sample_structural();
                    reply(ShardReply::Delta(self.delta(seq)))
                }
                ShardOp::Sync { seq } => {
                    reply(ShardReply::Sync { shard: self.shard, seq })
                }
            }
        }
        self.report.busy_s += t0.elapsed_s();
    }

    /// Accumulate this shard's structural gauges into the shared registry
    /// — called while answering a publish-barrier marker, after the engine
    /// zeroed the accumulators (`Metrics::zero_structural`). The barrier
    /// semantics of the marker channel guarantee every worker's share is
    /// in before the engine reads the merged sample.
    fn sample_structural(&self) {
        if !self.obs.enabled() {
            return;
        }
        let per_level = self.db.conn_level_live();
        self.obs
            .add_gauge(Gauge::EttVertices, per_level.iter().sum::<usize>() as u64);
        for (l, &n) in per_level.iter().enumerate() {
            self.obs.add_level_verts(l, n as u64);
        }
        self.obs.add_gauge(Gauge::EttEdges, self.db.conn_edge_count() as u64);
        let rs = self.db.repair_stats();
        self.obs.max_gauge(Gauge::HdtLevels, rs.levels as u64);
        self.obs.add_gauge(Gauge::EdgePromotions, rs.pushes);
    }

    /// Current stitch-visible state of a live ext.
    fn state_of(&self, pid: PointId, primary: bool) -> RepState {
        let clustered = !self.db.is_noise(pid);
        let root = if clustered { self.db.stable_cluster(pid) } else { 0 };
        (root, clustered, primary, self.db.is_core(pid))
    }

    /// Build the delta report: scan only the exts touched since the last
    /// report and ship the ones whose state actually changed — `O(Δ)`.
    pub fn delta(&mut self, seq: u64) -> ShardDelta {
        debug_assert!(self.track, "delta report from a non-tracking core");
        let mut upserts = Vec::new();
        let mut removals = Vec::new();
        let touched: Vec<u64> = self.dirty.drain().collect();
        for ext in touched {
            match self.ext_map.get(&ext) {
                Some(&(pid, primary)) => {
                    let state = self.state_of(pid, primary);
                    if self.reported.get(&ext) != Some(&state) {
                        self.reported.insert(ext, state);
                        let (root, clustered, primary, core) = state;
                        upserts.push(SnapPoint { ext, root, clustered, primary, core });
                    }
                }
                None => {
                    if self.reported.remove(&ext).is_some() {
                        removals.push(ext);
                    }
                }
            }
        }
        ShardDelta {
            shard: self.shard,
            seq,
            upserts,
            removals,
            live: self.db.num_points(),
        }
    }

    /// Full `(ext → local root)` dump — the `O(live)` fallback path; does
    /// not disturb the delta-tracking state.
    pub fn full_snapshot(&self, seq: u64) -> ShardSnapshot {
        let mut points = Vec::with_capacity(self.ext_map.len());
        for (&ext, &(pid, primary)) in self.ext_map.iter() {
            let (root, clustered, primary, core) = self.state_of(pid, primary);
            points.push(SnapPoint { ext, root, clustered, primary, core });
        }
        ShardSnapshot { shard: self.shard, seq, points, live: self.db.num_points() }
    }

    /// Final accounting (fills in the connectivity counters).
    pub fn into_report(self) -> WorkerReport {
        let mut report = self.report;
        report.conn = self.db.repair_stats();
        report
    }
}

/// Test-only fault injection for one shard worker, wired through
/// `ShardConfig::faults` by the recovery test matrix. A production engine
/// never sets it; the plan only *drops* work (an early thread exit or a
/// swallowed reply) — it cannot corrupt state, so exercising it validates
/// the engine's detection + respawn path, not the plan itself.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// shard this plan applies to
    pub shard: u32,
    /// simulate a worker panic: the thread exits — dropping its op
    /// channel and any un-applied batches — once it has applied at least
    /// this many data ops (batch granularity: it dies *before* the batch
    /// that would cross the budget, i.e. mid-stream)
    pub kill_after_ops: Option<u64>,
    /// simulate a wedged worker: silently swallow the next barrier reply
    /// (Delta/Snapshot/Sync), forcing the engine's publish timeout
    pub drop_next_reply: bool,
}

/// Worker loop: runs until the op channel disconnects. Marker replies are
/// best-effort (a vanished engine just ends the run). `track` enables the
/// delta-report plumbing (off for `StitchMode::FullRebuild` engines);
/// `faults` is the test-only injection plan (`None` in production).
#[allow(clippy::too_many_arguments)]
pub fn run_worker(
    shard: usize,
    cfg: DbscanConfig,
    conn: ConnKind,
    seed: u64,
    track: bool,
    obs: Arc<Metrics>,
    rx: Receiver<ShardBatch>,
    reply_tx: Sender<ShardReply>,
    faults: Option<FaultPlan>,
) -> WorkerReport {
    let mut core = ShardCore::new(shard, cfg, conn, seed, track, obs);
    let mut kill_budget = faults.and_then(|p| p.kill_after_ops);
    let mut drop_reply = faults.is_some_and(|p| p.drop_next_reply);
    for batch in rx.iter() {
        if let Some(left) = kill_budget.as_mut() {
            let data_ops = batch
                .ops
                .iter()
                .filter(|op| {
                    matches!(op, ShardOp::Insert { .. } | ShardOp::Delete { .. })
                })
                .count() as u64;
            if data_ops >= *left {
                // simulated panic: exit without applying the batch, leaving
                // the engine to discover the closed channel
                return core.into_report();
            }
            *left -= data_ops;
        }
        core.apply(&batch, &mut |r| {
            if drop_reply {
                drop_reply = false; // swallow exactly one barrier reply
                return;
            }
            let _ = reply_tx.send(r);
        });
    }
    core.into_report()
}
