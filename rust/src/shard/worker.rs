//! Shard worker: one thread owning one private `DynamicDbscan`, draining a
//! bounded channel of [`ShardBatch`]es.
//!
//! Workers know nothing about routing — they apply the inserts (primary or
//! ghost) and deletes the engine sends, track per-op latency, and answer
//! `Snapshot` markers with their current `(ext → local cluster root)`
//! assignment. Because the marker travels the same channel as the ops,
//! a snapshot reply reflects exactly the ops sent before it (per-channel
//! FIFO) — the engine uses this as a barrier.
//!
//! ## Batch wire format
//!
//! A [`ShardBatch`] carries its ops plus **one shared flat coordinate
//! buffer**: the j-th insert of the batch owns row j (`dim` floats) of
//! `coords`, so shipping a batch of B inserts costs two allocations total
//! instead of B per-op `Vec<f32>`s. On receipt the worker hashes the whole
//! buffer in one cache-friendly pass per hash function
//! (`GridHasher::keys_batch_into`) and feeds the precomputed key rows to
//! `add_point_with_keys` — the per-op hot loop allocates nothing.

use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

use rustc_hash::FxHashMap;

use crate::dbscan::{DbscanConfig, DynamicDbscan, RepairStats};
use crate::lsh::table::PointId;
use crate::lsh::BucketKey;
use crate::util::stats::LatencyHisto;

/// One operation on a shard's structure. Inserts carry no coordinates —
/// they consume the next row of the owning [`ShardBatch`]'s `coords`.
#[derive(Clone, Debug)]
pub enum ShardOp {
    Insert {
        ext: u64,
        /// false for ghost replicas of points owned by another shard
        primary: bool,
    },
    Delete {
        ext: u64,
    },
    /// Publish a [`ShardSnapshot`] for all ops received so far.
    Snapshot {
        seq: u64,
    },
}

/// A batch of ops for one shard, with the flat row-major coordinate buffer
/// shared by its inserts (insert j ⇒ `coords[j*dim .. (j+1)*dim]`, in op
/// order).
#[derive(Clone, Debug, Default)]
pub struct ShardBatch {
    pub ops: Vec<ShardOp>,
    pub coords: Vec<f32>,
}

impl ShardBatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// A control batch carrying only a snapshot marker.
    pub fn snapshot(seq: u64) -> Self {
        ShardBatch { ops: vec![ShardOp::Snapshot { seq }], coords: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Queue an insert, appending its coordinate row to the shared buffer.
    pub fn push_insert(&mut self, ext: u64, coords: &[f32], primary: bool) {
        self.ops.push(ShardOp::Insert { ext, primary });
        self.coords.extend_from_slice(coords);
    }

    pub fn push_delete(&mut self, ext: u64) {
        self.ops.push(ShardOp::Delete { ext });
    }

    /// Number of inserts (= coordinate rows) in the batch.
    pub fn inserts(&self) -> usize {
        self.ops
            .iter()
            .filter(|op| matches!(op, ShardOp::Insert { .. }))
            .count()
    }
}

/// One point's state inside one shard, as of a snapshot.
#[derive(Clone, Debug)]
pub struct SnapPoint {
    pub ext: u64,
    /// local cluster root (canonical forest root; meaningful when
    /// `clustered`)
    pub root: u64,
    /// core, or non-core attached to a core — i.e. not noise locally
    pub clustered: bool,
    pub primary: bool,
    pub core: bool,
}

/// A shard's reply to a `Snapshot` marker.
#[derive(Clone, Debug)]
pub struct ShardSnapshot {
    pub shard: usize,
    pub seq: u64,
    pub points: Vec<SnapPoint>,
    /// live points in this shard, ghosts included
    pub live: usize,
}

/// Final accounting returned when a worker's channel closes.
#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub shard: usize,
    pub primary_inserts: u64,
    pub ghost_inserts: u64,
    pub deletes: u64,
    pub add_latency: LatencyHisto,
    pub delete_latency: LatencyHisto,
    /// wall time spent applying ops (excludes channel waits)
    pub busy_s: f64,
    /// this shard's connectivity-layer counters (replacement searches,
    /// HDT level pushes, live levels — see `dbscan::RepairStats`)
    pub conn: RepairStats,
}

/// Worker loop: runs until the op channel disconnects. Snapshot sends are
/// best-effort (a vanished engine just ends the run).
pub fn run_worker(
    shard: usize,
    cfg: DbscanConfig,
    seed: u64,
    rx: Receiver<ShardBatch>,
    snap_tx: Sender<ShardSnapshot>,
) -> WorkerReport {
    let (dim, t) = (cfg.dim, cfg.t);
    let mut db = DynamicDbscan::new(cfg, seed);
    let mut ext_map: FxHashMap<u64, (PointId, bool)> = FxHashMap::default();
    let mut keybuf: Vec<BucketKey> = Vec::new();
    let mut scratch: Vec<i32> = Vec::new();
    let mut report = WorkerReport {
        shard,
        primary_inserts: 0,
        ghost_inserts: 0,
        deletes: 0,
        add_latency: LatencyHisto::new(),
        delete_latency: LatencyHisto::new(),
        busy_s: 0.0,
        conn: RepairStats::default(),
    };
    for batch in rx.iter() {
        let t0 = Instant::now();
        // hash every insert row of the batch in one pass per hash function
        let n_ins = batch.inserts();
        debug_assert_eq!(batch.coords.len(), n_ins * dim, "batch coords misaligned");
        keybuf.clear();
        keybuf.resize(n_ins * t, 0);
        let hash_ns_per_insert = if n_ins > 0 {
            let h0 = Instant::now();
            db.hasher.keys_batch_into(&batch.coords, n_ins, &mut scratch, &mut keybuf);
            // amortize the batch hash over its inserts so the recorded
            // per-op add latency stays comparable with the single-instance
            // path (which hashes inside the timed add_point call)
            (h0.elapsed().as_nanos() / n_ins as u128) as u64
        } else {
            0
        };
        let mut row = 0usize;
        for op in &batch.ops {
            match *op {
                ShardOp::Insert { ext, primary } => {
                    let x = &batch.coords[row * dim..(row + 1) * dim];
                    let keys = &keybuf[row * t..(row + 1) * t];
                    row += 1;
                    let o0 = Instant::now();
                    let pid = db.add_point_with_keys(x, keys);
                    report
                        .add_latency
                        .record(o0.elapsed().as_nanos() as u64 + hash_ns_per_insert);
                    if primary {
                        report.primary_inserts += 1;
                    } else {
                        report.ghost_inserts += 1;
                    }
                    let prev = ext_map.insert(ext, (pid, primary));
                    assert!(prev.is_none(), "shard {shard}: duplicate insert of ext {ext}");
                }
                ShardOp::Delete { ext } => {
                    let (pid, _) = ext_map
                        .remove(&ext)
                        .unwrap_or_else(|| panic!("shard {shard}: delete of unknown ext {ext}"));
                    let o0 = Instant::now();
                    db.delete_point(pid);
                    report.delete_latency.record(o0.elapsed().as_nanos() as u64);
                    report.deletes += 1;
                }
                ShardOp::Snapshot { seq } => {
                    let mut points = Vec::with_capacity(ext_map.len());
                    for (&ext, &(pid, primary)) in ext_map.iter() {
                        points.push(SnapPoint {
                            ext,
                            root: db.get_cluster(pid),
                            clustered: !db.is_noise(pid),
                            primary,
                            core: db.is_core(pid),
                        });
                    }
                    let snap =
                        ShardSnapshot { shard, seq, points, live: db.num_points() };
                    let _ = snap_tx.send(snap);
                }
            }
        }
        report.busy_s += t0.elapsed().as_secs_f64();
    }
    report.conn = db.repair_stats();
    report
}
