//! Periodic snapshot spill.
//!
//! A checkpoint serializes one *published* snapshot — the live point set
//! with coordinates, the label and core assignments, the snapshot version
//! and the WAL sequence floor it folds in — so recovery can rebuild the
//! engine from the checkpoint and replay only the WAL tail past
//! [`Checkpoint::wal_seq`] instead of the whole history.
//!
//! ## File format
//!
//! ```text
//! [magic "DDCKPT02"][u64 body_len][body][u32 crc32(body)]
//! ```
//!
//! body (all little-endian):
//!
//! ```text
//! version u64 · wal_seq u64 · eps f32 · dim u32
//! · n_points u32 · n×(ext u64 · label i64 · core u8 · dim×f32)
//! · placement_len u32 · placement_len bytes
//! ```
//!
//! The trailing placement blob (`shard::PlacementMap::export`, length 0
//! when the backend has no placement state) pins the cell→shard
//! assignment at spill time, so a durable reopen reshards to the *same*
//! assignment before re-ingesting points and the WAL tail re-evolves it
//! identically. Legacy `DDCKPT01` files (same body minus the trailing
//! placement field) still load — with `placement: None` — because the
//! WAL is truncated after every successful checkpoint: rejecting a
//! valid old-format checkpoint would silently drop everything folded
//! into it and replay only the post-checkpoint tail. New files are
//! always written as `DDCKPT02`.
//!
//! Writes go to a temp file that is fsynced and atomically renamed over
//! `checkpoint.ckpt`, so readers only ever observe the previous complete
//! checkpoint or the new complete one. The loader verifies magic, length
//! and CRC and returns `None` on any damage — the engine then falls back
//! to a cold replay of the full WAL, which is always correct (the WAL is
//! only truncated *after* a checkpoint rename succeeds).

use std::fs::{self, File, OpenOptions};
use std::io::{self, Read, Write};
use std::path::Path;

use rustc_hash::{FxHashMap, FxHashSet};

use super::crc32;
use crate::util::cow_map::chunk_ix_of;

/// Checkpoint file name inside a persist directory.
pub const CHECKPOINT_FILE: &str = "checkpoint.ckpt";

/// Incremental delta file name inside a persist directory (`DDCKPT03`,
/// chained to the full checkpoint — see [`CheckpointDelta`]).
pub const DELTA_FILE: &str = "checkpoint.delta";

const MAGIC: &[u8; 8] = b"DDCKPT02";
/// Incremental delta record: dirty coordinate chunks + a full label/core
/// overlay, chained to the `DDCKPT02` full spill whose version it names.
const MAGIC_DELTA: &[u8; 8] = b"DDCKPT03";
/// Pre-placement format: identical body without the trailing placement
/// field. Read-only — see the module docs for why rejecting it would
/// lose data.
const MAGIC_V1: &[u8; 8] = b"DDCKPT01";

/// One serialized published snapshot. `labels[i]`/`cores[i]` describe
/// `points[i]`: the row order is the only coupling between the three.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// `SnapshotView::version` of the spilled snapshot; recovery resumes
    /// version numbering from here.
    pub version: u64,
    /// Last WAL sequence number folded into this snapshot; replay skips
    /// records at or below it.
    pub wal_seq: u64,
    /// Engine ε, persisted for a sanity check at recovery.
    pub eps: f32,
    /// Point dimensionality.
    pub dim: u32,
    /// Live points as `(ext, coords)`.
    pub points: Vec<(u64, Vec<f32>)>,
    /// Cluster label per live point (same order as `points`).
    pub labels: Vec<i64>,
    /// Core flag per live point (same order as `points`).
    pub cores: Vec<bool>,
    /// Serialized cell→shard placement map (`PlacementMap::export`) at
    /// spill time; `None` for backends without placement state.
    pub placement: Option<Vec<u8>>,
}

impl Checkpoint {
    fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(32 + self.points.len() * (17 + self.dim as usize * 4));
        b.extend_from_slice(&self.version.to_le_bytes());
        b.extend_from_slice(&self.wal_seq.to_le_bytes());
        b.extend_from_slice(&self.eps.to_le_bytes());
        b.extend_from_slice(&self.dim.to_le_bytes());
        b.extend_from_slice(&(self.points.len() as u32).to_le_bytes());
        for (i, (ext, coords)) in self.points.iter().enumerate() {
            b.extend_from_slice(&ext.to_le_bytes());
            b.extend_from_slice(&self.labels[i].to_le_bytes());
            b.push(self.cores[i] as u8);
            for &x in coords {
                b.extend_from_slice(&x.to_le_bytes());
            }
        }
        let placement = self.placement.as_deref().unwrap_or(&[]);
        b.extend_from_slice(&(placement.len() as u32).to_le_bytes());
        b.extend_from_slice(placement);
        b
    }

    /// `legacy` decodes the `DDCKPT01` body layout, which ends at the
    /// point rows (no placement field).
    fn decode(body: &[u8], legacy: bool) -> Option<Checkpoint> {
        let mut at = 0usize;
        let take = |at: &mut usize, n: usize| -> Option<&[u8]> {
            let end = at.checked_add(n)?;
            if end > body.len() {
                return None;
            }
            let s = &body[*at..end];
            *at = end;
            Some(s)
        };
        let version = u64::from_le_bytes(take(&mut at, 8)?.try_into().ok()?);
        let wal_seq = u64::from_le_bytes(take(&mut at, 8)?.try_into().ok()?);
        let eps = f32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?);
        let dim = u32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?);
        let n = u32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?) as usize;
        let mut points = Vec::with_capacity(n.min(1 << 20));
        let mut labels = Vec::with_capacity(n.min(1 << 20));
        let mut cores = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            let ext = u64::from_le_bytes(take(&mut at, 8)?.try_into().ok()?);
            let label = i64::from_le_bytes(take(&mut at, 8)?.try_into().ok()?);
            let core = take(&mut at, 1)?[0] != 0;
            let row = take(&mut at, dim as usize * 4)?;
            let coords: Vec<f32> = row
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            points.push((ext, coords));
            labels.push(label);
            cores.push(core);
        }
        let placement = if legacy {
            None
        } else {
            let placement_len =
                u32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?) as usize;
            match placement_len {
                0 => None,
                n => Some(take(&mut at, n)?.to_vec()),
            }
        };
        if at != body.len() {
            return None;
        }
        Some(Checkpoint { version, wal_seq, eps, dim, points, labels, cores, placement })
    }
}

/// Atomically replace `<dir>/checkpoint.ckpt` with `ckpt`: write a temp
/// file, fsync it, rename over the target, then fsync the directory so the
/// rename itself is durable.
pub fn write_checkpoint(dir: &Path, ckpt: &Checkpoint) -> io::Result<()> {
    fs::create_dir_all(dir)?;
    let body = ckpt.encode();
    let tmp = dir.join("checkpoint.tmp");
    {
        let mut f = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&tmp)?;
        f.write_all(MAGIC)?;
        f.write_all(&(body.len() as u64).to_le_bytes())?;
        f.write_all(&body)?;
        f.write_all(&crc32(&body).to_le_bytes())?;
        f.sync_all()?;
    }
    fs::rename(&tmp, dir.join(CHECKPOINT_FILE))?;
    // fsync the directory entry; best-effort on platforms where opening a
    // directory for sync is not supported
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Load `<dir>/checkpoint.ckpt` if it exists and is intact; both current
/// (`DDCKPT02`) and legacy (`DDCKPT01`) formats load. Any damage
/// (missing file, unknown magic, short body, CRC mismatch, trailing
/// garbage) yields `None` and the caller falls back to cold WAL replay.
pub fn load_checkpoint(dir: &Path) -> Option<Checkpoint> {
    let mut buf = Vec::new();
    File::open(dir.join(CHECKPOINT_FILE)).ok()?.read_to_end(&mut buf).ok()?;
    if buf.len() < MAGIC.len() + 12 {
        return None;
    }
    let legacy = match &buf[..MAGIC.len()] {
        m if m == MAGIC => false,
        m if m == MAGIC_V1 => true,
        _ => return None,
    };
    let body_len =
        u64::from_le_bytes(buf[MAGIC.len()..MAGIC.len() + 8].try_into().ok()?) as usize;
    let start = MAGIC.len() + 8;
    let end = start.checked_add(body_len)?;
    if end + 4 != buf.len() {
        return None;
    }
    let body = &buf[start..end];
    let crc = u32::from_le_bytes(buf[end..end + 4].try_into().ok()?);
    if crc32(body) != crc {
        return None;
    }
    Checkpoint::decode(body, legacy)
}

/// Incremental checkpoint: the coordinate chunks of the façade's
/// `ChunkedCowMap` store that changed since the last *full* spill, plus a
/// compact `(ext, label, core)` overlay for every live point. Labels can
/// move en masse at a publish without their coordinate chunk changing
/// (cluster merges relabel points the update never touched), so the
/// overlay — 17 bytes/point vs `17 + 4·dim` for a full row — is always
/// complete while the bulky coordinate payload is spilled only for dirty
/// chunks.
///
/// A delta is *cumulative since the full spill it chains to*
/// ([`CheckpointDelta::base_version`]): each incremental spill atomically
/// replaces `checkpoint.delta`, so at most one delta exists and recovery
/// is always `full ⊕ delta ⊕ WAL tail`. Chunk-replacement semantics make
/// deletions implicit — reconstruction drops every base point whose chunk
/// (under [`chunk_ix_of`] at [`CheckpointDelta::chunk_count`]) is dirty,
/// then inserts the delta's rows for those chunks.
///
/// ## File format
///
/// ```text
/// [magic "DDCKPT03"][u64 body_len][body][u32 crc32(body)]
/// ```
///
/// body (all little-endian):
///
/// ```text
/// base_version u64 · version u64 · wal_seq u64 · eps f32 · dim u32
/// · chunk_count u32 · n_dirty u32
/// · n_dirty×(chunk_ix u32 · rows u32 · rows×(ext u64 · dim×f32))
/// · n_live u32 · n_live×(ext u64 · label i64 · core u8)
/// · placement_len u32 · placement_len bytes
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointDelta {
    /// `version` of the full `DDCKPT02` spill this delta chains to. A
    /// delta whose base is not the resident full checkpoint is stale and
    /// ignored.
    pub base_version: u64,
    /// Snapshot version of the delta spill itself.
    pub version: u64,
    /// Last WAL sequence number folded in; replay resumes past it.
    pub wal_seq: u64,
    /// Engine ε, for the recovery sanity check.
    pub eps: f32,
    /// Point dimensionality.
    pub dim: u32,
    /// Chunk count of the coordinate map at spill time (power of two);
    /// reconstruction re-derives base-point chunk membership with it.
    pub chunk_count: u32,
    /// Dirty chunks as `(chunk_ix, complete rows of that chunk)`.
    pub chunks: Vec<(u32, Vec<(u64, Vec<f32>)>)>,
    /// `(ext, label, core)` for every live point at the delta's version.
    pub overlay: Vec<(u64, i64, bool)>,
    /// Serialized placement map at delta spill time (`None` = empty).
    pub placement: Option<Vec<u8>>,
}

impl CheckpointDelta {
    fn encode(&self) -> Vec<u8> {
        let rows: usize = self.chunks.iter().map(|(_, r)| r.len()).sum();
        let mut b = Vec::with_capacity(
            44 + rows * (8 + self.dim as usize * 4) + self.overlay.len() * 17,
        );
        b.extend_from_slice(&self.base_version.to_le_bytes());
        b.extend_from_slice(&self.version.to_le_bytes());
        b.extend_from_slice(&self.wal_seq.to_le_bytes());
        b.extend_from_slice(&self.eps.to_le_bytes());
        b.extend_from_slice(&self.dim.to_le_bytes());
        b.extend_from_slice(&self.chunk_count.to_le_bytes());
        b.extend_from_slice(&(self.chunks.len() as u32).to_le_bytes());
        for (ix, rows) in &self.chunks {
            b.extend_from_slice(&ix.to_le_bytes());
            b.extend_from_slice(&(rows.len() as u32).to_le_bytes());
            for (ext, coords) in rows {
                b.extend_from_slice(&ext.to_le_bytes());
                for &x in coords {
                    b.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        b.extend_from_slice(&(self.overlay.len() as u32).to_le_bytes());
        for (ext, label, core) in &self.overlay {
            b.extend_from_slice(&ext.to_le_bytes());
            b.extend_from_slice(&label.to_le_bytes());
            b.push(*core as u8);
        }
        let placement = self.placement.as_deref().unwrap_or(&[]);
        b.extend_from_slice(&(placement.len() as u32).to_le_bytes());
        b.extend_from_slice(placement);
        b
    }

    fn decode(body: &[u8]) -> Option<CheckpointDelta> {
        let mut at = 0usize;
        let take = |at: &mut usize, n: usize| -> Option<&[u8]> {
            let end = at.checked_add(n)?;
            if end > body.len() {
                return None;
            }
            let s = &body[*at..end];
            *at = end;
            Some(s)
        };
        let base_version = u64::from_le_bytes(take(&mut at, 8)?.try_into().ok()?);
        let version = u64::from_le_bytes(take(&mut at, 8)?.try_into().ok()?);
        let wal_seq = u64::from_le_bytes(take(&mut at, 8)?.try_into().ok()?);
        let eps = f32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?);
        let dim = u32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?);
        let chunk_count = u32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?);
        if chunk_count == 0 || !chunk_count.is_power_of_two() {
            return None;
        }
        let n_dirty = u32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?) as usize;
        let mut chunks = Vec::with_capacity(n_dirty.min(1 << 20));
        for _ in 0..n_dirty {
            let ix = u32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?);
            if ix >= chunk_count {
                return None;
            }
            let n_rows = u32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?) as usize;
            let mut rows = Vec::with_capacity(n_rows.min(1 << 20));
            for _ in 0..n_rows {
                let ext = u64::from_le_bytes(take(&mut at, 8)?.try_into().ok()?);
                let raw = take(&mut at, dim as usize * 4)?;
                let coords: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                    .collect();
                rows.push((ext, coords));
            }
            chunks.push((ix, rows));
        }
        let n_live = u32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?) as usize;
        let mut overlay = Vec::with_capacity(n_live.min(1 << 20));
        for _ in 0..n_live {
            let ext = u64::from_le_bytes(take(&mut at, 8)?.try_into().ok()?);
            let label = i64::from_le_bytes(take(&mut at, 8)?.try_into().ok()?);
            let core = take(&mut at, 1)?[0] != 0;
            overlay.push((ext, label, core));
        }
        let placement_len = u32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?) as usize;
        let placement = match placement_len {
            0 => None,
            n => Some(take(&mut at, n)?.to_vec()),
        };
        if at != body.len() {
            return None;
        }
        Some(CheckpointDelta {
            base_version,
            version,
            wal_seq,
            eps,
            dim,
            chunk_count,
            chunks,
            overlay,
            placement,
        })
    }

    /// Fold this delta over its full base checkpoint, producing the
    /// equivalent full `Checkpoint` at the delta's version. `None` if the
    /// delta does not chain to `base` (stale base version, or an overlay
    /// inconsistent with the merged point set) — the caller then recovers
    /// from the base alone, which stays correct because WAL retention is
    /// floored at the *full* spill's sequence, not the delta's.
    pub fn apply_to(&self, base: &Checkpoint) -> Option<Checkpoint> {
        if self.base_version != base.version
            || self.dim != base.dim
            || self.eps.to_bits() != base.eps.to_bits()
        {
            return None;
        }
        let dirty: FxHashSet<u32> = self.chunks.iter().map(|&(ix, _)| ix).collect();
        let mut points: Vec<(u64, Vec<f32>)> = base
            .points
            .iter()
            .filter(|(ext, _)| {
                !dirty.contains(&(chunk_ix_of(*ext, self.chunk_count as usize) as u32))
            })
            .cloned()
            .collect();
        for (_, rows) in &self.chunks {
            points.extend(rows.iter().cloned());
        }
        let over: FxHashMap<u64, (i64, bool)> = self
            .overlay
            .iter()
            .map(|&(ext, label, core)| (ext, (label, core)))
            .collect();
        if over.len() != points.len() {
            return None;
        }
        let mut labels = Vec::with_capacity(points.len());
        let mut cores = Vec::with_capacity(points.len());
        for (ext, _) in &points {
            let &(label, core) = over.get(ext)?;
            labels.push(label);
            cores.push(core);
        }
        Some(Checkpoint {
            version: self.version,
            wal_seq: self.wal_seq,
            eps: self.eps,
            dim: self.dim,
            points,
            labels,
            cores,
            placement: self.placement.clone(),
        })
    }
}

/// Atomically replace `<dir>/checkpoint.delta` with `delta` — same
/// temp + fsync + rename + dir-sync discipline as [`write_checkpoint`].
pub fn write_delta(dir: &Path, delta: &CheckpointDelta) -> io::Result<()> {
    fs::create_dir_all(dir)?;
    let body = delta.encode();
    let tmp = dir.join("delta.tmp");
    {
        let mut f = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&tmp)?;
        f.write_all(MAGIC_DELTA)?;
        f.write_all(&(body.len() as u64).to_le_bytes())?;
        f.write_all(&body)?;
        f.write_all(&crc32(&body).to_le_bytes())?;
        f.sync_all()?;
    }
    fs::rename(&tmp, dir.join(DELTA_FILE))?;
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Remove `<dir>/checkpoint.delta` (after a full spill resets the chain).
/// A missing file is fine.
pub fn clear_delta(dir: &Path) {
    let _ = fs::remove_file(dir.join(DELTA_FILE));
}

/// Load `<dir>/checkpoint.delta` if present and intact; `None` on any
/// damage (recovery then uses the full checkpoint alone).
pub fn load_delta(dir: &Path) -> Option<CheckpointDelta> {
    let mut buf = Vec::new();
    File::open(dir.join(DELTA_FILE)).ok()?.read_to_end(&mut buf).ok()?;
    if buf.len() < MAGIC_DELTA.len() + 12 {
        return None;
    }
    if &buf[..MAGIC_DELTA.len()] != MAGIC_DELTA {
        return None;
    }
    let body_len =
        u64::from_le_bytes(buf[8..16].try_into().ok()?) as usize;
    let start = 16;
    let end = start.checked_add(body_len)?;
    if end + 4 != buf.len() {
        return None;
    }
    let body = &buf[start..end];
    let crc = u32::from_le_bytes(buf[end..end + 4].try_into().ok()?);
    if crc32(body) != crc {
        return None;
    }
    CheckpointDelta::decode(body)
}

/// Load the checkpoint chain: the full checkpoint, with the incremental
/// delta folded over it when one is present, intact and chained to this
/// exact base. A stale or damaged delta degrades silently to the full
/// checkpoint — never to an error — because the WAL is retained back to
/// the full spill's sequence floor, so the longer tail replay recovers
/// the same state.
pub fn load_checkpoint_chain(dir: &Path) -> Option<Checkpoint> {
    let base = load_checkpoint(dir)?;
    match load_delta(dir).and_then(|d| d.apply_to(&base)) {
        Some(merged) => Some(merged),
        None => Some(base),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    const CHUNKS: u32 = 64;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dyn-dbscan-ckpt-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Some ext that hashes into the same chunk as `like` (but isn't it).
    fn chunk_mate(like: u64) -> u64 {
        let want = chunk_ix_of(like, CHUNKS as usize);
        (100..).find(|&e| e != like && chunk_ix_of(e, CHUNKS as usize) == want).unwrap()
    }

    fn base() -> Checkpoint {
        Checkpoint {
            version: 10,
            wal_seq: 40,
            eps: 0.75,
            dim: 2,
            points: vec![
                (1, vec![1.0, 1.0]),
                (2, vec![2.0, 2.0]),
                (3, vec![3.0, 3.0]),
            ],
            labels: vec![0, 0, 1],
            cores: vec![true, true, false],
            placement: None,
        }
    }

    #[test]
    fn delta_roundtrip_preserves_every_field() {
        let dir = scratch("delta-roundtrip");
        let delta = CheckpointDelta {
            base_version: 10,
            version: 12,
            wal_seq: 55,
            eps: 0.75,
            dim: 2,
            chunk_count: CHUNKS,
            chunks: vec![
                (chunk_ix_of(2, CHUNKS as usize) as u32, vec![(2, vec![9.0, 9.0])]),
            ],
            overlay: vec![(1, 0, true), (2, 2, false), (3, 1, false)],
            placement: Some(vec![0xAB, 0xCD]),
        };
        write_delta(&dir, &delta).unwrap();
        assert_eq!(load_delta(&dir).expect("intact delta must load"), delta);

        // absent placement encodes as length 0 and reads back as None
        let bare = CheckpointDelta { placement: None, ..delta.clone() };
        write_delta(&dir, &bare).unwrap();
        assert_eq!(load_delta(&dir).unwrap().placement, None);

        // clear_delta ends the chain; clearing twice is fine
        clear_delta(&dir);
        assert!(load_delta(&dir).is_none());
        clear_delta(&dir);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Three exts guaranteed to live in three distinct chunks.
    fn distinct_chunk_exts() -> (u64, u64, u64) {
        let a = 1u64;
        let ca = chunk_ix_of(a, CHUNKS as usize);
        let b = (2u64..).find(|&e| chunk_ix_of(e, CHUNKS as usize) != ca).unwrap();
        let cb = chunk_ix_of(b, CHUNKS as usize);
        let c = (b + 1..)
            .find(|&e| {
                let cx = chunk_ix_of(e, CHUNKS as usize);
                cx != ca && cx != cb
            })
            .unwrap();
        (a, b, c)
    }

    #[test]
    fn apply_to_replaces_dirty_chunks_and_keeps_the_rest() {
        // a: untouched chunk — b: chunk rewritten — c: chunk emptied
        let (a, b, c) = distinct_chunk_exts();
        let base = Checkpoint {
            version: 10,
            wal_seq: 40,
            eps: 0.75,
            dim: 2,
            points: vec![
                (a, vec![1.0, 1.0]),
                (b, vec![2.0, 2.0]),
                (c, vec![3.0, 3.0]),
            ],
            labels: vec![0, 0, 1],
            cores: vec![true, true, false],
            placement: None,
        };
        let mate = chunk_mate(b); // inserted into b's chunk by the delta
        let delta = CheckpointDelta {
            base_version: 10,
            version: 12,
            wal_seq: 55,
            eps: 0.75,
            dim: 2,
            chunk_count: CHUNKS,
            chunks: vec![
                // b moved, `mate` is new; the complete rows of that chunk
                (
                    chunk_ix_of(b, CHUNKS as usize) as u32,
                    vec![(b, vec![9.0, 9.0]), (mate, vec![8.0, 8.0])],
                ),
                // c's chunk dirty with no surviving rows = deletion
                (chunk_ix_of(c, CHUNKS as usize) as u32, vec![]),
            ],
            overlay: vec![(a, 0, true), (b, 5, false), (mate, 5, true)],
            placement: Some(vec![0x01]),
        };

        let merged = delta.apply_to(&base).expect("chained delta must apply");
        assert_eq!(merged.version, 12);
        assert_eq!(merged.wal_seq, 55);
        assert_eq!(merged.placement, Some(vec![0x01]));
        let mut rows: Vec<(u64, Vec<f32>, i64, bool)> = merged
            .points
            .iter()
            .enumerate()
            .map(|(i, (ext, x))| (*ext, x.clone(), merged.labels[i], merged.cores[i]))
            .collect();
        rows.sort_by_key(|r| r.0);
        let mut expect = vec![
            (a, vec![1.0, 1.0], 0i64, true),    // clean chunk: carried over
            (b, vec![9.0, 9.0], 5, false),      // dirty chunk: replaced
            (mate, vec![8.0, 8.0], 5, true),    // dirty chunk: inserted
        ];
        expect.sort_by_key(|r| r.0);
        assert_eq!(rows, expect); // c gone: chunk dirty, re-listed without it
    }

    #[test]
    fn stale_or_inconsistent_delta_degrades_to_the_full_checkpoint() {
        let dir = scratch("delta-stale");
        let base = base();
        write_checkpoint(&dir, &base).unwrap();

        // base_version mismatch: the chain is broken
        let stale = CheckpointDelta {
            base_version: 9, // base is at 10
            version: 12,
            wal_seq: 55,
            eps: 0.75,
            dim: 2,
            chunk_count: CHUNKS,
            chunks: vec![],
            overlay: vec![(1, 0, true), (2, 0, true), (3, 1, false)],
            placement: None,
        };
        assert!(stale.apply_to(&base).is_none());
        write_delta(&dir, &stale).unwrap();
        let chain = load_checkpoint_chain(&dir).unwrap();
        assert_eq!(chain, base, "stale delta must degrade to the full spill");

        // an overlay that disagrees with the merged point set is rejected
        let short_overlay = CheckpointDelta {
            base_version: 10,
            overlay: vec![(1, 0, true)],
            ..stale.clone()
        };
        assert!(short_overlay.apply_to(&base).is_none());

        // CRC damage: load_delta refuses, the chain degrades
        let good = CheckpointDelta { base_version: 10, ..stale };
        write_delta(&dir, &good).unwrap();
        assert!(load_delta(&dir).is_some());
        let path = dir.join(DELTA_FILE);
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 6] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_delta(&dir).is_none());
        assert_eq!(load_checkpoint_chain(&dir).unwrap(), base);

        // truncation likewise
        std::fs::write(&path, &bytes[..n / 2]).unwrap();
        assert!(load_delta(&dir).is_none());
        assert_eq!(load_checkpoint_chain(&dir).unwrap(), base);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
