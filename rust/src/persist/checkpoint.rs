//! Periodic snapshot spill.
//!
//! A checkpoint serializes one *published* snapshot — the live point set
//! with coordinates, the label and core assignments, the snapshot version
//! and the WAL sequence floor it folds in — so recovery can rebuild the
//! engine from the checkpoint and replay only the WAL tail past
//! [`Checkpoint::wal_seq`] instead of the whole history.
//!
//! ## File format
//!
//! ```text
//! [magic "DDCKPT02"][u64 body_len][body][u32 crc32(body)]
//! ```
//!
//! body (all little-endian):
//!
//! ```text
//! version u64 · wal_seq u64 · eps f32 · dim u32
//! · n_points u32 · n×(ext u64 · label i64 · core u8 · dim×f32)
//! · placement_len u32 · placement_len bytes
//! ```
//!
//! The trailing placement blob (`shard::PlacementMap::export`, length 0
//! when the backend has no placement state) pins the cell→shard
//! assignment at spill time, so a durable reopen reshards to the *same*
//! assignment before re-ingesting points and the WAL tail re-evolves it
//! identically. Legacy `DDCKPT01` files (same body minus the trailing
//! placement field) still load — with `placement: None` — because the
//! WAL is truncated after every successful checkpoint: rejecting a
//! valid old-format checkpoint would silently drop everything folded
//! into it and replay only the post-checkpoint tail. New files are
//! always written as `DDCKPT02`.
//!
//! Writes go to a temp file that is fsynced and atomically renamed over
//! `checkpoint.ckpt`, so readers only ever observe the previous complete
//! checkpoint or the new complete one. The loader verifies magic, length
//! and CRC and returns `None` on any damage — the engine then falls back
//! to a cold replay of the full WAL, which is always correct (the WAL is
//! only truncated *after* a checkpoint rename succeeds).

use std::fs::{self, File, OpenOptions};
use std::io::{self, Read, Write};
use std::path::Path;

use super::crc32;

/// Checkpoint file name inside a persist directory.
pub const CHECKPOINT_FILE: &str = "checkpoint.ckpt";

const MAGIC: &[u8; 8] = b"DDCKPT02";
/// Pre-placement format: identical body without the trailing placement
/// field. Read-only — see the module docs for why rejecting it would
/// lose data.
const MAGIC_V1: &[u8; 8] = b"DDCKPT01";

/// One serialized published snapshot. `labels[i]`/`cores[i]` describe
/// `points[i]`: the row order is the only coupling between the three.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// `SnapshotView::version` of the spilled snapshot; recovery resumes
    /// version numbering from here.
    pub version: u64,
    /// Last WAL sequence number folded into this snapshot; replay skips
    /// records at or below it.
    pub wal_seq: u64,
    /// Engine ε, persisted for a sanity check at recovery.
    pub eps: f32,
    /// Point dimensionality.
    pub dim: u32,
    /// Live points as `(ext, coords)`.
    pub points: Vec<(u64, Vec<f32>)>,
    /// Cluster label per live point (same order as `points`).
    pub labels: Vec<i64>,
    /// Core flag per live point (same order as `points`).
    pub cores: Vec<bool>,
    /// Serialized cell→shard placement map (`PlacementMap::export`) at
    /// spill time; `None` for backends without placement state.
    pub placement: Option<Vec<u8>>,
}

impl Checkpoint {
    fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(32 + self.points.len() * (17 + self.dim as usize * 4));
        b.extend_from_slice(&self.version.to_le_bytes());
        b.extend_from_slice(&self.wal_seq.to_le_bytes());
        b.extend_from_slice(&self.eps.to_le_bytes());
        b.extend_from_slice(&self.dim.to_le_bytes());
        b.extend_from_slice(&(self.points.len() as u32).to_le_bytes());
        for (i, (ext, coords)) in self.points.iter().enumerate() {
            b.extend_from_slice(&ext.to_le_bytes());
            b.extend_from_slice(&self.labels[i].to_le_bytes());
            b.push(self.cores[i] as u8);
            for &x in coords {
                b.extend_from_slice(&x.to_le_bytes());
            }
        }
        let placement = self.placement.as_deref().unwrap_or(&[]);
        b.extend_from_slice(&(placement.len() as u32).to_le_bytes());
        b.extend_from_slice(placement);
        b
    }

    /// `legacy` decodes the `DDCKPT01` body layout, which ends at the
    /// point rows (no placement field).
    fn decode(body: &[u8], legacy: bool) -> Option<Checkpoint> {
        let mut at = 0usize;
        let take = |at: &mut usize, n: usize| -> Option<&[u8]> {
            let end = at.checked_add(n)?;
            if end > body.len() {
                return None;
            }
            let s = &body[*at..end];
            *at = end;
            Some(s)
        };
        let version = u64::from_le_bytes(take(&mut at, 8)?.try_into().ok()?);
        let wal_seq = u64::from_le_bytes(take(&mut at, 8)?.try_into().ok()?);
        let eps = f32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?);
        let dim = u32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?);
        let n = u32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?) as usize;
        let mut points = Vec::with_capacity(n.min(1 << 20));
        let mut labels = Vec::with_capacity(n.min(1 << 20));
        let mut cores = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            let ext = u64::from_le_bytes(take(&mut at, 8)?.try_into().ok()?);
            let label = i64::from_le_bytes(take(&mut at, 8)?.try_into().ok()?);
            let core = take(&mut at, 1)?[0] != 0;
            let row = take(&mut at, dim as usize * 4)?;
            let coords: Vec<f32> = row
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            points.push((ext, coords));
            labels.push(label);
            cores.push(core);
        }
        let placement = if legacy {
            None
        } else {
            let placement_len =
                u32::from_le_bytes(take(&mut at, 4)?.try_into().ok()?) as usize;
            match placement_len {
                0 => None,
                n => Some(take(&mut at, n)?.to_vec()),
            }
        };
        if at != body.len() {
            return None;
        }
        Some(Checkpoint { version, wal_seq, eps, dim, points, labels, cores, placement })
    }
}

/// Atomically replace `<dir>/checkpoint.ckpt` with `ckpt`: write a temp
/// file, fsync it, rename over the target, then fsync the directory so the
/// rename itself is durable.
pub fn write_checkpoint(dir: &Path, ckpt: &Checkpoint) -> io::Result<()> {
    fs::create_dir_all(dir)?;
    let body = ckpt.encode();
    let tmp = dir.join("checkpoint.tmp");
    {
        let mut f = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&tmp)?;
        f.write_all(MAGIC)?;
        f.write_all(&(body.len() as u64).to_le_bytes())?;
        f.write_all(&body)?;
        f.write_all(&crc32(&body).to_le_bytes())?;
        f.sync_all()?;
    }
    fs::rename(&tmp, dir.join(CHECKPOINT_FILE))?;
    // fsync the directory entry; best-effort on platforms where opening a
    // directory for sync is not supported
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Load `<dir>/checkpoint.ckpt` if it exists and is intact; both current
/// (`DDCKPT02`) and legacy (`DDCKPT01`) formats load. Any damage
/// (missing file, unknown magic, short body, CRC mismatch, trailing
/// garbage) yields `None` and the caller falls back to cold WAL replay.
pub fn load_checkpoint(dir: &Path) -> Option<Checkpoint> {
    let mut buf = Vec::new();
    File::open(dir.join(CHECKPOINT_FILE)).ok()?.read_to_end(&mut buf).ok()?;
    if buf.len() < MAGIC.len() + 12 {
        return None;
    }
    let legacy = match &buf[..MAGIC.len()] {
        m if m == MAGIC => false,
        m if m == MAGIC_V1 => true,
        _ => return None,
    };
    let body_len =
        u64::from_le_bytes(buf[MAGIC.len()..MAGIC.len() + 8].try_into().ok()?) as usize;
    let start = MAGIC.len() + 8;
    let end = start.checked_add(body_len)?;
    if end + 4 != buf.len() {
        return None;
    }
    let body = &buf[start..end];
    let crc = u32::from_le_bytes(buf[end..end + 4].try_into().ok()?);
    if crc32(body) != crc {
        return None;
    }
    Checkpoint::decode(body, legacy)
}
