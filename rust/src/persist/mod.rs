//! Durability primitives: an append-only op-log WAL and a periodic
//! checkpoint spill.
//!
//! The serving layer (`serve::DurableEngine`) composes the two into crash
//! recovery for any backend: on open it loads the latest *valid* checkpoint
//! chain (`checkpoint::load_checkpoint_chain` tolerates truncation and CRC
//! damage by degrading delta → full → `None`), replays the WAL tail past
//! the chain's `wal_seq` floor, and resumes at the recovered snapshot
//! version. The files live under one persist directory:
//!
//! ```text
//! <dir>/wal.log                    active WAL segment (CRC-framed records)
//! <dir>/wal.<ix>.<last_seq>.log    sealed WAL segments (retention units)
//! <dir>/checkpoint.ckpt            latest full snapshot spill (DDCKPT02)
//! <dir>/checkpoint.delta           incremental spill chained to it (DDCKPT03)
//! ```
//!
//! The segmented WAL lets checkpoint truncation and replica log-shipping
//! coexist: sealed segments are deleted only below
//! `min(full-checkpoint floor, slowest shipped floor)` — see `wal` and
//! `serve::DurableEngine`.
//!
//! Neither file format depends on in-memory layout: everything is
//! little-endian, length-prefixed and CRC-guarded, so a torn final record
//! (the only damage a crash mid-append can cause on a POSIX filesystem)
//! truncates cleanly to the last whole record instead of poisoning the log.
//!
//! This module is deliberately engine-agnostic — it knows about external
//! keys and coordinates, never about `PointId`s, shards or labels' internal
//! representation — so the recovery path is a plain re-ingestion through
//! the public `serve` façade and inherits its determinism.

pub mod checkpoint;
pub mod wal;

pub use checkpoint::{
    clear_delta, load_checkpoint, load_checkpoint_chain, load_delta,
    write_checkpoint, write_delta, Checkpoint, CheckpointDelta, CHECKPOINT_FILE,
    DELTA_FILE,
};
pub use wal::{
    decode_frame, encode_frame, read_frames_after, read_wal, WalOp, WalRecord,
    WalWriter, WAL_FILE,
};

/// CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`) — the same
/// checksum gzip/zip use. Hand-rolled bitwise form: the WAL frames are
/// small and append-bound by the engine work between them, so a lookup
/// table buys nothing worth the extra state.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::crc32;

    #[test]
    fn crc32_matches_reference_vectors() {
        // Standard check value for CRC-32/ISO-HDLC.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // Any flipped byte must change the sum.
        assert_ne!(crc32(b"123456788"), crc32(b"123456789"));
    }
}
