//! Append-only op-log WAL, segmented for retention.
//!
//! Every mutation that goes through a persistent engine is framed and
//! appended *before* it is applied in memory (write-ahead), and the log is
//! group-fsynced once per `publish()` — the publish is the durability
//! barrier, matching the read-side freshness contract (state you could
//! observe through a published snapshot is state that survives a crash).
//!
//! ## Frame format
//!
//! ```text
//! [u32 len][u32 crc32(payload)][payload: len bytes]
//! ```
//!
//! all little-endian. The payload starts with a one-byte record tag:
//!
//! | tag | record    | payload after tag                                  |
//! |-----|-----------|----------------------------------------------------|
//! | 1   | `Upsert`  | seq u64 · ext u64 · dim u32 · dim×f32              |
//! | 2   | `Remove`  | seq u64 · ext u64                                  |
//! | 3   | `Apply`   | seq u64 · n u32 · n ops, each `kind u8` then the `Upsert`/`Remove` body above (without seq) |
//! | 4   | `Publish` | seq u64 · version u64                              |
//!
//! `Upsert`/`Remove`/`Apply` mirror the three `serve::ClusterEngine` write
//! entry points one-to-one; `Publish` is the commit marker that records the
//! snapshot version minted at each publish so recovery can resume with
//! `SnapshotView::version` continuity (it is appended immediately before
//! the group fsync, so a fully-recovered log replays to exactly the
//! published state).
//!
//! This module is the *only* place frames are encoded or decoded
//! (`tests/lint.rs` enforces it): replication ships the exact on-disk
//! frame bytes over its `Transport`, and followers decode them with
//! [`decode_frame`] — one wire format, one codec.
//!
//! ## Segments
//!
//! The log is a sequence of segment files: one *active* segment
//! (`wal.log`, append-only) plus zero or more *sealed* segments named
//! `wal.<seal_ix>.<last_seq>.log`. [`WalWriter::roll`] seals the active
//! segment (fsync, then an atomic rename that embeds its highest sequence
//! number in the name) and starts a fresh one; [`WalWriter::retain`]
//! deletes sealed segments whose records all fall at or below a floor.
//! Segmentation lets checkpoint truncation and replica shipping coexist:
//! the engine rolls at every checkpoint and retains down to
//! `min(checkpoint floor, slowest shipped floor)`, so a lagging follower
//! holds history open without blocking checkpoints, and with no followers
//! the retention floor equals the checkpoint floor and sealed segments die
//! immediately (the old truncate-after-checkpoint behaviour). Directories
//! written before segmentation hold only `wal.log` and read unchanged.
//!
//! The reader stops at the first torn or corrupt frame and reports the log
//! as not clean — a crash mid-append damages at most the final record of
//! the active segment, and recovery proceeds from the longest valid
//! prefix.

use std::fs::{File, OpenOptions};
use std::io::{self, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::crc32;

/// Active WAL segment name inside a persist directory.
pub const WAL_FILE: &str = "wal.log";

const TAG_UPSERT: u8 = 1;
const TAG_REMOVE: u8 = 2;
const TAG_APPLY: u8 = 3;
const TAG_PUBLISH: u8 = 4;

/// One op inside an [`WalRecord::Apply`] batch. Kept in batch order —
/// a remove-then-upsert of the same ext is a replace, upsert-then-remove
/// is a delete; splitting the batch into two lists would lose that.
#[derive(Debug, Clone, PartialEq)]
pub enum WalOp {
    Upsert { ext: u64, coords: Vec<f32> },
    Remove { ext: u64 },
}

/// One durable op-log entry. Sequence numbers are assigned by the engine,
/// strictly increasing across the life of a persist directory; a
/// checkpoint records the last sequence number it folds in, and replay
/// skips records at or below that floor.
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    /// Single-point upsert (`ClusterEngine::upsert`).
    Upsert { seq: u64, ext: u64, coords: Vec<f32> },
    /// Single-point removal (`ClusterEngine::remove`).
    Remove { seq: u64, ext: u64 },
    /// One atomic batch (`ClusterEngine::apply`), kept whole and in op
    /// order so replay preserves both the semantics and the batch
    /// boundary (flush points) of the original run.
    Apply { seq: u64, ops: Vec<WalOp> },
    /// Commit marker: a publish happened here and minted `version`.
    Publish { seq: u64, version: u64 },
}

impl WalRecord {
    /// The record's sequence number.
    pub fn seq(&self) -> u64 {
        match self {
            WalRecord::Upsert { seq, .. }
            | WalRecord::Remove { seq, .. }
            | WalRecord::Apply { seq, .. }
            | WalRecord::Publish { seq, .. } => *seq,
        }
    }

    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            WalRecord::Upsert { seq, ext, coords } => {
                out.push(TAG_UPSERT);
                put_u64(out, *seq);
                put_u64(out, *ext);
                put_coords(out, coords);
            }
            WalRecord::Remove { seq, ext } => {
                out.push(TAG_REMOVE);
                put_u64(out, *seq);
                put_u64(out, *ext);
            }
            WalRecord::Apply { seq, ops } => {
                out.push(TAG_APPLY);
                put_u64(out, *seq);
                put_u32(out, ops.len() as u32);
                for op in ops {
                    match op {
                        WalOp::Upsert { ext, coords } => {
                            out.push(TAG_UPSERT);
                            put_u64(out, *ext);
                            put_coords(out, coords);
                        }
                        WalOp::Remove { ext } => {
                            out.push(TAG_REMOVE);
                            put_u64(out, *ext);
                        }
                    }
                }
            }
            WalRecord::Publish { seq, version } => {
                out.push(TAG_PUBLISH);
                put_u64(out, *seq);
                put_u64(out, *version);
            }
        }
    }

    fn decode(payload: &[u8]) -> Option<WalRecord> {
        let mut c = Cursor { buf: payload, at: 0 };
        let rec = match c.u8()? {
            TAG_UPSERT => WalRecord::Upsert {
                seq: c.u64()?,
                ext: c.u64()?,
                coords: c.coords()?,
            },
            TAG_REMOVE => WalRecord::Remove { seq: c.u64()?, ext: c.u64()? },
            TAG_APPLY => {
                let seq = c.u64()?;
                let n = c.u32()? as usize;
                let mut ops = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    let op = match c.u8()? {
                        TAG_UPSERT => {
                            WalOp::Upsert { ext: c.u64()?, coords: c.coords()? }
                        }
                        TAG_REMOVE => WalOp::Remove { ext: c.u64()? },
                        _ => return None,
                    };
                    ops.push(op);
                }
                WalRecord::Apply { seq, ops }
            }
            TAG_PUBLISH => WalRecord::Publish { seq: c.u64()?, version: c.u64()? },
            _ => return None,
        };
        // trailing garbage means a framing bug, not a valid record
        if c.at == payload.len() {
            Some(rec)
        } else {
            None
        }
    }
}

/// Frame one record exactly as [`WalWriter::append`] writes it to disk:
/// `[u32 len][u32 crc32(payload)][payload]`. The replication shipper uses
/// this only in tests; in production it forwards the on-disk bytes
/// verbatim — both sides of the wire share this one codec.
pub fn encode_frame(rec: &WalRecord) -> Vec<u8> {
    let mut payload = Vec::new();
    rec.encode(&mut payload);
    let mut out = Vec::with_capacity(payload.len() + 8);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Decode one frame from the head of `buf`. Returns the record and the
/// framed byte count consumed, or `None` if the head is torn, corrupt
/// (CRC mismatch) or not a valid record — the caller treats that as the
/// end of usable input, mirroring the on-disk reader.
pub fn decode_frame(buf: &[u8]) -> Option<(WalRecord, usize)> {
    if buf.len() < 8 {
        return None;
    }
    let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    let crc = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let end = 8usize.checked_add(len)?;
    if end > buf.len() {
        return None;
    }
    let payload = &buf[8..end];
    if crc32(payload) != crc {
        return None;
    }
    WalRecord::decode(payload).map(|rec| (rec, end))
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_coords(out: &mut Vec<u8>, coords: &[f32]) {
    put_u32(out, coords.len() as u32);
    for &x in coords {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> Option<&[u8]> {
        let end = self.at.checked_add(n)?;
        if end > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.at..end];
        self.at = end;
        Some(s)
    }

    fn u8(&mut self) -> Option<u8> {
        Some(self.take(1)?[0])
    }

    fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }

    fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    fn coords(&mut self) -> Option<Vec<f32>> {
        let dim = self.u32()? as usize;
        // an absurd dim means a corrupt frame; don't let it drive a huge
        // allocation before the bounds check in take() catches it
        let bytes = self.take(dim.checked_mul(4)?)?;
        Some(
            bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect(),
        )
    }
}

/// One sealed (read-only) segment: `wal.<ix>.<last_seq>.log`. The highest
/// sequence number lives in the file name so retention never has to read
/// segment bodies.
#[derive(Debug, Clone)]
struct Sealed {
    ix: u64,
    last_seq: u64,
    path: PathBuf,
}

/// Parse `wal.<ix>.<last_seq>.log`; `None` for any other name (including
/// the active `wal.log`). Unknown files are never deleted.
fn parse_sealed(dir: &Path, name: &str) -> Option<Sealed> {
    let rest = name.strip_prefix("wal.")?.strip_suffix(".log")?;
    let (ix, last_seq) = rest.split_once('.')?;
    Some(Sealed {
        ix: ix.parse().ok()?,
        last_seq: last_seq.parse().ok()?,
        path: dir.join(name),
    })
}

/// Sealed segments in `dir`, sorted by seal index (append order).
fn list_sealed(dir: &Path) -> io::Result<Vec<Sealed>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(e),
    };
    for entry in entries {
        let entry = entry?;
        if let Some(name) = entry.file_name().to_str() {
            if let Some(seg) = parse_sealed(dir, name) {
                out.push(seg);
            }
        }
    }
    out.sort_by_key(|s| s.ix);
    Ok(out)
}

/// Appending writer over the segmented log in `dir`. Records buffer in
/// user space until [`WalWriter::sync`] (the group fsync at publish); the
/// number of appended-but-unsynced records is exposed as
/// [`WalWriter::pending`] so the engine can surface it as the `wal_lag`
/// gauge.
pub struct WalWriter {
    dir: PathBuf,
    file: BufWriter<File>,
    path: PathBuf,
    pending: u64,
    frame: Vec<u8>,
    sealed: Vec<Sealed>,
    next_seal_ix: u64,
    /// Highest sequence number in the active segment (0 = none seen).
    active_last_seq: u64,
    /// Records in the active segment (pre-existing + appended).
    active_records: u64,
}

impl WalWriter {
    /// Open (creating if needed) the segmented WAL inside `dir` for
    /// appending. Pre-existing sealed segments are indexed from their
    /// names; a pre-existing active segment is scanned once so rolls and
    /// retention know its sequence range.
    pub fn open(dir: &Path) -> io::Result<Self> {
        std::fs::create_dir_all(dir)?;
        let sealed = list_sealed(dir)?;
        let next_seal_ix = sealed.last().map(|s| s.ix + 1).unwrap_or(1);
        let path = dir.join(WAL_FILE);
        let (active_last_seq, active_records) = match read_segment(&path) {
            Ok((recs, _clean)) => {
                (recs.last().map(|r| r.seq()).unwrap_or(0), recs.len() as u64)
            }
            Err(e) if e.kind() == io::ErrorKind::NotFound => (0, 0),
            Err(e) => return Err(e),
        };
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        Ok(WalWriter {
            dir: dir.to_path_buf(),
            file: BufWriter::new(file),
            path,
            pending: 0,
            frame: Vec::new(),
            sealed,
            next_seal_ix,
            active_last_seq,
            active_records,
        })
    }

    /// Path of the active segment file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Frame and append one record; returns the framed byte count. The
    /// record is buffered — call [`sync`](WalWriter::sync) to make it
    /// durable.
    pub fn append(&mut self, rec: &WalRecord) -> io::Result<usize> {
        self.frame.clear();
        rec.encode(&mut self.frame);
        let len = self.frame.len() as u32;
        let crc = crc32(&self.frame);
        self.file.write_all(&len.to_le_bytes())?;
        self.file.write_all(&crc.to_le_bytes())?;
        self.file.write_all(&self.frame)?;
        self.pending += 1;
        self.active_records += 1;
        self.active_last_seq = self.active_last_seq.max(rec.seq());
        Ok(self.frame.len() + 8)
    }

    /// Appended-but-unsynced record count (the `wal_lag` gauge).
    pub fn pending(&self) -> u64 {
        self.pending
    }

    /// Flush buffered frames to the OS **without** an fsync: after this,
    /// readers of the file see whole frames up to the last append (no
    /// torn mid-buffer tail), but the bytes are not yet crash-durable.
    /// The durable engine calls this before the inner publish so a warm
    /// shard heal running *inside* the publish reads a complete log.
    pub fn flush(&mut self) -> io::Result<()> {
        self.file.flush()
    }

    /// Group fsync: flush buffered frames and force them to stable
    /// storage. Returns how many records this barrier made durable.
    pub fn sync(&mut self) -> io::Result<u64> {
        self.file.flush()?;
        self.file.get_ref().sync_data()?;
        let n = self.pending;
        self.pending = 0;
        Ok(n)
    }

    /// Seal the active segment and start a fresh one. The active file is
    /// synced, then atomically renamed to `wal.<ix>.<last_seq>.log`; a
    /// crash between the steps leaves either the old active segment or
    /// the sealed file — both readable, no frame lost. An empty active
    /// segment is left in place (no zero-record seals).
    pub fn roll(&mut self) -> io::Result<()> {
        if self.active_records == 0 {
            return Ok(());
        }
        self.file.flush()?;
        self.file.get_ref().sync_data()?;
        let ix = self.next_seal_ix;
        let sealed_path =
            self.dir.join(format!("wal.{ix:06}.{}.log", self.active_last_seq));
        std::fs::rename(&self.path, &sealed_path)?;
        self.sealed.push(Sealed {
            ix,
            last_seq: self.active_last_seq,
            path: sealed_path,
        });
        self.next_seal_ix = ix + 1;
        let file = OpenOptions::new().create(true).append(true).open(&self.path)?;
        self.file = BufWriter::new(file);
        self.pending = 0;
        self.active_last_seq = 0;
        self.active_records = 0;
        // make the rename + new file durable as a directory entry change
        if let Ok(d) = File::open(&self.dir) {
            let _ = d.sync_all();
        }
        Ok(())
    }

    /// Delete sealed segments whose every record has `seq <= floor`. The
    /// active segment is never deleted. Callers compute the floor as
    /// `min(checkpoint wal_seq, slowest shipped seq)` so neither recovery
    /// nor a lagging follower loses history it still needs.
    pub fn retain(&mut self, floor: u64) -> io::Result<()> {
        let mut kept = Vec::with_capacity(self.sealed.len());
        for seg in self.sealed.drain(..) {
            if seg.last_seq <= floor && std::fs::remove_file(&seg.path).is_ok() {
                continue;
            }
            kept.push(seg);
        }
        self.sealed = kept;
        Ok(())
    }

    /// Number of sealed segments currently retained.
    pub fn sealed_segments(&self) -> usize {
        self.sealed.len()
    }

    /// Drop the entire log: every sealed segment plus the active
    /// contents. Equivalent to `roll()` + `retain(u64::MAX)` but keeps
    /// the pre-segmentation semantics (an empty, clean active file) for
    /// callers with no retention constraints.
    pub fn truncate(&mut self) -> io::Result<()> {
        for seg in self.sealed.drain(..) {
            let _ = std::fs::remove_file(&seg.path);
        }
        self.file.flush()?;
        let f = self.file.get_mut();
        f.set_len(0)?;
        f.seek(SeekFrom::Start(0))?;
        f.sync_data()?;
        self.pending = 0;
        self.active_last_seq = 0;
        self.active_records = 0;
        Ok(())
    }
}

/// Read every valid record from one segment file. Same contract as
/// [`read_wal`] but for a single file.
fn read_segment(path: &Path) -> io::Result<(Vec<WalRecord>, bool)> {
    let mut buf = Vec::new();
    File::open(path)?.read_to_end(&mut buf)?;
    let mut records = Vec::new();
    let mut at = 0usize;
    while at < buf.len() {
        match decode_frame(&buf[at..]) {
            Some((rec, used)) => {
                records.push(rec);
                at += used;
            }
            None => return Ok((records, false)), // torn or corrupt tail
        }
    }
    Ok((records, true))
}

/// Read every valid record from the segmented log in `dir` — sealed
/// segments in seal order, then the active segment. Returns the records
/// plus a `clean` flag: `false` means the log ended in a torn or corrupt
/// frame (expected after a crash mid-append) and recovery proceeds from
/// the returned prefix. A missing directory or file reads as empty and
/// clean.
pub fn read_wal(dir: &Path) -> io::Result<(Vec<WalRecord>, bool)> {
    let mut records = Vec::new();
    for seg in list_sealed(dir)? {
        let (mut recs, clean) = read_segment(&seg.path)?;
        records.append(&mut recs);
        if !clean {
            // damage in a sealed segment: recovery stops at the longest
            // valid prefix, exactly as with a torn active tail
            return Ok((records, false));
        }
    }
    match read_segment(&dir.join(WAL_FILE)) {
        Ok((mut recs, clean)) => {
            records.append(&mut recs);
            Ok((records, clean))
        }
        Err(e) if e.kind() == io::ErrorKind::NotFound => Ok((records, true)),
        Err(e) => Err(e),
    }
}

/// Read the raw frames of every record with `seq > floor`, in log order,
/// as `(seq, frame bytes)` pairs — the shipping tail. Sealed segments
/// whose name proves `last_seq <= floor` are skipped without opening
/// them. Only frames made durable by a prior [`WalWriter::sync`] are
/// guaranteed visible; the durable engine ships immediately after its
/// publish fsync, so the tail it reads is exactly the committed prefix.
pub fn read_frames_after(dir: &Path, floor: u64) -> io::Result<Vec<(u64, Vec<u8>)>> {
    let mut out = Vec::new();
    let mut paths: Vec<PathBuf> = list_sealed(dir)?
        .into_iter()
        .filter(|s| s.last_seq > floor)
        .map(|s| s.path)
        .collect();
    paths.push(dir.join(WAL_FILE));
    for path in paths {
        let mut buf = Vec::new();
        match File::open(&path) {
            Ok(mut f) => {
                f.read_to_end(&mut buf)?;
            }
            Err(e) if e.kind() == io::ErrorKind::NotFound => continue,
            Err(e) => return Err(e),
        }
        let mut at = 0usize;
        while at < buf.len() {
            match decode_frame(&buf[at..]) {
                Some((rec, used)) => {
                    if rec.seq() > floor {
                        out.push((rec.seq(), buf[at..at + used].to_vec()));
                    }
                    at += used;
                }
                None => break, // torn tail: ship only the valid prefix
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dyn-dbscan-wal-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn upsert(seq: u64) -> WalRecord {
        WalRecord::Upsert { seq, ext: seq * 10, coords: vec![seq as f32, -1.0] }
    }

    #[test]
    fn roll_seals_segments_and_read_wal_stitches_them_in_order() {
        let dir = scratch("roll");
        let mut w = WalWriter::open(&dir).unwrap();
        for seq in 1..=3 {
            w.append(&upsert(seq)).unwrap();
        }
        w.roll().unwrap();
        assert_eq!(w.sealed_segments(), 1);
        // an empty active segment never seals (no zero-record files)
        w.roll().unwrap();
        assert_eq!(w.sealed_segments(), 1);
        for seq in 4..=5 {
            w.append(&upsert(seq)).unwrap();
        }
        w.roll().unwrap();
        w.append(&upsert(6)).unwrap();
        w.sync().unwrap();
        assert_eq!(w.sealed_segments(), 2);
        // the last_seq in each sealed name matches its contents
        assert!(dir.join("wal.000001.3.log").exists());
        assert!(dir.join("wal.000002.5.log").exists());

        let (recs, clean) = read_wal(&dir).unwrap();
        assert!(clean);
        assert_eq!(recs.len(), 6);
        assert_eq!(recs.iter().map(WalRecord::seq).collect::<Vec<_>>(), vec![
            1, 2, 3, 4, 5, 6
        ]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retain_deletes_only_wholly_covered_sealed_segments() {
        let dir = scratch("retain");
        let mut w = WalWriter::open(&dir).unwrap();
        for seq in 1..=3 {
            w.append(&upsert(seq)).unwrap();
        }
        w.roll().unwrap(); // sealed: seqs 1..=3
        for seq in 4..=6 {
            w.append(&upsert(seq)).unwrap();
        }
        w.roll().unwrap(); // sealed: seqs 4..=6
        w.append(&upsert(7)).unwrap();
        w.sync().unwrap();

        // floor 5 covers the first segment but not the second
        w.retain(5).unwrap();
        assert_eq!(w.sealed_segments(), 1);
        let (recs, _) = read_wal(&dir).unwrap();
        assert_eq!(recs.first().unwrap().seq(), 4, "segment 2 survives whole");
        assert_eq!(recs.last().unwrap().seq(), 7, "active segment untouched");

        // the active segment is never deleted, whatever the floor
        w.retain(u64::MAX).unwrap();
        assert_eq!(w.sealed_segments(), 0);
        let (recs, _) = read_wal(&dir).unwrap();
        assert_eq!(recs.iter().map(WalRecord::seq).collect::<Vec<_>>(), vec![7]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_indexes_sealed_segments_and_continues_the_seal_sequence() {
        let dir = scratch("reopen");
        let mut w = WalWriter::open(&dir).unwrap();
        w.append(&upsert(1)).unwrap();
        w.roll().unwrap();
        w.append(&upsert(2)).unwrap();
        w.sync().unwrap();
        drop(w);

        let mut w = WalWriter::open(&dir).unwrap();
        assert_eq!(w.sealed_segments(), 1);
        w.append(&upsert(3)).unwrap();
        w.roll().unwrap(); // must seal as ix 2 with last_seq 3
        assert!(dir.join("wal.000002.3.log").exists());
        let (recs, clean) = read_wal(&dir).unwrap();
        assert!(clean);
        assert_eq!(recs.len(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_frames_after_ships_the_tail_past_the_floor() {
        let dir = scratch("frames-after");
        let mut w = WalWriter::open(&dir).unwrap();
        for seq in 1..=4 {
            w.append(&upsert(seq)).unwrap();
        }
        w.roll().unwrap();
        w.append(&WalRecord::Publish { seq: 5, version: 1 }).unwrap();
        w.sync().unwrap();

        // floor 0: everything, sealed then active, as verbatim frames
        let all = read_frames_after(&dir, 0).unwrap();
        assert_eq!(all.iter().map(|(s, _)| *s).collect::<Vec<_>>(), vec![
            1, 2, 3, 4, 5
        ]);
        // each shipped frame decodes back with the shared codec
        for (seq, frame) in &all {
            let (rec, used) = decode_frame(frame).expect("shipped frame decodes");
            assert_eq!(rec.seq(), *seq);
            assert_eq!(*used, frame.len());
            assert_eq!(encode_frame(&rec), *frame, "frame bytes are verbatim");
        }
        // floor 4 skips the sealed segment without opening it and the
        // covered prefix of the active one
        let tail = read_frames_after(&dir, 4).unwrap();
        assert_eq!(tail.len(), 1);
        assert_eq!(tail[0].0, 5);
        // floor at the frontier: nothing to ship
        assert!(read_frames_after(&dir, 5).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_single_file_directory_reads_unchanged() {
        let dir = scratch("legacy");
        // a pre-segmentation dir: just wal.log, no sealed segments
        let mut w = WalWriter::open(&dir).unwrap();
        for seq in 1..=3 {
            w.append(&upsert(seq)).unwrap();
        }
        w.sync().unwrap();
        drop(w);
        let (recs, clean) = read_wal(&dir).unwrap();
        assert!(clean);
        assert_eq!(recs.len(), 3);
        // truncate keeps the old semantics: an empty, clean active file
        let mut w = WalWriter::open(&dir).unwrap();
        w.truncate().unwrap();
        let (recs, clean) = read_wal(&dir).unwrap();
        assert!(clean);
        assert!(recs.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
