//! Append-only op-log WAL.
//!
//! Every mutation that goes through a persistent engine is framed and
//! appended *before* it is applied in memory (write-ahead), and the log is
//! group-fsynced once per `publish()` — the publish is the durability
//! barrier, matching the read-side freshness contract (state you could
//! observe through a published snapshot is state that survives a crash).
//!
//! ## Frame format
//!
//! ```text
//! [u32 len][u32 crc32(payload)][payload: len bytes]
//! ```
//!
//! all little-endian. The payload starts with a one-byte record tag:
//!
//! | tag | record    | payload after tag                                  |
//! |-----|-----------|----------------------------------------------------|
//! | 1   | `Upsert`  | seq u64 · ext u64 · dim u32 · dim×f32              |
//! | 2   | `Remove`  | seq u64 · ext u64                                  |
//! | 3   | `Apply`   | seq u64 · n u32 · n ops, each `kind u8` then the `Upsert`/`Remove` body above (without seq) |
//! | 4   | `Publish` | seq u64 · version u64                              |
//!
//! `Upsert`/`Remove`/`Apply` mirror the three `serve::ClusterEngine` write
//! entry points one-to-one; `Publish` is the commit marker that records the
//! snapshot version minted at each publish so recovery can resume with
//! `SnapshotView::version` continuity (it is appended immediately before
//! the group fsync, so a fully-recovered log replays to exactly the
//! published state).
//!
//! The reader stops at the first torn or corrupt frame and reports the log
//! as not clean — a crash mid-append damages at most the final record, and
//! recovery proceeds from the longest valid prefix.

use std::fs::{File, OpenOptions};
use std::io::{self, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::crc32;

/// WAL file name inside a persist directory.
pub const WAL_FILE: &str = "wal.log";

const TAG_UPSERT: u8 = 1;
const TAG_REMOVE: u8 = 2;
const TAG_APPLY: u8 = 3;
const TAG_PUBLISH: u8 = 4;

/// One op inside an [`WalRecord::Apply`] batch. Kept in batch order —
/// a remove-then-upsert of the same ext is a replace, upsert-then-remove
/// is a delete; splitting the batch into two lists would lose that.
#[derive(Debug, Clone, PartialEq)]
pub enum WalOp {
    Upsert { ext: u64, coords: Vec<f32> },
    Remove { ext: u64 },
}

/// One durable op-log entry. Sequence numbers are assigned by the engine,
/// strictly increasing across the life of a persist directory; a
/// checkpoint records the last sequence number it folds in, and replay
/// skips records at or below that floor.
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    /// Single-point upsert (`ClusterEngine::upsert`).
    Upsert { seq: u64, ext: u64, coords: Vec<f32> },
    /// Single-point removal (`ClusterEngine::remove`).
    Remove { seq: u64, ext: u64 },
    /// One atomic batch (`ClusterEngine::apply`), kept whole and in op
    /// order so replay preserves both the semantics and the batch
    /// boundary (flush points) of the original run.
    Apply { seq: u64, ops: Vec<WalOp> },
    /// Commit marker: a publish happened here and minted `version`.
    Publish { seq: u64, version: u64 },
}

impl WalRecord {
    /// The record's sequence number.
    pub fn seq(&self) -> u64 {
        match self {
            WalRecord::Upsert { seq, .. }
            | WalRecord::Remove { seq, .. }
            | WalRecord::Apply { seq, .. }
            | WalRecord::Publish { seq, .. } => *seq,
        }
    }

    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            WalRecord::Upsert { seq, ext, coords } => {
                out.push(TAG_UPSERT);
                put_u64(out, *seq);
                put_u64(out, *ext);
                put_coords(out, coords);
            }
            WalRecord::Remove { seq, ext } => {
                out.push(TAG_REMOVE);
                put_u64(out, *seq);
                put_u64(out, *ext);
            }
            WalRecord::Apply { seq, ops } => {
                out.push(TAG_APPLY);
                put_u64(out, *seq);
                put_u32(out, ops.len() as u32);
                for op in ops {
                    match op {
                        WalOp::Upsert { ext, coords } => {
                            out.push(TAG_UPSERT);
                            put_u64(out, *ext);
                            put_coords(out, coords);
                        }
                        WalOp::Remove { ext } => {
                            out.push(TAG_REMOVE);
                            put_u64(out, *ext);
                        }
                    }
                }
            }
            WalRecord::Publish { seq, version } => {
                out.push(TAG_PUBLISH);
                put_u64(out, *seq);
                put_u64(out, *version);
            }
        }
    }

    fn decode(payload: &[u8]) -> Option<WalRecord> {
        let mut c = Cursor { buf: payload, at: 0 };
        let rec = match c.u8()? {
            TAG_UPSERT => WalRecord::Upsert {
                seq: c.u64()?,
                ext: c.u64()?,
                coords: c.coords()?,
            },
            TAG_REMOVE => WalRecord::Remove { seq: c.u64()?, ext: c.u64()? },
            TAG_APPLY => {
                let seq = c.u64()?;
                let n = c.u32()? as usize;
                let mut ops = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    let op = match c.u8()? {
                        TAG_UPSERT => {
                            WalOp::Upsert { ext: c.u64()?, coords: c.coords()? }
                        }
                        TAG_REMOVE => WalOp::Remove { ext: c.u64()? },
                        _ => return None,
                    };
                    ops.push(op);
                }
                WalRecord::Apply { seq, ops }
            }
            TAG_PUBLISH => WalRecord::Publish { seq: c.u64()?, version: c.u64()? },
            _ => return None,
        };
        // trailing garbage means a framing bug, not a valid record
        if c.at == payload.len() {
            Some(rec)
        } else {
            None
        }
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_coords(out: &mut Vec<u8>, coords: &[f32]) {
    put_u32(out, coords.len() as u32);
    for &x in coords {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> Option<&[u8]> {
        let end = self.at.checked_add(n)?;
        if end > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.at..end];
        self.at = end;
        Some(s)
    }

    fn u8(&mut self) -> Option<u8> {
        Some(self.take(1)?[0])
    }

    fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }

    fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    fn coords(&mut self) -> Option<Vec<f32>> {
        let dim = self.u32()? as usize;
        // an absurd dim means a corrupt frame; don't let it drive a huge
        // allocation before the bounds check in take() catches it
        let bytes = self.take(dim.checked_mul(4)?)?;
        Some(
            bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect(),
        )
    }
}

/// Appending writer over `<dir>/wal.log`. Records buffer in user space
/// until [`WalWriter::sync`] (the group fsync at publish); the number of
/// appended-but-unsynced records is exposed as [`WalWriter::pending`] so
/// the engine can surface it as the `wal_lag` gauge.
pub struct WalWriter {
    file: BufWriter<File>,
    path: PathBuf,
    pending: u64,
    frame: Vec<u8>,
}

impl WalWriter {
    /// Open (creating if needed) the WAL inside `dir` for appending.
    pub fn open(dir: &Path) -> io::Result<Self> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(WAL_FILE);
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        Ok(WalWriter {
            file: BufWriter::new(file),
            path,
            pending: 0,
            frame: Vec::new(),
        })
    }

    /// Path of the underlying log file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Frame and append one record; returns the framed byte count. The
    /// record is buffered — call [`sync`](WalWriter::sync) to make it
    /// durable.
    pub fn append(&mut self, rec: &WalRecord) -> io::Result<usize> {
        self.frame.clear();
        rec.encode(&mut self.frame);
        let len = self.frame.len() as u32;
        let crc = crc32(&self.frame);
        self.file.write_all(&len.to_le_bytes())?;
        self.file.write_all(&crc.to_le_bytes())?;
        self.file.write_all(&self.frame)?;
        self.pending += 1;
        Ok(self.frame.len() + 8)
    }

    /// Appended-but-unsynced record count (the `wal_lag` gauge).
    pub fn pending(&self) -> u64 {
        self.pending
    }

    /// Group fsync: flush buffered frames and force them to stable
    /// storage. Returns how many records this barrier made durable.
    pub fn sync(&mut self) -> io::Result<u64> {
        self.file.flush()?;
        self.file.get_ref().sync_data()?;
        let n = self.pending;
        self.pending = 0;
        Ok(n)
    }

    /// Drop every record (after a checkpoint has folded them in). The file
    /// is truncated in place and the truncation is fsynced, so a crash
    /// right after leaves an empty (clean) log rather than a stale one.
    pub fn truncate(&mut self) -> io::Result<()> {
        self.file.flush()?;
        let f = self.file.get_mut();
        f.set_len(0)?;
        f.seek(SeekFrom::Start(0))?;
        f.sync_data()?;
        self.pending = 0;
        Ok(())
    }
}

/// Read every valid record from `<dir>/wal.log`. Returns the records plus
/// a `clean` flag: `false` means the log ended in a torn or corrupt frame
/// (expected after a crash mid-append) and recovery proceeds from the
/// returned prefix. A missing file reads as empty and clean.
pub fn read_wal(dir: &Path) -> io::Result<(Vec<WalRecord>, bool)> {
    let path = dir.join(WAL_FILE);
    let mut buf = Vec::new();
    match File::open(&path) {
        Ok(mut f) => {
            f.read_to_end(&mut buf)?;
        }
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok((Vec::new(), true)),
        Err(e) => return Err(e),
    }
    let mut records = Vec::new();
    let mut at = 0usize;
    while at < buf.len() {
        if at + 8 > buf.len() {
            return Ok((records, false)); // torn header
        }
        let len = u32::from_le_bytes(buf[at..at + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(buf[at + 4..at + 8].try_into().unwrap());
        let start = at + 8;
        let Some(end) = start.checked_add(len) else {
            return Ok((records, false));
        };
        if end > buf.len() {
            return Ok((records, false)); // torn payload
        }
        let payload = &buf[start..end];
        if crc32(payload) != crc {
            return Ok((records, false)); // bit rot / torn rewrite
        }
        match WalRecord::decode(payload) {
            Some(rec) => records.push(rec),
            None => return Ok((records, false)),
        }
        at = end;
    }
    Ok((records, true))
}
