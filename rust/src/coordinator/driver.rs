//! Convenience drivers gluing datasets, streams and engines — shared by
//! the CLI, the examples and the bench harness.
//!
//! Since the serve façade landed, the clustering engines themselves are
//! built and driven through [`crate::serve`] (`EngineBuilder` +
//! `run_stream`); this module keeps the *hash-stage* engine selection
//! ([`make_engine`]: native vs AOT-Pallas-artifact hashing), the
//! dataset-to-stream plumbing ([`to_stream_ops`]) and the dataset
//! convenience wrapper ([`stream_dataset`]).

use anyhow::Result;

use crate::data::stream::{self, Order, UpdateOp};
use crate::data::Dataset;
use crate::dbscan::DbscanConfig;
use crate::lsh::GridHasher;
use crate::runtime::engines::{HashingEngine, NativeHashing, XlaHashing};
use crate::runtime::Runtime;
use crate::serve::driver::{run_stream, ServeRunOutcome};
use crate::serve::EngineBuilder;

use super::{BatchReport, StreamOp};

pub use crate::serve::driver::final_quality;

/// Which hashing engine the hash stage should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Native,
    /// AOT Pallas artifact via PJRT; falls back to Native (with a warning)
    /// when no artifact matches the (d, t) configuration.
    Xla,
}

impl EngineKind {
    pub fn from_name(s: &str) -> Option<EngineKind> {
        match s {
            "native" => Some(EngineKind::Native),
            "xla" => Some(EngineKind::Xla),
            _ => None,
        }
    }
}

/// Build a hashing engine whose η/ε match what a clustering structure
/// built from `(cfg, seed)` draws internally (same seed ⇒ same
/// GridHasher).
pub fn make_engine(
    cfg: &DbscanConfig,
    seed: u64,
    kind: EngineKind,
) -> Result<Box<dyn HashingEngine>> {
    let hasher = GridHasher::new(cfg.t, cfg.dim, cfg.eps, seed);
    match kind {
        EngineKind::Native => Ok(Box::new(NativeHashing::new(hasher))),
        EngineKind::Xla => {
            let dir = Runtime::default_dir();
            let rt = Runtime::new(&dir)?;
            match XlaHashing::new(rt, hasher.clone()) {
                Ok(e) => Ok(Box::new(e)),
                Err(e) => {
                    eprintln!(
                        "[coordinator] no XLA hash artifact ({e}); falling back to native"
                    );
                    Ok(Box::new(NativeHashing::new(hasher)))
                }
            }
        }
    }
}

/// Convert dataset-index update ops into coordinator stream ops.
pub fn to_stream_ops(ds: &Dataset, batches: &[Vec<UpdateOp>]) -> Vec<Vec<StreamOp>> {
    batches
        .iter()
        .map(|b| {
            b.iter()
                .map(|op| match op {
                    UpdateOp::Insert(i) => StreamOp::Insert {
                        ext: *i as u64,
                        coords: ds.point(*i).to_vec(),
                    },
                    UpdateOp::Delete(i) => StreamOp::Delete { ext: *i as u64 },
                })
                .collect()
        })
        .collect()
}

/// Stream a dataset (insert-only) through the serve façade's single
/// backend with ground-truth snapshots every `snapshot_every` batches.
pub fn stream_dataset(
    ds: &Dataset,
    cfg: DbscanConfig,
    order: Order,
    batch: usize,
    snapshot_every: usize,
    seed: u64,
    kind: EngineKind,
) -> Result<ServeRunOutcome> {
    let batches = to_stream_ops(ds, &stream::insert_stream(ds, order, batch, seed));
    let engine = EngineBuilder::from_config(cfg).seed(seed).hashing(kind).build()?;
    let labels = &ds.labels;
    let truth = move |e: u64| labels[e as usize];
    run_stream(engine, batches, snapshot_every, Some(&truth))
}

/// Pretty one-line summary for [`super::run_pipeline`] progress logs.
pub fn summarize(r: &BatchReport) -> String {
    format!(
        "batch {:>4}: ops={:<5} live={:<7} cores={:<7} t={:.3}s (cum {:.2}s){}",
        r.seq,
        r.ops,
        r.live_points,
        r.core_points,
        r.apply_s,
        r.cumulative_apply_s,
        match (r.ari, r.nmi) {
            (Some(a), Some(n)) => format!(" ARI={a:.3} NMI={n:.3}"),
            _ => String::new(),
        }
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{make_blobs, BlobsConfig};

    #[test]
    fn stream_dataset_end_to_end() {
        let ds = make_blobs(
            &BlobsConfig {
                n: 600,
                dim: 4,
                clusters: 3,
                std: 0.3,
                center_box: 20.0,
                weights: vec![],
            },
            7,
        );
        let cfg = DbscanConfig { k: 8, t: 10, eps: 0.75, dim: 4, ..Default::default() };
        let out = stream_dataset(
            &ds,
            cfg,
            Order::Random,
            200,
            1,
            11,
            EngineKind::Native,
        )
        .unwrap();
        assert_eq!(out.reports.len(), 3);
        let (ari, nmi) = final_quality(&ds, &out);
        assert!(ari > 0.95, "ari {ari}");
        assert!(nmi > 0.9, "nmi {nmi}");
    }

    #[test]
    fn engine_kind_parsing() {
        assert_eq!(EngineKind::from_name("native"), Some(EngineKind::Native));
        assert_eq!(EngineKind::from_name("xla"), Some(EngineKind::Xla));
        assert_eq!(EngineKind::from_name("gpu"), None);
    }
}
