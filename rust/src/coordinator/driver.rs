//! Convenience drivers gluing datasets, streams, engines and the pipeline —
//! shared by the CLI, the examples and the bench harness.
//!
//! The single-instance apply stage here has a sharded alternative: the
//! same `StreamOp` batches can be fed to [`crate::shard::ShardedEngine`]
//! via the re-exported [`run_sharded`] / [`stream_dataset_sharded`]
//! drivers (S parallel `DynamicDbscan` workers with cross-shard cluster
//! stitching — see [`crate::shard`]).

use anyhow::Result;

use crate::data::stream::{self, Order, UpdateOp};
use crate::data::Dataset;
use crate::dbscan::DbscanConfig;
use crate::lsh::GridHasher;
use crate::runtime::engines::{HashingEngine, NativeHashing, XlaHashing};
use crate::runtime::Runtime;

use super::{run_pipeline, BatchReport, CoordinatorConfig, RunOutcome, StreamOp};

pub use crate::shard::driver::{
    final_quality_sharded, run_sharded, stream_dataset_sharded, summarize_shard,
    ShardReport, ShardedRunOutcome,
};

/// Which hashing engine the hash stage should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Native,
    /// AOT Pallas artifact via PJRT; falls back to Native (with a warning)
    /// when no artifact matches the (d, t) configuration.
    Xla,
}

impl EngineKind {
    pub fn from_name(s: &str) -> Option<EngineKind> {
        match s {
            "native" => Some(EngineKind::Native),
            "xla" => Some(EngineKind::Xla),
            _ => None,
        }
    }
}

/// Build a hashing engine whose η/ε match what `DynamicDbscan::new(cfg,
/// seed)` will draw internally (same seed ⇒ same GridHasher).
pub fn make_engine(
    cfg: &DbscanConfig,
    seed: u64,
    kind: EngineKind,
) -> Result<Box<dyn HashingEngine>> {
    let hasher = GridHasher::new(cfg.t, cfg.dim, cfg.eps, seed);
    match kind {
        EngineKind::Native => Ok(Box::new(NativeHashing::new(hasher))),
        EngineKind::Xla => {
            let dir = Runtime::default_dir();
            let rt = Runtime::new(&dir)?;
            match XlaHashing::new(rt, hasher.clone()) {
                Ok(e) => Ok(Box::new(e)),
                Err(e) => {
                    eprintln!(
                        "[coordinator] no XLA hash artifact ({e}); falling back to native"
                    );
                    Ok(Box::new(NativeHashing::new(hasher)))
                }
            }
        }
    }
}

/// Convert dataset-index update ops into coordinator stream ops.
pub fn to_stream_ops(ds: &Dataset, batches: &[Vec<UpdateOp>]) -> Vec<Vec<StreamOp>> {
    batches
        .iter()
        .map(|b| {
            b.iter()
                .map(|op| match op {
                    UpdateOp::Insert(i) => StreamOp::Insert {
                        ext: *i as u64,
                        coords: ds.point(*i).to_vec(),
                    },
                    UpdateOp::Delete(i) => StreamOp::Delete { ext: *i as u64 },
                })
                .collect()
        })
        .collect()
}

/// Stream a dataset (insert-only) through the pipeline with ground-truth
/// snapshots every `snapshot_every` batches.
pub fn stream_dataset(
    ds: &Dataset,
    cfg: DbscanConfig,
    order: Order,
    batch: usize,
    snapshot_every: usize,
    seed: u64,
    kind: EngineKind,
) -> Result<RunOutcome> {
    let batches = to_stream_ops(ds, &stream::insert_stream(ds, order, batch, seed));
    let mut engine = make_engine(&cfg, seed, kind)?;
    let ccfg = CoordinatorConfig { dbscan: cfg, queue: 4, snapshot_every, seed };
    let labels = &ds.labels;
    let truth = move |e: u64| labels[e as usize];
    run_pipeline(ccfg, engine.as_mut(), batches, Some(&truth))
}

/// Final-state quality of a run (ARI/NMI over the live points).
pub fn final_quality(ds: &Dataset, out: &RunOutcome) -> (f64, f64) {
    let truth: Vec<i64> =
        out.final_labels.iter().map(|&(e, _)| ds.labels[e as usize]).collect();
    let pred: Vec<i64> = out.final_labels.iter().map(|&(_, l)| l).collect();
    crate::metrics::ari_nmi(&truth, &pred)
}

/// Pretty one-line summary for progress logs.
pub fn summarize(r: &BatchReport) -> String {
    format!(
        "batch {:>4}: ops={:<5} live={:<7} cores={:<7} t={:.3}s (cum {:.2}s){}",
        r.seq,
        r.ops,
        r.live_points,
        r.core_points,
        r.apply_s,
        r.cumulative_apply_s,
        match (r.ari, r.nmi) {
            (Some(a), Some(n)) => format!(" ARI={a:.3} NMI={n:.3}"),
            _ => String::new(),
        }
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{make_blobs, BlobsConfig};

    #[test]
    fn stream_dataset_end_to_end() {
        let ds = make_blobs(
            &BlobsConfig {
                n: 600,
                dim: 4,
                clusters: 3,
                std: 0.3,
                center_box: 20.0,
                weights: vec![],
            },
            7,
        );
        let cfg = DbscanConfig { k: 8, t: 10, eps: 0.75, dim: 4, ..Default::default() };
        let out = stream_dataset(
            &ds,
            cfg,
            Order::Random,
            200,
            1,
            11,
            EngineKind::Native,
        )
        .unwrap();
        assert_eq!(out.reports.len(), 3);
        let (ari, nmi) = final_quality(&ds, &out);
        assert!(ari > 0.95, "ari {ari}");
        assert!(nmi > 0.9, "nmi {nmi}");
    }

    #[test]
    fn engine_kind_parsing() {
        assert_eq!(EngineKind::from_name("native"), Some(EngineKind::Native));
        assert_eq!(EngineKind::from_name("xla"), Some(EngineKind::Xla));
        assert_eq!(EngineKind::from_name("gpu"), None);
    }
}
