//! Streaming coordinator: the L3 serving loop.
//!
//! A three-stage, thread-per-stage pipeline over bounded channels (natural
//! backpressure — a slow applier throttles the hasher, a slow hasher
//! throttles ingestion):
//!
//! ```text
//!  source ──batches──▶ [hash stage] ──keyed batches──▶ [apply stage] ──▶ reports
//!            (bounded)   native or       (bounded)      DynamicDbscan
//!                        XLA artifact                   + snapshots
//! ```
//!
//! The hash stage computes bucket keys for every inserted point (batched —
//! this is where the AOT Pallas artifact slots in); the apply stage owns the
//! `DynamicDbscan` structure, tracks per-op latency histograms, and emits a
//! [`BatchReport`] per batch, with optional ARI/NMI snapshots against
//! ground-truth labels. Python never appears anywhere on this path.

pub mod driver;

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};

use anyhow::Result;

use crate::dbscan::{DbscanConfig, DynamicDbscan};
use crate::lsh::BucketKey;
use crate::metrics::ari_nmi;
use crate::runtime::engines::HashingEngine;
use crate::util::stats::LatencyHisto;

/// One update travelling through the pipeline. `ext` is the caller's stable
/// identifier (e.g. dataset row), decoupled from internal `PointId`s.
#[derive(Clone, Debug)]
pub enum StreamOp {
    Insert { ext: u64, coords: Vec<f32> },
    Delete { ext: u64 },
}

/// A batch after the hash stage: ops plus precomputed keys for the inserts
/// (in op order; deletes have no key entry).
struct KeyedBatch {
    seq: usize,
    ops: Vec<StreamOp>,
    keys: Vec<Vec<BucketKey>>,
}

/// Per-batch report from the apply stage.
#[derive(Clone, Debug)]
pub struct BatchReport {
    pub seq: usize,
    pub ops: usize,
    pub live_points: usize,
    pub core_points: usize,
    /// wall time spent applying this batch (seconds)
    pub apply_s: f64,
    /// cumulative apply time since stream start
    pub cumulative_apply_s: f64,
    /// ARI/NMI of current labels vs ground truth (when snapshotting)
    pub ari: Option<f64>,
    pub nmi: Option<f64>,
}

/// Coordinator configuration.
pub struct CoordinatorConfig {
    pub dbscan: DbscanConfig,
    /// bounded channel capacity (batches) between stages
    pub queue: usize,
    /// evaluate ARI/NMI every `snapshot_every` batches (0 = never)
    pub snapshot_every: usize,
    pub seed: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            dbscan: DbscanConfig::default(),
            queue: 4,
            snapshot_every: 0,
            seed: 42,
        }
    }
}

/// Ground truth used by snapshots: `truth_of(ext) -> label`.
pub type TruthFn<'a> = dyn Fn(u64) -> i64 + Sync + 'a;

/// Outcome of a full stream run.
pub struct RunOutcome {
    pub reports: Vec<BatchReport>,
    /// final predicted labels per live ext id (sorted by ext)
    pub final_labels: Vec<(u64, i64)>,
    pub add_latency: LatencyHisto,
    pub delete_latency: LatencyHisto,
    pub total_apply_s: f64,
}

/// Run a batched stream through the pipeline. `engine` runs on the hash
/// stage thread; the apply stage owns the clustering structure. Reports are
/// returned in batch order.
pub fn run_pipeline(
    cfg: CoordinatorConfig,
    engine: &mut dyn HashingEngine,
    batches: Vec<Vec<StreamOp>>,
    truth: Option<&TruthFn>,
) -> Result<RunOutcome> {
    let queue = cfg.queue.max(1);
    let (keyed_tx, keyed_rx): (SyncSender<KeyedBatch>, Receiver<KeyedBatch>) =
        sync_channel(queue);
    let dim = cfg.dbscan.dim;

    std::thread::scope(|scope| -> Result<RunOutcome> {
        // ---- apply stage ------------------------------------------------
        let apply = scope.spawn(move || -> Result<RunOutcome> {
            let mut db = DynamicDbscan::new(cfg.dbscan.clone(), cfg.seed);
            let mut ext_to_pid: rustc_hash::FxHashMap<u64, u64> =
                rustc_hash::FxHashMap::default();
            let mut add_latency = LatencyHisto::new();
            let mut delete_latency = LatencyHisto::new();
            let mut reports = Vec::new();
            let mut cumulative = 0.0f64;
            for KeyedBatch { seq, ops, keys } in keyed_rx.iter() {
                let t0 = std::time::Instant::now();
                let mut key_it = keys.into_iter();
                for op in &ops {
                    match op {
                        StreamOp::Insert { ext, coords } => {
                            let keys = key_it.next().expect("missing keys");
                            let o0 = std::time::Instant::now();
                            let pid = db.add_point_with_keys(coords, &keys);
                            add_latency.record(o0.elapsed().as_nanos() as u64);
                            ext_to_pid.insert(*ext, pid);
                        }
                        StreamOp::Delete { ext } => {
                            let pid = ext_to_pid
                                .remove(ext)
                                .expect("delete of unknown ext id");
                            let o0 = std::time::Instant::now();
                            db.delete_point(pid);
                            delete_latency.record(o0.elapsed().as_nanos() as u64);
                        }
                    }
                }
                let apply_s = t0.elapsed().as_secs_f64();
                cumulative += apply_s;
                let mut report = BatchReport {
                    seq,
                    ops: ops.len(),
                    live_points: db.num_points(),
                    core_points: db.num_core_points(),
                    apply_s,
                    cumulative_apply_s: cumulative,
                    ari: None,
                    nmi: None,
                };
                let snap = cfg.snapshot_every > 0
                    && (seq + 1) % cfg.snapshot_every == 0;
                if snap {
                    if let Some(truth) = truth {
                        let mut exts: Vec<u64> =
                            ext_to_pid.keys().copied().collect();
                        exts.sort_unstable();
                        let pids: Vec<u64> =
                            exts.iter().map(|e| ext_to_pid[e]).collect();
                        let pred = db.labels_for(&pids);
                        let want: Vec<i64> =
                            exts.iter().map(|&e| truth(e)).collect();
                        let (ari, nmi) = ari_nmi(&want, &pred);
                        report.ari = Some(ari);
                        report.nmi = Some(nmi);
                    }
                }
                reports.push(report);
            }
            // final labels
            let mut exts: Vec<u64> = ext_to_pid.keys().copied().collect();
            exts.sort_unstable();
            let pids: Vec<u64> = exts.iter().map(|e| ext_to_pid[e]).collect();
            let labels = db.labels_for(&pids);
            Ok(RunOutcome {
                reports,
                final_labels: exts.into_iter().zip(labels).collect(),
                add_latency,
                delete_latency,
                total_apply_s: cumulative,
            })
        });

        // ---- hash stage (this thread) -----------------------------------
        let mut flat: Vec<f32> = Vec::new();
        for (seq, ops) in batches.into_iter().enumerate() {
            flat.clear();
            let mut n = 0usize;
            for op in &ops {
                if let StreamOp::Insert { coords, .. } = op {
                    assert_eq!(coords.len(), dim, "bad dim in stream op");
                    flat.extend_from_slice(coords);
                    n += 1;
                }
            }
            let keys =
                if n > 0 { engine.keys_batch(&flat, n)? } else { Vec::new() };
            // bounded send: blocks when the applier lags ⇒ backpressure
            keyed_tx
                .send(KeyedBatch { seq, ops, keys })
                .map_err(|_| anyhow::anyhow!("apply stage terminated early"))?;
        }
        drop(keyed_tx); // close the stream
        apply.join().expect("apply stage panicked")
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{make_blobs, BlobsConfig};
    use crate::lsh::GridHasher;
    use crate::runtime::engines::NativeHashing;

    fn blob_ops(n: usize, seed: u64) -> (Vec<Vec<StreamOp>>, Vec<i64>) {
        let ds = make_blobs(
            &BlobsConfig {
                n,
                dim: 4,
                clusters: 3,
                std: 0.3,
                center_box: 20.0,
                weights: vec![],
            },
            seed,
        );
        let ops: Vec<StreamOp> = (0..n)
            .map(|i| StreamOp::Insert { ext: i as u64, coords: ds.point(i).to_vec() })
            .collect();
        let batches = ops.chunks(100).map(|c| c.to_vec()).collect();
        (batches, ds.labels)
    }

    #[test]
    fn pipeline_end_to_end_with_snapshots() {
        let (batches, labels) = blob_ops(800, 3);
        let cfg = CoordinatorConfig {
            dbscan: DbscanConfig { k: 8, t: 10, eps: 0.75, dim: 4, ..Default::default() },
            queue: 2,
            snapshot_every: 2,
            seed: 9,
        };
        let hasher = GridHasher::new(10, 4, 0.75, 9);
        let mut engine = NativeHashing::new(hasher);
        let truth = |e: u64| labels[e as usize];
        let out = run_pipeline(cfg, &mut engine, batches, Some(&truth)).unwrap();
        assert_eq!(out.reports.len(), 8);
        assert_eq!(out.reports.last().unwrap().live_points, 800);
        assert_eq!(out.final_labels.len(), 800);
        // snapshot batches carry metrics; final snapshot near-perfect ARI
        let last_snap = out.reports.iter().rev().find(|r| r.ari.is_some()).unwrap();
        assert!(last_snap.ari.unwrap() > 0.95, "ari={:?}", last_snap.ari);
        assert!(out.add_latency.count() == 800);
        assert!(out.total_apply_s > 0.0);
    }

    #[test]
    fn pipeline_handles_deletes() {
        let (mut batches, _) = blob_ops(300, 5);
        // delete the first 100 points in a trailing batch
        let dels: Vec<StreamOp> =
            (0..100).map(|e| StreamOp::Delete { ext: e as u64 }).collect();
        batches.push(dels);
        let cfg = CoordinatorConfig {
            dbscan: DbscanConfig { k: 6, t: 8, eps: 0.75, dim: 4, ..Default::default() },
            queue: 1,
            snapshot_every: 0,
            seed: 1,
        };
        let hasher = GridHasher::new(8, 4, 0.75, 1);
        let mut engine = NativeHashing::new(hasher);
        let out = run_pipeline(cfg, &mut engine, batches, None).unwrap();
        assert_eq!(out.reports.last().unwrap().live_points, 200);
        assert_eq!(out.delete_latency.count(), 100);
        assert_eq!(out.final_labels.len(), 200);
    }

    #[test]
    #[should_panic(expected = "bad dim")]
    fn dim_mismatch_is_caught() {
        let cfg = CoordinatorConfig::default(); // dim = 2
        let hasher = GridHasher::new(cfg.dbscan.t, 2, 0.75, 1);
        let mut engine = NativeHashing::new(hasher);
        let batches =
            vec![vec![StreamOp::Insert { ext: 0, coords: vec![1.0, 2.0, 3.0] }]];
        let _ = run_pipeline(cfg, &mut engine, batches, None);
    }
}
