//! Grid locality-sensitive hashing (Definition 3 of the paper).
//!
//! A hash function is `h(x) = ⌊(x + η·1_d) / (2ε)⌋` with `η ~ U[0, 2ε)`;
//! two points collide iff all `d` integer grid coordinates agree (Lemma 1:
//! collision probability ≥ 1 − ‖x−y‖₁/2ε, and collision ⟹ ‖x−y‖∞ ≤ 2ε).
//!
//! [`GridHasher`] owns the `t` independent shifts and turns a point into
//! per-function *bucket keys*; [`table::LshTable`] stores the buckets. The
//! numeric quantization here is the exact expression the L1 Pallas kernel
//! computes (`(x + η) * inv_two_eps`, add-then-multiply, f32) so the native
//! and AOT-artifact hashing engines agree bit-for-bit.

pub mod table;

use crate::util::rng::{mix64, Rng};

/// 128-bit bucket key: two independent 64-bit mixes of the grid-coordinate
/// row. Collision probability per pair is ~2⁻¹²⁸ — negligible against the
/// paper's δ. (`table::LshTable` tests confirm keys never collide in
/// practice against exact `Vec<i32>` keys.)
pub type BucketKey = u128;

#[derive(Clone, Debug)]
pub struct GridHasher {
    pub dim: usize,
    pub t: usize,
    pub eps: f32,
    inv_two_eps: f32,
    /// one shift per hash function
    pub etas: Vec<f32>,
}

/// One scaled-and-floored grid coordinate. The f32→i32 `as` cast
/// **saturates** at the type bounds, so a coordinate further than ~2³¹
/// cells from the origin (relative to `eps`) would silently alias into
/// one of the two extreme grid rows — corrupting density estimates with
/// no error anywhere downstream. Debug builds reject such inputs here
/// (NaN included); release builds keep the documented saturating
/// behaviour, which callers must treat as out-of-contract input.
#[inline]
fn grid_coord(v: f32, eta: f32, inv: f32) -> i32 {
    let scaled = ((v + eta) * inv).floor();
    debug_assert!(
        // 2_147_483_520 is the largest f32 below 2³¹; −2³¹ is exact
        (-2_147_483_648.0f32..=2_147_483_520.0).contains(&scaled),
        "grid coordinate {scaled} overflows i32 (|x| too large for eps)"
    );
    scaled as i32
}

impl GridHasher {
    pub fn new(t: usize, dim: usize, eps: f32, seed: u64) -> Self {
        assert!(eps > 0.0 && t > 0 && dim > 0);
        let mut rng = Rng::new(seed);
        let etas = (0..t)
            .map(|_| (rng.next_f64() * 2.0 * eps as f64) as f32)
            .collect();
        GridHasher { dim, t, eps, inv_two_eps: 1.0 / (2.0 * eps), etas }
    }

    #[inline]
    pub fn inv_two_eps(&self) -> f32 {
        self.inv_two_eps
    }

    /// Integer grid coordinates of `x` under hash function `i`.
    /// Exactly `floor((x + eta_i) * inv_two_eps)` in f32 — matching the
    /// Pallas kernel bit-for-bit. Coordinates must scale into i32 range
    /// (|x| ≲ 2³¹·2ε): debug builds assert this, release builds saturate
    /// (see [`grid_coord`]).
    #[inline]
    pub fn coords_into(&self, i: usize, x: &[f32], out: &mut [i32]) {
        debug_assert_eq!(x.len(), self.dim);
        let eta = self.etas[i];
        let inv = self.inv_two_eps;
        for (o, &v) in out.iter_mut().zip(x.iter()) {
            *o = grid_coord(v, eta, inv);
        }
    }

    pub fn coords(&self, i: usize, x: &[f32]) -> Vec<i32> {
        let mut out = vec![0i32; self.dim];
        self.coords_into(i, x, &mut out);
        out
    }

    /// Bucket key from a grid-coordinate row (shared by the native and the
    /// XLA-artifact hashing paths).
    #[inline]
    pub fn key_from_coords(coords: &[i32]) -> BucketKey {
        let mut h1: u64 = 0x243f_6a88_85a3_08d3; // pi digits — arbitrary
        let mut h2: u64 = 0x1319_8a2e_0370_7344;
        for &c in coords {
            let c = c as u32 as u64;
            h1 = mix64(h1 ^ c.wrapping_mul(0x9e37_79b9_7f4a_7c15));
            h2 = mix64(h2 ^ c.wrapping_mul(0xc2b2_ae3d_27d4_eb4f));
        }
        ((h1 as u128) << 64) | h2 as u128
    }

    /// All `t` bucket keys of a point written into `out` (length `t`) —
    /// the allocation-free form of [`Self::keys`] the update hot loop uses.
    pub fn keys_into(&self, x: &[f32], scratch: &mut Vec<i32>, out: &mut [BucketKey]) {
        debug_assert_eq!(out.len(), self.t);
        scratch.resize(self.dim, 0);
        for (i, o) in out.iter_mut().enumerate() {
            self.coords_into(i, x, scratch);
            *o = Self::key_from_coords(scratch);
        }
    }

    /// Batched hashing: `xs` is row-major `n × dim`; writes point-major key
    /// rows (`out[j*t + i]` = key of point j under function i, `out` length
    /// `n × t`). One pass per hash function — the η shift and multiplier
    /// stay hot across the whole batch instead of being reloaded per point.
    pub fn keys_batch_into(
        &self,
        xs: &[f32],
        n: usize,
        scratch: &mut Vec<i32>,
        out: &mut [BucketKey],
    ) {
        debug_assert_eq!(xs.len(), n * self.dim);
        debug_assert_eq!(out.len(), n * self.t);
        scratch.resize(self.dim, 0);
        for i in 0..self.t {
            let eta = self.etas[i];
            let inv = self.inv_two_eps;
            for j in 0..n {
                let row = &xs[j * self.dim..(j + 1) * self.dim];
                for (o, &v) in scratch.iter_mut().zip(row.iter()) {
                    *o = grid_coord(v, eta, inv);
                }
                out[j * self.t + i] = Self::key_from_coords(scratch);
            }
        }
    }

    /// All `t` bucket keys of a point (native path).
    pub fn keys(&self, x: &[f32], scratch: &mut Vec<i32>) -> Vec<BucketKey> {
        let mut out = vec![0; self.t];
        self.keys_into(x, scratch, &mut out);
        out
    }
}

/// 64-bit key for an integer ε-grid cell row — the read-side sibling of
/// [`GridHasher::key_from_coords`], used by the snapshot spatial index
/// (`serve::index`). 64 bits instead of 128 because the index stores keys
/// in a `ChunkedCowMap<_>` (u64-keyed) and a key collision there merely
/// merges two cells' candidate lists — the exact distance filter downstream
/// makes collisions harmless, unlike the write-path LSH buckets.
#[inline]
pub fn cell_key(cell: &[i64]) -> u64 {
    let mut h: u64 = 0x243f_6a88_85a3_08d3; // pi digits — arbitrary
    for &c in cell {
        h = mix64(h ^ (c as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run_prop, Gen};

    #[test]
    fn collision_implies_linf_bound() {
        // Lemma 1 (2): same key (same coords) => ||x-y||_inf <= 2 eps
        run_prop("lsh linf bound", 50, |g: &mut Gen| {
            let dim = g.usize_in(1..=8);
            let eps = g.f64_in(0.1, 2.0) as f32;
            let h = GridHasher::new(4, dim, eps, g.rng.next_u64());
            let n = 64;
            let pts: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..dim).map(|_| g.f64_in(-5.0, 5.0) as f32).collect())
                .collect();
            for i in 0..h.t {
                let coords: Vec<Vec<i32>> =
                    pts.iter().map(|p| h.coords(i, p)).collect();
                for a in 0..n {
                    for b in 0..n {
                        if coords[a] == coords[b] {
                            let linf = pts[a]
                                .iter()
                                .zip(&pts[b])
                                .map(|(x, y)| (x - y).abs())
                                .fold(0f32, f32::max);
                            assert!(
                                linf <= 2.0 * eps + 1e-4,
                                "collision with linf {linf} > 2eps {}",
                                2.0 * eps
                            );
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn collision_probability_lower_bound() {
        // Lemma 1 (1): Pr[h(x)=h(y)] >= 1 - ||x-y||_1/(2 eps), over eta.
        let eps = 1.0f32;
        let dim = 4;
        let trials = 4000;
        let x = vec![0.3f32, -0.7, 1.1, 0.0];
        let y = vec![0.5f32, -0.6, 1.0, 0.2];
        let l1: f32 = x.iter().zip(&y).map(|(a, b)| (a - b).abs()).sum();
        let bound = 1.0 - l1 / (2.0 * eps);
        let mut collide = 0;
        for s in 0..trials {
            let h = GridHasher::new(1, dim, eps, s as u64);
            if h.coords(0, &x) == h.coords(0, &y) {
                collide += 1;
            }
        }
        let freq = collide as f32 / trials as f32;
        assert!(
            freq >= bound - 0.03,
            "collision freq {freq} below Lemma 1 bound {bound}"
        );
    }

    #[test]
    fn keys_deterministic_and_seed_sensitive() {
        let x = vec![1.0f32, 2.0, 3.0];
        let mut s = Vec::new();
        let h1 = GridHasher::new(5, 3, 0.75, 42);
        let h2 = GridHasher::new(5, 3, 0.75, 42);
        let h3 = GridHasher::new(5, 3, 0.75, 43);
        assert_eq!(h1.keys(&x, &mut s), h2.keys(&x, &mut s));
        assert_ne!(h1.keys(&x, &mut s), h3.keys(&x, &mut s));
    }

    #[test]
    fn key_from_coords_is_order_sensitive() {
        let a = GridHasher::key_from_coords(&[1, 2, 3]);
        let b = GridHasher::key_from_coords(&[3, 2, 1]);
        let c = GridHasher::key_from_coords(&[1, 2, 3]);
        assert_ne!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn batched_keys_match_per_point_keys() {
        // keys_batch_into (t-outer, point-inner) must be bit-identical to
        // the per-point path on every input
        run_prop("batched vs per-point keys", 30, |g: &mut Gen| {
            let dim = g.usize_in(1..=8);
            let t = g.usize_in(1..=12);
            let eps = g.f64_in(0.1, 2.0) as f32;
            let h = GridHasher::new(t, dim, eps, g.rng.next_u64());
            let n = g.usize_in(1..=40);
            let mut xs = Vec::with_capacity(n * dim);
            for _ in 0..n * dim {
                xs.push(g.f64_in(-10.0, 10.0) as f32);
            }
            let mut scratch = Vec::new();
            let mut batched = vec![0u128; n * t];
            h.keys_batch_into(&xs, n, &mut scratch, &mut batched);
            for j in 0..n {
                let single = h.keys(&xs[j * dim..(j + 1) * dim], &mut scratch);
                assert_eq!(
                    &batched[j * t..(j + 1) * t],
                    single.as_slice(),
                    "batched keys diverged at point {j}"
                );
            }
        });
    }

    /// Regression (saturation bug): coordinates with |x| ≫ eps used to
    /// silently saturate the f32→i32 cast, aliasing every out-of-range
    /// point into the two extreme grid rows. Debug builds now reject the
    /// input at the cast site instead of corrupting density estimates.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "overflows i32")]
    fn far_from_origin_coordinates_are_rejected_in_debug() {
        let h = GridHasher::new(1, 2, 0.75, 1);
        let mut out = [0i32; 2];
        // 1e13 / (2·0.75) ≈ 6.7e12 ≫ 2³¹: would saturate
        h.coords_into(0, &[1.0e13, 0.0], &mut out);
    }

    /// The guarded cast is bit-identical to the old unchecked expression
    /// on every in-range input (the Pallas-kernel parity contract).
    #[test]
    fn guarded_cast_matches_unchecked_in_range() {
        run_prop("grid_coord parity", 40, |g: &mut Gen| {
            let dim = g.usize_in(1..=6);
            let eps = g.f64_in(0.05, 3.0) as f32;
            let h = GridHasher::new(3, dim, eps, g.rng.next_u64());
            let x: Vec<f32> =
                (0..dim).map(|_| g.f64_in(-1e6, 1e6) as f32).collect();
            for i in 0..h.t {
                let got = h.coords(i, &x);
                let eta = h.etas[i];
                let inv = h.inv_two_eps();
                let want: Vec<i32> =
                    x.iter().map(|&v| ((v + eta) * inv).floor() as i32).collect();
                assert_eq!(got, want, "hash fn {i} diverged");
            }
        });
    }

    #[test]
    fn no_key_collisions_on_distinct_coords() {
        // 128-bit keys over 100k distinct rows: no collisions expected.
        let mut seen = std::collections::HashSet::new();
        for i in 0..100_000i32 {
            let key = GridHasher::key_from_coords(&[i, -i, i ^ 7, i / 3]);
            assert!(seen.insert(key), "key collision at {i}");
        }
    }
}
