//! Hash-bucket tables: one [`LshTable`] per hash function.
//!
//! Each bucket keeps (a) the full member set (whose size against `k`
//! decides core-ness, Definition 4) and (b) the **core members ordered by
//! point index** — a `BTreeSet` giving the `O(log n)` predecessor/successor
//! queries that `LinkCorePoint`/`UnlinkCorePoint` (Algorithm 2, lines
//! 31–32 / 38–39) need to maintain the in-bucket path structure.

use std::collections::BTreeSet;

use rustc_hash::{FxHashMap, FxHashSet};

use super::BucketKey;

/// Monotonically increasing point identifier (`idx(·)` in the paper).
pub type PointId = u64;

#[derive(Debug, Default)]
pub struct Bucket {
    pub members: FxHashSet<PointId>,
    /// Core members ordered by idx — the in-bucket path follows this order.
    pub cores: BTreeSet<PointId>,
}

impl Bucket {
    #[inline]
    pub fn len(&self) -> usize {
        self.members.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Core predecessor of `p` by index (largest core idx < p).
    #[inline]
    pub fn core_pred(&self, p: PointId) -> Option<PointId> {
        self.cores.range(..p).next_back().copied()
    }

    /// Core successor of `p` by index (smallest core idx > p).
    #[inline]
    pub fn core_succ(&self, p: PointId) -> Option<PointId> {
        self.cores.range(p + 1..).next().copied()
    }

    /// Any core member other than `p`, if one exists.
    #[inline]
    pub fn any_core_not(&self, p: PointId) -> Option<PointId> {
        self.cores.iter().copied().find(|&c| c != p)
    }
}

/// Buckets of a single hash function.
#[derive(Debug, Default)]
pub struct LshTable {
    map: FxHashMap<BucketKey, Bucket>,
}

impl LshTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a point; returns the bucket size after insertion.
    pub fn insert(&mut self, key: BucketKey, p: PointId) -> usize {
        let b = self.map.entry(key).or_default();
        let added = b.members.insert(p);
        debug_assert!(added, "point {p} already in bucket");
        b.members.len()
    }

    /// Remove a point (must exist); drops the bucket when it empties.
    pub fn remove(&mut self, key: BucketKey, p: PointId) {
        let b = self.map.get_mut(&key).expect("bucket missing on remove");
        let removed = b.members.remove(&p);
        debug_assert!(removed, "point {p} not in bucket");
        b.cores.remove(&p);
        if b.members.is_empty() {
            self.map.remove(&key);
        }
    }

    #[inline]
    pub fn get(&self, key: BucketKey) -> Option<&Bucket> {
        self.map.get(&key)
    }

    #[inline]
    pub fn get_mut(&mut self, key: BucketKey) -> Option<&mut Bucket> {
        self.map.get_mut(&key)
    }

    #[inline]
    pub fn bucket(&self, key: BucketKey) -> &Bucket {
        self.map.get(&key).expect("bucket missing")
    }

    pub fn mark_core(&mut self, key: BucketKey, p: PointId) {
        let b = self.map.get_mut(&key).expect("bucket missing");
        debug_assert!(b.members.contains(&p));
        b.cores.insert(p);
    }

    pub fn unmark_core(&mut self, key: BucketKey, p: PointId) {
        if let Some(b) = self.map.get_mut(&key) {
            b.cores.remove(&p);
        }
    }

    pub fn num_buckets(&self) -> usize {
        self.map.len()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&BucketKey, &Bucket)> {
        self.map.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_lifecycle() {
        let mut t = LshTable::new();
        assert_eq!(t.insert(7, 1), 1);
        assert_eq!(t.insert(7, 2), 2);
        assert_eq!(t.insert(9, 3), 1);
        assert_eq!(t.num_buckets(), 2);
        t.remove(7, 1);
        assert_eq!(t.bucket(7).len(), 1);
        t.remove(7, 2);
        assert_eq!(t.num_buckets(), 1, "empty bucket must be dropped");
    }

    #[test]
    fn core_ordering_queries() {
        let mut t = LshTable::new();
        for p in [10u64, 20, 30, 40] {
            t.insert(5, p);
        }
        for p in [10u64, 30, 40] {
            t.mark_core(5, p);
        }
        let b = t.bucket(5);
        assert_eq!(b.core_pred(30), Some(10));
        assert_eq!(b.core_succ(30), Some(40));
        assert_eq!(b.core_pred(10), None);
        assert_eq!(b.core_succ(40), None);
        assert_eq!(b.core_pred(25), Some(10));
        assert_eq!(b.core_succ(25), Some(30));
        assert_eq!(b.any_core_not(10), Some(30));
    }

    #[test]
    fn remove_clears_core_flag() {
        let mut t = LshTable::new();
        t.insert(1, 100);
        t.insert(1, 200);
        t.mark_core(1, 100);
        t.remove(1, 100);
        assert!(t.bucket(1).cores.is_empty());
    }
}
