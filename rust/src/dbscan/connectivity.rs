//! Connectivity layer between Algorithm 2 and the Euler-tour forest.
//!
//! ## Why this layer exists — a soundness gap in the paper
//!
//! Algorithm 2's `LINK` "adds an edge only if the endpoints are in
//! different trees". When `LINK(c1,x)` or `LINK(x,c2)` is skipped because
//! the endpoints are already connected *through another bucket's edges*,
//! the bucket path silently depends on that other connectivity. Deleting
//! the shared point later cuts the real edges, and `UnlinkCorePoint` only
//! bridges the (pred, succ) pair — endpoint positions bridge nothing — so
//! colliding cores can end up **disconnected**, violating Theorem 2.
//! Minimal counterexample (d=1, k=2, t=2, found by our machine-checked
//! invariant): points p0, p1, p2 where buckets are `T0 = {p0,p2}`,
//! `T1 = {p0,p1,p2}`; real edges become (p0,p1), (p0,p2) — the T1-path edge
//! (p1,p2) is skipped as a cycle. Deleting p0 cuts both edges and bridges
//! nothing (p0 is the min-idx endpoint in both buckets), leaving cores p1,
//! p2 colliding in T1 but disconnected. See
//! `tests::paper_exact_violates_theorem2`.
//!
//! ## The fix (default mode)
//!
//! [`RepairConn`] maintains the **exact multiset of desired edges** (every
//! bucket's consecutive-core path pairs + non-core attachments) and keeps
//! the Euler-tour forest a spanning forest of that multigraph:
//!
//! * `desire(u,v)`   — multiplicity++; if new, link in the forest or record
//!   as a **non-tree edge**;
//! * `undesire(u,v)` — multiplicity--; when the last desire of a *tree*
//!   edge goes away, cut it and run a **replacement search**: walk the
//!   smaller resulting component (Euler tour traversal, O(size)) looking
//!   for a non-tree edge crossing the cut, promoting it to a tree edge.
//!
//! Correctness is unconditional (the forest always spans the desired
//! multigraph, whose components are exactly the components of `H` plus
//! attachments). The cost of `undesire` is `O(log n)` plus the replacement
//! search — `O(min-component)` worst case without HDT-style edge levels;
//! in the paper's workloads replacement searches are rare and small (the
//! A3 ablation measures this). [`PaperConn`] reproduces the paper's
//! verbatim behaviour for comparison benches.
//!
//! ## The default mode lives elsewhere
//!
//! The production default is [`super::leveled::LeveledConn`]: it keeps
//! `RepairConn`'s exact desired-edge semantics but replaces the
//! `O(min-component)` walk with Holm–de Lichtenberg–Thorup edge levels,
//! restoring the polylogarithmic bound the paper assumes. `RepairConn`
//! stays as the flat ablation reference (the chain-churn bench measures
//! the gap), and this module keeps the shared [`Connectivity`] trait.

use rustc_hash::{FxHashMap, FxHashSet};

use crate::ett::{Forest, VertexId};

/// What Algorithm 2 needs from the connectivity structure.
pub trait Connectivity {
    fn add_vertex(&mut self) -> VertexId;
    fn remove_vertex(&mut self, v: VertexId);
    /// Declare the edge {u,v} desired (bucket-path pair or attachment).
    fn desire(&mut self, u: VertexId, v: VertexId);
    /// Retract one desire of {u,v}.
    fn undesire(&mut self, u: VertexId, v: VertexId) {
        self.undesire_hinted(u, v, &[]);
    }
    /// Retract one desire; `hints` are edges likely to serve as the
    /// replacement if a tree edge is cut (checked in O(1) each before any
    /// component walk). Callers that know the local rewiring (Algorithm 2's
    /// pred/succ bridges) pass them here.
    fn undesire_hinted(
        &mut self,
        u: VertexId,
        v: VertexId,
        hints: &[(VertexId, VertexId)],
    );
    fn root(&self, v: VertexId) -> u64;
    fn connected(&self, u: VertexId, v: VertexId) -> bool {
        self.root(u) == self.root(v)
    }
    fn component_size(&self, v: VertexId) -> usize;
    /// Forest degree (tree edges only).
    fn tree_degree(&self, v: VertexId) -> usize;
    fn has_tree_edge(&self, u: VertexId, v: VertexId) -> bool;
    /// Is {u,v} desired at all (tree or non-tree)?
    fn is_desired(&self, u: VertexId, v: VertexId) -> bool;
    /// Vertices currently live in the forest (leak checks).
    fn live_vertices(&self) -> usize;
    /// Live forest vertices per internal level — flat structures report a
    /// single entry; the leveled structure one per forest. The churn leak
    /// checks assert every entry drains to zero.
    fn live_vertices_per_level(&self) -> Vec<usize> {
        vec![self.live_vertices()]
    }
    /// Replacement-search counters (0 for the paper-exact mode).
    fn repair_stats(&self) -> RepairStats;

    // ------------------------------------------------------------------
    // stable component ids (delta-snapshot plumbing)
    // ------------------------------------------------------------------

    /// Enable stable-component tracking on an empty structure. Flat modes
    /// ignore the request (they serve only the ablation benches);
    /// [`super::leveled::LeveledConn`] implements it — the sharded
    /// serving path's delta reports depend on it.
    fn set_comp_tracking(&mut self, _on: bool) {}

    /// Stable component identifier of `v`'s component. Unlike
    /// [`Connectivity::root`] — which changes whenever the underlying
    /// Euler tour restructures, even when no membership changed — this id
    /// changes only on genuine component merges/splits, and only for the
    /// vertices reported through [`Connectivity::drain_comp_changes`]
    /// (merges keep the larger side's id, splits mint a fresh id for the
    /// smaller side). Falls back to `root` when tracking is off.
    fn comp_id(&self, v: VertexId) -> u64 {
        self.root(v)
    }

    /// Drain the vertices whose stable component id changed since the
    /// last drain (may repeat vertices and include since-removed ones —
    /// consumers filter). No-op without tracking.
    fn drain_comp_changes(&mut self, _f: &mut dyn FnMut(VertexId)) {}

    // ------------------------------------------------------------------
    // observability hooks
    // ------------------------------------------------------------------

    /// Live (multi-)edges currently stored — the `ett_edges` structural
    /// gauge. Flat modes may report 0.
    fn edge_count(&self) -> usize {
        0
    }

    /// Toggle replacement-search stage timing (the `level_promotion`
    /// update-stage span). Off by default; flat modes ignore it.
    fn set_stage_timing(&mut self, _on: bool) {}

    /// Nanoseconds spent in replacement search (incl. level promotion
    /// sweeps) since the last call; resets to 0. Always 0 when stage
    /// timing is off or unimplemented.
    fn take_search_ns(&mut self) -> u64 {
        0
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RepairStats {
    pub nt_edges: usize,
    pub searches: u64,
    pub replacements: u64,
    pub visited: u64,
    /// HDT level promotions: tree or non-tree edges pushed up one level
    /// during replacement search (0 for the flat modes).
    pub pushes: u64,
    /// Live forest levels (1 for the flat modes).
    pub levels: usize,
}

#[inline]
pub(crate) fn ekey(u: VertexId, v: VertexId) -> (VertexId, VertexId) {
    if u < v {
        (u, v)
    } else {
        (v, u)
    }
}

// ---------------------------------------------------------------------
// Paper-exact mode
// ---------------------------------------------------------------------

/// Verbatim Algorithm 2 semantics: `desire` = `G.LINK` (only if acyclic),
/// `undesire` = `G.CUT` (only if that tree edge exists). Violates Theorem 2
/// in the corner documented above — kept for faithful benchmarking.
pub struct PaperConn<F: Forest> {
    pub forest: F,
}

impl<F: Forest> PaperConn<F> {
    pub fn new(forest: F) -> Self {
        PaperConn { forest }
    }
}

impl<F: Forest> Connectivity for PaperConn<F> {
    fn add_vertex(&mut self) -> VertexId {
        self.forest.add_vertex()
    }

    fn remove_vertex(&mut self, v: VertexId) {
        self.forest.remove_vertex(v);
    }

    fn desire(&mut self, u: VertexId, v: VertexId) {
        self.forest.link(u, v);
    }

    fn undesire_hinted(
        &mut self,
        u: VertexId,
        v: VertexId,
        _hints: &[(VertexId, VertexId)],
    ) {
        self.forest.cut(u, v);
    }

    fn root(&self, v: VertexId) -> u64 {
        self.forest.root(v)
    }

    fn component_size(&self, v: VertexId) -> usize {
        self.forest.component_size(v)
    }

    fn tree_degree(&self, v: VertexId) -> usize {
        self.forest.degree(v)
    }

    fn has_tree_edge(&self, u: VertexId, v: VertexId) -> bool {
        self.forest.has_edge(u, v)
    }

    fn is_desired(&self, u: VertexId, v: VertexId) -> bool {
        self.forest.has_edge(u, v)
    }

    fn live_vertices(&self) -> usize {
        self.forest.num_vertices()
    }

    fn repair_stats(&self) -> RepairStats {
        RepairStats { levels: 1, ..RepairStats::default() }
    }
}

// ---------------------------------------------------------------------
// Repair mode (default)
// ---------------------------------------------------------------------

/// Spanning forest of the desired-edge multigraph with non-tree edge
/// bookkeeping and replacement search.
pub struct RepairConn<F: Forest> {
    pub forest: F,
    /// desired multiplicity per unordered pair
    mult: FxHashMap<(VertexId, VertexId), u32>,
    /// non-tree desired edges, per endpoint
    nt_adj: FxHashMap<VertexId, FxHashSet<VertexId>>,
    nt_count: usize,
    stats: RepairStats,
}

impl<F: Forest> RepairConn<F> {
    pub fn new(forest: F) -> Self {
        RepairConn {
            forest,
            mult: FxHashMap::default(),
            nt_adj: FxHashMap::default(),
            nt_count: 0,
            stats: RepairStats::default(),
        }
    }

    fn nt_insert(&mut self, u: VertexId, v: VertexId) {
        self.nt_adj.entry(u).or_default().insert(v);
        self.nt_adj.entry(v).or_default().insert(u);
        self.nt_count += 1;
    }

    fn nt_remove(&mut self, u: VertexId, v: VertexId) -> bool {
        let had = self
            .nt_adj
            .get_mut(&u)
            .map(|s| s.remove(&v))
            .unwrap_or(false);
        if had {
            self.nt_adj.get_mut(&v).map(|s| s.remove(&u));
            self.nt_count -= 1;
        }
        had
    }

    fn is_nt(&self, u: VertexId, v: VertexId) -> bool {
        self.nt_adj.get(&u).map(|s| s.contains(&v)).unwrap_or(false)
    }

    /// Is the desired non-tree edge (a,b) a valid replacement for the cut
    /// that separated `ru` and `rv`? Promote it if so.
    fn try_promote(&mut self, a: VertexId, b: VertexId, ru: u64, rv: u64) -> bool {
        if !self.is_nt(a, b) {
            return false;
        }
        let (ra, rb) = (self.forest.root(a), self.forest.root(b));
        if (ra == ru && rb == rv) || (ra == rv && rb == ru) {
            self.nt_remove(a, b);
            let linked = self.forest.link(a, b);
            debug_assert!(linked);
            self.stats.replacements += 1;
            true
        } else {
            false
        }
    }

    /// After cutting tree edge (u,v): find a non-tree desired edge crossing
    /// the two components and promote it. Fast paths before the walk:
    /// caller-provided hints, then the NT edges incident to the cut
    /// endpoints (which cover Algorithm 2's local rewiring patterns).
    fn replace(&mut self, u: VertexId, v: VertexId, hints: &[(VertexId, VertexId)]) {
        self.stats.searches += 1;
        let (ru, rv) = (self.forest.root(u), self.forest.root(v));
        for &(a, b) in hints {
            if self.try_promote(a, b, ru, rv) {
                return;
            }
        }
        for end in [u, v] {
            if let Some(cands) = self.nt_adj.get(&end) {
                let cands: Vec<VertexId> = cands.iter().copied().collect();
                for z in cands {
                    if self.try_promote(end, z, ru, rv) {
                        return;
                    }
                }
            }
        }
        // full search: walk the smaller side
        let (su, sv) = (
            self.forest.component_size(u),
            self.forest.component_size(v),
        );
        let (small, other_root) = if su <= sv {
            (u, self.forest.root(v))
        } else {
            (v, self.forest.root(u))
        };
        let verts = self.forest.component_vertices(small);
        for w in verts {
            self.stats.visited += 1;
            let Some(cands) = self.nt_adj.get(&w) else { continue };
            let mut found: Option<VertexId> = None;
            for &z in cands {
                if self.forest.root(z) == other_root {
                    found = Some(z);
                    break;
                }
            }
            if let Some(z) = found {
                self.nt_remove(w, z);
                let linked = self.forest.link(w, z);
                debug_assert!(linked);
                self.stats.replacements += 1;
                return;
            }
        }
    }
}

impl<F: Forest> Connectivity for RepairConn<F> {
    fn add_vertex(&mut self) -> VertexId {
        self.forest.add_vertex()
    }

    fn remove_vertex(&mut self, v: VertexId) {
        debug_assert!(
            self.nt_adj.get(&v).map(|s| s.is_empty()).unwrap_or(true),
            "removing vertex {v} with live non-tree edges"
        );
        self.nt_adj.remove(&v);
        self.forest.remove_vertex(v);
    }

    fn desire(&mut self, u: VertexId, v: VertexId) {
        debug_assert_ne!(u, v);
        let m = self.mult.entry(ekey(u, v)).or_insert(0);
        *m += 1;
        if *m == 1 {
            // new desired edge: tree if it connects, else non-tree
            if !self.forest.link(u, v) {
                self.nt_insert(u, v);
            }
        }
    }

    fn undesire_hinted(
        &mut self,
        u: VertexId,
        v: VertexId,
        hints: &[(VertexId, VertexId)],
    ) {
        let key = ekey(u, v);
        let Some(m) = self.mult.get_mut(&key) else {
            debug_assert!(false, "undesire of non-desired edge ({u},{v})");
            return;
        };
        *m -= 1;
        if *m > 0 {
            return;
        }
        self.mult.remove(&key);
        if self.nt_remove(u, v) {
            return; // was non-tree: nothing else to do
        }
        let cut = self.forest.cut(u, v);
        debug_assert!(cut, "desired edge ({u},{v}) neither tree nor non-tree");
        self.replace(u, v, hints);
    }

    fn root(&self, v: VertexId) -> u64 {
        self.forest.root(v)
    }

    fn component_size(&self, v: VertexId) -> usize {
        self.forest.component_size(v)
    }

    fn tree_degree(&self, v: VertexId) -> usize {
        self.forest.degree(v)
    }

    fn has_tree_edge(&self, u: VertexId, v: VertexId) -> bool {
        self.forest.has_edge(u, v)
    }

    fn is_desired(&self, u: VertexId, v: VertexId) -> bool {
        self.mult.contains_key(&ekey(u, v))
    }

    fn live_vertices(&self) -> usize {
        self.forest.num_vertices()
    }

    fn repair_stats(&self) -> RepairStats {
        RepairStats { nt_edges: self.nt_count, levels: 1, ..self.stats }
    }
}

/// Shared connectivity test oracle: plain undirected multigraph + BFS.
#[cfg(test)]
pub(crate) mod testoracle {
    use rustc_hash::FxHashMap;

    pub(crate) struct GraphOracle {
        adj: Vec<FxHashMap<usize, u32>>,
    }

    impl GraphOracle {
        pub(crate) fn new(n: usize) -> Self {
            GraphOracle { adj: vec![FxHashMap::default(); n] }
        }

        pub(crate) fn desire(&mut self, u: usize, v: usize) {
            *self.adj[u].entry(v).or_insert(0) += 1;
            *self.adj[v].entry(u).or_insert(0) += 1;
        }

        pub(crate) fn undesire(&mut self, u: usize, v: usize) {
            let m = self.adj[u].get_mut(&v).unwrap();
            *m -= 1;
            let zero = *m == 0;
            let m2 = self.adj[v].get_mut(&u).unwrap();
            *m2 -= 1;
            debug_assert_eq!(zero, *m2 == 0, "oracle adjacency asymmetric");
            if zero {
                self.adj[u].remove(&v);
                self.adj[v].remove(&u);
            }
        }

        pub(crate) fn connected(&self, u: usize, v: usize) -> bool {
            let mut seen = vec![false; self.adj.len()];
            let mut stack = vec![u];
            seen[u] = true;
            while let Some(x) = stack.pop() {
                if x == v {
                    return true;
                }
                for (&y, _) in &self.adj[x] {
                    if !seen[y] {
                        seen[y] = true;
                        stack.push(y);
                    }
                }
            }
            u == v
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testoracle::GraphOracle;
    use super::*;
    use crate::ett::TreapForest;
    use crate::util::proptest::{run_prop, Gen};

    /// RepairConn must track multigraph connectivity exactly under random
    /// desire/undesire churn — the property the paper-exact mode fails.
    #[test]
    fn repair_conn_matches_graph_oracle() {
        run_prop("repair conn vs graph oracle", 60, |g: &mut Gen| {
            let n = g.usize_in(2..=16);
            let mut c = RepairConn::new(TreapForest::new(g.rng.next_u64()));
            let vs: Vec<VertexId> = (0..n).map(|_| c.add_vertex()).collect();
            let mut o = GraphOracle::new(n);
            let mut desired: Vec<(usize, usize)> = Vec::new();
            for _ in 0..g.usize_in(1..=120) {
                if desired.is_empty() || g.rng.coin(0.6) {
                    let a = g.usize_in(0..=n - 1);
                    let mut b = g.usize_in(0..=n - 1);
                    if a == b {
                        b = (b + 1) % n;
                    }
                    c.desire(vs[a], vs[b]);
                    o.desire(a, b);
                    desired.push((a, b));
                } else {
                    let i = g.usize_in(0..=desired.len() - 1);
                    let (a, b) = desired.swap_remove(i);
                    c.undesire(vs[a], vs[b]);
                    o.undesire(a, b);
                }
                for a in 0..n {
                    for b in 0..n {
                        assert_eq!(
                            c.connected(vs[a], vs[b]),
                            o.connected(a, b),
                            "connectivity({a},{b}) diverged"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn multiplicity_keeps_edge_alive() {
        let mut c = RepairConn::new(TreapForest::new(1));
        let a = c.add_vertex();
        let b = c.add_vertex();
        c.desire(a, b);
        c.desire(a, b); // second bucket desires the same pair
        c.undesire(a, b);
        assert!(c.connected(a, b), "edge must survive one undesire");
        c.undesire(a, b);
        assert!(!c.connected(a, b));
    }

    #[test]
    fn replacement_promotes_nt_edge() {
        // triangle: a-b, b-c tree edges; a-c desired but cyclic (non-tree).
        let mut c = RepairConn::new(TreapForest::new(2));
        let a = c.add_vertex();
        let b = c.add_vertex();
        let x = c.add_vertex();
        c.desire(a, b);
        c.desire(b, x);
        c.desire(a, x); // cycle → non-tree
        assert_eq!(c.repair_stats().nt_edges, 1);
        c.undesire(a, b); // cut tree edge → replacement via (a,x)
        assert!(c.connected(a, b), "replacement search must reconnect");
        let st = c.repair_stats();
        assert_eq!(st.nt_edges, 0);
        assert_eq!(st.replacements, 1);
    }
}
