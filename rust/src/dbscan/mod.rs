//! `DynamicDbscan` — Algorithm 2 of the paper, the system's core.
//!
//! Core points are defined through `t` grid-LSH hash functions
//! (Definition 4: `x` is core iff some bucket containing it has ≥ `k`
//! members). A spanning forest of the collision graph `H` is maintained in
//! an Euler-tour dynamic forest: within every bucket the core points form a
//! path in id order (unless an edge would close a cycle), bounding every
//! core's degree by `2t`; each non-core point attaches to at most one core
//! it collides with. `AddPoint`/`DeletePoint` run in
//! `O(t²k(d + log n))` = `O(d log³n + log⁴n)` for `t,k = O(log n)`
//! (Theorem 1) and preserve the spanning-forest invariant (Theorem 2 —
//! machine-checked by [`invariants`]).
//!
//! ## Memory layout
//!
//! Point storage is a flat slab arena ([`arena::PointArena`]): coordinates
//! and bucket keys live in two contiguous struct-of-arrays vectors
//! (`slot × dim` / `slot × t`), per-point metadata in parallel dense
//! vectors, and deleted slots are recycled through a free list. The update
//! hot loop is allocation-free in steady state: keys are hashed into a
//! reused scratch row, promotion/demotion work lists are reused scratch
//! vectors, and a core's attached set stays inline below
//! [`arena::ATTACH_INLINE`]. Batched ingestion ([`DynamicDbscan::add_points`]
//! / [`DynamicDbscan::apply_batch`]) additionally hashes a whole batch in
//! one cache-friendly pass per hash function.

pub mod arena;
pub mod connectivity;
pub mod invariants;
pub mod leveled;

use std::sync::Arc;

use rustc_hash::FxHashMap;

use crate::ett::{skiplist::SkipSeq, treap::TreapSeq, SkipForest, TreapForest, VertexId};
use crate::lsh::table::{LshTable, PointId};
use crate::lsh::{BucketKey, GridHasher};
use crate::obs::{Metrics, PhaseClock, Stopwatch, UpdateStage};

pub use arena::{AttachedSet, PointArena, ATTACH_INLINE};
pub use connectivity::{Connectivity, PaperConn, RepairConn, RepairStats};
pub use leveled::LeveledConn;

/// Default connectivity: HDT-leveled spanning forests over skip-list
/// Euler tour sequences — `O(log² n)` amortized per edge update (see
/// [`leveled`]).
pub type DefaultConn = LeveledConn<SkipSeq>;
/// The pre-leveled default, kept for ablation: repaired flat spanning
/// forest with `O(min-component)` replacement search.
pub type RepairSkipConn = RepairConn<SkipForest>;
/// The paper's verbatim (unsound — see [`connectivity`]) behaviour.
pub type PaperExactConn = PaperConn<SkipForest>;
/// Repair mode over the treap (Henzinger–King) backend.
pub type TreapConn = RepairConn<TreapForest>;
/// Leveled mode over the treap backend (cross-check).
pub type LeveledTreapConn = LeveledConn<TreapSeq>;

/// Which connectivity layer a clustering structure runs on — the serving
/// façade's ablation axis ([`crate::serve::EngineBuilder::conn`]). Only
/// [`ConnKind::Leveled`] supports the stable component ids the delta
/// publishing path needs; the flat modes are kept for ablation and require
/// full-rebuild publishing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConnKind {
    /// HDT-leveled spanning forests — the production default ([`leveled`]).
    Leveled,
    /// Flat repaired forest with `O(min-component)` replacement search.
    Repair,
    /// The paper's verbatim (unsound corner — see [`connectivity`]) mode.
    Paper,
}

impl ConnKind {
    pub fn from_name(s: &str) -> Option<ConnKind> {
        match s {
            "leveled" => Some(ConnKind::Leveled),
            "repair" => Some(ConnKind::Repair),
            "paper" => Some(ConnKind::Paper),
            _ => None,
        }
    }

    /// Stable component ids ([`Connectivity::comp_id`]) are implemented
    /// only by the leveled structure; everything downstream of delta
    /// publishing requires this.
    pub fn supports_comp_tracking(self) -> bool {
        matches!(self, ConnKind::Leveled)
    }
}

/// Hyper-parameters (paper §5 uses k = 10, t = 10, ε = 0.75 throughout).
#[derive(Clone, Debug)]
pub struct DbscanConfig {
    /// core threshold: bucket size conferring core-ness
    pub k: usize,
    /// number of hash functions
    pub t: usize,
    /// neighborhood radius (bucket side = 2ε)
    pub eps: f32,
    /// data dimensionality
    pub dim: usize,
    /// extension (off = exact Algorithm 2): when a fresh core point arrives,
    /// adopt unattached non-core points in its buckets (O(t·k) extra work).
    pub eager_attach: bool,
}

impl Default for DbscanConfig {
    fn default() -> Self {
        DbscanConfig { k: 10, t: 10, eps: 0.75, dim: 2, eager_attach: false }
    }
}

/// Operation counters (exposed for the perf harness and the polylog
/// update-cost ablation A3). `PartialEq` so the batched and single-op
/// ingestion paths can be asserted identical.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct OpStats {
    pub adds: u64,
    pub deletes: u64,
    pub promotions: u64,
    pub demotions: u64,
    pub forest_links: u64,
    pub forest_cuts: u64,
}

/// One update in a batch fed to [`DynamicDbscan::apply_batch`]. `Add`
/// borrows its coordinates — the batch path never copies them into
/// per-op allocations.
#[derive(Clone, Copy, Debug)]
pub enum Op<'a> {
    Add(&'a [f32]),
    Delete(PointId),
}

/// Every Nth update op (add or delete) has its individual ETT `link`/`cut`
/// calls timed into the `ett_link_cut` stage histogram. Sampling keeps the
/// two extra clock reads per forest edge off the common path while still
/// feeding the histogram true per-splice spans (a cut's span includes any
/// replacement search it triggers; the search share is *also* accumulated
/// separately into `level_promotion`, timed inside the HDT layer).
const SPAN_SAMPLE_EVERY: u32 = 32;

/// The dynamic clustering structure. Generic over the connectivity layer
/// (default: HDT-leveled spanning forests over the paper's skip-list Euler
/// tour sequences — see [`connectivity`] for why the paper's verbatim
/// forest needs repairing and [`leveled`] for the polylog replacement
/// search).
pub struct DynamicDbscan<C: Connectivity = DefaultConn> {
    pub cfg: DbscanConfig,
    pub hasher: GridHasher,
    tables: Vec<LshTable>,
    conn: C,
    arena: PointArena,
    n_core: usize,
    pub stats: OpStats,
    /// reused grid-coordinate row for hashing
    scratch: Vec<i32>,
    /// reused bucket-key rows (1 row for single adds, n for batches)
    scratch_keys: Vec<BucketKey>,
    /// reused flat coordinate buffer for `apply_batch`
    scratch_coords: Vec<f32>,
    /// reused promotion/demotion work list
    scratch_ids: Vec<PointId>,
    /// reused orphan re-attachment work list
    scratch_orphans: Vec<PointId>,
    /// owning point per forest vertex (delta-snapshot plumbing; stale
    /// entries are guarded by the arena's generation check)
    vertex_owner: Vec<PointId>,
    /// points whose stitch-visible flags (core / clustered) may have
    /// changed since the last drain; recorded only while `track_stitch`
    stitch_dirty: Vec<PointId>,
    /// see [`DynamicDbscan::enable_stitch_tracking`]
    track_stitch: bool,
    /// update-stage recorder (see [`DynamicDbscan::set_metrics`]) —
    /// `None` unless an *enabled* registry was attached, so the untimed
    /// path never reads a clock
    obs: Option<Arc<Metrics>>,
    /// rolling update-op counter driving [`SPAN_SAMPLE_EVERY`]
    op_tick: u32,
    /// true while the current op's link/cut spans are being timed
    span_ops: bool,
}

impl DynamicDbscan<DefaultConn> {
    /// `Initialise(k, t, ε)` — O(t·d): draw the t hash shifts.
    pub fn new(cfg: DbscanConfig, seed: u64) -> Self {
        Self::with_conn(cfg, seed, LeveledConn::new(seed ^ 0xF0E57))
    }
}

impl DynamicDbscan<RepairSkipConn> {
    /// Ablation mode: the flat repaired spanning forest that was the
    /// default before HDT edge levels (`O(min-component)` replacement
    /// search — the chain-churn bench measures the gap).
    pub fn repair_mode(cfg: DbscanConfig, seed: u64) -> Self {
        Self::with_conn(cfg, seed, RepairConn::new(SkipForest::new(seed ^ 0xF0E57)))
    }
}

impl DynamicDbscan<PaperExactConn> {
    /// Verbatim Algorithm 2 (unsound in a corner — see [`connectivity`]).
    pub fn paper_exact(cfg: DbscanConfig, seed: u64) -> Self {
        Self::with_conn(cfg, seed, PaperConn::new(SkipForest::new(seed ^ 0xF0E57)))
    }
}

impl<C: Connectivity> DynamicDbscan<C> {
    pub fn with_conn(cfg: DbscanConfig, seed: u64, conn: C) -> Self {
        let hasher = GridHasher::new(cfg.t, cfg.dim, cfg.eps, seed);
        let tables = (0..cfg.t).map(|_| LshTable::new()).collect();
        let arena = PointArena::new(cfg.dim, cfg.t);
        DynamicDbscan {
            cfg,
            hasher,
            tables,
            conn,
            arena,
            n_core: 0,
            stats: OpStats::default(),
            scratch: Vec::new(),
            scratch_keys: Vec::new(),
            scratch_coords: Vec::new(),
            scratch_ids: Vec::new(),
            scratch_orphans: Vec::new(),
            vertex_owner: Vec::new(),
            stitch_dirty: Vec::new(),
            track_stitch: false,
            obs: None,
            op_tick: 0,
            span_ops: false,
        }
    }

    /// Attach the engine's shared metrics registry: per-update stage spans
    /// (`hash` / `neighbor_query` / `ett_link_cut` / `level_promotion`)
    /// are recorded into it, and the connectivity layer starts timing its
    /// replacement search. A disabled registry detaches instead, so the
    /// hot path pays nothing when observation is off.
    pub fn set_metrics(&mut self, m: Arc<Metrics>) {
        if m.enabled() {
            self.conn.set_stage_timing(true);
            self.obs = Some(m);
        } else {
            self.conn.set_stage_timing(false);
            self.obs = None;
        }
    }

    /// Enable delta-snapshot change tracking: stable component ids in the
    /// connectivity layer plus dirty-point recording on every core /
    /// attachment flip. Must be called before any point is added. The
    /// sharded serving workers use this to ship `(ext, local-root)`
    /// *changes* instead of full state dumps; the single-instance path
    /// leaves it off and pays nothing.
    pub fn enable_stitch_tracking(&mut self) {
        assert_eq!(
            self.num_points(),
            0,
            "enable_stitch_tracking on a non-empty structure"
        );
        self.track_stitch = true;
        self.conn.set_comp_tracking(true);
    }

    /// Construct with externally computed hash shifts (used when the XLA
    /// hashing engine owns the η vector — it must match `hasher.etas`).
    pub fn hasher_mut(&mut self) -> &mut GridHasher {
        &mut self.hasher
    }

    // ------------------------------------------------------------------
    // queries
    // ------------------------------------------------------------------

    pub fn num_points(&self) -> usize {
        self.arena.len()
    }

    pub fn num_core_points(&self) -> usize {
        self.n_core
    }

    pub fn is_core(&self, p: PointId) -> bool {
        self.arena.get(p).map(|s| self.arena.is_core(s)).unwrap_or(false)
    }

    pub fn contains(&self, p: PointId) -> bool {
        self.arena.contains(p)
    }

    pub fn point_coords(&self, p: PointId) -> Option<&[f32]> {
        self.arena.get(p).map(|s| self.arena.coords_row(s))
    }

    /// `GetCluster(x)`: canonical cluster identifier — O(log n). Stable
    /// between updates; noise points (unattached non-cores) are singleton
    /// clusters.
    pub fn get_cluster(&self, p: PointId) -> u64 {
        let s = self.arena.require(p);
        self.conn.root(self.arena.vertex(s))
    }

    /// Live point ids (unordered).
    pub fn point_ids(&self) -> impl Iterator<Item = PointId> + '_ {
        self.arena.ids()
    }

    /// True when `p` is currently live noise: non-core and unattached —
    /// the singleton case `labels_for` reports as −1 (false for unknown
    /// ids, like [`Self::is_core`]). Used by the sharded engine's
    /// stitcher to decide which replicas carry cluster identity.
    pub fn is_noise(&self, p: PointId) -> bool {
        self.arena
            .get(p)
            .map(|s| !self.arena.is_core(s) && self.arena.attached_to(s).is_none())
            .unwrap_or(false)
    }

    /// Live points (= arena slots in use).
    pub fn live_slots(&self) -> usize {
        self.arena.len()
    }

    /// Arena slots ever allocated (live + free-listed for reuse); stable
    /// under churn once the high-water mark is reached.
    pub fn capacity_slots(&self) -> usize {
        self.arena.capacity_slots()
    }

    /// Vertices currently live in the connectivity forest (one per live
    /// point; 0 after a full drain — the leak check the churn tests use).
    pub fn live_vertices(&self) -> usize {
        self.conn.live_vertices()
    }

    /// Live forest vertices per connectivity level (a single entry for
    /// the flat modes, one per HDT forest for the leveled default). The
    /// churn leak checks assert every level drains to zero.
    pub fn conn_level_live(&self) -> Vec<usize> {
        self.conn.live_vertices_per_level()
    }

    /// Stable cluster identifier of `p` — like [`Self::get_cluster`] but
    /// backed by the connectivity layer's stable component ids (see
    /// [`Connectivity::comp_id`]): the id changes only when the cluster's
    /// *membership* changes, and every point whose id changed is reported
    /// through [`Self::drain_stitch_changes`]. Requires
    /// [`Self::enable_stitch_tracking`] for stability; falls back to the
    /// (restructure-sensitive) forest root otherwise.
    pub fn stable_cluster(&self, p: PointId) -> u64 {
        let s = self.arena.require(p);
        self.conn.comp_id(self.arena.vertex(s))
    }

    /// Drain the live points whose stitch-visible state — core flag,
    /// clustered flag or stable cluster id — may have changed since the
    /// last drain. May report false positives (unchanged points), never
    /// false negatives. Requires [`Self::enable_stitch_tracking`].
    pub fn drain_stitch_changes(&mut self, f: &mut dyn FnMut(PointId)) {
        debug_assert!(self.track_stitch, "stitch tracking is not enabled");
        // component-membership changes surfaced by the connectivity layer
        let owner = &self.vertex_owner;
        let arena = &self.arena;
        self.conn.drain_comp_changes(&mut |v| {
            if let Some(&pid) = owner.get(v as usize) {
                if arena.contains(pid) {
                    f(pid);
                }
            }
        });
        // direct flag flips recorded by the update path
        for pid in self.stitch_dirty.drain(..) {
            if self.arena.contains(pid) {
                f(pid);
            }
        }
    }

    /// Dense labels for a set of points: clusters numbered 0.., noise
    /// (unattached non-core singletons) labeled −1 to match sklearn
    /// conventions in the metrics.
    pub fn labels_for(&self, ids: &[PointId]) -> Vec<i64> {
        let mut roots: FxHashMap<u64, i64> = FxHashMap::default();
        let mut out = Vec::with_capacity(ids.len());
        for &p in ids {
            let s = self.arena.require(p);
            if !self.arena.is_core(s) && self.arena.attached_to(s).is_none() {
                out.push(-1);
                continue;
            }
            let r = self.conn.root(self.arena.vertex(s));
            let next = roots.len() as i64;
            out.push(*roots.entry(r).or_insert(next));
        }
        out
    }

    // ------------------------------------------------------------------
    // AddPoint
    // ------------------------------------------------------------------

    /// `AddPoint(x)` with natively computed hash keys. Allocation-free in
    /// steady state: keys land in a reused scratch row, the point in a
    /// recycled arena slot.
    pub fn add_point(&mut self, x: &[f32]) -> PointId {
        let mut kbuf = std::mem::take(&mut self.scratch_keys);
        kbuf.clear();
        kbuf.resize(self.cfg.t, 0);
        let mut sbuf = std::mem::take(&mut self.scratch);
        self.hasher.keys_into(x, &mut sbuf, &mut kbuf);
        self.scratch = sbuf;
        let idx = self.add_point_with_keys(x, &kbuf);
        self.scratch_keys = kbuf;
        idx
    }

    /// Batched `AddPoint`: `xs` is row-major `n × dim`. Hashes the whole
    /// batch in one pass per hash function (the η shift and multiplier
    /// stay in registers across the batch) before applying the inserts in
    /// order. Returns the new ids, in input order.
    pub fn add_points(&mut self, xs: &[f32], n: usize) -> Vec<PointId> {
        let (d, t) = (self.cfg.dim, self.cfg.t);
        assert_eq!(xs.len(), n * d, "flat coords length must be n × dim");
        let mut kbuf = std::mem::take(&mut self.scratch_keys);
        kbuf.clear();
        kbuf.resize(n * t, 0);
        let mut sbuf = std::mem::take(&mut self.scratch);
        self.hasher.keys_batch_into(xs, n, &mut sbuf, &mut kbuf);
        self.scratch = sbuf;
        let mut ids = Vec::with_capacity(n);
        for j in 0..n {
            ids.push(
                self.add_point_with_keys(&xs[j * d..(j + 1) * d], &kbuf[j * t..(j + 1) * t]),
            );
        }
        self.scratch_keys = kbuf;
        ids
    }

    /// Apply a mixed add/delete batch. Adds are batch-hashed up front
    /// (hashing is pure in the coordinates, so interleaved deletes cannot
    /// change their keys); ops then apply in order. Returns the ids of the
    /// added points, in op order — semantically identical to issuing the
    /// same `add_point`/`delete_point` calls one by one.
    pub fn apply_batch(&mut self, ops: &[Op]) -> Vec<PointId> {
        let (d, t) = (self.cfg.dim, self.cfg.t);
        let mut flat = std::mem::take(&mut self.scratch_coords);
        flat.clear();
        let mut n_adds = 0usize;
        for op in ops {
            if let Op::Add(x) = *op {
                assert_eq!(x.len(), d, "point dimensionality mismatch in batch");
                flat.extend_from_slice(x);
                n_adds += 1;
            }
        }
        let mut kbuf = std::mem::take(&mut self.scratch_keys);
        kbuf.clear();
        kbuf.resize(n_adds * t, 0);
        let mut sbuf = std::mem::take(&mut self.scratch);
        self.hasher.keys_batch_into(&flat, n_adds, &mut sbuf, &mut kbuf);
        self.scratch = sbuf;
        let mut ids = Vec::with_capacity(n_adds);
        let mut j = 0usize;
        for op in ops {
            match *op {
                Op::Add(x) => {
                    ids.push(self.add_point_with_keys(x, &kbuf[j * t..(j + 1) * t]));
                    j += 1;
                }
                Op::Delete(p) => self.delete_point(p),
            }
        }
        self.scratch_keys = kbuf;
        self.scratch_coords = flat;
        ids
    }

    /// `AddPoint(x)` with precomputed bucket keys (the XLA-artifact hashing
    /// path and the shard workers' batch path; keys must come from the same
    /// η/ε as `self.hasher`).
    pub fn add_point_with_keys(&mut self, x: &[f32], keys: &[BucketKey]) -> PointId {
        assert_eq!(x.len(), self.cfg.dim, "point dimensionality mismatch");
        assert_eq!(keys.len(), self.cfg.t);
        self.tick_span_sampling();
        self.stats.adds += 1;
        let vertex = self.conn.add_vertex();
        let idx = self.arena.alloc(x, keys, vertex);
        let vi = vertex as usize;
        if vi >= self.vertex_owner.len() {
            self.vertex_owner.resize(vi + 1, u64::MAX);
        }
        self.vertex_owner[vi] = idx;
        if self.track_stitch {
            self.stitch_dirty.push(idx);
        }
        // bucket insertion + new-core detection (Algorithm 2 lines 6-11)
        let mut clk = PhaseClock::maybe(self.obs.is_some());
        let mut newly_core = std::mem::take(&mut self.scratch_ids);
        newly_core.clear();
        let mut self_core = false;
        for (i, &key) in keys.iter().enumerate() {
            let size = self.tables[i].insert(key, idx);
            if size > self.cfg.k {
                self_core = true;
            } else if size == self.cfg.k {
                // the whole bucket crosses the threshold
                self_core = true;
                let b = self.tables[i].bucket(key);
                for &y in &b.members {
                    if y != idx && !self.arena.is_core(self.arena.slot_unchecked(y)) {
                        newly_core.push(y);
                    }
                }
            }
        }
        if self_core {
            newly_core.push(idx);
        }
        newly_core.sort_unstable();
        newly_core.dedup();
        if let (Some(clk), Some(m)) = (clk.as_mut(), self.obs.as_deref()) {
            m.record_update_stage(UpdateStage::NeighborQuery, clk.lap());
        }
        // promote + link each new core (lines 12-14)
        for &c in &newly_core {
            self.promote(c);
        }
        newly_core.clear();
        self.scratch_ids = newly_core;
        if !self_core {
            // line 15-16
            self.link_non_core(idx);
        } else if self.cfg.eager_attach {
            self.eager_attach(idx);
        }
        if let (Some(clk), Some(m)) = (clk.as_mut(), self.obs.as_deref()) {
            // per-splice `ett_link_cut` spans are sampled at the call sites
            // (every SPAN_SAMPLE_EVERY-th op); the replacement-search share
            // is timed inside the HDT search and drained here
            let search = self.conn.take_search_ns();
            let _ = clk.lap();
            m.record_update_stage(UpdateStage::LevelPromotion, search);
        }
        idx
    }

    /// Arm per-splice span timing for every [`SPAN_SAMPLE_EVERY`]-th
    /// update op (no-op, and no clock reads, while metrics are detached).
    fn tick_span_sampling(&mut self) {
        self.span_ops = if self.obs.is_some() {
            self.op_tick = self.op_tick.wrapping_add(1);
            self.op_tick % SPAN_SAMPLE_EVERY == 0
        } else {
            false
        };
    }

    fn record_span(&self, sw: Stopwatch) {
        if let Some(m) = self.obs.as_deref() {
            m.record_update_stage(UpdateStage::EttLinkCut, sw.elapsed_ns());
        }
    }

    fn timed_desire(&mut self, u: VertexId, v: VertexId) {
        if self.span_ops {
            let sw = Stopwatch::start();
            self.conn.desire(u, v);
            self.record_span(sw);
        } else {
            self.conn.desire(u, v);
        }
    }

    fn timed_undesire(&mut self, u: VertexId, v: VertexId) {
        if self.span_ops {
            let sw = Stopwatch::start();
            self.conn.undesire(u, v);
            self.record_span(sw);
        } else {
            self.conn.undesire(u, v);
        }
    }

    fn timed_undesire_hinted(
        &mut self,
        u: VertexId,
        v: VertexId,
        hints: &[(VertexId, VertexId)],
    ) {
        if self.span_ops {
            let sw = Stopwatch::start();
            self.conn.undesire_hinted(u, v, hints);
            self.record_span(sw);
        } else {
            self.conn.undesire_hinted(u, v, hints);
        }
    }

    /// Mark `c` core in all its buckets, then splice it into each bucket's
    /// core path (`LinkCorePoint`, lines 28-35).
    fn promote(&mut self, c: PointId) {
        let cs = self.arena.slot_unchecked(c);
        debug_assert!(!self.arena.is_core(cs));
        self.stats.promotions += 1;
        self.n_core += 1;
        if self.track_stitch {
            self.stitch_dirty.push(c);
        }
        for i in 0..self.cfg.t {
            let key = self.arena.key(cs, i);
            self.tables[i].mark_core(key, c);
        }
        self.arena.set_core(cs, true);
        // line 29: cut any edge incident to c (it was non-core: ≤ 1 edge)
        if let Some(h) = self.arena.take_attached_to(cs) {
            let hs = self.arena.slot_unchecked(h);
            let (vc, vh) = (self.arena.vertex(cs), self.arena.vertex(hs));
            self.timed_undesire(vc, vh);
            self.stats.forest_cuts += 1;
            let removed = self.arena.attached_mut(hs).remove(c);
            debug_assert!(removed);
        }
        // lines 30-35: splice into the id-ordered core path of each bucket
        let vc = self.arena.vertex(cs);
        for i in 0..self.cfg.t {
            let key = self.arena.key(cs, i);
            let (c1, c2) = {
                let b = self.tables[i].bucket(key);
                (b.core_pred(c), b.core_succ(c))
            };
            // Desire the new path edges before retracting (c1,c2) so the
            // retraction's replacement is found in O(1) via the hint.
            let v1 = c1.map(|p| self.arena.vertex(self.arena.slot_unchecked(p)));
            let v2 = c2.map(|p| self.arena.vertex(self.arena.slot_unchecked(p)));
            if let Some(v1) = v1 {
                self.timed_desire(v1, vc);
                self.stats.forest_links += 1;
            }
            if let Some(v2) = v2 {
                self.timed_desire(vc, v2);
                self.stats.forest_links += 1;
            }
            if let (Some(v1), Some(v2)) = (v1, v2) {
                self.timed_undesire_hinted(v1, v2, &[(v1, vc), (vc, v2)]);
                self.stats.forest_cuts += 1;
            }
        }
    }

    /// `LinkNonCorePoint` (lines 44-45): attach to one colliding core.
    fn link_non_core(&mut self, p: PointId) {
        let ps = self.arena.slot_unchecked(p);
        debug_assert!(!self.arena.is_core(ps));
        debug_assert!(self.arena.attached_to(ps).is_none());
        let mut target = None;
        for i in 0..self.cfg.t {
            let key = self.arena.key(ps, i);
            if let Some(b) = self.tables[i].get(key) {
                if let Some(c) = b.any_core_not(p) {
                    target = Some(c);
                    break;
                }
            }
        }
        if let Some(c) = target {
            let cs = self.arena.slot_unchecked(c);
            let (vp, vc) = (self.arena.vertex(ps), self.arena.vertex(cs));
            self.timed_desire(vp, vc);
            self.stats.forest_links += 1;
            self.arena.set_attached_to(ps, Some(c));
            self.arena.attached_mut(cs).insert(p);
            if self.track_stitch {
                self.stitch_dirty.push(p);
            }
        }
    }

    /// Extension: adopt unattached non-core points in the buckets of the
    /// fresh core `c`.
    fn eager_attach(&mut self, c: PointId) {
        let cs = self.arena.slot_unchecked(c);
        let mut orphans = std::mem::take(&mut self.scratch_orphans);
        orphans.clear();
        for i in 0..self.cfg.t {
            let key = self.arena.key(cs, i);
            if let Some(b) = self.tables[i].get(key) {
                for &y in &b.members {
                    if y != c {
                        let ys = self.arena.slot_unchecked(y);
                        if !self.arena.is_core(ys) && self.arena.attached_to(ys).is_none()
                        {
                            orphans.push(y);
                        }
                    }
                }
            }
        }
        orphans.sort_unstable();
        orphans.dedup();
        for &y in &orphans {
            self.link_non_core(y);
        }
        orphans.clear();
        self.scratch_orphans = orphans;
    }

    // ------------------------------------------------------------------
    // DeletePoint
    // ------------------------------------------------------------------

    /// `DeletePoint(x)` (lines 17-27).
    pub fn delete_point(&mut self, p: PointId) {
        assert!(self.arena.contains(p), "delete of unknown point {p}");
        self.tick_span_sampling();
        self.stats.deletes += 1;
        let mut clk = PhaseClock::maybe(self.obs.is_some());
        let ps = self.arena.slot_unchecked(p);
        let is_core = self.arena.is_core(ps);
        if is_core {
            // line 19-22: cores demoted by this removal — y loses core-ness
            // iff after removing x from every bucket, none of y's buckets
            // has ≥ k members.
            let mut demoted = std::mem::take(&mut self.scratch_ids);
            demoted.clear();
            for i in 0..self.cfg.t {
                let key = self.arena.key(ps, i);
                let b = self.tables[i].bucket(key);
                if b.len() == self.cfg.k {
                    for &y in &b.members {
                        if y != p
                            && self.arena.is_core(self.arena.slot_unchecked(y))
                            && !self.still_core_without(y, p)
                        {
                            demoted.push(y);
                        }
                    }
                }
            }
            demoted.sort_unstable();
            demoted.dedup();
            if let (Some(clk), Some(m)) = (clk.as_mut(), self.obs.as_deref()) {
                m.record_update_stage(UpdateStage::NeighborQuery, clk.lap());
            }
            // unlink x itself first (its pred/succ computed while it is
            // still marked), re-link its attached non-cores elsewhere
            self.unlink_core(p);
            self.demote_marks(p);
            self.reattach_orphans_of(p);
            // drop x from all buckets before processing the demotions
            for i in 0..self.cfg.t {
                let key = self.arena.key(ps, i);
                self.tables[i].remove(key, p);
            }
            // lines 23-26
            for &c in &demoted {
                self.unlink_core(c);
                self.demote_marks(c);
                self.reattach_orphans_of(c);
                self.link_non_core(c);
            }
            demoted.clear();
            self.scratch_ids = demoted;
        } else {
            if let Some(h) = self.arena.take_attached_to(ps) {
                let hs = self.arena.slot_unchecked(h);
                let (vp, vh) = (self.arena.vertex(ps), self.arena.vertex(hs));
                self.timed_undesire(vp, vh);
                self.stats.forest_cuts += 1;
                let removed = self.arena.attached_mut(hs).remove(p);
                debug_assert!(removed);
            }
            for i in 0..self.cfg.t {
                let key = self.arena.key(ps, i);
                self.tables[i].remove(key, p);
            }
        }
        if let (Some(clk), Some(m)) = (clk.as_mut(), self.obs.as_deref()) {
            // as in `add_point_with_keys`: sampled spans feed
            // `ett_link_cut`, the search share feeds `level_promotion`
            let search = self.conn.take_search_ns();
            let _ = clk.lap();
            m.record_update_stage(UpdateStage::LevelPromotion, search);
        }
        // line 27: remove x from G and the point store (slot to free list)
        let vertex = self.arena.vertex(ps);
        debug_assert_eq!(
            self.conn.tree_degree(vertex),
            0,
            "point {p} still has forest edges at removal"
        );
        self.arena.free(p);
        self.conn.remove_vertex(vertex);
        self.vertex_owner[vertex as usize] = u64::MAX;
    }

    /// Would `y` still be core after removing `x` from every bucket?
    fn still_core_without(&self, y: PointId, x: PointId) -> bool {
        let ys = self.arena.slot_unchecked(y);
        let xs = self.arena.slot_unchecked(x);
        for i in 0..self.cfg.t {
            let key = self.arena.key(ys, i);
            let len = self.tables[i].bucket(key).len();
            let contains_x = self.arena.key(xs, i) == key;
            if len - usize::from(contains_x) >= self.cfg.k {
                return true;
            }
        }
        false
    }

    /// `UnlinkCorePoint` (lines 36-42): remove `c` from every bucket's core
    /// path, bridging its neighbors.
    fn unlink_core(&mut self, c: PointId) {
        let cs = self.arena.slot_unchecked(c);
        debug_assert!(self.arena.is_core(cs));
        let vc = self.arena.vertex(cs);
        for i in 0..self.cfg.t {
            let key = self.arena.key(cs, i);
            let (c1, c2) = {
                let b = self.tables[i].bucket(key);
                (b.core_pred(c), b.core_succ(c))
            };
            let v1 = c1.map(|p| self.arena.vertex(self.arena.slot_unchecked(p)));
            let v2 = c2.map(|p| self.arena.vertex(self.arena.slot_unchecked(p)));
            // Bridge (c1,c2) first so the two retractions below repair
            // through the hint instead of a component walk.
            let mut bridge: Option<(VertexId, VertexId)> = None;
            if let (Some(v1), Some(v2)) = (v1, v2) {
                self.timed_desire(v1, v2);
                self.stats.forest_links += 1;
                bridge = Some((v1, v2));
            }
            let hints: &[(VertexId, VertexId)] = match &bridge {
                Some(b) => std::slice::from_ref(b),
                None => &[],
            };
            if let Some(v1) = v1 {
                self.timed_undesire_hinted(v1, vc, hints);
                self.stats.forest_cuts += 1;
            }
            if let Some(v2) = v2 {
                self.timed_undesire_hinted(vc, v2, hints);
                self.stats.forest_cuts += 1;
            }
        }
    }

    /// Clear core marks of `c` in all tables and flip its flag.
    fn demote_marks(&mut self, c: PointId) {
        self.stats.demotions += 1;
        self.n_core -= 1;
        if self.track_stitch {
            self.stitch_dirty.push(c);
        }
        let cs = self.arena.slot_unchecked(c);
        for i in 0..self.cfg.t {
            let key = self.arena.key(cs, i);
            self.tables[i].unmark_core(key, c);
        }
        self.arena.set_core(cs, false);
    }

    /// Line 43 / 26: re-link every non-core point that was attached to `c`.
    fn reattach_orphans_of(&mut self, c: PointId) {
        let cs = self.arena.slot_unchecked(c);
        let mut orphans = std::mem::take(&mut self.scratch_orphans);
        orphans.clear();
        self.arena.attached_mut(cs).drain_into(&mut orphans);
        let vc = self.arena.vertex(cs);
        for &nc in &orphans {
            let ns = self.arena.slot_unchecked(nc);
            let vn = self.arena.vertex(ns);
            self.timed_undesire(vc, vn);
            self.stats.forest_cuts += 1;
            self.arena.set_attached_to(ns, None);
            if self.track_stitch {
                // re-linking may fail (orphan turns noise) — record the
                // flip either way; link_non_core re-records on success
                self.stitch_dirty.push(nc);
            }
            self.link_non_core(nc);
        }
        orphans.clear();
        self.scratch_orphans = orphans;
    }

    // ------------------------------------------------------------------
    // introspection for invariants / benches
    // ------------------------------------------------------------------

    pub(crate) fn conn(&self) -> &C {
        &self.conn
    }

    /// Replacement-search counters from the connectivity layer.
    pub fn repair_stats(&self) -> RepairStats {
        self.conn.repair_stats()
    }

    /// Live (multi-)edges in the connectivity layer — the `ett_edges`
    /// structural gauge (0 for modes that don't track it).
    pub fn conn_edge_count(&self) -> usize {
        self.conn.edge_count()
    }

    pub(crate) fn tables(&self) -> &[LshTable] {
        &self.tables
    }

    pub(crate) fn point_state(
        &self,
        p: PointId,
    ) -> (bool, Option<PointId>, &AttachedSet, VertexId) {
        let s = self.arena.require(p);
        (
            self.arena.is_core(s),
            self.arena.attached_to(s),
            self.arena.attached(s),
            self.arena.vertex(s),
        )
    }

    pub(crate) fn point_keys(&self, p: PointId) -> &[BucketKey] {
        self.arena.key_row(self.arena.require(p))
    }
}

/// Dispatch an [`AnyDbscan`] method to whichever connectivity mode it
/// wraps.
macro_rules! with_db {
    ($self:expr, $db:ident => $e:expr) => {
        match $self {
            AnyDbscan::Leveled($db) => $e,
            AnyDbscan::Repair($db) => $e,
            AnyDbscan::Paper($db) => $e,
        }
    };
}

/// A [`DynamicDbscan`] over any of the three connectivity modes behind one
/// concrete type — the handle the serving layer ([`crate::serve`] and the
/// shard workers) holds, so the connectivity ablation runs through the
/// production engines instead of only through hand-rolled bench loops.
/// Delegates the update/query surface the serving path uses; everything
/// else stays on the typed structure.
pub enum AnyDbscan {
    Leveled(DynamicDbscan<DefaultConn>),
    Repair(DynamicDbscan<RepairSkipConn>),
    Paper(DynamicDbscan<PaperExactConn>),
}

impl AnyDbscan {
    pub fn new(kind: ConnKind, cfg: DbscanConfig, seed: u64) -> AnyDbscan {
        match kind {
            ConnKind::Leveled => AnyDbscan::Leveled(DynamicDbscan::new(cfg, seed)),
            ConnKind::Repair => {
                AnyDbscan::Repair(DynamicDbscan::repair_mode(cfg, seed))
            }
            ConnKind::Paper => AnyDbscan::Paper(DynamicDbscan::paper_exact(cfg, seed)),
        }
    }

    pub fn kind(&self) -> ConnKind {
        match self {
            AnyDbscan::Leveled(_) => ConnKind::Leveled,
            AnyDbscan::Repair(_) => ConnKind::Repair,
            AnyDbscan::Paper(_) => ConnKind::Paper,
        }
    }

    pub fn hasher(&self) -> &GridHasher {
        with_db!(self, db => &db.hasher)
    }

    /// See [`DynamicDbscan::enable_stitch_tracking`]. Requires a mode
    /// whose connectivity supports stable component ids.
    pub fn enable_stitch_tracking(&mut self) {
        debug_assert!(
            self.kind().supports_comp_tracking(),
            "stitch tracking needs stable component ids (ConnKind::Leveled)"
        );
        with_db!(self, db => db.enable_stitch_tracking())
    }

    pub fn add_point(&mut self, x: &[f32]) -> PointId {
        with_db!(self, db => db.add_point(x))
    }

    pub fn add_point_with_keys(&mut self, x: &[f32], keys: &[BucketKey]) -> PointId {
        with_db!(self, db => db.add_point_with_keys(x, keys))
    }

    pub fn delete_point(&mut self, p: PointId) {
        with_db!(self, db => db.delete_point(p))
    }

    pub fn num_points(&self) -> usize {
        with_db!(self, db => db.num_points())
    }

    pub fn num_core_points(&self) -> usize {
        with_db!(self, db => db.num_core_points())
    }

    pub fn is_core(&self, p: PointId) -> bool {
        with_db!(self, db => db.is_core(p))
    }

    pub fn is_noise(&self, p: PointId) -> bool {
        with_db!(self, db => db.is_noise(p))
    }

    pub fn contains(&self, p: PointId) -> bool {
        with_db!(self, db => db.contains(p))
    }

    pub fn stable_cluster(&self, p: PointId) -> u64 {
        with_db!(self, db => db.stable_cluster(p))
    }

    pub fn drain_stitch_changes(&mut self, f: &mut dyn FnMut(PointId)) {
        with_db!(self, db => db.drain_stitch_changes(f))
    }

    pub fn repair_stats(&self) -> RepairStats {
        with_db!(self, db => db.repair_stats())
    }

    /// See [`DynamicDbscan::set_metrics`].
    pub fn set_metrics(&mut self, m: Arc<Metrics>) {
        with_db!(self, db => db.set_metrics(m))
    }

    /// See [`DynamicDbscan::live_vertices`].
    pub fn live_vertices(&self) -> usize {
        with_db!(self, db => db.live_vertices())
    }

    /// See [`DynamicDbscan::conn_level_live`].
    pub fn conn_level_live(&self) -> Vec<usize> {
        with_db!(self, db => db.conn_level_live())
    }

    /// See [`DynamicDbscan::conn_edge_count`].
    pub fn conn_edge_count(&self) -> usize {
        with_db!(self, db => db.conn_edge_count())
    }

    pub fn verify(&self) -> Result<(), invariants::InvariantError> {
        with_db!(self, db => db.verify())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{make_blobs, BlobsConfig};

    fn tight_cluster(center: f32, n: usize, dim: usize) -> Vec<Vec<f32>> {
        // n points within a tiny ball around `center`·1_d
        (0..n)
            .map(|i| (0..dim).map(|j| center + 1e-3 * (i + j) as f32).collect())
            .collect()
    }

    #[test]
    fn dense_region_becomes_one_cluster() {
        let cfg = DbscanConfig { k: 5, t: 8, eps: 0.5, dim: 3, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg, 42);
        let ids: Vec<_> = tight_cluster(0.0, 20, 3)
            .iter()
            .map(|p| db.add_point(p))
            .collect();
        assert!(db.num_core_points() >= 20 - 1, "tight ball must be core");
        let c0 = db.get_cluster(ids[0]);
        for &i in &ids {
            assert_eq!(db.get_cluster(i), c0, "point {i} in different cluster");
        }
    }

    #[test]
    fn distant_regions_are_distinct_clusters() {
        let cfg = DbscanConfig { k: 4, t: 8, eps: 0.3, dim: 2, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg, 7);
        let a: Vec<_> = tight_cluster(0.0, 10, 2)
            .iter()
            .map(|p| db.add_point(p))
            .collect();
        let b: Vec<_> = tight_cluster(100.0, 10, 2)
            .iter()
            .map(|p| db.add_point(p))
            .collect();
        assert_ne!(db.get_cluster(a[0]), db.get_cluster(b[0]));
        assert_eq!(db.get_cluster(a[3]), db.get_cluster(a[9]));
        assert_eq!(db.get_cluster(b[3]), db.get_cluster(b[9]));
    }

    #[test]
    fn sparse_points_are_noise() {
        let cfg = DbscanConfig { k: 5, t: 6, eps: 0.1, dim: 2, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg, 3);
        let mut ids = Vec::new();
        for i in 0..10 {
            ids.push(db.add_point(&[i as f32 * 50.0, -(i as f32) * 50.0]));
        }
        assert_eq!(db.num_core_points(), 0);
        let labels = db.labels_for(&ids);
        assert!(labels.iter().all(|&l| l == -1), "{labels:?}");
    }

    #[test]
    fn delete_reverses_add() {
        let cfg = DbscanConfig { k: 5, t: 8, eps: 0.5, dim: 3, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg, 42);
        let pts = tight_cluster(0.0, 30, 3);
        let ids: Vec<_> = pts.iter().map(|p| db.add_point(p)).collect();
        assert!(db.num_core_points() > 0);
        for &i in &ids {
            db.delete_point(i);
        }
        assert_eq!(db.num_points(), 0);
        assert_eq!(db.num_core_points(), 0);
        // structure stays usable
        let j = db.add_point(&pts[0]);
        assert!(db.contains(j));
    }

    #[test]
    fn delete_can_split_clusters() {
        // two tight balls joined by a bridge point; deleting the bridge
        // separates them (when the bridge was the only collision path).
        let cfg = DbscanConfig { k: 3, t: 10, eps: 0.6, dim: 1, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg, 11);
        let left: Vec<_> = (0..6).map(|i| vec![0.0 + 0.01 * i as f32]).collect();
        let right: Vec<_> = (0..6).map(|i| vec![2.0 + 0.01 * i as f32]).collect();
        let lids: Vec<_> = left.iter().map(|p| db.add_point(p)).collect();
        let rids: Vec<_> = right.iter().map(|p| db.add_point(p)).collect();
        // bridge cloud in the middle making everything one component
        let bids: Vec<_> =
            (0..6).map(|i| db.add_point(&[1.0 + 0.01 * i as f32])).collect();
        let one = db.get_cluster(lids[0]);
        if db.get_cluster(rids[0]) == one {
            // bridge connected them; removing the bridge must split them
            for &b in &bids {
                db.delete_point(b);
            }
            assert_ne!(db.get_cluster(lids[0]), db.get_cluster(rids[0]));
        }
    }

    #[test]
    fn labels_noise_and_dense() {
        let cfg = DbscanConfig { k: 4, t: 8, eps: 0.4, dim: 2, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg, 5);
        let mut ids = Vec::new();
        for p in tight_cluster(0.0, 10, 2) {
            ids.push(db.add_point(&p));
        }
        ids.push(db.add_point(&[500.0, 500.0])); // isolated noise
        let labels = db.labels_for(&ids);
        assert_eq!(labels[10], -1);
        assert!(labels[..10].iter().all(|&l| l == labels[0] && l >= 0));
    }

    #[test]
    fn blobs_end_to_end_quality() {
        // 3 well-separated blobs; ARI of the maintained labels ≈ 1
        let ds = make_blobs(
            &BlobsConfig {
                n: 900,
                dim: 4,
                clusters: 3,
                std: 0.3,
                center_box: 20.0,
                weights: vec![],
            },
            13,
        );
        let cfg = DbscanConfig {
            k: 8,
            t: 10,
            eps: 0.75,
            dim: 4,
            ..Default::default()
        };
        let mut db = DynamicDbscan::new(cfg, 99);
        let ids: Vec<_> = (0..ds.n()).map(|i| db.add_point(ds.point(i))).collect();
        let pred = db.labels_for(&ids);
        let ari = crate::metrics::adjusted_rand_index(&ds.labels, &pred);
        assert!(ari > 0.98, "ARI {ari} too low on separable blobs");
    }

    #[test]
    #[should_panic(expected = "delete of unknown point")]
    fn double_delete_panics() {
        let cfg = DbscanConfig { dim: 1, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg, 1);
        let p = db.add_point(&[0.0]);
        db.delete_point(p);
        db.delete_point(p);
    }

    #[test]
    fn slot_reuse_keeps_ids_unique() {
        // delete/re-add churn reuses arena slots but never re-issues an id
        let cfg = DbscanConfig { k: 3, t: 4, eps: 0.5, dim: 2, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg, 9);
        let mut seen = std::collections::HashSet::new();
        let mut live: Vec<u64> = Vec::new();
        for round in 0..50 {
            let p = db.add_point(&[round as f32 * 0.01, 0.0]);
            assert!(seen.insert(p), "id {p} issued twice");
            live.push(p);
            if round % 3 == 2 {
                let victim = live.remove(0);
                db.delete_point(victim);
                assert!(!db.contains(victim), "stale id must not resolve");
            }
        }
        // capacity is bounded by the high-water mark, not total inserts
        assert!(db.capacity_slots() <= 50);
        assert!(db.capacity_slots() >= db.live_slots());
    }
}

impl<C: Connectivity> DynamicDbscan<C> {
    /// Test-only structural dump: per-point (core?, attached_to) and per
    /// table the bucket membership, plus forest edge list between points.
    pub fn debug_dump(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let mut ids: Vec<PointId> = self.arena.ids().collect();
        ids.sort_unstable();
        for &p in &ids {
            let s = self.arena.slot_unchecked(p);
            write!(
                out,
                "p{p}(core={},att={:?}) ",
                self.arena.is_core(s),
                self.arena.attached_to(s)
            )
            .ok();
        }
        for (i, t) in self.tables.iter().enumerate() {
            write!(out, "| T{i}: ").ok();
            for (_, b) in t.iter() {
                let mut m: Vec<_> = b.members.iter().collect();
                m.sort();
                write!(out, "{m:?}c{:?} ", b.cores).ok();
            }
        }
        write!(out, "| edges: ").ok();
        for &a in &ids {
            for &b in &ids {
                if a < b {
                    let (va, vb) = (
                        self.arena.vertex(self.arena.slot_unchecked(a)),
                        self.arena.vertex(self.arena.slot_unchecked(b)),
                    );
                    if self.conn.has_tree_edge(va, vb) {
                        write!(out, "({a},{b}) ").ok();
                    }
                }
            }
        }
        out
    }
}
