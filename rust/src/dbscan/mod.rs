//! `DynamicDbscan` — Algorithm 2 of the paper, the system's core.
//!
//! Core points are defined through `t` grid-LSH hash functions
//! (Definition 4: `x` is core iff some bucket containing it has ≥ `k`
//! members). A spanning forest of the collision graph `H` is maintained in
//! an Euler-tour dynamic forest: within every bucket the core points form a
//! path in index order (unless an edge would close a cycle), bounding every
//! core's degree by `2t`; each non-core point attaches to at most one core
//! it collides with. `AddPoint`/`DeletePoint` run in
//! `O(t²k(d + log n))` = `O(d log³n + log⁴n)` for `t,k = O(log n)`
//! (Theorem 1) and preserve the spanning-forest invariant (Theorem 2 —
//! machine-checked by [`invariants`]).

pub mod connectivity;
pub mod invariants;

use rustc_hash::{FxHashMap, FxHashSet};

use crate::ett::{SkipForest, TreapForest, VertexId};
use crate::lsh::table::{LshTable, PointId};
use crate::lsh::{BucketKey, GridHasher};

pub use connectivity::{Connectivity, PaperConn, RepairConn, RepairStats};

/// Default connectivity: repaired spanning forest over skip-list ETT.
pub type DefaultConn = RepairConn<SkipForest>;
/// The paper's verbatim (unsound — see [`connectivity`]) behaviour.
pub type PaperExactConn = PaperConn<SkipForest>;
/// Repair mode over the treap (Henzinger–King) backend.
pub type TreapConn = RepairConn<TreapForest>;

/// Hyper-parameters (paper §5 uses k = 10, t = 10, ε = 0.75 throughout).
#[derive(Clone, Debug)]
pub struct DbscanConfig {
    /// core threshold: bucket size conferring core-ness
    pub k: usize,
    /// number of hash functions
    pub t: usize,
    /// neighborhood radius (bucket side = 2ε)
    pub eps: f32,
    /// data dimensionality
    pub dim: usize,
    /// extension (off = exact Algorithm 2): when a fresh core point arrives,
    /// adopt unattached non-core points in its buckets (O(t·k) extra work).
    pub eager_attach: bool,
}

impl Default for DbscanConfig {
    fn default() -> Self {
        DbscanConfig { k: 10, t: 10, eps: 0.75, dim: 2, eager_attach: false }
    }
}

struct PointState {
    x: Vec<f32>,
    /// bucket key per hash function (length t)
    keys: Vec<BucketKey>,
    vertex: VertexId,
    is_core: bool,
    /// non-core: the core point this point is attached to (≤ 1)
    attached_to: Option<PointId>,
    /// core: non-core points attached to this point
    attached: FxHashSet<PointId>,
}

/// Operation counters (exposed for the perf harness and the polylog
/// update-cost ablation A3).
#[derive(Clone, Debug, Default)]
pub struct OpStats {
    pub adds: u64,
    pub deletes: u64,
    pub promotions: u64,
    pub demotions: u64,
    pub forest_links: u64,
    pub forest_cuts: u64,
}

/// The dynamic clustering structure. Generic over the connectivity layer
/// (default: repaired spanning forest over the paper's skip-list Euler tour
/// sequences — see [`connectivity`] for why repair is needed).
pub struct DynamicDbscan<C: Connectivity = DefaultConn> {
    pub cfg: DbscanConfig,
    pub hasher: GridHasher,
    tables: Vec<LshTable>,
    conn: C,
    points: FxHashMap<PointId, PointState>,
    next_idx: PointId,
    n_core: usize,
    pub stats: OpStats,
    scratch: Vec<i32>,
}

impl DynamicDbscan<DefaultConn> {
    /// `Initialise(k, t, ε)` — O(t·d): draw the t hash shifts.
    pub fn new(cfg: DbscanConfig, seed: u64) -> Self {
        Self::with_conn(cfg, seed, RepairConn::new(SkipForest::new(seed ^ 0xF0E57)))
    }
}

impl DynamicDbscan<PaperExactConn> {
    /// Verbatim Algorithm 2 (unsound in a corner — see [`connectivity`]).
    pub fn paper_exact(cfg: DbscanConfig, seed: u64) -> Self {
        Self::with_conn(cfg, seed, PaperConn::new(SkipForest::new(seed ^ 0xF0E57)))
    }
}

impl<C: Connectivity> DynamicDbscan<C> {
    pub fn with_conn(cfg: DbscanConfig, seed: u64, conn: C) -> Self {
        let hasher = GridHasher::new(cfg.t, cfg.dim, cfg.eps, seed);
        let tables = (0..cfg.t).map(|_| LshTable::new()).collect();
        DynamicDbscan {
            cfg,
            hasher,
            tables,
            conn,
            points: FxHashMap::default(),
            next_idx: 0,
            n_core: 0,
            stats: OpStats::default(),
            scratch: Vec::new(),
        }
    }

    /// Construct with externally computed hash shifts (used when the XLA
    /// hashing engine owns the η vector — it must match `hasher.etas`).
    pub fn hasher_mut(&mut self) -> &mut GridHasher {
        &mut self.hasher
    }

    // ------------------------------------------------------------------
    // queries
    // ------------------------------------------------------------------

    pub fn num_points(&self) -> usize {
        self.points.len()
    }

    pub fn num_core_points(&self) -> usize {
        self.n_core
    }

    pub fn is_core(&self, p: PointId) -> bool {
        self.points.get(&p).map(|s| s.is_core).unwrap_or(false)
    }

    pub fn contains(&self, p: PointId) -> bool {
        self.points.contains_key(&p)
    }

    pub fn point_coords(&self, p: PointId) -> Option<&[f32]> {
        self.points.get(&p).map(|s| s.x.as_slice())
    }

    /// `GetCluster(x)`: canonical cluster identifier — O(log n). Stable
    /// between updates; noise points (unattached non-cores) are singleton
    /// clusters.
    pub fn get_cluster(&self, p: PointId) -> u64 {
        let st = &self.points[&p];
        self.conn.root(st.vertex)
    }

    /// Live point ids (unordered).
    pub fn point_ids(&self) -> impl Iterator<Item = PointId> + '_ {
        self.points.keys().copied()
    }

    /// True when `p` is currently live noise: non-core and unattached —
    /// the singleton case `labels_for` reports as −1 (false for unknown
    /// ids, like [`Self::is_core`]). Used by the sharded engine's
    /// stitcher to decide which replicas carry cluster identity.
    pub fn is_noise(&self, p: PointId) -> bool {
        self.points
            .get(&p)
            .map(|st| !st.is_core && st.attached_to.is_none())
            .unwrap_or(false)
    }

    /// Dense labels for a set of points: clusters numbered 0.., noise
    /// (unattached non-core singletons) labeled −1 to match sklearn
    /// conventions in the metrics.
    pub fn labels_for(&self, ids: &[PointId]) -> Vec<i64> {
        let mut roots: FxHashMap<u64, i64> = FxHashMap::default();
        let mut out = Vec::with_capacity(ids.len());
        for &p in ids {
            let st = &self.points[&p];
            if !st.is_core && st.attached_to.is_none() {
                out.push(-1);
                continue;
            }
            let r = self.conn.root(st.vertex);
            let next = roots.len() as i64;
            out.push(*roots.entry(r).or_insert(next));
        }
        out
    }

    // ------------------------------------------------------------------
    // AddPoint
    // ------------------------------------------------------------------

    /// `AddPoint(x)` with natively computed hash keys.
    pub fn add_point(&mut self, x: &[f32]) -> PointId {
        let keys = {
            let mut scratch = std::mem::take(&mut self.scratch);
            let keys = self.hasher.keys(x, &mut scratch);
            self.scratch = scratch;
            keys
        };
        self.add_point_with_keys(x, keys)
    }

    /// `AddPoint(x)` with precomputed bucket keys (the XLA-artifact hashing
    /// path; keys must come from the same η/ε as `self.hasher`).
    pub fn add_point_with_keys(&mut self, x: &[f32], keys: Vec<BucketKey>) -> PointId {
        assert_eq!(x.len(), self.cfg.dim, "point dimensionality mismatch");
        assert_eq!(keys.len(), self.cfg.t);
        self.stats.adds += 1;
        let idx = self.next_idx;
        self.next_idx += 1;
        let vertex = self.conn.add_vertex();
        // bucket insertion + new-core detection (Algorithm 2 lines 6-11)
        let mut newly_core: Vec<PointId> = Vec::new();
        let mut self_core = false;
        for i in 0..self.cfg.t {
            let size = self.tables[i].insert(keys[i], idx);
            if size > self.cfg.k {
                self_core = true;
            } else if size == self.cfg.k {
                // the whole bucket crosses the threshold
                self_core = true;
                let b = self.tables[i].bucket(keys[i]);
                for &y in &b.members {
                    if y != idx && !self.points[&y].is_core {
                        newly_core.push(y);
                    }
                }
            }
        }
        self.points.insert(
            idx,
            PointState {
                x: x.to_vec(),
                keys,
                vertex,
                is_core: false,
                attached_to: None,
                attached: FxHashSet::default(),
            },
        );
        if self_core {
            newly_core.push(idx);
        }
        newly_core.sort_unstable();
        newly_core.dedup();
        // promote + link each new core (lines 12-14)
        for &c in &newly_core {
            self.promote(c);
        }
        if !self_core {
            // line 15-16
            self.link_non_core(idx);
        } else if self.cfg.eager_attach {
            self.eager_attach(idx);
        }
        idx
    }

    /// Mark `c` core in all its buckets, then splice it into each bucket's
    /// core path (`LinkCorePoint`, lines 28-35).
    fn promote(&mut self, c: PointId) {
        debug_assert!(!self.points[&c].is_core);
        self.stats.promotions += 1;
        self.n_core += 1;
        let keys = self.points[&c].keys.clone();
        for (i, &key) in keys.iter().enumerate() {
            self.tables[i].mark_core(key, c);
        }
        self.points.get_mut(&c).unwrap().is_core = true;
        // line 29: cut any edge incident to c (it was non-core: ≤ 1 edge)
        if let Some(h) = self.points.get_mut(&c).unwrap().attached_to.take() {
            let (vc, vh) = (self.points[&c].vertex, self.points[&h].vertex);
            self.conn.undesire(vc, vh);
            self.stats.forest_cuts += 1;
            self.points.get_mut(&h).unwrap().attached.remove(&c);
        }
        // lines 30-35: splice into the idx-ordered core path of each bucket
        let vc = self.points[&c].vertex;
        for (i, &key) in keys.iter().enumerate() {
            let b = self.tables[i].bucket(key);
            let c1 = b.core_pred(c);
            let c2 = b.core_succ(c);
            // Desire the new path edges before retracting (c1,c2) so the
            // retraction's replacement is found in O(1) via the hint.
            let v1 = c1.map(|c| self.points[&c].vertex);
            let v2 = c2.map(|c| self.points[&c].vertex);
            if let Some(v1) = v1 {
                self.conn.desire(v1, vc);
                self.stats.forest_links += 1;
            }
            if let Some(v2) = v2 {
                self.conn.desire(vc, v2);
                self.stats.forest_links += 1;
            }
            if let (Some(v1), Some(v2)) = (v1, v2) {
                self.conn.undesire_hinted(v1, v2, &[(v1, vc), (vc, v2)]);
                self.stats.forest_cuts += 1;
            }
        }
    }

    /// `LinkNonCorePoint` (lines 44-45): attach to one colliding core.
    fn link_non_core(&mut self, p: PointId) {
        debug_assert!(!self.points[&p].is_core);
        debug_assert!(self.points[&p].attached_to.is_none());
        let keys = &self.points[&p].keys;
        let mut target = None;
        for (i, &key) in keys.iter().enumerate() {
            if let Some(b) = self.tables[i].get(key) {
                if let Some(c) = b.any_core_not(p) {
                    target = Some(c);
                    break;
                }
            }
        }
        if let Some(c) = target {
            let (vp, vc) = (self.points[&p].vertex, self.points[&c].vertex);
            self.conn.desire(vp, vc);
            self.stats.forest_links += 1;
            self.points.get_mut(&p).unwrap().attached_to = Some(c);
            self.points.get_mut(&c).unwrap().attached.insert(p);
        }
    }

    /// Extension: adopt unattached non-core points in the buckets of the
    /// fresh core `c`.
    fn eager_attach(&mut self, c: PointId) {
        let keys = self.points[&c].keys.clone();
        let mut orphans: Vec<PointId> = Vec::new();
        for (i, &key) in keys.iter().enumerate() {
            if let Some(b) = self.tables[i].get(key) {
                for &y in &b.members {
                    if y != c {
                        let st = &self.points[&y];
                        if !st.is_core && st.attached_to.is_none() {
                            orphans.push(y);
                        }
                    }
                }
            }
        }
        orphans.sort_unstable();
        orphans.dedup();
        for y in orphans {
            self.link_non_core(y);
        }
    }

    // ------------------------------------------------------------------
    // DeletePoint
    // ------------------------------------------------------------------

    /// `DeletePoint(x)` (lines 17-27).
    pub fn delete_point(&mut self, p: PointId) {
        assert!(self.points.contains_key(&p), "delete of unknown point {p}");
        self.stats.deletes += 1;
        let is_core = self.points[&p].is_core;
        if is_core {
            // line 19-22: cores demoted by this removal — y loses core-ness
            // iff after removing x from every bucket, none of y's buckets
            // has ≥ k members.
            let keys = self.points[&p].keys.clone();
            let mut demoted: Vec<PointId> = Vec::new();
            for (i, &key) in keys.iter().enumerate() {
                let b = self.tables[i].bucket(key);
                if b.len() == self.cfg.k {
                    for &y in &b.members {
                        if y != p
                            && self.points[&y].is_core
                            && !self.still_core_without(y, p)
                        {
                            demoted.push(y);
                        }
                    }
                }
            }
            demoted.sort_unstable();
            demoted.dedup();
            // unlink x itself first (its pred/succ computed while it is
            // still marked), re-link its attached non-cores elsewhere
            self.unlink_core(p);
            self.demote_marks(p);
            self.reattach_orphans_of(p);
            // drop x from all buckets before processing the demotions
            let keys_p = self.points[&p].keys.clone();
            for (i, &key) in keys_p.iter().enumerate() {
                self.tables[i].remove(key, p);
            }
            // lines 23-26
            for c in demoted {
                self.unlink_core(c);
                self.demote_marks(c);
                self.reattach_orphans_of(c);
                self.link_non_core(c);
            }
        } else {
            if let Some(h) = self.points.get_mut(&p).unwrap().attached_to.take() {
                let (vp, vh) = (self.points[&p].vertex, self.points[&h].vertex);
                self.conn.undesire(vp, vh);
                self.stats.forest_cuts += 1;
                self.points.get_mut(&h).unwrap().attached.remove(&p);
            }
            let keys = self.points[&p].keys.clone();
            for (i, &key) in keys.iter().enumerate() {
                self.tables[i].remove(key, p);
            }
        }
        // line 27: remove x from G and the point store
        let st = self.points.remove(&p).unwrap();
        debug_assert_eq!(
            self.conn.tree_degree(st.vertex),
            0,
            "point {p} still has forest edges at removal"
        );
        self.conn.remove_vertex(st.vertex);
    }

    /// Would `y` still be core after removing `x` from every bucket?
    fn still_core_without(&self, y: PointId, x: PointId) -> bool {
        let sy = &self.points[&y];
        let sx = &self.points[&x];
        for (i, &key) in sy.keys.iter().enumerate() {
            let len = self.tables[i].bucket(key).len();
            let contains_x = sx.keys[i] == key;
            if len - usize::from(contains_x) >= self.cfg.k {
                return true;
            }
        }
        false
    }

    /// `UnlinkCorePoint` (lines 36-42): remove `c` from every bucket's core
    /// path, bridging its neighbors.
    fn unlink_core(&mut self, c: PointId) {
        debug_assert!(self.points[&c].is_core);
        let keys = self.points[&c].keys.clone();
        let vc = self.points[&c].vertex;
        for (i, &key) in keys.iter().enumerate() {
            let b = self.tables[i].bucket(key);
            let c1 = b.core_pred(c);
            let c2 = b.core_succ(c);
            // Bridge (c1,c2) first so the two retractions below repair
            // through the hint instead of a component walk.
            let v1 = c1.map(|c| self.points[&c].vertex);
            let v2 = c2.map(|c| self.points[&c].vertex);
            let mut hints: Vec<(VertexId, VertexId)> = Vec::with_capacity(1);
            if let (Some(v1), Some(v2)) = (v1, v2) {
                self.conn.desire(v1, v2);
                self.stats.forest_links += 1;
                hints.push((v1, v2));
            }
            if let Some(v1) = v1 {
                self.conn.undesire_hinted(v1, vc, &hints);
                self.stats.forest_cuts += 1;
            }
            if let Some(v2) = v2 {
                self.conn.undesire_hinted(vc, v2, &hints);
                self.stats.forest_cuts += 1;
            }
        }
    }

    /// Clear core marks of `c` in all tables and flip its flag.
    fn demote_marks(&mut self, c: PointId) {
        self.stats.demotions += 1;
        self.n_core -= 1;
        let keys = self.points[&c].keys.clone();
        for (i, &key) in keys.iter().enumerate() {
            self.tables[i].unmark_core(key, c);
        }
        self.points.get_mut(&c).unwrap().is_core = false;
    }

    /// Line 43 / 26: re-link every non-core point that was attached to `c`.
    fn reattach_orphans_of(&mut self, c: PointId) {
        let orphans: Vec<PointId> =
            self.points.get_mut(&c).unwrap().attached.drain().collect();
        let vc = self.points[&c].vertex;
        for nc in orphans {
            let vn = self.points[&nc].vertex;
            self.conn.undesire(vc, vn);
            self.stats.forest_cuts += 1;
            self.points.get_mut(&nc).unwrap().attached_to = None;
            self.link_non_core(nc);
        }
    }

    // ------------------------------------------------------------------
    // introspection for invariants / benches
    // ------------------------------------------------------------------

    pub(crate) fn conn(&self) -> &C {
        &self.conn
    }

    /// Replacement-search counters from the connectivity layer.
    pub fn repair_stats(&self) -> RepairStats {
        self.conn.repair_stats()
    }

    pub(crate) fn tables(&self) -> &[LshTable] {
        &self.tables
    }

    pub(crate) fn point_state(
        &self,
        p: PointId,
    ) -> (bool, Option<PointId>, &FxHashSet<PointId>, VertexId) {
        let st = &self.points[&p];
        (st.is_core, st.attached_to, &st.attached, st.vertex)
    }

    pub(crate) fn point_keys(&self, p: PointId) -> &[BucketKey] {
        &self.points[&p].keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{make_blobs, BlobsConfig};

    fn tight_cluster(center: f32, n: usize, dim: usize) -> Vec<Vec<f32>> {
        // n points within a tiny ball around `center`·1_d
        (0..n)
            .map(|i| (0..dim).map(|j| center + 1e-3 * (i + j) as f32).collect())
            .collect()
    }

    #[test]
    fn dense_region_becomes_one_cluster() {
        let cfg = DbscanConfig { k: 5, t: 8, eps: 0.5, dim: 3, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg, 42);
        let ids: Vec<_> = tight_cluster(0.0, 20, 3)
            .iter()
            .map(|p| db.add_point(p))
            .collect();
        assert!(db.num_core_points() >= 20 - 1, "tight ball must be core");
        let c0 = db.get_cluster(ids[0]);
        for &i in &ids {
            assert_eq!(db.get_cluster(i), c0, "point {i} in different cluster");
        }
    }

    #[test]
    fn distant_regions_are_distinct_clusters() {
        let cfg = DbscanConfig { k: 4, t: 8, eps: 0.3, dim: 2, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg, 7);
        let a: Vec<_> = tight_cluster(0.0, 10, 2)
            .iter()
            .map(|p| db.add_point(p))
            .collect();
        let b: Vec<_> = tight_cluster(100.0, 10, 2)
            .iter()
            .map(|p| db.add_point(p))
            .collect();
        assert_ne!(db.get_cluster(a[0]), db.get_cluster(b[0]));
        assert_eq!(db.get_cluster(a[3]), db.get_cluster(a[9]));
        assert_eq!(db.get_cluster(b[3]), db.get_cluster(b[9]));
    }

    #[test]
    fn sparse_points_are_noise() {
        let cfg = DbscanConfig { k: 5, t: 6, eps: 0.1, dim: 2, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg, 3);
        let mut ids = Vec::new();
        for i in 0..10 {
            ids.push(db.add_point(&[i as f32 * 50.0, -(i as f32) * 50.0]));
        }
        assert_eq!(db.num_core_points(), 0);
        let labels = db.labels_for(&ids);
        assert!(labels.iter().all(|&l| l == -1), "{labels:?}");
    }

    #[test]
    fn delete_reverses_add() {
        let cfg = DbscanConfig { k: 5, t: 8, eps: 0.5, dim: 3, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg, 42);
        let pts = tight_cluster(0.0, 30, 3);
        let ids: Vec<_> = pts.iter().map(|p| db.add_point(p)).collect();
        assert!(db.num_core_points() > 0);
        for &i in &ids {
            db.delete_point(i);
        }
        assert_eq!(db.num_points(), 0);
        assert_eq!(db.num_core_points(), 0);
        // structure stays usable
        let j = db.add_point(&pts[0]);
        assert!(db.contains(j));
    }

    #[test]
    fn delete_can_split_clusters() {
        // two tight balls joined by a bridge point; deleting the bridge
        // separates them (when the bridge was the only collision path).
        let cfg = DbscanConfig { k: 3, t: 10, eps: 0.6, dim: 1, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg, 11);
        let left: Vec<_> = (0..6).map(|i| vec![0.0 + 0.01 * i as f32]).collect();
        let right: Vec<_> = (0..6).map(|i| vec![2.0 + 0.01 * i as f32]).collect();
        let lids: Vec<_> = left.iter().map(|p| db.add_point(p)).collect();
        let rids: Vec<_> = right.iter().map(|p| db.add_point(p)).collect();
        // bridge cloud in the middle making everything one component
        let bids: Vec<_> =
            (0..6).map(|i| db.add_point(&[1.0 + 0.01 * i as f32])).collect();
        let one = db.get_cluster(lids[0]);
        if db.get_cluster(rids[0]) == one {
            // bridge connected them; removing the bridge must split them
            for &b in &bids {
                db.delete_point(b);
            }
            assert_ne!(db.get_cluster(lids[0]), db.get_cluster(rids[0]));
        }
    }

    #[test]
    fn labels_noise_and_dense() {
        let cfg = DbscanConfig { k: 4, t: 8, eps: 0.4, dim: 2, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg, 5);
        let mut ids = Vec::new();
        for p in tight_cluster(0.0, 10, 2) {
            ids.push(db.add_point(&p));
        }
        ids.push(db.add_point(&[500.0, 500.0])); // isolated noise
        let labels = db.labels_for(&ids);
        assert_eq!(labels[10], -1);
        assert!(labels[..10].iter().all(|&l| l == labels[0] && l >= 0));
    }

    #[test]
    fn blobs_end_to_end_quality() {
        // 3 well-separated blobs; ARI of the maintained labels ≈ 1
        let ds = make_blobs(
            &BlobsConfig {
                n: 900,
                dim: 4,
                clusters: 3,
                std: 0.3,
                center_box: 20.0,
                weights: vec![],
            },
            13,
        );
        let cfg = DbscanConfig {
            k: 8,
            t: 10,
            eps: 0.75,
            dim: 4,
            ..Default::default()
        };
        let mut db = DynamicDbscan::new(cfg, 99);
        let ids: Vec<_> = (0..ds.n()).map(|i| db.add_point(ds.point(i))).collect();
        let pred = db.labels_for(&ids);
        let ari = crate::metrics::adjusted_rand_index(&ds.labels, &pred);
        assert!(ari > 0.98, "ARI {ari} too low on separable blobs");
    }

    #[test]
    #[should_panic(expected = "delete of unknown point")]
    fn double_delete_panics() {
        let cfg = DbscanConfig { dim: 1, ..Default::default() };
        let mut db = DynamicDbscan::new(cfg, 1);
        let p = db.add_point(&[0.0]);
        db.delete_point(p);
        db.delete_point(p);
    }
}

impl<C: Connectivity> DynamicDbscan<C> {
    /// Test-only structural dump: per-point (core?, attached_to) and per
    /// table the bucket membership, plus forest edge list between points.
    pub fn debug_dump(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let mut ids: Vec<PointId> = self.points.keys().copied().collect();
        ids.sort_unstable();
        for &p in &ids {
            let st = &self.points[&p];
            write!(s, "p{p}(core={},att={:?}) ", st.is_core, st.attached_to).ok();
        }
        for (i, t) in self.tables.iter().enumerate() {
            write!(s, "| T{i}: ").ok();
            for (_, b) in t.iter() {
                let mut m: Vec<_> = b.members.iter().collect();
                m.sort();
                write!(s, "{m:?}c{:?} ", b.cores).ok();
            }
        }
        write!(s, "| edges: ").ok();
        for &a in &ids {
            for &b in &ids {
                if a < b
                    && self
                        .conn
                        .has_tree_edge(self.points[&a].vertex, self.points[&b].vertex)
                {
                    write!(s, "({a},{b}) ").ok();
                }
            }
        }
        s
    }
}
